"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # not installed: deterministic fixed-seed fallback
    from repro.testing.hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.embedding_lookup import embedding_lookup_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_adagrad import adagrad_pallas
from repro.kernels.scatter_add import scatter_add_pallas


# ---------------------------------------------------------------- lookup
@pytest.mark.parametrize("N,D,B", [(16, 128, 8), (64, 256, 32), (128, 512, 7), (32, 2048, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_lookup_sweep(N, D, B, dtype):
    key = jax.random.PRNGKey(N + D + B)
    table = jax.random.normal(key, (N, D), dtype)
    ids = jax.random.randint(key, (B,), 0, N)
    out = embedding_lookup_pallas(table, ids, interpret=True)
    np.testing.assert_array_equal(out, ref.embedding_lookup_ref(table, ids))


# ---------------------------------------------------------------- scatter
@pytest.mark.parametrize("N,D,B", [(16, 128, 8), (64, 256, 64), (8, 512, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scatter_add_with_duplicates(N, D, B, dtype):
    key = jax.random.PRNGKey(N * D + B)
    table = jax.random.normal(key, (N, D), jnp.float32).astype(dtype)
    ids = jax.random.randint(key, (B,), 0, N)  # heavy duplication when B > N
    grads = jax.random.normal(jax.random.fold_in(key, 1), (B, D), jnp.float32).astype(dtype)
    out = ops.scatter_add(table, ids, grads, use_pallas=True, interpret=True)
    expect = ref.scatter_add_ref(table, ids, grads)
    atol = 1e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=atol, rtol=atol
    )


@given(st.integers(1, 40), st.integers(2, 30))
@settings(max_examples=20, deadline=None)
def test_scatter_add_property(B, N):
    key = jax.random.PRNGKey(B * 31 + N)
    D = 128
    table = jnp.zeros((N, D), jnp.float32)
    ids = jax.random.randint(key, (B,), 0, N)
    grads = jnp.ones((B, D), jnp.float32)
    out = ops.scatter_add(table, ids, grads, use_pallas=True, interpret=True)
    counts = np.bincount(np.asarray(ids), minlength=N).astype(np.float32)
    np.testing.assert_allclose(np.asarray(out[:, 0]), counts)


# ---------------------------------------------------------------- adagrad
@pytest.mark.parametrize("B,D", [(8, 128), (256, 512), (16, 1024)])
def test_fused_adagrad(B, D):
    key = jax.random.PRNGKey(B + D)
    p = jax.random.normal(key, (B, D))
    a = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (B, D)))
    g = jax.random.normal(jax.random.fold_in(key, 2), (B, D))
    p1, a1 = adagrad_pallas(p, a, g, 0.1, interpret=True)
    p2, a2 = ref.adagrad_ref(p, a, g, 0.1)
    np.testing.assert_allclose(p1, p2, atol=1e-6)
    np.testing.assert_allclose(a1, a2, atol=1e-6)


# ---------------------------------------------------------------- attention
CASES = [
    # B, H, Hkv, Sq, Skv, Dh, causal, window, q_offset
    (2, 4, 2, 128, 128, 32, True, 0, 0),
    (1, 4, 1, 256, 256, 16, True, 0, 0),
    (1, 2, 2, 128, 256, 32, False, 0, 0),
    (2, 4, 2, 128, 256, 64, True, 64, 128),
    (1, 1, 1, 1, 128, 32, True, 0, 127),
    (1, 8, 4, 128, 128, 128, True, 32, 0),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_attention_vs_ref(case):
    B, H, Hkv, Sq, Skv, Dh, causal, window, qoff = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case[:6])), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, Skv, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, Skv, Dh))
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=qoff, interpret=True
    )
    expect = ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", CASES)
def test_blockwise_attention_vs_ref(case):
    B, H, Hkv, Sq, Skv, Dh, causal, window, qoff = case
    ks = jax.random.split(jax.random.PRNGKey(1 + sum(case[:6])), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, Skv, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, Skv, Dh))
    out = ops.attention_blockwise(q, k, v, causal=causal, window=window, q_offset=qoff, block_k=64)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_blockwise_gradients_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32))
    k = jax.random.normal(ks[1], (1, 1, 64, 32))
    v = jax.random.normal(ks[2], (1, 1, 64, 32))
    g1 = jax.grad(lambda *a: ops.attention_blockwise(*a, causal=True, block_k=16).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: ref.attention_ref(*a, causal=True).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


def test_flash_custom_vjp_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 1, 128, 32))
    v = jax.random.normal(ks[2], (1, 1, 128, 32))
    g1 = jax.grad(lambda *a: ops.attention(*a, causal=True, impl="flash").sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: ref.attention_ref(*a, causal=True).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


def test_kv_len_masking_matches_truncation():
    """kv_len masking == physically truncating the cache."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (2, 2, 1, 32))
    k = jax.random.normal(ks[1], (2, 2, 64, 32))
    v = jax.random.normal(ks[2], (2, 2, 64, 32))
    out = ref.attention_ref(q, k, v, causal=False, kv_len=40)
    exp = ref.attention_ref(q, k[:, :, :40], v[:, :, :40], causal=False)
    np.testing.assert_allclose(out, exp, atol=1e-6)
    out_b = ops.attention_blockwise(q, k, v, causal=False, kv_len=40, block_k=16)
    np.testing.assert_allclose(out_b, exp, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- gmm (MoE)
@pytest.mark.parametrize(
    "E,K,N,sizes",
    [
        (4, 128, 128, [100, 0, 300, 56]),
        (3, 256, 128, [128, 128, 128]),
        (5, 128, 256, [7, 250, 1, 0, 130]),
    ],
)
def test_gmm_vs_ref(E, K, N, sizes):
    key = jax.random.PRNGKey(E * K + N)
    T = sum(sizes)
    x = jax.random.normal(key, (T, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, K, N)) * 0.1
    gs = jnp.array(sizes, jnp.int32)
    out = ops.gmm(x, w, gs, use_pallas=True, interpret=True)
    np.testing.assert_allclose(out, ref.gmm_ref(x, w, gs), atol=2e-4, rtol=2e-4)


@given(st.lists(st.integers(0, 60), min_size=2, max_size=6))
@settings(max_examples=10, deadline=None)
def test_gmm_property_group_isolation(sizes):
    """Zeroing one expert's weights zeroes exactly that group's rows."""
    E = len(sizes)
    T = sum(sizes)
    if T == 0:
        return
    key = jax.random.PRNGKey(sum(sizes))
    x = jax.random.normal(key, (T, 128))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, 128, 128))
    w = w.at[0].set(0.0)
    gs = jnp.array(sizes, jnp.int32)
    out = np.asarray(ops.gmm(x, w, gs, use_pallas=True, interpret=True))
    assert np.allclose(out[: sizes[0]], 0.0)
    if T > sizes[0]:
        assert not np.allclose(out[sizes[0] :], 0.0) or sizes[0] == T
