"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # not installed: deterministic fixed-seed fallback
    from repro.testing.hypothesis_fallback import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.embedding_lookup import embedding_lookup_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_adagrad import adagrad_pallas
from repro.kernels.scatter_add import scatter_add_pallas


# ---------------------------------------------------------------- lookup
@pytest.mark.parametrize("N,D,B", [(16, 128, 8), (64, 256, 32), (128, 512, 7), (32, 2048, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_lookup_sweep(N, D, B, dtype):
    key = jax.random.PRNGKey(N + D + B)
    table = jax.random.normal(key, (N, D), dtype)
    ids = jax.random.randint(key, (B,), 0, N)
    out = embedding_lookup_pallas(table, ids, interpret=True)
    np.testing.assert_array_equal(out, ref.embedding_lookup_ref(table, ids))


# ---------------------------------------------------------------- scatter
@pytest.mark.parametrize("N,D,B", [(16, 128, 8), (64, 256, 64), (8, 512, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scatter_add_with_duplicates(N, D, B, dtype):
    key = jax.random.PRNGKey(N * D + B)
    table = jax.random.normal(key, (N, D), jnp.float32).astype(dtype)
    ids = jax.random.randint(key, (B,), 0, N)  # heavy duplication when B > N
    grads = jax.random.normal(jax.random.fold_in(key, 1), (B, D), jnp.float32).astype(dtype)
    out = ops.scatter_add(table, ids, grads, use_pallas=True, interpret=True)
    expect = ref.scatter_add_ref(table, ids, grads)
    atol = 1e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=atol, rtol=atol
    )


@given(st.integers(1, 40), st.integers(2, 30))
@settings(max_examples=20, deadline=None)
def test_scatter_add_property(B, N):
    key = jax.random.PRNGKey(B * 31 + N)
    D = 128
    table = jnp.zeros((N, D), jnp.float32)
    ids = jax.random.randint(key, (B,), 0, N)
    grads = jnp.ones((B, D), jnp.float32)
    out = ops.scatter_add(table, ids, grads, use_pallas=True, interpret=True)
    counts = np.bincount(np.asarray(ids), minlength=N).astype(np.float32)
    np.testing.assert_allclose(np.asarray(out[:, 0]), counts)


# ---------------------------------------------------------------- embedding bag
def _bag_inputs(B, nnz, n_slots, emb, dtype=jnp.float32, seed=None):
    key = jax.random.PRNGKey(B * 7 + nnz * 3 + n_slots + emb if seed is None else seed)
    N = max(8, 2 * B)
    table = jax.random.normal(key, (N, emb), jnp.float32).astype(dtype)
    ids = jax.random.randint(jax.random.fold_in(key, 1), (B, nnz), 0, N)
    slot_of = jax.random.randint(jax.random.fold_in(key, 2), (B, nnz), 0, n_slots)
    valid = jax.random.bernoulli(jax.random.fold_in(key, 3), 0.8, (B, nnz))
    return table, ids, slot_of, valid


BAG_SHAPES = [
    # B, nnz, n_slots, emb
    (4, 12, 6, 8),
    (8, 1, 1, 16),
    (16, 32, 8, 4),
    (2, 64, 16, 128),
    (8, 16, 32, 256),  # emb > block tile: exercises d-tiling
    (4, 8, 4, 96),  # emb not a divisor of the default tile: gcd tiling
]


@pytest.mark.parametrize("B,nnz,n_slots,emb", BAG_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_pallas_sweep(B, nnz, n_slots, emb, dtype):
    table, ids, slot_of, valid = _bag_inputs(B, nnz, n_slots, emb, dtype)
    out = embedding_bag_pallas(
        table, ids, slot_of, valid, n_slots=n_slots, block_d=128, interpret=True
    )
    expect = ref.embedding_bag_ref(table, ids, slot_of, valid, n_slots)
    atol = 1e-5 if dtype == jnp.float32 else 0.1
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32), atol=atol, rtol=atol
    )


@pytest.mark.parametrize("B,nnz,n_slots,emb", BAG_SHAPES)
def test_embedding_bag_portable_sweep(B, nnz, n_slots, emb):
    """The segment-sum fallback (the production path off-TPU) vs the oracle."""
    table, ids, slot_of, valid = _bag_inputs(B, nnz, n_slots, emb)
    out = ops.embedding_bag(table, ids, slot_of, valid, n_slots, use_pallas=False)
    expect = ref.embedding_bag_ref(table, ids, slot_of, valid, n_slots)
    np.testing.assert_allclose(out, expect, atol=1e-5, rtol=1e-5)


@given(st.integers(1, 16), st.integers(1, 24), st.integers(1, 12))
@settings(max_examples=15, deadline=None)
def test_embedding_bag_property(B, nnz, n_slots):
    """All-valid ones-table: pooled[b, s] counts the nonzeros in slot s."""
    table = jnp.ones((32, 8), jnp.float32)
    key = jax.random.PRNGKey(B * 131 + nnz * 17 + n_slots)
    ids = jax.random.randint(key, (B, nnz), 0, 32)
    slot_of = jax.random.randint(jax.random.fold_in(key, 1), (B, nnz), 0, n_slots)
    valid = jnp.ones((B, nnz), bool)
    out = np.asarray(ops.embedding_bag(table, ids, slot_of, valid, n_slots, use_pallas=False))
    for b in range(B):
        counts = np.bincount(np.asarray(slot_of[b]), minlength=n_slots)
        np.testing.assert_allclose(out[b, :, 0], counts)


def test_embedding_bag_float_mask_consistent_across_paths():
    """valid is a MASK (!= 0), not weights: a float mask must pool the same
    on the Pallas and portable paths."""
    table, ids, slot_of, _ = _bag_inputs(4, 8, 4, 8, seed=11)
    fmask = jnp.array(np.random.default_rng(0).choice([0.0, 0.5, 1.0], (4, 8)))
    a = ops.embedding_bag(table, ids, slot_of, fmask, 4, use_pallas=False)
    b = ops.embedding_bag(table, ids, slot_of, fmask, 4, use_pallas=True, interpret=True)
    np.testing.assert_allclose(a, b, atol=1e-6)
    expect = ref.embedding_bag_ref(table, ids, slot_of, fmask != 0, 4)
    np.testing.assert_allclose(a, expect, atol=1e-6)


def test_embedding_bag_grad_bitwise_vs_ref_autodiff():
    """The custom VJP (take_along_axis + scatter_add) must equal autodiff
    through the dense one-hot/einsum chain BITWISE for f32."""
    table, ids, slot_of, valid = _bag_inputs(8, 24, 6, 16, seed=42)
    g1 = jax.grad(
        lambda t: (ops.embedding_bag(t, ids, slot_of, valid, 6, use_pallas=False).sum()) ** 2
    )(table)
    g2 = jax.grad(
        lambda t: (ref.embedding_bag_ref(t, ids, slot_of, valid, 6).sum()) ** 2
    )(table)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))


def test_embedding_bag_grad_pallas_path():
    """grad through the Pallas forward + sorted-scatter backward vs ref."""
    table, ids, slot_of, valid = _bag_inputs(4, 12, 4, 8, seed=3)
    g1 = jax.grad(
        lambda t: ops.embedding_bag(
            t, ids, slot_of, valid, 4, use_pallas=True, interpret=True
        ).sum()
    )(table)
    g2 = jax.grad(lambda t: ref.embedding_bag_ref(t, ids, slot_of, valid, 4).sum())(table)
    np.testing.assert_allclose(g1, g2, atol=1e-5, rtol=1e-5)


def test_grouped_forward_matches_seed_math():
    """forward_grouped through the fused op == the seed one-hot/einsum math,
    loss included (the hetero multi-table device step is unchanged)."""
    from repro.configs.ctr_models import TINY_HETERO
    from repro.models import ctr as ctr_model

    cfg = TINY_HETERO
    key = jax.random.PRNGKey(0)
    tower = ctr_model.init_tower(cfg, key)
    B = 32
    tables, inputs = {}, {}
    for gi, g in enumerate(cfg.groups):
        k = jax.random.fold_in(key, gi + 1)
        n_working = 64
        tables[g.name] = jax.random.normal(k, (n_working, g.emb_dim))
        inputs[g.name] = {
            "slot_ids": jax.random.randint(jax.random.fold_in(k, 1), (B, 8), 0, n_working),
            "slot_of": jax.random.randint(jax.random.fold_in(k, 2), (B, 8), 0, g.n_slots),
            "valid": jax.random.bernoulli(jax.random.fold_in(k, 3), 0.9, (B, 8)),
        }
    labels = jnp.asarray(np.random.default_rng(0).integers(0, 2, B), jnp.float32)

    logits = ctr_model.forward_grouped(cfg, tower, tables, inputs)

    seed_tower = lambda h: ctr_model._tower_mlp(tower, h)
    pooled = [
        ref.embedding_bag_ref(
            tables[g.name], inputs[g.name]["slot_ids"], inputs[g.name]["slot_of"],
            inputs[g.name]["valid"], g.n_slots,
        ).reshape(B, -1)
        for g in cfg.groups
    ]
    seed_logits = seed_tower(jnp.concatenate(pooled, axis=-1))
    np.testing.assert_allclose(logits, seed_logits, atol=1e-6, rtol=1e-6)
    loss = ctr_model.loss_fn_grouped(cfg, tower, tables, inputs, labels)
    seed_bce = ctr_model._bce_with_logits(seed_logits, labels)
    np.testing.assert_allclose(loss, seed_bce, atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------- adagrad
@pytest.mark.parametrize("B,D", [(8, 128), (256, 512), (16, 1024)])
def test_fused_adagrad(B, D):
    key = jax.random.PRNGKey(B + D)
    p = jax.random.normal(key, (B, D))
    a = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (B, D)))
    g = jax.random.normal(jax.random.fold_in(key, 2), (B, D))
    p1, a1 = adagrad_pallas(p, a, g, 0.1, interpret=True)
    p2, a2 = ref.adagrad_ref(p, a, g, 0.1)
    np.testing.assert_allclose(p1, p2, atol=1e-6)
    np.testing.assert_allclose(a1, a2, atol=1e-6)


@pytest.mark.parametrize("B,D", [(13, 40), (1, 1), (7, 129), (8, 128)])
def test_adagrad_update_pads_to_pallas_path(B, D, monkeypatch):
    """Non-(8,128)-tiling working sets must take the Pallas kernel (padded),
    not silently fall back to the reference path."""
    calls = []
    real = ops.adagrad_pallas
    monkeypatch.setattr(
        ops, "adagrad_pallas", lambda *a, **k: calls.append(a[0].shape) or real(*a, **k)
    )
    key = jax.random.PRNGKey(B * 101 + D)
    p = jax.random.normal(key, (B, D))
    a = jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (B, D)))
    g = jax.random.normal(jax.random.fold_in(key, 2), (B, D))
    p1, a1 = ops.adagrad_update(p, a, g, 0.1, use_pallas=True, interpret=True)
    assert len(calls) == 1, "Pallas kernel must be invoked"
    pb, pd = calls[0]
    assert pb % 8 == 0 and pd % 128 == 0, f"padded shape {calls[0]} must tile"
    assert (p1.shape, a1.shape) == ((B, D), (B, D))
    p2, a2 = ref.adagrad_ref(p, a, g, 0.1)
    np.testing.assert_allclose(p1, p2, atol=1e-6)
    np.testing.assert_allclose(a1, a2, atol=1e-6)


def test_scatter_add_assume_sorted_fast_path():
    """Pre-sorted ids skip the wrapper argsort but accumulate identically."""
    key = jax.random.PRNGKey(17)
    N, D, B = 24, 128, 64
    table = jax.random.normal(key, (N, D))
    ids = jnp.sort(jax.random.randint(key, (B,), 0, N))  # heavy duplication
    grads = jax.random.normal(jax.random.fold_in(key, 1), (B, D))
    out = ops.scatter_add(table, ids, grads, assume_sorted=True, use_pallas=True, interpret=True)
    np.testing.assert_allclose(out, ref.scatter_add_ref(table, ids, grads), atol=1e-5, rtol=1e-5)


def test_working_table_accumulate_sorted(monkeypatch):
    """WorkingTable.accumulate(assume_sorted=True) forwards the flag so the
    kernel path never re-sorts sorted-unique MEM-PS working sets."""
    from repro.core.hbm_ps import WorkingTable

    seen = {}
    real = ops.scatter_add
    monkeypatch.setattr(
        "repro.core.hbm_ps.kops.scatter_add",
        lambda *a, **k: seen.update(k) or real(*a, **k),
    )
    table = jnp.zeros((8, 8), jnp.float32)
    slots = jnp.array([1, 3, 3, 7], jnp.int32)
    out = WorkingTable.accumulate(table, slots, jnp.ones((4, 8)), assume_sorted=True)
    assert seen.get("assume_sorted") is True
    exp = np.zeros((8, 8), np.float32)
    np.add.at(exp, np.asarray(slots), 1.0)
    np.testing.assert_allclose(out, exp)


# ---------------------------------------------------------------- attention
CASES = [
    # B, H, Hkv, Sq, Skv, Dh, causal, window, q_offset
    (2, 4, 2, 128, 128, 32, True, 0, 0),
    (1, 4, 1, 256, 256, 16, True, 0, 0),
    (1, 2, 2, 128, 256, 32, False, 0, 0),
    (2, 4, 2, 128, 256, 64, True, 64, 128),
    (1, 1, 1, 1, 128, 32, True, 0, 127),
    (1, 8, 4, 128, 128, 128, True, 32, 0),
]


@pytest.mark.parametrize("case", CASES)
def test_flash_attention_vs_ref(case):
    B, H, Hkv, Sq, Skv, Dh, causal, window, qoff = case
    ks = jax.random.split(jax.random.PRNGKey(sum(case[:6])), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, Skv, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, Skv, Dh))
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=qoff, interpret=True
    )
    expect = ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", CASES)
def test_blockwise_attention_vs_ref(case):
    B, H, Hkv, Sq, Skv, Dh, causal, window, qoff = case
    ks = jax.random.split(jax.random.PRNGKey(1 + sum(case[:6])), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, Dh))
    k = jax.random.normal(ks[1], (B, Hkv, Skv, Dh))
    v = jax.random.normal(ks[2], (B, Hkv, Skv, Dh))
    out = ops.attention_blockwise(q, k, v, causal=causal, window=window, q_offset=qoff, block_k=64)
    expect = ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    np.testing.assert_allclose(out, expect, atol=2e-5, rtol=2e-5)


def test_blockwise_gradients_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 32))
    k = jax.random.normal(ks[1], (1, 1, 64, 32))
    v = jax.random.normal(ks[2], (1, 1, 64, 32))
    g1 = jax.grad(lambda *a: ops.attention_blockwise(*a, causal=True, block_k=16).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: ref.attention_ref(*a, causal=True).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


def test_flash_custom_vjp_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 1, 128, 32))
    v = jax.random.normal(ks[2], (1, 1, 128, 32))
    g1 = jax.grad(lambda *a: ops.attention(*a, causal=True, impl="flash").sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: ref.attention_ref(*a, causal=True).sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


def test_kv_len_masking_matches_truncation():
    """kv_len masking == physically truncating the cache."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (2, 2, 1, 32))
    k = jax.random.normal(ks[1], (2, 2, 64, 32))
    v = jax.random.normal(ks[2], (2, 2, 64, 32))
    out = ref.attention_ref(q, k, v, causal=False, kv_len=40)
    exp = ref.attention_ref(q, k[:, :, :40], v[:, :, :40], causal=False)
    np.testing.assert_allclose(out, exp, atol=1e-6)
    out_b = ops.attention_blockwise(q, k, v, causal=False, kv_len=40, block_k=16)
    np.testing.assert_allclose(out_b, exp, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- gmm (MoE)
@pytest.mark.parametrize(
    "E,K,N,sizes",
    [
        (4, 128, 128, [100, 0, 300, 56]),
        (3, 256, 128, [128, 128, 128]),
        (5, 128, 256, [7, 250, 1, 0, 130]),
    ],
)
def test_gmm_vs_ref(E, K, N, sizes):
    key = jax.random.PRNGKey(E * K + N)
    T = sum(sizes)
    x = jax.random.normal(key, (T, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, K, N)) * 0.1
    gs = jnp.array(sizes, jnp.int32)
    out = ops.gmm(x, w, gs, use_pallas=True, interpret=True)
    np.testing.assert_allclose(out, ref.gmm_ref(x, w, gs), atol=2e-4, rtol=2e-4)


@given(st.lists(st.integers(0, 60), min_size=2, max_size=6))
@settings(max_examples=10, deadline=None)
def test_gmm_property_group_isolation(sizes):
    """Zeroing one expert's weights zeroes exactly that group's rows."""
    E = len(sizes)
    T = sum(sizes)
    if T == 0:
        return
    key = jax.random.PRNGKey(sum(sizes))
    x = jax.random.normal(key, (T, 128))
    w = jax.random.normal(jax.random.fold_in(key, 1), (E, 128, 128))
    w = w.at[0].set(0.0)
    gs = jnp.array(sizes, jnp.int32)
    out = np.asarray(ops.gmm(x, w, gs, use_pallas=True, interpret=True))
    assert np.allclose(out[: sizes[0]], 0.0)
    if T > sizes[0]:
        assert not np.allclose(out[sizes[0] :], 0.0) or sizes[0] == T
