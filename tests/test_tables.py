"""Multi-table PS client API: RowSchema/TableSpec namespacing, BatchSession
commit/abort semantics, and the two-table dict-model parity harness
(namespaced keys never collide; per-table rows bit-identical whether a
table is co-hosted or runs alone)."""

import numpy as np
import pytest

from repro.core.client import PSClient, SessionStateError
from repro.core.keys import (
    KEY_BITS,
    deterministic_init,
    namespace_keys,
    split_namespaced,
)
from repro.core.node import Cluster, NetworkModel
from repro.core.tables import RowSchema, TableRegistry, TableSpec


# --------------------------------------------------------------- schemas


def test_row_schema_layout_and_slices():
    s = RowSchema.with_slots(8, m=4, v=4, step=1)
    assert s.width == 17 and s.emb_dim == 8 and s.opt_dim == 9
    assert s.slice_of("emb") == slice(0, 8)
    assert s.slice_of("m") == slice(8, 12)
    assert s.slice_of("step") == slice(16, 17)
    assert RowSchema.embedding(6).opt_dim == 0
    assert RowSchema.with_adagrad(5).width == 10


def test_row_schema_validation():
    with pytest.raises(ValueError):
        RowSchema(())
    with pytest.raises(ValueError):
        RowSchema((("emb", 4), ("emb", 2)))
    with pytest.raises(ValueError):
        RowSchema((("emb", 0),))
    s = RowSchema.with_adagrad(4)
    with pytest.raises(KeyError):
        s.slice_of("nope")


def test_schema_manifest_roundtrip():
    s = RowSchema.with_slots(8, m=3, v=3)
    assert RowSchema.from_manifest(s.to_manifest()) == s
    spec = TableSpec("ads", s, table_id=7, init_scale=0.05)
    assert TableSpec.from_manifest(spec.to_manifest()) == spec
    reg = TableRegistry([spec, TableSpec("lm", RowSchema.embedding(16))])
    reg2 = TableRegistry.from_manifest(reg.to_manifest())
    assert [t.name for t in reg2] == [t.name for t in reg]
    assert reg2.get("ads") == spec and reg2.width == reg.width


# ---------------------------------------------------------- namespacing


def test_namespace_keys_identity_for_table_zero():
    k = np.array([0, 1, 2**40, (1 << KEY_BITS) - 1], dtype=np.uint64)
    np.testing.assert_array_equal(namespace_keys(k, 0), k)


def test_namespace_keys_never_collide_across_tables():
    k = np.arange(1000, dtype=np.uint64)
    tagged = [namespace_keys(k, t) for t in (0, 1, 2, 255)]
    allk = np.concatenate(tagged)
    assert len(np.unique(allk)) == len(allk)
    for t, tk in zip((0, 1, 2, 255), tagged):
        tids, raw = split_namespaced(tk)
        assert (tids == t).all()
        np.testing.assert_array_equal(raw, k)


def test_namespace_keys_rejects_out_of_range():
    with pytest.raises(ValueError):
        namespace_keys(np.array([1 << KEY_BITS], dtype=np.uint64), 1)
    with pytest.raises(ValueError):
        namespace_keys(np.array([1], dtype=np.uint64), 256)


def test_registry_assigns_free_ids_and_rejects_conflicts():
    reg = TableRegistry()
    a = reg.add(TableSpec("a", RowSchema.embedding(4)))
    b = reg.add(TableSpec("b", RowSchema.embedding(4)))
    assert (a.table_id, b.table_id) == (0, 1)
    c = reg.add(TableSpec("c", RowSchema.embedding(8), table_id=5))
    assert c.table_id == 5
    assert reg.add(TableSpec("a", RowSchema.embedding(4))) is a  # idempotent
    with pytest.raises(ValueError):
        reg.add(TableSpec("a", RowSchema.embedding(2)))  # same name, new schema
    with pytest.raises(ValueError):
        reg.add(TableSpec("d", RowSchema.embedding(2), table_id=5))  # id taken
    with pytest.raises(ValueError):
        # an explicit id is the key namespace: NEVER silently remapped —
        # honoring it with id 0 taken must reject, not reassign
        reg.add(TableSpec("e", RowSchema.embedding(2), table_id=0))
    assert reg.width == 8


def test_unregistered_spec_cannot_namespace():
    spec = TableSpec("floating", RowSchema.embedding(4))  # no id yet
    with pytest.raises(ValueError, match="no table_id"):
        spec.namespace(np.array([1], dtype=np.uint64))


# ------------------------------------------------- two-table dict model


def _ref_init(spec, raw_keys, scale):
    """The reference model's missing-row value: deterministic init of the
    emb field from the *namespaced* key, optimizer slots zero."""
    row = np.zeros((len(raw_keys), spec.schema.width), dtype=np.float32)
    row[:, : spec.schema.emb_dim] = deterministic_init(
        spec.namespace(np.asarray(raw_keys, dtype=np.uint64)), spec.schema.emb_dim, scale
    )
    return row


def _dict_model_pull(ref, spec, raw_keys, scale):
    out = np.empty((len(raw_keys), spec.schema.width), dtype=np.float32)
    for i, k in enumerate(raw_keys):
        got = ref.get((spec.name, int(k)))
        out[i] = got if got is not None else _ref_init(spec, [k], scale)[0]
    return out


def _update(rows, salt):
    """A value-dependent update so divergence compounds across rounds."""
    return (rows * 1.25 + salt).astype(np.float32)


def test_two_tables_dict_model_parity(tmp_path):
    """Two tables with different schemas over ONE cluster, interleaved
    update streams sharing raw key values: a per-table dict model must
    match every flushed row bit-for-bit, proving the namespaced key spaces
    never bleed into each other through cache eviction, the staging
    buffer, SSD compaction, or the fixed-width row prefix."""
    specs = {
        "a": TableSpec("a", RowSchema.with_adagrad(3)),  # width 6
        "b": TableSpec("b", RowSchema.with_slots(5, m=2)),  # width 7
    }
    # tiny cache forces eviction churn through both key spaces
    cluster = Cluster(2, str(tmp_path / "ps"), dim=7, cache_capacity=64,
                      file_capacity=16)
    client = PSClient(cluster, list(specs.values()))
    # registration auto-assigns table ids — use the registered specs
    specs = {name: client.table(name) for name in specs}
    scale = cluster.init_scale
    ref: dict = {}
    rng = np.random.default_rng(0)
    for rnd in range(12):
        for name, spec in specs.items():
            raw = rng.integers(0, 200, size=40).astype(np.uint64)
            with client.session(name, raw) as s:
                uniq = s.raw_keys
                width = spec.schema.width
                rows = np.concatenate([s.params, s.opt_state], axis=1)
                np.testing.assert_array_equal(
                    rows, _dict_model_pull(ref, spec, uniq, scale),
                    err_msg=f"round {rnd} table {name}: pulled rows diverged",
                )
                new = _update(rows, salt=rnd + (0.5 if name == "b" else 0.0))
                s.commit(new[:, : spec.schema.emb_dim], new[:, spec.schema.emb_dim :])
                for k, row in zip(uniq.tolist(), new):
                    ref[(name, int(k))] = row
    # final state: every key of both tables, straight off the flushed SSD
    cluster.flush_all()
    for name, spec in specs.items():
        raw = np.arange(200, dtype=np.uint64)
        pulled = cluster.pull(spec.namespace(raw), pin=False)
        want = _dict_model_pull(ref, spec, raw, scale)
        np.testing.assert_array_equal(pulled[:, : spec.schema.width], want)
        # the row tail beyond the schema width stays zero (prefix design)
        assert not pulled[:, spec.schema.width :].any()
    assert cluster.total_pins() == 0
    assert client.n_inflight() == 0


def test_cohosted_table_rows_bit_identical_to_solo_run(tmp_path):
    """A table's rows must be bitwise independent of its neighbours: the
    same update stream on table "b" produces identical rows whether "b"
    shares the cluster with a chatty table "a" or runs alone (given the
    same table_id, i.e. the same key namespace)."""
    spec_b = TableSpec("b", RowSchema.with_adagrad(4), table_id=2)

    def run(with_neighbour: bool):
        tables = [spec_b] + (
            [TableSpec("a", RowSchema.with_slots(6, m=6, v=2), table_id=1)]
            if with_neighbour else []
        )
        dim = 14 if with_neighbour else 8
        cl = Cluster(2, str(tmp_path / f"ps{with_neighbour}"), dim=dim,
                     cache_capacity=48, file_capacity=16)
        client = PSClient(cl, tables)
        rng = np.random.default_rng(7)  # table b's stream: identical in both runs
        rng_noise = np.random.default_rng(8)
        for rnd in range(10):
            raw = rng.integers(0, 150, size=32).astype(np.uint64)
            with client.session("b", raw) as s:
                new = _update(np.concatenate([s.params, s.opt_state], axis=1), rnd)
                s.commit(new[:, :4], new[:, 4:])
            if with_neighbour:  # neighbour churns the shared cache
                noise = rng_noise.integers(0, 500, size=64).astype(np.uint64)
                with client.session("a", noise) as sa:
                    sa.commit(np.ones((sa.n_working, 6), np.float32),
                              np.zeros((sa.n_working, 8), np.float32))
        cl.flush_all()
        rows = cl.pull(spec_b.namespace(np.arange(150, dtype=np.uint64)), pin=False)
        return rows[:, : spec_b.schema.width]

    np.testing.assert_array_equal(run(True), run(False))


# ------------------------------------------------------ session semantics


@pytest.fixture
def client(tmp_path):
    cluster = Cluster(2, str(tmp_path / "ps"), dim=8, cache_capacity=256,
                      file_capacity=32)
    return PSClient(cluster, [TableSpec("t", RowSchema.with_adagrad(4))])


def _keys(*ids):
    return np.array(ids, dtype=np.uint64)


def test_session_double_commit_rejected(client):
    s = client.session("t", _keys(1, 2, 3))
    s.commit(np.zeros((3, 4), np.float32), np.zeros((3, 4), np.float32))
    with pytest.raises(SessionStateError):
        s.commit(np.ones((3, 4), np.float32))
    with pytest.raises(SessionStateError):
        s.abort()  # committed sessions cannot be aborted either
    assert client.cluster.total_pins() == 0


def test_session_abort_then_commit_rejected(client):
    s = client.session("t", _keys(1, 2))
    s.abort()
    with pytest.raises(SessionStateError):
        s.commit(np.zeros((2, 4), np.float32))
    with pytest.raises(SessionStateError):
        s.abort()
    assert client.cluster.total_pins() == 0
    assert client.n_inflight() == 0


def test_session_context_exit_without_commit_aborts(client):
    with client.session("t", _keys(1, 2, 3)) as s:
        assert client.cluster.total_pins() == 3
    assert s.state == "aborted"
    assert client.cluster.total_pins() == 0
    assert client.n_inflight() == 0


def test_session_context_exception_aborts_and_propagates(client):
    with pytest.raises(RuntimeError, match="boom"):
        with client.session("t", _keys(5, 6)) as s:
            raise RuntimeError("boom")
    assert s.state == "aborted"
    assert client.cluster.total_pins() == 0


def test_read_only_session_pulls_without_pin(client):
    with client.session("t", _keys(1, 2, 3)) as s:
        s.commit(np.full((3, 4), 2.0, np.float32), np.full((3, 4), 3.0, np.float32))
    with client.session("t", _keys(1, 2, 3), read_only=True) as r:
        np.testing.assert_array_equal(r.params, np.full((3, 4), 2.0))
        np.testing.assert_array_equal(r.field("adagrad"), np.full((3, 4), 3.0))
        assert client.cluster.total_pins() == 0  # no pin taken at all
        assert client.n_inflight() == 0  # never enters the registry
        with pytest.raises(SessionStateError):
            r.commit(np.zeros((3, 4), np.float32))
    assert r.state == "aborted"


def test_session_field_views(client):
    s = client.session("t", _keys(9))
    assert s.field("emb").shape == (1, 4)
    assert s.field("adagrad").shape == (1, 4)
    np.testing.assert_array_equal(s.field("emb"), s.params)
    s.abort()


# ----------------------------------------------------- manifest / restore


def test_cluster_manifest_restores_tables_and_init(tmp_path):
    spec = TableSpec("emb6", RowSchema.embedding(6), table_id=3, init_scale=0.5)
    cluster = Cluster(2, str(tmp_path / "ps"), dim=8, cache_capacity=64,
                      file_capacity=16, tables=TableRegistry([spec]))
    client = PSClient(cluster)
    with client.session("emb6", _keys(1, 2)) as s:
        s.commit(np.full((2, 6), 7.0, np.float32))
    m = client.manifest()
    restored = Cluster.restore(m, cluster.base_dir)
    c2 = PSClient(restored)
    assert c2.table_names == ["emb6"]
    assert c2.table("emb6") == spec
    with c2.session("emb6", _keys(1, 2), read_only=True) as r:
        np.testing.assert_array_equal(r.params, np.full((2, 6), 7.0))
    # unseen keys on the restored cluster still use the table's own init
    with c2.session("emb6", _keys(100, 101), read_only=True) as r:
        want = deterministic_init(spec.namespace(_keys(100, 101)), 6, 0.5)
        np.testing.assert_array_equal(r.params, want)


def test_client_over_wider_cluster_keeps_narrow_table_exact(tmp_path):
    """Width-asymmetry regression: a schema narrower than the cluster row
    must round-trip exactly through prepare/commit (prefix write, zero
    tail), including the conflict-forwarding path."""
    spec = TableSpec("n", RowSchema.with_adagrad(2))  # width 4 on dim-12 rows
    cluster = Cluster(1, str(tmp_path / "ps"), dim=12, cache_capacity=64,
                      file_capacity=16)
    client = PSClient(cluster, [spec])
    blocker = client.session("n", _keys(99))  # untrained: holds push order
    s1 = client.session("n", _keys(1, 2, 3))
    s1.commit(np.full((3, 2), 5.0, np.float32), np.full((3, 2), 6.0, np.float32),
              defer=True)  # trained, but its push is queued behind blocker
    # successor conflicts on keys 2,3 -> version-forwarded from s1's commit
    s2 = client.session("n", _keys(2, 3, 4))
    np.testing.assert_array_equal(s2.params[:2], np.full((2, 2), 5.0))
    np.testing.assert_array_equal(s2.opt_state[:2], np.full((2, 2), 6.0))
    assert client.engine("n").stats.rows_forwarded == 2
    blocker.abort()
    s2.commit(np.full((3, 2), 8.0, np.float32), np.full((3, 2), 9.0, np.float32))
    cluster.flush_all()
    rows = cluster.pull(_keys(1, 2, 3, 4), pin=False)
    np.testing.assert_array_equal(rows[0, :4], [5.0, 5.0, 6.0, 6.0])
    np.testing.assert_array_equal(rows[1:, :2], np.full((3, 2), 8.0))
    np.testing.assert_array_equal(rows[1:, 2:4], np.full((3, 2), 9.0))
    assert not rows[:, 4:].any()  # tail beyond the schema width stays zero
    assert cluster.total_pins() == 0
    assert client.n_inflight() == 0
