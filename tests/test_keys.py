"""Key hashing/partitioning invariants (property-based)."""

import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # not installed: deterministic fixed-seed fallback
    from repro.testing.hypothesis_fallback import given, settings, st

from repro.core.keys import (
    deterministic_init,
    hash_keys,
    key_to_node,
    key_to_shard,
    partition_by_owner,
    splitmix64,
)

keys_arrays = st.lists(st.integers(0, 2**63 - 1), min_size=1, max_size=200).map(
    lambda xs: np.asarray(xs, dtype=np.uint64)
)


@given(keys_arrays)
def test_hash_deterministic(keys):
    assert (hash_keys(keys) == hash_keys(keys)).all()


@given(keys_arrays, st.integers(1, 16))
def test_owner_in_range(keys, n):
    owners = key_to_node(keys, n)
    assert ((owners >= 0) & (owners < n)).all()


@given(keys_arrays)
def test_node_and_shard_maps_independent(keys):
    # different seeds -> a key's node does not determine its device shard
    n = key_to_node(keys, 4)
    s = key_to_shard(keys, 4)
    assert n.shape == s.shape


def test_splitmix_bijective_on_sample():
    xs = np.arange(100_000, dtype=np.uint64)
    assert len(np.unique(splitmix64(xs))) == len(xs)


def test_partition_balance():
    keys = np.arange(100_000, dtype=np.uint64)
    counts = np.bincount(key_to_node(keys, 8), minlength=8)
    assert counts.min() > 0.9 * counts.mean()
    assert counts.max() < 1.1 * counts.mean()


@given(keys_arrays, st.integers(1, 8), st.integers(1, 16))
def test_deterministic_init_is_per_key(keys, dim, seed_unused):
    a = deterministic_init(keys, dim)
    b = deterministic_init(keys[::-1].copy(), dim)[::-1]
    np.testing.assert_array_equal(a, b)
    assert (np.abs(a) <= 0.01 + 1e-9).all()


@given(keys_arrays, st.integers(1, 7))
def test_partition_by_owner_roundtrip(keys, n):
    owners = key_to_node(keys, n)
    order, splits = partition_by_owner(keys, owners, n)
    parts = np.split(keys[order], splits)
    assert sum(len(p) for p in parts) == len(keys)
    for i, p in enumerate(parts):
        assert (key_to_node(p, n) == i).all() if len(p) else True
    # scatter-back property
    rebuilt = np.empty_like(keys)
    rebuilt[order] = keys[order]
    np.testing.assert_array_equal(rebuilt, keys)
