"""Per-arch smoke tests: reduced config, one forward + one train step on CPU,
shape + finiteness asserts; decode parity where exactness is expected."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config, replace
from repro.models import get_model
from repro.train.optim import AdamW
from repro.train.train_step import TrainSettings, make_lm_train_step, make_lm_train_step_hier


def make_batch(cfg, B=2, S=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "targets": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(ks[2], (B, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(ks[3], (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = replace(get_smoke_config(arch), embedding_mode="dense")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    kwargs = {}
    if cfg.family == "audio":
        kwargs["frames"] = batch["frames"]
    if cfg.family == "vlm":
        kwargs["image_embeds"] = batch["image_embeds"]
    logits, aux = model.forward(cfg, params, batch["tokens"], **kwargs)
    S_out = batch["tokens"].shape[1] + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step_dense(arch):
    cfg = replace(get_smoke_config(arch), embedding_mode="dense")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    settings = TrainSettings(optimizer=AdamW(lr=1e-3), microbatches=2, remat=True)
    step = jax.jit(make_lm_train_step(cfg, settings))
    opt_state = settings.optimizer.init(params)
    batch = make_batch(cfg, B=4, S=8)
    new_params, _, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # parameters must actually change
    moved = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).sum()), params, new_params),
    )
    assert moved > 0


@pytest.mark.parametrize("arch", ["yi-9b", "olmoe-1b-7b", "whisper-tiny", "xlstm-1.3b", "hymba-1.5b"])
def test_smoke_train_step_hier(arch):
    cfg = get_smoke_config(arch)  # hier_ps default
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    settings = TrainSettings(optimizer=AdamW(lr=1e-3), microbatches=1)
    step = jax.jit(make_lm_train_step_hier(cfg, settings))
    opt_state = settings.optimizer.init(params)
    batch = make_batch(cfg, B=2, S=8)
    n_working = 64
    batch["tokens"] = batch["tokens"] % n_working  # slots
    wt = jax.random.normal(jax.random.PRNGKey(5), (n_working, cfg.d_model)) * 0.01
    acc = jnp.zeros_like(wt)
    _, _, metrics, new_wt, new_acc = step(params, opt_state, batch, wt, acc)
    assert np.isfinite(float(metrics["loss"]))
    assert float(jnp.abs(new_wt - wt).sum()) > 0
    assert float(new_acc.sum()) > 0


def test_transformer_decode_matches_forward():
    from repro.models import transformer as T
    from repro.models.attention import KVCache

    cfg = replace(get_smoke_config("granite-20b"), embedding_mode="dense")
    params = T.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = T.forward(cfg, params, tokens)
    _, cache = T.prefill(cfg, params, tokens[:, : S - 1])
    pad = lambda a: jnp.pad(a, ((0, 0),) * 3 + ((0, 1), (0, 0)))
    dec, _ = T.decode_step(
        cfg, params, tokens[:, S - 1 :], KVCache(pad(cache.k), pad(cache.v)), jnp.int32(S - 1)
    )
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]), atol=2e-2, rtol=2e-2)


def test_xlstm_decode_matches_forward_exactly():
    from repro.models import xlstm as X

    cfg = replace(get_smoke_config("xlstm-1.3b"), embedding_mode="dense")
    params = X.init(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    full, _ = X.forward(cfg, params, tokens, chunk=8)
    cache = X.init_cache(cfg, 2)
    outs = []
    for t in range(16):
        lg, cache = X.decode_step(cfg, params, tokens[:, t : t + 1], cache)
        outs.append(lg)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, axis=1)), np.asarray(full), atol=3e-2, rtol=3e-2
    )


def test_hymba_prefill_decode_continuity():
    from repro.models import hymba as H

    cfg = replace(get_smoke_config("hymba-1.5b"), embedding_mode="dense")
    params = H.init(cfg, jax.random.PRNGKey(0))
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    full, _ = H.forward(cfg, params, tokens)
    # prefill first S-1 tokens, then decode token S-1: must match forward
    total = cfg.n_meta_tokens + S
    _, cache = H.prefill(cfg, params, tokens[:, : S - 1], max_len=total)
    dec, _ = H.decode_step(cfg, params, tokens[:, S - 1 :], cache, jnp.int32(total - 1))
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]), atol=3e-2, rtol=3e-2)


def test_mlstm_chunkwise_matches_sequential():
    from repro.models import xlstm as X

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, H, S, dh = 2, 3, 64, 16
    q = jax.random.normal(ks[0], (B, H, S, dh))
    k = jax.random.normal(ks[1], (B, H, S, dh))
    v = jax.random.normal(ks[2], (B, H, S, dh))
    li = jax.random.normal(ks[3], (B, H, S)) * 2
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) * 2)
    h_seq, st_seq = X.mlstm_sequential(q, k, v, li, lf)
    for chunk in (8, 32, 64):
        h_chk, st_chk = X.mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
        np.testing.assert_allclose(h_seq, h_chk, atol=2e-4, rtol=2e-4)
        for a, b in zip(st_seq, st_chk):
            np.testing.assert_allclose(a, b, atol=2e-4, rtol=2e-4)


def test_mamba_chunked_matches_recurrent():
    from repro.models import mamba as M
    from repro.models.common import init_params

    params = init_params(M.mamba_schema(32, 4), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    din = params["out_proj"].shape[0]
    xz = x @ params["in_proj"]
    xin, _ = M._conv_causal(xz[..., :din], params["conv_w"], params["conv_b"])
    xin = jax.nn.silu(xin)
    dt, B_t, C_t, A = M._ssm_inputs(params, xin)
    y_rec, h_rec = M._scan_recurrent(xin, dt, B_t, C_t, A, None)
    y_chk, h_chk = M._scan_chunked(xin, dt, B_t, C_t, A, None, chunk=16)
    np.testing.assert_allclose(y_rec, y_chk, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h_rec, h_chk, atol=1e-4, rtol=1e-4)


def test_moe_capacity_flops_structure():
    """Dispatch never routes more than capacity tokens to one expert."""
    from repro.models import moe as MoE

    cfg = get_smoke_config("olmoe-1b-7b")
    C = MoE.expert_capacity(cfg, 64)
    assert C >= 64 * cfg.top_k // cfg.n_experts
    assert C % 8 == 0
