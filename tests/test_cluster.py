"""Multi-node cluster: partitioned pull/push, failure, elastic reshard."""

import numpy as np
import pytest

from repro.core.elastic import reshard
from repro.core.node import Cluster, NodeDownError


def make_cluster(tmp_path, n=4, dim=4):
    return Cluster(n, str(tmp_path / f"c{n}"), dim=dim, cache_capacity=512, file_capacity=32)


def test_partitioned_pull_push(tmp_path):
    cl = make_cluster(tmp_path)
    keys = np.random.default_rng(0).integers(0, 2**40, 300).astype(np.uint64)
    v = cl.pull(keys)
    cl.push(keys, v + 2.0)
    np.testing.assert_allclose(cl.pull(np.unique(keys), pin=False),
                               np.unique(keys)[:, None] * 0 + (cl.pull(np.unique(keys), pin=False)))
    got = cl.pull(keys, requester=2, pin=False)
    np.testing.assert_allclose(got, v + 2.0)
    assert cl.network.bytes_moved > 0  # remote traffic happened


def test_remote_vs_local_accounting(tmp_path):
    cl = make_cluster(tmp_path)
    keys = np.arange(1000, dtype=np.uint64)
    cl.pull(keys, requester=0, pin=False)
    assert cl.pull_local_time > 0 and cl.pull_remote_time > 0
    # ~3/4 of keys are remote for requester 0
    owners = cl.owner_of(keys)
    assert 0.5 < (owners != 0).mean() < 0.95


def test_node_failure_raises_and_restart(tmp_path):
    cl = make_cluster(tmp_path)
    keys = np.arange(100, dtype=np.uint64)
    v = cl.pull(keys, pin=False)
    cl.push(keys, v + 1, unpin=False)
    cl.flush_all()
    cl.kill_node(1)
    with pytest.raises(NodeDownError):
        cl.pull(keys, pin=False)
    cl.nodes[1].restart()
    got = cl.pull(keys, pin=False)  # SSD state survived the DRAM loss
    np.testing.assert_allclose(got, v + 1)


def test_manifest_restore_roundtrip(tmp_path):
    cl = make_cluster(tmp_path)
    keys = np.arange(200, dtype=np.uint64)
    v = cl.pull(keys)
    cl.push(keys, v * 3)
    manifest = cl.manifest()
    cl2 = Cluster.restore(manifest, cl.base_dir)
    np.testing.assert_allclose(cl2.pull(keys, pin=False), v * 3)


def test_elastic_reshard_preserves_ctor_kwargs_and_tables(tmp_path):
    """reshard must rebuild the new cluster from the FULL ctor-kwarg set
    (the hand-picked subset used to silently revert file_capacity/init
    settings to defaults) and carry the hosted table specs — including
    their key namespacing and per-table missing-row init — onto the new
    shards."""
    from repro.core.client import PSClient
    from repro.core.keys import deterministic_init
    from repro.core.node import NetworkModel
    from repro.core.tables import RowSchema, TableRegistry, TableSpec

    spec = TableSpec("t", RowSchema.with_adagrad(3), table_id=4, init_scale=0.3)
    cl = Cluster(3, str(tmp_path / "src"), dim=8, cache_capacity=77,
                 file_capacity=24, init_scale=0.05, init_cols=6,
                 network=NetworkModel(latency_s=3e-4, bandwidth_gbps=9.0,
                                      wire_quantize=True),
                 tables=TableRegistry([spec]))
    client = PSClient(cl)
    raw = np.arange(60, dtype=np.uint64)
    with client.session("t", raw) as s:
        s.commit(np.full((60, 3), 4.0, np.float32), np.full((60, 3), 5.0, np.float32))

    new = reshard(cl, 2, str(tmp_path / "dst"))
    # full kwargs carried (file_capacity/init_* used to fall back to defaults)
    assert new.cache_capacity == 77 and new.file_capacity == 24
    assert new.init_scale == 0.05 and new.init_cols == 6
    assert all(n.ssd.file_capacity == 24 for n in new.nodes)
    assert all(n.mem.capacity == 77 for n in new.nodes)
    # NIC parameters carried, counters fresh for this reshard's traffic
    assert new.network.latency_s == 3e-4 and new.network.bandwidth_gbps == 9.0
    assert new.network.wire_quantize and new.network is not cl.network
    # table specs carried: rows, namespacing and per-table init all intact
    # (pinned pulls: the carried wire_quantize=True makes unpinned remote
    # reads intentionally lossy, training pulls stay exact)
    assert new.tables is not None and new.tables.get("t") == spec
    rows = new.pull(spec.namespace(raw), pin=True)
    new.unpin(spec.namespace(raw))
    np.testing.assert_array_equal(rows[:, :3], np.full((60, 3), 4.0))
    np.testing.assert_array_equal(rows[:, 3:6], np.full((60, 3), 5.0))
    unseen = spec.namespace(np.arange(500, 504, dtype=np.uint64))
    want = deterministic_init(unseen, 3, 0.3)
    got = new.pull(unseen, pin=True)
    new.unpin(unseen)
    np.testing.assert_array_equal(got[:, :3], want)


@pytest.mark.parametrize("new_n", [2, 6])
def test_elastic_reshard_preserves_rows(tmp_path, new_n):
    cl = make_cluster(tmp_path, n=4)
    keys = np.random.default_rng(1).integers(0, 2**40, 500).astype(np.uint64)
    keys = np.unique(keys)
    v = cl.pull(keys)
    cl.push(keys, v + 5)
    new = reshard(cl, new_n, str(tmp_path / f"resharded{new_n}"))
    got = new.pull(keys, pin=False)
    np.testing.assert_allclose(got, v + 5)
    # every node owns roughly 1/new_n of the keys
    counts = np.bincount(new.owner_of(keys), minlength=new_n)
    assert counts.min() > 0.7 * counts.mean()
