"""Dry-run machinery on a small in-process mesh (8 host devices).

The full 512-device production dry-run runs via ``python -m
repro.launch.dryrun`` (results in EXPERIMENTS.md); this test proves the same
build path (sharding rules, abstract inputs, lower+compile, roofline parse)
works for every family on a mesh with both axes > 1.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import pytest

from repro.configs import ShapeSpec, get_smoke_config
from repro.launch import dryrun as DR
from repro.launch import roofline as rl

pytestmark = pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")

SHAPES = {
    "train": ShapeSpec("train_t", "train", 64, 8),
    "prefill": ShapeSpec("prefill_t", "prefill", 128, 4),
    "decode": ShapeSpec("decode_t", "decode", 128, 8),
}

FAMILIES = ["yi-9b", "olmoe-1b-7b", "hymba-1.5b", "xlstm-1.3b", "whisper-tiny", "pixtral-12b"]


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((2, 4), ("data", "model"))


@pytest.mark.parametrize("arch", FAMILIES)
@pytest.mark.parametrize("kind", list(SHAPES))
def test_cell_lowers_and_compiles(arch, kind, mesh):
    cfg = get_smoke_config(arch)
    shape = SHAPES[kind]
    fn, args, shards = DR.build_cell(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=shards).lower(*args).compile()
    cost = rl.flat_cost(compiled)
    assert cost.get("flops", 0) > 0
    stats = rl.parse_collectives(compiled.as_text())
    assert stats.total_bytes > 0, "sharded program must communicate"
    ma = compiled.memory_analysis()
    assert ma.argument_size_in_bytes > 0


def test_roofline_terms_behave(mesh):
    cfg = get_smoke_config("yi-9b")
    fn, args, shards = DR.build_cell(cfg, SHAPES["train"], mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=shards).lower(*args).compile()
    roof = rl.analyze("yi-9b", "train_t", "2x4", compiled, 1e12, 8)
    assert roof.t_compute > 0 and roof.t_memory > 0 and roof.t_collective > 0
    assert roof.bottleneck in ("compute", "memory", "collective")
    d = roof.to_dict()
    assert "roofline_fraction" in d and "useful_flops_ratio" in d
