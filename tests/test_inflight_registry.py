"""HierarchicalPS in-flight registry: conflict-aware pulls, version
forwarding, deferred pushes, pin accounting, speculation dedup."""

import threading
import time

import numpy as np
import pytest

from repro.core.hier_ps import HierarchicalPS
from repro.core.node import Cluster
from repro.configs.ctr_models import TINY
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.train.trainer import CTRTrainer, TrainerConfig

EMB, OPT = 4, 4


@pytest.fixture
def ps(tmp_path):
    cl = Cluster(2, str(tmp_path / "ps"), dim=EMB + OPT, cache_capacity=512,
                 file_capacity=32, init_cols=EMB)
    return HierarchicalPS(cl, EMB, OPT)


def keys(*ids):
    return np.array(ids, dtype=np.uint64)


def test_prepare_dedup_by_batch_id_no_double_pin(ps):
    """Pin-leak regression: a straggling pull/push stage re-running
    prepare_batch for the same batch must get the existing working set back
    instead of pinning every key a second time (the leak only cleared at
    MemoryError before)."""
    ws1 = ps.prepare_batch(keys(1, 2, 3, 4), batch_id=7)
    assert ps.cluster.total_pins() == 4
    ws2 = ps.prepare_batch(keys(1, 2, 3, 4), batch_id=7)  # speculative rerun
    assert ws2 is ws1
    assert ps.stats.dedup_reuses == 1
    assert ps.cluster.total_pins() == 4, "re-execution double-pinned"
    ps.complete_batch(ws1, np.ones((4, EMB), np.float32), np.ones((4, OPT), np.float32))
    assert ps.cluster.total_pins() == 0
    assert ps.n_inflight() == 0


def test_conflict_keys_forward_from_completing_batch(ps):
    """prepare(i+1) must not re-pull keys held by in-flight batch i: it
    blocks (per key segment, not whole-batch) until i's results arrive and
    forwards the pushed rows — the cluster copy is stale until i pushes."""
    ws1 = ps.prepare_batch(keys(10, 11, 12), batch_id=0)
    pulled_before = ps.stats.rows_pulled
    out = {}

    def prepare_next():
        out["ws"] = ps.prepare_batch(keys(11, 12, 13), batch_id=1)

    t = threading.Thread(target=prepare_next)
    t.start()
    time.sleep(0.15)
    assert t.is_alive(), "prepare(i+1) should await batch i's results"
    new_p = np.full((3, EMB), 5.0, np.float32)
    new_o = np.full((3, OPT), 6.0, np.float32)
    ps.finish_batch(ws1, new_p, new_o)
    t.join(5.0)
    assert not t.is_alive()
    ws2 = out["ws"]
    # shared keys carry batch 0's pushed values, fresh key came from the PS
    i11, i12 = np.searchsorted(ws2.keys, [11, 12])
    np.testing.assert_array_equal(ws2.params[[i11, i12]], new_p[[1, 2]])
    np.testing.assert_array_equal(ws2.opt_state[[i11, i12]], new_o[[1, 2]])
    assert ps.stats.rows_forwarded == 2
    assert ps.stats.rows_pulled == pulled_before + 1  # only key 13 pulled
    assert ps.stats.pull_bytes_saved == 2 * (EMB + OPT) * 4
    # pin transfer: ws2 now holds pins on all 3 of its keys (batch 0's
    # deferred push released its own); complete both and nothing leaks
    ps.apply_ready_pushes()
    assert ps.cluster.total_pins() == 3
    ps.complete_batch(ws2, np.zeros((3, EMB), np.float32), np.zeros((3, OPT), np.float32))
    assert ps.cluster.total_pins() == 0
    assert ps.n_inflight() == 0


def test_abort_wakes_blocked_conflicting_prepare(ps):
    """abort_batch must wake a prepare blocked on the aborted batch's keys;
    the waiter falls back to pulling the (current) cluster rows instead of
    hanging forever on a results token that will never be signalled."""
    ws1 = ps.prepare_batch(keys(1, 2, 3), batch_id=0)
    out = {}

    def prepare_next():
        out["ws"] = ps.prepare_batch(keys(2, 3, 4), batch_id=1)

    t = threading.Thread(target=prepare_next)
    t.start()
    time.sleep(0.15)
    assert t.is_alive(), "prepare(i+1) should await batch i"
    baseline = ps.cluster.pull(keys(2, 3), pin=False)  # pre-abort rows
    ps.abort_batch(ws1)
    t.join(5.0)
    assert not t.is_alive(), "abort left the conflicting prepare blocked"
    ws2 = out["ws"]
    i2, i3 = np.searchsorted(ws2.keys, [2, 3])
    np.testing.assert_array_equal(ws2.params[[i2, i3]], baseline[:, :EMB])
    ps.abort_batch(ws2)
    assert ps.cluster.total_pins() == 0
    assert ps.n_inflight() == 0


def test_abort_fallback_forwards_from_older_unpushed_holder(ps):
    """When the awaited holder is aborted, the waiter must re-scan for an
    older in-flight holder of the same keys: a trained-but-unpushed batch
    may still carry an update the cluster copy lacks."""
    ws_block = ps.prepare_batch(keys(99), batch_id=0)  # untrained: blocks push order
    ws_a = ps.prepare_batch(keys(5), batch_id=1)
    new_p = np.full((1, EMB), 7.0, np.float32)
    new_o = np.full((1, OPT), 8.0, np.float32)
    ps.finish_batch(ws_a, new_p, new_o)  # trained, but push blocked behind batch 0
    ws_b = ps.prepare_batch(keys(5), batch_id=2)  # forwards from ws_a
    np.testing.assert_array_equal(ws_b.params, new_p)
    out = {}

    def prepare_c():
        out["ws"] = ps.prepare_batch(keys(5), batch_id=3)

    t = threading.Thread(target=prepare_c)
    t.start()
    time.sleep(0.15)
    assert t.is_alive()  # awaiting ws_b's training
    ps.abort_batch(ws_b)
    t.join(5.0)
    assert not t.is_alive()
    # the fallback must carry ws_a's unpushed update, not the stale SSD row
    np.testing.assert_array_equal(out["ws"].params, new_p)
    np.testing.assert_array_equal(out["ws"].opt_state, new_o)
    for w in (ws_block, out["ws"]):
        ps.abort_batch(w)
    ps.drain()
    assert ps.cluster.total_pins() == 0


def test_deferred_push_applies_in_order_and_on_drain(ps):
    ws1 = ps.prepare_batch(keys(1, 2), batch_id=0)
    ps.finish_batch(ws1, np.full((2, EMB), 1.0, np.float32), np.zeros((2, OPT), np.float32))
    # nothing pushed yet: the push waits for the pull/push stage thread
    assert ps.n_inflight() == 1
    ps.drain()
    assert ps.n_inflight() == 0
    rows = ps.cluster.pull(keys(1, 2), pin=False)
    np.testing.assert_array_equal(rows[:, :EMB], np.full((2, EMB), 1.0))
    assert ps.cluster.total_pins() == 0


def test_drain_unpins_untrained_batches(ps):
    ps.prepare_batch(keys(1, 2, 3), batch_id=0)
    assert ps.cluster.total_pins() == 3
    ps.drain()  # e.g. the pipeline died before the train stage ran
    assert ps.cluster.total_pins() == 0
    assert ps.n_inflight() == 0


def test_abort_batch_unpins_and_unregisters(ps):
    ws = ps.prepare_batch(keys(5, 6), batch_id=0)
    ps.abort_batch(ws)
    assert ps.cluster.total_pins() == 0
    assert ps.n_inflight() == 0
    # the same external id can now be prepared again (no stale dedup hit)
    ws2 = ps.prepare_batch(keys(5, 6), batch_id=0)
    assert ws2 is not ws
    assert ps.cluster.total_pins() == 2
    ps.abort_batch(ws2)


def test_trainer_straggler_timeout_leaks_no_pins(tmp_path):
    """End-to-end pin-leak regression: with an aggressive straggler timeout
    every pull/push job overruns, but the stage is non-idempotent so no
    speculative re-execution (and no double pinning) happens."""
    cl = Cluster(2, str(tmp_path / "ps"), dim=TINY.emb_dim * 2, cache_capacity=2048,
                 file_capacity=128, init_cols=TINY.emb_dim)
    tr = CTRTrainer(TINY, cl, TrainerConfig(stage_timeout=1e-4))
    s = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots,
                           TINY.batch_size, seed=2)
    res = tr.run(s, 5)
    assert len(res) == 5
    assert tr.ps.stats.dedup_reuses == 0  # nothing re-executed at all
    assert cl.total_pins() == 0, "pins leaked across the pipelined run"
    assert tr.ps.n_inflight() == 0


def test_partial_pull_failure_rolls_back_pins(tmp_path):
    """A pull that fails on a later node (NodeDownError / pin pressure)
    must unpin the segments it already served — retries of the pull/push
    stage would otherwise accumulate stranded pins on the healthy nodes."""
    cl = Cluster(3, str(tmp_path / "ps"), dim=EMB + OPT, cache_capacity=512,
                 file_capacity=32, init_cols=EMB)
    cl.kill_node(2)
    all_keys = np.arange(200, dtype=np.uint64)  # spans all three shards
    with pytest.raises(Exception):
        cl.pull(all_keys, pin=True)
    assert cl.total_pins() == 0, "healthy nodes kept the failed pull's pins"
    # MEM-PS pin-pressure failure inside one node rolls back the same way
    cl2 = Cluster(1, str(tmp_path / "ps2"), dim=EMB + OPT, cache_capacity=32,
                  file_capacity=32, init_cols=EMB)
    cl2.pull(np.arange(32, dtype=np.uint64), pin=True)  # cache fully pinned
    with pytest.raises(MemoryError):
        cl2.pull(np.arange(100, 140, dtype=np.uint64), pin=True)
    assert cl2.total_pins() == 32  # only the first pull's pins remain
    cl2.unpin(np.arange(32, dtype=np.uint64))
    assert cl2.total_pins() == 0  # REPRO_SANLOCK asserts this at teardown


def test_owner_kill_mid_batch_drains_and_replays_bitwise(tmp_path):
    """Full ride-through of the scenario above (DESIGN.md §9): an owner
    node dies mid-batch under *pipelined* training. The trainer must drain
    the in-flight batches (trained prefix's deferred pushes land, untrained
    remainder unpinned), recover the node (restart + redo replay), replay
    the untrained batches, and resume — with losses bitwise-equal to a
    fault-free run and zero leaked pins or in-flight entries."""
    from repro.core.faults import NODE_KILL, FaultInjector, FaultSpec

    def run(tag, schedule):
        cl = Cluster(2, str(tmp_path / tag), dim=TINY.emb_dim * 2,
                     cache_capacity=2048, file_capacity=128,
                     init_cols=TINY.emb_dim)
        tr = CTRTrainer(TINY, cl, TrainerConfig(ride_through=True))
        inj = FaultInjector(schedule).arm(cl)
        s = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example,
                               TINY.n_slots, TINY.batch_size, seed=5)
        losses = [r["loss"] for r in tr.run(s, 8, pipelined=True)]
        inj.disarm()
        return losses, tr, cl, inj

    want, *_ = run("clean", [])
    got, tr, cl, inj = run("chaos", [FaultSpec(NODE_KILL, at_op=25, node_id=1)])
    assert inj.all_fired(), "the owner kill must actually have happened"
    assert cl.fault_counters["node_recoveries"] >= 1
    np.testing.assert_array_equal(got, want)
    assert cl.total_pins() == 0, "drain+replay leaked pins"
    assert tr.ps.n_inflight() == 0, "drain+replay leaked in-flight entries"


def test_eval_prepare_does_not_taint_device_residency(tmp_path):
    """The train_ctr_e2e.py flow: an eval-style prepare_batch + abort_batch
    between training runs must not leave the registry believing those keys
    are device-resident — the next run would device-serve rows that never
    reached the device and train zeros in their place."""
    def trainer(tag):
        cl = Cluster(2, str(tmp_path / tag), dim=TINY.emb_dim * 2, cache_capacity=2048,
                     file_capacity=128, init_cols=TINY.emb_dim)
        return CTRTrainer(TINY, cl, TrainerConfig())

    tainted, clean = trainer("t"), trainer("c")
    stream = lambda: SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example,
                                        TINY.n_slots, TINY.batch_size, seed=9)
    eval_stream = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example,
                                     TINY.n_slots, TINY.batch_size, seed=4)
    ws = tainted.ps.prepare_batch(eval_stream.next_batch().keys)  # eval pull
    tainted.ps.abort_batch(ws)
    got = [r["loss"] for r in tainted.run(stream(), 4)]
    want = [r["loss"] for r in clean.run(stream(), 4)]
    np.testing.assert_array_equal(got, want)


def test_token_floor_tracks_inflight_window(ps):
    """Completion tokens are collapsed into a floor watermark derived from
    the registry's actual in-flight window (not a hardcoded distance): a
    late waiter on a long-departed batch returns immediately instead of
    hanging, and the done-set stays bounded over long runs."""
    n = 100
    for i in range(n):
        ws = ps.prepare_batch(keys(i % 7, 7 + i % 5), batch_id=i)
        ps.complete_batch(ws, np.zeros((ws.n_working, EMB), np.float32),
                          np.zeros((ws.n_working, OPT), np.float32))
    fam = ps._token_family
    # every departed batch's token answers instantly (floor, not hang)
    for seq in (0, 1, n // 2, n - 1):
        ps.deps.wait((fam, seq), timeout=0.05)
        assert ps.deps.is_done((fam, seq))
    # the done-set itself holds no per-batch backlog
    assert len(ps.deps._done) == 0
    assert ps.n_inflight() == 0
    # an untrained in-flight batch holds the floor back: its own token (and
    # any later one) must NOT read as done
    ws = ps.prepare_batch(keys(1, 2), batch_id=n)
    assert not ps.deps.is_done((fam, ws.batch_id))
    with pytest.raises(TimeoutError):
        ps.deps.wait((fam, ws.batch_id), timeout=0.05)
    ps.abort_batch(ws)
    ps.deps.wait((fam, ws.batch_id), timeout=0.05)  # abort released it


def test_two_trainer_configs_do_not_share_state(tmp_path):
    c1, c2 = TrainerConfig(), TrainerConfig()
    assert c1 is not c2
    cl = Cluster(1, str(tmp_path / "ps"), dim=TINY.emb_dim * 2, cache_capacity=256,
                 file_capacity=32, init_cols=TINY.emb_dim)
    t1 = CTRTrainer(TINY, cl)
    t2 = CTRTrainer(TINY, cl)
    assert t1.tcfg is not t2.tcfg  # no shared mutable default instance
    t1.tcfg.queue_capacity = 99
    assert t2.tcfg.queue_capacity == 2
