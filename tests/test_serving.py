"""Serving subsystem semantics (DESIGN.md §7).

Pins the acceptance contract of the ServingEngine / snapshot stack:

* publishing is repointing (no SSD rewrite) and atomic (LATEST flips last);
* ServingEngine rows are bit-identical to a direct cluster pull on the
  cold, hot-cached, coalesced and device paths;
* version rollover is atomic under in-flight lookups — every request is
  served from exactly one version;
* retention keeps a published version readable across compaction, and
  release reclaims the parked files;
* serving counters flow through metrics.Counters.
"""

import os
import threading

import numpy as np
import pytest

from repro.core.client import PSClient
from repro.core.hbm_ps import DeviceHotSet
from repro.core.node import Cluster, NetworkModel
from repro.core.tables import RowSchema, TableSpec
from repro.metrics import Counters
from repro.serve import (
    ServingCluster,
    ServingEngine,
    SnapshotPublisher,
    latest_version,
    list_versions,
)
from repro.serve.engine import HotRowCache

DIM = 8
N_KEYS = 300


@pytest.fixture
def setup(tmp_path):
    cluster = Cluster(2, str(tmp_path / "train"), dim=DIM,
                      cache_capacity=1024, file_capacity=64)
    client = PSClient(cluster, [TableSpec("emb", RowSchema.embedding(DIM))])
    keys = np.arange(N_KEYS, dtype=np.uint64)
    rows = np.random.default_rng(0).normal(size=(N_KEYS, DIM)).astype(np.float32)
    cluster.push(keys, rows, unpin=False)
    pub = SnapshotPublisher(cluster, str(tmp_path / "snap"))
    return cluster, client, pub, keys, rows


# ------------------------------------------------------------- publishing


def test_publish_is_repoint_not_copy(setup):
    cluster, client, pub, keys, rows = setup
    cluster.flush_all()
    written_before = sum(n.ssd.stats.bytes_written for n in cluster.nodes)
    v = pub.publish()
    assert v == 1 and latest_version(pub.dir) == 1
    # no parameter bytes were rewritten: the manifest repoints existing files
    assert sum(n.ssd.stats.bytes_written for n in cluster.nodes) == written_before
    v2 = pub.publish()
    assert list_versions(pub.dir) == [1, 2] and latest_version(pub.dir) == v2


def test_publisher_resumes_version_numbering(setup, tmp_path):
    cluster, client, pub, keys, rows = setup
    pub.publish()
    pub.publish()
    pub2 = SnapshotPublisher(cluster, pub.dir)  # restart
    assert pub2.publish() == 3


def test_release_reaches_versions_of_a_previous_publisher(setup):
    """A restarted publisher must be able to release versions it did not
    publish itself (retained paths come from the on-disk manifest), and
    release must be idempotent — a double release would over-decrement
    refs shared with still-live versions."""
    cluster, client, pub, keys, rows = setup
    v1 = pub.publish()
    pub2 = SnapshotPublisher(cluster, pub.dir)
    v2 = pub2.publish()  # shares v1's (unchanged) files -> refs now 2
    cluster.push(keys, rows * 2, unpin=False)
    cluster.flush_all()
    pub2.release(v1)
    pub2.release(v1)  # idempotent: must not touch v2's shared refs
    for n in cluster.nodes:
        n.ssd.compact(force=True)
    # v2 still readable: its files survived v1's (double) release
    eng = ServingEngine(ServingCluster(pub.dir, version=v2), cache_rows=0)
    np.testing.assert_array_equal(eng.lookup("emb", keys[:40]), rows[:40])
    pub2.release(v2)
    assert sum(n.ssd.n_retained_orphans for n in cluster.nodes) == 0


def test_serving_cluster_requires_a_version(tmp_path):
    with pytest.raises(FileNotFoundError):
        ServingCluster(str(tmp_path / "empty"))


# ------------------------------------------------- bit-identical serving


def test_cold_hot_and_coalesced_paths_bit_identical(setup):
    cluster, client, pub, keys, rows = setup
    pub.publish()
    q = np.concatenate([keys[:64], keys[200:240]])
    direct = cluster.pull(q, pin=False)[:, :DIM]  # the reference rows
    eng = client.serving_view(snapshots=pub, cache_rows=512)

    cold = eng.lookup("emb", q)
    np.testing.assert_array_equal(cold, direct)
    assert eng.counters["hot_hits"] == 0

    hot = eng.lookup("emb", q)  # every row now cache-resident
    np.testing.assert_array_equal(hot, direct)
    assert eng.counters["hot_hits"] == len(q)

    # coalesced multi-stream == per-stream, including cross-request dedup
    streams = [keys[:50], keys[25:75], keys[250:290]]
    merged = eng.lookup_many([("emb", s) for s in streams])
    fresh = client.serving_view(snapshots=pub, cache_rows=0)
    for got, s in zip(merged, streams):
        np.testing.assert_array_equal(got, fresh.lookup("emb", s))
    assert eng.counters["coalesced_requests"] >= 3


def test_missing_keys_serve_deterministic_init_parity(setup):
    cluster, client, pub, keys, rows = setup
    pub.publish()
    never_written = np.arange(10_000, 10_040, dtype=np.uint64)
    direct = cluster.pull(never_written, pin=False)[:, :DIM]
    eng = client.serving_view(snapshots=pub)
    np.testing.assert_array_equal(eng.lookup("emb", never_written), direct)


def test_lookup_preserves_request_shape_and_dedups(setup):
    cluster, client, pub, keys, rows = setup
    pub.publish()
    eng = client.serving_view(snapshots=pub)
    q = np.array([[5, 7, 5], [7, 5, 2]], dtype=np.uint64)
    out = eng.lookup("emb", q)
    assert out.shape == (2, 3, DIM)
    np.testing.assert_array_equal(out[0, 0], out[0, 2])
    np.testing.assert_array_equal(out[0, 1], out[1, 0])
    with pytest.raises(KeyError):
        eng.lookup("nope", q)


def test_wire_quantized_engine_matches_read_only_session(setup):
    """int8 serving transport: engine rows == the PR-3 read-only session's
    rows over an identically-quantizing network (both decode the same
    deterministic packets)."""
    cluster, client, pub, keys, rows = setup
    pub.publish()
    q = keys[:100]
    eng = client.serving_view(
        snapshots=pub, network=NetworkModel(wire_quantize=True), cache_rows=256
    )
    got = eng.lookup("emb", q)
    cluster.network.wire_quantize = True
    try:
        with client.session("emb", q, read_only=True) as s:
            ref = s.params[s.slots]
    finally:
        cluster.network.wire_quantize = False
    np.testing.assert_array_equal(got, ref)
    # hot path returns the SAME decoded bytes again
    np.testing.assert_array_equal(eng.lookup("emb", q), got)
    assert eng.source.network.quantized_messages > 0


# ------------------------------------------------------- version rollover


def test_rollover_atomic_under_concurrent_lookups(setup):
    cluster, client, pub, keys, rows = setup
    v_rows = {}
    for marker in (1.0, 2.0):
        cluster.push(keys, np.full((N_KEYS, DIM), marker, np.float32), unpin=False)
        v_rows[pub.publish()] = marker
    eng = client.serving_view(snapshots=pub, version=1, cache_rows=512)
    assert eng.version == 1

    stop = threading.Event()
    bad: list[str] = []
    done_iters: list[int] = []
    rng_seeds = range(4)

    def worker(seed):
        rng = np.random.default_rng(seed)
        n = 0
        try:
            while not stop.is_set():
                q = rng.choice(N_KEYS, size=32).astype(np.uint64)
                out = eng.lookup("emb", q)
                n += 1
                vals = np.unique(out)
                # every row of one request must be from exactly one version
                if len(vals) != 1 or vals[0] not in (1.0, 2.0):
                    bad.append(f"mixed versions in one request: {vals[:4]}")
                    stop.set()
        except BaseException as e:  # a crash must fail the test, not pass it
            bad.append(f"worker raised: {e!r}")
            stop.set()
        finally:
            done_iters.append(n)

    threads = [threading.Thread(target=worker, args=(s,)) for s in rng_seeds]
    for t in threads:
        t.start()
    eng.roll_forward(2)
    stop.set()
    for t in threads:
        t.join()
    assert not bad, bad[0]
    assert sum(done_iters) > 0, "workers never completed a lookup"
    assert eng.version == 2 and eng.counters["version_rolls"] == 1
    # post-roll: the version-keyed cache must not serve v1 rows
    np.testing.assert_array_equal(
        eng.lookup("emb", keys[:16]), np.full((16, DIM), 2.0, np.float32)
    )
    # rolling to the version already active is a no-op
    assert eng.roll_forward() == 2 and eng.counters["version_rolls"] == 1


def test_retention_survives_compaction_and_release_reclaims(setup):
    cluster, client, pub, keys, rows = setup
    v1 = pub.publish()
    e1 = ServingEngine(ServingCluster(pub.dir, version=v1), cache_rows=0)
    before = e1.lookup("emb", keys[:80])
    # supersede every row, then force compaction — v1's files turn stale
    cluster.push(keys, rows * 3.0, unpin=False)
    cluster.flush_all()
    for n in cluster.nodes:
        n.ssd.compact(force=True)
    orphans = [n.ssd.n_retained_orphans for n in cluster.nodes]
    assert sum(orphans) > 0, "compaction should park retained files, not delete"
    parked = [
        os.path.join(n.ssd.dir, p) if not os.path.isabs(p) else p
        for n in cluster.nodes
        for p in n.ssd._orphaned
    ]
    assert all(os.path.exists(p) for p in parked)
    # v1 still serves its original rows from the parked files
    np.testing.assert_array_equal(e1.lookup("emb", keys[:80]), before)
    pub.release(v1)
    assert sum(n.ssd.n_retained_orphans for n in cluster.nodes) == 0
    assert not any(os.path.exists(p) for p in parked)


def test_retention_survives_cluster_restore(setup, tmp_path):
    """Retention refs live in the SSD instances; a Cluster.restore starts
    with zero. publisher.rebind must re-take them or compaction on the
    restored cluster deletes files published versions still reference."""
    cluster, client, pub, keys, rows = setup
    v1 = pub.publish()
    e1 = ServingEngine(ServingCluster(pub.dir, version=v1), cache_rows=0)
    before = e1.lookup("emb", keys[:60])
    manifest = cluster.manifest()
    restored = Cluster.restore(manifest, cluster.base_dir, **cluster.ctor_kwargs())
    pub.rebind(restored)
    restored.push(keys, rows * 7.0, unpin=False)
    restored.flush_all()
    for n in restored.nodes:
        n.ssd.compact(force=True)
    # v1's files were superseded + compacted on the restored cluster — the
    # re-taken refs must have parked them, not deleted them
    np.testing.assert_array_equal(e1.lookup("emb", keys[:60]), before)
    pub.release(v1)
    assert sum(n.ssd.n_retained_orphans for n in restored.nodes) == 0


def test_publisher_keep_auto_releases_old_versions(setup):
    cluster, client, pub, keys, rows = setup
    pub.keep = 2
    versions = [pub.publish() for _ in range(4)]
    assert sorted(pub._live) == versions[-2:]  # older refs dropped


# ------------------------------------------------------------ live serving


def test_live_view_and_manual_invalidation(setup):
    cluster, client, pub, keys, rows = setup
    eng = client.serving_view(cache_rows=256)  # no snapshots: live cluster
    q = keys[:40]
    np.testing.assert_array_equal(
        eng.lookup("emb", q), cluster.pull(q, pin=False)[:, :DIM]
    )
    cluster.push(q, rows[:40] * 5.0, unpin=False)
    # cached rows are stale until the caller rolls the serving epoch
    eng.roll_forward()
    np.testing.assert_array_equal(eng.lookup("emb", q), rows[:40] * 5.0)
    assert cluster.total_pins() == 0, "serving must never pin"


# ---------------------------------------------------------- device tier


def test_lookup_device_matches_host_rows_across_steps(setup):
    cluster, client, pub, keys, rows = setup
    pub.publish()
    eng = client.serving_view(snapshots=pub, cache_rows=512, device_hot_rows=64)
    rng = np.random.default_rng(1)
    for step in range(12):
        q = rng.choice(128, size=(3, 6)).astype(np.uint64)  # heavy reuse
        slots, tbl = eng.lookup_device("emb", q)
        got = np.asarray(tbl)[slots]
        np.testing.assert_array_equal(got, rows[q.reshape(-1)].reshape(3, 6, DIM))
    st = eng.device_hot_stats("emb")
    assert st.rows_reused > 0 and eng.counters["device_rows_reused"] == st.rows_reused


def test_device_hot_set_version_keyed_reset():
    dev = DeviceHotSet(capacity=8, row_bytes=16)
    import jax.numpy as jnp

    keys = np.array([1, 2, 3], dtype=np.uint64)
    rows = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    plan = dev.plan(keys, version=1)
    assert plan.n_reused == 0
    dev.assemble_and_admit(rows, plan)
    assert dev.plan(keys, version=1).n_reused == 3  # resident now
    assert dev.plan(keys, version=2).n_reused == 0  # roll resets residency


def test_device_hot_set_capacity_keeps_hottest():
    dev = DeviceHotSet(capacity=2, row_bytes=16)
    import jax.numpy as jnp

    hot = np.array([1, 2], dtype=np.uint64)
    rows2 = jnp.ones((2, 4), dtype=jnp.float32)
    for _ in range(3):  # make keys 1,2 clearly hottest
        dev.assemble_and_admit(rows2, dev.plan(hot, version=1))
    cold = np.array([3, 4], dtype=np.uint64)
    dev.assemble_and_admit(rows2 * 2, dev.plan(cold, version=1))
    assert dev.n_resident == 2
    plan = dev.plan(hot, version=1)
    assert plan.n_reused == 2, "hottest keys must stay resident"


# ------------------------------------------------------- hot-row cache


def test_hot_row_cache_eviction_and_version_keying():
    cache = HotRowCache(capacity=4, dim=2)
    k = np.arange(4, dtype=np.uint64)
    r = np.arange(8, dtype=np.float32).reshape(4, 2)
    cache.insert(k, r, version=1)
    mask, rows = cache.lookup(k, version=1)
    assert mask.all()
    np.testing.assert_array_equal(rows, r)
    # same keys at another version: all misses (staleness-free)
    mask, _ = cache.lookup(k, version=2)
    assert not mask.any()
    # inserting at v2 overwrites in place, then new keys evict the coldest
    cache.insert(k[:2], r[:2] * 10, version=2)
    newk = np.array([100, 101], dtype=np.uint64)
    cache.insert(newk, r[:2], version=2)
    mask, rows = cache.lookup(np.concatenate([k[:2], newk]), version=2)
    assert mask.all()
    np.testing.assert_array_equal(rows[:2], r[:2] * 10)
    assert len(cache) == 4  # never exceeds capacity


def test_cache_smaller_than_working_set_stays_correct(setup):
    cluster, client, pub, keys, rows = setup
    pub.publish()
    eng = client.serving_view(snapshots=pub, cache_rows=32)  # N_KEYS >> 32
    for lo in (0, 100, 200, 50):
        q = keys[lo : lo + 90]
        np.testing.assert_array_equal(eng.lookup("emb", q), rows[lo : lo + 90])


# ----------------------------------------------------- coalescing + counters


def test_threaded_lookups_coalesce_and_match_per_stream(setup):
    cluster, client, pub, keys, rows = setup
    pub.publish()
    eng = client.serving_view(snapshots=pub, coalesce_window_s=0.05)
    ref = client.serving_view(snapshots=pub, cache_rows=0)
    streams = {i: keys[i * 30 : i * 30 + 60] for i in range(5)}
    outs: dict[int, np.ndarray] = {}
    barrier = threading.Barrier(len(streams))

    def worker(i):
        barrier.wait()
        outs[i] = eng.lookup("emb", streams[i])

    threads = [threading.Thread(target=worker, args=(i,)) for i in streams]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for i, s in streams.items():
        np.testing.assert_array_equal(outs[i], ref.lookup("emb", s))
    c = eng.counters.snapshot()
    assert c["lookups"] == len(streams)
    # the point of coalescing: strictly fewer pulls than requests
    assert c["merged_pulls"] < len(streams)


def test_counters_schema_and_metrics_counters():
    # strict=False: this test pins the dynamic-minting behavior, which
    # strict mode (REPRO_SANLOCK / REPRO_STRICT_COUNTERS) forbids
    c = Counters("a", "b", strict=False)
    assert c.snapshot() == {"a": 0, "b": 0}
    c.inc("a")
    c.inc("c", 5)
    assert c["a"] == 1 and c["c"] == 5
    c.reset()
    assert c.snapshot() == {"a": 0, "b": 0, "c": 0}


def test_counters_strict_mode_rejects_unknown_names():
    c = Counters("a", strict=True)
    c.inc("a")
    c.inc("lookups")  # registry name: fine even if not pre-declared
    with pytest.raises(ValueError, match="unknown counter"):
        c.inc("definitely_not_a_counter")


def test_trainer_publishes_versions_during_pipelined_run(tmp_path):
    """The train->serve handoff: publish_every emits versions mid-run at
    consistent cuts; a final publish serves rows bit-identical to the
    trained cluster."""
    from repro.configs.ctr_models import TINY
    from repro.data.synthetic_ctr import SyntheticCTRStream
    from repro.train.trainer import CTRTrainer, TrainerConfig

    cfg = TINY
    cluster = Cluster(2, str(tmp_path / "ps"), dim=cfg.emb_dim * 2,
                      cache_capacity=50_000, init_cols=cfg.emb_dim)
    tr = CTRTrainer(cfg, cluster, TrainerConfig(
        publish_every=2, publish_dir=str(tmp_path / "snap")))
    stream = SyntheticCTRStream(cfg.n_sparse_keys, cfg.nnz_per_example,
                                cfg.n_slots, cfg.batch_size, seed=0)
    tr.run(stream, 5, pipelined=True)
    assert latest_version(tr.publisher.dir) == 2  # batches 2 and 4
    v_final = tr.publish()
    eng = tr.client.serving_view(snapshots=tr.publisher)
    assert eng.version == v_final
    spec = tr.client.table(tr.table)
    q = np.arange(50, dtype=np.uint64)
    served = eng.lookup(tr.table, q)
    direct = cluster.pull(spec.namespace(q), pin=False)[:, : spec.schema.emb_dim]
    np.testing.assert_array_equal(served, direct)
    # the mid-run version is a different, still-readable cut
    old = tr.client.serving_view(version=2, snapshots=tr.publisher)
    assert not np.array_equal(old.lookup(tr.table, q), served)


def test_engine_counters_cover_issue_schema(setup):
    cluster, client, pub, keys, rows = setup
    pub.publish()
    eng = client.serving_view(snapshots=pub)
    eng.lookup("emb", keys[:10])
    snap = eng.counters.snapshot()
    for name in ("lookups", "coalesced_requests", "hot_hits", "version_rolls"):
        assert name in snap
