"""Model-based parity: vectorized MEM-PS vs a reference dict model.

The vectorized ``MemParameterServer`` replaces per-key OrderedDict/dict
bookkeeping with batched numpy structures. Its visible semantics (the
canonical Appendix-D batch contract documented in mem_ps.py) are pinned
here by an independent sequential implementation — plain dicts, plain
Python loops — driven side by side over randomized mixed-operation traces.

After every operation we assert identical:

* returned rows (bit-for-bit);
* hit/miss/demotion/eviction/flush counters;
* full cached state (per-key freq, pin count, dirty bit, tier, value) and
  staging-buffer state via ``debug_snapshot``;
* MemoryError behaviour under pin pressure;

and at the end of each trace, identical SSD-visible state.
"""

import numpy as np
import pytest

from repro.core.mem_ps import MemParameterServer, MemStats
from repro.core.ssd_ps import SSDParameterServer


class _Ent:
    __slots__ = ("freq", "pins", "dirty", "tier", "last_used", "lfu_time", "value")

    def __init__(self):
        self.freq = 0
        self.pins = 0
        self.dirty = False
        self.tier = "lru"
        self.last_used = 0
        self.lfu_time = 0
        self.value = None


class RefMemPS:
    """Sequential dict-model of the canonical MEM-PS batch semantics."""

    def __init__(self, ssd, capacity, lru_frac=0.5, flush_batch=2048):
        self.ssd = ssd
        self.dim = ssd.dim
        self.capacity = int(capacity)
        self.lru_capacity = max(1, int(capacity * lru_frac))
        self.flush_batch = int(flush_batch)
        self.entries: dict[int, _Ent] = {}
        self.pending: dict[int, np.ndarray] = {}
        self.clock = 0
        self.stats = MemStats()

    # ------------------------------------------------------------ internals
    def _evictable(self) -> int:
        return sum(1 for e in self.entries.values() if e.pins == 0)

    def _evict(self, need: int) -> None:
        lfu = sorted(
            (e.freq, e.lfu_time, k)
            for k, e in self.entries.items()
            if e.tier == "lfu" and e.pins == 0
        )
        victims = [k for _, _, k in lfu[:need]]
        self.stats.evict_lfu_to_ssd += len(victims)
        if len(victims) < need:
            lru = sorted(
                (e.last_used, k)
                for k, e in self.entries.items()
                if e.tier == "lru" and e.pins == 0
            )
            victims += [k for _, k in lru[: need - len(victims)]]
        for k in victims:
            e = self.entries.pop(k)
            if e.dirty:
                self.pending[k] = e.value.copy()
        if len(self.pending) >= self.flush_batch:
            self._flush_pending()

    def _shrink_lru(self) -> None:
        n_lru = sum(1 for e in self.entries.values() if e.tier == "lru")
        excess = n_lru - self.lru_capacity
        if excess <= 0:
            return
        unpinned = sorted(
            (e.last_used, k)
            for k, e in self.entries.items()
            if e.tier == "lru" and e.pins == 0
        )
        for _, k in unpinned[:excess]:
            e = self.entries[k]
            e.tier = "lfu"
            e.lfu_time = self.clock
            self.clock += 1
            self.stats.evict_lru_to_lfu += 1

    def _flush_pending(self) -> None:
        if not self.pending:
            return
        ks = np.fromiter(self.pending.keys(), np.uint64, len(self.pending))
        self.ssd.write_batch(ks, np.stack([self.pending[int(k)] for k in ks]))
        self.stats.flushed_rows += len(ks)
        self.pending.clear()

    # ------------------------------------------------------------ interface
    def pull(self, keys, pin=True):
        keys = np.asarray(keys, np.uint64).reshape(-1)
        uniq, first_idx, inverse, counts = np.unique(
            keys, return_index=True, return_inverse=True, return_counts=True
        )
        base = self.clock
        self.clock += len(keys)
        out_u = np.empty((len(uniq), self.dim), np.float32)
        absent = []
        for i, k in enumerate(uniq.tolist()):
            e = self.entries.get(k)
            if e is None:
                absent.append(i)
                continue
            c = int(counts[i])
            self.stats.hits += c
            e.freq += c
            e.tier = "lru"  # re-visits promote LFU rows back into LRU
            e.last_used = base + int(first_idx[i])
            if pin:
                e.pins += c
            out_u[i] = e.value
        absent.sort(key=lambda i: int(first_idx[i]))
        while absent:
            free = self.capacity - len(self.entries)
            avail = free + self._evictable()
            if avail == 0:
                raise MemoryError("all rows pinned")
            chunk, absent = absent[:avail], absent[avail:]
            if len(chunk) > free:
                self._evict(len(chunk) - free)
            miss = [int(uniq[i]) for i in chunk if int(uniq[i]) not in self.pending]
            vals = {}
            if miss:
                arr = self.ssd.read_batch(np.asarray(miss, np.uint64))
                vals = {k: arr[j] for j, k in enumerate(miss)}
            for i in chunk:
                k, c = int(uniq[i]), int(counts[i])
                e = _Ent()
                if k in self.pending:
                    self.stats.hits += c
                    e.value = self.pending.pop(k)
                    e.dirty = True
                else:
                    self.stats.misses += c
                    e.value = np.array(vals[k], np.float32)
                e.freq = c
                e.pins = c if pin else 0
                e.last_used = base + int(first_idx[i])
                self.entries[k] = e
                out_u[i] = e.value
        self._shrink_lru()
        return out_u[inverse]

    def push(self, keys, values, unpin=True):
        keys = np.asarray(keys, np.uint64).reshape(-1)
        values = np.asarray(values, np.float32).reshape(len(keys), -1)
        uniq, first_idx, inverse, counts = np.unique(
            keys, return_index=True, return_inverse=True, return_counts=True
        )
        base = self.clock
        self.clock += len(keys)
        last_idx = np.empty(len(uniq), np.int64)
        last_idx[inverse] = np.arange(len(keys))  # last occurrence wins
        absent = []
        for i, k in enumerate(uniq.tolist()):
            e = self.entries.get(k)
            if e is None:
                absent.append(i)
                continue
            e.value = np.array(values[last_idx[i]], np.float32)
            e.dirty = True
            if unpin:
                e.pins = max(e.pins - int(counts[i]), 0)
        absent.sort(key=lambda i: int(first_idx[i]))
        while absent:
            free = self.capacity - len(self.entries)
            avail = free + self._evictable()
            if avail == 0:
                raise MemoryError("all rows pinned")
            chunk, absent = absent[:avail], absent[avail:]
            for i in chunk:  # pushed value supersedes any staged copy
                self.pending.pop(int(uniq[i]), None)
            if len(chunk) > free:
                self._evict(len(chunk) - free)
            for i in chunk:
                k = int(uniq[i])
                e = _Ent()
                e.value = np.array(values[last_idx[i]], np.float32)
                e.freq = 1
                e.dirty = True
                e.last_used = base + int(first_idx[i])
                self.entries[k] = e
        self._shrink_lru()

    def unpin(self, keys):
        keys = np.asarray(keys, np.uint64).reshape(-1)
        uniq, counts = np.unique(keys, return_counts=True)
        for k, c in zip(uniq.tolist(), counts.tolist()):
            e = self.entries.get(k)
            if e is not None:
                e.pins = max(e.pins - c, 0)

    def flush_all(self):
        dirty = [(k, e) for k, e in self.entries.items() if e.dirty]
        if dirty:
            ks = np.asarray([k for k, _ in dirty], np.uint64)
            self.ssd.write_batch(ks, np.stack([e.value for _, e in dirty]))
            self.stats.flushed_rows += len(dirty)
            for _, e in dirty:
                e.dirty = False
        self._flush_pending()

    def debug_snapshot(self):
        cached = {
            k: (e.freq, e.pins, e.dirty, e.tier, tuple(float(x) for x in e.value))
            for k, e in self.entries.items()
        }
        pending = {k: tuple(float(x) for x in v) for k, v in self.pending.items()}
        return cached, pending


# --------------------------------------------------------------------------
# trace driver
# --------------------------------------------------------------------------

DIM = 3
CAPACITY = 24
KEY_SPACE = 60
FLUSH_BATCH = 8


def _stats_tuple(s):
    return (s.hits, s.misses, s.evict_lru_to_lfu, s.evict_lfu_to_ssd, s.flushed_rows)


def _assert_same_state(vec, ref, step):
    assert _stats_tuple(vec.stats) == _stats_tuple(ref.stats), f"stats @ op {step}"
    vc, vp = vec.debug_snapshot()
    rc, rp = ref.debug_snapshot()
    assert vc == rc, f"cached state @ op {step}"
    assert vp == rp, f"pending state @ op {step}"
    assert vec.n_cached == len(ref.entries)


def _run_trace(tmp_path, seed, n_ops):
    ssd_v = SSDParameterServer(str(tmp_path / f"v{seed}"), dim=DIM, file_capacity=8)
    ssd_r = SSDParameterServer(str(tmp_path / f"r{seed}"), dim=DIM, file_capacity=8)
    vec = MemParameterServer(ssd_v, CAPACITY, flush_batch=FLUSH_BATCH)
    ref = RefMemPS(ssd_r, CAPACITY, flush_batch=FLUSH_BATCH)
    rng = np.random.default_rng(seed)
    raised = 0
    for step in range(n_ops):
        op = rng.choice(
            ["pull_pin", "pull", "push", "unpin", "flush", "big_pull"],
            p=[0.25, 0.25, 0.25, 0.15, 0.05, 0.05],
        )
        keys = rng.integers(0, KEY_SPACE, size=int(rng.integers(1, 12))).astype(np.uint64)
        if op == "big_pull":  # unpinned batch larger than the whole cache
            keys = rng.permutation(KEY_SPACE).astype(np.uint64)[: CAPACITY + 10]
        vals = rng.standard_normal((len(keys), DIM)).astype(np.float32)

        def apply(m):
            if op in ("pull_pin", "pull", "big_pull"):
                return m.pull(keys, pin=op == "pull_pin")
            if op == "push":
                return m.push(keys, vals)
            if op == "unpin":
                return m.unpin(keys)
            return m.flush_all()

        results, errors = [], []
        for m in (vec, ref):
            try:
                results.append(apply(m))
                errors.append(None)
            except MemoryError as e:
                results.append(None)
                errors.append(e)
        assert (errors[0] is None) == (errors[1] is None), f"MemoryError parity @ op {step}"
        if errors[0] is not None:
            raised += 1
        elif results[0] is not None:
            np.testing.assert_array_equal(results[0], results[1], err_msg=f"pull @ op {step}")
        _assert_same_state(vec, ref, step)
    vec.flush_all()
    ref.flush_all()
    _assert_same_state(vec, ref, "final")
    universe = np.arange(KEY_SPACE, dtype=np.uint64)
    np.testing.assert_array_equal(
        ssd_v.read_batch(universe), ssd_r.read_batch(universe), err_msg="SSD state"
    )
    assert ssd_v.n_live_rows == ssd_r.n_live_rows
    return vec, raised


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_randomized_trace_parity(tmp_path, seed):
    """>=1000 mixed ops across the three seeds, identical visible state."""
    vec, _ = _run_trace(tmp_path, seed, n_ops=400)
    s = vec.stats
    # the trace must actually exercise the interesting machinery
    assert s.hits > 0 and s.misses > 0
    assert s.evict_lru_to_lfu > 0 and s.evict_lfu_to_ssd > 0
    assert s.flushed_rows > 0


def test_pin_pressure_memoryerror_parity(tmp_path):
    """Both models raise MemoryError at the same point, and agree after."""
    ssd_v = SSDParameterServer(str(tmp_path / "v"), dim=DIM, file_capacity=8)
    ssd_r = SSDParameterServer(str(tmp_path / "r"), dim=DIM, file_capacity=8)
    vec = MemParameterServer(ssd_v, 16, flush_batch=FLUSH_BATCH)
    ref = RefMemPS(ssd_r, 16, flush_batch=FLUSH_BATCH)
    keys = np.arange(16, dtype=np.uint64)
    np.testing.assert_array_equal(vec.pull(keys, pin=True), ref.pull(keys, pin=True))
    overflow = np.arange(16, 26, dtype=np.uint64)
    with pytest.raises(MemoryError):
        vec.pull(overflow, pin=True)
    with pytest.raises(MemoryError):
        ref.pull(overflow, pin=True)
    _assert_same_state(vec, ref, "after MemoryError")
    vec.unpin(keys)
    ref.unpin(keys)
    np.testing.assert_array_equal(
        vec.pull(overflow, pin=False), ref.pull(overflow, pin=False)
    )
    _assert_same_state(vec, ref, "after unpin recovery")
