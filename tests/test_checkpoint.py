"""Checkpoint/restart: atomicity, async, deterministic resume, node failure."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.ctr_models import TINY
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.train import checkpoint as ckpt
from repro.train.trainer import CTRTrainer, TrainerConfig


def test_save_restore_roundtrip(tmp_path):
    tree = {
        "a": np.arange(10, dtype=np.float32),
        "nested": {"b": np.ones((3, 4)), "c": np.int32(7)},
    }
    ckpt.save(str(tmp_path), 3, tree, extra={"note": "hi"})
    got, step, extra, _ = ckpt.restore(str(tmp_path), tree)
    assert step == 3 and extra["note"] == "hi"
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["nested"]["b"], tree["nested"]["b"])


def test_latest_pointer_and_gc(tmp_path):
    tree = {"x": np.zeros(4)}
    c = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        c.save(s, tree)
    c.wait()
    assert ckpt.latest_step(str(tmp_path)) == 4
    kept = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(kept) == 2


def test_async_overlap_correctness(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = {"x": np.random.default_rng(0).random(1000)}
    c.save(1, tree)
    tree["x"] = tree["x"] + 1  # mutate AFTER snapshot; save must be isolated
    c.wait()
    got, _, _, _ = ckpt.restore(str(tmp_path), tree)
    np.testing.assert_allclose(got["x"], tree["x"] - 1)


def test_trainer_crash_restart_continues(tmp_path):
    """Kill after N batches; a fresh trainer restores and continues with the
    PS state intact (SSD manifest + params)."""
    cl = Cluster(2, str(tmp_path / "ps"), dim=TINY.emb_dim * 2,
                 cache_capacity=2048, file_capacity=64, init_cols=TINY.emb_dim)
    cfg = TrainerConfig(checkpoint_every=4, checkpoint_dir=str(tmp_path / "ck"))
    tr = CTRTrainer(TINY, cl, cfg)
    stream = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=3)
    tr.run(stream, 8)
    tower_before = jax.tree.map(np.asarray, tr.tower)
    del tr  # "crash"

    cl2 = Cluster(2, str(tmp_path / "ps"), dim=TINY.emb_dim * 2,
                  cache_capacity=2048, file_capacity=64, init_cols=TINY.emb_dim)
    tr2 = CTRTrainer(TINY, cl2, cfg)
    step = tr2.resume()
    assert step == 8
    for k in tower_before:
        np.testing.assert_allclose(np.asarray(tr2.tower[k]), tower_before[k])
    # training continues without error and params keep moving
    more = tr2.run(stream, 4)
    assert len(more) == 4


def test_resume_preserves_cluster_config(tmp_path):
    """Cluster.restore used to be called with defaults, silently reverting a
    resumed cluster to cache_capacity=100_000 / file_capacity=4096 and a
    fresh NetworkModel; resume must rebuild with the original kwargs."""
    from repro.core.node import NetworkModel

    net = NetworkModel(latency_s=1e-3, bandwidth_gbps=7.0)
    cl = Cluster(2, str(tmp_path / "ps"), dim=TINY.emb_dim * 2,
                 cache_capacity=777, file_capacity=64, network=net,
                 init_cols=TINY.emb_dim)
    cfg = TrainerConfig(checkpoint_every=2, checkpoint_dir=str(tmp_path / "ck"))
    tr = CTRTrainer(TINY, cl, cfg)
    stream = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example,
                                TINY.n_slots, TINY.batch_size, seed=3)
    tr.run(stream, 2)
    tr.resume()
    assert tr.cluster is not cl  # a restored cluster, not the original
    assert tr.cluster.cache_capacity == 777
    assert tr.cluster.file_capacity == 64
    assert tr.cluster.network is net  # stats keep accumulating
    assert all(n.mem.capacity == 777 for n in tr.cluster.nodes)
    assert all(n.ssd.file_capacity == 64 for n in tr.cluster.nodes)


def test_ps_node_failure_recovery(tmp_path):
    """A dead node loses DRAM; restart + manifest restore recovers rows."""
    cl = Cluster(3, str(tmp_path / "ps"), dim=4, cache_capacity=256, file_capacity=32)
    keys = np.arange(120, dtype=np.uint64)
    v = cl.pull(keys)
    cl.push(keys, v + 3)
    manifest = cl.manifest()  # flushes dirty rows
    cl.kill_node(1)
    restored = Cluster.restore(manifest, cl.base_dir)
    got = restored.pull(keys, pin=False)
    np.testing.assert_allclose(got, v + 3)
