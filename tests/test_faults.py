"""Fault injection + ride-through recovery across the PS hierarchy (§9).

Pins the acceptance contract of the fault model:

* dead owners are surfaced (``NodeDownError``), never silently skipped —
  and with ``auto_recover`` the cluster rides through transparently;
* ``recover_node`` (restart + redo replay) restores a killed node's DRAM
  state *bit-exactly*;
* SSD file corruption (drop/truncate/bit-flip) is detected by the CRC32
  checksum, quarantined, and healed bit-exactly from snapshot+redo (or the
  deterministic initializer+redo for clusters born empty) — garbage is
  never served;
* the pipelined trainer drains in-flight batches on a node kill, replays
  them after recovery, and finishes with losses and parameters bitwise
  equal to a fault-free run;
* elastic reshard recovers (or raises with the at-risk row count) instead
  of dropping a dead shard's rows; ``reshard_live`` replays the redo delta
  so the new cluster matches the old bit-for-bit;
* the serving engine fails over to surviving replicas and never poisons
  the hot-row cache with failover rows;
* the :class:`FaultInjector` itself is deterministic (seeded schedules).
"""

import os

import numpy as np
import pytest

from repro.configs.ctr_models import TINY
from repro.core import elastic
from repro.core.client import PSClient
from repro.core.faults import (
    NIC_STALL,
    NODE_KILL,
    SSD_DROP,
    SSD_TRUNCATE,
    FaultInjector,
    FaultSpec,
)
from repro.core.node import Cluster, NodeDownError
from repro.core.recovery import RedoLog, RedoTruncatedError, collapse_entries
from repro.core.ssd_ps import SSDCorruptionError
from repro.core.tables import RowSchema, TableSpec
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.serve import ServingCluster, ServingEngine, SnapshotPublisher
from repro.train.trainer import CTRTrainer, TrainerConfig

DIM = 8


def make_cluster(tmp_path, tag="ps", n=2, **kw):
    kw.setdefault("cache_capacity", 1024)
    kw.setdefault("file_capacity", 32)
    return Cluster(n, str(tmp_path / tag), dim=DIM, **kw)


def rand_rows(n, seed=0):
    return np.random.default_rng(seed).normal(size=(n, DIM)).astype(np.float32)


# ------------------------------------------------------------ redo log


def test_redo_log_pin_and_compaction():
    log = RedoLog()
    k = np.arange(10, dtype=np.uint64)
    log.append(k, rand_rows(10, 1))
    pin = log.pin()
    log.append(k, rand_rows(10, 2))
    log.mark_durable()  # compacts only up to the pin
    assert log.covers(log.pin_index(pin))
    assert len(log.since(log.pin_index(pin))) == 1  # the post-pin entry
    log.release(pin)
    log.mark_durable()
    with pytest.raises(RedoTruncatedError):
        log.since(0)


def test_collapse_entries_last_writer_wins():
    log = RedoLog()
    k = np.array([1, 2, 3], dtype=np.uint64)
    log.append(k, np.full((3, DIM), 1.0, np.float32))
    log.append(k[:2], np.full((2, DIM), 2.0, np.float32))
    ck, cv = collapse_entries(log.entries())
    got = {int(a): float(b[0]) for a, b in zip(ck, cv)}
    assert got == {1: 2.0, 2: 2.0, 3: 1.0}


# ------------------------------------------------------- fault injector


def test_injector_seeded_schedule_is_deterministic():
    a = FaultInjector.from_seed(7, n_nodes=4, kills=2, drops=1, stalls=1)
    b = FaultInjector.from_seed(7, n_nodes=4, kills=2, drops=1, stalls=1)
    assert a.schedule == b.schedule
    c = FaultInjector.from_seed(8, n_nodes=4, kills=2, drops=1, stalls=1)
    assert a.schedule != c.schedule


def test_injector_kill_fires_at_op_and_surfaces_node_down(tmp_path):
    cl = make_cluster(tmp_path)
    inj = FaultInjector([FaultSpec(NODE_KILL, at_op=3, node_id=1)]).arm(cl)
    keys = np.arange(64, dtype=np.uint64)
    cl.pull(keys, pin=False)  # ops 1..2 (one per touched node)
    with pytest.raises(NodeDownError):
        # op 3 kills node 1 -> the touch of node 1 in this pull raises
        cl.pull(keys, pin=False)
    assert not cl.nodes[1].alive and inj.all_fired()
    assert inj.fired[0]["kind"] == NODE_KILL
    inj.disarm()
    assert cl.nodes[0].faults is None and cl.network.faults is None


def test_injector_nic_stall_adds_latency(tmp_path):
    cl = make_cluster(tmp_path)
    FaultInjector([FaultSpec(NIC_STALL, at_op=1, stall_s=0.5)]).arm(cl)
    before = cl.network.stall_time
    cl.pull(np.arange(64, dtype=np.uint64), pin=False)
    assert cl.network.stall_time >= before + 0.5


# ------------------------------------- dead owners surface, never skip


def test_pull_push_pin_raise_on_dead_owner(tmp_path):
    """Satellite: Cluster.pull/push/pin previously skipped dead owners
    silently (returning init rows / dropping updates). They must raise."""
    cl = make_cluster(tmp_path)
    keys = np.arange(100, dtype=np.uint64)  # spans both shards
    rows = rand_rows(100)
    cl.push(keys, rows, unpin=False)
    cl.kill_node(1)
    with pytest.raises(NodeDownError):
        cl.pull(keys, pin=False)
    with pytest.raises(NodeDownError):
        cl.push(keys, rows, unpin=False)
    with pytest.raises(NodeDownError):
        cl.pin(keys)
    assert cl.total_pins() == 0


def test_auto_recover_rides_through_a_kill(tmp_path):
    cl = make_cluster(tmp_path, auto_recover=True)
    cl.enable_redo()
    keys = np.arange(100, dtype=np.uint64)
    rows = rand_rows(100)
    cl.push(keys, rows, unpin=False)
    cl.kill_node(1)
    got = cl.pull(keys, pin=False)  # transparent restart + redo replay
    np.testing.assert_array_equal(got, rows)
    assert cl.fault_counters["node_recoveries"] == 1
    assert cl.recovery_time_s > 0.0


# ----------------------------------------------------- exact recovery


def test_recover_node_is_bit_exact(tmp_path):
    cl = make_cluster(tmp_path)
    cl.enable_redo()
    keys = np.arange(200, dtype=np.uint64)
    for seed in range(3):  # several overwrite rounds: replay must keep order
        cl.push(keys, rand_rows(200, seed), unpin=False)
    want = cl.pull(keys, pin=False)
    cl.kill_node(0)
    assert cl.recover_node(0)
    np.testing.assert_array_equal(cl.pull(keys, pin=False), want)
    assert cl.fault_counters["node_recoveries"] == 1
    assert cl.fault_counters["rows_replayed"] > 0


def test_recover_without_redo_raises(tmp_path):
    cl = make_cluster(tmp_path)  # redo off by default
    cl.push(np.arange(10, dtype=np.uint64), rand_rows(10), unpin=False)
    cl.kill_node(0)
    with pytest.raises(NodeDownError):
        cl.recover_node(0)


# -------------------------------------------- SSD corruption + healing


def _corrupt_one_local_file(cl, mode="flip"):
    """Damage one non-retained parameter file; returns its path."""
    for node in cl.nodes:
        for meta in node.ssd.files.values():
            if node.ssd.is_retained(meta.path):
                continue
            if mode == "drop":
                os.remove(meta.path)
            elif mode == "truncate":
                size = os.path.getsize(meta.path)
                with open(meta.path, "r+b") as f:
                    f.truncate(size // 2)
            else:  # flip payload bytes, length/header intact
                with open(meta.path, "r+b") as f:
                    f.seek(-4, os.SEEK_END)
                    f.write(b"\xde\xad\xbe\xef")
            return meta.path
    raise AssertionError("no local (non-retained) file to corrupt")


@pytest.mark.parametrize("mode", ["flip", "truncate", "drop"])
def test_checksum_quarantines_and_heals_from_init_plus_redo(tmp_path, mode):
    """A cluster born empty heals a lost file bit-exactly from the
    deterministic initializer + full redo replay."""
    cl = make_cluster(tmp_path, n=1)
    cl.enable_redo()
    pin = cl.pin_redo()  # pin at genesis: keep the FULL log (covers index 0)
    keys = np.arange(120, dtype=np.uint64)
    rows = rand_rows(120, 3)
    cl.push(keys, rows, unpin=False)
    try:
        cl.flush_all()
        _corrupt_one_local_file(cl, mode)
        got = cl.nodes[0].ssd.read_batch(keys)  # detect -> quarantine -> heal
        np.testing.assert_array_equal(got, rows)
        assert cl.fault_counters["ssd_files_quarantined"] == 1
        assert cl.fault_counters["ssd_rows_healed"] > 0
        assert cl.fault_counters["ssd_rows_reinit"] == 0
    finally:
        cl.release_redo(pin)


def test_corruption_heals_from_snapshot_plus_redo(tmp_path):
    """After a publish, healing uses snapshot(version) as the base and
    replays only the post-pin redo suffix — bit-exact current values."""
    cl = make_cluster(tmp_path, n=1)
    cl.enable_redo()
    keys = np.arange(150, dtype=np.uint64)
    base = rand_rows(150, 4)
    cl.push(keys, base, unpin=False)
    pub = SnapshotPublisher(cl, str(tmp_path / "snap"))
    pub.publish()  # pins the redo suffix; sets the heal source
    upd = rand_rows(60, 5)
    cl.push(keys[40:100], upd, unpin=False)  # post-snapshot updates
    cl.flush_all()
    want = base.copy()
    want[40:100] = upd
    _corrupt_one_local_file(cl, "flip")
    got = cl.nodes[0].ssd.read_batch(keys)
    np.testing.assert_array_equal(got, want)
    assert cl.fault_counters["ssd_files_quarantined"] == 1
    assert cl.fault_counters["ssd_rows_healed"] > 0


def test_unhealable_corruption_degrades_to_initializer(tmp_path):
    """No redo, no snapshot: the quarantined rows re-serve deterministic
    init values (counted), and garbage is never returned."""
    cl = make_cluster(tmp_path, n=1)  # redo off
    keys = np.arange(90, dtype=np.uint64)
    cl.push(keys, rand_rows(90, 6), unpin=False)
    cl.flush_all()
    _corrupt_one_local_file(cl, "flip")
    got = cl.nodes[0].ssd.read_batch(keys)
    assert np.isfinite(got).all()
    assert cl.fault_counters["ssd_files_quarantined"] == 1
    assert cl.fault_counters["ssd_rows_reinit"] > 0
    # the reinit rows equal what a fresh read of never-written keys returns
    fresh = cl.nodes[0].ssd.init_rows(np.array([10**9], dtype=np.uint64))
    assert np.isfinite(fresh).all()


def test_injected_drop_skips_snapshot_retained_files(tmp_path):
    """The injector models replicated snapshot storage: a scheduled drop
    never lands on a retained file (it would destroy the heal base that
    real deployments keep on durable remote storage)."""
    cl = make_cluster(tmp_path, n=1)
    cl.enable_redo()
    keys = np.arange(80, dtype=np.uint64)
    cl.push(keys, rand_rows(80, 7), unpin=False)
    pub = SnapshotPublisher(cl, str(tmp_path / "snap"))
    pub.publish()
    retained = {m.path for m in cl.nodes[0].ssd.files.values()
                if cl.nodes[0].ssd.is_retained(m.path)}
    assert retained, "publish must retain the flushed files"
    inj = FaultInjector([FaultSpec(SSD_DROP, at_op=1)]).arm(cl)
    cl.push(keys[:40], rand_rows(40, 8), unpin=False)
    cl.flush_all()  # a local-only (non-retained) file now exists
    got = cl.nodes[0].ssd.read_batch(keys)  # file reads fire the injector
    assert np.isfinite(got).all()
    dropped = [f["path"] for f in inj.fired if f["kind"] == SSD_DROP]
    assert dropped and all(p not in retained for p in dropped)


# ------------------------------------------------------ elastic reshard


def test_reshard_recovers_dead_node_instead_of_dropping_rows(tmp_path):
    cl = make_cluster(tmp_path, n=3)
    cl.enable_redo()
    keys = np.arange(300, dtype=np.uint64)
    rows = rand_rows(300, 9)
    cl.push(keys, rows, unpin=False)
    cl.kill_node(1)
    new = elastic.reshard(cl, 2, str(tmp_path / "ps2"))
    np.testing.assert_array_equal(new.pull(keys, pin=False), rows)


def test_reshard_with_unrecoverable_dead_node_raises_with_row_count(tmp_path):
    cl = make_cluster(tmp_path, n=3)  # no redo -> unrecoverable
    keys = np.arange(300, dtype=np.uint64)
    cl.push(keys, rand_rows(300, 10), unpin=False)
    cl.flush_all()
    at_risk = cl.nodes[1].ssd.n_live_rows
    cl.kill_node(1)
    with pytest.raises(NodeDownError, match=f">= {at_risk} rows"):
        elastic.reshard(cl, 2, str(tmp_path / "ps2"))


def test_reshard_live_replays_mid_copy_traffic_bit_exact(tmp_path, monkeypatch):
    """Pushes that land *during* the bulk copy (post-pin, post-flush: in
    MEM + redo suffix only) must reach the new cluster via the gated delta
    replay — the new shards end bit-identical to the old cluster."""
    cl = make_cluster(tmp_path, n=2)
    cl.enable_redo()
    keys = np.arange(256, dtype=np.uint64)
    rows = rand_rows(256, 11)
    cl.push(keys, rows, unpin=False)
    mid = rand_rows(64, 12)
    real_copy = elastic._bulk_copy

    def copy_with_traffic(cluster, new, n):
        moved = real_copy(cluster, new, n)
        cluster.push(keys[100:164], mid, unpin=False)  # races the copy
        return moved

    monkeypatch.setattr(elastic, "_bulk_copy", copy_with_traffic)
    new, info = elastic.reshard_live(cl, 3, str(tmp_path / "ps3"))
    assert info["delta_rows"] > 0 and info["gap_s"] >= 0.0
    want = rows.copy()
    want[100:164] = mid
    np.testing.assert_array_equal(new.pull(keys, pin=False), want)
    np.testing.assert_array_equal(cl.pull(keys, pin=False), want)


def test_paused_writes_block_then_resume(tmp_path):
    cl = make_cluster(tmp_path)
    keys = np.arange(10, dtype=np.uint64)
    cl.pause_writes()
    import threading

    done = threading.Event()

    def writer():
        cl.push(keys, rand_rows(10, 13), unpin=False)
        done.set()

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    assert not done.wait(0.1), "push must block while the gate is closed"
    cl.resume_writes()
    assert done.wait(5.0), "push must complete once writes resume"
    t.join()


# ------------------------------------------------------ serving failover


def _published(tmp_path):
    cluster = Cluster(2, str(tmp_path / "train"), dim=DIM,
                      cache_capacity=1024, file_capacity=64)
    PSClient(cluster, [TableSpec("emb", RowSchema.embedding(DIM))])
    keys = np.arange(200, dtype=np.uint64)
    rows = rand_rows(200, 14)
    cluster.push(keys, rows, unpin=False)
    pub = SnapshotPublisher(cluster, str(tmp_path / "snap"))
    v = pub.publish()
    return cluster, pub, keys, rows, v


def test_serving_fails_over_to_surviving_replica(tmp_path):
    cluster, pub, keys, rows, v = _published(tmp_path)
    primary = ServingCluster(pub.dir, version=v)
    replica = ServingCluster(pub.dir, version=v)
    eng = ServingEngine(primary, cache_rows=256, fallbacks=[replica])
    q = keys[:50]
    want = eng.lookup("emb", q)
    np.testing.assert_array_equal(want, rows[:50])
    primary.kill()
    got = eng.lookup("emb", keys[50:120])  # cold keys -> failover path
    np.testing.assert_array_equal(got, rows[50:120])
    assert eng.counters["failovers"] >= 1
    assert eng.counters["failover_rows"] >= 70
    # hot rows cached before the kill still serve (cache, no source touch)
    np.testing.assert_array_equal(eng.lookup("emb", q), rows[:50])


def test_failover_rows_never_poison_the_cache(tmp_path):
    """Rows served by a fallback replica must not be cached under the
    primary's version key: after the primary revives, a hot hit must be
    bit-identical to a cold primary pull."""
    cluster, pub, keys, rows, v = _published(tmp_path)
    primary = ServingCluster(pub.dir, version=v)
    replica = ServingCluster(pub.dir, version=v)
    eng = ServingEngine(primary, cache_rows=256, fallbacks=[replica])
    primary.kill()
    q = keys[:60]
    np.testing.assert_array_equal(eng.lookup("emb", q), rows[:60])
    hits_before = eng.counters["hot_hits"]
    primary.roll_forward(v)  # replacement replica on the same version
    assert primary.alive
    np.testing.assert_array_equal(eng.lookup("emb", q), rows[:60])
    # the failover rows were NOT hot hits — they were re-pulled cold
    assert eng.counters["hot_hits"] == hits_before


def test_all_replicas_down_raises(tmp_path):
    cluster, pub, keys, rows, v = _published(tmp_path)
    primary = ServingCluster(pub.dir, version=v)
    replica = ServingCluster(pub.dir, version=v)
    eng = ServingEngine(primary, cache_rows=0, fallbacks=[replica])
    primary.kill()
    replica.kill()
    with pytest.raises(NodeDownError):
        eng.lookup("emb", keys[:10])
    assert eng.counters["failed_lookups"] == 1


def test_failover_across_version_roll(tmp_path):
    """roll_forward moves primary AND fallbacks; a kill right after the
    roll still fails over, on the new version's rows."""
    cluster, pub, keys, rows, v1 = _published(tmp_path)
    cluster.push(keys, rows * 2.0, unpin=False)
    v2 = pub.publish()
    primary = ServingCluster(pub.dir, version=v1)
    replica = ServingCluster(pub.dir, version=v1)
    eng = ServingEngine(primary, cache_rows=128, fallbacks=[replica])
    assert eng.roll_forward(v2) == v2
    assert replica.version == v2
    primary.kill()
    np.testing.assert_array_equal(eng.lookup("emb", keys[:30]), rows[:30] * 2.0)


# --------------------------------------------- trainer ride-through


def _chaos_cluster(tmp_path, tag):
    return Cluster(2, str(tmp_path / tag), dim=TINY.emb_dim * 2,
                   cache_capacity=2048, file_capacity=128,
                   init_cols=TINY.emb_dim)


def _stream():
    return SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example,
                              TINY.n_slots, TINY.batch_size, seed=5)


def test_trainer_rides_through_node_kill_bitwise(tmp_path):
    """Tentpole acceptance: kill an owner mid-pipeline; the trainer drains
    in-flight batches, recovers the node (restart + redo replay), replays
    the untrained suffix, resumes pipelining — and the final losses AND
    flushed parameters are bitwise-equal to a fault-free run."""
    clean_cl = _chaos_cluster(tmp_path, "clean")
    clean = CTRTrainer(TINY, clean_cl, TrainerConfig())
    want = [r["loss"] for r in clean.run(_stream(), 10)]
    clean_cl.flush_all()
    want_rows = clean_cl.pull(
        np.arange(TINY.n_sparse_keys, dtype=np.uint64), pin=False)

    chaos_cl = _chaos_cluster(tmp_path, "chaos")
    tr = CTRTrainer(TINY, chaos_cl, TrainerConfig(ride_through=True))
    inj = FaultInjector([FaultSpec(NODE_KILL, at_op=40, node_id=1)]).arm(chaos_cl)
    got = [r["loss"] for r in tr.run(_stream(), 10)]
    inj.disarm()
    assert inj.all_fired(), "the kill must actually have happened"
    assert tr.recovery_time_s > 0.0
    assert chaos_cl.fault_counters["node_recoveries"] >= 1
    np.testing.assert_array_equal(got, want)
    chaos_cl.flush_all()
    got_rows = chaos_cl.pull(
        np.arange(TINY.n_sparse_keys, dtype=np.uint64), pin=False)
    np.testing.assert_array_equal(got_rows, want_rows)
    assert chaos_cl.total_pins() == 0 and tr.ps.n_inflight() == 0


def test_trainer_without_ride_through_still_raises(tmp_path):
    cl = _chaos_cluster(tmp_path, "hard")
    tr = CTRTrainer(TINY, cl, TrainerConfig())  # ride_through off
    FaultInjector([FaultSpec(NODE_KILL, at_op=40, node_id=0)]).arm(cl)
    with pytest.raises(Exception):
        tr.run(_stream(), 10)
    assert cl.total_pins() == 0, "failure path must still release pins"


def test_trainer_survives_two_kills(tmp_path):
    cl = _chaos_cluster(tmp_path, "twice")
    tr = CTRTrainer(TINY, cl, TrainerConfig(ride_through=True))
    inj = FaultInjector([
        FaultSpec(NODE_KILL, at_op=30, node_id=0),
        FaultSpec(NODE_KILL, at_op=70, node_id=1),
    ]).arm(cl)
    res = tr.run(_stream(), 12)
    inj.disarm()
    assert inj.all_fired()
    assert len(res) == 12 and all(np.isfinite(r["loss"]) for r in res)
    assert cl.fault_counters["node_recoveries"] >= 2
