"""Gradient compression: quantization error bounds, error feedback, and the
int8 wire format on the cluster's remote serving reads."""

import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # not installed: deterministic fixed-seed fallback
    from repro.testing.hypothesis_fallback import given, settings, st

from repro.core.compression import (
    ErrorFeedbackCompressor,
    dequantize_int8,
    quantize_int8,
    sparse_decode,
    sparse_encode,
)


@given(
    st.integers(1, 32),
    st.integers(1, 64),
    st.floats(0.01, 100.0),
)
@settings(max_examples=30, deadline=None)
def test_quantization_error_bound(n, d, scale):
    rng = np.random.default_rng(42)
    x = (rng.standard_normal((n, d)) * scale).astype(np.float32)
    q, s = quantize_int8(x)
    err = np.abs(dequantize_int8(q, s) - x)
    per_row_bound = np.abs(x).max(axis=1, keepdims=True) / 127.0
    assert (err <= per_row_bound * 0.5 + 1e-6).all()


def test_sparse_packet_roundtrip_and_size():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**40, 100).astype(np.uint64)
    vals = rng.standard_normal((100, 16)).astype(np.float32)
    pkt = sparse_encode(keys, vals, quantize=True)
    k2, v2 = sparse_decode(pkt)
    np.testing.assert_array_equal(k2, keys)
    assert np.abs(v2 - vals).max() < np.abs(vals).max() / 100
    raw = keys.nbytes + vals.nbytes
    assert pkt.nbytes < raw * 0.5  # ~3.2x compression incl. keys


def _quantize_clusters(tmp_path, dim=16, n_keys=400):
    """Two identical clusters, one with the int8 wire format enabled, both
    seeded with the same pushed rows."""
    from repro.core.node import Cluster, NetworkModel

    out = []
    for tag, wq in (("exact", False), ("quant", True)):
        cl = Cluster(2, str(tmp_path / tag), dim=dim, cache_capacity=512,
                     file_capacity=64, network=NetworkModel(wire_quantize=wq))
        keys = np.arange(n_keys, dtype=np.uint64)
        rows = (np.sin(np.arange(n_keys * dim)).reshape(n_keys, dim)).astype(np.float32)
        cl.push(keys, rows, unpin=False)
        out.append((cl, keys, rows))
    return out


def test_wire_quantize_applies_to_remote_serving_reads(tmp_path):
    (exact, keys, rows), (quant, _, _) = _quantize_clusters(tmp_path)
    got_exact = exact.pull(keys, requester=0, pin=False)
    got_quant = quant.pull(keys, requester=0, pin=False)
    np.testing.assert_array_equal(got_exact, rows)
    # remote segments crossed the wire in int8: close but not exact
    assert not np.array_equal(got_quant, rows)
    assert np.abs(got_quant - rows).max() <= np.abs(rows).max() / 127.0 + 1e-6
    # requester-local segments never touch the NIC and stay exact
    local = quant.owner_of(keys) == 0
    np.testing.assert_array_equal(got_quant[local], rows[local])
    assert quant.network.quantized_messages > 0
    assert quant.network.quantize_bytes_saved > 0
    # the Fig-4b accounting sees the smaller on-wire packets
    assert quant.network.bytes_moved < exact.network.bytes_moved


def test_wire_quantize_never_touches_training_pulls(tmp_path):
    (exact, keys, rows), (quant, _, _) = _quantize_clusters(tmp_path)
    got = quant.pull(keys, requester=0, pin=True)  # pinned = training pull
    np.testing.assert_array_equal(got, rows)
    assert quant.network.quantized_messages == 0
    quant.unpin(keys)
    # pushes stay exact too (they carry training state)
    quant.push(keys, rows + 1.0, unpin=False)
    np.testing.assert_array_equal(
        quant.pull(keys, requester=0, pin=True), rows + 1.0
    )
    quant.unpin(keys)


def test_error_feedback_unbiased_over_time():
    """Sum of applied (dequantized) updates converges to the sum of true
    gradients — the residual never grows."""
    rng = np.random.default_rng(1)
    comp = ErrorFeedbackCompressor((8, 32))
    total_true = np.zeros((8, 32), np.float32)
    total_applied = np.zeros((8, 32), np.float32)
    for _ in range(200):
        g = rng.standard_normal((8, 32)).astype(np.float32)
        q, s = comp.compress(g)
        total_true += g
        total_applied += dequantize_int8(q, s)
    # residual bounded => averages match closely
    assert np.abs(total_true - total_applied).max() < 1.0
    assert np.abs(comp.residual).max() < 0.5
