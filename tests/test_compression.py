"""Gradient compression: quantization error bounds + error feedback."""

import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # not installed: deterministic fixed-seed fallback
    from repro.testing.hypothesis_fallback import given, settings, st

from repro.core.compression import (
    ErrorFeedbackCompressor,
    dequantize_int8,
    quantize_int8,
    sparse_decode,
    sparse_encode,
)


@given(
    st.integers(1, 32),
    st.integers(1, 64),
    st.floats(0.01, 100.0),
)
@settings(max_examples=30, deadline=None)
def test_quantization_error_bound(n, d, scale):
    rng = np.random.default_rng(42)
    x = (rng.standard_normal((n, d)) * scale).astype(np.float32)
    q, s = quantize_int8(x)
    err = np.abs(dequantize_int8(q, s) - x)
    per_row_bound = np.abs(x).max(axis=1, keepdims=True) / 127.0
    assert (err <= per_row_bound * 0.5 + 1e-6).all()


def test_sparse_packet_roundtrip_and_size():
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 2**40, 100).astype(np.uint64)
    vals = rng.standard_normal((100, 16)).astype(np.float32)
    pkt = sparse_encode(keys, vals, quantize=True)
    k2, v2 = sparse_decode(pkt)
    np.testing.assert_array_equal(k2, keys)
    assert np.abs(v2 - vals).max() < np.abs(vals).max() / 100
    raw = keys.nbytes + vals.nbytes
    assert pkt.nbytes < raw * 0.5  # ~3.2x compression incl. keys


def test_error_feedback_unbiased_over_time():
    """Sum of applied (dequantized) updates converges to the sum of true
    gradients — the residual never grows."""
    rng = np.random.default_rng(1)
    comp = ErrorFeedbackCompressor((8, 32))
    total_true = np.zeros((8, 32), np.float32)
    total_applied = np.zeros((8, 32), np.float32)
    for _ in range(200):
        g = rng.standard_normal((8, 32)).astype(np.float32)
        q, s = comp.compress(g)
        total_true += g
        total_applied += dequantize_int8(q, s)
    # residual bounded => averages match closely
    assert np.abs(total_true - total_applied).max() < 1.0
    assert np.abs(comp.residual).max() < 0.5
