"""OP+OSRP invariants."""

import numpy as np

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # not installed: deterministic fixed-seed fallback
    from repro.testing.hypothesis_fallback import given, settings, st

from repro.core.hashing import OPOSRP

cols_strategy = st.lists(st.integers(0, 2**40), min_size=1, max_size=100, unique=True).map(
    lambda xs: np.asarray(xs, dtype=np.uint64)
)


@given(cols_strategy, st.sampled_from([16, 64, 256]))
def test_output_range_and_determinism(cols, k):
    h = OPOSRP(k, seed=3)
    out1, out2 = h.transform_row(cols), h.transform_row(cols)
    np.testing.assert_array_equal(out1, out2)
    assert (out1 >= 0).all() and (out1 < 2 * k).all()
    # one output feature per nonzero bin at most
    assert len(np.unique(out1 // 2)) == len(out1)


@given(cols_strategy)
def test_input_order_invariance(cols):
    h = OPOSRP(32, seed=1)
    a = h.transform_row(cols)
    b = h.transform_row(np.random.default_rng(0).permutation(cols))
    np.testing.assert_array_equal(np.sort(a), np.sort(b))


def test_padded_matches_rowwise():
    h = OPOSRP(64, seed=9)
    rng = np.random.default_rng(1)
    cols = rng.integers(0, 2**40, size=(20, 30)).astype(np.uint64)
    valid = rng.random((20, 30)) < 0.8
    oc, ov = h.transform_padded(cols, valid)
    for i in range(20):
        row = h.transform_row(cols[i][valid[i]]) if valid[i].any() else np.zeros(0, np.int64)
        assert set(oc[i][ov[i]].tolist()) == set(row.tolist())


def test_collision_compression():
    # hashing into few bins must produce <= 2k distinct features
    h = OPOSRP(8, seed=0)
    cols = np.arange(10_000, dtype=np.uint64)
    out = h.transform_row(cols)
    assert len(out) <= 16
