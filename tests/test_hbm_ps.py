"""HBM-PS device working table: single-device ops + sharded exchange."""

import os

import numpy as np
import pytest

# this module needs >1 device: spawn with 8 host platform devices
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core.hbm_ps import (
    DeviceWorkingSet,
    ShardedWorkingTable,
    WorkingTable,
    from_sharded_rows,
    plan_a2a,
    to_sharded_rows,
)


def test_single_device_ops():
    table = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    slots = jnp.array([1, 3, 3, 7], jnp.int32)
    got = WorkingTable.get(table, slots)
    np.testing.assert_array_equal(got, np.asarray(table)[np.asarray(slots)])
    t2 = WorkingTable.accumulate(table, slots, jnp.ones((4, 8)))
    exp = np.asarray(table).copy()
    np.add.at(exp, np.asarray(slots), 1.0)
    np.testing.assert_allclose(t2, exp)
    t3 = WorkingTable.insert(table, jnp.array([0], jnp.int32), jnp.full((1, 8), 9.0))
    assert (np.asarray(t3)[0] == 9.0).all()


def test_host_shard_layout_roundtrip():
    vals = np.random.default_rng(0).random((37, 8)).astype(np.float32)
    sharded = to_sharded_rows(vals, 4)
    np.testing.assert_array_equal(from_sharded_rows(sharded, 37, 4), vals)


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")
def test_sharded_get_and_accumulate():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    swt = ShardedWorkingTable(mesh, "model")
    n, d = 53, 16
    vals = np.random.default_rng(1).random((n, d)).astype(np.float32)
    table = jax.device_put(jnp.asarray(to_sharded_rows(vals, 4)), swt.sharding())
    slots = jnp.asarray(np.random.default_rng(2).integers(0, n, 24), jnp.int32)
    got = swt.get_psum(table, slots)
    np.testing.assert_allclose(got, vals[np.asarray(slots)], rtol=1e-6)
    grads = jnp.asarray(np.random.default_rng(3).random((24, d)), jnp.float32)
    t2 = swt.accumulate(table, slots, grads)
    back = from_sharded_rows(np.asarray(t2), n, 4)
    exp = vals.copy()
    np.add.at(exp, np.asarray(slots), np.asarray(grads))
    np.testing.assert_allclose(back, exp, rtol=1e-5, atol=1e-6)


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")
def test_sharded_get_a2a_matches_psum():
    """The two-all_to_all p2p exchange returns the same rows as the psum
    exchange (bitwise — both are pure data movement)."""
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    swt = ShardedWorkingTable(mesh, "model")
    n, d, S = 53, 16, 4
    vals = np.random.default_rng(1).random((n, d)).astype(np.float32)
    table = jax.device_put(jnp.asarray(to_sharded_rows(vals, S)), swt.sharding())
    slots = np.random.default_rng(4).integers(0, n, 24)
    req, restore = plan_a2a(slots, S)
    got = swt.get_a2a(table, jnp.asarray(req), jnp.asarray(restore))
    np.testing.assert_array_equal(np.asarray(got), vals[slots])
    psum = swt.get_psum(table, jnp.asarray(slots.astype(np.int32)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(psum))


def test_plan_a2a_pads_per_owner_lists_equally():
    S = 4
    slots = np.array([0, 4, 8, 12, 1, 2, 3, 7], dtype=np.int64)  # skewed owners
    req, restore = plan_a2a(slots, S)
    assert req.shape == (S, S, 2)  # requester 0 asks owner 0 for two slots
    np.testing.assert_array_equal(req[0, 0], [0, 4])
    np.testing.assert_array_equal(req[1, 0], [8, 12])
    # unused (requester, owner) lists are pure padding: the owner's own slot
    # id, which resolves to its local row 0 — always a valid gather
    np.testing.assert_array_equal(req[0, 1], [1, 1])
    assert (req % S == np.arange(S)[None, :, None]).all()  # owner-routed
    assert (restore < S * 2).all()
    # restore maps each batch position to its row in the received block
    flat_rows = req.reshape(S, -1)  # pretend each owner returned its slots
    for r in range(S):
        np.testing.assert_array_equal(flat_rows[r][restore[r]], slots.reshape(S, 2)[r])


def test_device_working_set_reuse_plan_and_assemble():
    dws = DeviceWorkingSet(row_bytes=8)
    k1 = np.array([3, 5, 9], dtype=np.uint64)
    p1 = dws.plan(k1)
    assert p1.n_reused == 0 and list(p1.fresh_dst) == [0, 1, 2]
    t1 = jnp.asarray(np.array([[3.0], [5.0], [9.0]], np.float32))
    assert DeviceWorkingSet.assemble(None, t1, p1) is t1  # identity transfer

    # next batch shares keys 5 and 9; only key 7's row crosses the link
    k2 = np.array([5, 7, 9], dtype=np.uint64)
    p2 = dws.plan(k2)
    assert p2.n_reused == 2
    np.testing.assert_array_equal(p2.reuse_src, [1, 2])  # rows of 5, 9 in t1
    np.testing.assert_array_equal(p2.reuse_dst, [0, 2])
    np.testing.assert_array_equal(p2.fresh_dst, [1])
    fresh = jnp.asarray(np.array([[7.0]], np.float32))
    t2 = DeviceWorkingSet.assemble(t1, fresh, p2)
    np.testing.assert_array_equal(np.asarray(t2), [[5.0], [7.0], [9.0]])
    assert dws.stats.rows_reused == 2 and dws.stats.bytes_saved == 16

    # reset invalidates residency (resume / aborted pipeline)
    dws.reset()
    p3 = dws.plan(k2)
    assert p3.n_reused == 0
