"""HBM-PS device working table: single-device ops + sharded exchange."""

import os

import numpy as np
import pytest

# this module needs >1 device: spawn with 8 host platform devices
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core.hbm_ps import (
    ShardedWorkingTable,
    WorkingTable,
    from_sharded_rows,
    to_sharded_rows,
)


def test_single_device_ops():
    table = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    slots = jnp.array([1, 3, 3, 7], jnp.int32)
    got = WorkingTable.get(table, slots)
    np.testing.assert_array_equal(got, np.asarray(table)[np.asarray(slots)])
    t2 = WorkingTable.accumulate(table, slots, jnp.ones((4, 8)))
    exp = np.asarray(table).copy()
    np.add.at(exp, np.asarray(slots), 1.0)
    np.testing.assert_allclose(t2, exp)
    t3 = WorkingTable.insert(table, jnp.array([0], jnp.int32), jnp.full((1, 8), 9.0))
    assert (np.asarray(t3)[0] == 9.0).all()


def test_host_shard_layout_roundtrip():
    vals = np.random.default_rng(0).random((37, 8)).astype(np.float32)
    sharded = to_sharded_rows(vals, 4)
    np.testing.assert_array_equal(from_sharded_rows(sharded, 37, 4), vals)


@pytest.mark.skipif(len(jax.devices()) < 8, reason="needs 8 devices")
def test_sharded_get_and_accumulate():
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    swt = ShardedWorkingTable(mesh, "model")
    n, d = 53, 16
    vals = np.random.default_rng(1).random((n, d)).astype(np.float32)
    table = jax.device_put(jnp.asarray(to_sharded_rows(vals, 4)), swt.sharding())
    slots = jnp.asarray(np.random.default_rng(2).integers(0, n, 24), jnp.int32)
    got = swt.get_psum(table, slots)
    np.testing.assert_allclose(got, vals[np.asarray(slots)], rtol=1e-6)
    grads = jnp.asarray(np.random.default_rng(3).random((24, d)), jnp.float32)
    t2 = swt.accumulate(table, slots, grads)
    back = from_sharded_rows(np.asarray(t2), n, 4)
    exp = vals.copy()
    np.add.at(exp, np.asarray(slots), np.asarray(grads))
    np.testing.assert_allclose(back, exp, rtol=1e-5, atol=1e-6)
