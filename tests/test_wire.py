"""The training wire (DESIGN.md §13): quantized gradient push with error
feedback, conflict-class delta/dedup encoding, and bytes-on-wire metering.

Contract under test:

* exact mode (default WireConfig) — nothing changes, bitwise;
* lossy mode — serial and pipelined runs stay bitwise-equal to each other
  (device reuse off), the final loss tracks the exact run within a pinned
  tolerance, and the error-feedback residual survives checkpoint/restore;
* dedup mode — bitwise lossless, strictly fewer bytes on the wire;
* metering — the NIC charges encoded bytes (pushes and quantized serving
  replies), and NIC_STALL faults fire on the bytes actually moved.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # not installed: deterministic fixed-seed fallback
    from repro.testing.hypothesis_fallback import given, settings, st

from repro.configs.ctr_models import TINY
from repro.core.compression import (
    CLAMP_MAG,
    KeyedRowStore,
    PUSH_HEADER_BYTES,
    WireConfig,
    decode_push,
    encode_push,
    quantize_int8,
    quantize_rows_f16,
    dequantize_rows_f16,
    raw_push_row_bytes,
)
from repro.core.faults import NIC_STALL, NODE_KILL, FaultInjector, FaultSpec
from repro.core.node import Cluster, NetworkModel
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.train.trainer import CTRTrainer, TrainerConfig

# bounded-loss-delta harness tolerance: final-loss delta between the lossy
# and exact 20-batch TINY runs (observed ~3e-4; pinned with 30x headroom)
LOSS_DELTA_TOL = 1e-2


# ------------------------------------------------------- wire format units


@given(st.integers(1, 48), st.integers(1, 24), st.integers(0, 8), st.floats(1e-4, 1e3))
@settings(max_examples=25, deadline=None)
def test_push_roundtrip_decode_equals_applied(n, emb, opt, scale):
    """decode_push(packet, base) must reconstruct bitwise the rows the
    sender reports as applied — the wire cannot diverge from the cluster."""
    rng = np.random.default_rng(7)
    width = emb + opt
    base = (rng.standard_normal((n, width)) * scale).astype(np.float32)
    new = base + (rng.standard_normal((n, width)) * scale * 0.01).astype(np.float32)
    res = np.zeros((n, width), np.float32)
    pkt, applied, new_res, n_bad = encode_push(new, base, res, emb)
    assert n_bad == 0
    np.testing.assert_array_equal(decode_push(pkt, base), applied)
    # error feedback closes the loop: residual == what the wire dropped
    np.testing.assert_allclose(applied + new_res, new + res, rtol=0, atol=1e-5 * scale)
    # the packet really is smaller than the raw key+f32 wire
    assert pkt.nbytes < n * raw_push_row_bytes(width) or n * width < 8


def test_push_zero_rows():
    z = np.zeros((0, 4), np.float32)
    pkt, applied, res, n_bad = encode_push(z, z, z, 2)
    assert pkt.n_rows == 0 and applied.shape == (0, 4) and n_bad == 0
    assert pkt.nbytes == PUSH_HEADER_BYTES
    np.testing.assert_array_equal(decode_push(pkt, z), applied)


def test_push_single_element_rows():
    new = np.array([[3.0], [-1.5], [0.0]], np.float32)
    base = np.zeros((3, 1), np.float32)
    pkt, applied, res, _ = encode_push(new, base, np.zeros_like(base), 1)
    np.testing.assert_allclose(applied, new, atol=np.abs(new).max() / 127 + 1e-7)
    np.testing.assert_array_equal(decode_push(pkt, base), applied)
    assert applied[2, 0] == 0.0  # zero row stays exactly zero


def test_push_non_contiguous_inputs():
    rng = np.random.default_rng(3)
    big = rng.standard_normal((32, 17)).astype(np.float32)
    new, base = big[::2, 1:9], big[1::2, 1:9]  # strided views
    assert not new.flags["C_CONTIGUOUS"]
    res = np.zeros((16, 8), np.float32)
    pkt, applied, _, _ = encode_push(new, base, res, 4)
    np.testing.assert_array_equal(decode_push(pkt, np.ascontiguousarray(base)), applied)


def test_push_bf16_inputs_widen():
    jnp = pytest.importorskip("jax.numpy")
    rng = np.random.default_rng(5)
    new32 = rng.standard_normal((8, 6)).astype(np.float32)
    new_bf = np.asarray(jnp.asarray(new32, dtype=jnp.bfloat16))
    base = np.zeros((8, 6), np.float32)
    pkt, applied, _, _ = encode_push(new_bf, base, base.copy(), 3)
    assert applied.dtype == np.float32
    # bf16 keeps ~3 decimal digits; the int8 wire adds <1% on top
    np.testing.assert_allclose(applied, new32, atol=np.abs(new32).max() * 0.02)


def test_push_absolute_rows_when_no_base():
    rng = np.random.default_rng(11)
    new = rng.standard_normal((6, 4)).astype(np.float32)
    stale = rng.standard_normal((6, 4)).astype(np.float32)
    has_base = np.array([True, False, True, False, False, True])
    pkt, applied, _, _ = encode_push(
        new, stale, np.zeros_like(new), 2, has_base=has_base
    )
    np.testing.assert_array_equal(pkt.is_delta, has_base)
    # absolute rows ignore the (stale) base entirely
    np.testing.assert_allclose(applied[~has_base], new[~has_base], atol=0.05)
    np.testing.assert_array_equal(decode_push(pkt, stale), applied)


def test_f16_scale_underflow_and_overflow():
    tiny = np.full((2, 4), 1e-9, np.float32)  # absmax/127 underflows f16
    q, s = quantize_rows_f16(tiny)
    assert (s > 0).all() and np.isfinite(s.astype(np.float32)).all()
    huge = np.full((2, 4), 3e38, np.float32)  # absmax/127 overflows f16
    q2, s2 = quantize_rows_f16(huge)
    assert np.isfinite(s2.astype(np.float32)).all()
    assert np.abs(dequantize_rows_f16(q2, s2)).max() <= 127.0 * 65504.0


# --------------------------------------------------------- non-finite guard


def test_quantize_int8_raises_on_nonfinite():
    x = np.ones((4, 3), np.float32)
    x[2, 1] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        quantize_int8(x)
    x[2, 1] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        quantize_int8(x)


def test_quantize_int8_clamp_mode_stays_finite():
    x = np.ones((4, 3), np.float32)
    x[0, 0], x[1, 1], x[2, 2] = np.nan, np.inf, -np.inf
    q, s = quantize_int8(x, nonfinite="clamp")
    assert np.isfinite(s).all()
    out = q.astype(np.float32) * s
    assert np.isfinite(out).all()
    assert out[0, 0] == 0.0  # nan -> 0
    assert out[1, 1] == pytest.approx(CLAMP_MAG)
    assert out[2, 2] == pytest.approx(-CLAMP_MAG)
    # untouched finite rows are unaffected
    np.testing.assert_allclose(out[3], x[3], atol=1e-2)


def test_encode_push_counts_nonfinite_rows():
    new = np.ones((5, 4), np.float32)
    new[1, 2] = np.inf
    new[4, 0] = np.nan
    base = np.zeros_like(new)
    with pytest.raises(ValueError):
        encode_push(new, base, np.zeros_like(new), 2)
    pkt, applied, _, n_bad = encode_push(
        new, base, np.zeros_like(new), 2, nonfinite="clamp"
    )
    assert n_bad == 2
    assert np.isfinite(applied).all()


# ------------------------------------------------------------ KeyedRowStore


@given(st.integers(1, 200), st.integers(2, 16))
@settings(max_examples=15, deadline=None)
def test_keyed_row_store_roundtrip(n, width):
    rng = np.random.default_rng(n * width)
    keys = np.unique(rng.integers(1, 2**60, n).astype(np.uint64))
    rows = rng.standard_normal((len(keys), width)).astype(np.float32)
    store = KeyedRowStore(width, expected=4)  # force arena growth
    store.put(keys, rows, seq=0)
    got, found = store.get(keys)
    assert found.all()
    np.testing.assert_array_equal(got, rows)
    # state/load round trip
    clone = KeyedRowStore(width)
    clone.load(store.state())
    got2, found2 = clone.get(keys)
    assert found2.all()
    np.testing.assert_array_equal(got2, rows)


def test_keyed_row_store_window_eviction():
    store = KeyedRowStore(2, window=2)
    for seq in range(6):
        store.put(np.array([seq + 1], np.uint64), np.full((1, 2), seq, np.float32), seq=seq)
    # after seq 5 with window 2, only stamps 4 and 5 survive
    alive = store.contains(np.arange(1, 7).astype(np.uint64))
    assert alive.tolist() == [False, False, False, False, True, True]
    # upsert re-stamps an existing key, rescuing it from eviction
    store.put(np.array([5], np.uint64), np.zeros((1, 2), np.float32), seq=7)
    assert store.contains(np.array([5], np.uint64)).all()
    assert not store.contains(np.array([6], np.uint64)).any()


# ----------------------------------------------------- NIC metering (wire)


def test_quantized_serving_reply_meters_payload_only():
    """A quantized reply must not re-charge the keys the request already
    moved: encoded reply bytes = int8 payload + f32 scales, keys excluded."""
    net = NetworkModel(wire_quantize=True)
    keys = np.arange(100, dtype=np.uint64)
    vals = np.random.default_rng(0).standard_normal((100, 16)).astype(np.float32)
    net.reply(keys, vals, serving=True)
    expected = 100 * 16 + 100 * 4  # int8 payload + f32 scale, NO key bytes
    assert net.bytes_moved == expected
    assert net.quantize_bytes_saved == vals.nbytes - expected


def test_cluster_push_with_packet_meters_encoded_bytes(tmp_path):
    dim = 16
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 2**40, 256).astype(np.uint64)
    rows = rng.standard_normal((256, dim)).astype(np.float32)

    def push_bytes(packet):
        cl = Cluster(4, str(tmp_path / f"m{packet is not None}"), dim=dim,
                     cache_capacity=1024, file_capacity=128)
        cl.pull(keys, pin=True)
        cl.network.bytes_moved = 0
        cl.push(keys, rows, unpin=True, packet=packet)
        return cl.network.bytes_moved, cl.network

    raw_bytes, _ = push_bytes(None)
    pkt, applied, _, _ = encode_push(
        rows, np.zeros_like(rows), np.zeros_like(rows), 8
    )
    enc_bytes, net = push_bytes(pkt)
    assert enc_bytes < raw_bytes / 3, (enc_bytes, raw_bytes)
    assert net.push_enc_messages == 3  # one per remote owner segment
    assert net.push_bytes_saved == raw_bytes - enc_bytes
    # fresh() must zero the new counters too
    assert net.fresh().push_enc_messages == 0 and net.fresh().push_bytes_saved == 0


def test_nic_stall_fires_on_encoded_push(tmp_path):
    cl = Cluster(2, str(tmp_path / "stall"), dim=8, cache_capacity=512,
                 file_capacity=64)
    keys = np.arange(64, dtype=np.uint64)
    rows = np.ones((64, 8), np.float32)
    cl.pull(keys, pin=True)
    # armed after the pull: the stall's transfer counter only sees the push,
    # so the fault fires on the *encoded* packet transfer
    inj = FaultInjector([FaultSpec(NIC_STALL, at_op=1, stall_s=0.5)]).arm(cl)
    pkt, _, _, _ = encode_push(rows, np.zeros_like(rows), np.zeros_like(rows), 4)
    before = cl.network.virtual_time
    cl.push(keys, rows, unpin=True, packet=pkt)
    inj.disarm()
    assert inj.all_fired()
    assert cl.network.stalls == 1
    assert cl.network.stall_time == pytest.approx(0.5)
    # the stall's extra latency landed in virtual time on encoded transfers
    assert cl.network.virtual_time > before


# ------------------------------------------------- trainer-level contracts


def _cluster(tmp_path, tag):
    return Cluster(2, str(tmp_path / tag), dim=TINY.emb_dim * 2,
                   cache_capacity=2048, file_capacity=128, init_cols=TINY.emb_dim)


def _stream():
    return SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example,
                              TINY.n_slots, TINY.batch_size, seed=5)


def _run(tmp_path, tag, tcfg, n=8, pipelined=True):
    cl = _cluster(tmp_path, tag)
    tr = CTRTrainer(TINY, cl, tcfg)
    losses = [r["loss"] for r in tr.run(_stream(), n, pipelined=pipelined)]
    cl.flush_all()
    rows = cl.pull(np.arange(TINY.n_sparse_keys, dtype=np.uint64), pin=False)
    return {"losses": losses, "rows": rows, "trainer": tr, "cluster": cl}


def test_lossy_serial_equals_lossy_pipelined(tmp_path):
    """Quantization happens at deposit time, so version forwarding and the
    deferred push both carry the dequantized rows — the lossy pipeline is
    bitwise-equal to the lossy serial run (device reuse off: the device
    copy intentionally keeps pre-quantization rows)."""
    q = lambda: TrainerConfig(wire_quantize_train=True, device_reuse=False)
    serial = _run(tmp_path, "ls", q(), pipelined=False)
    pipe = _run(tmp_path, "lp", q(), pipelined=True)
    np.testing.assert_array_equal(serial["losses"], pipe["losses"])
    np.testing.assert_array_equal(serial["rows"], pipe["rows"])
    assert pipe["cluster"].total_pins() == 0


def test_bounded_loss_delta_and_push_ratio(tmp_path):
    """The lossy acceptance harness: final loss within the pinned tolerance
    of the exact run, >=3x training push bytes-on-wire reduction, NIC push
    savings recorded, and per-conflict-class pull counters populated."""
    exact = _run(tmp_path, "ex", TrainerConfig(), n=20)
    lossy = _run(tmp_path, "lq", TrainerConfig(wire_quantize_train=True), n=20)
    delta = abs(exact["losses"][-1] - lossy["losses"][-1])
    assert delta < LOSS_DELTA_TOL, delta
    wc = lossy["trainer"].client.wire_counters()
    assert wc["wire_push_rows"] > 0
    ratio = wc["wire_push_raw_bytes"] / wc["wire_push_enc_bytes"]
    assert ratio >= 3.0, ratio
    net = lossy["cluster"].network
    assert net.push_enc_messages > 0 and net.push_bytes_saved > 0
    # the zipf stream exercises every conflict class
    assert wc["wire_pull_fresh_rows"] > 0
    assert wc["wire_pull_device_rows"] > 0
    assert wc["wire_pull_forwarded_rows"] > 0
    # quantized training moved measurably fewer bytes than exact training
    assert net.bytes_moved < exact["cluster"].network.bytes_moved
    # exact mode never touches the push wire counters
    assert exact["trainer"].client.wire_counters()["wire_push_rows"] == 0


def test_dedup_window_is_bitwise_lossless(tmp_path):
    """Repeat-key pulls served from the pushed-row window are bitwise the
    cluster rows, so the whole run stays bitwise-equal to the exact run —
    while moving strictly fewer bytes."""
    base = _run(tmp_path, "db", TrainerConfig(), n=12)
    dd = _run(tmp_path, "dd", TrainerConfig(wire_dedup_window=4), n=12)
    np.testing.assert_array_equal(base["losses"], dd["losses"])
    np.testing.assert_array_equal(base["rows"], dd["rows"])
    st = dd["trainer"].ps.stats
    assert st.rows_dedup_served > 0
    wc = dd["trainer"].client.wire_counters()
    assert wc["wire_pull_dedup_rows"] == st.rows_dedup_served
    assert dd["cluster"].network.bytes_moved < base["cluster"].network.bytes_moved
    assert dd["cluster"].total_pins() == 0


def test_lossy_ride_through_matches_fault_free_lossy_run(tmp_path):
    """The ride-through path (drain + serial replay) must produce the same
    results AND the same bytes-on-wire semantics as the pipelined lossy
    path: a mid-run node kill leaves losses and rows bitwise-equal to the
    fault-free lossy run, with push compression still metered."""
    cfg = lambda **kw: TrainerConfig(
        wire_quantize_train=True, device_reuse=False, **kw
    )
    clean = _run(tmp_path, "rt_clean", cfg(), n=10)
    chaos_cl = _cluster(tmp_path, "rt_chaos")
    tr = CTRTrainer(TINY, chaos_cl, cfg(ride_through=True))
    inj = FaultInjector([FaultSpec(NODE_KILL, at_op=40, node_id=1)]).arm(chaos_cl)
    got = [r["loss"] for r in tr.run(_stream(), 10)]
    inj.disarm()
    assert inj.all_fired()
    assert chaos_cl.fault_counters["node_recoveries"] >= 1
    np.testing.assert_array_equal(got, clean["losses"])
    chaos_cl.flush_all()
    rows = chaos_cl.pull(np.arange(TINY.n_sparse_keys, dtype=np.uint64), pin=False)
    np.testing.assert_array_equal(rows, clean["rows"])
    wc = tr.client.wire_counters()
    assert wc["wire_push_enc_bytes"] > 0
    assert wc["wire_push_raw_bytes"] / wc["wire_push_enc_bytes"] >= 3.0
    assert chaos_cl.total_pins() == 0 and tr.ps.n_inflight() == 0


def test_error_feedback_survives_checkpoint_restore(tmp_path):
    """EF residuals are model state: a resume must carry them forward (the
    'wire_ef' checkpoint subtree), and the resumed trainer keeps training."""
    cl = _cluster(tmp_path, "ck")
    tcfg = TrainerConfig(
        wire_quantize_train=True,
        checkpoint_every=5,
        checkpoint_dir=str(tmp_path / "ckpt"),
    )
    tr = CTRTrainer(TINY, cl, tcfg)
    tr.run(_stream(), 10)
    saved = tr.client.wire_state()
    assert saved and TINY.groups[0].name in saved
    assert len(saved[TINY.groups[0].name]["keys"]) > 0

    cl2 = _cluster(tmp_path, "ck2")
    tcfg2 = TrainerConfig(
        wire_quantize_train=True,
        checkpoint_every=5,
        checkpoint_dir=str(tmp_path / "ckpt"),
    )
    tr2 = CTRTrainer(TINY, cl2, tcfg2)
    step = tr2.resume()
    assert step == 10
    restored = tr2.client.wire_state()
    name = TINY.groups[0].name
    # the restored residual store holds exactly the checkpointed rows
    sk = np.argsort(saved[name]["keys"])
    rk = np.argsort(restored[name]["keys"])
    np.testing.assert_array_equal(saved[name]["keys"][sk], restored[name]["keys"][rk])
    np.testing.assert_array_equal(saved[name]["rows"][sk], restored[name]["rows"][rk])
    # and the resumed trainer still trains
    res = tr2.run(_stream(), 4)
    assert len(res) == 4 and all(np.isfinite(r["loss"]) for r in res)


def test_exact_mode_engine_state_is_inert(tmp_path):
    """Default WireConfig must not allocate wire state or touch the push
    path — the exact-mode contract is 'compiled in, default off'."""
    cl = _cluster(tmp_path, "inert")
    tr = CTRTrainer(TINY, cl, TrainerConfig())
    assert tr.ps._ef is None and tr.ps._pushed is None
    assert not tr.ps.wire.enabled
    assert tr.client.wire_state() == {}
    tr.run(_stream(), 3)
    wc = tr.client.wire_counters()
    assert wc["wire_push_enc_bytes"] == 0 and wc["wire_push_rows"] == 0
    # pull-class accounting still works in exact mode (bench visibility)
    assert wc["wire_pull_fresh_rows"] > 0
