"""Self-tests for pscheck (repro.analysis): each rule must catch its
known-bad fixture and stay quiet on the known-good one, the live tree must
be clean modulo the checked-in baseline, and the SanLock runtime sanitizer
must detect lock cycles and residual pins."""

import textwrap
import threading

import numpy as np
import pytest

from repro.analysis import sanlock
from repro.analysis.check import (
    REPO_ROOT,
    check_paths,
    load_baseline,
    main as check_main,
)
from repro.analysis.rules import run_rules
from repro.core.node import Cluster

REG = frozenset({"lookups", "hot_hits"})


def rules_of(src, path="src/repro/core/fake.py", registry=REG):
    fs = run_rules(textwrap.dedent(src), path, registry=registry)
    return [f.rule for f in fs]


# ------------------------------------------------------------------ PS101
def test_ps101_flags_pin_without_release_path():
    bad = """
    class Engine:
        def grab(self, keys):
            rows = self.cluster.pull(keys, pin=True)
            return rows
    """
    assert "PS101" in rules_of(bad)


def test_ps101_accepts_release_handler_and_redo_cursors():
    good = """
    class Engine:
        def grab(self, keys):
            rows = self.cluster.pull(keys, pin=True)
            try:
                return self.wrap(rows)
            except Exception:
                self.cluster.unpin(keys)
                raise

        def cursor(self):
            return self.redo.pin()  # redo-log cursor, not a row pin
    """
    assert "PS101" not in rules_of(good)


# ----------------------------------------------------------- PS201 / PS202
def test_ps201_flags_order_violation_and_undeclared_lock():
    bad_order = """
    class ServingEngine:
        def bad(self):
            with self._cache_mu:
                with self._mu:
                    pass
    """
    assert "PS201" in rules_of(bad_order)
    undeclared = """
    class Widget:
        def f(self):
            with self._zzz_mu:
                pass
    """
    assert "PS201" in rules_of(undeclared)


def test_ps201_accepts_declared_order():
    good = """
    class ServingEngine:
        def good(self):
            with self._mu:
                with self._cache_mu:
                    pass
    """
    assert "PS201" not in rules_of(good)


def test_ps202_flags_blocking_call_under_strict_lock():
    bad = """
    class ServingEngine:
        def bad(self, keys):
            with self._mu:
                return self.source.pull(keys)
    """
    assert "PS202" in rules_of(bad)


def test_ps202_flags_transitively_blocking_helper():
    bad = """
    class ServingEngine:
        def helper(self, keys):
            return self.source.pull(keys)

        def bad(self, keys):
            with self._mu:
                return self.helper(keys)
    """
    assert "PS202" in rules_of(bad)


def test_ps202_accepts_pull_outside_lock_and_blocking_ok_locks():
    good = """
    class ServingEngine:
        def good(self, keys):
            rows = self.source.pull(keys)
            with self._mu:
                self.cache[0] = rows
            return rows

    class MemParameterServer:
        def fill(self, keys):
            with self._lock:  # blocking_ok: SSD miss-fill is its design
                return self.ssd.read_batch(keys)
    """
    assert "PS202" not in rules_of(good)


# ------------------------------------------------------------------ PS301
def test_ps301_flags_swallowing_excepts():
    for body in (
        "try:\n    f()\nexcept Exception:\n    pass",
        "try:\n    f()\nexcept:\n    x = 1",
        "def g():\n    try:\n        f()\n    except NodeDownError:\n        pass",
    ):
        assert "PS301" in rules_of(body), body


def test_ps301_accepts_loud_handlers():
    good = """
    def a():
        try:
            f()
        except Exception:
            raise

    def b(log):
        try:
            f()
        except Exception as err:
            log.append(err)

    def c(counters):
        try:
            f()
        except Exception:
            counters.inc("lookups")

    def d():
        try:
            f()
        except NodeDownError:
            recover()
    """
    assert "PS301" not in rules_of(good)


# ------------------------------------------------------------------ PS302
def test_ps302_flags_silent_shape_fallback():
    bad = """
    def wrapper(x):
        if x.shape[0] % 8:
            return foo_ref(x)
        return foo_pallas(x)
    """
    assert "PS302" in rules_of(bad)


def test_ps302_accepts_explicit_dispatch_and_loud_fallback():
    good = """
    def dispatch(x, use_pallas):
        if not use_pallas:
            return foo_ref(x)
        return foo_pallas(x)

    def loud(x):
        if x.shape[0] % 8:
            warnings.warn("foo: ragged batch, reference fallback")
            return foo_ref(x)
        return foo_pallas(x)
    """
    assert "PS302" not in rules_of(good)


# ------------------------------------------------------------------ PS401
def test_ps401_flags_unregistered_and_dynamic_counter_names():
    assert "PS401" in rules_of("self.counters.inc('nope')")
    assert "PS401" in rules_of("self.counters.inc(name)")
    assert "PS401" in rules_of("c = Counters('nope')")
    assert "PS401" in rules_of("COUNTER_NAMES = ('nope',)")


def test_ps401_accepts_registry_names():
    src = """
    COUNTER_NAMES = ("lookups", "hot_hits")
    c = Counters("lookups")
    c.inc("hot_hits", 2)
    self.counters.inc("lookups")
    """
    assert "PS401" not in rules_of(src)


# ------------------------------------------------------------------ PS501
def test_ps501_flags_take_and_one_hot_only_under_models():
    src = """
    def fwd(table, ids):
        a = jnp.take(table, ids, axis=0)
        b = jax.nn.one_hot(ids, 100)
        return a, b
    """
    assert rules_of(src, path="src/repro/models/fake.py").count("PS501") == 2
    assert "PS501" not in rules_of(src, path="src/repro/core/fake.py")


# ------------------------------------------------------------------ PS502
def test_ps502_requires_explicit_specs():
    bad = "y = pl.pallas_call(kernel, out_shape=s)(x)"
    assert "PS502" in rules_of(bad)
    good = """
    y = pl.pallas_call(kernel, out_shape=s, grid=(8,),
                       in_specs=[spec], out_specs=spec)(x)
    z = pl.pallas_call(kernel, out_shape=s, grid_spec=gspec)(x)
    """
    assert "PS502" not in rules_of(good)


# ------------------------------------------- suppression + CLI + live tree
def test_pragma_suppresses_and_cli_exit_codes(tmp_path):
    nobase = tmp_path / "empty_baseline.txt"
    bad = tmp_path / "bad.py"
    bad.write_text("try:\n    f()\nexcept Exception:\n    pass\n")
    report = tmp_path / "report.txt"
    rc = check_main([str(bad), "--baseline", str(nobase), "--report", str(report)])
    assert rc == 1
    assert "PS301" in report.read_text()

    ok = tmp_path / "ok.py"
    ok.write_text(
        "try:\n    f()\n"
        "except Exception:  # pscheck: ok PS301 fixture demonstrating pragmas\n"
        "    pass\n"
    )
    assert check_main([str(ok), "--baseline", str(nobase)]) == 0


def test_baseline_suppresses_by_rule_and_qualname(tmp_path):
    bad = tmp_path / "mod.py"
    bad.write_text("def f():\n    try:\n        g()\n    except Exception:\n        pass\n")
    findings, _, _ = check_paths([bad])
    assert [f.rule for f in findings] == ["PS301"]
    baseline = {findings[0].baseline_key()}
    findings2, _, n_base = check_paths([bad], baseline=baseline)
    assert findings2 == [] and n_base == 1


def test_live_tree_clean_modulo_baseline():
    baseline = load_baseline(REPO_ROOT / "pscheck_baseline.txt")
    findings, _, _ = check_paths([REPO_ROOT / "src"], baseline=baseline)
    assert findings == [], "\n".join(f.format() for f in findings)


# ------------------------------------------------------------- SanLock
def _preserving_graph():
    saved = dict(sanlock._edges)
    sanlock.reset_graph()
    return saved


def _restore_graph(saved):
    sanlock.reset_graph()
    sanlock._edges.update(saved)


def test_sanlock_detects_cycle_and_instance_granularity():
    saved = _preserving_graph()
    try:
        a = sanlock._SanLock(threading.Lock(), "a.py:1")
        b = sanlock._SanLock(threading.Lock(), "b.py:1")
        with a:
            with b:
                pass
        assert sanlock.find_cycle() is None
        with b:
            with a:  # reversed order: a->b->a cycle
                pass
        cyc = sanlock.find_cycle()
        assert cyc is not None and "a.py:1" in cyc and "b.py:1" in cyc
        with pytest.raises(AssertionError, match="cycle"):
            sanlock.assert_acyclic()
    finally:
        _restore_graph(saved)

    # instance-level graph: same allocation site, different instances (the
    # SSD heal path: training shard lock -> snapshot-view lock) is NOT a
    # self-cycle
    saved = _preserving_graph()
    try:
        t1 = sanlock._SanLock(threading.Lock(), "ssd_ps.py:155")
        t2 = sanlock._SanLock(threading.Lock(), "ssd_ps.py:155")
        with t1:
            with t2:
                pass
        assert sanlock.find_cycle() is None
    finally:
        _restore_graph(saved)


def test_sanlock_reentrant_rlock_adds_no_edge():
    saved = _preserving_graph()
    try:
        r = sanlock._SanRLock(threading.RLock(), "r.py:1")
        with r:
            with r:
                pass
        assert sanlock.find_cycle() is None and sanlock.edges() == []
    finally:
        _restore_graph(saved)


def test_sanlock_pin_registry_tracks_cluster_pins(tmp_path):
    mark = sanlock.cluster_mark()
    cl = Cluster(1, str(tmp_path / "ps"), dim=8, cache_capacity=64,
                 file_capacity=32, init_cols=4)
    keys = np.arange(4, dtype=np.uint64)
    cl.pull(keys, pin=True)
    leaks = sanlock.pin_leaks(mark)
    assert len(leaks) == 1 and leaks[0][1] == 4
    cl.unpin(keys)
    assert sanlock.pin_leaks(mark) == []


@pytest.mark.pscheck_allow_pins
def test_allow_pins_marker_opts_out_of_teardown_assert(tmp_path):
    # under REPRO_SANLOCK=1 the autouse fixture would fail this test's
    # teardown without the marker — the marker IS the assertion here
    cl = Cluster(1, str(tmp_path / "ps"), dim=8, cache_capacity=64,
                 file_capacity=32, init_cols=4)
    cl.pull(np.arange(3, dtype=np.uint64), pin=True)
    assert cl.total_pins() == 3
