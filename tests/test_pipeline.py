"""4-stage pipeline: overlap, back-pressure, stragglers, failures, shutdown."""

import threading
import time

import pytest

from repro.core.pipeline import (
    DependencyAborted,
    DependencyRegistry,
    Pipeline,
    PipelineError,
    Stage,
)


def sleeper(dur):
    def f(x):
        time.sleep(dur)
        return x

    return f


def test_results_in_order_and_overlap():
    pipe = Pipeline(
        [
            Stage("read", sleeper(0.01)),
            Stage("pull", sleeper(0.02)),
            Stage("xfer", sleeper(0.005)),
            Stage("train", sleeper(0.02)),
        ]
    )
    t0 = time.perf_counter()
    out = list(pipe.run(range(20)))
    elapsed = time.perf_counter() - t0
    assert out == list(range(20))
    serial = 20 * 0.055
    assert elapsed < serial * 0.75, f"no overlap: {elapsed:.2f}s vs {serial:.2f}s"
    assert pipe.bottleneck() in ("pull", "train")


def test_backpressure_bounds_queue():
    in_flight = []
    lock = threading.Lock()

    def slow_sink(x):
        time.sleep(0.05)
        with lock:
            in_flight.append(x)
        return x

    counted = []

    def fast_src(x):
        counted.append(x)
        return x

    pipe = Pipeline([Stage("fast", fast_src, capacity=2), Stage("slow", slow_sink, capacity=2)])
    it = pipe.run(range(50))
    next(it)
    time.sleep(0.12)
    # fast stage must have stalled: far fewer than 50 items pulled through
    assert len(counted) <= 10
    for _ in it:
        pass


def test_straggler_speculative_rescue():
    calls = {"n": 0}
    lock = threading.Lock()

    def sometimes_hangs(x):
        with lock:
            calls["n"] += 1
            me = calls["n"]
        if x == 3 and me <= 4:  # first attempt at job 3 hangs; backup is fast
            time.sleep(0.5)
        return x * 10

    pipe = Pipeline([Stage("work", sometimes_hangs, timeout=0.1)])
    t0 = time.perf_counter()
    out = list(pipe.run(range(6)))
    elapsed = time.perf_counter() - t0
    assert sorted(out) == [0, 10, 20, 30, 40, 50]
    assert elapsed < 0.5, "speculative backup should have rescued the straggler"
    assert pipe.stats[0].speculative_wins >= 1


def test_failure_retry_then_succeed():
    attempts = {"n": 0}

    def flaky(x):
        if x == 2:
            attempts["n"] += 1
            if attempts["n"] <= 2:
                raise RuntimeError("transient")
        return x

    pipe = Pipeline([Stage("flaky", flaky, max_retries=3)])
    assert list(pipe.run(range(4))) == [0, 1, 2, 3]
    assert pipe.stats[0].retries == 2


def test_permanent_failure_surfaces():
    def bad(x):
        raise ValueError("boom")

    pipe = Pipeline([Stage("bad", bad, max_retries=1)])
    with pytest.raises(PipelineError):
        list(pipe.run(range(3)))


def test_non_idempotent_stage_never_speculated():
    """A stage with side effects (e.g. pull/push pinning MEM-PS rows) must
    not be re-executed by straggler speculation: each job runs exactly once
    even when it blows way past the straggler timeout."""
    calls = []
    lock = threading.Lock()

    def slow_side_effect(x):
        with lock:
            calls.append(x)
        time.sleep(0.15)  # every job is a "straggler" vs timeout=0.01
        return x

    pipe = Pipeline([Stage("pins", slow_side_effect, timeout=0.01, idempotent=False)])
    out = list(pipe.run(range(4)))
    assert out == [0, 1, 2, 3]
    assert sorted(calls) == [0, 1, 2, 3], f"re-executed jobs: {calls}"
    assert pipe.stats[0].speculative_wins == 0


def test_abandoned_consumer_releases_workers():
    """Abandoning the run() iterator early must not leave a worker thread
    blocked forever in a full-queue put (it would keep its batch's rows
    pinned): every put/get is stop-aware and queues drain on shutdown."""
    def slow_sink(x):
        time.sleep(0.05)
        return x

    pipe = Pipeline([Stage("fast", lambda x: x, capacity=2),
                     Stage("slow", slow_sink, capacity=2)])
    it = pipe.run(range(1000))
    assert next(it) == 0
    it.close()  # consumer walks away mid-stream
    deadline = time.monotonic() + 5.0
    for t in pipe._threads:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
    assert not any(t.is_alive() for t in pipe._threads), "leaked worker thread"


def test_downstream_error_releases_blocked_upstream():
    """An error in the sink stage stops upstream workers that are blocked
    pushing into full queues (they previously never observed _stop)."""
    def boom(x):
        if x == 3:
            raise ValueError("boom")
        time.sleep(0.01)
        return x

    pipe = Pipeline([Stage("src", lambda x: x, capacity=1),
                     Stage("boom", boom, capacity=1, max_retries=0)])
    with pytest.raises(PipelineError):
        list(pipe.run(range(1000)))
    deadline = time.monotonic() + 5.0
    for t in pipe._threads:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
    assert not any(t.is_alive() for t in pipe._threads), "leaked worker thread"


def test_dependency_registry_signal_wait_abort():
    reg = DependencyRegistry()
    reg.signal(("trained", 1))
    reg.wait(("trained", 1))  # already done: returns immediately
    got = {}

    def waiter():
        try:
            reg.wait(("trained", 2))
            got["ok"] = True
        except DependencyAborted:
            got["aborted"] = True

    t = threading.Thread(target=waiter)
    t.start()
    time.sleep(0.05)
    assert t.is_alive()  # genuinely blocked on the unsignalled token
    reg.signal(("trained", 2))
    t.join(2.0)
    assert got.get("ok")

    t2 = threading.Thread(target=waiter)  # waits on ("trained", 2): done
    t2.start()
    t2.join(2.0)
    assert not t2.is_alive()

    reg2 = DependencyRegistry()
    res = {}

    def waiter2():
        try:
            reg2.wait(("trained", 9))
        except DependencyAborted:
            res["aborted"] = True

    t3 = threading.Thread(target=waiter2)
    t3.start()
    time.sleep(0.02)
    reg2.abort()
    t3.join(2.0)
    assert res.get("aborted")
    reg2.reset()
    with pytest.raises(TimeoutError):
        reg2.wait(("trained", 9), timeout=0.05)


def test_error_aborts_dependency_waiters():
    """A stage crash must wake stages blocked on dependency tokens."""
    deps = DependencyRegistry()
    state = {}

    def stage_a(x):
        if x == 1:
            time.sleep(0.05)  # let stage b start waiting on item 0's token
            raise ValueError("dead producer")
        return x

    def stage_b(x):
        try:  # waits for a token the dead producer will never signal
            deps.wait(("token", x))
        except DependencyAborted:
            state["released"] = True
            raise
        return x

    pipe = Pipeline([Stage("a", stage_a, max_retries=0),
                     Stage("b", stage_b, max_retries=0)], deps=deps)
    with pytest.raises(PipelineError):
        list(pipe.run(range(5)))
    assert state.get("released")
