"""4-stage pipeline: overlap, back-pressure, stragglers, failures."""

import threading
import time

import pytest

from repro.core.pipeline import Pipeline, PipelineError, Stage


def sleeper(dur):
    def f(x):
        time.sleep(dur)
        return x

    return f


def test_results_in_order_and_overlap():
    pipe = Pipeline(
        [
            Stage("read", sleeper(0.01)),
            Stage("pull", sleeper(0.02)),
            Stage("xfer", sleeper(0.005)),
            Stage("train", sleeper(0.02)),
        ]
    )
    t0 = time.perf_counter()
    out = list(pipe.run(range(20)))
    elapsed = time.perf_counter() - t0
    assert out == list(range(20))
    serial = 20 * 0.055
    assert elapsed < serial * 0.75, f"no overlap: {elapsed:.2f}s vs {serial:.2f}s"
    assert pipe.bottleneck() in ("pull", "train")


def test_backpressure_bounds_queue():
    in_flight = []
    lock = threading.Lock()

    def slow_sink(x):
        time.sleep(0.05)
        with lock:
            in_flight.append(x)
        return x

    counted = []

    def fast_src(x):
        counted.append(x)
        return x

    pipe = Pipeline([Stage("fast", fast_src, capacity=2), Stage("slow", slow_sink, capacity=2)])
    it = pipe.run(range(50))
    next(it)
    time.sleep(0.12)
    # fast stage must have stalled: far fewer than 50 items pulled through
    assert len(counted) <= 10
    for _ in it:
        pass


def test_straggler_speculative_rescue():
    calls = {"n": 0}
    lock = threading.Lock()

    def sometimes_hangs(x):
        with lock:
            calls["n"] += 1
            me = calls["n"]
        if x == 3 and me <= 4:  # first attempt at job 3 hangs; backup is fast
            time.sleep(0.5)
        return x * 10

    pipe = Pipeline([Stage("work", sometimes_hangs, timeout=0.1)])
    t0 = time.perf_counter()
    out = list(pipe.run(range(6)))
    elapsed = time.perf_counter() - t0
    assert sorted(out) == [0, 10, 20, 30, 40, 50]
    assert elapsed < 0.5, "speculative backup should have rescued the straggler"
    assert pipe.stats[0].speculative_wins >= 1


def test_failure_retry_then_succeed():
    attempts = {"n": 0}

    def flaky(x):
        if x == 2:
            attempts["n"] += 1
            if attempts["n"] <= 2:
                raise RuntimeError("transient")
        return x

    pipe = Pipeline([Stage("flaky", flaky, max_retries=3)])
    assert list(pipe.run(range(4))) == [0, 1, 2, 3]
    assert pipe.stats[0].retries == 2


def test_permanent_failure_surfaces():
    def bad(x):
        raise ValueError("boom")

    pipe = Pipeline([Stage("bad", bad, max_retries=1)])
    with pytest.raises(PipelineError):
        list(pipe.run(range(3)))
