"""Streaming ingestion subsystem (DESIGN.md §11): device extraction parity,
staging-ring ownership under abort/drain, trainer integration, faults."""

import threading
import time

import numpy as np
import pytest

from repro.configs.ctr_models import TINY
from repro.core.faults import NIC_STALL, NODE_KILL, FaultInjector, FaultSpec
from repro.core.keys import hash_keys, splitmix64
from repro.core.node import Cluster
from repro.core.pipeline import (
    DependencyAborted,
    DependencyRegistry,
    Pipeline,
    Stage,
)
from repro.data.synthetic_ctr import (
    RawRecordBatch,
    SyntheticCTRStream,
    extract_host,
    to_ctr_batch,
)
from repro.ingest import DeviceIngestor, StagingRing
from repro.kernels import ops as kops
from repro.kernels.feature_extract import (
    feature_extract_pallas,
    feature_extract_portable,
    mod_pair,
    mod_pair_wide,
    splitmix64_pair,
)
from repro.metrics import KNOWN_COUNTERS, Counters
from repro.train.trainer import CTRTrainer, TrainerConfig

_EDGE_U64 = np.array(
    [0, 1, 2, 0xFFFFFFFF, 0x100000000, 2**63, 2**64 - 1, 0x9E3779B97F4A7C15],
    dtype=np.uint64,
)


def _rand_u64(rng, n):
    return rng.integers(0, 2**64, size=n, dtype=np.uint64)


def _pairs(x):
    x = np.asarray(x, dtype=np.uint64)
    return (x >> np.uint64(32)).astype(np.uint32), (x & np.uint64(0xFFFFFFFF)).astype(
        np.uint32
    )


# ------------------------------------------------------- u32-pair hash math


def test_splitmix64_pair_matches_numpy():
    rng = np.random.default_rng(0)
    x = np.concatenate([_EDGE_U64, _rand_u64(rng, 512)])
    hi, lo = _pairs(x)
    for seed in (0, 17, 31, 23, 2**64 - 1):
        want = splitmix64(x ^ np.uint64(seed))
        got_hi, got_lo = splitmix64_pair(hi, lo, seed)
        got = (np.asarray(got_hi).astype(np.uint64) << np.uint64(32)) | np.asarray(
            got_lo
        ).astype(np.uint64)
        np.testing.assert_array_equal(got, want)


def test_mod_pair_matches_numpy():
    rng = np.random.default_rng(1)
    x = np.concatenate([_EDGE_U64, _rand_u64(rng, 256)])
    hi, lo = _pairs(x)
    # u32-result range: the narrow loop up to 2^31, the wide-backed tail
    # (2^31, 2^32] that used to be rejected
    for m in (1, 2, 3, 7, 25, 127, 128, 4096, 600_000, 2**31 - 1, 2**31,
              2**31 + 1, 2**32 - 5, 2**32):
        np.testing.assert_array_equal(
            np.asarray(mod_pair(hi, lo, m)).astype(np.uint64),
            x % np.uint64(m),
            err_msg=f"modulus {m}",
        )


def test_mod_pair_wide_matches_numpy():
    """Paper-scale moduli (1e11-key spaces and beyond, up to 2^63): the
    pair-remainder long division is bit-exact against numpy u64."""
    rng = np.random.default_rng(6)
    x = np.concatenate([_EDGE_U64, _rand_u64(rng, 256)])
    hi, lo = _pairs(x)
    for m in (3, 600_000, 2**31, 2**31 + 1, 2**32 - 1, 2**32, 2**32 + 1,
              10**11, 10**11 + 7, 2**48 - 59, 2**62 + 11, 2**63 - 25, 2**63):
        got_hi, got_lo = mod_pair_wide(hi, lo, m)
        got = (np.asarray(got_hi).astype(np.uint64) << np.uint64(32)) | np.asarray(
            got_lo
        ).astype(np.uint64)
        np.testing.assert_array_equal(got, x % np.uint64(m), err_msg=f"modulus {m}")


def test_mod_pair_rejects_out_of_range_modulus():
    hi, lo = _pairs(_EDGE_U64)
    with pytest.raises(ValueError):
        mod_pair(hi, lo, 2**32 + 1)  # result would not fit one u32
    with pytest.raises(ValueError):
        mod_pair_wide(hi, lo, 2**63 + 1)  # carry shift would drop a bit
    with pytest.raises(ValueError):
        mod_pair_wide(hi, lo, 0)


# ------------------------------------------------- device extraction parity


def _assert_extract_parity(raw, lengths, n_keys, n_slots):
    want_k, want_s, want_v = extract_host(raw, lengths, n_keys, n_slots)
    hi, lo = _pairs(raw)
    valid = want_v
    for fn in (
        lambda: feature_extract_portable(lo, hi, valid, n_keys=n_keys, n_slots=n_slots),
        lambda: feature_extract_pallas(
            lo, hi, valid, n_keys=n_keys, n_slots=n_slots, interpret=True
        ),
        lambda: kops.feature_extract(lo, hi, valid, n_keys=n_keys, n_slots=n_slots),
    ):
        got_hi, got_lo, got_s = fn()
        got_k = (np.asarray(got_hi).astype(np.uint64) << np.uint64(32)) | np.asarray(
            got_lo
        ).astype(np.uint64)
        np.testing.assert_array_equal(got_k, want_k)
        np.testing.assert_array_equal(np.asarray(got_s), want_s)


def test_feature_extract_bitwise_parity():
    rng = np.random.default_rng(2)
    raw = _rand_u64(rng, 64 * 16).reshape(64, 16)
    lengths = rng.integers(0, 17, 64).astype(np.int32)
    _assert_extract_parity(raw, lengths, 600_000, 25)


def test_feature_extract_full_rows_and_odd_shapes():
    rng = np.random.default_rng(3)
    # non-multiple-of-(8*128) element counts exercise the kernel's padding
    for B, P in ((1, 1), (3, 5), (7, 129), (64, 16)):
        raw = _rand_u64(rng, B * P).reshape(B, P)
        _assert_extract_parity(raw, None, 1000, 8)


def test_feature_extract_empty_examples():
    rng = np.random.default_rng(4)
    raw = _rand_u64(rng, 8 * 4).reshape(8, 4)
    lengths = np.zeros(8, dtype=np.int32)  # every example empty
    want_k, want_s, want_v = extract_host(raw, lengths, 1000, 8)
    assert not want_v.any() and not want_k.any() and not want_s.any()
    _assert_extract_parity(raw, lengths, 1000, 8)


def test_feature_extract_paper_scale_key_space():
    """n_keys past 2^32 (the paper's 1e11-key regime): keys come back as a
    real u32 pair — the high plane carries live bits — and all three device
    arms stay bitwise-equal to the host feeder."""
    rng = np.random.default_rng(7)
    raw = _rand_u64(rng, 32 * 8).reshape(32, 8)
    lengths = rng.integers(0, 9, 32).astype(np.int32)
    for n_keys in (10**11, 2**36 - 5):
        _assert_extract_parity(raw, lengths, n_keys, 25)
        want_k, _, _ = extract_host(raw, lengths, n_keys, 25)
        assert (want_k >> np.uint64(32)).any(), (
            "test vector too small to exercise the high key plane"
        )


def test_extract_host_golden_values():
    """Pin the extraction contract itself: these values may never change
    without breaking every stored key space."""
    raw = np.array([[0, 1, 2**63, 2**64 - 1, 123456789]], dtype=np.uint64)
    k, s, v = extract_host(raw, None, 600_000, 25)
    assert k.tolist() == [[41379, 321095, 501017, 21531, 431833]]
    assert s.tolist() == [[21, 23, 10, 17, 22]]
    assert v.all()


def test_extract_host_truncates_past_pack_width():
    # nnz > pack width: the reader row is wider than the trainer packs
    rng = np.random.default_rng(5)
    raw = _rand_u64(rng, 4 * 10).reshape(4, 10)
    lengths = np.array([10, 7, 3, 0], dtype=np.int32)
    k, s, v = extract_host(raw, lengths, 1000, 8, pack_width=6)
    assert k.shape == (4, 6)
    np.testing.assert_array_equal(v.sum(axis=1), [6, 6, 3, 0])
    full_k, _, _ = extract_host(raw[:, :6], None, 1000, 8)
    np.testing.assert_array_equal(k[0], full_k[0])  # truncation = slice


# --------------------------------------------------------- raw record stream


def test_next_batch_is_extract_host_composition():
    """The host feeder is exactly: draw raw surrogates, extract_host them.
    (next_batch is the bitwise parity oracle for the device path.)"""
    a = SyntheticCTRStream(1000, 16, 8, 32, seed=9)
    b = SyntheticCTRStream(1000, 16, 8, 32, seed=9)
    raw = b._draw_raw((32, 16))
    want_k, want_s, want_v = extract_host(raw, None, 1000, 8)
    got = a.next_batch()
    np.testing.assert_array_equal(got.keys, want_k)
    np.testing.assert_array_equal(got.slot_of, want_s)
    assert got.keys.dtype == np.uint64 and got.slot_of.dtype == np.int32


def test_raw_records_variable_nnz():
    s = SyntheticCTRStream(1000, 16, 8, 64, seed=1)
    it = s.raw_records(min_nnz=1, max_nnz=24)
    seen = set()
    for bid in range(4):
        r = next(it)
        assert r.raw_ids.shape == (64, 24) and r.raw_ids.dtype == np.uint64
        assert r.lengths.min() >= 1 and r.lengths.max() <= 24
        assert r.labels.dtype == np.float32 and r.batch_id == bid
        seen.update(r.lengths.tolist())
    assert len(seen) > 4, "nnz should actually vary across examples"


def test_ingestor_matches_host_feeder_bitwise():
    cfg = TINY
    s1 = SyntheticCTRStream(cfg.n_sparse_keys, cfg.nnz_per_example, cfg.n_slots, 32, seed=2)
    s2 = SyntheticCTRStream(cfg.n_sparse_keys, cfg.nnz_per_example, cfg.n_slots, 32, seed=2)
    ing = DeviceIngestor(
        n_keys=cfg.n_sparse_keys, n_slots=cfg.n_slots, pack_width=cfg.nnz_per_example
    )
    for raw_wide, raw_same in zip(
        s1.raw_records(max_nnz=cfg.nnz_per_example + 8),
        s2.raw_records(max_nnz=cfg.nnz_per_example + 8),
    ):
        host = to_ctr_batch(raw_same, cfg.n_sparse_keys, cfg.n_slots, cfg.nnz_per_example)
        dev = ing.ingest(raw_wide)
        np.testing.assert_array_equal(dev.keys, host.keys)
        np.testing.assert_array_equal(np.asarray(dev.slot_of), host.slot_of)
        np.testing.assert_array_equal(np.asarray(dev.valid), host.valid)
        np.testing.assert_array_equal(np.asarray(dev.labels), host.labels)
        ing.release(dev)
        if raw_wide.batch_id >= 3:
            break


def test_ingestor_pads_narrow_reader_rows():
    ing = DeviceIngestor(n_keys=1000, n_slots=8, pack_width=6)
    raw = RawRecordBatch(
        raw_ids=np.arange(8, dtype=np.uint64).reshape(2, 4),  # L=4 < P=6
        lengths=np.array([4, 2], dtype=np.int32),
        labels=np.zeros(2, dtype=np.float32),
        batch_id=0,
    )
    got = ing.ingest(raw)
    want_k, want_s, want_v = extract_host(
        np.pad(raw.raw_ids, ((0, 0), (0, 2))), raw.lengths, 1000, 8
    )
    np.testing.assert_array_equal(got.keys, want_k)
    np.testing.assert_array_equal(np.asarray(got.valid), want_v)


# ------------------------------------------------------------- staging ring


def test_staging_ring_blocks_at_depth_and_releases_in_order():
    deps = DependencyRegistry()
    ring = StagingRing(depth=2, deps=deps)
    host = {"x": np.zeros(4, dtype=np.float32)}
    s0 = ring.stage(0, host)
    s1 = ring.stage(1, host)
    assert ring.live_slots == 2

    staged3 = []

    def third():
        staged3.append(ring.stage(2, host))

    t = threading.Thread(target=third, daemon=True)
    t.start()
    time.sleep(0.15)
    assert not staged3, "third stage must block until slot 0 frees"
    ring.release(s0)
    t.join(timeout=5.0)
    assert not t.is_alive() and staged3[0].seq == 2
    ring.release(s1)
    ring.release(staged3[0])
    ring.release(staged3[0])  # idempotent
    assert ring.live_slots == 0
    assert ring.counters["ingest_batches"] == 3


def test_staging_ring_abort_wakes_blocked_stager():
    deps = DependencyRegistry()
    ring = StagingRing(depth=1, deps=deps)
    ring.stage(0, {"x": np.zeros(2, dtype=np.float32)})
    err = []

    def second():
        try:
            ring.stage(1, {"x": np.zeros(2, dtype=np.float32)})
        except DependencyAborted as e:
            err.append(e)

    t = threading.Thread(target=second, daemon=True)
    t.start()
    time.sleep(0.1)
    deps.abort()
    t.join(timeout=5.0)
    assert not t.is_alive() and err, "abort must wake the blocked stage()"


def test_pipeline_on_drain_releases_unconsumed_outputs():
    """A mid-pipeline failure drains queued stage outputs through the
    producer's on_drain hook (and hook errors are collected, not raised)."""
    deps = DependencyRegistry()
    ring = StagingRing(depth=8, deps=deps)
    released = []

    def mk(i):
        return ring.stage(i, {"x": np.zeros(2, dtype=np.float32)})

    def boom(item):
        raise RuntimeError("consumer died")

    pipe = Pipeline(
        [
            Stage("stage", lambda i: mk(i), capacity=4,
                  on_drain=lambda s: (released.append(s.seq), ring.drain_release(s))),
            Stage("boom", boom, capacity=4, max_retries=0),
        ],
        deps=deps,
    )
    with pytest.raises(Exception):
        for _ in pipe.run(range(6)):
            pass
    # every slot frees except the one the failing consumer had already
    # dequeued — that in-flight item is the trainer's ring.reset() job
    assert ring.live_slots == 1
    assert len(released) == ring.staged_total - 1 and released
    assert not pipe.drain_errors


def test_pipeline_on_drain_collects_hook_errors():
    def bad_hook(item):
        raise ValueError("hook failure")

    pipe = Pipeline(
        [
            Stage("a", lambda i: i, capacity=4, on_drain=bad_hook),
            Stage("b", lambda i: 1 / 0, capacity=4, max_retries=0),
        ]
    )
    with pytest.raises(Exception) as ei:
        for _ in pipe.run(range(5)):
            pass
    assert "division" in str(ei.value)  # hook errors never mask the cause
    assert all(isinstance(e, ValueError) for e in pipe.drain_errors)


# ------------------------------------------------------ trainer integration


def _cluster(tmp_path, tag):
    return Cluster(2, str(tmp_path / tag), dim=TINY.emb_dim * 2,
                   cache_capacity=2048, file_capacity=128,
                   init_cols=TINY.emb_dim)


def _raw_stream(seed=3):
    cfg = TINY
    return SyntheticCTRStream(cfg.n_sparse_keys, cfg.nnz_per_example,
                              cfg.n_slots, cfg.batch_size, seed=seed)


def _host_arm(seed=3):
    cfg = TINY
    return (
        to_ctr_batch(r, cfg.n_sparse_keys, cfg.n_slots, cfg.nnz_per_example)
        for r in _raw_stream(seed).raw_records()
    )


def test_trainer_ingest_bitwise_equals_host_feeder(tmp_path):
    """Acceptance: the ingest pipeline's losses are bitwise-equal to the
    host numpy feeder on the same raw records — pipelined AND serial."""
    tr_h = CTRTrainer(TINY, _cluster(tmp_path, "host"), TrainerConfig())
    want = [r["loss"] for r in tr_h.run(_host_arm(), 8)]

    tr_i = CTRTrainer(TINY, _cluster(tmp_path, "ingest"), TrainerConfig(ingest=True))
    got = [r["loss"] for r in tr_i.run(_raw_stream().raw_records(), 8)]
    assert got == want

    tr_s = CTRTrainer(TINY, _cluster(tmp_path, "serial"), TrainerConfig(ingest=True))
    got_serial = [r["loss"] for r in tr_s.run(_raw_stream().raw_records(), 8,
                                              pipelined=False)]
    assert got_serial == want

    c = tr_i.ingestor.counters
    assert c["ingest_batches"] == 8 and c["ingest_examples"] == 8 * TINY.batch_size
    assert c["staging_bytes"] > 0
    assert tr_i.ingestor.ring.live_slots == 0, "run end must leave no slot live"


def test_trainer_ingest_failure_path_frees_slots(tmp_path):
    cl = _cluster(tmp_path, "die")
    tr = CTRTrainer(TINY, cl, TrainerConfig(ingest=True))  # no ride-through
    FaultInjector([FaultSpec(NODE_KILL, at_op=20, node_id=0)]).arm(cl)
    with pytest.raises(Exception):
        tr.run(_raw_stream().raw_records(), 10)
    assert tr.ingestor.ring.live_slots == 0
    assert cl.total_pins() == 0


def test_trainer_ingest_rides_through_nic_stall_in_staging(tmp_path):
    """NIC stall injected on the very first transfer — which, with ingest
    on, is the staging H2D copy — must only slow the run, not change it."""
    tr_c = CTRTrainer(TINY, _cluster(tmp_path, "calm"), TrainerConfig(ingest=True))
    want = [r["loss"] for r in tr_c.run(_raw_stream().raw_records(), 6)]

    cl = _cluster(tmp_path, "stall")
    tr = CTRTrainer(TINY, cl, TrainerConfig(ingest=True, ride_through=True))
    inj = FaultInjector([FaultSpec(NIC_STALL, at_op=1, stall_s=0.2)]).arm(cl)
    got = [r["loss"] for r in tr.run(_raw_stream().raw_records(), 6)]
    inj.disarm()
    assert inj.all_fired() and cl.network.stalls >= 1
    assert got == want


def test_trainer_ingest_rides_through_node_kill_bitwise(tmp_path):
    tr_c = CTRTrainer(TINY, _cluster(tmp_path, "clean"), TrainerConfig(ingest=True))
    want = [r["loss"] for r in tr_c.run(_raw_stream().raw_records(), 10)]

    cl = _cluster(tmp_path, "chaos")
    tr = CTRTrainer(TINY, cl, TrainerConfig(ingest=True, ride_through=True))
    inj = FaultInjector([FaultSpec(NODE_KILL, at_op=40, node_id=1)]).arm(cl)
    got = [r["loss"] for r in tr.run(_raw_stream().raw_records(), 10)]
    inj.disarm()
    assert inj.all_fired()
    assert cl.fault_counters["node_recoveries"] >= 1
    np.testing.assert_array_equal(got, want)
    assert tr.ingestor.ring.live_slots == 0
    assert cl.total_pins() == 0 and tr.ps.n_inflight() == 0


# ---------------------------------------------------------------- registry


def test_ingest_counters_registered():
    for name in ("ingest_batches", "ingest_examples", "staging_bytes",
                 "ingest_wait_us", "ingest_overlap_us", "ingest_drained"):
        assert name in KNOWN_COUNTERS
    c = Counters(strict=True)
    c.inc("ingest_batches")  # strict mode accepts registered names
    assert c["ingest_batches"] == 1
