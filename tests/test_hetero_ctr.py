"""Heterogeneous per-slot embedding widths: CTR slot groups as named tables.

``TINY_HETERO`` splits its feature slots into a width-4 "query" group and a
width-8 "ad" group; each group is its own named PS table on one shared
cluster and the grouped train step updates both working tables (at their
native widths) inside one jit.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.ctr_models import TINY_HETERO, table_specs
from repro.core.client import PSClient
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.models import ctr as ctr_model
from repro.train.optim import AdamW
from repro.train.train_step import make_ctr_train_step_grouped


def test_table_specs_one_per_group():
    specs = table_specs(TINY_HETERO)
    assert [s.name for s in specs] == ["query", "ad"]
    assert [s.schema.emb_dim for s in specs] == [4, 8]
    assert all(s.schema.opt_dim == s.schema.emb_dim for s in specs)  # adagrad
    assert TINY_HETERO.pooled_dim == 4 * 4 + 4 * 8  # tower input width


def test_hetero_groups_train_on_one_cluster(tmp_path):
    cfg = TINY_HETERO
    specs = table_specs(cfg)
    width = max(s.schema.width for s in specs)
    cluster = Cluster(2, str(tmp_path / "ps"), dim=width, cache_capacity=2048,
                      file_capacity=64)
    client = PSClient(cluster, specs)

    tower = ctr_model.init_tower(cfg, jax.random.PRNGKey(0))
    assert tower["w0"].shape[0] == cfg.pooled_dim
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(tower)
    step = jax.jit(make_ctr_train_step_grouped(cfg, row_lr=0.05, tower_opt=opt))

    streams = {
        g.name: SyntheticCTRStream(
            cfg.n_sparse_keys, cfg.nnz_per_example, g.n_slots, cfg.batch_size,
            seed=i, noise=0.2,
        )
        for i, g in enumerate(cfg.groups)
    }
    k = cfg.minibatches_per_batch
    mb = cfg.batch_size // k
    stack = lambda a: jnp.asarray(a.reshape((k, mb) + a.shape[1:]))
    losses = []
    for _ in range(30):
        batches = {name: s.next_batch() for name, s in streams.items()}
        sessions = {name: client.session(name, b.keys) for name, b in batches.items()}
        try:
            minibatches = {
                # labels come from the query group's planted ground truth
                "labels": stack(batches["query"].labels),
                "inputs": {
                    name: {
                        "slot_ids": stack(sessions[name].slots),
                        "slot_of": stack(batches[name].slot_of),
                        "valid": stack(batches[name].valid),
                    }
                    for name in streams
                },
            }
            tables = {n: jnp.asarray(s.params) for n, s in sessions.items()}
            accums = {n: jnp.asarray(s.opt_state) for n, s in sessions.items()}
            tower, opt_state, tables, accums, m = step(
                tower, opt_state, tables, accums, minibatches
            )
            for name, s in sessions.items():
                s.commit(np.asarray(tables[name]), np.asarray(accums[name]))
        except BaseException:
            for s in sessions.values():
                if s.state == "open":
                    s.abort()
            raise
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "hetero model must learn"
    # each group's rows really live at its own width on the shared cluster
    for s in specs:
        spec = client.table(s.name)
        assert sessions[s.name].params.shape[1] == spec.schema.emb_dim
    cluster.flush_all()
    assert cluster.total_pins() == 0
    assert client.n_inflight() == 0
