import os
import sys

# Smoke tests and benches must see ONE device; only launch/dryrun.py forces
# 512 placeholder devices (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

_SANLOCK = bool(os.environ.get("REPRO_SANLOCK"))
if _SANLOCK:
    # Patch the threading lock factories BEFORE any repro module allocates
    # a lock (sanlock only wraps locks constructed under src/repro), so the
    # runtime lock-order sanitizer sees every product lock for the whole
    # tier-1 run. See repro.analysis.sanlock / DESIGN.md §10.
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.analysis import sanlock

    sanlock.install()

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _pscheck_sanitizers(request):
    """REPRO_SANLOCK=1: after every test, fail if the recorded
    lock-acquisition graph has a cycle (potential deadlock between the
    pipeline/serving threads) or a cluster created by this test still
    holds MEM-PS row pins (``pscheck_allow_pins`` marks intentional
    leaks). The graph accumulates across the whole session on purpose:
    cross-test edges are real edges."""
    if not _SANLOCK:
        yield
        return
    from repro.analysis import sanlock

    mark = sanlock.cluster_mark()
    yield
    cycle = sanlock.find_cycle()
    assert cycle is None, (
        "SanLock: lock-acquisition cycle (potential deadlock): "
        + " -> ".join(cycle)
    )
    if request.node.get_closest_marker("pscheck_allow_pins") is None:
        leaks = sanlock.pin_leaks(mark)
        assert not leaks, f"residual MEM-PS pins at teardown: {leaks}"
    sanlock.prune_dead_clusters()


@pytest.fixture(autouse=True)
def _clear_sharding_hooks():
    """Launcher hooks (logical constraints, shard_map gather, grad
    constraints) are process-global; never let one test leak into another."""
    yield
    from repro.models import common

    common.set_logical_constraint_fn(None)
    common.set_embed_gather_fn(None)
    common.set_param_constraint_fn(None)
