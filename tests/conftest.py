import os

# Smoke tests and benches must see ONE device; only launch/dryrun.py forces
# 512 placeholder devices (and only in its own process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _clear_sharding_hooks():
    """Launcher hooks (logical constraints, shard_map gather, grad
    constraints) are process-global; never let one test leak into another."""
    yield
    from repro.models import common

    common.set_logical_constraint_fn(None)
    common.set_embed_gather_fn(None)
    common.set_param_constraint_fn(None)
