"""Paper Fig 3b: hierarchical-PS training is LOSSLESS.

The paper validates via online A/B AUC (within 0.1%). Our adaptation makes
the claim *exact and testable*: training through the full HBM/MEM/SSD-PS
machinery (pull -> renumber -> device -> push, with eviction, compaction,
multi-node remote pulls) must produce the SAME parameters as a flat
in-memory table — to float tolerance — because the math is identical and
missing-key init is a deterministic function of the key.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.ctr_models import CTRConfig
from repro.core.keys import deterministic_init
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.models import ctr as ctr_model
from repro.train.optim import AdamW
from repro.train.train_step import make_ctr_train_step
from repro.train.trainer import CTRTrainer, TrainerConfig

CFG = CTRConfig(
    name="lossless",
    n_sparse_keys=500,
    nnz_per_example=12,
    emb_dim=4,
    n_slots=6,
    mlp_hidden=(16, 8),
    batch_size=32,
    minibatches_per_batch=2,
)
N_BATCHES = 8


def run_hier(tmp_path, tiny_cache: bool) -> tuple[np.ndarray, dict]:
    """Train through the full PS stack; tiny_cache forces eviction churn +
    compaction so the storage path is genuinely exercised."""
    # tiny: big enough for one batch's pinned working set (~128 rows/node),
    # smaller than the 500-key steady state -> constant eviction + SSD churn
    cache = 160 if tiny_cache else 4096
    cl = Cluster(
        3, str(tmp_path / f"ps{tiny_cache}"), dim=CFG.emb_dim * 2,
        cache_capacity=cache, file_capacity=32, init_cols=CFG.emb_dim,
    )
    tr = CTRTrainer(CFG, cl, TrainerConfig())
    stream = SyntheticCTRStream(CFG.n_sparse_keys, CFG.nnz_per_example, CFG.n_slots, CFG.batch_size, seed=7)
    # serial mode: exact algorithmic parity (the pipelined schedule adds the
    # paper's bounded one-batch staleness, tested in test_system.py)
    tr.run(stream, N_BATCHES, pipelined=False)
    cl.flush_all()
    all_keys = np.arange(CFG.n_sparse_keys, dtype=np.uint64)
    rows = cl.pull(all_keys, pin=False)
    return rows[:, : CFG.emb_dim], tr.tower


def run_flat() -> tuple[np.ndarray, dict]:
    """Flat in-memory baseline: full table on device, same stream/seeds."""
    table = jnp.asarray(deterministic_init(np.arange(CFG.n_sparse_keys, dtype=np.uint64), CFG.emb_dim, 0.01))
    accum = jnp.zeros_like(table)
    tower = ctr_model.init_tower(CFG, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(tower)
    step = jax.jit(make_ctr_train_step(CFG, 0.05, opt))
    stream = SyntheticCTRStream(CFG.n_sparse_keys, CFG.nnz_per_example, CFG.n_slots, CFG.batch_size, seed=7)
    k = CFG.minibatches_per_batch
    for _ in range(N_BATCHES):
        b = stream.next_batch()
        mb = CFG.batch_size // k
        sl = lambda a: jnp.asarray(a.reshape((k, mb) + a.shape[1:]))
        minibatches = {
            "slot_ids": sl(b.keys.astype(np.int64)),  # keys ARE row ids here
            "slot_of": sl(b.slot_of),
            "valid": sl(b.valid),
            "labels": sl(b.labels),
        }
        tower, opt_state, table, accum, _ = step(tower, opt_state, table, accum, minibatches)
    return np.asarray(table), tower


@pytest.mark.parametrize("tiny_cache", [False, True])
def test_hier_ps_training_is_lossless(tmp_path, tiny_cache):
    hier_table, hier_tower = run_hier(tmp_path, tiny_cache)
    flat_table, flat_tower = run_flat()
    np.testing.assert_allclose(hier_table, flat_table, atol=1e-5, rtol=1e-4)
    for k in flat_tower:
        np.testing.assert_allclose(
            np.asarray(hier_tower[k]), np.asarray(flat_tower[k]), atol=1e-5, rtol=1e-4
        )
