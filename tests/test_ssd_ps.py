"""SSD-PS: log-structured semantics, compaction bound, manifests."""

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # not installed: deterministic fixed-seed fallback
    from repro.testing.hypothesis_fallback import HealthCheck, given, settings, st

from repro.core.keys import deterministic_init
from repro.core.ssd_ps import SSDParameterServer


def test_roundtrip(tmp_path):
    ssd = SSDParameterServer(str(tmp_path), dim=4, file_capacity=16)
    keys = np.arange(100, dtype=np.uint64)
    vals = np.random.default_rng(0).random((100, 4)).astype(np.float32)
    ssd.write_batch(keys, vals)
    np.testing.assert_allclose(ssd.read_batch(keys[::7]), vals[::7])


def test_overwrite_latest_wins(tmp_path):
    ssd = SSDParameterServer(str(tmp_path), dim=2, file_capacity=8)
    keys = np.arange(32, dtype=np.uint64)
    for i in range(5):
        ssd.write_batch(keys, np.full((32, 2), float(i), np.float32))
    np.testing.assert_allclose(ssd.read_batch(keys), np.full((32, 2), 4.0))


def test_space_bound_after_churn(tmp_path):
    """Paper: >50%-stale compaction bounds disk at <=2x live rows."""
    ssd = SSDParameterServer(str(tmp_path), dim=4, file_capacity=32)
    keys = np.arange(256, dtype=np.uint64)
    rng = np.random.default_rng(0)
    for _ in range(30):
        sub = rng.choice(keys, size=64, replace=False).astype(np.uint64)
        ssd.write_batch(sub, rng.random((64, 4)).astype(np.float32))
    assert ssd.space_amplification() <= 2.5  # 2x + one in-flight batch
    assert ssd.n_live_rows == 256


def test_missing_key_deterministic_init(tmp_path):
    ssd = SSDParameterServer(str(tmp_path), dim=6, file_capacity=8, init_cols=3)
    got = ssd.read_batch(np.array([42, 43], dtype=np.uint64))
    exp = deterministic_init(np.array([42, 43], dtype=np.uint64), 3, 0.01)
    np.testing.assert_allclose(got[:, :3], exp)
    assert (got[:, 3:] == 0).all()  # optimizer slots start at zero


def test_manifest_restore(tmp_path):
    ssd = SSDParameterServer(str(tmp_path), dim=3, file_capacity=8)
    keys = np.arange(50, dtype=np.uint64)
    vals = np.random.default_rng(1).random((50, 3)).astype(np.float32)
    ssd.write_batch(keys, vals)
    ssd.write_batch(keys[:20], vals[:20] * 2)
    m = ssd.manifest()
    ssd2 = SSDParameterServer.from_manifest(str(tmp_path), m)
    got = ssd2.read_batch(keys)
    np.testing.assert_allclose(got[:20], vals[:20] * 2)
    np.testing.assert_allclose(got[20:], vals[20:])


def test_read_amplification_counted(tmp_path):
    ssd = SSDParameterServer(str(tmp_path), dim=2, file_capacity=16)
    keys = np.arange(64, dtype=np.uint64)
    ssd.write_batch(keys, np.zeros((64, 2), np.float32))
    ssd.read_batch(keys[:1])  # reads a whole 16-row file for 1 key
    assert ssd.stats.read_amplification >= 8


@settings(max_examples=20, deadline=None, suppress_health_check=[HealthCheck.function_scoped_fixture])
@given(
    ops=st.lists(
        st.tuples(st.integers(0, 63), st.floats(-10, 10, allow_nan=False)),
        min_size=1,
        max_size=100,
    )
)
def test_matches_dict_model(tmp_path, ops):
    """Arbitrary interleaved writes/reads == a plain dict (property test)."""
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        ssd = SSDParameterServer(d, dim=1, file_capacity=4)
        model: dict[int, float] = {}
        for i, (key, val) in enumerate(ops):
            if i % 3 == 2 and model:  # read check
                ks = np.asarray(sorted(model), dtype=np.uint64)
                got = ssd.read_batch(ks)[:, 0]
                exp = np.asarray([model[int(k)] for k in ks], np.float32)
                np.testing.assert_allclose(got, exp, rtol=1e-6)
            ssd.write_batch(
                np.asarray([key], np.uint64), np.asarray([[val]], np.float32)
            )
            model[key] = np.float32(val)
        ks = np.asarray(sorted(model), dtype=np.uint64)
        np.testing.assert_allclose(
            ssd.read_batch(ks)[:, 0],
            np.asarray([model[int(k)] for k in ks], np.float32),
            rtol=1e-6,
        )
