"""End-to-end behaviour tests for the hierarchical parameter server system."""

import numpy as np
import pytest

from repro.configs.ctr_models import TINY
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.train.trainer import CTRTrainer, TrainerConfig


@pytest.fixture
def cluster(tmp_path):
    return Cluster(
        2, str(tmp_path / "ps"), dim=TINY.emb_dim * 2,
        cache_capacity=2048, file_capacity=128, init_cols=TINY.emb_dim,
    )


def test_pipelined_training_learns(cluster):
    # note: pipelined scheduling makes the trajectory mildly nondeterministic
    # (bounded one-batch staleness depends on thread timing), so the check is
    # a trend over enough batches, not a fixed margin.
    tr = CTRTrainer(TINY, cluster, TrainerConfig())
    stream = SyntheticCTRStream(
        TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=0, noise=0.2
    )
    res = tr.run(stream, 60)
    losses = [r["loss"] for r in res]
    assert np.mean(losses[-15:]) < np.mean(losses[:15]), "training must learn"
    assert all(np.isfinite(l) for l in losses)
    # every result carries the working-set size (dedup really happened)
    assert all(0 < r["n_working"] <= TINY.batch_size * TINY.nnz_per_example for r in res)


def _run(tmp_path, tag, pipelined, n=6):
    cl = Cluster(2, str(tmp_path / f"ps_{tag}"), dim=TINY.emb_dim * 2,
                 cache_capacity=2048, file_capacity=128, init_cols=TINY.emb_dim)
    tr = CTRTrainer(TINY, cl, TrainerConfig())
    s = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=5)
    return [r["loss"] for r in tr.run(s, n, pipelined=pipelined)]


def test_serial_training_is_deterministic(tmp_path):
    np.testing.assert_allclose(
        _run(tmp_path, "a", False), _run(tmp_path, "b", False), rtol=1e-7
    )


def test_pipeline_staleness_is_bounded(tmp_path):
    """The 4-stage pipeline prefetches batch i+1's parameters while batch i
    still trains (paper Appendix B), so keys shared across adjacent batches
    see <=1-batch-stale values — trajectories stay close but are not
    bitwise equal. (The paper's lossless claim is AUC-level; the exact
    algorithmic parity test lives in test_lossless.py, serial mode.)"""
    pipe = _run(tmp_path, "p", True)
    serial = _run(tmp_path, "s", False)
    np.testing.assert_allclose(pipe, serial, atol=2e-2)
    assert not np.allclose(pipe, serial, rtol=1e-9) or True  # may differ


def test_cache_and_ssd_actually_used(cluster):
    tr = CTRTrainer(TINY, cluster, TrainerConfig())
    stream = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=1)
    tr.run(stream, 10)
    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    assert hits > 0 and misses > 0
    cluster.flush_all()
    assert sum(n.ssd.n_live_rows for n in cluster.nodes) > 0
    assert cluster.network.bytes_moved > 0  # remote pulls happened
