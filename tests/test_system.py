"""End-to-end behaviour tests for the hierarchical parameter server system."""

import numpy as np
import pytest

from repro.configs.ctr_models import TINY
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.train.trainer import CTRTrainer, TrainerConfig


@pytest.fixture
def cluster(tmp_path):
    return Cluster(
        2, str(tmp_path / "ps"), dim=TINY.emb_dim * 2,
        cache_capacity=2048, file_capacity=128, init_cols=TINY.emb_dim,
    )


def test_pipelined_training_learns(cluster):
    tr = CTRTrainer(TINY, cluster, TrainerConfig())
    stream = SyntheticCTRStream(
        TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=0, noise=0.2
    )
    res = tr.run(stream, 60)
    losses = [r["loss"] for r in res]
    assert np.mean(losses[-15:]) < np.mean(losses[:15]), "training must learn"
    assert all(np.isfinite(l) for l in losses)
    # every result carries the working-set size (dedup really happened)
    assert all(0 < r["n_working"] <= TINY.batch_size * TINY.nnz_per_example for r in res)


def _run(tmp_path, tag, pipelined, n=6):
    out = _run_full(tmp_path, tag, pipelined, n)
    return out["losses"]


def _run_full(tmp_path, tag, pipelined, n=6):
    """Train on a zipf key stream (TINY: 1024 draws over 1000 keys, so
    adjacent batches share most hot keys — forcing cross-batch conflicts)
    and return losses + the full flushed parameter state + counters."""
    cl = Cluster(2, str(tmp_path / f"ps_{tag}"), dim=TINY.emb_dim * 2,
                 cache_capacity=2048, file_capacity=128, init_cols=TINY.emb_dim)
    tr = CTRTrainer(TINY, cl, TrainerConfig())
    s = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=5)
    losses = [r["loss"] for r in tr.run(s, n, pipelined=pipelined)]
    cl.flush_all()
    rows = cl.pull(np.arange(TINY.n_sparse_keys, dtype=np.uint64), pin=False)
    return {"losses": losses, "rows": rows, "trainer": tr, "cluster": cl}


def test_serial_training_is_deterministic(tmp_path):
    np.testing.assert_allclose(
        _run(tmp_path, "a", False), _run(tmp_path, "b", False), rtol=1e-7
    )


def test_pipeline_is_lossless_bitwise(tmp_path):
    """The paper's central correctness claim: overlapping pull(i+1) with
    train(i) must not change the learned model. Conflict-aware pulls forward
    the completing batch's pushed rows per key instead of re-reading stale
    host copies, so the pipelined trajectory — losses AND every flushed SSD
    row — is bitwise-identical to serial execution, not merely close."""
    pipe = _run_full(tmp_path, "p", True, n=8)
    serial = _run_full(tmp_path, "s", False, n=8)
    np.testing.assert_array_equal(pipe["losses"], serial["losses"])
    np.testing.assert_array_equal(pipe["rows"], serial["rows"])
    # the zipf stream really exercised the conflict path, and no pin leaked
    assert pipe["trainer"].ps.stats.conflict_rows > 0
    # serial never overlaps, so it never awaits another batch's results
    # (device-serving shared keys is legal in both modes — bitwise equal)
    assert serial["trainer"].ps.stats.rows_forwarded == 0
    assert pipe["cluster"].total_pins() == 0
    assert pipe["trainer"].ps.n_inflight() == 0


def test_device_working_set_reuse_cuts_bytes(tmp_path):
    """Rows shared between consecutive batches stay device-resident and
    conflict keys are forwarded instead of re-pulled, so the pipelined run
    moves strictly fewer bytes than the pull-everything serial baseline
    (PR-1 behaviour) — while training the exact same model."""
    pipe = _run_full(tmp_path, "pb", True, n=8)
    serial = _run_full(tmp_path, "sb", False, n=8)
    tr = pipe["trainer"]
    # forwarded rows never crossed the simulated NIC for a second pull
    assert tr.ps.stats.pull_bytes_saved > 0
    assert pipe["cluster"].network.bytes_moved < serial["cluster"].network.bytes_moved
    # shared rows never re-crossed the host->device link either: on this
    # zipf stream the majority of every batch's working set stays resident
    assert tr.dev_ws.stats.rows_reused > 0
    assert tr.dev_ws.stats.bytes_saved > 0
    assert tr.dev_ws.stats.rows_reused > tr.dev_ws.stats.rows_transferred // 2


def test_cache_and_ssd_actually_used(cluster):
    tr = CTRTrainer(TINY, cluster, TrainerConfig())
    stream = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=1)
    tr.run(stream, 10)
    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    assert hits > 0 and misses > 0
    cluster.flush_all()
    assert sum(n.ssd.n_live_rows for n in cluster.nodes) > 0
    assert cluster.network.bytes_moved > 0  # remote pulls happened
