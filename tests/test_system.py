"""End-to-end behaviour tests for the hierarchical parameter server system."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.ctr_models import TINY
from repro.core.client import PSClient
from repro.core.node import Cluster
from repro.core.tables import RowSchema, TableSpec
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.train.trainer import CTRTrainer, TrainerConfig


@pytest.fixture
def cluster(tmp_path):
    return Cluster(
        2, str(tmp_path / "ps"), dim=TINY.emb_dim * 2,
        cache_capacity=2048, file_capacity=128, init_cols=TINY.emb_dim,
    )


def test_pipelined_training_learns(cluster):
    tr = CTRTrainer(TINY, cluster, TrainerConfig())
    stream = SyntheticCTRStream(
        TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=0, noise=0.2
    )
    res = tr.run(stream, 60)
    losses = [r["loss"] for r in res]
    assert np.mean(losses[-15:]) < np.mean(losses[:15]), "training must learn"
    assert all(np.isfinite(l) for l in losses)
    # every result carries the working-set size (dedup really happened)
    assert all(0 < r["n_working"] <= TINY.batch_size * TINY.nnz_per_example for r in res)


def _run(tmp_path, tag, pipelined, n=6):
    out = _run_full(tmp_path, tag, pipelined, n)
    return out["losses"]


def _run_full(tmp_path, tag, pipelined, n=6):
    """Train on a zipf key stream (TINY: 1024 draws over 1000 keys, so
    adjacent batches share most hot keys — forcing cross-batch conflicts)
    and return losses + the full flushed parameter state + counters."""
    cl = Cluster(2, str(tmp_path / f"ps_{tag}"), dim=TINY.emb_dim * 2,
                 cache_capacity=2048, file_capacity=128, init_cols=TINY.emb_dim)
    tr = CTRTrainer(TINY, cl, TrainerConfig())
    s = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=5)
    losses = [r["loss"] for r in tr.run(s, n, pipelined=pipelined)]
    cl.flush_all()
    rows = cl.pull(np.arange(TINY.n_sparse_keys, dtype=np.uint64), pin=False)
    return {"losses": losses, "rows": rows, "trainer": tr, "cluster": cl}


def test_serial_training_is_deterministic(tmp_path):
    np.testing.assert_allclose(
        _run(tmp_path, "a", False), _run(tmp_path, "b", False), rtol=1e-7
    )


def test_pipeline_is_lossless_bitwise(tmp_path):
    """The paper's central correctness claim: overlapping pull(i+1) with
    train(i) must not change the learned model. Conflict-aware pulls forward
    the completing batch's pushed rows per key instead of re-reading stale
    host copies, so the pipelined trajectory — losses AND every flushed SSD
    row — is bitwise-identical to serial execution, not merely close."""
    pipe = _run_full(tmp_path, "p", True, n=8)
    serial = _run_full(tmp_path, "s", False, n=8)
    np.testing.assert_array_equal(pipe["losses"], serial["losses"])
    np.testing.assert_array_equal(pipe["rows"], serial["rows"])
    # the zipf stream really exercised the conflict path, and no pin leaked
    assert pipe["trainer"].ps.stats.conflict_rows > 0
    # serial never overlaps, so it never awaits another batch's results
    # (device-serving shared keys is legal in both modes — bitwise equal)
    assert serial["trainer"].ps.stats.rows_forwarded == 0
    assert pipe["cluster"].total_pins() == 0
    assert pipe["trainer"].ps.n_inflight() == 0


def test_device_working_set_reuse_cuts_bytes(tmp_path):
    """Rows shared between consecutive batches stay device-resident and
    conflict keys are forwarded instead of re-pulled, so the pipelined run
    moves strictly fewer bytes than the pull-everything serial baseline
    (PR-1 behaviour) — while training the exact same model."""
    pipe = _run_full(tmp_path, "pb", True, n=8)
    serial = _run_full(tmp_path, "sb", False, n=8)
    tr = pipe["trainer"]
    # forwarded rows never crossed the simulated NIC for a second pull
    assert tr.ps.stats.pull_bytes_saved > 0
    assert pipe["cluster"].network.bytes_moved < serial["cluster"].network.bytes_moved
    # shared rows never re-crossed the host->device link either: on this
    # zipf stream the majority of every batch's working set stays resident
    assert tr.dev_ws.stats.rows_reused > 0
    assert tr.dev_ws.stats.bytes_saved > 0
    assert tr.dev_ws.stats.rows_reused > tr.dev_ws.stats.rows_transferred // 2


def test_two_tables_cohost_ctr_and_lm_on_one_cluster(tmp_path):
    """Scenario diversity through the multi-table client: a CTR model
    (emb_dim 4 slot table) and an LM (d_model 64 vocab table) train against
    ONE shared cluster in one run — different schemas, different widths,
    namespaced keys. Both workloads must train bit-identically to running
    each alone on its own cluster (per-table losslessness under
    co-hosting)."""
    from repro.configs import get_smoke_config
    from repro.models import ctr as ctr_model
    from repro.models import transformer as T
    from repro.train.optim import AdamW
    from repro.train.train_step import (
        TrainSettings,
        make_ctr_train_step,
        make_lm_train_step_hier,
    )

    ctr_cfg = TINY  # emb_dim 4
    lm_cfg = get_smoke_config("yi-9b")  # hier_ps embedding, d_model 64
    ctr_spec = TableSpec("ctr_slots", RowSchema.with_adagrad(ctr_cfg.emb_dim), table_id=1)
    lm_spec = TableSpec("lm_vocab", RowSchema.with_adagrad(lm_cfg.d_model), table_id=2)
    n_steps = 4

    def lm_data(step, B=4, S=8):
        k = jax.random.PRNGKey(100 + step)
        toks = jax.random.randint(k, (B, S + 1), 0, lm_cfg.vocab_size)
        return np.asarray(toks[:, :-1]), np.asarray(toks[:, 1:])

    def make_steps():
        ctr_opt = AdamW(lr=1e-3)
        lm_settings = TrainSettings(
            optimizer=AdamW(lr=1e-3, clip_norm=0.0), microbatches=1, row_lr=0.05
        )
        return (
            jax.jit(make_ctr_train_step(ctr_cfg, 0.05, ctr_opt)), ctr_opt,
            jax.jit(make_lm_train_step_hier(lm_cfg, lm_settings)), lm_settings,
        )

    def train_ctr_batch(client, step, state, batch):
        tower, opt_state = state
        with client.session("ctr_slots", batch.keys) as s:
            k = ctr_cfg.minibatches_per_batch
            mb = ctr_cfg.batch_size // k
            sl = lambda a: jnp.asarray(a.reshape((k, mb) + a.shape[1:]))
            minibatches = {
                "slot_ids": sl(s.slots), "slot_of": sl(batch.slot_of),
                "valid": sl(batch.valid), "labels": sl(batch.labels),
            }
            tower, opt_state, table, accum, m = step(
                tower, opt_state, jnp.asarray(s.params), jnp.asarray(s.opt_state),
                minibatches,
            )
            s.commit(np.asarray(table), np.asarray(accum))
        return (tower, opt_state), float(m["loss"])

    def train_lm_step(client, step, state, i):
        params, opt_state = state
        toks, tgts = lm_data(i)
        with client.session("lm_vocab", toks.astype(np.uint64)) as s:
            batch = {"tokens": jnp.asarray(s.slots), "targets": jnp.asarray(tgts)}
            params, opt_state, m, new_t, new_acc = step(
                params, opt_state, batch, jnp.asarray(s.params), jnp.asarray(s.opt_state)
            )
            s.commit(np.asarray(new_t), np.asarray(new_acc))
        return (params, opt_state), float(m["loss"])

    def final_rows(client, table):
        client.cluster.flush_all()
        spec = client.table(table)
        n = ctr_cfg.n_sparse_keys if table == "ctr_slots" else lm_cfg.vocab_size
        keys = spec.namespace(np.arange(n, dtype=np.uint64))
        return client.cluster.pull(keys, pin=False)[:, : spec.schema.width]

    def run(tag, tables):
        """tables: which specs this cluster hosts (cohosted or solo)."""
        dim = 2 * max(lm_cfg.d_model if lm_spec in tables else 0,
                      ctr_cfg.emb_dim if ctr_spec in tables else 0)
        cl = Cluster(2, str(tmp_path / tag), dim=dim, cache_capacity=2048,
                     file_capacity=64)
        client = PSClient(cl, tables)
        ctr_step, ctr_opt, lm_step, lm_settings = make_steps()
        ctr_state = lm_state = None
        ctr_losses, lm_losses = [], []
        if ctr_spec in tables:
            tower = ctr_model.init_tower(ctr_cfg, jax.random.PRNGKey(0))
            ctr_state = (tower, ctr_opt.init(tower))
            stream = SyntheticCTRStream(ctr_cfg.n_sparse_keys, ctr_cfg.nnz_per_example,
                                        ctr_cfg.n_slots, ctr_cfg.batch_size, seed=11)
        if lm_spec in tables:
            lm_params = T.init(lm_cfg, jax.random.PRNGKey(0))
            lm_state = (lm_params, lm_settings.optimizer.init(lm_params))
        for i in range(n_steps):  # interleave the two workloads
            if ctr_state is not None:
                ctr_state, l = train_ctr_batch(client, ctr_step, ctr_state,
                                               stream.next_batch())
                ctr_losses.append(l)
            if lm_state is not None:
                lm_state, l = train_lm_step(client, lm_step, lm_state, i)
                lm_losses.append(l)
        assert cl.total_pins() == 0 and client.n_inflight() == 0
        return client, ctr_losses, lm_losses

    both, ctr_l, lm_l = run("both", [ctr_spec, lm_spec])
    ctr_rows = final_rows(both, "ctr_slots")
    lm_rows = final_rows(both, "lm_vocab")
    assert all(np.isfinite(ctr_l)) and all(np.isfinite(lm_l))

    solo_ctr, ctr_l_solo, _ = run("ctr", [ctr_spec])
    solo_lm, _, lm_l_solo = run("lm", [lm_spec])
    np.testing.assert_array_equal(ctr_l, ctr_l_solo)
    np.testing.assert_array_equal(lm_l, lm_l_solo)
    # per-table rows bit-identical: co-hosting perturbs neither workload
    np.testing.assert_array_equal(ctr_rows, final_rows(solo_ctr, "ctr_slots"))
    np.testing.assert_array_equal(lm_rows, final_rows(solo_lm, "lm_vocab"))


def test_cache_and_ssd_actually_used(cluster):
    tr = CTRTrainer(TINY, cluster, TrainerConfig())
    stream = SyntheticCTRStream(TINY.n_sparse_keys, TINY.nnz_per_example, TINY.n_slots, TINY.batch_size, seed=1)
    tr.run(stream, 10)
    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    assert hits > 0 and misses > 0
    cluster.flush_all()
    assert sum(n.ssd.n_live_rows for n in cluster.nodes) > 0
    assert cluster.network.bytes_moved > 0  # remote pulls happened
