"""Retrieval subsystem semantics (DESIGN.md §12).

Pins the acceptance contract of the top-k MIPS stack:

* the blocked Pallas kernel is **bitwise-equal** to the pure-jnp oracle
  (``kernels.ref.topk_mips_ref``) — scores and indices, deterministic
  tie-breaking (score desc, corpus index asc), (-inf, -1) padding when k
  exceeds the live corpus — under ``interpret=True`` on dyadic-grid inputs
  (every score is one dot over the full feature dim, never accumulated
  across grid steps, so quantized embeddings make f32 exact);
* ``RetrievalIndex.build`` materializes exactly one table's live rows from
  a published snapshot, in ascending raw-key order, lane-padded;
* ``RetrievalEngine.search`` equals the oracle on the bound version, and a
  concurrent ``roll_forward`` is atomic — every in-flight search matches
  the oracle of the single version it reports;
* retention refs keep the bound snapshot's files readable across training
  compaction, and ``close`` releases them;
* rerank re-scores deterministically and reads user rows at the pinned
  version; retrieval counters flow through metrics.Counters.
"""

import threading

import numpy as np
import pytest

from repro.core.client import PSClient
from repro.core.node import Cluster
from repro.core.tables import RowSchema, TableSpec
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.topk_mips import topk_mips_pallas
from repro.metrics import KNOWN_COUNTERS, Counters
from repro.retrieval import RETRIEVAL_COUNTER_NAMES, RetrievalEngine, RetrievalIndex
from repro.serve import SnapshotPublisher

DIM = 8
N_ADS = 300


def _dyadic(rng, shape):
    """f32 values on a 1/64 grid: blocked and full matmuls agree bitwise."""
    return (rng.integers(-128, 128, size=shape) / 64.0).astype(np.float32)


def _pad_cols(x, d):
    return np.pad(x, ((0, 0), (0, d - x.shape[1])))


# ------------------------------------------------------- kernel vs oracle


def _assert_kernel_matches_oracle(q, c, k, *, n_valid=None, block_q=8, block_n=64):
    got_v, got_i = topk_mips_pallas(
        q, c, k, n_valid=n_valid, block_q=block_q, block_n=block_n, interpret=True
    )
    want_v, want_i = kref.topk_mips_ref(q, c, k, n_valid=n_valid)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))


def test_topk_kernel_matches_oracle_sweep():
    rng = np.random.default_rng(0)
    for qn, n, d, k in ((5, 200, 8, 10), (1, 64, 16, 1), (17, 130, 4, 7),
                        (8, 64, 8, 64)):
        _assert_kernel_matches_oracle(
            _dyadic(rng, (qn, d)), _dyadic(rng, (n, d)), k
        )


def test_topk_kernel_deterministic_tie_breaking():
    rng = np.random.default_rng(1)
    base = _dyadic(rng, (40, DIM))
    # every corpus row appears 4x: ties must resolve to the smallest index
    c = np.tile(base, (4, 1))
    q = _dyadic(rng, (6, DIM))
    got_v, got_i = topk_mips_pallas(q, c, 8, block_q=8, block_n=32, interpret=True)
    got_v, got_i = np.asarray(got_v), np.asarray(got_i)
    _assert_kernel_matches_oracle(q, c, 8, block_n=32)
    # within each query, equal scores must carry strictly ascending indices
    for b in range(6):
        for a in range(7):
            if got_v[b, a] == got_v[b, a + 1]:
                assert got_i[b, a] < got_i[b, a + 1]


def test_topk_k_exceeds_corpus_pads_with_sentinels():
    rng = np.random.default_rng(2)
    q, c = _dyadic(rng, (3, DIM)), _dyadic(rng, (10, DIM))
    got_v, got_i = topk_mips_pallas(q, c, 16, block_q=8, block_n=8, interpret=True)
    got_v, got_i = np.asarray(got_v), np.asarray(got_i)
    _assert_kernel_matches_oracle(q, c, 16, block_n=8)
    assert np.isneginf(got_v[:, 10:]).all() and (got_i[:, 10:] == -1).all()
    assert (got_i[:, :10] >= 0).all()


def test_topk_n_valid_masks_corpus_tail():
    rng = np.random.default_rng(3)
    q, c = _dyadic(rng, (4, DIM)), _dyadic(rng, (96, DIM))
    _assert_kernel_matches_oracle(q, c, 12, n_valid=50, block_n=32)
    got_v, got_i = topk_mips_pallas(
        q, c, 12, n_valid=50, block_q=8, block_n=32, interpret=True
    )
    assert (np.asarray(got_i) < 50).all()  # masked tail can never surface


def test_topk_ragged_query_batches():
    rng = np.random.default_rng(4)
    c = _dyadic(rng, (64, DIM))
    for qn in (1, 7, 9):  # none a multiple of block_q
        _assert_kernel_matches_oracle(_dyadic(rng, (qn, DIM)), c, 5, block_q=8)


def test_topk_rejects_bad_k():
    rng = np.random.default_rng(5)
    with pytest.raises(ValueError):
        topk_mips_pallas(_dyadic(rng, (2, DIM)), _dyadic(rng, (8, DIM)), 0,
                         interpret=True)


def test_topk_dispatcher_arms_agree():
    rng = np.random.default_rng(6)
    q, c = _dyadic(rng, (5, DIM)), _dyadic(rng, (70, DIM))
    ref_v, ref_i = kops.topk_mips(q, c, 6, use_pallas=False)
    pal_v, pal_i = kops.topk_mips(q, c, 6, use_pallas=True, interpret=True,
                                  block_q=8, block_n=32)
    np.testing.assert_array_equal(np.asarray(pal_v), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(pal_i), np.asarray(ref_i))


# ------------------------------------------------------------ index build


@pytest.fixture
def setup(tmp_path):
    cluster = Cluster(2, str(tmp_path / "train"), dim=2 * DIM,
                      cache_capacity=1024, file_capacity=64, init_cols=DIM)
    client = PSClient(cluster, [
        TableSpec("ads", RowSchema.with_adagrad(DIM)),
        TableSpec("user", RowSchema.with_adagrad(DIM)),
    ])
    rng = np.random.default_rng(7)
    keys = np.arange(N_ADS, dtype=np.uint64)
    rows = _dyadic(rng, (N_ADS, DIM))
    full = np.zeros((N_ADS, 2 * DIM), np.float32)
    full[:, :DIM] = rows
    ads = client.registry.get("ads")
    cluster.push(ads.namespace(keys), full, unpin=False)
    # a second table in the same key range: the index must filter it out
    user = client.registry.get("user")
    ufull = np.full((40, 2 * DIM), 9.0, np.float32)
    cluster.push(user.namespace(np.arange(40, dtype=np.uint64)), ufull,
                 unpin=False)
    pub = SnapshotPublisher(cluster, str(tmp_path / "snap"))
    pub.publish()
    return cluster, client, pub, keys, rows


def _engine(client, pub, **kw):
    eng = client.serving_view(snapshots=pub, cache_rows=1024)
    kw.setdefault("block_q", 8)
    kw.setdefault("block_n", 64)
    kw.setdefault("use_pallas", True)
    kw.setdefault("interpret", True)
    return RetrievalEngine(eng, "ads", **kw)


def test_index_build_filters_sorts_and_pads(setup):
    cluster, client, pub, keys, rows = setup
    src = client.serving_view(snapshots=pub).source
    idx = RetrievalIndex.build(src, "ads", block_n=64)
    assert idx.n_rows == N_ADS and idx.dim == DIM and idx.version == 1
    np.testing.assert_array_equal(idx.keys, keys)  # ascending raw keys
    corpus = np.asarray(idx.corpus)
    assert corpus.shape == (320, 128)  # 64-row blocks x 128-lane columns
    np.testing.assert_array_equal(corpus[:N_ADS, :DIM], rows)
    assert not corpus[N_ADS:].any() and not corpus[:, DIM:].any()
    # the "user" table's 9.0 rows never leak into the ads corpus
    assert not (corpus == 9.0).any()


def test_index_rejects_live_view(setup):
    cluster, client, pub, keys, rows = setup
    live = client.serving_view()  # LiveClusterView: no immutable version
    with pytest.raises(TypeError):
        RetrievalEngine(live, "ads")


# -------------------------------------------------------- engine semantics


def test_search_matches_oracle_on_snapshot(setup):
    cluster, client, pub, keys, rows = setup
    retr = _engine(client, pub)
    rng = np.random.default_rng(8)
    q = _dyadic(rng, (5, DIM))
    res = retr.search(q, 10)
    want_v, want_i = kref.topk_mips_ref(q, rows, 10)
    np.testing.assert_array_equal(res.scores, np.asarray(want_v))
    np.testing.assert_array_equal(res.indices, np.asarray(want_i))
    # ascending-key corpus order makes index == key here
    np.testing.assert_array_equal(res.ad_keys[res.valid],
                                  res.indices[res.valid].astype(np.uint64))
    assert res.valid.all() and res.version == 1
    assert retr.counters["retrieval_searches"] == 1
    assert retr.counters["retrieval_rows_scored"] == 5 * N_ADS


def test_search_shape_contract_and_validation(setup):
    cluster, client, pub, keys, rows = setup
    retr = _engine(client, pub)
    empty = retr.search(np.zeros((0, DIM), np.float32), 7)
    assert empty.scores.shape == (0, 7) and empty.indices.shape == (0, 7)
    with pytest.raises(ValueError):
        retr.search(np.zeros((2, DIM + 1), np.float32), 5)  # wrong emb dim
    with pytest.raises(ValueError):
        retr.search(np.zeros((2, DIM), np.float32), 0)  # k < 1
    retr.close()
    with pytest.raises(RuntimeError):
        retr.search(np.zeros((2, DIM), np.float32), 5)


def test_roll_forward_atomic_under_concurrent_search(setup):
    """Acceptance: every in-flight search during a roll matches the oracle
    of the single version it reports — never a mix of two corpora."""
    cluster, client, pub, keys, rows = setup
    ads = client.registry.get("ads")
    rows2 = rows * 2.0  # still dyadic; every score differs from v1's
    full2 = np.zeros((N_ADS, 2 * DIM), np.float32)
    full2[:, :DIM] = rows2
    retr = _engine(client, pub)
    assert retr.version == 1

    rng = np.random.default_rng(9)
    q = _dyadic(rng, (4, DIM))
    oracle = {}
    for v, r in ((1, rows), (2, rows2)):
        wv, wi = kref.topk_mips_ref(q, r, 6)
        oracle[v] = (np.asarray(wv), np.asarray(wi))

    stop = threading.Event()
    bad: list[str] = []
    done: list[int] = []

    def worker():
        n = 0
        try:
            while not stop.is_set():
                res = retr.search(q, 6)
                wv, wi = oracle[res.version]
                if not (np.array_equal(res.scores, wv)
                        and np.array_equal(res.indices, wi)):
                    bad.append(f"version {res.version} result != its oracle")
                    stop.set()
                n += 1
        except BaseException as e:  # a crash must fail the test, not pass it
            bad.append(f"worker raised: {e!r}")
            stop.set()
        finally:
            done.append(n)

    threads = [threading.Thread(target=worker) for _ in range(3)]
    for t in threads:
        t.start()
    cluster.push(ads.namespace(keys), full2, unpin=False)
    v2 = pub.publish()
    after = retr.roll_forward()
    stop.set()
    for t in threads:
        t.join()
    assert not bad, bad[0]
    assert sum(done) > 0, "workers never completed a search"
    assert after == v2 == 2 and retr.version == 2
    assert retr.counters["retrieval_rolls"] == 1
    # post-roll searches score the new corpus
    res = retr.search(q, 6)
    np.testing.assert_array_equal(res.scores, oracle[2][0])
    # rolling to the version already bound is a no-op
    assert retr.roll_forward() == 2 and retr.counters["retrieval_rolls"] == 1
    assert retr.counters["retrieval_index_builds"] == 2


def test_retention_refs_survive_compaction_until_close(setup):
    """The engine's own refs (not the publisher's) keep the bound version's
    files readable across training-side compaction: version-pinned rerank
    lookups go to disk through the pinned view."""
    cluster, client, pub, keys, rows = setup
    retr = _engine(client, pub, retain_cluster=cluster)
    rng = np.random.default_rng(10)
    q = _dyadic(rng, (3, DIM))
    res = retr.search(q, 5)
    pub.release(1)  # drop the publisher's refs; the engine's remain
    for n in cluster.nodes:
        n.ssd.compact(force=True)
    uk = rng.integers(0, N_ADS, size=(3, 4)).astype(np.uint64)
    so = np.zeros((3, 4), np.int32)
    rr = retr.rerank(res, uk, so, np.ones((3, 4), bool), n_slots=2)
    assert rr.valid.all()  # v1 files still readable through the pinned view
    retr.close()
    for n in cluster.nodes:
        n.ssd.compact(force=True)
    assert sum(n.ssd.n_retained_orphans for n in cluster.nodes) == 0


def test_rerank_matches_manual_rescoring(setup):
    cluster, client, pub, keys, rows = setup
    retr = _engine(client, pub)
    rng = np.random.default_rng(11)
    q = _dyadic(rng, (5, DIM))
    res = retr.search(q, 10)
    uk = rng.integers(0, N_ADS, size=(5, 6)).astype(np.uint64)
    so = rng.integers(0, 4, size=(5, 6)).astype(np.int32)
    va = rng.random((5, 6)) < 0.8
    rr = retr.rerank(res, uk, so, va, n_slots=4)
    user_vec = np.einsum("bn,bnd->bd", va.astype(np.float32), rows[uk])
    inter = np.einsum("qd,qkd->qk", user_vec, rows[res.indices])
    final = res.scores + inter
    for b in range(5):
        order = np.lexsort((res.indices[b], -final[b]))
        np.testing.assert_allclose(rr.scores[b], final[b][order], rtol=1e-6)
        np.testing.assert_array_equal(rr.indices[b], res.indices[b][order])
    assert rr.version == res.version
    assert retr.counters["retrieval_reranks"] == 1


def test_lookup_at_pins_version_across_roll(setup):
    cluster, client, pub, keys, rows = setup
    eng = client.serving_view(snapshots=pub, cache_rows=1024)
    v1_view = eng.source.acquire()
    ads = client.registry.get("ads")
    full2 = np.zeros((N_ADS, 2 * DIM), np.float32)
    full2[:, :DIM] = rows * 3.0
    cluster.push(ads.namespace(keys), full2, unpin=False)
    pub.publish()
    eng.roll_forward()
    # latest view serves v2 rows; the pinned view still serves v1's
    np.testing.assert_array_equal(eng.lookup("ads", keys[:8]), rows[:8] * 3.0)
    np.testing.assert_array_equal(
        eng.lookup_at("ads", keys[:8], view=v1_view), rows[:8]
    )


def test_retrieval_counters_registered():
    for name in RETRIEVAL_COUNTER_NAMES:
        assert name in KNOWN_COUNTERS
    c = Counters(strict=True)
    c.inc("retrieval_searches")  # strict mode accepts registered names
    assert c["retrieval_searches"] == 1
