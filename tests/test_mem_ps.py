"""MEM-PS cache: LRU+LFU semantics, pinning, dirty-flush correctness."""

import numpy as np
import pytest

from repro.core.mem_ps import MemParameterServer
from repro.core.ssd_ps import SSDParameterServer


@pytest.fixture
def stack(tmp_path):
    ssd = SSDParameterServer(str(tmp_path), dim=4, file_capacity=16)
    mem = MemParameterServer(ssd, capacity=32)
    return ssd, mem


def test_pull_push_roundtrip(stack):
    ssd, mem = stack
    keys = np.arange(10, dtype=np.uint64)
    a = mem.pull(keys)
    mem.push(keys, a + 1)
    np.testing.assert_allclose(mem.pull(keys, pin=False), a + 1)


def test_eviction_flushes_dirty_rows(stack):
    ssd, mem = stack
    keys = np.arange(16, dtype=np.uint64)
    vals = mem.pull(keys)
    mem.push(keys, vals * 0 + 7.0)
    # churn far more rows than capacity to force eviction of the dirty ones
    for s in range(100, 100 + 10 * 32, 32):
        mem.pull(np.arange(s, s + 16, dtype=np.uint64), pin=False)
    mem.flush_all()
    np.testing.assert_allclose(ssd.read_batch(keys), np.full((16, 4), 7.0))


def test_pinned_rows_survive_pressure(stack):
    ssd, mem = stack
    pinned = np.arange(8, dtype=np.uint64)
    vals = mem.pull(pinned, pin=True)  # stays pinned
    for s in range(1000, 1000 + 20 * 16, 16):
        mem.pull(np.arange(s, s + 8, dtype=np.uint64), pin=False)
    got = mem.pull(pinned, pin=False)
    np.testing.assert_allclose(got, vals)
    assert mem.stats.hits >= 8  # pinned rows were cache hits, not re-reads
    mem.unpin(pinned)


def test_cache_exhaustion_raises(stack):
    ssd, mem = stack
    with pytest.raises(MemoryError):
        mem.pull(np.arange(100, dtype=np.uint64), pin=True)  # 100 > capacity 32


def test_hit_rate_on_zipf_traffic(tmp_path):
    ssd = SSDParameterServer(str(tmp_path), dim=4, file_capacity=64)
    mem = MemParameterServer(ssd, capacity=256)
    rng = np.random.default_rng(0)
    for _ in range(50):
        ranks = (rng.zipf(1.2, size=128) - 1) % 4096
        mem.pull(np.unique(ranks.astype(np.uint64)), pin=False)
    assert mem.stats.hit_rate > 0.3  # hot keys get captured (paper Fig 4c)


def test_all_pinned_raises_until_unpin(stack):
    """Working set above capacity with every row pinned must raise the
    documented MemoryError; unpin must make the cache usable again."""
    ssd, mem = stack
    resident = np.arange(32, dtype=np.uint64)
    mem.pull(resident, pin=True)  # fill the cache, all pinned
    with pytest.raises(MemoryError):
        mem.pull(np.arange(100, 108, dtype=np.uint64), pin=True)
    with pytest.raises(MemoryError):  # fresh pushes need rows too
        mem.push(np.arange(200, 208, dtype=np.uint64), np.zeros((8, 4), np.float32))
    mem.unpin(resident[:8])
    got = mem.pull(np.arange(100, 108, dtype=np.uint64), pin=False)  # progress
    assert got.shape == (8, 4)
    # the still-pinned rows survived the eviction pressure as cache hits
    np.testing.assert_allclose(
        mem.pull(resident[8:], pin=False), mem.pull(resident[8:], pin=False)
    )
    assert mem.stats.hits >= 2 * len(resident[8:])


def test_dirty_row_bounced_through_pending_keeps_update(tmp_path):
    """A dirty row evicted into the write buffer, re-pulled, re-evicted and
    finally flushed must never lose its update (repeatedly bounced)."""
    ssd = SSDParameterServer(str(tmp_path), dim=4, file_capacity=8)
    mem = MemParameterServer(ssd, capacity=8, flush_batch=10_000)
    k = np.array([3], dtype=np.uint64)
    v = mem.pull(k)
    mem.push(k, v + 5)
    for bounce in range(4):
        # churn unpinned traffic until k is evicted into _pending
        for s in range(1000 * (bounce + 1), 1000 * (bounce + 1) + 12 * 8, 8):
            mem.pull(np.arange(s, s + 6, dtype=np.uint64), pin=False)
        got = mem.pull(k, pin=False)  # back from the pending buffer
        np.testing.assert_allclose(got, v + 5)
    mem.flush_all()
    np.testing.assert_allclose(ssd.read_batch(k), v + 5)


def test_pending_flush_readback(tmp_path):
    """A dirty row evicted into the write buffer must still read correctly."""
    ssd = SSDParameterServer(str(tmp_path), dim=2, file_capacity=8)
    mem = MemParameterServer(ssd, capacity=8, flush_batch=1000)  # buffer big
    k = np.array([5], dtype=np.uint64)
    v = mem.pull(k)
    mem.push(k, v + 9)
    for s in range(100, 100 + 16 * 8, 8):  # force eviction into _pending
        mem.pull(np.arange(s, s + 4, dtype=np.uint64), pin=False)
    got = mem.pull(k, pin=False)  # must come back from the pending buffer
    np.testing.assert_allclose(got, v + 9)
