"""The paper's technique on an LM: hier-PS embedding == dense embedding.

Trains a reduced LM twice — (a) dense [vocab, d] embedding parameter,
(b) hier_ps working-table path with host renumbering + row updates pushed
through a real PS cluster — and asserts the loss trajectories and final
logits agree. This is the LM analogue of the CTR lossless test.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, replace
from repro.core.hier_ps import HierarchicalPS
from repro.core.node import Cluster
from repro.core.keys import deterministic_init
from repro.models import transformer as T
from repro.train.optim import AdamW
from repro.train.train_step import TrainSettings, make_lm_train_step_hier

ARCH = "yi-9b"
N_STEPS = 5


def _data(cfg, step, B=4, S=8):
    k = jax.random.PRNGKey(100 + step)
    toks = jax.random.randint(k, (B, S + 1), 0, cfg.vocab_size)
    return np.asarray(toks[:, :-1]), np.asarray(toks[:, 1:])


def test_hier_lm_equals_flat_embedding(tmp_path):
    cfg = get_smoke_config(ARCH)  # hier_ps
    settings = TrainSettings(optimizer=AdamW(lr=1e-3, clip_norm=0.0), microbatches=1, row_lr=0.05)
    step = jax.jit(make_lm_train_step_hier(cfg, settings))

    # shared backbone init
    params = T.init(cfg, jax.random.PRNGKey(0))

    # ---- path A: flat "table" = all vocab rows resident (working set = vocab)
    flat_table = jnp.asarray(
        deterministic_init(np.arange(cfg.vocab_size, dtype=np.uint64), cfg.d_model, 0.01)
    )
    flat_accum = jnp.zeros_like(flat_table)
    pa, oa = params, settings.optimizer.init(params)
    losses_a = []
    for i in range(N_STEPS):
        toks, tgts = _data(cfg, i)
        batch = {"tokens": jnp.asarray(toks), "targets": jnp.asarray(tgts)}
        pa, oa, m, flat_table, flat_accum = step(pa, oa, batch, flat_table, flat_accum)
        losses_a.append(float(m["loss"]))

    # ---- path B: true PS pull/push per batch (dedup + renumber + SSD churn)
    cl = Cluster(2, str(tmp_path / "ps"), dim=cfg.d_model * 2,
                 cache_capacity=256, file_capacity=64, init_cols=cfg.d_model)
    ps = HierarchicalPS(cl, cfg.d_model, cfg.d_model)
    pb, ob = params, settings.optimizer.init(params)
    losses_b = []
    for i in range(N_STEPS):
        toks, tgts = _data(cfg, i)
        ws = ps.prepare_batch(toks.astype(np.uint64))
        batch = {"tokens": jnp.asarray(ws.slots), "targets": jnp.asarray(tgts)}
        pb, ob, m, new_t, new_acc = step(pb, ob, batch, jnp.asarray(ws.params), jnp.asarray(ws.opt_state))
        ps.complete_batch(ws, np.asarray(new_t), np.asarray(new_acc))
        losses_b.append(float(m["loss"]))

    np.testing.assert_allclose(losses_a, losses_b, rtol=1e-4, atol=1e-5)
    # final embedding rows identical
    cl.flush_all()
    rows = cl.pull(np.arange(cfg.vocab_size, dtype=np.uint64), pin=False)[:, : cfg.d_model]
    np.testing.assert_allclose(rows, np.asarray(flat_table), atol=2e-5, rtol=1e-4)
