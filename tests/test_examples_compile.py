"""API migrations must not silently break the examples/ scripts: every
example byte-compiles AND resolves its repro imports (the CI workflow also
byte-compiles them as a separate step)."""

import ast
import importlib
import pathlib
import py_compile

import pytest

EXAMPLES = sorted(
    (pathlib.Path(__file__).parent.parent / "examples").glob("*.py")
)


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.name)
def test_example_byte_compiles(path):
    py_compile.compile(str(path), doraise=True)


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.name)
def test_example_repro_imports_resolve(path):
    """Every ``from repro.x import y`` in an example names a real attribute
    — catches renamed/removed API symbols without running the example."""
    tree = ast.parse(path.read_text())
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module and node.module.startswith("repro"):
            mod = importlib.import_module(node.module)
            for alias in node.names:
                try:  # the name may be a submodule rather than an attribute
                    importlib.import_module(f"{node.module}.{alias.name}")
                    continue
                except ImportError:
                    pass
                assert hasattr(mod, alias.name), (
                    f"{path.name}: {node.module}.{alias.name} does not exist"
                )
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith("repro"):
                    importlib.import_module(alias.name)
