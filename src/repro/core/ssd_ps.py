"""SSD-PS: log-structured, file-granularity parameter store (paper Section 6).

Design points taken directly from the paper / Appendix E:

* Parameters are grouped into immutable **parameter files**; a file is the
  SSD I/O unit. Reading any requested key reads its whole file (bandwidth
  over random access; file size is tunable).
* Updates are **never in-place**: updated rows are chunked and written
  sequentially as *new* files; the in-memory parameter->file mapping is then
  repointed and the old copies become stale.
* Each file keeps a **stale counter** (maintained on mapping updates, no file
  reads needed). A background/regular **compaction** merges files whose stale
  fraction exceeds 50%, which bounds total disk usage at <= 2x live bytes
  (1/0.5), plus one in-flight write batch.
* The same never-in-place property makes **snapshot publishing repointing,
  not copying** (DESIGN.md §7): :meth:`publish_manifest` captures the
  key->file map and takes a per-file *retention reference* on every file it
  mentions. Compaction still merges retained files, but parks their paths in
  an orphan set instead of deleting them; :meth:`release_files` drops the
  references and removes any orphan that reached zero. A published version
  therefore stays readable for as long as someone holds it, at zero write
  cost to the trainer.
* The key->file map lives in memory (a descriptor is a few bytes/key; a node
  only holds its key shard). It is a batched open-addressing ``U64Index``
  (DESIGN.md §5) storing ``file_id * file_capacity + row_in_file`` packed in
  one int64, so read/write/compaction probe and repoint whole batches with
  numpy ops — the only Python loops left iterate over *files* (the I/O
  unit), never over keys.

Values are float32 rows of fixed width ``dim`` (embedding row [+ optimizer
slots] — exactly the paper's fixed-size-value observation that lets the
serialized bucket fit SSD blocks with no I/O amplification).

File layout (little-endian): header  <u32 magic, u32 n_rows, u32 dim,
u32 crc32(payload)> followed by the payload: n_rows u64 keys then
n_rows*dim f32 values. The CRC makes a dropped, truncated, or bit-flipped
parameter file *detectable* (DESIGN.md §9): a failed read raises
:class:`SSDCorruptionError` and the file is **quarantined** — its index
entries are purged and its live rows are either healed exactly from a
published snapshot + the cluster redo log (``heal_fn``, installed by
``Cluster``) or degraded to the deterministic missing-row initializer.
Garbage is never served.
"""

from __future__ import annotations

import os
import struct
import threading
import time
import zlib
from dataclasses import dataclass, field

import numpy as np

from repro.core.hash_index import U64Index
from repro.core.keys import deterministic_init
from repro.metrics import Counters

_MAGIC = 0x55D9A5
_HEADER = struct.Struct("<IIII")


class SSDCorruptionError(RuntimeError):
    """A parameter file failed its integrity check (missing / truncated /
    checksum mismatch). Carries the file id so the reader can quarantine."""

    def __init__(self, file_id: int, path: str, reason: str):
        super().__init__(f"corrupt parameter file {path}: {reason}")
        self.file_id = file_id
        self.path = path
        self.reason = reason


@dataclass
class FileMeta:
    file_id: int
    path: str
    n_rows: int
    n_stale: int = 0

    @property
    def stale_frac(self) -> float:
        return self.n_stale / max(1, self.n_rows)


@dataclass
class SSDStats:
    bytes_written: int = 0
    bytes_read: int = 0
    rows_read: int = 0
    rows_requested: int = 0
    files_written: int = 0
    files_read: int = 0
    compactions: int = 0
    compaction_time: float = 0.0
    read_time: float = 0.0
    write_time: float = 0.0

    @property
    def read_amplification(self) -> float:
        """rows read from disk / rows actually requested (paper's I/O amp)."""
        return self.rows_read / max(1, self.rows_requested)


class SSDParameterServer:
    """One node's materialized parameter shard on local SSD."""

    def __init__(
        self,
        directory: str,
        dim: int,
        file_capacity: int = 4096,
        compact_stale_frac: float = 0.5,
        init_scale: float = 0.01,
        init_cols: int | None = None,
        auto_compact: bool = True,
        lock: bool = True,
        initializer=None,
        counters: Counters | None = None,
    ):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self.dim = dim
        self.file_capacity = int(file_capacity)
        self.compact_stale_frac = float(compact_stale_frac)
        self.init_scale = init_scale
        # rows for unseen keys: random-init the first init_cols columns
        # (embedding), zero the rest (optimizer slots ride along in the row)
        self.init_cols = dim if init_cols is None else int(init_cols)
        # optional schema-aware override: a callable (keys) -> [n, dim] rows
        # (installed by the cluster's TableRegistry for multi-table hosting)
        self.initializer = initializer
        self.auto_compact = auto_compact
        self._next_file_id = 0
        self.files: dict[int, FileMeta] = {}
        # key -> file_id * file_capacity + row_in_file (packed int64)
        self.index = U64Index(4 * self.file_capacity)
        # snapshot retention: path -> live reference count, plus the paths
        # compaction already dropped from `files` but must keep on disk
        self._file_refs: dict[str, int] = {}
        self._orphaned: set[str] = set()
        self.stats = SSDStats()
        # fault-model wiring (DESIGN.md §9): quarantine/heal event counters
        # (a Cluster passes its shared fault counters in), the exact-heal
        # callback (keys -> rows or None) installed by the owning cluster,
        # and an optional armed FaultInjector observing file reads
        self.counters = counters if counters is not None else Counters(
            "ssd_files_quarantined", "ssd_rows_quarantined",
            "ssd_rows_healed", "ssd_rows_reinit",
        )
        self.heal_fn = None
        self.faults = None
        self._in_compact = False
        self._lock = threading.RLock()

    # ------------------------------------------------------------------ io
    def _file_path(self, file_id: int) -> str:
        return os.path.join(self.dir, f"params_{file_id:08d}.bin")

    def _write_file(self, keys: np.ndarray, values: np.ndarray) -> int:
        fid = self._next_file_id
        self._next_file_id += 1
        path = self._file_path(fid)
        t0 = time.perf_counter()
        kb = np.ascontiguousarray(keys, dtype=np.uint64).tobytes()
        vb = np.ascontiguousarray(values, dtype=np.float32).tobytes()
        crc = zlib.crc32(vb, zlib.crc32(kb)) & 0xFFFFFFFF
        with open(path, "wb") as f:
            f.write(_HEADER.pack(_MAGIC, len(keys), self.dim, crc))
            f.write(kb)
            f.write(vb)
        self.stats.write_time += time.perf_counter() - t0
        nbytes = _HEADER.size + keys.nbytes + values.nbytes
        self.stats.bytes_written += nbytes
        self.stats.files_written += 1
        self.files[fid] = FileMeta(fid, path, len(keys))
        return fid

    def _read_file(self, fid: int) -> tuple[np.ndarray, np.ndarray]:
        """Whole-file read with integrity verification. Any failure —
        missing file (dropped), short read (truncated), header or CRC
        mismatch (bit rot) — raises :class:`SSDCorruptionError`; the file
        is never partially served."""
        meta = self.files[fid]
        if self.faults is not None:
            self.faults.on_file_read(self, meta)
        t0 = time.perf_counter()
        try:
            with open(meta.path, "rb") as f:
                head = f.read(_HEADER.size)
                if len(head) < _HEADER.size:
                    raise SSDCorruptionError(fid, meta.path, "truncated header")
                magic, n_rows, dim, crc = _HEADER.unpack(head)
                if magic != _MAGIC:
                    raise SSDCorruptionError(fid, meta.path, "bad magic")
                if dim != self.dim or n_rows != meta.n_rows:
                    raise SSDCorruptionError(
                        fid, meta.path,
                        f"header mismatch (dim={dim}, n_rows={n_rows})",
                    )
                payload = f.read(n_rows * (8 + 4 * dim))
        except OSError as e:  # FileNotFoundError, EIO, ...
            raise SSDCorruptionError(fid, meta.path, f"unreadable: {e}") from e
        if len(payload) != n_rows * (8 + 4 * dim):
            raise SSDCorruptionError(fid, meta.path, "truncated payload")
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            raise SSDCorruptionError(fid, meta.path, "checksum mismatch")
        keys = np.frombuffer(payload[: 8 * n_rows], dtype=np.uint64)
        values = np.frombuffer(payload[8 * n_rows :], dtype=np.float32)
        self.stats.read_time += time.perf_counter() - t0
        self.stats.bytes_read += _HEADER.size + keys.nbytes + values.nbytes
        self.stats.files_read += 1
        self.stats.rows_read += n_rows
        return keys, values.reshape(n_rows, dim)

    # ------------------------------------------------------------ interface
    def write_batch(self, keys: np.ndarray, values: np.ndarray) -> None:
        """Dump updated rows as new sequential files (paper: never in-place)."""
        keys = np.asarray(keys, dtype=np.uint64)
        values = np.asarray(values, dtype=np.float32)
        assert values.shape == (len(keys), self.dim)
        if len(keys) == 0:
            return
        with self._lock:
            for start in range(0, len(keys), self.file_capacity):
                sl = slice(start, start + self.file_capacity)
                k, v = keys[sl], values[sl]
                fid = self._write_file(k, v)
                # repoint mapping (batched); old copies become stale
                uniq, first, inverse, cnt = np.unique(
                    k, return_index=True, return_inverse=True, return_counts=True
                )
                old = self.index.lookup(uniq)
                had = old >= 0
                if had.any():
                    for f, c in zip(*np.unique(old[had] // self.file_capacity, return_counts=True)):
                        self.files[int(f)].n_stale += int(c)
                # duplicate keys within one file: all but the last row stale
                self.files[fid].n_stale += int((cnt - 1).sum())
                last = np.empty(len(uniq), dtype=np.int64)
                last[inverse] = np.arange(len(k))
                self.index.set(uniq, fid * self.file_capacity + last)
            if self.auto_compact and not self._in_compact:
                # quarantine healing writes from inside a compaction read
                # path; re-entering compact there would recurse
                self.compact()

    def read_batch(self, keys: np.ndarray) -> np.ndarray:
        """Gather rows for ``keys``; whole-file reads; missing keys get the
        deterministic per-key initialization (fresh parameters).

        A file that fails its integrity check mid-gather is quarantined
        (index purged, live rows healed exactly via ``heal_fn`` or left to
        re-initialize) and the gather retries — each quarantine removes one
        file, so the loop terminates. The caller never sees garbage rows
        and never sees the corruption as an exception."""
        keys = np.asarray(keys, dtype=np.uint64)
        with self._lock:
            self.stats.rows_requested += len(keys)
            while True:
                try:
                    return self._gather_locked(keys)
                except SSDCorruptionError as e:
                    self._quarantine_locked(e.file_id)

    def _gather_locked(self, keys: np.ndarray) -> np.ndarray:
        out = np.empty((len(keys), self.dim), dtype=np.float32)
        locs = self.index.lookup(keys)
        found = np.nonzero(locs >= 0)[0]
        if found.size:
            floc = locs[found]
            order = np.argsort(floc, kind="stable")  # groups by file id
            floc, found = floc[order], found[order]
            fids = floc // self.file_capacity
            starts = np.concatenate([[0], np.nonzero(np.diff(fids))[0] + 1, [len(fids)]])
            for s, e in zip(starts[:-1], starts[1:]):
                _, vals = self._read_file(int(fids[s]))  # file = I/O unit
                out[found[s:e]] = vals[floc[s:e] % self.file_capacity]
        missing = locs < 0
        if missing.any():
            out[missing] = self.init_rows(keys[missing])
        return out

    def init_rows(self, keys: np.ndarray) -> np.ndarray:
        """Deterministic fresh-parameter rows for never-seen keys (also the
        degraded-serving fallback for unhealable quarantined rows)."""
        keys = np.asarray(keys, dtype=np.uint64)
        if self.initializer is not None:
            return np.asarray(self.initializer(keys), dtype=np.float32)
        fresh = np.zeros((len(keys), self.dim), dtype=np.float32)
        fresh[:, : self.init_cols] = deterministic_init(
            keys, self.init_cols, self.init_scale
        )
        return fresh

    # ---------------------------------------------------------- quarantine
    def quarantine_file(self, file_id: int) -> int:
        """Public entry (tests/operators): quarantine one parameter file.
        Returns the number of live rows that were lost from the file."""
        with self._lock:
            return self._quarantine_locked(file_id)

    def _quarantine_locked(self, file_id: int) -> int:
        """Pull a corrupt file out of service: purge its index entries,
        delete it from disk, then restore its live rows — exactly, via
        ``heal_fn`` (published snapshot + redo-log replay, wired by the
        Cluster), or degraded, by leaving them to the missing-row
        initializer. Counter names follow the DESIGN.md §9 fault model."""
        meta = self.files.pop(file_id, None)
        if meta is None:
            return 0
        all_keys, all_locs = self.index.items()
        lost = all_keys[all_locs // self.file_capacity == file_id]
        if lost.size:
            self.index.delete(lost)
        self.counters.inc("ssd_files_quarantined")
        self.counters.inc("ssd_rows_quarantined", int(lost.size))
        self._orphaned.discard(meta.path)
        self._file_refs.pop(meta.path, None)  # corrupt: no version can use it
        try:
            os.remove(meta.path)
        except OSError:
            pass
        if not lost.size:
            return 0
        healed = None
        if self.heal_fn is not None:
            try:
                healed = self.heal_fn(lost)
            except SSDCorruptionError:
                raise  # a snapshot view hit corruption too: let reader retry
            except Exception:
                # heal source unavailable -> degraded (deterministic reinit)
                # serving; counted so the degradation is never silent
                healed = None
                self.counters.inc("ssd_heal_degraded")
        if healed is not None:
            self.write_batch(lost, np.asarray(healed, dtype=np.float32))
            self.counters.inc("ssd_rows_healed", int(lost.size))
        else:
            # rows fall back to the deterministic initializer on next read
            self.counters.inc("ssd_rows_reinit", int(lost.size))
        return int(lost.size)

    def contains(self, key: int) -> bool:
        return bool(self.index.contains(np.asarray([key], dtype=np.uint64))[0])

    # ---------------------------------------------------------- compaction
    def compact(self, force: bool = False) -> int:
        """Merge files whose stale fraction exceeds the threshold.

        Returns number of files merged. Only >50%-stale files are eligible
        (paper threshold), bounding disk usage at <=2x live rows.
        """
        with self._lock:
            victims = [
                m
                for m in self.files.values()
                if m.n_rows > 0 and (force or m.stale_frac > self.compact_stale_frac) and m.n_stale > 0
            ]
            if not victims:
                return 0
            t0 = time.perf_counter()
            self._in_compact = True
            try:
                live_keys: list[np.ndarray] = []
                live_vals: list[np.ndarray] = []
                for meta in victims:
                    try:
                        fkeys, fvals = self._read_file(meta.file_id)
                    except SSDCorruptionError:
                        # victim turned out corrupt: quarantine it (heals or
                        # degrades its live rows) instead of aborting the
                        # whole compaction
                        self._quarantine_locked(meta.file_id)
                        continue
                    current = meta.file_id * self.file_capacity + np.arange(len(fkeys))
                    mask = self.index.lookup(fkeys) == current
                    if mask.any():
                        live_keys.append(fkeys[mask])
                        live_vals.append(fvals[mask])
                # write survivors as fresh files and erase victims
                if live_keys:
                    all_k = np.concatenate(live_keys)
                    all_v = np.concatenate(live_vals)
                    for start in range(0, len(all_k), self.file_capacity):
                        sl = slice(start, start + self.file_capacity)
                        k, v = all_k[sl], all_v[sl]
                        fid = self._write_file(k, v)
                        self.index.set(k, fid * self.file_capacity + np.arange(len(k)))
                for meta in victims:
                    if meta.file_id not in self.files:
                        continue  # quarantined above: already gone
                    if self._file_refs.get(meta.path, 0) > 0:
                        # a published snapshot still points here: park the path
                        # until every referencing version is released
                        self._orphaned.add(meta.path)
                    else:
                        try:
                            os.remove(meta.path)
                        except FileNotFoundError:
                            pass
                    del self.files[meta.file_id]
            finally:
                self._in_compact = False
            self.stats.compactions += 1
            self.stats.compaction_time += time.perf_counter() - t0
            return len(victims)

    # -------------------------------------------------------------- info
    @property
    def n_live_rows(self) -> int:
        return len(self.index)

    @property
    def n_disk_rows(self) -> int:
        return sum(m.n_rows for m in self.files.values())

    @property
    def disk_bytes(self) -> int:
        return sum(_HEADER.size + m.n_rows * (8 + 4 * self.dim) for m in self.files.values())

    def space_amplification(self) -> float:
        return self.n_disk_rows / max(1, self.n_live_rows)

    # --------------------------------------------------- snapshot retention
    def publish_manifest(self) -> dict:
        """Manifest + atomic retention of every file it references.

        Capturing the map and taking the references under one lock hold is
        what makes publishing safe against a concurrent ``write_batch`` ->
        auto-``compact`` deleting a just-referenced file. The returned dict
        adds ``retained_paths`` — the caller (SnapshotPublisher) passes it
        back to :meth:`release_files` when the version is retired.
        """
        with self._lock:
            m = self.manifest()
            paths = [meta.path for meta in self.files.values()]
            for p in paths:
                self._file_refs[p] = self._file_refs.get(p, 0) + 1
            m["retained_paths"] = paths
            return m

    def retain_files(self, paths: "list[str]") -> None:
        """Re-take retention references on ``paths`` (publisher re-attach
        after Cluster.restore — refs live in SSD instances, so a restored
        instance starts with zero and would let compaction delete files a
        published version still references). Paths the restored manifest no
        longer lists as active files are parked as orphans so a later
        release still reclaims them."""
        with self._lock:
            active = {m.path for m in self.files.values()}
            for p in paths:
                self._file_refs[p] = self._file_refs.get(p, 0) + 1
                if p not in active and os.path.exists(p):
                    self._orphaned.add(p)

    def release_files(self, paths: "list[str]") -> None:
        """Drop one retention reference per path; orphans at zero are
        deleted from disk (files still live in ``self.files`` just lose
        the reference and stay)."""
        with self._lock:
            for p in paths:
                n = self._file_refs.get(p, 0) - 1
                if n > 0:
                    self._file_refs[p] = n
                else:
                    self._file_refs.pop(p, None)
                    if p in self._orphaned:
                        self._orphaned.discard(p)
                        try:
                            os.remove(p)
                        except FileNotFoundError:
                            pass

    def is_retained(self, path: str) -> bool:
        """True if a published snapshot holds a retention ref on ``path``."""
        with self._lock:
            return self._file_refs.get(path, 0) > 0

    @property
    def n_retained_orphans(self) -> int:
        """Stale-but-retained files currently parked on disk."""
        with self._lock:
            return len(self._orphaned)

    # ------------------------------------------------------- checkpointing
    def manifest(self) -> dict:
        keys, locs = self.index.items()
        return {
            "dim": self.dim,
            "file_capacity": self.file_capacity,
            "next_file_id": self._next_file_id,
            "files": {fid: (m.path, m.n_rows, m.n_stale) for fid, m in self.files.items()},
            "key_to_file": {
                int(k): (int(l) // self.file_capacity, int(l) % self.file_capacity)
                for k, l in zip(keys.tolist(), locs.tolist())
            },
        }

    @classmethod
    def from_manifest(cls, directory: str, manifest: dict, **kw) -> "SSDParameterServer":
        ps = cls(directory, manifest["dim"], manifest["file_capacity"], **kw)
        ps._next_file_id = manifest["next_file_id"]
        ps.files = {
            int(fid): FileMeta(int(fid), path, n_rows, n_stale)
            for fid, (path, n_rows, n_stale) in manifest["files"].items()
        }
        k2f = manifest["key_to_file"]
        keys = np.fromiter((int(k) for k in k2f), dtype=np.uint64, count=len(k2f))
        locs = np.fromiter(
            (int(f) * ps.file_capacity + int(r) for f, r in k2f.values()),
            dtype=np.int64,
            count=len(k2f),
        )
        ps.index.insert(keys, locs)
        return ps

    def iter_live(self, chunk: int = 65536):
        """Yield (keys, values) over all live rows (for reshard/checkpoint).

        Corruption-safe: a corrupt file is quarantined in place and, if it
        healed, its rows land in a *new* file — so iteration re-scans for
        unvisited file ids each round instead of snapshotting the file list
        up front (a snapshot would silently skip the healed rows)."""
        with self._lock:
            visited: set[int] = set()
            while True:
                pending = [fid for fid in self.files if fid not in visited]
                if not pending:
                    return
                for fid in pending:
                    visited.add(fid)
                    if fid not in self.files:
                        continue  # merged away by a heal-triggered compaction
                    try:
                        fkeys, fvals = self._read_file(fid)
                    except SSDCorruptionError:
                        self._quarantine_locked(fid)
                        continue
                    current = fid * self.file_capacity + np.arange(len(fkeys))
                    mask = self.index.lookup(fkeys) == current
                    if mask.any():
                        yield fkeys[mask], fvals[mask]
