"""Logical multi-node PS cluster with a simulated network (paper Section 5).

Each node owns one shard of the key space (modulo partition) with its own
MEM-PS + SSD-PS stack. A requesting node pulls local keys from its own
MEM-PS/SSD-PS and remote keys from peer MEM-PS "through the network"; remote
updates are NOT pushed back (paper: the remote node's own GPUs hold the
synchronized copy and its MEM-PS pulls from them) — in our adaptation the
synchronized updates are applied on the *owner* node by the orchestrator
after the device all-reduce, which preserves exactly the same semantics.

The container has one host, so nodes are in-process objects; the NIC is a
latency+bandwidth model whose virtual time is recorded (and optionally slept)
so Fig-4b/5b style benchmarks are meaningful. All protocols (partitioned
pull, failure, reshard) are real code paths.
"""

from __future__ import annotations

import dataclasses
import os
import shutil
import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.compression import sparse_decode, sparse_encode
from repro.core.keys import key_to_node, partition_by_owner
from repro.core.mem_ps import MemParameterServer
from repro.core.recovery import RedoLog, apply_entries
from repro.core.ssd_ps import SSDParameterServer
from repro.core.tables import TableRegistry
from repro.metrics import Counters


@dataclass
class NetworkModel:
    """Simulated NIC: per-message latency + bandwidth (default ~100Gb RDMA).

    ``wire_quantize=True`` opts remote *serving-style* reads (``pull`` with
    ``pin=False``) into the int8 row-sparse wire format of
    :mod:`repro.core.compression`; bytes-on-wire then count the encoded
    packet, and ``quantize_bytes_saved`` feeds the Fig-4b accounting.
    Pinned training pulls stay exact. Training *pushes* may cross encoded
    when the engine's training wire is on (``Cluster.push(packet=...)``):
    the values applied are the exact dequantized rows, but the NIC meters
    the encoded packet — latency, ``bytes_moved`` and NIC_STALL faults all
    see the bytes actually moved, and ``push_bytes_saved`` records the win.
    """

    latency_s: float = 5e-6
    bandwidth_gbps: float = 100.0
    real_sleep: bool = False
    time_scale: float = 1.0  # scale factor applied when sleeping
    wire_quantize: bool = False  # int8 wire format for serving reads

    virtual_time: float = 0.0
    bytes_moved: int = 0
    messages: int = 0
    quantized_messages: int = 0
    quantize_bytes_saved: int = 0  # raw f32 bytes minus encoded packet bytes
    push_enc_messages: int = 0  # training pushes that crossed encoded
    push_bytes_saved: int = 0  # raw push bytes minus encoded packet bytes
    stalls: int = 0  # NIC_STALL faults absorbed (DESIGN.md §9)
    stall_time: float = 0.0  # extra virtual seconds those stalls added
    faults: object = field(default=None, compare=False, repr=False)

    def transfer(self, nbytes: int) -> float:
        dt = self.latency_s + nbytes * 8.0 / (self.bandwidth_gbps * 1e9)
        if self.faults is not None:
            extra = self.faults.on_transfer(self)
            if extra > 0.0:
                dt += extra
                self.stalls += 1
                self.stall_time += extra
        self.virtual_time += dt
        self.bytes_moved += nbytes
        self.messages += 1
        if self.real_sleep:
            time.sleep(dt * self.time_scale)
        return dt

    def reply(self, keys: np.ndarray, vals: np.ndarray, serving: bool) -> np.ndarray:
        """Account one remote reply and return the rows as the requester
        sees them: with ``wire_quantize`` on and a *serving-style* read
        (``serving=True``), the reply crosses the wire int8 row-sparse and
        the requester gets the decoded (lossy) rows; training replies stay
        exact f32. One implementation serves both the training cluster's
        pull and the snapshot ServingCluster's — the Fig-4b byte accounting
        cannot diverge between them."""
        if self.wire_quantize and serving:
            pkt = sparse_encode(keys, vals, quantize=True)
            # the reply resends values only — the keys crossed the wire in
            # the request message the caller already metered; charging
            # pkt.nbytes here double-counted 8 B/row of key traffic
            self.transfer(pkt.payload_nbytes)
            self.quantized_messages += 1
            self.quantize_bytes_saved += max(0, vals.nbytes - pkt.payload_nbytes)
            return sparse_decode(pkt)[1]
        self.transfer(vals.nbytes)
        return vals

    def fresh(self) -> "NetworkModel":
        """Same link parameters, zeroed counters (reshard target NIC).
        ``replace`` copies every field by construction — a future parameter
        can't silently revert to its default here."""
        return dataclasses.replace(
            self, virtual_time=0.0, bytes_moved=0, messages=0,
            quantized_messages=0, quantize_bytes_saved=0,
            push_enc_messages=0, push_bytes_saved=0,
            stalls=0, stall_time=0.0,
        )


class NodeDownError(RuntimeError):
    pass


class PSNode:
    """One node: MEM-PS cache over an SSD-PS shard."""

    def __init__(
        self,
        node_id: int,
        base_dir: str,
        dim: int,
        cache_capacity: int = 100_000,
        file_capacity: int = 4096,
        init_scale: float = 0.01,
        init_cols: int | None = None,
    ):
        self.node_id = node_id
        self.dir = os.path.join(base_dir, f"node_{node_id:03d}")
        self.ssd = SSDParameterServer(
            self.dir, dim, file_capacity=file_capacity, init_scale=init_scale,
            init_cols=init_cols,
        )
        self.mem = MemParameterServer(self.ssd, capacity=cache_capacity)
        self.alive = True
        self.faults = None  # armed FaultInjector observing this node's ops

    def pull(self, keys: np.ndarray, pin: bool = True) -> np.ndarray:
        if self.faults is not None:
            self.faults.on_node_op(self, "pull")
        if not self.alive:
            raise NodeDownError(f"node {self.node_id} is down")
        return self.mem.pull(keys, pin=pin)

    def push(self, keys: np.ndarray, values: np.ndarray, unpin: bool = True) -> None:
        if self.faults is not None:
            self.faults.on_node_op(self, "push")
        if not self.alive:
            raise NodeDownError(f"node {self.node_id} is down")
        self.mem.push(keys, values, unpin=unpin)

    def pin(self, keys: np.ndarray) -> None:  # pscheck: ok PS101 RPC shim: pin ownership stays with the Cluster caller
        if self.faults is not None:
            self.faults.on_node_op(self, "pin")
        if not self.alive:
            raise NodeDownError(f"node {self.node_id} is down")
        self.mem.pin(keys)

    def kill(self) -> None:
        """Simulate a node failure: in-memory state is lost."""
        self.alive = False

    def restart(self) -> None:
        """Restart after failure: DRAM cache is cold, SSD manifest rebuilt
        from the checkpointed manifest by the caller (Cluster.restore)."""
        self.mem = MemParameterServer(self.ssd, capacity=self.mem.capacity)
        self.alive = True


class Cluster:
    """N logical PS nodes + the partitioned pull/push protocol."""

    def __init__(
        self,
        n_nodes: int,
        base_dir: str,
        dim: int,
        cache_capacity: int = 100_000,
        file_capacity: int = 4096,
        network: NetworkModel | None = None,
        init_scale: float = 0.01,
        init_cols: int | None = None,
        tables: TableRegistry | None = None,
        redo_rows: int = 0,
        auto_recover: bool = False,
        recover_attempts: int = 3,
        recover_backoff_s: float = 0.005,
    ):
        self.n_nodes = n_nodes
        self.base_dir = base_dir
        self.dim = dim
        # remember construction parameters so restore() can rebuild an
        # identically-configured cluster (resume must not silently revert
        # cache/file capacities or the network model to defaults)
        self.cache_capacity = cache_capacity
        self.file_capacity = file_capacity
        self.init_scale = init_scale
        self.init_cols = init_cols
        self.network = network or NetworkModel()
        self.tables: TableRegistry | None = None
        # ---- fault model state (DESIGN.md §9) -------------------------
        # redo_rows > 0 enables the push redo log (exact node recovery,
        # snapshot healing, live reshard) with auto-flush past that many
        # retained rows; auto_recover turns a dead-owner segment into
        # bounded retry-with-backoff around recover_node() instead of
        # surfacing NodeDownError to the caller
        self.redo: RedoLog | None = RedoLog() if redo_rows else None
        self.redo_rows = int(redo_rows)
        self.auto_recover = bool(auto_recover)
        self.recover_attempts = int(recover_attempts)
        self.recover_backoff_s = float(recover_backoff_s)
        self.fault_counters = Counters(
            "node_recoveries", "rows_replayed",
            "ssd_files_quarantined", "ssd_rows_quarantined",
            "ssd_rows_healed", "ssd_rows_reinit",
        )
        self.recovery_time_s = 0.0
        self._heal_src: "tuple[str, int, int] | None" = None  # (dir, version, redo idx)
        self._heal_pin: int | None = None
        self._heal_view = None  # cached ServingVersion for _heal_src
        # a cluster whose SSD shards started empty can heal exactly from
        # initializer + full redo even before any snapshot is published;
        # restore()/reshard clears this (pre-existing rows aren't derivable)
        self._heal_from_init_ok = True
        self._write_gate = threading.Event()
        self._write_gate.set()
        self.nodes = [
            PSNode(i, base_dir, dim, cache_capacity, file_capacity, init_scale, init_cols)
            for i in range(n_nodes)
        ]
        for node in self.nodes:
            self._wire_node(node)
        if tables is not None:
            self.register_tables(tables)
        self.pull_local_time = 0.0
        self.pull_remote_time = 0.0
        # the SanLock sanitizer (REPRO_SANLOCK=1) asserts total_pins()==0 at
        # test teardown for every cluster; registration is a weakref append
        from repro.analysis import sanlock
        sanlock.register_cluster(self)

    def _wire_node(self, node: PSNode) -> None:
        """Attach the cluster's fault-model plumbing to one node's SSD:
        shared quarantine counters and the exact-heal callback (called on
        restore() too — a rebuilt SSD instance starts unwired)."""
        node.ssd.counters = self.fault_counters
        node.ssd.heal_fn = lambda lost, _node=node: self._heal_rows(_node, lost)

    def register_tables(self, tables: TableRegistry) -> None:
        """Host a set of named tables: installs the registry's schema-aware
        missing-row initializer on every node's SSD-PS (each table's ``emb``
        field gets its own deterministic init; the row tail beyond the
        table's schema width stays zero)."""
        if tables.width > self.dim:
            raise ValueError(
                f"cluster row width {self.dim} < widest table schema {tables.width}"
            )
        self.tables = tables
        init = tables.initializer(self.dim, self.init_scale, self.init_cols)
        for node in self.nodes:
            node.ssd.initializer = init

    # ------------------------------------------------------------ protocol
    def owner_of(self, keys: np.ndarray) -> np.ndarray:
        return key_to_node(keys, self.n_nodes)

    def _partition(self, keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Owner-sort once: (order, bounds) with one contiguous segment per
        node — no per-node boolean-mask scans over the full key set."""
        owners = self.owner_of(keys)
        order, splits = partition_by_owner(keys, owners, self.n_nodes)
        bounds = np.concatenate([[0], splits, [len(keys)]])
        return order, bounds

    def _with_recovery(self, node_id: int, op):
        """Run one per-node segment op. A dead owner raises
        :class:`NodeDownError` — never a silent skip returning
        uninitialized rows. With ``auto_recover`` the segment instead gets
        bounded retry-with-backoff around :meth:`recover_node`; the error
        still surfaces once the attempts are spent or recovery itself is
        impossible (no redo log)."""
        attempt = 0
        while True:
            try:
                return op()
            except NodeDownError:
                if not self.auto_recover or attempt >= self.recover_attempts:
                    raise
                time.sleep(self.recover_backoff_s * (2.0 ** attempt))
                attempt += 1
                self.recover_node(node_id)

    def pull(self, keys: np.ndarray, requester: int = 0, pin: bool = True) -> np.ndarray:
        """Partitioned pull: local shard from local MEM-PS/SSD-PS, remote
        shards from peer MEM-PS over the (simulated) network.

        Pin-transactional: if a node fails partway (NodeDownError, MEM-PS
        pin pressure), pins taken by the already-served segments — including
        rows a failing MEM-PS allocated before raising — are rolled back, so
        a retried or abandoned pull never strands pinned rows."""
        keys = np.asarray(keys, dtype=np.uint64)
        order, bounds = self._partition(keys)
        sorted_keys = keys[order]
        sorted_out = np.empty((len(keys), self.dim), dtype=np.float32)
        for node_id in range(self.n_nodes):
            lo, hi = int(bounds[node_id]), int(bounds[node_id + 1])
            if lo == hi:
                continue
            t0 = time.perf_counter()
            try:
                vals = self._with_recovery(
                    node_id,
                    lambda n=node_id: self.nodes[n].pull(sorted_keys[lo:hi], pin=pin),
                )
            except BaseException:
                if pin:  # roll back this + every prior segment's pins
                    for nid in range(node_id + 1):
                        l, h = int(bounds[nid]), int(bounds[nid + 1])
                        if l < h and self.nodes[nid].alive:
                            self.nodes[nid].mem.unpin(sorted_keys[l:h])
                raise
            elapsed = time.perf_counter() - t0
            if node_id == requester:
                self.pull_local_time += elapsed
            else:
                # request keys out + rows back over the NIC; unpinned reads
                # are serving-style and may ride the int8 wire (pinned
                # training pulls stay exact)
                self.network.transfer((hi - lo) * 8)
                vals = self.network.reply(sorted_keys[lo:hi], vals, serving=not pin)
                self.pull_remote_time += elapsed
            sorted_out[lo:hi] = vals
        out = np.empty_like(sorted_out)
        out[order] = sorted_out  # one scatter back into request order
        return out

    def push(
        self,
        keys: np.ndarray,
        values: np.ndarray,
        requester: int = 0,
        unpin: bool = True,
        packet=None,
    ) -> None:
        """Partitioned push. ``values`` are always the exact f32 rows to
        apply (with the training wire on, the engine already quantized and
        *dequantized* them, so nodes, the redo log, and recovery replay all
        see precisely the rows the receiver reconstructs). ``packet`` — a
        :class:`repro.core.compression.PushPacket` covering these rows — is
        metering-only: remote segments then charge the NIC the encoded
        segment bytes instead of raw key+f32."""
        if not self._write_gate.wait(timeout=120.0):
            raise RuntimeError("cluster write gate held >120s (pause_writes leak?)")
        keys = np.asarray(keys, dtype=np.uint64)
        values = np.asarray(values, dtype=np.float32)
        if self.redo is not None:
            # logged before any node is touched: a node killed mid-push is
            # recovered by replaying the log, so a partially-applied push
            # still converges to fully-applied after recover_node()
            self.redo.append(keys, values)
        order, bounds = self._partition(keys)
        sorted_keys = keys[order]
        sorted_vals = values[order]
        for node_id in range(self.n_nodes):
            lo, hi = int(bounds[node_id]), int(bounds[node_id + 1])
            if lo == hi:
                continue
            if node_id != requester:
                raw = (hi - lo) * (8 + 4 * self.dim)
                if packet is not None:
                    enc = packet.segment_nbytes(hi - lo)
                    self.network.transfer(enc)
                    self.network.push_enc_messages += 1
                    self.network.push_bytes_saved += max(0, raw - enc)
                else:
                    self.network.transfer(raw)
            self._with_recovery(
                node_id,
                lambda n=node_id, l=lo, h=hi: self.nodes[n].push(
                    sorted_keys[l:h], sorted_vals[l:h], unpin=unpin
                ),
            )
        if (
            self.redo is not None
            and self.redo_rows
            and self.redo.rows_held > self.redo_rows
            and all(n.alive for n in self.nodes)
        ):
            self.flush_all()  # durability point: log prefix becomes droppable

    def pin(self, keys: np.ndarray, requester: int = 0) -> None:
        """Partitioned pin (version-forwarding pin transfer): a successor
        batch takes over eviction pins on rows it received without a pull.
        Remote pins cost one key-sized control message, far below the row
        pull they replace. Pin-transactional like ``pull``: a node failure
        mid-way rolls back the segments already pinned."""
        keys = np.asarray(keys, dtype=np.uint64)
        order, bounds = self._partition(keys)
        sorted_keys = keys[order]
        for node_id in range(self.n_nodes):
            lo, hi = int(bounds[node_id]), int(bounds[node_id + 1])
            if lo == hi:
                continue
            try:
                self._with_recovery(
                    node_id,
                    lambda n=node_id: self.nodes[n].pin(sorted_keys[lo:hi]),
                )
            except BaseException:
                for nid in range(node_id):
                    l, h = int(bounds[nid]), int(bounds[nid + 1])
                    if l < h and self.nodes[nid].alive:
                        self.nodes[nid].mem.unpin(sorted_keys[l:h])
                raise
            if node_id != requester:
                self.network.transfer((hi - lo) * 8)

    def unpin(self, keys: np.ndarray) -> None:
        """Partitioned unpin without a push (abort/drain path)."""
        keys = np.asarray(keys, dtype=np.uint64)
        order, bounds = self._partition(keys)
        sorted_keys = keys[order]
        for node_id in range(self.n_nodes):
            lo, hi = int(bounds[node_id]), int(bounds[node_id + 1])
            if lo < hi and self.nodes[node_id].alive:
                self.nodes[node_id].mem.unpin(sorted_keys[lo:hi])

    def total_pins(self) -> int:
        """Live pin count across nodes (pin-leak regression checks)."""
        return sum(n.mem.total_pins for n in self.nodes if n.alive)

    def ctor_kwargs(self) -> dict:
        """ALL non-positional construction parameters, for restore() and
        elastic.reshard() — rebuilding from a hand-picked subset silently
        reverts any parameter the subset misses to its default."""
        return {
            "cache_capacity": self.cache_capacity,
            "file_capacity": self.file_capacity,
            "network": self.network,
            "init_scale": self.init_scale,
            "init_cols": self.init_cols,
            "tables": self.tables,
            "redo_rows": self.redo_rows,
            "auto_recover": self.auto_recover,
            "recover_attempts": self.recover_attempts,
            "recover_backoff_s": self.recover_backoff_s,
        }

    # ------------------------------------------------------------ lifecycle
    def flush_all(self) -> None:
        all_alive = True
        for n in self.nodes:
            if n.alive:
                n.mem.flush_all()
            else:
                all_alive = False
        if self.redo is not None and all_alive:
            # durability point — but only if every shard actually flushed; a
            # dead node's entries must survive in the log until it recovers
            self.redo.mark_durable()

    def kill_node(self, node_id: int) -> None:
        self.nodes[node_id].kill()

    def alive_nodes(self) -> list[int]:
        return [n.node_id for n in self.nodes if n.alive]

    # ------------------------------------------------- recovery (DESIGN §9)
    def enable_redo(self, max_rows: int = 262_144) -> None:
        """Turn on the push redo log post-construction (the trainer does
        this for ride-through runs). ``max_rows`` bounds retained rows via
        auto-flush; call before the first push for full coverage."""
        if self.redo is None:
            self.redo = RedoLog()
        self.redo_rows = int(max_rows)

    def recover_node(self, node_id: int) -> bool:
        """Exact recovery of a killed node: restart over the intact SSD
        shard, then replay the redo log's owner-filtered suffix in order
        (last writer wins), reconstructing every DRAM-resident update the
        kill destroyed. Raises :class:`NodeDownError` when the redo log is
        disabled — a bare ``restart()`` would silently revert the shard to
        its last flush, which is exactly the corruption this PR removes."""
        node = self.nodes[node_id]
        if node.alive:
            return False
        if self.redo is None:
            raise NodeDownError(
                f"node {node_id} is down and the redo log is disabled; exact "
                "recovery is impossible (enable_redo(), or restore from a "
                "checkpoint)"
            )
        t0 = time.perf_counter()
        node.restart()
        replayed = 0
        for ekeys, evals in self.redo.entries():
            mask = self.owner_of(ekeys) == node_id
            if mask.any():
                seg_k, seg_v = ekeys[mask], evals[mask]
                # replayed rows cross the NIC from the requester's log
                self.network.transfer(len(seg_k) * (8 + 4 * self.dim))
                node.push(seg_k, seg_v, unpin=False)
                replayed += len(seg_k)
        self.fault_counters.inc("node_recoveries")
        self.fault_counters.inc("rows_replayed", replayed)
        self.recovery_time_s += time.perf_counter() - t0
        return True

    def recover_dead_nodes(self) -> list[int]:
        """Recover every dead node; returns the recovered ids."""
        return [
            n.node_id for n in self.nodes if not n.alive and self.recover_node(n.node_id)
        ]

    def pause_writes(self) -> None:
        """Close the write gate: pushes block (reads keep flowing). Used by
        elastic.reshard_live for its delta-replay cutover window."""
        self._write_gate.clear()

    def resume_writes(self) -> None:
        self._write_gate.set()

    def pin_redo(self) -> int | None:
        """Pin the redo log at its current end (heal/reshard cursor)."""
        return self.redo.pin() if self.redo is not None else None

    def release_redo(self, pin_id: int | None) -> None:
        if self.redo is not None and pin_id is not None:
            self.redo.release(pin_id)

    def set_heal_source(self, directory: str, version: int, redo_pin: int | None) -> None:
        """Register a published snapshot as the exact-heal base for SSD
        quarantines: ``snapshot(version) + redo[pin:] == current values``.
        The publisher takes the pin *before* publishing (so the retained
        suffix covers everything after the snapshot's flush) and hands it
        over here; the previous heal source's pin is released."""
        if self.redo is None or redo_pin is None:
            return
        idx = self.redo.pin_index(redo_pin)
        old_pin = self._heal_pin
        self._heal_src = (directory, int(version), int(idx))
        self._heal_pin = redo_pin
        self._heal_view = None
        if old_pin is not None:
            self.redo.release(old_pin)

    def _heal_rows(self, node: PSNode, keys: np.ndarray):
        """Exact current values for rows lost to an SSD quarantine, or
        ``None`` when only degraded re-initialization is possible.

        Base rows come from the registered heal snapshot (or, for a
        cluster whose shards started empty, the deterministic initializer
        with the log covering from index 0); the redo suffix is then
        replayed over them, oldest first, so the result equals the newest
        pushed value — bit-exact, which is what keeps training loss
        trajectories identical through an injected file drop."""
        if self.redo is None:
            return None
        keys = np.asarray(keys, dtype=np.uint64)
        if self._heal_src is not None:
            directory, version, idx = self._heal_src
            if not self.redo.covers(idx):
                return None  # pin bookkeeping failed us; degrade, don't lie
            view = self._heal_view
            if view is None or view.version != version:
                from repro.serve.snapshot import ServingVersion  # circular import

                view = ServingVersion(directory, version)
                self._heal_view = view
            rows = np.empty((len(keys), self.dim), dtype=np.float32)
            owners = key_to_node(keys, view.n_nodes)
            for nid in range(view.n_nodes):
                m = owners == nid
                if m.any():
                    rows[m] = view.read(nid, keys[m])
            entries = self.redo.since(idx)
        elif self._heal_from_init_ok and self.redo.covers(0):
            rows = node.ssd.init_rows(keys)
            entries = self.redo.since(0)
        else:
            return None
        apply_entries(entries, keys, rows)
        return rows

    def manifest(self) -> dict:
        self.flush_all()
        out = {
            "n_nodes": self.n_nodes,
            "dim": self.dim,
            "nodes": {n.node_id: n.ssd.manifest() for n in self.nodes},
        }
        if self.tables is not None:
            # checkpoints record the hosted table specs, so a restore (or a
            # reshard from a manifest) reconstructs the same named tables
            out["tables"] = self.tables.to_manifest()
        return out

    def publish_manifest(self) -> dict:
        """Snapshot-publishing manifest (DESIGN.md §7): like :meth:`manifest`
        but every node's SSD-PS atomically *retains* the files the manifest
        references (compaction parks instead of deleting them), and the
        missing-row init parameters ride along so a read-only serving view
        initializes unseen keys bit-identically to this cluster."""
        self.flush_all()
        out = {
            "n_nodes": self.n_nodes,
            "dim": self.dim,
            "init_scale": self.init_scale,
            "init_cols": self.init_cols,
            "nodes": {n.node_id: n.ssd.publish_manifest() for n in self.nodes},
        }
        if self.tables is not None:
            out["tables"] = self.tables.to_manifest()
        return out

    def release_files(self, per_node: "dict[int, list[str]]") -> None:
        """Retire one published version's retention references."""
        for nid, paths in per_node.items():
            self.nodes[int(nid)].ssd.release_files(paths)

    @classmethod
    def restore(cls, manifest: dict, base_dir: str, **kw) -> "Cluster":
        if kw.get("tables") is None and manifest.get("tables"):
            kw["tables"] = TableRegistry.from_manifest(manifest["tables"])
        c = cls(manifest["n_nodes"], base_dir, manifest["dim"], **kw)
        nodes = manifest["nodes"]
        for node in c.nodes:
            m = nodes.get(node.node_id, nodes.get(str(node.node_id)))  # JSON strs
            node.ssd = SSDParameterServer.from_manifest(node.dir, m)
            node.mem = MemParameterServer(node.ssd, capacity=node.mem.capacity)
            c._wire_node(node)  # rebuilt SSDs need counters + heal_fn again
        # restored shards hold pre-existing rows the redo log never saw, so
        # initializer+full-replay healing would fabricate values; exact
        # healing resumes once a snapshot is published on this cluster
        c._heal_from_init_ok = False
        if c.tables is not None:
            c.register_tables(c.tables)  # re-install on the restored SSDs
        return c

    def destroy(self) -> None:
        shutil.rmtree(self.base_dir, ignore_errors=True)
