"""HBM-PS: the device-resident working-parameter table (paper Section 4).

TPU adaptation of the multi-GPU distributed hash table (see DESIGN.md §3):
the MEM-PS renumbers the batch's unique keys to contiguous *working slots*
[0, n_working); the device table is then a dense ``[n_working, dim]`` matrix
and the hash-table ops become:

  get(slots)               -> gather              (Pallas embedding_lookup)
  accumulate(slots, vals)  -> scatter-add         (Pallas scatter_add)
  insert(slots, vals)      -> scatter-write

Distribution across the ``model`` mesh axis mirrors the paper's per-GPU
modulo partition: slot s lives on shard ``s % n_shards`` at local row
``s // n_shards``. Two exchange strategies are provided:

* ``gather_psum`` — each shard contributes its owned rows, one ``psum``
  assembles the full row set on every shard (paper's all-reduce-style sync;
  2(S-1)/S * B * dim bytes per link).
* ``gather_a2a`` — requests routed to owners and rows routed back with two
  ``all_to_all`` ops (paper's NVLink p2p ``get``; B * dim * (S-1)/S bytes),
  requires per-shard request lists of equal size (host pads).

``accumulate`` in the distributed setting reduces gradient rows across the
data axis (``psum``) and each shard applies only its owned rows — the same
"synchronize after every mini-batch" semantics as Algorithm 1 line 14.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels import ops as kops


# --------------------------------------------------------------------------
# single-device working table (used inside one jitted train step)
# --------------------------------------------------------------------------


class WorkingTable:
    """Dense device working table with hash-table semantics."""

    @staticmethod
    def get(table: jax.Array, slots: jax.Array) -> jax.Array:
        return kops.embedding_lookup(table, slots)

    @staticmethod
    def accumulate(table: jax.Array, slots: jax.Array, values: jax.Array) -> jax.Array:
        return kops.scatter_add(table, slots, values)

    @staticmethod
    def insert(table: jax.Array, slots: jax.Array, values: jax.Array) -> jax.Array:
        return table.at[slots].set(values.astype(table.dtype))


# --------------------------------------------------------------------------
# sharded working table over the `model` mesh axis
# --------------------------------------------------------------------------


def shard_layout(n_working: int, n_shards: int) -> int:
    """Rows per shard after padding (slot s -> shard s % S, row s // S)."""
    return (n_working + n_shards - 1) // n_shards


def to_sharded_rows(values: np.ndarray, n_shards: int) -> np.ndarray:
    """Host-side: [n_working, d] -> [S * rows_per_shard, d] padded, where the
    shard-major layout matches the device partition (shard = slot % S)."""
    n, d = values.shape
    rps = shard_layout(n, n_shards)
    out = np.zeros((n_shards * rps, d), dtype=values.dtype)
    for s in range(n_shards):
        rows = values[s::n_shards]
        out[s * rps : s * rps + len(rows)] = rows
    return out


def from_sharded_rows(sharded: np.ndarray, n_working: int, n_shards: int) -> np.ndarray:
    n, d = n_working, sharded.shape[1]
    rps = shard_layout(n, n_shards)
    out = np.zeros((n, d), dtype=sharded.dtype)
    for s in range(n_shards):
        take = len(out[s::n_shards])
        out[s::n_shards] = sharded[s * rps : s * rps + take]
    return out


class ShardedWorkingTable:
    """Working table sharded over a mesh axis with explicit collectives."""

    def __init__(self, mesh: Mesh, axis: str = "model"):
        self.mesh = mesh
        self.axis = axis
        self.n_shards = mesh.shape[axis]
        self.table_spec = P(axis, None)  # [S * rows_per_shard, d] row-sharded

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.table_spec)

    # -- psum exchange: every shard ends with all requested rows -----------
    def get_psum(self, table: jax.Array, slots: jax.Array) -> jax.Array:
        """table: [S*rps, d] sharded on axis; slots: [B] replicated ->
        [B, d] replicated."""
        S = self.n_shards
        rps = table.shape[0] // S

        def body(tbl, sl):
            # tbl: local [rps, d]; sl: [B] (replicated)
            me = jax.lax.axis_index(self.axis)
            owned = (sl % S) == me
            local_row = jnp.where(owned, sl // S, 0)
            rows = kops.embedding_lookup(tbl, local_row.astype(jnp.int32))
            rows = jnp.where(owned[:, None], rows, 0.0)
            return jax.lax.psum(rows, self.axis)

        spec_rest = [a for a in self.mesh.axis_names if a != self.axis]
        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self.table_spec, P()),
            out_specs=P(),
            check_rep=False,
        )(table, slots)

    # -- accumulate: grads for all B slots -> owned rows only --------------
    def accumulate(self, table: jax.Array, slots: jax.Array, grads: jax.Array) -> jax.Array:
        """grads: [B, d] replicated (already summed over data axis);
        each shard applies its owned rows."""
        S = self.n_shards

        def body(tbl, sl, g):
            me = jax.lax.axis_index(self.axis)
            owned = (sl % S) == me
            local_row = jnp.where(owned, sl // S, tbl.shape[0] - 1)
            g = jnp.where(owned[:, None], g, 0.0)
            # rows not owned scatter zeros into the last row: harmless
            return kops.scatter_add(tbl, local_row.astype(jnp.int32), g)

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self.table_spec, P(), P()),
            out_specs=self.table_spec,
            check_rep=False,
        )(table, slots, grads)
