"""HBM-PS: the device-resident working-parameter table (paper Section 4).

TPU adaptation of the multi-GPU distributed hash table (see DESIGN.md §3):
the MEM-PS renumbers the batch's unique keys to contiguous *working slots*
[0, n_working); the device table is then a dense ``[n_working, dim]`` matrix
and the hash-table ops become:

  get(slots)               -> gather              (Pallas embedding_lookup)
  accumulate(slots, vals)  -> scatter-add         (Pallas scatter_add)
  insert(slots, vals)      -> scatter-write

Distribution across the ``model`` mesh axis mirrors the paper's per-GPU
modulo partition: slot s lives on shard ``s % n_shards`` at local row
``s // n_shards``. Two exchange strategies are provided:

* ``get_psum`` — each shard contributes its owned rows, one ``psum``
  assembles the full row set on every shard (paper's all-reduce-style sync;
  2(S-1)/S * B * dim bytes per link).
* ``get_a2a`` — requests routed to owners and rows routed back with two
  ``all_to_all`` ops (paper's NVLink p2p ``get``; B * dim * (S-1)/S bytes);
  requires per-shard request lists of equal size, which the host pads via
  :func:`plan_a2a`. Output is requester-sharded: shard r ends holding the
  rows for its B/S slice of the batch, exactly the paper's per-GPU pattern.

``accumulate`` in the distributed setting reduces gradient rows across the
data axis (``psum``) and each shard applies only its owned rows — the same
"synchronize after every mini-batch" semantics as Algorithm 1 line 14.

On top of the per-batch table sits :class:`DeviceWorkingSet` — the paper's
HBM-PS caching behaviour across batches: rows whose keys repeat in the next
batch stay device-resident and are *slot-remapped* (a device gather), so the
host only transfers the delta rows. On skewed (zipfian) CTR streams adjacent
batches share most of their hot keys, making this the dominant PCIe/host
traffic win.

:class:`DeviceHotSet` generalizes the same mechanism to the *serving* path
(DESIGN.md §7): instead of "previous batch only", it keeps a
frequency-ranked resident set of the hottest rows on device across decode
steps. Because serving rows are immutable within a snapshot version, any
device-resident copy equals the host copy bit-for-bit — residency is keyed
by version and resets on a roll-forward.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.keys import member_sorted
from repro.kernels import ops as kops


# --------------------------------------------------------------------------
# single-device working table (used inside one jitted train step)
# --------------------------------------------------------------------------


class WorkingTable:
    """Dense device working table with hash-table semantics."""

    @staticmethod
    def get(table: jax.Array, slots: jax.Array) -> jax.Array:
        return kops.embedding_lookup(table, slots)

    @staticmethod
    def accumulate(
        table: jax.Array, slots: jax.Array, values: jax.Array,
        *, assume_sorted: bool = False,
    ) -> jax.Array:
        return kops.scatter_add(table, slots, values, assume_sorted=assume_sorted)

    @staticmethod
    def insert(table: jax.Array, slots: jax.Array, values: jax.Array) -> jax.Array:
        return table.at[slots].set(values.astype(table.dtype))


# --------------------------------------------------------------------------
# sharded working table over the `model` mesh axis
# --------------------------------------------------------------------------


def shard_layout(n_working: int, n_shards: int) -> int:
    """Rows per shard after padding (slot s -> shard s % S, row s // S)."""
    return (n_working + n_shards - 1) // n_shards


def to_sharded_rows(values: np.ndarray, n_shards: int) -> np.ndarray:
    """Host-side: [n_working, d] -> [S * rows_per_shard, d] padded, where the
    shard-major layout matches the device partition (shard = slot % S)."""
    n, d = values.shape
    rps = shard_layout(n, n_shards)
    out = np.zeros((n_shards * rps, d), dtype=values.dtype)
    for s in range(n_shards):
        rows = values[s::n_shards]
        out[s * rps : s * rps + len(rows)] = rows
    return out


def from_sharded_rows(sharded: np.ndarray, n_working: int, n_shards: int) -> np.ndarray:
    n, d = n_working, sharded.shape[1]
    rps = shard_layout(n, n_shards)
    out = np.zeros((n, d), dtype=sharded.dtype)
    for s in range(n_shards):
        take = len(out[s::n_shards])
        out[s::n_shards] = sharded[s * rps : s * rps + take]
    return out


class ShardedWorkingTable:
    """Working table sharded over a mesh axis with explicit collectives."""

    def __init__(self, mesh: Mesh, axis: str = "model"):
        self.mesh = mesh
        self.axis = axis
        self.n_shards = mesh.shape[axis]
        self.table_spec = P(axis, None)  # [S * rows_per_shard, d] row-sharded

    def sharding(self) -> NamedSharding:
        return NamedSharding(self.mesh, self.table_spec)

    # -- psum exchange: every shard ends with all requested rows -----------
    def get_psum(self, table: jax.Array, slots: jax.Array) -> jax.Array:
        """table: [S*rps, d] sharded on axis; slots: [B] replicated ->
        [B, d] replicated."""
        S = self.n_shards
        rps = table.shape[0] // S

        def body(tbl, sl):
            # tbl: local [rps, d]; sl: [B] (replicated)
            me = jax.lax.axis_index(self.axis)
            owned = (sl % S) == me
            local_row = jnp.where(owned, sl // S, 0)
            rows = kops.embedding_lookup(tbl, local_row.astype(jnp.int32))
            rows = jnp.where(owned[:, None], rows, 0.0)
            return jax.lax.psum(rows, self.axis)

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self.table_spec, P()),
            out_specs=P(),
            check_rep=False,
        )(table, slots)

    # -- accumulate: grads for all B slots -> owned rows only --------------
    def accumulate(
        self, table: jax.Array, slots: jax.Array, grads: jax.Array,
        *, assume_sorted: bool = False,
    ) -> jax.Array:
        """grads: [B, d] replicated (already summed over data axis);
        each shard applies its owned rows.

        ``assume_sorted=True`` when ``slots`` is ascending (the MEM-PS emits
        sorted-unique working sets): every slot maps to local row
        ``slot // S`` — non-decreasing — so the Pallas scatter kernel skips
        its argsort. Non-owned entries scatter zero grads into their (valid)
        ``slot // S`` row, which is harmless and keeps the order sorted."""
        S = self.n_shards

        def body(tbl, sl, g):
            me = jax.lax.axis_index(self.axis)
            owned = (sl % S) == me
            g = jnp.where(owned[:, None], g, 0.0)
            return kops.scatter_add(
                tbl, (sl // S).astype(jnp.int32), g, assume_sorted=assume_sorted
            )

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self.table_spec, P(), P()),
            out_specs=self.table_spec,
            check_rep=False,
        )(table, slots, grads)

    # -- all_to_all exchange: requests to owners, rows back (p2p ``get``) --
    def get_a2a(self, table: jax.Array, req: jax.Array, restore: jax.Array) -> jax.Array:
        """Two-``all_to_all`` row exchange (paper's NVLink p2p pattern).

        ``req``/``restore`` come from :func:`plan_a2a`: ``req[r, o]`` lists
        the (padded, equal-length) slots requester shard r asks owner shard
        o for, and ``restore[r]`` maps r's batch positions back into its
        received rows. Returns the [B, d] rows requester-sharded over the
        axis (shard r holds rows for its contiguous B/S slice of slots)."""
        S = self.n_shards

        def body(tbl, req_r, restore_r):
            d = tbl.shape[-1]
            m = req_r.shape[-1]
            # a2a #1: route each requester's per-owner slot lists to owners
            got = jax.lax.all_to_all(req_r, self.axis, split_axis=1, concat_axis=0, tiled=True)
            local_rows = (got.reshape(S, m) // S).astype(jnp.int32)
            rows = kops.embedding_lookup(tbl, local_rows.reshape(-1)).reshape(S, m, d)
            # a2a #2: route the gathered rows back to their requesters
            back = jax.lax.all_to_all(rows, self.axis, split_axis=0, concat_axis=0, tiled=True)
            return back.reshape(S * m, d)[restore_r[0]]

        return shard_map(
            body,
            mesh=self.mesh,
            in_specs=(self.table_spec, P(self.axis, None, None), P(self.axis, None)),
            out_specs=P(self.axis, None),
            check_rep=False,
        )(table, req, restore)


def plan_a2a(slots: np.ndarray, n_shards: int) -> tuple[np.ndarray, np.ndarray]:
    """Host-side routing plan for :meth:`ShardedWorkingTable.get_a2a`.

    Splits the batch into one contiguous chunk per requester shard and
    groups each chunk's slots by owner shard, padding every (requester,
    owner) request list to the same length m (pad entries request slot
    ``o`` — owner o's local row 0 — and are dropped by ``restore``).

    Returns (req [S, S, m] int32, restore [S, B//S] int32) with
    ``restore[r, j]`` indexing into the [S*m] rows shard r receives.
    """
    slots = np.asarray(slots, dtype=np.int64)
    S = n_shards
    B = len(slots)
    assert B % S == 0, f"batch {B} must pad to a multiple of {S} requesters"
    chunk = B // S
    # group by (requester, owner) in a few vectorized passes: a stable
    # argsort on the pair id keeps each group's request order, cumsum gives
    # group starts, and positions within a group follow by subtraction
    owners = slots % S
    pair = np.repeat(np.arange(S, dtype=np.int64), chunk) * S + owners
    order = np.argsort(pair, kind="stable")
    counts = np.bincount(pair, minlength=S * S)
    m = max(1, int(counts.max()))
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    rank = np.arange(B, dtype=np.int64) - np.repeat(starts, counts)
    req = np.tile(np.arange(S, dtype=np.int32), (S, 1))[:, :, None].repeat(m, axis=2)
    req.reshape(S * S, m)[pair[order], rank] = slots[order]
    restore = np.empty(B, dtype=np.int32)
    restore[order] = owners[order] * m + rank
    return req, restore.reshape(S, chunk)


# --------------------------------------------------------------------------
# cross-batch device working-set reuse (HBM-PS caching across batches)
# --------------------------------------------------------------------------


def assemble_rows(
    prev_table: jax.Array | None,
    fresh_rows: jax.Array,
    reuse_src: np.ndarray,
    reuse_dst: np.ndarray,
    fresh_dst: np.ndarray,
    n_working: int,
) -> jax.Array:
    """Build a [n_working, d] device table from already-resident rows plus
    the freshly-transferred delta: gather of ``prev_table[reuse_src]`` into
    ``reuse_dst`` + scatter of ``fresh_rows`` into ``fresh_dst``. Pure data
    movement — bitwise. Shared by the training :class:`DeviceWorkingSet`
    (previous-batch residency) and the serving :class:`DeviceHotSet`
    (frequency-ranked residency)."""
    if len(reuse_src) == 0:
        return fresh_rows  # fresh_dst is the identity permutation
    out = jnp.zeros((n_working, fresh_rows.shape[-1]), dtype=fresh_rows.dtype)
    out = out.at[jnp.asarray(reuse_dst)].set(prev_table[jnp.asarray(reuse_src)])
    return out.at[jnp.asarray(fresh_dst)].set(fresh_rows)


@dataclass
class ReusePlan:
    """How to assemble one batch's device table from the previous one."""

    n_working: int
    seq: int  # device-table generation this plan expects to remap from
    reuse_src: np.ndarray  # int32 — row in the PREVIOUS device table
    reuse_dst: np.ndarray  # int32 — row in the new table (same key)
    fresh_dst: np.ndarray  # int32 — new-table rows transferred from host

    @property
    def n_reused(self) -> int:
        return len(self.reuse_src)


@dataclass
class ReuseStats:
    batches: int = 0
    rows_reused: int = 0
    rows_transferred: int = 0
    bytes_saved: int = 0  # host->device bytes avoided by on-device remap
    bytes_transferred: int = 0


class DeviceWorkingSet:
    """Keeps consecutive batches' shared rows device-resident.

    The MEM-PS renumbers each batch's keys to fresh contiguous slots, so a
    key shared by batches i and i+1 lands at a *different* slot — but its
    post-train value already lives in batch i's final device table. ``plan``
    matches the new batch's (sorted, unique) keys against the previous
    batch's and emits a slot remap; ``assemble`` builds the new table on
    device from the remapped rows plus only the freshly-transferred delta.
    Values are bitwise-identical to a full host pull because the final
    device rows are exactly what the host push wrote back.
    """

    def __init__(self, row_bytes: int):
        self.row_bytes = int(row_bytes)
        self.stats = ReuseStats()
        self._prev_keys: np.ndarray | None = None
        self._seq = 0
        self._last_ext_id: int | None = None
        self._last_plan: ReusePlan | None = None

    def reset(self) -> None:
        """Invalidate residency (resume/restore or an aborted pipeline)."""
        self._prev_keys = None
        self._last_ext_id = None
        self._last_plan = None

    def plan(self, keys: np.ndarray, batch_id: int | None = None) -> ReusePlan:
        """keys: sorted unique uint64 of the new batch. Updates state.

        ``batch_id`` dedups a retried transfer stage: re-planning the same
        batch would diff its keys against themselves (and skew the device
        generation), so an immediate re-plan returns the original plan."""
        if batch_id is not None and batch_id == self._last_ext_id:
            return self._last_plan
        n = len(keys)
        prev = self._prev_keys
        self._prev_keys = keys
        self._seq += 1
        self._last_ext_id = batch_id
        self.stats.batches += 1
        if prev is None or len(prev) == 0:
            fresh = np.arange(n, dtype=np.int32)
            empty = np.empty(0, dtype=np.int32)
            self.stats.rows_transferred += n
            self.stats.bytes_transferred += n * self.row_bytes
            self._last_plan = ReusePlan(n, self._seq, empty, empty, fresh)
            return self._last_plan
        hit, pos_c = member_sorted(prev, keys)
        reuse_dst = np.nonzero(hit)[0].astype(np.int32)
        reuse_src = pos_c[hit].astype(np.int32)
        fresh_dst = np.nonzero(~hit)[0].astype(np.int32)
        self.stats.rows_reused += len(reuse_dst)
        self.stats.rows_transferred += len(fresh_dst)
        self.stats.bytes_saved += len(reuse_dst) * self.row_bytes
        self.stats.bytes_transferred += len(fresh_dst) * self.row_bytes
        self._last_plan = ReusePlan(n, self._seq, reuse_src, reuse_dst, fresh_dst)
        return self._last_plan

    @staticmethod
    def assemble(prev_table: jax.Array | None, fresh_rows: jax.Array, plan: ReusePlan) -> jax.Array:
        """Build the [n_working, d] table: device gather of reused rows +
        scatter of the transferred delta. Pure data movement — bitwise."""
        return assemble_rows(
            prev_table, fresh_rows,
            plan.reuse_src, plan.reuse_dst, plan.fresh_dst, plan.n_working,
        )


# --------------------------------------------------------------------------
# serving-path device residency: hottest rows stay on device across steps
# --------------------------------------------------------------------------


@dataclass
class HotPlan:
    """How to assemble one lookup's device table from the hot resident set."""

    n_working: int
    version: int
    keys: np.ndarray  # uint64 — the lookup's sorted unique keys
    reuse_src: np.ndarray  # int32 — row in the RESIDENT device table
    reuse_dst: np.ndarray  # int32 — row in the lookup's table (same key)
    fresh_dst: np.ndarray  # int32 — lookup rows transferred from host

    @property
    def n_reused(self) -> int:
        return len(self.reuse_src)


@dataclass
class HotSetStats:
    steps: int = 0
    rows_reused: int = 0
    rows_transferred: int = 0
    bytes_saved: int = 0  # host->device bytes avoided by residency
    bytes_transferred: int = 0

    @property
    def device_hit_rate(self) -> float:
        return self.rows_reused / max(1, self.rows_reused + self.rows_transferred)


class DeviceHotSet:
    """Keeps the hottest serving rows device-resident across decode steps.

    :class:`DeviceWorkingSet` exploits *adjacency* (training batch i+1
    shares keys with batch i); serving streams instead revisit a skewed hot
    set over many steps, so this class ranks keys by visit frequency and
    keeps the top ``capacity`` resident. Per lookup:

      1. ``plan``      — match the lookup's unique keys against the resident
                         set (one ``member_sorted`` pass); only the misses
                         need a host row.
      2. ``assemble``  — build the lookup's dense [n_working, d] table on
                         device: gather of resident rows + scatter of the
                         transferred delta (same primitive as training).
      3. ``admit``     — fold the lookup's keys into the frequency ranking
                         and refresh the resident table, sourcing rows from
                         the just-built lookup table and the old resident
                         table (both bitwise-correct: a version's rows are
                         immutable, so every copy of a key's row is equal).

    Residency is **version-keyed**: ``plan`` with a different snapshot
    version resets the set, so a roll-forward can never serve a stale row.
    """

    def __init__(self, capacity: int, row_bytes: int):
        self.capacity = int(capacity)
        self.row_bytes = int(row_bytes)
        self.stats = HotSetStats()
        self.generation = 0  # bumped on every resident-set mutation; lets
        # callers release their lock across the host pull and detect a
        # concurrent admit/reset before assembling against a stale plan
        self._version: int | None = None
        self._keys: np.ndarray | None = None  # sorted unique resident keys
        self._freq: np.ndarray | None = None  # int64, aligned with _keys
        self._table: jax.Array | None = None  # [len(_keys), d] resident rows

    @property
    def n_resident(self) -> int:
        return 0 if self._keys is None else len(self._keys)

    def reset(self) -> None:
        self.generation += 1
        self._version = None
        self._keys = None
        self._freq = None
        self._table = None

    def plan(self, keys: np.ndarray, version: int) -> HotPlan:
        """keys: sorted unique uint64 of one lookup; version: the snapshot
        version the caller's rows come from."""
        if version != self._version:
            self.reset()
            self._version = version
        n = len(keys)
        self.stats.steps += 1
        if self._keys is None or len(self._keys) == 0:
            fresh = np.arange(n, dtype=np.int32)
            empty = np.empty(0, dtype=np.int32)
            self.stats.rows_transferred += n
            self.stats.bytes_transferred += n * self.row_bytes
            return HotPlan(n, version, keys, empty, empty, fresh)
        hit, pos = member_sorted(self._keys, keys)
        reuse_dst = np.nonzero(hit)[0].astype(np.int32)
        reuse_src = pos[hit].astype(np.int32)
        fresh_dst = np.nonzero(~hit)[0].astype(np.int32)
        self.stats.rows_reused += len(reuse_dst)
        self.stats.rows_transferred += len(fresh_dst)
        self.stats.bytes_saved += len(reuse_dst) * self.row_bytes
        self.stats.bytes_transferred += len(fresh_dst) * self.row_bytes
        return HotPlan(n, version, keys, reuse_src, reuse_dst, fresh_dst)

    def assemble(self, fresh_rows: jax.Array, plan: HotPlan) -> jax.Array:
        """Lookup table from resident rows + transferred delta (device-side
        data movement only)."""
        return assemble_rows(
            self._table, fresh_rows,
            plan.reuse_src, plan.reuse_dst, plan.fresh_dst, plan.n_working,
        )

    def admit(self, batch_table: jax.Array, plan: HotPlan) -> None:
        """Update the frequency ranking with this lookup and refresh the
        resident set to the top-``capacity`` keys."""
        if plan.version != self._version:
            return  # raced with a reset; next plan() rebuilds
        keys = plan.keys
        if self._keys is None or len(self._keys) == 0:
            cand, freq = keys, np.ones(len(keys), dtype=np.int64)
        else:
            cand = np.union1d(self._keys, keys)  # sorted unique
            m_old, p_old = member_sorted(self._keys, cand)
            freq = np.where(m_old, self._freq[np.minimum(p_old, len(self._freq) - 1)], 0)
            m_new, _ = member_sorted(keys, cand)
            freq = freq + m_new
        if len(cand) > self.capacity:
            keep = np.zeros(len(cand), dtype=bool)
            keep[np.argsort(-freq, kind="stable")[: self.capacity]] = True
            cand, freq = cand[keep], freq[keep]  # mask keeps the sort order
        in_batch, pos_b = member_sorted(keys, cand)
        tbl = jnp.zeros((len(cand), batch_table.shape[-1]), dtype=batch_table.dtype)
        b_idx = np.nonzero(in_batch)[0]
        if b_idx.size:
            tbl = tbl.at[jnp.asarray(b_idx)].set(batch_table[jnp.asarray(pos_b[in_batch])])
        if self._keys is not None and len(self._keys):
            rest = ~in_batch
            if rest.any():
                m_old, p_old = member_sorted(self._keys, cand[rest])
                # every kept non-batch key came from the old resident set
                r_idx = np.nonzero(rest)[0]
                tbl = tbl.at[jnp.asarray(r_idx)].set(self._table[jnp.asarray(p_old)])
        self._keys, self._freq, self._table = cand, freq, tbl
        self.generation += 1

    def assemble_and_admit(self, fresh_rows: jax.Array, plan: HotPlan) -> jax.Array:
        table = self.assemble(fresh_rows, plan)
        self.admit(table, plan)
        return table
