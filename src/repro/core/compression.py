"""Gradient compression for the distributed optimizer path.

Two mechanisms:

1. **Row-sparse deltas** — inherent to the paper's design: only the rows
   referenced by the batch are communicated (keys + values), never the 10TB
   table. ``sparse_encode``/``sparse_decode`` implement the wire format with
   optional int8 quantization.
2. **Int8 quantization with error feedback** — per-row absmax scaling; the
   quantization residual is carried into the next step's gradient
   (error-feedback keeps SGD convergence; see 1-bit SGD lineage). Used for
   the *dense* backbone gradients when DCN bandwidth is the bottleneck.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def quantize_int8(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-row symmetric absmax int8 quantization. x: [n, d] float32."""
    x = np.asarray(x, dtype=np.float32)
    scale = np.abs(x).max(axis=-1, keepdims=True) / 127.0
    scale = np.where(scale == 0.0, 1.0, scale).astype(np.float32)
    q = np.clip(np.rint(x / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


@dataclass
class SparsePacket:
    """Wire format for a row-sparse update."""

    keys: np.ndarray  # uint64 [n]
    q: np.ndarray  # int8 [n, d] (or float32 when quantize=False)
    scale: np.ndarray | None  # float32 [n, 1]

    @property
    def nbytes(self) -> int:
        n = self.keys.nbytes + self.q.nbytes
        if self.scale is not None:
            n += self.scale.nbytes
        return n


def sparse_encode(keys: np.ndarray, values: np.ndarray, quantize: bool = True) -> SparsePacket:
    keys = np.asarray(keys, dtype=np.uint64)
    if quantize:
        q, scale = quantize_int8(values)
        return SparsePacket(keys, q, scale)
    return SparsePacket(keys, np.asarray(values, dtype=np.float32), None)


def sparse_decode(pkt: SparsePacket) -> tuple[np.ndarray, np.ndarray]:
    if pkt.scale is None:
        return pkt.keys, pkt.q
    return pkt.keys, dequantize_int8(pkt.q, pkt.scale)


class ErrorFeedbackCompressor:
    """Int8 compression with an error-feedback residual buffer.

    compress(g) returns (q, scale); the residual (g + e) - dequant(q) is
    stored and added to the next gradient, so the *accumulated* applied
    update is unbiased over time.
    """

    def __init__(self, shape: tuple[int, ...]):
        self.residual = np.zeros(shape, dtype=np.float32)

    def compress(self, grad: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        g = grad.astype(np.float32) + self.residual
        flat = g.reshape(g.shape[0], -1) if g.ndim > 1 else g.reshape(1, -1)
        q, scale = quantize_int8(flat)
        deq = dequantize_int8(q, scale).reshape(g.shape)
        self.residual = g - deq
        return q, scale

    def ratio(self) -> float:
        """Compression ratio vs float32 (≈4x minus the per-row scale)."""
        return 4.0 * self.residual.size / (self.residual.size + 4 * self.residual.shape[0])
