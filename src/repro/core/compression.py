"""Gradient compression + the training wire formats (DESIGN.md §13).

Three mechanisms:

1. **Row-sparse deltas** — inherent to the paper's design: only the rows
   referenced by the batch are communicated (keys + values), never the 10TB
   table. ``sparse_encode``/``sparse_decode`` implement the serving-read
   wire format with optional int8 quantization.
2. **Quantized gradient push with error feedback** — the training push wire
   (arxiv 2201.05500 lineage): per-row symmetric absmax int8 quantization of
   the *delta* against the receiver's current row, float16 scales, keys by
   reference to the batch's already-transmitted pinned set. The quantization
   residual is carried per key in an :class:`ErrorFeedbackStore` and folded
   into the next push of the same row, so the accumulated applied update is
   unbiased over time.
3. **Conflict-class dedup** — :class:`KeyedRowStore` retains the rows pushed
   within a bounded window of recent batches; a repeat-key pull inside that
   window is served from the retained copy (bitwise what the cluster holds,
   single-writer-per-table) for the cost of a pin message instead of a full
   row transfer.

Exact mode is the default everywhere: :class:`WireConfig()` disables both
the lossy push and the dedup window, and the bitwise serial/pipelined parity
contract is untouched.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.hash_index import U64Index

# float16 scale ceiling: absmax above 127 * f16_max would quantize through
# an inf scale; clamp mode folds non-finite values to this magnitude so the
# scale stays representable (error feedback absorbs the clipping)
F16_MAX = 65504.0
CLAMP_MAG = np.float32(127.0 * F16_MAX)
# push packet header: magic/version u32, n_rows u32, width u16, emb_dim u16,
# flags u16 (delta bitmap present? keys by reference?), key-set seq u16
PUSH_HEADER_BYTES = 16


def _guard_nonfinite(x: np.ndarray, nonfinite: str) -> tuple[np.ndarray, int]:
    """Handle inf/nan rows before absmax scaling (they poison the scale and
    dequantize to garbage). ``raise`` (default) rejects; ``clamp`` replaces
    nan with 0 and ±inf with ±CLAMP_MAG. Returns (safe x, n bad rows)."""
    finite = np.isfinite(x)
    if finite.all():
        return x, 0
    if nonfinite == "raise":
        bad = int((~finite.all(axis=-1)).sum()) if x.ndim > 1 else 1
        raise ValueError(
            f"quantize_int8: {bad} row(s) contain non-finite values; pass "
            "nonfinite='clamp' to fold them into the finite range"
        )
    if nonfinite != "clamp":
        raise ValueError(f"nonfinite must be 'raise' or 'clamp', got {nonfinite!r}")
    n_bad = int((~finite.all(axis=-1)).sum()) if x.ndim > 1 else 1
    return np.nan_to_num(x, nan=0.0, posinf=CLAMP_MAG, neginf=-CLAMP_MAG), n_bad


def quantize_int8(
    x: np.ndarray, nonfinite: str = "raise"
) -> tuple[np.ndarray, np.ndarray]:
    """Per-row symmetric absmax int8 quantization. x: [n, d] float32."""
    x = np.asarray(x).astype(np.float32, copy=False)
    x, _ = _guard_nonfinite(x, nonfinite)
    scale = np.abs(x).max(axis=-1, keepdims=True) / 127.0
    scale = np.where(scale == 0.0, 1.0, scale).astype(np.float32)
    q = np.clip(np.rint(x / scale), -127, 127).astype(np.int8)
    return q, scale


def dequantize_int8(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * scale


def quantize_rows_f16(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Absmax int8 with a *float16* per-row scale (2 wire bytes per scale).

    The scale is rounded to f16 BEFORE quantizing, so encode and decode use
    bitwise the same scale. Rows whose absmax/127 underflows f16 get the
    smallest f16 subnormal (values then clip to ±127 and error feedback
    carries the remainder); overflow clamps to f16 max. Caller has already
    guarded non-finite input."""
    x = np.asarray(x, dtype=np.float32)
    absmax = np.abs(x).max(axis=-1, keepdims=True)
    with np.errstate(over="ignore"):  # overflow -> inf, substituted below
        s16 = (absmax / 127.0).astype(np.float16)
    s16 = np.where((s16 == 0) & (absmax > 0), np.float16(6e-8), s16)
    s16 = np.where(np.isinf(s16), np.float16(F16_MAX), s16)
    s32 = s16.astype(np.float32)
    q = np.clip(np.rint(x / np.where(s32 == 0.0, 1.0, s32)), -127, 127).astype(np.int8)
    return q, s16


def dequantize_rows_f16(q: np.ndarray, s16: np.ndarray) -> np.ndarray:
    return q.astype(np.float32) * s16.astype(np.float32)


@dataclass
class SparsePacket:
    """Wire format for a row-sparse serving read."""

    keys: np.ndarray  # uint64 [n]
    q: np.ndarray  # int8 [n, d] (or float32 when quantize=False)
    scale: np.ndarray | None  # float32 [n, 1]

    @property
    def nbytes(self) -> int:
        n = self.keys.nbytes + self.q.nbytes
        if self.scale is not None:
            n += self.scale.nbytes
        return n

    @property
    def payload_nbytes(self) -> int:
        """Bytes of the value payload alone (a pull *reply* does not resend
        the keys — they crossed the wire in the request; metering them twice
        over-charges the NIC model)."""
        n = self.q.nbytes
        if self.scale is not None:
            n += self.scale.nbytes
        return n


def sparse_encode(keys: np.ndarray, values: np.ndarray, quantize: bool = True) -> SparsePacket:
    keys = np.asarray(keys, dtype=np.uint64)
    if quantize:
        q, scale = quantize_int8(values)
        return SparsePacket(keys, q, scale)
    return SparsePacket(keys, np.asarray(values, dtype=np.float32), None)


def sparse_decode(pkt: SparsePacket) -> tuple[np.ndarray, np.ndarray]:
    if pkt.scale is None:
        return pkt.keys, pkt.q
    return pkt.keys, dequantize_int8(pkt.q, pkt.scale)


# --------------------------------------------------------------------------
# training push wire (DESIGN.md §13)
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class WireConfig:
    """Training-wire options carried per table engine.

    * ``quantize_push`` — int8 delta push with error feedback (lossy; the
      exact-mode default ``False`` keeps the bitwise parity contract).
    * ``dedup_window`` — batches of pushed rows retained for repeat-key pull
      dedup (0 = off). Lossless: a dedup-served row is bitwise the cluster
      value (single writer per table; the engine drops the cache whenever
      the cluster reports a degraded heal).
    * ``nonfinite`` — ``'raise'`` (default) or ``'clamp'`` handling of
      non-finite gradient rows at quantization time.
    """

    quantize_push: bool = False
    dedup_window: int = 0
    nonfinite: str = "raise"

    @property
    def enabled(self) -> bool:
        return self.quantize_push or self.dedup_window > 0


@dataclass
class PushPacket:
    """Training push wire format.

    Header (16 B): magic/version, n_rows, width, emb_dim, flags, key-set ref.
    Payload: int8 ``q [n, width]``, f16 scales (one per field group: emb and
    optimizer slots quantize separately so their magnitudes don't share an
    absmax), a 1-bit-per-row delta/absolute bitmap, and — only when the
    receiver has no record of the batch's pinned key set — explicit u64 keys.
    The engine's pushes always reference the key set already shipped by the
    batch's pull request + pin messages, so ``keys_by_ref=True`` and the key
    bytes are zero.
    """

    q: np.ndarray  # int8 [n, width]
    scale_emb: np.ndarray  # f16 [n, 1]
    scale_opt: np.ndarray | None  # f16 [n, 1] when opt slots exist
    is_delta: np.ndarray  # bool [n]: row adds to the receiver's base
    emb_dim: int
    keys: np.ndarray | None = None  # u64 [n] when not by reference

    @property
    def n_rows(self) -> int:
        return self.q.shape[0]

    @property
    def width(self) -> int:
        return self.q.shape[1]

    def row_bytes(self) -> float:
        """Average encoded bytes per row (payload only)."""
        return self.segment_nbytes(self.n_rows) / max(1, self.n_rows)

    def segment_nbytes(self, n_rows: int) -> int:
        """On-wire bytes for a contiguous ``n_rows``-row slice of this packet
        (the cluster meters each remote owner segment separately)."""
        per_row = self.width + 2  # int8 payload + f16 emb scale
        if self.scale_opt is not None:
            per_row += 2
        if self.keys is not None:
            per_row += 8
        return PUSH_HEADER_BYTES + n_rows * per_row + (n_rows + 7) // 8

    @property
    def nbytes(self) -> int:
        return self.segment_nbytes(self.n_rows)


def raw_push_row_bytes(dim: int) -> int:
    """Bytes per row of the exact push wire: u64 key + f32 row."""
    return 8 + 4 * dim


def encode_push(
    new_rows: np.ndarray,
    base_rows: np.ndarray,
    residual: np.ndarray,
    emb_dim: int,
    has_base: np.ndarray | None = None,
    nonfinite: str = "raise",
    keys: np.ndarray | None = None,
) -> tuple[PushPacket, np.ndarray, np.ndarray, int]:
    """Encode one batch's push as a quantized delta packet.

    ``new_rows``/``base_rows``: [n, width] (bf16/f16 inputs are widened to
    f32). Rows where ``has_base`` is False encode absolute values (the
    receiver replaces instead of adds — used when no base is known).
    ``residual`` [n, width] is each row's carried error-feedback state.

    Returns ``(packet, applied, new_residual, n_nonfinite)`` where
    ``applied`` is bitwise the rows the receiver reconstructs (the caller
    pushes exactly these, so wire decode and cluster state cannot diverge)
    and ``new_residual`` is the residual to store back per key.
    """
    new_rows = np.asarray(new_rows).astype(np.float32, copy=False)
    base_rows = np.asarray(base_rows).astype(np.float32, copy=False)
    residual = np.asarray(residual, dtype=np.float32)
    n, width = new_rows.shape
    if has_base is None:
        has_base = np.ones(n, dtype=bool)
    base_eff = np.where(has_base[:, None], base_rows, 0.0).astype(np.float32)
    target = new_rows - base_eff
    g = target + residual
    g, n_bad = _guard_nonfinite(g, nonfinite)
    opt_dim = width - emb_dim
    q = np.empty((n, width), dtype=np.int8)
    qe, se = quantize_rows_f16(g[:, :emb_dim])
    q[:, :emb_dim] = qe
    if opt_dim > 0:
        qo, so = quantize_rows_f16(g[:, emb_dim:])
        q[:, emb_dim:] = qo
    else:
        so = None
    pkt = PushPacket(
        q=q, scale_emb=se, scale_opt=so, is_delta=has_base.copy(),
        emb_dim=emb_dim, keys=None if keys is None else np.asarray(keys, np.uint64),
    )
    deq = decode_push_payload(pkt)
    applied = base_eff + deq
    new_residual = g - deq
    return pkt, applied, new_residual, n_bad


def decode_push_payload(pkt: PushPacket) -> np.ndarray:
    """Dequantize the packet payload (the delta for ``is_delta`` rows, the
    absolute row otherwise) — the receiver adds its base to delta rows."""
    out = np.empty(pkt.q.shape, dtype=np.float32)
    out[:, : pkt.emb_dim] = dequantize_rows_f16(pkt.q[:, : pkt.emb_dim], pkt.scale_emb)
    if pkt.scale_opt is not None:
        out[:, pkt.emb_dim :] = dequantize_rows_f16(pkt.q[:, pkt.emb_dim :], pkt.scale_opt)
    return out


def decode_push(pkt: PushPacket, base_rows: np.ndarray) -> np.ndarray:
    """Receiver-side reconstruction: ``base + delta`` for delta rows, the
    absolute payload otherwise."""
    deq = decode_push_payload(pkt)
    base = np.asarray(base_rows, dtype=np.float32)
    return np.where(pkt.is_delta[:, None], base + deq, deq).astype(np.float32)


# --------------------------------------------------------------------------
# per-key row stores: error-feedback residuals + the dedup/base window
# --------------------------------------------------------------------------


class KeyedRowStore:
    """Vectorized uint64-key -> f32-row store (U64Index over a grown arena).

    Used twice by the wire path: as the **error-feedback store** (one
    residual row per pushed key, unbounded — residuals decay toward the
    quantization step so dropping them is never required for correctness)
    and as the **pushed-row window** (``window > 0``: rows whose last
    writing batch is more than ``window`` batches old are evicted, bounding
    the dedup/base cache to the coalescing window).
    """

    def __init__(self, width: int, window: int = 0, expected: int = 1024):
        self.width = int(width)
        self.window = int(window)
        self.index = U64Index(expected)
        cap = max(16, int(expected))
        self._rows = np.zeros((cap, self.width), dtype=np.float32)
        self._keys = np.zeros(cap, dtype=np.uint64)
        self._seq = np.full(cap, -1, dtype=np.int64)  # last writing batch
        self._n = 0
        self._free: list[int] = []

    def __len__(self) -> int:
        return len(self.index)

    def contains(self, keys: np.ndarray) -> np.ndarray:
        return self.index.contains(keys)

    def get(self, keys: np.ndarray, default: float = 0.0) -> tuple[np.ndarray, np.ndarray]:
        """(rows [n, width], found mask). Absent keys read ``default``."""
        keys = np.asarray(keys, dtype=np.uint64)
        slots = self.index.lookup(keys)
        found = slots >= 0
        out = np.full((len(keys), self.width), default, dtype=np.float32)
        out[found] = self._rows[slots[found]]
        return out, found

    def put(self, keys: np.ndarray, rows: np.ndarray, seq: int = 0) -> None:
        """Upsert unique keys; ``seq`` stamps the writing batch (window
        eviction removes rows with stamp <= seq - window)."""
        keys = np.asarray(keys, dtype=np.uint64)
        rows = np.asarray(rows, dtype=np.float32)
        if len(keys):
            slots = self.index.lookup(keys)
            found = slots >= 0
            fslots = slots[found]
            self._rows[fslots] = rows[found]
            self._seq[fslots] = seq
            miss = ~found
            n_new = int(miss.sum())
            if n_new:
                new_slots = self._alloc(n_new)
                self._rows[new_slots] = rows[miss]
                self._keys[new_slots] = keys[miss]
                self._seq[new_slots] = seq
                self.index.insert(keys[miss], new_slots)
        if self.window > 0:
            self._evict_older_than(seq - self.window)

    def _alloc(self, n: int) -> np.ndarray:
        take = min(n, len(self._free))
        out = [self._free.pop() for _ in range(take)]
        n -= take
        if n:
            if self._n + n > len(self._rows):
                cap = max(2 * len(self._rows), self._n + n)
                for name in ("_rows", "_keys", "_seq"):
                    old = getattr(self, name)
                    new = np.zeros((cap,) + old.shape[1:], dtype=old.dtype)
                    new[: len(old)] = old
                    setattr(self, name, new)
                self._seq[self._n + n :] = -1
            out.extend(range(self._n, self._n + n))
            self._n += n
        return np.asarray(out, dtype=np.int64)

    def _evict_older_than(self, floor_seq: int) -> None:
        live = self._seq[: self._n] >= 0
        stale = live & (self._seq[: self._n] <= floor_seq)
        idx = np.nonzero(stale)[0]
        if idx.size:
            self.index.delete(self._keys[idx])
            self._seq[idx] = -1
            self._free.extend(idx.tolist())

    def clear(self) -> None:
        self.index.clear()
        self._seq[: self._n] = -1
        self._free = []
        self._n = 0

    # --------------------------------------------------- checkpoint support
    def state(self) -> dict[str, np.ndarray]:
        """All live (keys, rows) plus their batch stamps, checkpoint-ready."""
        live = np.nonzero(self._seq[: self._n] >= 0)[0]
        return {
            "keys": self._keys[live].copy(),
            "rows": self._rows[live].copy(),
            "seq": self._seq[live].copy(),
        }

    def load(self, state: dict[str, np.ndarray]) -> None:
        self.clear()
        keys = np.asarray(state["keys"], dtype=np.uint64)
        if len(keys):
            rows = np.asarray(state["rows"], dtype=np.float32)
            seqs = np.asarray(state["seq"], dtype=np.int64)
            slots = self._alloc(len(keys))
            self._rows[slots] = rows
            self._keys[slots] = keys
            self._seq[slots] = seqs
            self.index.insert(keys, slots)


class ErrorFeedbackCompressor:
    """Int8 compression with a dense error-feedback residual buffer.

    compress(g) returns (q, scale); the residual (g + e) - dequant(q) is
    stored and added to the next gradient, so the *accumulated* applied
    update is unbiased over time. (The sparse, per-key variant used by the
    training push wire is :class:`KeyedRowStore` + :func:`encode_push`.)
    """

    def __init__(self, shape: tuple[int, ...]):
        self.residual = np.zeros(shape, dtype=np.float32)

    def compress(self, grad: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        g = grad.astype(np.float32) + self.residual
        flat = g.reshape(g.shape[0], -1) if g.ndim > 1 else g.reshape(1, -1)
        q, scale = quantize_int8(flat)
        deq = dequantize_int8(q, scale).reshape(g.shape)
        self.residual = g - deq
        return q, scale

    def ratio(self) -> float:
        """Compression ratio vs float32 (≈4x minus the per-row scale)."""
        return 4.0 * self.residual.size / (self.residual.size + 4 * self.residual.shape[0])
