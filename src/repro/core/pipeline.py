"""The 4-stage prefetch pipeline (paper Section 3 + Appendix B).

Stages map to independent hardware resources —

    read (network/HDFS)  ->  pull/push (CPU+SSD)  ->  transfer (PCIe/ICI)
        ->  train (accelerator)

Each stage is a worker thread feeding a bounded prefetch queue; a worker
stalls when the next stage's queue is full (the paper's back-pressure rule:
"the worker thread stalls when the prefetch queue of the next stage is
full"). Overall batch latency is then max(stage) instead of sum(stage).

Extras for 1000+-node operation:

* per-stage timing stats (drives the Fig-3c reproduction);
* straggler mitigation: a job whose stage exceeds ``timeout`` is
  speculatively re-executed on a backup worker; first completion wins.
  Speculation is only legal for stages marked ``idempotent`` — re-running a
  stage with side effects (e.g. the pull/push stage, which pins MEM-PS rows)
  would double-apply them, so non-idempotent stages never get a backup;
* failure handling: a stage exception is retried ``max_retries`` times,
  then the pipeline drains and surfaces the error;
* inter-stage dependencies: a :class:`DependencyRegistry` lets one stage
  publish completion tokens (e.g. "batch i trained") that another stage
  awaits (e.g. "pull of batch i+1 forwards batch i's pushed rows") — the
  mechanism behind the lossless overlap of pull(i+1) with train(i);
* clean shutdown: every queue put/get is stop-aware, so abandoning the
  ``run`` iterator early (or a downstream error) cannot leave a worker
  blocked forever on a full queue with its batch's rows still pinned.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Hashable, Iterable, Iterator


_SENTINEL = object()
_STOPPED = object()  # returned by stop-aware get when the pipeline is halting
_POLL_S = 0.05  # granularity at which blocked puts/gets observe _stop


class DependencyAborted(RuntimeError):
    """Raised to a waiter when the pipeline shuts down before its token."""


class DependencyRegistry:
    """Completion tokens signalled by one stage and awaited by another.

    Tokens are arbitrary hashable values (e.g. ``("trained", batch_id)``).
    ``wait`` blocks until the token is signalled; ``abort`` wakes every
    waiter with :class:`DependencyAborted` so a dying pipeline never leaves
    a stage blocked on an event that will no longer happen.
    """

    def __init__(self):
        self._cond = threading.Condition()
        self._done: set[Hashable] = set()
        self._floors: dict[Hashable, int] = {}
        self._aborted = False

    def signal(self, token: Hashable) -> None:
        with self._cond:
            self._done.add(token)
            self._cond.notify_all()

    def discard(self, token: Hashable) -> None:
        """Drop a token no waiter can reference anymore (keeps the done-set
        bounded over long runs); waiting on a discarded token hangs."""
        with self._cond:
            self._done.discard(token)

    def set_floor(self, family: Hashable, upto: int) -> None:
        """Collapse every token ``(family, seq)`` with ``seq <= upto`` into
        one permanently-signalled watermark: they count as done forever and
        are dropped from the done-set. This is how a producer of monotone
        sequence tokens keeps the set bounded *without* the hang risk of
        ``discard`` — a late waiter on a collapsed token returns
        immediately instead of blocking on a token that will never
        reappear. Floors only move forward."""
        with self._cond:
            if self._floors.get(family, upto - 1) >= upto:
                return
            self._floors[family] = upto
            self._done = {t for t in self._done if not self._under_floor(t)}
            self._cond.notify_all()

    def _under_floor(self, token: Hashable) -> bool:
        if not (isinstance(token, tuple) and len(token) == 2):
            return False
        floor = self._floors.get(token[0])
        return floor is not None and isinstance(token[1], int) and token[1] <= floor

    def is_done(self, token: Hashable) -> bool:
        with self._cond:
            return token in self._done or self._under_floor(token)

    def wait(self, token: Hashable, timeout: float | None = None) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while token not in self._done and not self._under_floor(token):
                if self._aborted:
                    raise DependencyAborted(f"pipeline stopped before {token!r}")
                remaining = _POLL_S if deadline is None else min(
                    _POLL_S, deadline - time.monotonic()
                )
                if remaining <= 0:
                    raise TimeoutError(f"dependency {token!r} not signalled")
                self._cond.wait(remaining)

    def abort(self) -> None:
        with self._cond:
            self._aborted = True
            self._cond.notify_all()

    def reset(self) -> None:
        """Clear a previous abort AND all signalled tokens/floors (a fresh
        pipeline run reuses the registry; stale tokens would satisfy a new
        run's waits instantly). Call only with no waiter in flight —
        Pipeline.run does so before starting its workers."""
        with self._cond:
            self._aborted = False
            self._done.clear()
            self._floors.clear()


@dataclass
class StageStats:
    name: str
    jobs: int = 0
    busy_time: float = 0.0
    stall_time: float = 0.0  # blocked pushing downstream (back-pressure)
    wait_time: float = 0.0  # blocked waiting upstream
    retries: int = 0
    speculative_wins: int = 0

    @property
    def mean_time(self) -> float:
        return self.busy_time / max(1, self.jobs)


@dataclass
class Stage:
    name: str
    fn: Callable[[Any], Any]
    capacity: int = 2  # prefetch-queue depth feeding the NEXT stage
    timeout: float | None = None  # straggler threshold (seconds)
    max_retries: int = 2
    idempotent: bool = True  # False => never speculatively re-executed
    # called at shutdown for each item this stage produced but the next
    # stage never consumed — stages whose outputs own resources (e.g. a
    # staging-ring slot, pinned rows) release them here so an abort/drain
    # cannot strand ownership inside a dead queue
    on_drain: Callable[[Any], None] | None = None


class PipelineError(RuntimeError):
    pass


class Pipeline:
    """Chain of stages, each on its own worker thread."""

    def __init__(self, stages: list[Stage], deps: DependencyRegistry | None = None):
        self.stages = stages
        self.stats = [StageStats(s.name) for s in stages]
        self.deps = deps
        self._error: Exception | None = None
        self.error_stage: str | None = None  # stage whose job raised first
        self.drained_items = 0  # in-flight batches discarded at shutdown
        self.drain_errors: list[Exception] = []  # on_drain hook failures
        self._stop = threading.Event()
        self._threads: list[threading.Thread] = []

    # --------------------------------------------------- stop-aware queue ops
    def _put(self, q: queue.Queue, item: Any) -> bool:
        """Blocking put that observes ``_stop``; returns False if halted."""
        while not self._stop.is_set():
            try:
                q.put(item, timeout=_POLL_S)
                return True
            except queue.Full:
                continue
        return False

    def _get(self, q: queue.Queue) -> Any:
        """Blocking get that observes ``_stop``; returns _STOPPED if halted."""
        while not self._stop.is_set():
            try:
                return q.get(timeout=_POLL_S)
            except queue.Empty:
                continue
        return _STOPPED

    def _drain(self, q: queue.Queue, on_drain: Callable | None = None) -> int:
        n = 0
        while True:
            try:
                item = q.get_nowait()
            except queue.Empty:
                return n
            if item is _SENTINEL or item is _STOPPED:
                continue
            n += 1
            if on_drain is not None:
                try:
                    on_drain(item)
                except Exception as e:
                    # a failing release hook must not mask the primary
                    # pipeline error; collected for callers/tests to check
                    self.drain_errors.append(e)

    # ------------------------------------------------------------- running
    def run(self, source: Iterable[Any]) -> Iterator[Any]:
        """Stream ``source`` items through all stages, yielding results in
        order. Timing of each stage is recorded in ``self.stats``."""
        if self.deps is not None:
            self.deps.reset()
        self._stop.clear()
        self._error = None
        queues = [queue.Queue(maxsize=max(1, s.capacity)) for s in self.stages]
        out_q: queue.Queue = queue.Queue(maxsize=max(1, self.stages[-1].capacity))
        all_queues = queues + [out_q]

        def feeder():
            try:
                for item in source:
                    if not self._put(queues[0], item):
                        return
            except Exception as e:  # propagate source errors
                self._error = e
                self._stop.set()
            finally:
                self._put(queues[0], _SENTINEL)

        def worker(idx: int):
            stage, stats = self.stages[idx], self.stats[idx]
            in_q = queues[idx]
            nxt = queues[idx + 1] if idx + 1 < len(self.stages) else out_q
            while True:
                t0 = time.perf_counter()
                item = self._get(in_q)
                stats.wait_time += time.perf_counter() - t0
                if item is _STOPPED:
                    return
                if item is _SENTINEL:
                    self._put(nxt, _SENTINEL)
                    return
                try:
                    result = self._run_job(stage, stats, item)
                except Exception as e:
                    if self._error is None:  # keep the root cause: secondary
                        self._error = e  # failures (DependencyAborted in a
                        self.error_stage = stage.name  # stage the abort
                    self._stop.set()  # released) don't mask it
                    if self.deps is not None:
                        self.deps.abort()
                    return
                t0 = time.perf_counter()
                if not self._put(nxt, result):
                    # the pipeline halted while this output waited for queue
                    # space: it will never be consumed OR drained from a
                    # queue, so release its resources here
                    if stage.on_drain is not None:
                        try:
                            stage.on_drain(result)
                        except Exception as e:
                            self.drain_errors.append(e)
                    return
                stats.stall_time += time.perf_counter() - t0

        self._threads = [threading.Thread(target=feeder, daemon=True)]
        for i in range(len(self.stages)):
            self._threads.append(threading.Thread(target=worker, args=(i,), daemon=True))
        for t in self._threads:
            t.start()

        # speculative duplicates never reach the sink: the stage returns the
        # first completion and drops the loser, so results stay exactly-once.
        try:
            while True:
                item = self._get(out_q)
                if item is _STOPPED or item is _SENTINEL:
                    break
                yield item
        finally:
            self._shutdown(all_queues)
        if self._error is not None:
            where = f" at stage {self.error_stage!r}" if self.error_stage else ""
            raise PipelineError(
                f"pipeline failed{where}: {self._error!r}"
            ) from self._error

    def _shutdown(self, all_queues: list[queue.Queue]) -> None:
        """Halt workers and release every blocked thread: stop flag first
        (puts/gets poll it), then abort dependency waiters, then drain the
        queues so no batch stays enqueued with its rows pinned."""
        self._stop.set()
        if self.deps is not None:
            self.deps.abort()
        deadline = time.monotonic() + 5.0
        for t in self._threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))
        # drained items are batches that entered the pipeline but never
        # reached the sink — fault-recovery code (CTRTrainer._ride_through)
        # replays them from its own buffer; the count is diagnostic.
        # queues[0] holds raw source items (no producer stage); queue i+1
        # and out_q hold stage i's outputs, released via its on_drain hook
        producers = [None] + list(self.stages)
        self.drained_items += sum(
            self._drain(q, s.on_drain if s is not None else None)
            for q, s in zip(all_queues, producers)
        )

    # ------------------------------------------------- one job, one stage
    def _run_job(self, stage: Stage, stats: StageStats, item: Any) -> Any:
        attempts = 0
        while True:
            t0 = time.perf_counter()
            try:
                if stage.timeout is None or not stage.idempotent:
                    result = stage.fn(item)
                else:
                    result = self._run_speculative(stage, stats, item)
                stats.jobs += 1
                stats.busy_time += time.perf_counter() - t0
                return result
            except DependencyAborted:
                raise  # the pipeline is dying; re-running cannot succeed
            except Exception:
                attempts += 1
                stats.retries += 1
                if attempts > stage.max_retries:
                    raise

    def _run_speculative(self, stage: Stage, stats: StageStats, item: Any) -> Any:
        """Run fn; if it exceeds the straggler timeout, launch a backup and
        take whichever finishes first. Only called for idempotent stages —
        the backup may re-execute a job whose primary later also completes."""
        result_q: queue.Queue = queue.Queue()

        def attempt(tag: str):
            try:
                result_q.put((tag, stage.fn(item), None))
            except Exception as e:  # pragma: no cover - surfaced by caller
                result_q.put((tag, None, e))

        primary = threading.Thread(target=attempt, args=("primary",), daemon=True)
        primary.start()
        try:
            tag, res, err = result_q.get(timeout=stage.timeout)
        except queue.Empty:
            backup = threading.Thread(target=attempt, args=("backup",), daemon=True)
            backup.start()
            tag, res, err = result_q.get()  # first of the two
            if tag == "backup" and err is None:
                stats.speculative_wins += 1
        if err is not None:
            raise err
        return res

    # ---------------------------------------------------------------- info
    def report(self) -> dict[str, dict]:
        return {
            s.name: {
                "jobs": s.jobs,
                "mean_s": s.mean_time,
                "busy_s": s.busy_time,
                "stall_s": s.stall_time,
                "wait_s": s.wait_time,
                "retries": s.retries,
                "speculative_wins": s.speculative_wins,
            }
            for s in self.stats
        }

    def bottleneck(self) -> str:
        return max(self.stats, key=lambda s: s.busy_time).name
