"""The 4-stage prefetch pipeline (paper Section 3 + Appendix B).

Stages map to independent hardware resources —

    read (network/HDFS)  ->  pull/push (CPU+SSD)  ->  transfer (PCIe/ICI)
        ->  train (accelerator)

Each stage is a worker thread feeding a bounded prefetch queue; a worker
stalls when the next stage's queue is full (the paper's back-pressure rule:
"the worker thread stalls when the prefetch queue of the next stage is
full"). Overall batch latency is then max(stage) instead of sum(stage).

Extras for 1000+-node operation:

* per-stage timing stats (drives the Fig-3c reproduction);
* straggler mitigation: a job whose stage exceeds ``timeout`` is
  speculatively re-executed on a backup worker; first completion wins
  (stages must be idempotent — pull/transfer are; train consumes its input
  exactly once at the sink via job-id dedup);
* failure handling: a stage exception is retried ``max_retries`` times,
  then the pipeline drains and surfaces the error.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Iterator


_SENTINEL = object()


@dataclass
class StageStats:
    name: str
    jobs: int = 0
    busy_time: float = 0.0
    stall_time: float = 0.0  # blocked pushing downstream (back-pressure)
    wait_time: float = 0.0  # blocked waiting upstream
    retries: int = 0
    speculative_wins: int = 0

    @property
    def mean_time(self) -> float:
        return self.busy_time / max(1, self.jobs)


@dataclass
class Stage:
    name: str
    fn: Callable[[Any], Any]
    capacity: int = 2  # prefetch-queue depth feeding the NEXT stage
    timeout: float | None = None  # straggler threshold (seconds)
    max_retries: int = 2


class PipelineError(RuntimeError):
    pass


class Pipeline:
    """Chain of stages, each on its own worker thread."""

    def __init__(self, stages: list[Stage]):
        self.stages = stages
        self.stats = [StageStats(s.name) for s in stages]
        self._error: Exception | None = None
        self._stop = threading.Event()

    # ------------------------------------------------------------- running
    def run(self, source: Iterable[Any]) -> Iterator[Any]:
        """Stream ``source`` items through all stages, yielding results in
        order. Timing of each stage is recorded in ``self.stats``."""
        queues = [queue.Queue(maxsize=max(1, s.capacity)) for s in self.stages]
        out_q: queue.Queue = queue.Queue(maxsize=max(1, self.stages[-1].capacity))
        threads = []

        def feeder():
            try:
                for item in source:
                    if self._stop.is_set():
                        return
                    queues[0].put(item)
            except Exception as e:  # propagate source errors
                self._error = e
            finally:
                queues[0].put(_SENTINEL)

        def worker(idx: int):
            stage, stats = self.stages[idx], self.stats[idx]
            in_q = queues[idx]
            nxt = queues[idx + 1] if idx + 1 < len(self.stages) else out_q
            while not self._stop.is_set():
                t0 = time.perf_counter()
                item = in_q.get()
                stats.wait_time += time.perf_counter() - t0
                if item is _SENTINEL:
                    nxt.put(_SENTINEL)
                    return
                try:
                    result = self._run_job(stage, stats, item)
                except Exception as e:
                    self._error = e
                    self._stop.set()
                    nxt.put(_SENTINEL)
                    return
                t0 = time.perf_counter()
                nxt.put(result)
                stats.stall_time += time.perf_counter() - t0

        threads.append(threading.Thread(target=feeder, daemon=True))
        for i in range(len(self.stages)):
            threads.append(threading.Thread(target=worker, args=(i,), daemon=True))
        for t in threads:
            t.start()

        # speculative duplicates never reach the sink: the stage returns the
        # first completion and drops the loser, so results stay exactly-once.
        while True:
            item = out_q.get()
            if item is _SENTINEL:
                break
            yield item
        self._stop.set()
        if self._error is not None:
            raise PipelineError(f"pipeline failed: {self._error!r}") from self._error

    # ------------------------------------------------- one job, one stage
    def _run_job(self, stage: Stage, stats: StageStats, item: Any) -> Any:
        attempts = 0
        while True:
            t0 = time.perf_counter()
            try:
                if stage.timeout is None:
                    result = stage.fn(item)
                else:
                    result = self._run_speculative(stage, stats, item)
                stats.jobs += 1
                stats.busy_time += time.perf_counter() - t0
                return result
            except Exception:
                attempts += 1
                stats.retries += 1
                if attempts > stage.max_retries:
                    raise

    def _run_speculative(self, stage: Stage, stats: StageStats, item: Any) -> Any:
        """Run fn; if it exceeds the straggler timeout, launch a backup and
        take whichever finishes first."""
        result_q: queue.Queue = queue.Queue()

        def attempt(tag: str):
            try:
                result_q.put((tag, stage.fn(item), None))
            except Exception as e:  # pragma: no cover - surfaced by caller
                result_q.put((tag, None, e))

        primary = threading.Thread(target=attempt, args=("primary",), daemon=True)
        primary.start()
        try:
            tag, res, err = result_q.get(timeout=stage.timeout)
        except queue.Empty:
            backup = threading.Thread(target=attempt, args=("backup",), daemon=True)
            backup.start()
            tag, res, err = result_q.get()  # first of the two
            if tag == "backup" and err is None:
                stats.speculative_wins += 1
        if err is not None:
            raise err
        return res

    # ---------------------------------------------------------------- info
    def report(self) -> dict[str, dict]:
        return {
            s.name: {
                "jobs": s.jobs,
                "mean_s": s.mean_time,
                "busy_s": s.busy_time,
                "stall_s": s.stall_time,
                "wait_s": s.wait_time,
                "retries": s.retries,
                "speculative_wins": s.speculative_wins,
            }
            for s in self.stats
        }

    def bottleneck(self) -> str:
        return max(self.stats, key=lambda s: s.busy_time).name
