"""Multi-table PS client: named tables + batch sessions (DESIGN.md §6).

:class:`PSClient` is the user-facing surface of the hierarchical parameter
server. It hosts any number of named tables (``TableSpec``/``RowSchema``,
see :mod:`repro.core.tables`) over ONE shared HBM/MEM/SSD cluster and
replaces the loose ``prepare_batch`` / ``finish_batch`` / ``complete_batch``
/ ``abort_batch`` quartet with a :class:`BatchSession` handle carrying
explicit commit/abort semantics::

    client = PSClient(cluster, [TableSpec("ctr", RowSchema.with_adagrad(8))])
    with client.session("ctr", batch.keys) as s:
        new_emb, new_acc = device_step(s.params, s.opt_state, s.slots, ...)
        s.commit(new_emb, new_acc)            # push + unpin
    # exiting without commit aborts (unpin, no update)

Each table gets its own :class:`~repro.core.hier_ps.HierarchicalPS` engine
over the shared cluster; the engines share one
:class:`~repro.core.pipeline.DependencyRegistry` (token families are
namespaced per table id). Because session keys are namespaced by high-bit
tagging before they reach the engine, cross-batch conflicts — and therefore
the in-flight registry, version forwarding and deferred pushes behind the
bitwise serial-parity guarantee — are strictly per-table.

Session flavours:

* **training** (default) — pulls with MEM-PS pins through the in-flight
  registry; ``commit(new_params, new_opt)`` pushes + unpins (pass
  ``defer=True`` from a pipeline's train stage to deposit only, letting
  the pull/push stage thread apply the push); ``abort()`` unpins without
  updating. Exiting a ``with`` block without committing aborts.
* **read-only** (``read_only=True``) — ad-hoc single-shot reads: pulls
  *without* pins and never touches the in-flight registry, so decode loops
  cannot accumulate pin pressure; ``commit`` is an error. With
  ``NetworkModel(wire_quantize=True)`` these reads travel the int8 wire
  format. The first-class serving path is :meth:`PSClient.serving_view`
  (DESIGN.md §7): a request-coalescing
  :class:`~repro.serve.engine.ServingEngine` with a version-keyed hot-row
  cache over published snapshots.
"""

from __future__ import annotations

import numpy as np

from repro.core.compression import WireConfig
from repro.core.hier_ps import HierarchicalPS, WorkingSet
from repro.core.node import Cluster
from repro.core.pipeline import DependencyRegistry
from repro.core.tables import RowSchema, TableRegistry, TableSpec
from repro.metrics import Counters


class SessionStateError(RuntimeError):
    """Commit/abort called on a session that already left the open state."""


class BatchSession:
    """One batch's working rows on one named table.

    Construct via :meth:`PSClient.session`. Usable as a context manager
    (exit without commit = abort) or as a plain handle passed between
    pipeline stages (the trainer prepares on the pull/push thread and
    commits from the train stage with ``defer=True``).
    """

    def __init__(
        self,
        engine: HierarchicalPS,
        spec: TableSpec,
        batch_keys: np.ndarray,
        *,
        batch_id: int | None = None,
        device_resident_prev: bool = False,
        read_only: bool = False,
        requester: int = 0,
    ):
        self.spec = spec
        self.read_only = read_only
        self._engine = engine
        self._state = "open"
        tagged = spec.namespace(batch_keys)
        if read_only:
            # serving path: no pins, no in-flight registry — stale-by-one
            # reads are acceptable for inference, pin pressure is not
            flat = np.asarray(tagged, dtype=np.uint64).reshape(-1)
            uniq, inverse = np.unique(flat, return_inverse=True)
            rows = engine.cluster.pull(uniq, requester=requester, pin=False)
            self.ws = WorkingSet(
                keys=uniq,
                params=rows[:, : engine.emb_dim],
                opt_state=rows[:, engine.emb_dim : engine.width],
                slots=inverse.astype(np.int32).reshape(np.shape(tagged)),
                batch_id=-1,
            )
        else:
            self.ws = engine.prepare_batch(
                tagged,
                requester=requester,
                batch_id=batch_id,
                device_resident_prev=device_resident_prev,
            )

    # ----------------------------------------------------------- the rows
    @property
    def keys(self) -> np.ndarray:
        """Unique referenced keys in *cluster* key space (tagged)."""
        return self.ws.keys

    @property
    def raw_keys(self) -> np.ndarray:
        """Unique referenced keys in this table's raw key space."""
        return self.spec.raw(self.ws.keys)

    @property
    def params(self) -> np.ndarray:
        return self.ws.params

    @property
    def opt_state(self) -> np.ndarray:
        return self.ws.opt_state

    @property
    def slots(self) -> np.ndarray:
        return self.ws.slots

    @property
    def n_working(self) -> int:
        return self.ws.n_working

    @property
    def batch_id(self) -> int:
        return self.ws.batch_id

    @property
    def state(self) -> str:
        return self._state

    def field(self, name: str) -> np.ndarray:
        """View of one named schema field of the working rows."""
        sl = self.spec.schema.slice_of(name)
        if sl.start < self._engine.emb_dim:
            return self.ws.params[:, sl]
        off = self._engine.emb_dim
        return self.ws.opt_state[:, sl.start - off : sl.stop - off]

    # ------------------------------------------------------- commit/abort
    def commit(
        self,
        new_params: np.ndarray,
        new_opt_state: np.ndarray | None = None,
        *,
        defer: bool = False,
    ) -> None:
        """Publish the trained rows and release the session.

        ``defer=True`` only deposits the results (the push runs on the next
        ``prepare``/``apply_ready_pushes``/``drain`` on the pull/push stage
        thread, and the rows become the forwarding source for conflicting
        successor batches); the default pushes synchronously."""
        if self.read_only:
            raise SessionStateError("read-only session cannot commit")
        if self._state != "open":
            raise SessionStateError(f"commit on a {self._state} session")
        self._engine.finish_batch(self.ws, new_params, new_opt_state)
        self._state = "committed"
        if not defer:
            self._engine.apply_ready_pushes()

    def abort(self) -> None:
        """Release the session without publishing (unpins pulled rows)."""
        if self._state != "open":
            raise SessionStateError(f"abort on a {self._state} session")
        if not self.read_only:
            self._engine.abort_batch(self.ws)
        self._state = "aborted"

    # ----------------------------------------------------- context manager
    def __enter__(self) -> "BatchSession":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._state == "open":
            self.abort()
        return False


class PSClient:
    """Named tables + sessions over one shared PS cluster.

    ``tables`` seeds the cluster's :class:`TableRegistry` (specs, or
    ``(name, RowSchema)`` pairs — ids are auto-assigned in order). A client
    over a cluster that already hosts tables (restored from a checkpoint
    manifest, or shared with another client) picks those up automatically.
    """

    def __init__(
        self,
        cluster: Cluster,
        tables: "list[TableSpec | tuple[str, RowSchema]] | None" = None,
        deps: DependencyRegistry | None = None,
        wire: WireConfig | None = None,
    ):
        self.cluster = cluster
        self.deps = deps or DependencyRegistry()
        # the training-wire config (quantized push + dedup window, DESIGN.md
        # §13) applies uniformly to every table engine this client builds
        self.wire = wire or WireConfig()
        registry = cluster.tables if cluster.tables is not None else TableRegistry()
        for t in tables or []:
            spec = t if isinstance(t, TableSpec) else TableSpec(name=t[0], schema=t[1])
            registry.add(spec)
        self.registry = registry
        if len(registry):
            cluster.register_tables(registry)
        self._engines: dict[str, HierarchicalPS] = {}
        for spec in registry:
            self._engines[spec.name] = HierarchicalPS(
                cluster, deps=self.deps, spec=spec, wire=self.wire
            )

    # ------------------------------------------------------------- tables
    def create_table(
        self,
        name: str,
        schema: RowSchema,
        *,
        table_id: int | None = None,
        init_scale: float | None = None,
    ) -> TableSpec:
        """Register a table after construction (id auto-assigned unless
        given explicitly)."""
        spec = self.registry.add(
            TableSpec(name, schema, table_id=table_id, init_scale=init_scale)
        )
        self.cluster.register_tables(self.registry)
        self._engines[spec.name] = HierarchicalPS(
            self.cluster, deps=self.deps, spec=spec, wire=self.wire
        )
        return spec

    @property
    def table_names(self) -> list[str]:
        return self.registry.names

    def table(self, name: str) -> TableSpec:
        return self.registry.get(name)

    def engine(self, name: str) -> HierarchicalPS:
        """The per-table orchestration engine (in-flight registry, stats)."""
        return self._engines[name]

    def stats(self, name: str):
        return self._engines[name].stats

    # --------------------------------------------------------- training wire
    def wire_counters(self) -> dict:
        """Per-class bytes-on-wire counters summed across every table."""
        acc = Counters()
        for e in self._engines.values():
            acc.add_from(e.wire_counters)
        return acc.snapshot()

    def wire_state(self) -> dict:
        """Checkpointable error-feedback residual state, keyed by table
        name (tables with the lossy wire off are omitted)."""
        out = {}
        for name, e in self._engines.items():
            st = e.wire_state()
            if st is not None:
                out[name] = st
        return out

    def load_wire_state(self, state: dict) -> None:
        """Restore per-table error-feedback residuals saved by
        :meth:`wire_state` (unknown tables are ignored)."""
        for name, st in (state or {}).items():
            if name in self._engines:
                self._engines[name].load_wire_state(st)

    # ------------------------------------------------------------ sessions
    def session(
        self,
        table: str,
        batch_keys: np.ndarray,
        *,
        batch_id: int | None = None,
        device_resident_prev: bool = False,
        read_only: bool = False,
        requester: int = 0,
    ) -> BatchSession:
        """Open a batch session on ``table`` for the given raw keys."""
        spec = self.registry.require(table)
        return BatchSession(
            self._engines[table],
            spec,
            batch_keys,
            batch_id=batch_id,
            device_resident_prev=device_resident_prev,
            read_only=read_only,
            requester=requester,
        )

    # ------------------------------------------------------------- serving
    def serving_view(
        self,
        version: int | None = None,
        *,
        snapshots=None,
        network=None,
        **engine_kw,
    ) -> "ServingEngine":
        """The serving entry point (DESIGN.md §7): a request-coalescing
        :class:`~repro.serve.engine.ServingEngine` over this client's tables.

        With ``snapshots`` (a :class:`~repro.serve.snapshot.SnapshotPublisher`
        or a snapshot directory) the engine opens the published ``version``
        (default: latest) **read-only** — the production train->serve
        handoff, isolated from ongoing training and atomically
        roll-forwardable. Without it the engine serves pin-free straight off
        the live cluster (demos, tests). ``network`` configures the
        serving-side NIC model (e.g. ``NetworkModel(wire_quantize=True)``
        for int8 remote reads); remaining kwargs reach the engine
        (``cache_rows``, ``device_hot_rows``, ``coalesce_window_s``).
        """
        from repro.serve.engine import LiveClusterView, ServingEngine
        from repro.serve.snapshot import ServingCluster

        if snapshots is not None:
            directory = getattr(snapshots, "dir", snapshots)
            source = ServingCluster(directory, version=version, network=network)
        else:
            if version is not None:
                raise ValueError(
                    "pinning a published version needs `snapshots=`; the live "
                    "cluster view is unversioned"
                )
            if network is not None:
                raise ValueError(
                    "the live view reads over the cluster's own NetworkModel; "
                    "`network=` only configures a snapshot ServingCluster"
                )
            source = LiveClusterView(self.cluster)
        return ServingEngine(source, **engine_kw)

    # ----------------------------------------------------------- lifecycle
    def apply_ready_pushes(self) -> int:
        """Apply every table's completed deferred pushes (pull/push stage)."""
        return sum(e.apply_ready_pushes() for e in self._engines.values())

    def drain(self, strict: bool = True) -> None:
        """Push every trained batch and unpin the rest, on every table."""
        errs = []
        for e in self._engines.values():
            try:
                e.drain(strict=strict)
            except Exception as err:  # keep draining the other tables
                errs.append(err)
        if errs and strict:
            raise errs[0]

    def n_inflight(self) -> int:
        return sum(e.n_inflight() for e in self._engines.values())

    def manifest(self) -> dict:
        """Cluster manifest (flushes dirty rows); records the table specs."""
        return self.cluster.manifest()
