"""Elastic scaling: reshard a PS cluster from N to M nodes.

At 1000+ node scale, node counts change (failures, preemption, scale-up).
Key ownership is ``hash(key) % n_nodes``, so a change of n_nodes remaps
roughly (1 - 1/max(N, M)) of keys. Resharding streams each node's live rows
file-by-file (sequential reads), repartitions them by the new owner map, and
writes them into fresh SSD-PS shards — the same file-granularity sequential
I/O discipline the paper uses for updates.

Two entry points (DESIGN.md §9):

* :func:`reshard` — offline: flush, bulk-copy, done. Dead nodes are
  recovered first (``Cluster.recover_node``: restart + redo replay); if
  recovery is impossible the reshard *raises* with the lost-row count
  instead of silently dropping the dead shard's rows.
* :func:`reshard_live` — under traffic: bulk-copy while pulls/pushes keep
  flowing, then a brief write-gate pause replays only the redo-log delta
  onto the new shards. The measured pause is the write availability gap
  (reads never stop); it scales with the delta, not the table.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.keys import key_to_node
from repro.core.node import Cluster, NodeDownError
from repro.core.recovery import collapse_entries


def _recover_or_raise(cluster: Cluster, action: str) -> None:
    """Bring every dead node back (restart + redo replay) before moving
    rows. Without the redo log a dead shard's DRAM-resident updates are
    unrecoverable — surface that with the at-risk row count rather than
    producing a new cluster that silently lost them."""
    dead = [n for n in cluster.nodes if not n.alive]
    if not dead:
        return
    try:
        cluster.recover_dead_nodes()
    except NodeDownError as e:
        at_risk = sum(n.ssd.n_live_rows for n in dead)
        raise NodeDownError(
            f"{action} with dead node(s) {[n.node_id for n in dead]} would lose "
            f"updates to >= {at_risk} rows (SSD-resident; DRAM-resident updates "
            "uncounted): recovery failed"
        ) from e


def _bulk_copy(cluster: Cluster, new: Cluster, new_n_nodes: int) -> int:
    """Stream every live row into the new shards; returns rows moved."""
    # stage rows per new owner so each write is one (or few) sequential files
    staged_keys: list[list[np.ndarray]] = [[] for _ in range(new_n_nodes)]
    staged_vals: list[list[np.ndarray]] = [[] for _ in range(new_n_nodes)]
    moved = 0
    for node in cluster.nodes:
        for keys, vals in node.ssd.iter_live():
            owners = key_to_node(keys, new_n_nodes)
            for dst in range(new_n_nodes):
                mask = owners == dst
                if mask.any():
                    staged_keys[dst].append(keys[mask])
                    staged_vals[dst].append(vals[mask])
                    if dst != node.node_id:  # data actually moves
                        new.network.transfer(int(mask.sum()) * (8 + 4 * cluster.dim))
    for dst in range(new_n_nodes):
        if staged_keys[dst]:
            k = np.concatenate(staged_keys[dst])
            v = np.concatenate(staged_vals[dst])
            new.nodes[dst].ssd.write_batch(k, v)
            moved += len(k)
    return moved


def _make_target(cluster: Cluster, new_n_nodes: int, new_base_dir: str) -> Cluster:
    kw = cluster.ctor_kwargs()
    kw["network"] = cluster.network.fresh()
    new = Cluster(new_n_nodes, new_base_dir, cluster.dim, **kw)
    # the new shards receive rows via direct SSD writes below, which the
    # new cluster's own (empty) redo log never saw — initializer+replay
    # healing would fabricate values, so disable it until its first publish
    new._heal_from_init_ok = False
    return new


def reshard(cluster: Cluster, new_n_nodes: int, new_base_dir: str) -> Cluster:
    """Build a new cluster with ``new_n_nodes`` holding the same live rows.

    The new cluster is rebuilt from ``cluster.ctor_kwargs()`` — the full
    construction-parameter set — rather than a hand-picked subset, so no
    kwarg (file/cache capacities, init scheme, hosted table specs, future
    additions) silently reverts to its default across a reshard; only the
    NIC is replaced by a fresh same-parameter instance so the transfer
    counters below measure this reshard's own traffic. Hosted table specs
    ride along via ``tables``, keeping every named table's key namespacing
    and missing-row initializer intact on the new shards.

    Dead nodes are recovered (never silently skipped) — see
    :func:`_recover_or_raise`."""
    _recover_or_raise(cluster, "reshard")
    cluster.flush_all()
    new = _make_target(cluster, new_n_nodes, new_base_dir)
    _bulk_copy(cluster, new, new_n_nodes)
    return new


def reshard_live(
    cluster: Cluster, new_n_nodes: int, new_base_dir: str
) -> "tuple[Cluster, dict]":
    """Reshard under sustained traffic with a bounded write-availability gap.

    Phase 1 (traffic flows): flush, pin the redo log, bulk-copy every live
    row — concurrent pushes keep landing on the old cluster *and* in the
    pinned redo suffix. Phase 2 (write gate closed, reads still served):
    collapse the redo delta last-writer-wins and write it onto the new
    shards, so the new cluster ends bit-identical to the old one's final
    state. Returns ``(new_cluster, info)`` where ``info['gap_s']`` is the
    measured wall-clock write gap and ``info['delta_rows']`` the rows that
    crossed during it.

    Requires the redo log (``Cluster.enable_redo``): without delta
    tracking, traffic during the bulk copy would be silently lost."""
    if cluster.redo is None:
        raise ValueError(
            "reshard_live needs the redo log to track the live delta "
            "(Cluster.enable_redo() / redo_rows=...)"
        )
    _recover_or_raise(cluster, "reshard_live")
    # ---- phase 1: bulk copy, writes still flowing ----------------------
    # pin BEFORE flushing: a push racing into the gap between the two would
    # otherwise be neither SSD-resident for the bulk copy nor inside the
    # pinned suffix for the delta replay — i.e. silently lost
    pin = cluster.pin_redo()
    cluster.flush_all()  # everything appended before the pin is now on SSD
    new = _make_target(cluster, new_n_nodes, new_base_dir)
    moved = _bulk_copy(cluster, new, new_n_nodes)
    # ---- phase 2: gate writes, replay the delta, cut over --------------
    t0 = time.perf_counter()
    cluster.pause_writes()
    try:
        # pushes that raced the bulk copy live in MEM (dirty) *and* in the
        # pinned redo suffix; the suffix alone reconstructs their newest
        # values, no extra flush of the old cluster needed
        dk, dv = collapse_entries(cluster.redo.since(cluster.redo.pin_index(pin)))
        if len(dk):
            owners = key_to_node(dk, new_n_nodes)
            for dst in range(new_n_nodes):
                mask = owners == dst
                if mask.any():
                    new.network.transfer(int(mask.sum()) * (8 + 4 * cluster.dim))
                    new.nodes[dst].ssd.write_batch(dk[mask], dv[mask])
        gap_s = time.perf_counter() - t0
    finally:
        cluster.resume_writes()
        cluster.release_redo(pin)
    return new, {"gap_s": gap_s, "delta_rows": int(len(dk)), "moved_rows": int(moved)}
