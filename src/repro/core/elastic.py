"""Elastic scaling: reshard a PS cluster from N to M nodes.

At 1000+ node scale, node counts change (failures, preemption, scale-up).
Key ownership is ``hash(key) % n_nodes``, so a change of n_nodes remaps
roughly (1 - 1/max(N, M)) of keys. Resharding streams each node's live rows
file-by-file (sequential reads), repartitions them by the new owner map, and
writes them into fresh SSD-PS shards — the same file-granularity sequential
I/O discipline the paper uses for updates.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.keys import key_to_node
from repro.core.node import Cluster, NetworkModel


def reshard(cluster: Cluster, new_n_nodes: int, new_base_dir: str) -> Cluster:
    """Build a new cluster with ``new_n_nodes`` holding the same live rows."""
    cluster.flush_all()
    new = Cluster(
        new_n_nodes,
        new_base_dir,
        cluster.dim,
        cache_capacity=cluster.nodes[0].mem.capacity,
        file_capacity=cluster.nodes[0].ssd.file_capacity,
        network=NetworkModel(
            latency_s=cluster.network.latency_s,
            bandwidth_gbps=cluster.network.bandwidth_gbps,
        ),
    )
    # stage rows per new owner so each write is one (or few) sequential files
    staged_keys: list[list[np.ndarray]] = [[] for _ in range(new_n_nodes)]
    staged_vals: list[list[np.ndarray]] = [[] for _ in range(new_n_nodes)]
    for node in cluster.nodes:
        if not node.alive:
            continue
        for keys, vals in node.ssd.iter_live():
            owners = key_to_node(keys, new_n_nodes)
            for dst in range(new_n_nodes):
                mask = owners == dst
                if mask.any():
                    staged_keys[dst].append(keys[mask])
                    staged_vals[dst].append(vals[mask])
                    if dst != node.node_id:  # data actually moves
                        new.network.transfer(int(mask.sum()) * (8 + 4 * cluster.dim))
    for dst in range(new_n_nodes):
        if staged_keys[dst]:
            k = np.concatenate(staged_keys[dst])
            v = np.concatenate(staged_vals[dst])
            new.nodes[dst].ssd.write_batch(k, v)
    return new
