"""Elastic scaling: reshard a PS cluster from N to M nodes.

At 1000+ node scale, node counts change (failures, preemption, scale-up).
Key ownership is ``hash(key) % n_nodes``, so a change of n_nodes remaps
roughly (1 - 1/max(N, M)) of keys. Resharding streams each node's live rows
file-by-file (sequential reads), repartitions them by the new owner map, and
writes them into fresh SSD-PS shards — the same file-granularity sequential
I/O discipline the paper uses for updates.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.keys import key_to_node
from repro.core.node import Cluster


def reshard(cluster: Cluster, new_n_nodes: int, new_base_dir: str) -> Cluster:
    """Build a new cluster with ``new_n_nodes`` holding the same live rows.

    The new cluster is rebuilt from ``cluster.ctor_kwargs()`` — the full
    construction-parameter set — rather than a hand-picked subset, so no
    kwarg (file/cache capacities, init scheme, hosted table specs, future
    additions) silently reverts to its default across a reshard; only the
    NIC is replaced by a fresh same-parameter instance so the transfer
    counters below measure this reshard's own traffic. Hosted table specs
    ride along via ``tables``, keeping every named table's key namespacing
    and missing-row initializer intact on the new shards."""
    cluster.flush_all()
    kw = cluster.ctor_kwargs()
    kw["network"] = cluster.network.fresh()
    new = Cluster(new_n_nodes, new_base_dir, cluster.dim, **kw)
    # stage rows per new owner so each write is one (or few) sequential files
    staged_keys: list[list[np.ndarray]] = [[] for _ in range(new_n_nodes)]
    staged_vals: list[list[np.ndarray]] = [[] for _ in range(new_n_nodes)]
    for node in cluster.nodes:
        if not node.alive:
            continue
        for keys, vals in node.ssd.iter_live():
            owners = key_to_node(keys, new_n_nodes)
            for dst in range(new_n_nodes):
                mask = owners == dst
                if mask.any():
                    staged_keys[dst].append(keys[mask])
                    staged_vals[dst].append(vals[mask])
                    if dst != node.node_id:  # data actually moves
                        new.network.transfer(int(mask.sum()) * (8 + 4 * cluster.dim))
    for dst in range(new_n_nodes):
        if staged_keys[dst]:
            k = np.concatenate(staged_keys[dst])
            v = np.concatenate(staged_vals[dst])
            new.nodes[dst].ssd.write_batch(k, v)
    return new
