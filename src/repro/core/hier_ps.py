"""Hierarchical parameter server orchestrator — Algorithm 1 of the paper.

Per training batch:

  1. identify the union of referenced sparse keys (dedup);
  2. pull their rows from the cluster (local MEM-PS/SSD-PS + remote MEM-PS),
     pinning them for the duration of the batch;
  3. renumber keys to contiguous *working slots* and hand a dense working
     table (+ per-row optimizer state) to the device step;
  4. after the device finishes its mini-batches, push the updated rows back
     to their owner nodes and unpin.

Row layout is described by a :class:`~repro.core.tables.RowSchema`: the SSD
row packs ``[emb | optimizer slots...]`` in one fixed-size value so a key's
full training state moves through MEM-PS/SSD-PS as one row (the paper's
fixed-size-value design). A table narrower than the cluster row uses a
prefix; the tail is kept zero. One engine serves exactly one table — the
multi-table façade (:class:`repro.core.client.PSClient`) runs one engine
per named table over the shared cluster, which keeps every guarantee below
*per table* (namespaced keys cannot conflict across tables).

Lossless pipeline overlap (paper §3-4: the 4-stage pipeline must not change
the learned model) is implemented with an **in-flight registry**: every
prepared batch is registered until its push lands on the cluster. When
``prepare_batch(i+1)`` runs concurrently with the training of batch ``i``,
its keys are partitioned into

* **fresh** keys — held by no in-flight batch; pulled from the cluster
  immediately (this is the work that overlaps device compute), and
* **conflicting** keys — held by a still-in-flight batch; these are NOT
  pulled (the cluster copy is stale until that batch pushes). Instead the
  prepare waits, per conflicting predecessor, for its training results and
  **forwards the pushed rows directly** into the new working set (per-key
  version forwarding), transferring the MEM-PS pin in the same step.

The push itself is deferred: the train stage only deposits its results
(:meth:`finish_batch`); the next ``prepare_batch`` call — which the trainer
runs on the pull/push stage thread — applies all completed pushes in batch
order before pulling, so SSD/MEM-PS traffic stays off the device stage and
overlaps the next batch's compute. ``drain()`` applies whatever is left at
end of stream. The result is bitwise equality with serial execution while
pull, push and train all overlap.

Completion tokens stay bounded via the registry's floor watermark: once
every batch up to seq ``s`` has left the in-flight window, the engine
collapses their tokens into ``DependencyRegistry.set_floor`` — derived
from the *actual* in-flight window, not a hardcoded token-discard distance.
"""

from __future__ import annotations

import sys
import threading
from collections import OrderedDict
from dataclasses import dataclass, field

import numpy as np

from repro.core.compression import (
    KeyedRowStore,
    WireConfig,
    encode_push,
    raw_push_row_bytes,
)
from repro.core.keys import member_sorted
from repro.core.node import Cluster
from repro.core.pipeline import DependencyRegistry
from repro.core.tables import RowSchema, TableSpec
from repro.metrics import Counters

# training-wire byte accounting (DESIGN.md §13), one Counters set per engine.
# Push direction: raw key+f32 bytes the exact wire would move vs the encoded
# packet bytes actually metered. Pull direction: per-conflict-class rows and
# the row bytes each class kept off the wire.
WIRE_COUNTER_NAMES = (
    "wire_push_rows", "wire_push_raw_bytes", "wire_push_enc_bytes",
    "wire_push_nonfinite_rows",
    "wire_pull_fresh_rows", "wire_pull_fresh_bytes",
    "wire_pull_device_rows", "wire_pull_device_bytes_saved",
    "wire_pull_forwarded_rows", "wire_pull_forwarded_bytes_saved",
    "wire_pull_dedup_rows", "wire_pull_dedup_bytes_saved",
)


@dataclass
class WorkingSet:
    """The device-ready working parameters of one batch."""

    keys: np.ndarray  # uint64 [n_working] — unique referenced keys
    params: np.ndarray  # float32 [n_working, emb_dim]
    opt_state: np.ndarray  # float32 [n_working, opt_dim]
    slots: np.ndarray  # int32, same shape as the batch's key tensor
    batch_id: int

    @property
    def n_working(self) -> int:
        return len(self.keys)


@dataclass
class PSStats:
    """Counters for the conflict-aware pull path."""

    batches_prepared: int = 0
    rows_pulled: int = 0  # fresh rows actually pulled from the cluster
    rows_forwarded: int = 0  # conflict rows served by host version forwarding
    rows_device_served: int = 0  # conflict rows served by the HBM-PS copy
    rows_dedup_served: int = 0  # repeat-key pulls served by the push window
    pull_bytes_saved: int = 0  # row bytes NOT pulled thanks to all paths
    dedup_reuses: int = 0  # prepare_batch calls answered by the registry
    deferred_pushes: int = 0  # pushes applied off the train stage

    @property
    def conflict_rows(self) -> int:
        return self.rows_forwarded + self.rows_device_served


@dataclass
class _InFlight:
    """One prepared batch, tracked until its push lands on the cluster."""

    seq: int
    ws: WorkingSet
    requester: int
    ext_id: int | None  # caller-supplied batch id (speculation dedup)
    pinned: list = field(default_factory=list)  # key arrays we hold pins on
    new_params: np.ndarray | None = None  # trained results (finish_batch)
    new_opt: np.ndarray | None = None
    trained: bool = False
    device_mask: np.ndarray | None = None  # rows served by the HBM-PS copy
    packet: object | None = None  # encoded PushPacket (wire metering)


class HierarchicalPS:
    """Host-side orchestrator of ONE table over a PS cluster.

    ``spec`` describes the table (schema + key-namespace id); the legacy
    two-int signature ``HierarchicalPS(cluster, emb_dim, opt_dim)`` still
    works and builds an anonymous full-width ``[emb | opt]`` spec with
    table id 0 (whose key tagging is the identity) — the exact pre-
    multi-table behaviour. Keys passed to this engine are already in
    cluster key space; namespacing raw per-table keys is the session
    layer's job (:class:`repro.core.client.BatchSession`).
    """

    def __init__(
        self,
        cluster: Cluster,
        emb_dim: int | None = None,
        opt_dim: int = 0,
        deps: DependencyRegistry | None = None,
        spec: TableSpec | None = None,
        wire: WireConfig | None = None,
    ):
        self.cluster = cluster
        if spec is None:
            assert emb_dim is not None, "pass emb_dim/opt_dim or spec"
            assert cluster.dim == emb_dim + opt_dim, (
                f"cluster value dim {cluster.dim} != emb {emb_dim} + opt {opt_dim}"
            )
            schema = (
                RowSchema.embedding(emb_dim)
                if opt_dim == 0
                else RowSchema.with_slots(emb_dim, opt=opt_dim)
            )
            spec = TableSpec("default", schema, table_id=0)
        self.spec = spec
        self.schema = spec.schema
        self.emb_dim = self.schema.emb_dim
        self.opt_dim = self.schema.opt_dim
        self.width = self.schema.width
        assert cluster.dim >= self.width, (
            f"cluster row width {cluster.dim} < table schema width {self.width}"
        )
        self.deps = deps or DependencyRegistry()
        # one token family per table: engines sharing a DependencyRegistry
        # (PSClient) must not collide on their per-batch sequence numbers
        self._token_family = ("trained", self.spec.table_id)
        self.stats = PSStats()
        self._batch_counter = 0
        self._lock = threading.RLock()  # registry state
        self._push_lock = threading.Lock()  # serializes deferred pushes
        self._inflight: "OrderedDict[int, _InFlight]" = OrderedDict()
        self._ext_to_seq: dict[int, int] = {}
        # seqs allocated by a prepare that has not registered yet — they
        # hold the token floor back so a successor can never see their
        # token as "already done" before they trained
        self._preparing: set[int] = set()
        # keys of the last fully-prepared *device-resident* batch (the set
        # the caller keeps on device when device_resident_prev is passed).
        # Any unflagged prepare (eval-style), an abort of that batch, or
        # drain() invalidates it — device-serving against a batch whose
        # rows never reached the device would train zeros.
        self._last_prepared_keys: np.ndarray | None = None
        self._last_prepared_seq: int = -1
        # ---- training wire (DESIGN.md §13) ----------------------------
        self.wire = wire or WireConfig()
        self.wire_counters = Counters(*WIRE_COUNTER_NAMES)
        # per-key quantization residual, carried into the key's next push
        # (unbounded: a residual is at most one quantization step per field)
        self._ef = KeyedRowStore(self.width) if self.wire.quantize_push else None
        # rows pushed within the coalescing window: delta base for
        # device-served rows (window 1 suffices — the base is always the
        # immediately-previous batch) and the dedup source for repeat-key
        # pulls. Written at deposit time under ``_lock``.
        cache_window = max(
            1 if self.wire.quantize_push else 0, self.wire.dedup_window
        )
        self._pushed = (
            KeyedRowStore(self.width, window=cache_window) if cache_window else None
        )
        # degraded SSD heals re-initialize rows behind our back; the cached
        # copies then no longer match the cluster, so drop them wholesale
        fc = cluster.fault_counters
        self._heal_seen = fc["ssd_rows_reinit"] + fc["ssd_heal_degraded"]

    # ------------------------------------------------------------- tokens
    def _trained_token(self, seq: int):
        return (self._token_family, seq)

    def _floor_bound_locked(self) -> int:
        """Largest seq known to have left the in-flight window (all tokens
        at or below it are collapsible). Derived from the registry's actual
        window: the oldest in-flight or still-preparing batch holds it."""
        cands = []
        if self._inflight:
            cands.append(min(self._inflight))
        if self._preparing:
            cands.append(min(self._preparing))
        return (min(cands) if cands else self._batch_counter) - 1

    # ----------------------------------------------------------- pull side
    def prepare_batch(
        self,
        batch_keys: np.ndarray,
        requester: int = 0,
        batch_id: int | None = None,
        device_resident_prev: bool = False,
    ) -> WorkingSet:
        """batch_keys: any-shape uint64 tensor of referenced keys (padded
        entries may use key 0 — slot 0 then maps to key 0's row, which is
        fine: its update contribution is masked out by the model).

        ``batch_id`` (the caller's external batch identifier) dedups
        re-execution: a straggler-speculation or retry re-running the
        pull/push stage for a batch already in flight gets the existing
        working set back instead of double-pinning every key.

        ``device_resident_prev``: the caller keeps the previous batch's
        final rows device-resident (DeviceWorkingSet) and will remap shared
        keys on device. Conflicts held by the *immediately preceding* batch
        then need no host value at all — the paper's "served from the
        HBM-PS copy" case — so this prepare does not wait for that batch's
        training; only conflicts with older in-flight batches still use
        host version forwarding. The returned working set's rows for those
        keys are zero and must not be transferred (the device remap covers
        exactly these keys: they are, by construction, in the previous
        batch's key set)."""
        # apply any completed-but-unpushed predecessors first: this runs on
        # the pull/push stage thread, keeping SSD/MEM-PS write traffic off
        # the train stage and overlapped with device compute
        self.apply_ready_pushes()

        flat = np.asarray(batch_keys, dtype=np.uint64).reshape(-1)
        uniq, inverse = np.unique(flat, return_inverse=True)
        n = len(uniq)

        with self._lock:
            if batch_id is not None and batch_id in self._ext_to_seq:
                entry = self._inflight.get(self._ext_to_seq[batch_id])
                if entry is not None:
                    self.stats.dedup_reuses += 1
                    return entry.ws
            seq = self._batch_counter
            self._batch_counter += 1
            # conflict detection: latest in-flight holder per key (scan the
            # few in-flight batches newest-first; both key sets are sorted)
            holder_seq = np.full(n, -1, dtype=np.int64)
            holder_pos = np.zeros(n, dtype=np.int64)
            entries = {s: e for s, e in self._inflight.items()}
            for s in sorted(entries, reverse=True):
                open_mask = holder_seq < 0
                if not open_mask.any():
                    break
                m, pos = member_sorted(entries[s].ws.keys, uniq)
                m &= open_mask
                holder_seq[m] = s
                holder_pos[m] = pos[m]
            last_keys = self._last_prepared_keys
            # last statement under the lock, immediately before the guarded
            # region: nothing between add and the except can leak the seq
            # (a leaked seq would hold the token floor back forever)
            self._preparing.add(seq)

        pinned_fresh = None  # keys pinned by the pull, until entry owns them
        try:
            # keys of the previous prepared batch are served from the
            # device-resident HBM-PS copy: no host value, no waiting — the
            # device remap is inherently ordered after that batch's train
            # step, and its final device rows are bitwise what its push wrote
            # (so this holds whether or not that push has landed yet). Push
            # ordering guarantees no OLDER in-flight batch still holds such
            # a key.
            if device_resident_prev and last_keys is not None:
                device_served, _ = member_sorted(last_keys, uniq)
            else:
                device_served = np.zeros(n, dtype=bool)
            fresh = (holder_seq < 0) & ~device_served
            # pull dedup (DESIGN.md §13): a fresh key whose push landed
            # within the coalescing window is served from the retained copy
            # — bitwise what the cluster holds (single writer per table, and
            # no-holder means the writing batch's push already applied) —
            # for the cost of a pin message instead of a row transfer
            dedup = np.zeros(n, dtype=bool)
            dedup_rows = None
            if self._pushed is not None and self.wire.dedup_window > 0:
                self._check_heal_coherence()
                with self._lock:
                    if len(self._pushed):
                        dedup = fresh & self._pushed.contains(uniq)
                        if dedup.any():
                            dedup_rows, _ = self._pushed.get(uniq[dedup])
                        fresh = fresh & ~dedup
            n_fresh = int(fresh.sum())
            if n_fresh == n:
                # conflict-free (every serial batch after its predecessor's
                # push landed): the pulled buffer is freshly allocated per
                # batch, so the working set views straight into it
                rows = self.cluster.pull(uniq, requester=requester, pin=True)
                pinned_fresh = uniq[fresh]
            else:
                rows = np.zeros((n, self.cluster.dim), dtype=np.float32)
                if dedup_rows is not None:
                    rows[dedup, : self.width] = dedup_rows
                if n_fresh:
                    # the overlap win: fresh rows pull while predecessors train
                    rows[fresh] = self.cluster.pull(
                        uniq[fresh], requester=requester, pin=True
                    )
                    pinned_fresh = uniq[fresh]
            ws = WorkingSet(
                keys=uniq,
                params=rows[:, : self.emb_dim],
                opt_state=rows[:, self.emb_dim : self.width],
                slots=inverse.astype(np.int32).reshape(np.shape(batch_keys)),
                batch_id=seq,
            )
            entry = _InFlight(
                seq=seq, ws=ws, requester=requester, ext_id=batch_id,
                device_mask=device_served if device_served.any() else None,
            )
            if pinned_fresh is not None:
                entry.pinned.append(pinned_fresh)
        except BaseException:
            # pscheck PS101: the pull takes pins before the in-flight entry
            # exists to own them — release here or they leak forever
            with self._lock:
                self._preparing.discard(seq)
            if pinned_fresh is not None:
                self.cluster.unpin(pinned_fresh)
            raise
        with self._lock:
            self._inflight[seq] = entry
            self._preparing.discard(seq)
            if batch_id is not None:
                self._ext_to_seq[batch_id] = seq
        self.stats.batches_prepared += 1
        self.stats.rows_pulled += n_fresh
        row_bytes = self.cluster.dim * 4
        if n_fresh:
            self.wire_counters.inc("wire_pull_fresh_rows", n_fresh)
            self.wire_counters.inc("wire_pull_fresh_bytes", n_fresh * row_bytes)

        n_dd = int(dedup.sum())
        if n_dd:
            try:
                # the dedup-served rows still need eviction pins for the
                # batch's lifetime; the pin message is all that hits the wire
                dd_keys = uniq[dedup]
                self.cluster.pin(dd_keys, requester=requester)
                entry.pinned.append(dd_keys)
            except BaseException:
                self._forget(entry, unpin=True)
                raise
            self.stats.rows_dedup_served += n_dd
            self.stats.pull_bytes_saved += n_dd * row_bytes
            self.wire_counters.inc("wire_pull_dedup_rows", n_dd)
            self.wire_counters.inc("wire_pull_dedup_bytes_saved", n_dd * row_bytes)
        n_dev = int(device_served.sum())
        if n_dev:
            try:
                # pin transfer happens now, while the predecessor still holds
                # its own pin (its deferred push releases that one later)
                dev_keys = uniq[device_served]
                self.cluster.pin(dev_keys, requester=requester)
                entry.pinned.append(dev_keys)
            except BaseException:
                self._forget(entry, unpin=True)
                raise
            self.stats.rows_device_served += n_dev
            self.stats.pull_bytes_saved += n_dev * row_bytes
            self.wire_counters.inc("wire_pull_device_rows", n_dev)
            self.wire_counters.inc("wire_pull_device_bytes_saved", n_dev * row_bytes)
        if n_fresh + n_dd + n_dev < n:
            holder_seq = np.where(device_served, -1, holder_seq)
            try:
                self._resolve_conflicts(entry, uniq, holder_seq, holder_pos, entries)
            except BaseException:
                self._forget(entry, unpin=True)
                raise
        with self._lock:
            if device_resident_prev:
                self._last_prepared_keys = uniq
                self._last_prepared_seq = seq
            else:
                # a foreign (eval-style) prepare breaks the previous-batch
                # relationship the device remap relies on
                self._last_prepared_keys = None
                self._last_prepared_seq = -1
        return ws

    def _resolve_conflicts(  # pscheck: ok PS101 caller wraps with _forget(unpin=True)
        self,
        entry: _InFlight,
        uniq: np.ndarray,
        holder_seq: np.ndarray,
        holder_pos: np.ndarray,
        entries: dict[int, _InFlight],
    ) -> None:
        """Per-key version forwarding: for each conflicting predecessor (in
        batch order) wait for its training results, copy its pushed rows for
        the shared keys straight into this working set, and take over the
        MEM-PS pin on those keys. No whole-batch blocking: only the batches
        that actually share keys are awaited, and their non-shared work
        (fresh pull above, device train below) already overlapped."""
        ws = entry.ws
        # worklist of (holder seq, ws row indices), resolved oldest-first; a
        # holder aborted mid-wait re-queues its keys against the next-older
        # in-flight holder (which may still carry an unpushed update) and
        # only keys with no holder at all fall back to a cluster pull
        work = [
            (s, np.nonzero(holder_seq == s)[0], holder_pos[holder_seq == s])
            for s in sorted(set(holder_seq[holder_seq >= 0].tolist()))
        ]
        while work:
            s, idx, pos = work.pop(0)
            src = entries[s]
            self.deps.wait(self._trained_token(s))
            if src.new_params is None:
                # aborted without training (token signalled by abort/drain):
                # an older in-flight batch may still hold a pending update
                sub_keys = uniq[idx]
                with self._lock:
                    entries.update(
                        {s2: e for s2, e in self._inflight.items() if s2 < s}
                    )
                h2 = np.full(len(sub_keys), -1, dtype=np.int64)
                p2 = np.zeros(len(sub_keys), dtype=np.int64)
                for s2 in sorted((x for x in entries if x < s), reverse=True):
                    open_m = h2 < 0
                    if not open_m.any():
                        break
                    m2, pp = member_sorted(entries[s2].ws.keys, sub_keys)
                    m2 &= open_m
                    h2[m2] = s2
                    p2[m2] = pp[m2]
                for s2 in sorted(set(h2[h2 >= 0].tolist())):
                    sel = h2 == s2
                    work.append((s2, idx[sel], p2[sel]))
                work.sort(key=lambda w: w[0])
                unheld = idx[h2 < 0]
                if unheld.size:
                    pulled = self.cluster.pull(
                        uniq[unheld], requester=entry.requester, pin=True
                    )
                    ws.params[unheld] = pulled[:, : self.emb_dim]
                    if self.opt_dim:
                        ws.opt_state[unheld] = pulled[:, self.emb_dim : self.width]
                    entry.pinned.append(uniq[unheld])
                    self.stats.rows_pulled += len(unheld)
                    self.wire_counters.inc("wire_pull_fresh_rows", len(unheld))
                    self.wire_counters.inc(
                        "wire_pull_fresh_bytes", len(unheld) * self.cluster.dim * 4
                    )
                continue
            ws.params[idx] = src.new_params[pos]
            if self.opt_dim:
                ws.opt_state[idx] = (
                    src.new_opt[pos] if src.new_opt is not None else src.ws.opt_state[pos]
                )
            # pin transfer: we now hold these rows in place of (alongside)
            # the predecessor, whose deferred push will unpin its own count
            self.cluster.pin(uniq[idx], requester=entry.requester)
            entry.pinned.append(uniq[idx])
            n_fwd = len(idx)
            self.stats.rows_forwarded += n_fwd
            self.stats.pull_bytes_saved += n_fwd * self.cluster.dim * 4
            self.wire_counters.inc("wire_pull_forwarded_rows", n_fwd)
            self.wire_counters.inc(
                "wire_pull_forwarded_bytes_saved", n_fwd * self.cluster.dim * 4
            )

    # ----------------------------------------------------------- push side
    def finish_batch(
        self,
        ws: WorkingSet,
        new_params: np.ndarray,
        new_opt_state: np.ndarray | None = None,
    ) -> None:
        """Deposit a batch's trained rows without touching the cluster.

        The actual push is deferred to the pull/push stage thread (the next
        ``prepare_batch`` / ``apply_ready_pushes`` / ``drain`` call), and the
        results become the forwarding source for conflicting successors.

        With the training wire on (``wire.quantize_push``) the quantize →
        dequantize round trip happens HERE, at deposit time: the entry then
        holds the *applied* (dequantized) rows, so version forwarding, the
        deferred push, the redo log and recovery replay all see bitwise the
        rows the wire's receiver reconstructs — lossy serial and lossy
        pipelined runs stay bitwise equal (modulo device-resident reuse,
        which keeps pre-quantization rows on device by design)."""
        with self._lock:
            entry = self._inflight.get(ws.batch_id)
            if entry is None:
                raise KeyError(f"batch {ws.batch_id} is not in flight")
            new_params = np.asarray(new_params, dtype=np.float32)
            new_opt = (
                None if new_opt_state is None else np.asarray(new_opt_state, dtype=np.float32)
            )
            if self.wire.enabled:
                new_params, new_opt = self._encode_deposit(entry, new_params, new_opt)
            entry.new_params = new_params
            entry.new_opt = new_opt
            entry.trained = True
        self.deps.signal(self._trained_token(ws.batch_id))

    def _encode_deposit(
        self,
        entry: _InFlight,
        new_params: np.ndarray,
        new_opt: np.ndarray | None,
    ) -> tuple[np.ndarray, np.ndarray | None]:
        """Wire-side processing of one deposit (called under ``_lock``).

        Quantizes the push as a delta against each row's base — the batch's
        starting rows, which by push ordering are exactly what the receiver
        holds when this push applies; device-served rows (zero in the
        working set) take their base from the pushed-row window instead,
        falling back to absolute encoding on a cache miss. Stores the
        error-feedback residual per key, retains the applied rows in the
        pushed-row window, and returns the applied (dequantized) rows."""
        ws = entry.ws
        n = ws.n_working
        if self.opt_dim:
            opt_src = new_opt if new_opt is not None else ws.opt_state
            new_rows = np.concatenate(
                [new_params, np.asarray(opt_src, dtype=np.float32)], axis=1
            )
        else:
            new_rows = new_params
        if self.wire.quantize_push:
            base = (
                np.concatenate([ws.params, ws.opt_state], axis=1)
                if self.opt_dim
                else np.array(ws.params, dtype=np.float32)
            )
            has_base = np.ones(n, dtype=bool)
            if entry.device_mask is not None:
                m = entry.device_mask
                cached, found = (
                    self._pushed.get(ws.keys[m])
                    if self._pushed is not None
                    else (np.zeros((int(m.sum()), self.width), np.float32),
                          np.zeros(int(m.sum()), bool))
                )
                base[m] = cached
                has_base[m] = found
            residual, _ = self._ef.get(ws.keys)
            pkt, applied, new_res, n_bad = encode_push(
                new_rows, base, residual, self.emb_dim,
                has_base=has_base, nonfinite=self.wire.nonfinite,
            )
            self._ef.put(ws.keys, new_res, seq=entry.seq)
            entry.packet = pkt
            self.wire_counters.inc("wire_push_rows", n)
            self.wire_counters.inc(
                "wire_push_raw_bytes", n * raw_push_row_bytes(self.cluster.dim)
            )
            self.wire_counters.inc("wire_push_enc_bytes", pkt.nbytes)
            if n_bad:
                self.wire_counters.inc("wire_push_nonfinite_rows", n_bad)
            new_rows = applied
        if self._pushed is not None:
            self._pushed.put(ws.keys, new_rows, seq=entry.seq)
        if self.opt_dim:
            return new_rows[:, : self.emb_dim], new_rows[:, self.emb_dim :]
        return new_rows, new_opt

    def _check_heal_coherence(self) -> None:
        """Drop the pushed-row window if any degraded SSD heal happened
        since we last looked: re-initialized rows no longer match the
        retained copies, so neither dedup nor delta bases may use them."""
        fc = self.cluster.fault_counters
        h = fc["ssd_rows_reinit"] + fc["ssd_heal_degraded"]
        if h != self._heal_seen:
            self._heal_seen = h
            with self._lock:
                if self._pushed is not None:
                    self._pushed.clear()

    # ------------------------------------------------- wire state lifecycle
    def wire_state(self) -> "dict[str, np.ndarray] | None":
        """Checkpointable error-feedback state (``None`` when the lossy
        wire is off). The pushed-row window is deliberately NOT part of it:
        it re-warms from live traffic and must not survive a restore onto a
        cluster whose rows it never observed."""
        if self._ef is None:
            return None
        with self._lock:
            st = self._ef.state()
        return {"keys": st["keys"], "rows": st["rows"]}

    def load_wire_state(self, state: "dict[str, np.ndarray]") -> None:
        if self._ef is None:
            return
        with self._lock:
            self._ef.clear()
            keys = np.asarray(state["keys"], dtype=np.uint64)
            if len(keys):
                self._ef.put(keys, np.asarray(state["rows"], dtype=np.float32))
            if self._pushed is not None:
                self._pushed.clear()

    def apply_ready_pushes(self) -> int:
        """Apply the deferred pushes of every trained in-flight batch, oldest
        first, stopping at the first still-training one (pushes must land in
        batch order so later batches' rows supersede earlier ones)."""
        applied = 0
        with self._push_lock:
            while True:
                with self._lock:
                    entry = next(iter(self._inflight.values()), None)
                    if entry is None or not entry.trained:
                        return applied
                self._push_entry(entry)
                with self._lock:
                    self._inflight.pop(entry.seq, None)
                    if entry.ext_id is not None:
                        self._ext_to_seq.pop(entry.ext_id, None)
                    # collapse the departed batches' tokens into the floor
                    # watermark (bounded token set, no hardcoded window)
                    self.deps.set_floor(self._token_family, self._floor_bound_locked())
                applied += 1
                self.stats.deferred_pushes += 1

    def _push_entry(self, entry: _InFlight) -> None:
        ws = entry.ws
        full = self.width == self.cluster.dim
        rows = (np.empty if full else np.zeros)(
            (ws.n_working, self.cluster.dim), dtype=np.float32
        )
        rows[:, : self.emb_dim] = entry.new_params
        rows[:, self.emb_dim : self.width] = (
            entry.new_opt if entry.new_opt is not None else ws.opt_state
        )
        # entry.packet (set at deposit when the lossy wire is on) makes the
        # cluster meter the encoded bytes; the values pushed are the exact
        # dequantized rows either way
        self.cluster.push(
            ws.keys, rows, requester=entry.requester, unpin=True, packet=entry.packet
        )

    def complete_batch(
        self,
        ws: WorkingSet,
        new_params: np.ndarray,
        new_opt_state: np.ndarray | None = None,
        requester: int = 0,
    ) -> None:
        """Synchronous finish+push (serial callers: examples, LM trainer).

        Pushes land in batch order, so the push is immediate only when every
        earlier in-flight batch already finished (always true for the serial
        prepare->train->complete loop). The push is attributed to the
        requester recorded at prepare time; ``requester`` here is kept for
        signature compatibility."""
        del requester
        self.finish_batch(ws, new_params, new_opt_state)
        self.apply_ready_pushes()

    def drain(self, strict: bool = True) -> None:
        """End of stream / failure: push every trained batch, unpin the rest.

        ``strict`` (the success path) propagates a push failure — the tail
        batches' updates landing is part of the run's contract. Pass
        ``strict=False`` on the failure path, where a push that cannot land
        (e.g. its owner node died) must not mask the original pipeline
        error; the remaining batches' pins are still released."""
        try:
            self.apply_ready_pushes()
        except Exception:
            if strict:
                raise
        finally:
            with self._lock:
                remaining = list(self._inflight.values())
                self._inflight.clear()
                self._ext_to_seq.clear()
                self._last_prepared_keys = None  # residency ends with the run
                self._last_prepared_seq = -1
                if self._pushed is not None and any(e.trained for e in remaining):
                    # a trained batch whose push never landed has deposited
                    # rows in the window that the cluster never saw
                    self._pushed.clear()
            # pscheck PS101: one entry's unpin failing must not leak the
            # rest — attempt every release, then surface the first error
            # only if it would not mask an already-propagating exception
            unpin_errs: list[BaseException] = []
            for entry in remaining:
                self.deps.signal(self._trained_token(entry.seq))  # wake waiters
                for keys in entry.pinned:
                    try:
                        self.cluster.unpin(keys)
                    except Exception as err:
                        unpin_errs.append(err)
            with self._lock:
                self.deps.set_floor(self._token_family, self._floor_bound_locked())
            if unpin_errs and sys.exc_info()[0] is None:
                raise unpin_errs[0]

    def abort_batch(self, ws: WorkingSet) -> None:
        """Unpin without applying (failure path)."""
        with self._lock:
            entry = self._inflight.pop(ws.batch_id, None)
            if entry is not None and entry.ext_id is not None:
                self._ext_to_seq.pop(entry.ext_id, None)
            if ws.batch_id == self._last_prepared_seq:
                self._last_prepared_keys = None  # its rows never trained
                self._last_prepared_seq = -1
            if self._pushed is not None and entry is not None and entry.trained:
                self._pushed.clear()  # its deposited rows never landed
        # wake any prepare blocked on this batch's keys; it will see the
        # missing results and fall back to pulling the (current) cluster copy
        self.deps.signal(self._trained_token(ws.batch_id))
        with self._lock:
            self.deps.set_floor(self._token_family, self._floor_bound_locked())
        pinned = entry.pinned if entry is not None else [ws.keys]
        unpin_errs: list[BaseException] = []
        for keys in pinned:  # release every group even if one owner is down
            try:
                self.cluster.unpin(keys)
            except Exception as err:
                unpin_errs.append(err)
        if unpin_errs:
            raise unpin_errs[0]

    def _forget(self, entry: _InFlight, unpin: bool) -> None:
        with self._lock:
            self._inflight.pop(entry.seq, None)
            if entry.ext_id is not None:
                self._ext_to_seq.pop(entry.ext_id, None)
            if entry.seq == self._last_prepared_seq:
                self._last_prepared_keys = None
                self._last_prepared_seq = -1
        self.deps.signal(self._trained_token(entry.seq))
        with self._lock:
            self.deps.set_floor(self._token_family, self._floor_bound_locked())
        if unpin:
            for keys in entry.pinned:
                self.cluster.unpin(keys)

    # ------------------------------------------------------------- testing
    def n_inflight(self) -> int:
        with self._lock:
            return len(self._inflight)
