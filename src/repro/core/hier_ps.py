"""Hierarchical parameter server orchestrator — Algorithm 1 of the paper.

Per training batch:

  1. identify the union of referenced sparse keys (dedup);
  2. pull their rows from the cluster (local MEM-PS/SSD-PS + remote MEM-PS),
     pinning them for the duration of the batch;
  3. renumber keys to contiguous *working slots* and hand a dense working
     table (+ per-row optimizer state) to the device step;
  4. after the device finishes its mini-batches, push the updated rows back
     to their owner nodes and unpin.

The SSD row layout packs ``[embedding | optimizer slots]`` in one value so a
key's full training state moves through MEM-PS/SSD-PS as one fixed-size row
(the paper's fixed-size-value design).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.node import Cluster


@dataclass
class WorkingSet:
    """The device-ready working parameters of one batch."""

    keys: np.ndarray  # uint64 [n_working] — unique referenced keys
    params: np.ndarray  # float32 [n_working, emb_dim]
    opt_state: np.ndarray  # float32 [n_working, opt_dim]
    slots: np.ndarray  # int32, same shape as the batch's key tensor
    batch_id: int

    @property
    def n_working(self) -> int:
        return len(self.keys)


class HierarchicalPS:
    """Host-side orchestrator over a PS cluster."""

    def __init__(self, cluster: Cluster, emb_dim: int, opt_dim: int = 0):
        self.cluster = cluster
        self.emb_dim = emb_dim
        self.opt_dim = opt_dim
        assert cluster.dim == emb_dim + opt_dim, (
            f"cluster value dim {cluster.dim} != emb {emb_dim} + opt {opt_dim}"
        )
        self._batch_counter = 0

    # ----------------------------------------------------------- pull side
    def prepare_batch(self, batch_keys: np.ndarray, requester: int = 0) -> WorkingSet:
        """batch_keys: any-shape uint64 tensor of referenced keys (padded
        entries may use key 0 — slot 0 then maps to key 0's row, which is
        fine: its update contribution is masked out by the model)."""
        flat = np.asarray(batch_keys, dtype=np.uint64).reshape(-1)
        uniq, inverse = np.unique(flat, return_inverse=True)
        rows = self.cluster.pull(uniq, requester=requester, pin=True)
        # the pulled buffer is freshly allocated per batch, so the working
        # set can view straight into it — no re-copy of the row data
        ws = WorkingSet(
            keys=uniq,
            params=rows if self.opt_dim == 0 else rows[:, : self.emb_dim],
            opt_state=rows[:, self.emb_dim :],
            slots=inverse.astype(np.int32).reshape(np.shape(batch_keys)),
            batch_id=self._batch_counter,
        )
        self._batch_counter += 1
        return ws

    # ----------------------------------------------------------- push side
    def complete_batch(
        self,
        ws: WorkingSet,
        new_params: np.ndarray,
        new_opt_state: np.ndarray | None = None,
        requester: int = 0,
    ) -> None:
        rows = np.empty((ws.n_working, self.cluster.dim), dtype=np.float32)
        rows[:, : self.emb_dim] = new_params
        rows[:, self.emb_dim :] = (
            new_opt_state if new_opt_state is not None else ws.opt_state
        )
        self.cluster.push(ws.keys, rows, requester=requester, unpin=True)

    def abort_batch(self, ws: WorkingSet) -> None:
        """Unpin without applying (failure path)."""
        order, bounds = self.cluster._partition(ws.keys)
        sorted_keys = ws.keys[order]
        for node_id in range(self.cluster.n_nodes):
            lo, hi = int(bounds[node_id]), int(bounds[node_id + 1])
            if lo < hi and self.cluster.nodes[node_id].alive:
                self.cluster.nodes[node_id].mem.unpin(sorted_keys[lo:hi])
