"""MEM-PS: per-node DRAM parameter cache (paper Section 5 / Appendix D).

Eviction policy straight from Appendix D:

* every visited parameter is placed in an **LRU** tier;
* rows evicted from the LRU tier fall into an **LFU** tier (frequency counted
  across both tiers);
* rows evicted from the LFU tier are flushed to the SSD-PS (if dirty) before
  their memory is released;
* the working parameters of in-flight batches are **pinned** — they cannot be
  evicted until their batch completes (pipeline data-integrity guarantee).

Rows live in a preallocated float32 arena [capacity, dim]; bookkeeping is
O(1) per op (OrderedDict recency list + freq-bucket LFU). Dirty rows evicted
from the LFU tier are staged in a bounded write buffer and written to the
SSD-PS in file-sized batches (the paper's "chunk updated parameters into
files" behaviour); the buffer is consulted on cache misses so no update is
ever lost or reordered.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass

import numpy as np

from repro.core.ssd_ps import SSDParameterServer


@dataclass
class MemStats:
    hits: int = 0
    misses: int = 0
    evict_lru_to_lfu: int = 0
    evict_lfu_to_ssd: int = 0
    flushed_rows: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.hits + self.misses)


class _Row:
    __slots__ = ("row", "freq", "dirty", "pins", "tier")

    def __init__(self, row: int):
        self.row = row
        self.freq = 0
        self.dirty = False
        self.pins = 0
        self.tier = "lru"


class MemParameterServer:
    def __init__(
        self,
        ssd: SSDParameterServer,
        capacity: int,
        lru_frac: float = 0.5,
        flush_batch: int = 2048,
    ):
        self.ssd = ssd
        self.dim = ssd.dim
        self.capacity = int(capacity)
        self.lru_capacity = max(1, int(capacity * lru_frac))
        self.arena = np.zeros((self.capacity, self.dim), dtype=np.float32)
        self.free_rows: list[int] = list(range(self.capacity - 1, -1, -1))
        self.entries: dict[int, _Row] = {}
        self.lru: OrderedDict[int, None] = OrderedDict()
        self.lfu_buckets: dict[int, OrderedDict[int, None]] = {}
        self.flush_batch = flush_batch
        # evicted-but-dirty rows awaiting a batched SSD write (key -> value)
        self._pending: OrderedDict[int, np.ndarray] = OrderedDict()
        self.stats = MemStats()
        self._lock = threading.RLock()

    # ------------------------------------------------------------ internals
    def _lfu_add(self, key: int, ent: _Row) -> None:
        ent.tier = "lfu"
        self.lfu_buckets.setdefault(ent.freq, OrderedDict())[key] = None

    def _lfu_remove(self, key: int, ent: _Row) -> None:
        bucket = self.lfu_buckets.get(ent.freq)
        if bucket is not None and key in bucket:
            del bucket[key]
            if not bucket:
                del self.lfu_buckets[ent.freq]

    def _touch(self, key: int, ent: _Row) -> None:
        """Record a visit: bump frequency, (re)place into the LRU tier."""
        if ent.tier == "lru":
            ent.freq += 1
            self.lru.move_to_end(key)
        else:  # promoted back from LFU on re-visit (paper: visits go to LRU)
            self._lfu_remove(key, ent)
            ent.freq += 1
            ent.tier = "lru"
            self.lru[key] = None
        self._shrink_lru()

    def _shrink_lru(self) -> None:
        # LRU-tier overflow demotes the coldest unpinned rows into LFU
        while len(self.lru) > self.lru_capacity:
            demoted = False
            for key in self.lru:
                ent = self.entries[key]
                if ent.pins == 0:
                    del self.lru[key]
                    self._lfu_add(key, ent)
                    self.stats.evict_lru_to_lfu += 1
                    demoted = True
                    break
            if not demoted:
                return  # everything pinned; let the LRU tier grow

    def _evict_one(self) -> bool:
        """Free one arena row, preferring the LFU tier; stage dirty rows."""
        for freq in sorted(self.lfu_buckets):
            for key in self.lfu_buckets[freq]:
                ent = self.entries[key]
                if ent.pins == 0:
                    self._release(key, ent)
                    self.stats.evict_lfu_to_ssd += 1
                    return True
        # fall back to the LRU tier (cache smaller than the working set)
        for key in self.lru:
            ent = self.entries[key]
            if ent.pins == 0:
                del self.lru[key]
                self._release(key, ent)
                return True
        return False

    def _release(self, key: int, ent: _Row) -> None:
        if ent.tier == "lfu":
            self._lfu_remove(key, ent)
        if ent.dirty:
            self._pending[key] = self.arena[ent.row].copy()
            if len(self._pending) >= self.flush_batch:
                self._flush_pending()
        self.free_rows.append(ent.row)
        del self.entries[key]

    def _flush_pending(self) -> None:
        if not self._pending:
            return
        keys = np.fromiter(self._pending.keys(), dtype=np.uint64, count=len(self._pending))
        vals = np.stack(list(self._pending.values()))
        self.ssd.write_batch(keys, vals)
        self.stats.flushed_rows += len(keys)
        self._pending.clear()

    def _alloc(self, key: int) -> _Row:
        if not self.free_rows and not self._evict_one():
            raise MemoryError(
                "MEM-PS cache exhausted with all rows pinned; increase capacity "
                "or reduce the prefetch-queue depth"
            )
        ent = _Row(self.free_rows.pop())
        self.entries[key] = ent
        self.lru[key] = None
        return ent

    # ------------------------------------------------------------ interface
    def pull(self, keys: np.ndarray, pin: bool = True) -> np.ndarray:
        """Gather rows for unique ``keys``; misses read from the SSD-PS."""
        keys = np.asarray(keys, dtype=np.uint64)
        out = np.empty((len(keys), self.dim), dtype=np.float32)
        with self._lock:
            ssd_miss: list[int] = []
            for i, k in enumerate(keys.tolist()):
                ent = self.entries.get(k)
                if ent is not None:
                    self.stats.hits += 1
                    self._touch(k, ent)
                    if pin:
                        ent.pins += 1
                    out[i] = self.arena[ent.row]
                    continue
                pending = self._pending.pop(k, None)
                if pending is not None:  # evicted but not yet on SSD
                    self.stats.hits += 1
                    ent = self._alloc(k)
                    ent.freq = 1
                    ent.dirty = True  # still newer than the SSD copy
                    if pin:
                        ent.pins += 1
                    self.arena[ent.row] = pending
                    out[i] = pending
                    continue
                ssd_miss.append(i)
            if ssd_miss:
                self.stats.misses += len(ssd_miss)
                midx = np.asarray(ssd_miss, dtype=np.int64)
                vals = self.ssd.read_batch(keys[midx])
                for j, i in enumerate(ssd_miss):
                    k = int(keys[i])
                    ent = self._alloc(k)
                    ent.freq = 1
                    if pin:
                        ent.pins += 1
                    self.arena[ent.row] = vals[j]
                    out[i] = vals[j]
                self._shrink_lru()
        return out

    def push(self, keys: np.ndarray, values: np.ndarray, unpin: bool = True) -> None:
        """Apply updated rows (paper: updates land in the pinned cache rows)."""
        keys = np.asarray(keys, dtype=np.uint64)
        values = np.asarray(values, dtype=np.float32)
        with self._lock:
            for i, k in enumerate(keys.tolist()):
                ent = self.entries.get(k)
                if ent is None:  # not pinned/pulled first: treat as fresh row
                    self._pending.pop(k, None)
                    ent = self._alloc(k)
                    ent.freq = 1
                self.arena[ent.row] = values[i]
                ent.dirty = True
                if unpin and ent.pins > 0:
                    ent.pins -= 1

    def unpin(self, keys: np.ndarray) -> None:
        with self._lock:
            for k in np.asarray(keys, dtype=np.uint64).tolist():
                ent = self.entries.get(k)
                if ent is not None and ent.pins > 0:
                    ent.pins -= 1

    def flush_all(self) -> None:
        """Write every dirty row to the SSD-PS (checkpoint/shutdown path)."""
        with self._lock:
            dirty = [k for k, e in self.entries.items() if e.dirty]
            if dirty:
                rows = np.asarray([self.entries[k].row for k in dirty], dtype=np.int64)
                self.ssd.write_batch(np.asarray(dirty, dtype=np.uint64), self.arena[rows])
                self.stats.flushed_rows += len(dirty)
                for k in dirty:
                    self.entries[k].dirty = False
            self._flush_pending()

    @property
    def n_cached(self) -> int:
        return len(self.entries)
