"""MEM-PS: per-node DRAM parameter cache (paper Section 5 / Appendix D).

Eviction policy straight from Appendix D:

* every visited parameter is placed in an **LRU** tier;
* rows evicted from the LRU tier fall into an **LFU** tier (frequency counted
  across both tiers);
* rows evicted from the LFU tier are flushed to the SSD-PS (if dirty) before
  their memory is released;
* the working parameters of in-flight batches are **pinned** — they cannot be
  evicted until their batch completes (pipeline data-integrity guarantee).

All bookkeeping is **array-backed and batch-vectorized** (DESIGN.md §2): a
batched open-addressing ``U64Index`` maps key -> arena row, and per-row state
(frequency, pin count, dirty bit, tier, recency stamp) lives in flat numpy
arrays indexed by arena row. A pull or push of N keys runs a constant number
of numpy passes — there is no Python loop over keys on the hit path, the
miss path, or the eviction path.

Batch semantics (the canonical contract pinned by tests/test_mem_ps_model.py;
a reference dict-model implements the same spec):

* ``pull``/``push`` dedup their keys; per-key stats/freq/pin counts use the
  occurrence counts, values use the last occurrence (push).
* recency stamps within a batch follow request order (first occurrence);
* hits are serviced (touched, pinned, gathered) before any allocation;
* misses/pending-hits allocate in request order, evicting in one batched
  pass: LFU victims first ordered by (freq, LFU-entry time), then LRU
  victims ordered by recency — pinned rows are never victims. If the batch
  needs more rows than free+evictable, it proceeds in rounds so an unpinned
  batch larger than the cache cycles rows through the staging buffer exactly
  like the sequential implementation did; if a round finds nothing evictable
  the documented ``MemoryError`` is raised.
* dirty evicted rows are staged in a bounded write buffer (array-backed,
  indexed by its own ``U64Index``) and written to the SSD-PS in file-sized
  batches; the buffer is consulted (batched) on misses so no update is ever
  lost or reordered.
* the LRU tier is re-shrunk at the end of every pull *and* push (the
  sequential version leaked LRU capacity on the pending-hit path).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass

import numpy as np

from repro.core.hash_index import U64Index
from repro.core.ssd_ps import SSDParameterServer

_FREE = np.int8(0)
_LRU = np.int8(1)
_LFU = np.int8(2)


@dataclass
class MemStats:
    hits: int = 0
    misses: int = 0
    evict_lru_to_lfu: int = 0
    evict_lfu_to_ssd: int = 0
    flushed_rows: int = 0

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.hits + self.misses)


class MemParameterServer:
    def __init__(
        self,
        ssd: SSDParameterServer,
        capacity: int,
        lru_frac: float = 0.5,
        flush_batch: int = 2048,
    ):
        self.ssd = ssd
        self.dim = ssd.dim
        self.capacity = int(capacity)
        self.lru_capacity = max(1, int(capacity * lru_frac))
        self.flush_batch = int(flush_batch)
        self.arena = np.zeros((self.capacity, self.dim), dtype=np.float32)

        # per-arena-row state (valid where tier != _FREE)
        self.key_of_row = np.zeros(self.capacity, dtype=np.uint64)
        self.freq = np.zeros(self.capacity, dtype=np.int64)
        self.pins = np.zeros(self.capacity, dtype=np.int64)
        self.dirty = np.zeros(self.capacity, dtype=bool)
        self.tier = np.full(self.capacity, _FREE, dtype=np.int8)
        self.last_used = np.zeros(self.capacity, dtype=np.int64)  # LRU recency
        self.lfu_time = np.zeros(self.capacity, dtype=np.int64)  # LFU entry order
        self._clock = 0
        self._n_lru = 0
        self._n_lfu = 0

        self.index = U64Index(self.capacity)
        self._free = np.arange(self.capacity - 1, -1, -1, dtype=np.int64)
        self._free_n = self.capacity

        # staging buffer for evicted-but-dirty rows awaiting a batched SSD
        # write; sized so one eviction pass can never overflow it
        pcap = self.flush_batch + self.capacity
        self._pend_vals = np.zeros((pcap, self.dim), dtype=np.float32)
        self._pend_index = U64Index(pcap)
        self._pend_free = np.arange(pcap - 1, -1, -1, dtype=np.int64)
        self._pend_free_n = pcap

        self.stats = MemStats()
        self._lock = threading.RLock()

    # ------------------------------------------------------------ internals
    def _take_free(self, n: int) -> np.ndarray:
        rows = self._free[self._free_n - n : self._free_n].copy()
        self._free_n -= n
        return rows

    def _give_free(self, rows: np.ndarray) -> None:
        self._free[self._free_n : self._free_n + len(rows)] = rows
        self._free_n += len(rows)

    def _evictable_count(self) -> int:
        return int(((self.tier != _FREE) & (self.pins == 0)).sum())

    def _evict_rows(self, need: int) -> None:
        """Free ``need`` arena rows in one batched pass (caller checked
        feasibility): LFU victims by (freq, LFU-entry time), then LRU
        victims by recency. Dirty victims are staged for the SSD."""
        evictable = (self.tier != _FREE) & (self.pins == 0)
        lfu_rows = np.nonzero(evictable & (self.tier == _LFU))[0]
        order = np.lexsort((self.lfu_time[lfu_rows], self.freq[lfu_rows]))
        n_lfu = min(need, len(lfu_rows))
        victims = lfu_rows[order[:n_lfu]]
        self.stats.evict_lfu_to_ssd += n_lfu
        self._n_lfu -= n_lfu
        if n_lfu < need:
            lru_rows = np.nonzero(evictable & (self.tier == _LRU))[0]
            order = np.argsort(self.last_used[lru_rows], kind="stable")
            lru_victims = lru_rows[order[: need - n_lfu]]
            self._n_lru -= len(lru_victims)
            victims = np.concatenate([victims, lru_victims])
        d = victims[self.dirty[victims]]
        if d.size:
            self._pend_add(self.key_of_row[d], self.arena[d])
        self.index.delete(self.key_of_row[victims])
        self.tier[victims] = _FREE
        self.dirty[victims] = False
        self._give_free(victims)
        if len(self._pend_index) >= self.flush_batch:
            self._flush_pending()

    def _shrink_lru(self) -> None:
        """Demote the coldest unpinned LRU rows into LFU until the LRU tier
        fits (all in one pass; if everything is pinned the tier may grow)."""
        excess = self._n_lru - self.lru_capacity
        if excess <= 0:
            return
        lru_rows = np.nonzero((self.tier == _LRU) & (self.pins == 0))[0]
        k = min(excess, len(lru_rows))
        if k <= 0:
            return
        order = np.argsort(self.last_used[lru_rows], kind="stable")
        demoted = lru_rows[order[:k]]
        self.tier[demoted] = _LFU
        self.lfu_time[demoted] = self._clock + np.arange(k)
        self._clock += k
        self._n_lru -= k
        self._n_lfu += k
        self.stats.evict_lru_to_lfu += k

    # ------------------------------------------------- pending write buffer
    def _pend_add(self, keys: np.ndarray, vals: np.ndarray) -> None:
        slots = self._pend_free[self._pend_free_n - len(keys) : self._pend_free_n].copy()
        self._pend_free_n -= len(keys)
        self._pend_vals[slots] = vals
        self._pend_index.insert(keys, slots)

    def _pend_release(self, keys: np.ndarray, slots: np.ndarray) -> None:
        self._pend_index.delete(keys)
        self._pend_free[self._pend_free_n : self._pend_free_n + len(slots)] = slots
        self._pend_free_n += len(slots)

    def _flush_pending(self) -> None:
        keys, slots = self._pend_index.items()
        if len(keys) == 0:
            return
        self.ssd.write_batch(keys, self._pend_vals[slots])
        self.stats.flushed_rows += len(keys)
        self._pend_index.clear()
        pcap = len(self._pend_free)
        self._pend_free[:] = np.arange(pcap - 1, -1, -1, dtype=np.int64)
        self._pend_free_n = pcap

    # ------------------------------------------------------------ interface
    def _dedup(self, keys: np.ndarray):
        """(uniq, first_idx, inverse, counts); inverse/counts are None when
        the input is already strictly increasing (identity dedup, all-ones
        counts). The hierarchy's callers — HierarchicalPS after its
        ``np.unique`` and the owner-sorted cluster segments — always pass
        sorted unique keys, so the hot path skips the O(n log n) dedup."""
        if len(keys) < 2 or bool((keys[1:] > keys[:-1]).all()):
            return keys, np.arange(len(keys), dtype=np.int64), None, None
        uniq, first_idx, inverse, counts = np.unique(
            keys, return_index=True, return_inverse=True, return_counts=True
        )
        return uniq, first_idx.astype(np.int64), inverse, counts

    def pull(self, keys: np.ndarray, pin: bool = True) -> np.ndarray:
        """Gather rows for ``keys``; misses read from the SSD-PS."""
        keys = np.asarray(keys, dtype=np.uint64).reshape(-1)
        if keys.size == 0:
            return np.empty((0, self.dim), dtype=np.float32)
        with self._lock:
            uniq, first_idx, inverse, counts = self._dedup(keys)
            # advance the clock up front so recency stamps stay globally
            # unique even if pin pressure aborts the batch midway
            base = self._clock
            self._clock += len(keys)
            rows = self.index.lookup(uniq)
            hit = rows >= 0
            n_hit = int(hit.sum())
            all_hit = n_hit == len(uniq)
            hrows = rows if all_hit else rows[hit]
            if n_hit:
                c_hit = None if counts is None else counts[hit]
                self.stats.hits += n_hit if c_hit is None else int(c_hit.sum())
                self.freq[hrows] += 1 if c_hit is None else c_hit
                if self._n_lfu:
                    promoted = hrows[self.tier[hrows] == _LFU]
                    self.tier[promoted] = _LRU
                    self._n_lru += len(promoted)
                    self._n_lfu -= len(promoted)
                self.last_used[hrows] = base + (first_idx if all_hit else first_idx[hit])
                if pin:
                    self.pins[hrows] += 1 if c_hit is None else c_hit
            if all_hit:
                out_u = self.arena[hrows]  # the one gather on the hit path
                self._shrink_lru()
                return out_u if inverse is None else out_u[inverse]
            out_u = np.empty((len(uniq), self.dim), dtype=np.float32)
            if n_hit:
                out_u[hit] = self.arena[hrows]
            absent = np.nonzero(~hit)[0]
            # allocate in request order; rounds let an unpinned over-capacity
            # batch cycle rows through the staging buffer
            absent = absent[np.argsort(first_idx[absent], kind="stable")]
            while absent.size:
                avail = self._free_n + self._evictable_count()
                if avail == 0:
                    raise MemoryError(
                        "MEM-PS cache exhausted with all rows pinned; increase "
                        "capacity or reduce the prefetch-queue depth"
                    )
                chunk, absent = absent[:avail], absent[avail:]
                n = len(chunk)
                if n > self._free_n:
                    self._evict_rows(n - self._free_n)
                new_rows = self._take_free(n)
                a_keys = uniq[chunk]
                c_chunk = np.ones(n, dtype=np.int64) if counts is None else counts[chunk]
                pend_slots = self._pend_index.lookup(a_keys)
                from_pend = pend_slots >= 0
                self.stats.hits += int(c_chunk[from_pend].sum())
                self.stats.misses += int(c_chunk[~from_pend].sum())
                vals = np.empty((n, self.dim), dtype=np.float32)
                if from_pend.any():
                    psl = pend_slots[from_pend]
                    vals[from_pend] = self._pend_vals[psl]
                    self._pend_release(a_keys[from_pend], psl)
                if (~from_pend).any():
                    vals[~from_pend] = self.ssd.read_batch(a_keys[~from_pend])
                self.arena[new_rows] = vals
                self.key_of_row[new_rows] = a_keys
                self.freq[new_rows] = c_chunk
                self.pins[new_rows] = c_chunk if pin else 0
                self.dirty[new_rows] = from_pend  # still newer than SSD copy
                self.tier[new_rows] = _LRU
                self.last_used[new_rows] = base + first_idx[chunk]
                self._n_lru += n
                self.index.insert(a_keys, new_rows)
                out_u[chunk] = vals
            self._shrink_lru()
            return out_u if inverse is None else out_u[inverse]

    def push(self, keys: np.ndarray, values: np.ndarray, unpin: bool = True) -> None:
        """Apply updated rows (paper: updates land in the pinned cache rows)."""
        keys = np.asarray(keys, dtype=np.uint64).reshape(-1)
        if keys.size == 0:
            return
        values = np.asarray(values, dtype=np.float32).reshape(len(keys), -1)
        with self._lock:
            uniq, first_idx, inverse, counts = self._dedup(keys)
            base = self._clock
            self._clock += len(keys)
            if inverse is None:
                vals_u = values
            else:
                last_idx = np.empty(len(uniq), dtype=np.int64)
                last_idx[inverse] = np.arange(len(keys))  # last occurrence wins
                vals_u = values[last_idx]
            rows = self.index.lookup(uniq)
            hit = rows >= 0
            n_hit = int(hit.sum())
            all_hit = n_hit == len(uniq)
            hrows = rows if all_hit else rows[hit]
            if n_hit:
                self.arena[hrows] = vals_u if all_hit else vals_u[hit]
                self.dirty[hrows] = True
                if unpin:
                    c_hit = 1 if counts is None else counts[hit]
                    self.pins[hrows] = np.maximum(self.pins[hrows] - c_hit, 0)
            if all_hit:
                self._shrink_lru()
                return
            absent = np.nonzero(~hit)[0]
            absent = absent[np.argsort(first_idx[absent], kind="stable")]
            while absent.size:  # not pulled first: treat as fresh rows
                avail = self._free_n + self._evictable_count()
                if avail == 0:
                    raise MemoryError(
                        "MEM-PS cache exhausted with all rows pinned; increase "
                        "capacity or reduce the prefetch-queue depth"
                    )
                chunk, absent = absent[:avail], absent[avail:]
                n = len(chunk)
                a_keys = uniq[chunk]
                pend_slots = self._pend_index.lookup(a_keys)
                from_pend = pend_slots >= 0
                if from_pend.any():  # pushed value supersedes the staged one
                    self._pend_release(a_keys[from_pend], pend_slots[from_pend])
                if n > self._free_n:
                    self._evict_rows(n - self._free_n)
                new_rows = self._take_free(n)
                self.arena[new_rows] = vals_u[chunk]
                self.key_of_row[new_rows] = a_keys
                self.freq[new_rows] = 1
                self.pins[new_rows] = 0
                self.dirty[new_rows] = True
                self.tier[new_rows] = _LRU
                self.last_used[new_rows] = base + first_idx[chunk]
                self._n_lru += n
                self.index.insert(a_keys, new_rows)
            self._shrink_lru()

    def pin(self, keys: np.ndarray) -> None:
        """Add a pin to already-cached rows (per-key occurrence counts).

        Used by the pipeline's version forwarding: a successor batch takes
        over a predecessor's rows without re-pulling them, so it must take
        over the eviction pin too. Keys not currently cached are ignored —
        their value safety is guaranteed by the dirty-row staging buffer."""
        keys = np.asarray(keys, dtype=np.uint64).reshape(-1)
        if keys.size == 0:
            return
        with self._lock:
            uniq, counts = np.unique(keys, return_counts=True)
            rows = self.index.lookup(uniq)
            hit = rows >= 0
            self.pins[rows[hit]] += counts[hit]

    def unpin(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, dtype=np.uint64).reshape(-1)
        if keys.size == 0:
            return
        with self._lock:
            uniq, counts = np.unique(keys, return_counts=True)
            rows = self.index.lookup(uniq)
            hit = rows >= 0
            hrows = rows[hit]
            self.pins[hrows] = np.maximum(self.pins[hrows] - counts[hit], 0)

    @property
    def total_pins(self) -> int:
        """Sum of live pin counts (pin-leak regression checks)."""
        with self._lock:
            return int(self.pins[self.tier != _FREE].sum())

    def flush_all(self) -> None:
        """Write every dirty row to the SSD-PS (checkpoint/shutdown path)."""
        with self._lock:
            d = np.nonzero((self.tier != _FREE) & self.dirty)[0]
            if d.size:
                self.ssd.write_batch(self.key_of_row[d], self.arena[d])
                self.stats.flushed_rows += len(d)
                self.dirty[d] = False
            self._flush_pending()

    @property
    def n_cached(self) -> int:
        return self.capacity - self._free_n

    # ------------------------------------------------------------- testing
    def debug_snapshot(self) -> tuple[dict, dict]:
        """(cached, pending) visible state for the model-parity tests.

        ``cached``: key -> (freq, pins, dirty, tier, value tuple);
        ``pending``: key -> value tuple. Test-only (per-key Python loop).
        """
        tiers = {int(_LRU): "lru", int(_LFU): "lfu"}
        cached = {}
        for r in np.nonzero(self.tier != _FREE)[0]:
            cached[int(self.key_of_row[r])] = (
                int(self.freq[r]),
                int(self.pins[r]),
                bool(self.dirty[r]),
                tiers[int(self.tier[r])],
                tuple(float(x) for x in self.arena[r]),
            )
        pk, ps = self._pend_index.items()
        pending = {
            int(k): tuple(float(x) for x in self._pend_vals[s])
            for k, s in zip(pk.tolist(), ps.tolist())
        }
        return cached, pending
