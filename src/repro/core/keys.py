"""Key hashing / partitioning for the hierarchical parameter server.

Parameters are identified by 64-bit keys. The paper partitions keys across
nodes and across GPUs with modulo hashing ("the features of the input
training data are usually distributed randomly"). We hash with splitmix64
first so that *any* key distribution partitions evenly, then take the modulo.
All functions are vectorized over numpy uint64 arrays and deterministic —
determinism matters: missing-key initialization is derived from the key so
that the hierarchical-PS path and the flat in-memory path train identically
(the paper's "lossless" property becomes an exact, testable invariant).
"""

from __future__ import annotations

import numpy as np

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_MIX1 = np.uint64(0xBF58476D1CE4E5B9)
_MIX2 = np.uint64(0x94D049BB133111EB)
_U64 = np.uint64

# --- table namespacing (multi-table PS client, DESIGN.md §6) ---------------
# The top TABLE_BITS of a cluster key tag which named table the row belongs
# to; the low KEY_BITS carry the caller's raw key. Table id 0 tags to the
# identity, so a single anonymous table (the pre-multi-table API) lives in
# exactly the same key space as before.
TABLE_BITS = 8
KEY_BITS = 64 - TABLE_BITS
MAX_TABLES = 1 << TABLE_BITS
MAX_RAW_KEY = np.uint64((1 << KEY_BITS) - 1)  # inclusive
_RAW_MASK = np.uint64((1 << KEY_BITS) - 1)


def namespace_keys(keys: np.ndarray, table_id: int) -> np.ndarray:
    """Tag raw per-table keys into the shared cluster key space.

    The tag occupies the high TABLE_BITS, so two tables' keys can never
    collide; the hash-partitioned owner map then spreads each table's rows
    across all nodes (splitmix64 mixes the high bits into every output bit).
    """
    if not 0 <= table_id < MAX_TABLES:
        raise ValueError(f"table_id {table_id} out of range [0, {MAX_TABLES})")
    keys = np.asarray(keys, dtype=np.uint64)
    if keys.size and bool((keys > _RAW_MASK).any()):
        raise ValueError(f"raw keys must fit in {KEY_BITS} bits (max {int(_RAW_MASK)})")
    if table_id == 0:
        return keys
    return keys | _U64(table_id << KEY_BITS)


def split_namespaced(keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Inverse of :func:`namespace_keys`: (table_ids int64, raw uint64)."""
    keys = np.asarray(keys, dtype=np.uint64)
    return (keys >> _U64(KEY_BITS)).astype(np.int64), keys & _RAW_MASK


def splitmix64(x: np.ndarray) -> np.ndarray:
    """Bijective 64-bit finalizer (vectorized). Input/output uint64."""
    x = np.asarray(x, dtype=np.uint64)
    with np.errstate(over="ignore"):
        z = x + _GOLDEN
        z = (z ^ (z >> _U64(30))) * _MIX1
        z = (z ^ (z >> _U64(27))) * _MIX2
        z = z ^ (z >> _U64(31))
    return z


def hash_keys(keys: np.ndarray, seed: int = 0) -> np.ndarray:
    with np.errstate(over="ignore"):
        return splitmix64(np.asarray(keys, dtype=np.uint64) ^ _U64(seed))


def key_to_node(keys: np.ndarray, n_nodes: int, seed: int = 1) -> np.ndarray:
    """Owner node of each key (paper: modulo partitioning across MEM-PS)."""
    return (hash_keys(keys, seed) % _U64(n_nodes)).astype(np.int64)


def key_to_shard(keys: np.ndarray, n_shards: int, seed: int = 2) -> np.ndarray:
    """Owner device shard within the HBM-PS (paper: per-GPU partition)."""
    return (hash_keys(keys, seed) % _U64(n_shards)).astype(np.int64)


def deterministic_init(keys: np.ndarray, dim: int, scale: float = 0.01, seed: int = 3) -> np.ndarray:
    """Per-key deterministic pseudo-random init, vectorized.

    Row i is a function of keys[i] only — independent of read order, node
    count, or cache state. Values ~ scale * U(-1, 1) per component.
    """
    keys = np.asarray(keys, dtype=np.uint64)
    cols = np.arange(dim, dtype=np.uint64)
    with np.errstate(over="ignore"):
        grid = hash_keys(keys, seed)[:, None] * _GOLDEN + cols[None, :] * _MIX1
        bits = splitmix64(grid)
    u = (bits >> _U64(11)).astype(np.float64) * (1.0 / (1 << 53))  # [0,1)
    return ((u * 2.0 - 1.0) * scale).astype(np.float32)


def member_sorted(ref: np.ndarray, q: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Membership of sorted ``q`` in sorted-unique ``ref``.

    Returns (mask, pos): ``mask[i]`` iff ``q[i]`` is in ``ref``, and
    ``pos[i]`` is its index there (valid only where ``mask``). One
    searchsorted pass — the shared primitive behind the in-flight conflict
    scan (hier_ps) and the device working-set reuse plan (hbm_ps)."""
    if len(ref) == 0 or len(q) == 0:
        return np.zeros(len(q), dtype=bool), np.zeros(len(q), dtype=np.int64)
    pos = np.searchsorted(ref, q)
    pos_c = np.minimum(pos, len(ref) - 1)
    return ref[pos_c] == q, pos_c


def partition_by_owner(keys: np.ndarray, owners: np.ndarray, n_owners: int):
    """Group ``keys`` by owner id.

    Returns (order, splits) such that keys[order] is owner-sorted and
    np.split(keys[order], splits) yields one array per owner. ``order`` lets
    callers scatter per-owner results back into request order.
    """
    order = np.argsort(owners, kind="stable")
    counts = np.bincount(owners, minlength=n_owners)
    splits = np.cumsum(counts)[:-1]
    return order, splits
