"""Named embedding tables over one shared PS cluster (DESIGN.md §6).

The paper's production system serves many heterogeneous sparse feature
families (query, ad, user-portrait slots) out of one HBM/MEM/SSD hierarchy.
This module provides the vocabulary for that:

* :class:`RowSchema` — the named fields of one table's row (an ``emb``
  field first, then optimizer slots of any width). It replaces the
  ``emb_dim``/``opt_dim`` slicing previously hardcoded through
  ``hier_ps.py``: a row's layout is data, not convention.
* :class:`TableSpec` — a named table binding a schema to a table id. Keys
  are namespaced into the shared cluster key space by high-bit tagging
  (``keys.namespace_keys``), so tables can never collide while the
  hash-partitioned owner map still spreads every table across all nodes.
* :class:`TableRegistry` — the set of tables hosted by one cluster. The
  cluster row width is the *maximum* schema width across tables; narrower
  tables use a prefix of the fixed-size row — the paper's fixed-size-value
  design survives multi-tenancy. The registry also builds the per-key
  missing-row initializer (each table's ``emb`` field gets the
  deterministic per-key init at its own width/scale; optimizer slots and
  the unused tail are zero) and serializes to/from checkpoint manifests.

Sessions over these tables live in :mod:`repro.core.client`.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from repro.core.keys import (
    MAX_TABLES,
    deterministic_init,
    namespace_keys,
    split_namespaced,
)


@dataclass(frozen=True)
class RowSchema:
    """Named fields of one table row: ``((name, width), ...)``.

    The first field is the embedding (randomly initialized for unseen
    keys); every later field is optimizer state of arbitrary width
    (zero-initialized). The concatenation, in order, is the fixed-size
    value that moves through MEM-PS/SSD-PS as one float32 row.
    """

    fields: tuple[tuple[str, int], ...]

    def __post_init__(self):
        if not self.fields:
            raise ValueError("RowSchema needs at least one field")
        names = [n for n, _ in self.fields]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate field names in {names}")
        for n, w in self.fields:
            if int(w) <= 0:
                raise ValueError(f"field {n!r} has non-positive width {w}")

    # ------------------------------------------------------------- layout
    @property
    def width(self) -> int:
        return sum(w for _, w in self.fields)

    @property
    def emb_dim(self) -> int:
        return self.fields[0][1]

    @property
    def opt_dim(self) -> int:
        return self.width - self.emb_dim

    def offset_of(self, name: str) -> int:
        off = 0
        for n, w in self.fields:
            if n == name:
                return off
            off += w
        raise KeyError(f"no field {name!r} in {self.fields}")

    def slice_of(self, name: str) -> slice:
        off = self.offset_of(name)
        return slice(off, off + dict(self.fields)[name])

    # ------------------------------------------------------- constructors
    @classmethod
    def embedding(cls, dim: int) -> "RowSchema":
        """Inference/serving rows: just the embedding."""
        return cls((("emb", int(dim)),))

    @classmethod
    def with_adagrad(cls, dim: int) -> "RowSchema":
        """The paper's training row: ``[emb | adagrad accumulator]``."""
        return cls((("emb", int(dim)), ("adagrad", int(dim))))

    @classmethod
    def with_slots(cls, dim: int, **slots: int) -> "RowSchema":
        """Embedding plus arbitrary named optimizer slots, e.g.
        ``RowSchema.with_slots(8, m=8, v=8, step=1)`` for row-Adam."""
        return cls((("emb", int(dim)),) + tuple((n, int(w)) for n, w in slots.items()))

    # ------------------------------------------------------- serialization
    def to_manifest(self) -> list:
        return [[n, int(w)] for n, w in self.fields]

    @classmethod
    def from_manifest(cls, m: list) -> "RowSchema":
        return cls(tuple((str(n), int(w)) for n, w in m))


@dataclass(frozen=True)
class TableSpec:
    """One named table: schema + id (the key-namespace tag) + init scale.

    ``table_id=None`` (the default) asks the registry to assign the next
    free id at registration; an explicit id is honored exactly or rejected
    if taken — never silently remapped, since the id IS the key namespace
    and a remap would point the table at different rows. ``init_scale=None``
    defers to the hosting cluster's ``init_scale`` so a single-table client
    initializes bit-identically to the pre-multi-table code path.
    """

    name: str
    schema: RowSchema
    table_id: int | None = None
    init_scale: float | None = None

    def __post_init__(self):
        if self.table_id is not None and not 0 <= self.table_id < MAX_TABLES:
            raise ValueError(f"table_id {self.table_id} out of [0, {MAX_TABLES})")

    def _assigned_id(self) -> int:
        if self.table_id is None:
            raise ValueError(
                f"table {self.name!r} has no table_id yet — register it first"
            )
        return self.table_id

    def namespace(self, keys: np.ndarray) -> np.ndarray:
        """Raw per-table keys -> shared cluster key space."""
        return namespace_keys(keys, self._assigned_id())

    def raw(self, keys: np.ndarray) -> np.ndarray:
        """Cluster keys -> this table's raw keys (drops the tag)."""
        return split_namespaced(keys)[1]

    def to_manifest(self) -> dict:
        return {
            "name": self.name,
            "table_id": None if self.table_id is None else int(self.table_id),
            "schema": self.schema.to_manifest(),
            "init_scale": self.init_scale,
        }

    @classmethod
    def from_manifest(cls, m: dict) -> "TableSpec":
        return cls(
            name=str(m["name"]),
            schema=RowSchema.from_manifest(m["schema"]),
            table_id=None if m.get("table_id") is None else int(m["table_id"]),
            init_scale=None if m.get("init_scale") is None else float(m["init_scale"]),
        )


class TableRegistry:
    """The named tables hosted by one cluster (id- and name-addressable)."""

    def __init__(self, specs: "list[TableSpec] | None" = None):
        self._by_name: dict[str, TableSpec] = {}
        self._by_id: dict[int, TableSpec] = {}
        for spec in specs or []:
            self.add(spec)

    def add(self, spec: TableSpec) -> TableSpec:
        """Register a spec. ``table_id=None`` gets the next free id; an
        explicit id is honored exactly or rejected if taken (the id is the
        key namespace — silently remapping it would point the table at
        different rows). Re-adding an identical spec is a no-op."""
        prev = self._by_name.get(spec.name)
        if prev is not None:
            if prev == spec or (spec.table_id is None and replace(spec, table_id=prev.table_id) == prev):
                return prev
            raise ValueError(f"table {spec.name!r} already registered with a different spec")
        if spec.table_id is None:
            spec = replace(spec, table_id=self._next_free_id())
        elif spec.table_id in self._by_id:
            raise ValueError(f"table_id {spec.table_id} already taken")
        self._by_name[spec.name] = spec
        self._by_id[spec.table_id] = spec
        return spec

    def _next_free_id(self) -> int:
        tid = 0
        while tid in self._by_id:
            tid += 1
        if tid >= MAX_TABLES:
            raise ValueError(f"registry full ({MAX_TABLES} tables)")
        return tid

    def __len__(self) -> int:
        return len(self._by_name)

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __iter__(self):
        return iter(self._by_name.values())

    def get(self, name: str) -> TableSpec:
        return self._by_name[name]

    def require(self, name: str) -> TableSpec:
        """`get` with an error that names the tables that DO exist — the
        lookup surfaces (sessions, serving engines) route through this so a
        typo'd table name fails with the menu, not a bare KeyError."""
        spec = self._by_name.get(name)
        if spec is None:
            raise KeyError(
                f"no table {name!r}; registered tables: {self.names or '(none)'}"
            )
        return spec

    def by_id(self, table_id: int) -> TableSpec:
        return self._by_id[table_id]

    @property
    def names(self) -> list[str]:
        return list(self._by_name)

    @property
    def width(self) -> int:
        """Cluster row width: the max schema width across tables (narrower
        tables use a row prefix — the fixed-size-value design survives)."""
        return max((s.schema.width for s in self), default=0)

    # --------------------------------------------------------- initializer
    def initializer(self, dim: int, default_scale: float, default_init_cols: int | None = None):
        """Vectorized missing-row initializer for the hosting SSD-PS.

        Groups the requested keys by table tag and fills each group's
        ``emb`` field with the table's deterministic per-key init (at the
        table's own width and scale); optimizer slots and the unused row
        tail stay zero. Keys with an unregistered tag fall back to the
        cluster's legacy init (``default_init_cols`` random columns at
        ``default_scale``) so raw cluster access keeps working alongside
        registered tables.
        """
        fallback_cols = dim if default_init_cols is None else int(default_init_cols)

        def init(keys: np.ndarray) -> np.ndarray:
            keys = np.asarray(keys, dtype=np.uint64)
            out = np.zeros((len(keys), dim), dtype=np.float32)
            tids, _ = split_namespaced(keys)
            for tid in np.unique(tids):
                sel = tids == tid
                spec = self._by_id.get(int(tid))
                if spec is None:
                    out[sel, :fallback_cols] = deterministic_init(
                        keys[sel], fallback_cols, default_scale
                    )
                    continue
                scale = default_scale if spec.init_scale is None else spec.init_scale
                emb = spec.schema.emb_dim
                out[sel, :emb] = deterministic_init(keys[sel], emb, scale)
            return out

        return init

    # ------------------------------------------------------- serialization
    def to_manifest(self) -> list:
        return [s.to_manifest() for s in self]

    @classmethod
    def from_manifest(cls, m: list) -> "TableRegistry":
        return cls([TableSpec.from_manifest(s) for s in m])
