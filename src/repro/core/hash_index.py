"""Batched open-addressing uint64 -> int64 hash index.

The DRAM tier of the hierarchy (MEM-PS) and the SSD-PS key->file map both
need a key index that can be probed for an entire batch of keys with numpy
ops only — no Python loop over keys. This module provides it:

* open addressing with linear probing over a power-of-two table;
* slot state tracked in an int8 array (EMPTY / FULL / TOMBstone) so any
  uint64 — including 0 and 2**64-1 — is a valid key;
* every operation (``lookup``, ``insert``, ``set``, ``delete``) probes all
  its keys simultaneously: the probe loop advances *probe distance*, not key
  index, so the expected iteration count is O(1) at bounded load factor;
* deletions leave tombstones; the table rehashes in place once tombstones
  exceed 25% of capacity, and grows 2x when live+incoming load would exceed
  75% (HugeCTR's inference PS batches its cache index the same way — see
  PAPERS.md, arXiv 2210.08804).

Keys within one ``insert``/``delete``/``set`` call must be unique (callers
dedup with ``np.unique`` first); ``lookup`` accepts duplicates.
"""

from __future__ import annotations

import numpy as np

from repro.core.keys import splitmix64

_EMPTY = np.int8(0)
_FULL = np.int8(1)
_TOMB = np.int8(2)


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


class U64Index:
    """Vectorized uint64 -> int64 open-addressing map. -1 means "absent"."""

    __slots__ = ("cap", "_mask", "keys", "vals", "state", "n_full", "n_tomb")

    def __init__(self, expected: int):
        self._alloc(next_pow2(max(8, 2 * int(expected))))

    def _alloc(self, cap: int) -> None:
        self.cap = cap
        self._mask = np.uint64(cap - 1)
        self.keys = np.zeros(cap, dtype=np.uint64)
        self.vals = np.full(cap, -1, dtype=np.int64)
        self.state = np.zeros(cap, dtype=np.int8)
        self.n_full = 0
        self.n_tomb = 0

    def __len__(self) -> int:
        return self.n_full

    def _home(self, keys: np.ndarray) -> np.ndarray:
        return (splitmix64(keys) & self._mask).astype(np.int64)

    # ------------------------------------------------------------- probing
    def find_slots(self, keys: np.ndarray) -> np.ndarray:
        """Slot of each key, -1 if absent. Batched linear probing."""
        keys = np.asarray(keys, dtype=np.uint64)
        out = np.full(len(keys), -1, dtype=np.int64)
        if len(keys) == 0 or self.n_full == 0:
            return out
        slot = self._home(keys)
        live = np.arange(len(keys), dtype=np.int64)
        imask = self.cap - 1
        while live.size:
            s = self.state[slot]
            hit = (s == _FULL) & (self.keys[slot] == keys[live])
            out[live[hit]] = slot[hit]
            cont = (s != _EMPTY) & ~hit  # tombstone / other key: keep probing
            live = live[cont]
            slot = (slot[cont] + 1) & imask
        return out

    def lookup(self, keys: np.ndarray) -> np.ndarray:
        """Value of each key, -1 if absent."""
        slots = self.find_slots(keys)
        out = np.full(len(slots), -1, dtype=np.int64)
        found = slots >= 0
        out[found] = self.vals[slots[found]]
        return out

    def contains(self, keys: np.ndarray) -> np.ndarray:
        return self.find_slots(keys) >= 0

    # ------------------------------------------------------------ mutation
    def insert(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Insert unique keys known to be absent from the table."""
        keys = np.asarray(keys, dtype=np.uint64)
        vals = np.asarray(vals, dtype=np.int64)
        n = len(keys)
        if n == 0:
            return
        if (self.n_full + self.n_tomb + n) * 4 > self.cap * 3:
            self._rehash(max(self.cap, next_pow2(4 * (self.n_full + n))))
        slot = self._home(keys)
        live = np.arange(n, dtype=np.int64)
        imask = self.cap - 1
        while live.size:
            s = self.state[slot]
            claim = s != _FULL
            if claim.any():
                cand, cslot = live[claim], slot[claim]
                # several keys may race for one slot this round: first wins
                _, first = np.unique(cslot, return_index=True)
                winners, wslots = cand[first], cslot[first]
                self.n_tomb -= int((self.state[wslots] == _TOMB).sum())
                self.state[wslots] = _FULL
                self.keys[wslots] = keys[winners]
                self.vals[wslots] = vals[winners]
                self.n_full += len(winners)
                won = np.zeros(len(cand), dtype=bool)
                won[first] = True
                live = np.concatenate([live[~claim], cand[~won]])
                slot = np.concatenate([slot[~claim], cslot[~won]])
            else:
                pass  # every probe blocked by a FULL slot: advance all
            slot = (slot + 1) & imask

    def set(self, keys: np.ndarray, vals: np.ndarray) -> None:
        """Upsert: update present keys, insert absent ones. Keys unique."""
        keys = np.asarray(keys, dtype=np.uint64)
        vals = np.asarray(vals, dtype=np.int64)
        slots = self.find_slots(keys)
        found = slots >= 0
        self.vals[slots[found]] = vals[found]
        if (~found).any():
            self.insert(keys[~found], vals[~found])

    def delete(self, keys: np.ndarray) -> None:
        """Remove unique keys; absent keys are ignored."""
        slots = self.find_slots(keys)
        slots = slots[slots >= 0]
        if slots.size:
            self.state[slots] = _TOMB
            self.n_full -= len(slots)
            self.n_tomb += len(slots)
            if self.n_tomb * 4 > self.cap:
                self._rehash(self.cap)

    # ------------------------------------------------------------ plumbing
    def items(self) -> tuple[np.ndarray, np.ndarray]:
        """All (keys, vals) currently stored, in unspecified order."""
        full = self.state == _FULL
        return self.keys[full].copy(), self.vals[full].copy()

    def clear(self) -> None:
        self.vals.fill(-1)
        self.state.fill(_EMPTY)
        self.n_full = 0
        self.n_tomb = 0

    def _rehash(self, cap: int) -> None:
        k, v = self.items()
        self._alloc(cap)
        self.insert(k, v)
