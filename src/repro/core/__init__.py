# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

# The user-facing PS surface: named tables + batch sessions over one
# shared HBM/MEM/SSD cluster (DESIGN.md §6).
from repro.core.client import BatchSession, PSClient, SessionStateError  # noqa: F401
from repro.core.tables import RowSchema, TableRegistry, TableSpec  # noqa: F401
