"""OP+OSRP: one permutation + one sign random projection (paper Section 2).

Reduces p-dimensional binary sparse features to 2k-dimensional binary
features:

  1. pseudo-randomly permute the p columns (realized as a keyed bijective
     mix — splitmix64 is a bijection on u64, so permuted position order is a
     true permutation of the key space);
  2. break the permuted columns into k bins (contiguous ranges of the
     permuted order == uniform hash binning);
  3. inside each bin compute z = sum_i x_i * r_i with r_i in {-1,+1};
  4. emit the sign of z expanded to 2 binary dims:
     [0 1] if z > 0, [1 0] if z < 0, [0 0] if z = 0.

Output stays binary so the (binary-optimized) training pipeline is unchanged —
that was the point of the design. Touches each nonzero exactly once.
"""

from __future__ import annotations

import numpy as np

from repro.core.keys import hash_keys

_U64 = np.uint64


class OPOSRP:
    def __init__(self, k: int, seed: int = 0):
        if k <= 0:
            raise ValueError("k must be positive")
        self.k = k
        self.seed = seed

    def bin_of(self, cols: np.ndarray) -> np.ndarray:
        """Bin index in [0, k) for each column id (steps 1+2)."""
        return (hash_keys(cols, self.seed) % _U64(self.k)).astype(np.int64)

    def sign_of(self, cols: np.ndarray) -> np.ndarray:
        """Rademacher sign for each column id (step 3)."""
        bit = (hash_keys(cols, self.seed ^ 0x5EED) >> _U64(63)).astype(np.int64)
        return bit * 2 - 1

    def transform_row(self, nz_cols: np.ndarray) -> np.ndarray:
        """Hash one example's nonzero column ids -> nonzero output feature ids.

        Output feature ids live in [0, 2k): bin b maps to 2b (z<0) or 2b+1
        (z>0); z==0 emits nothing.
        """
        nz_cols = np.asarray(nz_cols, dtype=np.uint64)
        bins = self.bin_of(nz_cols)
        signs = self.sign_of(nz_cols)
        uniq, inv = np.unique(bins, return_inverse=True)
        z = np.zeros(len(uniq), dtype=np.int64)
        np.add.at(z, inv, signs)
        nz = uniq[z != 0]
        sign = (z[z != 0] > 0).astype(np.int64)
        return (nz * 2 + sign).astype(np.int64)

    def transform_batch(self, rows: list[np.ndarray]) -> list[np.ndarray]:
        return [self.transform_row(r) for r in rows]

    def transform_padded(self, cols: np.ndarray, valid: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized batch transform on padded [B, nnz] column-id matrices.

        cols: uint64 [B, nnz]; valid: bool [B, nnz]. Returns (out_cols,
        out_valid) with out feature ids in [0, 2k), padded with zeros.
        """
        B, nnz = cols.shape
        bins = self.bin_of(cols.reshape(-1)).reshape(B, nnz)
        signs = self.sign_of(cols.reshape(-1)).reshape(B, nnz) * valid
        # accumulate z per (row, bin) via a flat bincount
        flat = bins + np.arange(B)[:, None] * self.k
        z = np.bincount(flat.reshape(-1), weights=signs.reshape(-1), minlength=B * self.k)
        z = z.reshape(B, self.k)
        out_valid = z != 0
        out_cols = (np.arange(self.k)[None, :] * 2 + (z > 0)).astype(np.uint64)
        return out_cols, out_valid
