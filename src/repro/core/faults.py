"""Deterministic fault injection across the PS hierarchy (DESIGN.md §9).

A :class:`FaultInjector` is armed onto a :class:`~repro.core.node.Cluster`
and fires a fixed schedule of faults at deterministic *operation counts*
(not wall-clock times — the same schedule hits the same op index on every
run). Three hook points cover the hierarchy's failure surface:

* ``on_node_op``   — counted at every ``PSNode.pull/push/pin``; a
  ``NODE_KILL`` event kills the target node *mid-pipeline* (DRAM lost,
  SSD shard intact), which the next touch of that node surfaces as
  :class:`~repro.core.node.NodeDownError`.
* ``on_file_read`` — counted at every SSD-PS parameter-file read; an
  ``SSD_DROP`` deletes the file about to be read, ``SSD_TRUNCATE`` cuts it
  in half. Both are *detected* by the CRC32 file checksum and quarantined
  (ssd_ps.py), never served as garbage.
* ``on_transfer``  — counted at every simulated NIC message; a
  ``NIC_STALL`` adds a burst of extra latency (virtual time, plus a real
  sleep when the network model sleeps), modeling a congested/flapping link.

Schedules are either explicit (a list of :class:`FaultSpec`) or generated
from a seed (``FaultInjector.from_seed``), so a chaos benchmark can say
"1 node kill + 1 SSD file drop + 1 NIC stall, seed 7" and get the same
fault sequence on every run. Every fired fault is appended to
``injector.fired`` for assertions and bench reporting.

The injector is simulation machinery: hooks are no-ops (one attribute
check) when no injector is armed, and nothing in the recovery paths ever
consults it — recovery sees only the faults' *effects*.
"""

from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field

import numpy as np

NODE_KILL = "node_kill"
SSD_DROP = "ssd_drop"
SSD_TRUNCATE = "ssd_truncate"
NIC_STALL = "nic_stall"

_KINDS = (NODE_KILL, SSD_DROP, SSD_TRUNCATE, NIC_STALL)
# which op counter each fault kind fires on
_COUNTER_OF = {
    NODE_KILL: "node_op",
    SSD_DROP: "file_read",
    SSD_TRUNCATE: "file_read",
    NIC_STALL: "transfer",
}


@dataclass
class FaultSpec:
    """One scheduled fault: fires once when its op counter reaches ``at_op``."""

    kind: str
    at_op: int
    node_id: int = 0  # NODE_KILL target
    stall_s: float = 0.02  # NIC_STALL extra seconds (virtual)
    fired: bool = field(default=False, compare=False)

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


class FaultInjector:
    """Seed- or schedule-driven fault source for the PS hierarchy."""

    def __init__(self, schedule: "list[FaultSpec]"):
        self.schedule = list(schedule)
        self.fired: list[dict] = []
        self._lock = threading.Lock()
        self._ops = {"node_op": 0, "file_read": 0, "transfer": 0}
        self._cluster = None

    @classmethod
    def from_seed(
        cls,
        seed: int,
        n_nodes: int,
        kills: int = 1,
        drops: int = 1,
        stalls: int = 1,
        truncates: int = 0,
        horizon: int = 200,
    ) -> "FaultInjector":
        """A reproducible random schedule: op indices, kill targets, and
        stall lengths all come from one seeded generator."""
        rng = np.random.default_rng(seed)
        schedule: list[FaultSpec] = []
        for _ in range(kills):
            schedule.append(
                FaultSpec(
                    NODE_KILL,
                    at_op=int(rng.integers(1, max(2, horizon))),
                    node_id=int(rng.integers(0, max(1, n_nodes))),
                )
            )
        for kind, n in ((SSD_DROP, drops), (SSD_TRUNCATE, truncates)):
            for _ in range(n):
                schedule.append(
                    FaultSpec(kind, at_op=int(rng.integers(1, max(2, horizon))))
                )
        for _ in range(stalls):
            schedule.append(
                FaultSpec(
                    NIC_STALL,
                    at_op=int(rng.integers(1, max(2, horizon))),
                    stall_s=float(rng.uniform(0.005, 0.05)),
                )
            )
        return cls(schedule)

    # ------------------------------------------------------------- arming
    def arm(self, cluster) -> "FaultInjector":
        """Wire the hooks into every node (ops + SSD reads) and the NIC."""
        self._cluster = cluster
        for node in cluster.nodes:
            node.faults = self
            node.ssd.faults = self
        cluster.network.faults = self
        return self

    def disarm(self) -> None:
        if self._cluster is not None:
            for node in self._cluster.nodes:
                node.faults = None
                node.ssd.faults = None
            self._cluster.network.faults = None
            self._cluster = None

    # -------------------------------------------------------------- hooks
    def _due(self, counter: str) -> "list[FaultSpec]":
        """Advance ``counter`` and return the specs due at this op. A spec
        stays due (``at_op <= count``, not ``==``) until its handler marks
        it fired — so a fault scheduled between two observed ops fires at
        the next one, and a handler that declines a target (e.g. a
        snapshot-retained file) retries at the next op."""
        count = self._ops[counter] = self._ops[counter] + 1
        return [
            spec
            for spec in self.schedule
            if not spec.fired
            and _COUNTER_OF[spec.kind] == counter
            and spec.at_op <= count
        ]

    def _log(self, spec: FaultSpec, **detail) -> None:
        self.fired.append(
            {"kind": spec.kind, "at_op": self._ops[_COUNTER_OF[spec.kind]], **detail}
        )

    def on_node_op(self, node, op: str) -> None:
        """Called at the top of PSNode.pull/push/pin. May kill any node in
        the armed cluster (including the one being touched — the caller's
        alive check then raises NodeDownError, i.e. a kill mid-request)."""
        with self._lock:
            for spec in self._due("node_op"):
                if spec.kind == NODE_KILL and self._cluster is not None:
                    spec.fired = True
                    target = self._cluster.nodes[spec.node_id % len(self._cluster.nodes)]
                    target.kill()
                    self._log(spec, node_id=target.node_id, during=op)

    def on_file_read(self, ssd, meta) -> None:
        """Called before SSD-PS opens ``meta.path``. Drops or truncates the
        file about to be read so the corruption is observed immediately.

        Snapshot-retained files are skipped (the fault defers to the next
        read of a local-only file): published snapshots model replicas on
        durable remote storage — see DESIGN.md §9 — and dropping the local
        path would, in this single-host simulation, also destroy the heal
        base that real deployments keep elsewhere."""
        with self._lock:
            for spec in self._due("file_read"):
                if spec.kind not in (SSD_DROP, SSD_TRUNCATE):
                    continue
                if ssd.is_retained(meta.path):
                    continue  # stays due; fires on the next local-only read
                spec.fired = True
                if spec.kind == SSD_DROP:
                    try:
                        os.remove(meta.path)
                    except FileNotFoundError:
                        pass
                else:
                    try:
                        size = os.path.getsize(meta.path)
                        with open(meta.path, "r+b") as f:
                            f.truncate(max(1, size // 2))
                    except FileNotFoundError:
                        pass
                self._log(spec, path=meta.path)

    def on_transfer(self, network) -> float:
        """Called per NIC message; returns extra stall seconds (0 normally)."""
        extra = 0.0
        with self._lock:
            for spec in self._due("transfer"):
                if spec.kind == NIC_STALL:
                    spec.fired = True
                    extra += spec.stall_s
                    self._log(spec, stall_s=spec.stall_s)
        return extra

    # ------------------------------------------------------------- report
    def ops_seen(self) -> dict:
        with self._lock:
            return dict(self._ops)

    def all_fired(self) -> bool:
        return all(s.fired for s in self.schedule)
