"""Redo log: the exact-recovery substrate of the PS hierarchy (DESIGN.md §9).

A killed :class:`~repro.core.node.PSNode` loses its DRAM (MEM-PS cache,
including dirty rows whose updates were pushed but not yet flushed to the
SSD-PS). The redo log makes that loss exactly recoverable: every
``Cluster.push`` appends its (keys, full-width rows) to the log *before*
touching any node, and ``Cluster.flush_all`` — the durability point: after
it, every pushed row is on SSD — marks the log durable, dropping the
now-redundant prefix. Recovery of a restarted node is then

    node.restart()                 # cold MEM-PS over the intact SSD shard
    replay log suffix (owner-filtered, in order)   # last writer wins

which reconstructs bit-exact pre-kill values: rows flushed before the
durability mark are on disk, rows pushed after it are replayed, and replay
order preserves last-writer-wins for keys pushed more than once.

Cursors (``pin``) retain a suffix across durability marks for two more
consumers:

* **snapshot healing** — the publisher pins the log at publish time; a
  quarantined SSD file's rows are later healed exactly as
  ``snapshot value ⊕ redo entries since the pin`` (ssd_ps.py quarantine);
* **live reshard** — ``elastic.reshard_live`` pins *before* its bulk
  copy's flush (a push racing the gap must land in the suffix) and replays
  only the delta onto the new shards during the brief write-pause window,
  instead of requiring a quiesced cluster.

Dropping is always a *prefix* (never a pinned or newer entry), so a replay
of the retained suffix can never resurrect a stale value over a newer one.
"""

from __future__ import annotations

import threading

import numpy as np


class RedoTruncatedError(RuntimeError):
    """A consumer asked for log entries that were already compacted away."""


class RedoLog:
    """Append-only (keys, rows) log with prefix compaction and pinned cursors.

    Indices are *absolute* (monotone over the log's lifetime); compaction
    moves the base forward but never renumbers. Thread-safe: appends come
    from the pull/push stage thread while recovery/heal/reshard readers run
    elsewhere.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: list[tuple[np.ndarray, np.ndarray]] = []
        self._base = 0  # absolute index of _entries[0]
        self._rows = 0  # rows currently retained
        self._pins: dict[int, int] = {}  # pin id -> absolute index
        self._next_pin = 0

    # ------------------------------------------------------------ writing
    def append(self, keys: np.ndarray, rows: np.ndarray) -> None:
        keys = np.ascontiguousarray(keys, dtype=np.uint64).copy()
        rows = np.ascontiguousarray(rows, dtype=np.float32).copy()
        with self._lock:
            self._entries.append((keys, rows))
            self._rows += len(keys)

    def mark_durable(self) -> None:
        """Every previously-appended push is now on SSD: drop the prefix
        (down to the oldest pinned cursor, which heal/reshard still need)."""
        with self._lock:
            self._compact_locked(self.end)

    def _compact_locked(self, durable_upto: int) -> None:
        floor = min([durable_upto] + list(self._pins.values()))
        drop = max(0, floor - self._base)
        if drop:
            for k, _ in self._entries[:drop]:
                self._rows -= len(k)
            del self._entries[:drop]
            self._base += drop

    # ------------------------------------------------------------ cursors
    def pin(self) -> int:
        """Retain everything from the current end onward; returns a pin id."""
        with self._lock:
            pid = self._next_pin
            self._next_pin += 1
            self._pins[pid] = self.end
            return pid

    def release(self, pin_id: int) -> None:
        with self._lock:
            idx = self._pins.pop(pin_id, None)
            if idx is not None:
                # entries the pin alone was retaining become droppable at
                # the next durability mark; nothing to do eagerly
                pass

    def pin_index(self, pin_id: int) -> int:
        with self._lock:
            return self._pins[pin_id]

    # ------------------------------------------------------------ reading
    @property
    def end(self) -> int:
        return self._base + len(self._entries)

    @property
    def rows_held(self) -> int:
        with self._lock:
            return self._rows

    def covers(self, index: int) -> bool:
        """True if every entry at absolute ``index`` or later is retained."""
        with self._lock:
            return index >= self._base

    def since(self, index: int) -> list[tuple[np.ndarray, np.ndarray]]:
        """Entries with absolute index >= ``index``, oldest first."""
        with self._lock:
            if index < self._base:
                raise RedoTruncatedError(
                    f"redo entries before {self._base} were compacted "
                    f"(requested from {index})"
                )
            return list(self._entries[index - self._base :])

    def entries(self) -> list[tuple[np.ndarray, np.ndarray]]:
        """Every retained entry, oldest first (node recovery replays all:
        replaying a pinned-but-durable prefix is an idempotent overwrite)."""
        with self._lock:
            return list(self._entries)


def apply_entries(
    entries: "list[tuple[np.ndarray, np.ndarray]]", keys: np.ndarray, rows: np.ndarray
) -> int:
    """Overwrite ``rows[i]`` with the newest logged value of ``keys[i]``
    (entries oldest-first; later entries win; duplicate keys inside one
    entry resolve to the last occurrence, matching push semantics).
    Returns the number of row overwrites applied."""
    keys = np.asarray(keys, dtype=np.uint64)
    applied = 0
    for ekeys, evals in entries:
        if not len(ekeys):
            continue
        sorter = np.argsort(ekeys, kind="stable")
        se = ekeys[sorter]
        # side="right" - 1: the LAST occurrence of a duplicated key wins
        pos = np.searchsorted(se, keys, side="right") - 1
        hit = (pos >= 0) & (se[np.clip(pos, 0, len(se) - 1)] == keys)
        if hit.any():
            rows[hit] = evals[sorter[pos[hit]]]
            applied += int(hit.sum())
    return applied


def collapse_entries(
    entries: "list[tuple[np.ndarray, np.ndarray]]",
) -> tuple[np.ndarray, np.ndarray]:
    """Flatten entries (oldest first) into one last-writer-wins batch."""
    if not entries:
        return np.empty(0, dtype=np.uint64), np.empty((0, 0), dtype=np.float32)
    all_k = np.concatenate([k for k, _ in entries])
    all_v = np.concatenate([v for _, v in entries])
    uniq, inverse = np.unique(all_k, return_inverse=True)
    last = np.empty(len(uniq), dtype=np.int64)
    last[inverse] = np.arange(len(all_k))
    return uniq, all_v[last]
