"""Roofline analysis from the compiled dry-run artifact (no hardware runs).

Three terms per (arch x shape x mesh), all in seconds-per-step per chip:

  compute    = HLO_FLOPs / PEAK_FLOPS_BF16
  memory     = HLO_bytes / HBM_BW
  collective = collective_bytes / ICI_BW

``compiled.cost_analysis()`` reports the *partitioned* (per-device) module,
so FLOPs/bytes are already per chip — dividing the global numbers by chip
count and using globals would give the same result; we use the per-device
numbers directly. collective_bytes is not in cost_analysis: we parse the
post-SPMD HLO text and sum *operand* sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute / ragged-all-to-all op
(operand shapes in the partitioned module are per-device shards, i.e. bytes
actually leaving the chip, modulo algorithm factors noted in EXPERIMENTS.md).
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "ragged-all-to-all",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=dict)  # op kind -> #instructions
    bytes_by_kind: dict = field(default_factory=dict)  # op kind -> operand bytes

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_OP_RE = re.compile(r"\b(" + "|".join(_COLLECTIVES) + r")(-done)?\(")
_GROUP_SET_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUP_SET_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 1


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum *operand* sizes of every collective in partitioned HLO text.

    The partitioned module prints operands as bare %refs, so operand bytes
    are derived from the instruction's output shape and the op semantics:
      all-gather:      operand = output / group      (output is gathered)
      reduce-scatter:  operand = output * group      (output is the shard)
      all-reduce / all-to-all / collective-permute: operand = output.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if "-start" in s:  # async pair: count at the -done (final shapes)
            continue
        eq = s.find("=")
        if eq < 0:
            continue
        m = _OP_RE.search(s, eq)
        if not m:
            continue
        kind = m.group(1)
        out_bytes = sum(_shape_bytes(t, d) for t, d in _SHAPE_RE.findall(s[eq : m.start()]))
        if out_bytes == 0:
            continue
        g = max(1, _group_size(s))
        if kind == "all-gather":
            operand_bytes = out_bytes // g
        elif kind == "reduce-scatter":
            operand_bytes = out_bytes * g
        else:
            operand_bytes = out_bytes
        stats.counts[kind] = stats.counts.get(kind, 0) + 1
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + operand_bytes
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_counts: dict
    collective_bytes_by_kind: dict
    model_flops_global: float  # 6*N*D (6*N_active*D for MoE)
    n_chips: int
    memory_per_chip: dict  # from memory_analysis
    compile_seconds: float = 0.0
    # raw XLA flat numbers (while bodies counted once) for reference
    xla_flat_flops: float = 0.0
    xla_flat_bytes: float = 0.0
    unknown_trip_loops: int = 0

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory, "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): >1 => HLO under-counts
        (e.g. scan bodies), <1 => remat/dispatch overhead."""
        hlo_global = self.flops_per_chip * self.n_chips
        return self.model_flops_global / hlo_global if hlo_global else float("inf")

    @property
    def roofline_fraction(self) -> float:
        """useful-compute share of the bounding term: (model_flops/chips/peak)
        / max(term) — the score we hillclimb."""
        t_useful = self.model_flops_global / self.n_chips / PEAK_FLOPS_BF16
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / t_bound if t_bound else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def flat_cost(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to a dict: some jax versions
    return the per-computation ``[dict]`` form instead of a flat dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        return cost[0] if cost else {}
    return cost


def analyze(arch, shape, mesh_name, compiled, model_flops_global, n_chips, compile_seconds=0.0) -> Roofline:
    """Roofline terms from the partitioned module, trip-count corrected.

    XLA's flat cost_analysis counts while bodies once; the hlo_analysis
    walker multiplies by known trip counts and computes exact dot FLOPs,
    fusion-level HBM bytes, and per-kind collective operand bytes.
    """
    from repro.launch.hlo_analysis import analyze_text

    flat = flat_cost(compiled)
    text = compiled.as_text()
    hc = analyze_text(text)
    flops = float(hc.dot_flops)
    byts = float(hc.hbm_bytes)
    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "peak_bytes": int(
            getattr(ma, "peak_memory_in_bytes", 0)
            or getattr(ma, "temp_size_in_bytes", 0) + getattr(ma, "argument_size_in_bytes", 0)
        ),
    }
    return Roofline(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        collective_bytes_per_chip=float(hc.total_collective_bytes),
        collective_counts=hc.collective_counts,
        collective_bytes_by_kind=hc.collective_bytes,
        model_flops_global=model_flops_global,
        n_chips=n_chips,
        memory_per_chip=mem,
        compile_seconds=compile_seconds,
        xla_flat_flops=float(flat.get("flops", 0.0)),
        xla_flat_bytes=float(flat.get("bytes accessed", 0.0)),
        unknown_trip_loops=hc.unknown_trip_loops,
    )


def model_flops(cfg, shape, n_params_active: int) -> float:
    """6*N*D for training; 2*N*D for inference (fwd only). D = tokens."""
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * toks
    toks = shape.global_batch * 1
    return 2.0 * n_params_active * toks
