import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production step (train_step for ``train_*``
shapes, prefill/serve steps for ``prefill_*``/``decode_*``/``long_*``),
lowers it against abstract inputs with full production shardings on the
single-pod (16,16) and multi-pod (2,16,16) meshes, compiles, and records
memory_analysis + cost_analysis + the collective schedule for the roofline.

Results stream into a JSON file incrementally (resumable; a completed cell
is skipped on rerun unless --force).

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import math
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES, ArchConfig, ShapeSpec, get_config, replace
from repro.launch import inputs as inp
from repro.launch import roofline as rl
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import get_model
from repro.models.common import abstract_params, logical_specs, param_count
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.optim import AdamW
from repro.train.train_step import TrainSettings, make_lm_train_step, make_lm_train_step_hier

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "dryrun_results.json")


def microbatches_for(cfg: ArchConfig, shape: ShapeSpec, mesh) -> int:
    """Pick grad-accum factor so per-microbatch activations fit HBM."""
    dp = math.prod(mesh.shape[a] for a in shd.data_axes(mesh))
    per_shard = shape.global_batch // max(1, dp)
    if per_shard <= 1:
        return 1
    if cfg.d_model >= 8192:
        return per_shard  # largest models: microbatch of 1 sequence/shard
    if cfg.d_model >= 4096:
        return max(1, per_shard // 2)
    return max(1, per_shard // 4) if per_shard >= 4 else 1


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, settings_overrides=None):
    """Returns (fn, args, in_shardings) ready to lower."""
    rules = shd.build_rules(cfg, mesh)
    shd.install_constraints(mesh, rules)
    model = get_model(cfg)
    schema = model.schema(cfg)
    params = abstract_params(schema)
    param_shard = shd.schema_shardings(schema, rules, mesh)

    # per-microbatch gradients constrained to the FSDP param sharding ->
    # XLA reduce-scatters each contribution (see §Perf)
    from repro.models.common import set_param_constraint_fn

    set_param_constraint_fn(
        lambda grads: jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s), grads, param_shard
        )
    )

    if shape.kind == "train":
        settings = TrainSettings(
            optimizer=AdamW(),
            microbatches=microbatches_for(cfg, shape, mesh),
            attn_impl="blockwise" if shape.seq_len > 8192 else "auto",
            remat=True,
        )
        if settings_overrides:
            settings = replace(settings, **settings_overrides)
        opt = settings.optimizer
        opt_state = jax.eval_shape(opt.init, params)
        # m/v mirror the param shardings; step counter replicated
        from repro.train.optim import AdamState

        opt_shard = AdamState(shd.replicated(mesh), param_shard, param_shard)
        batch = inp.train_batch(cfg, shape)
        batch_shard = inp.batch_sharding(mesh, rules, batch)
        if cfg.embedding_mode == "hier_ps":
            fn = make_lm_train_step_hier(cfg, settings)
            wt, acc = inp.hier_tables(cfg, shape.global_batch * shape.seq_len)
            wt_shard = inp.batch_sharding(mesh, rules, {"working_table": wt})["working_table"]
            args = (params, opt_state, batch, wt, acc)
            shards = (param_shard, opt_shard, batch_shard, wt_shard, wt_shard)
        else:
            fn = make_lm_train_step(cfg, settings)
            args = (params, opt_state, batch)
            shards = (param_shard, opt_shard, batch_shard)
        return fn, args, shards

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, attn_impl="blockwise")
        batch = inp.prefill_batch(cfg, shape)
        batch_shard = inp.batch_sharding(mesh, rules, batch)
        return fn, (params, batch), (param_shard, batch_shard)

    # decode
    fn = make_decode_step(cfg, attn_impl="naive")
    batch = inp.decode_batch(cfg, shape)
    batch_shard = inp.batch_sharding(mesh, rules, batch)
    cache, cache_shard = inp.decode_cache(cfg, shape, mesh, rules)
    pos = inp.sds((), jnp.int32)
    return (
        fn,
        (params, batch, cache, pos),
        (param_shard, batch_shard, cache_shard, shd.replicated(mesh)),
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, settings_overrides=None, verbose=True):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports(shape):
        return {"arch": arch, "shape": shape_name, "skipped": "unsupported (see DESIGN.md)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    fn, args, shards = build_cell(cfg, shape, mesh, settings_overrides)
    with mesh:
        lowered = jax.jit(fn, in_shardings=shards).lower(*args)
        compiled = lowered.compile()
    dt = time.perf_counter() - t0
    shd.clear_constraints()

    n_active = cfg.param_count(active_only=True)
    mf = rl.model_flops(cfg, shape, n_active)
    roof = rl.analyze(arch, shape_name, mesh_name, compiled, mf, n_chips, compile_seconds=dt)
    if verbose:
        ma = roof.memory_per_chip
        print(
            f"[{arch} x {shape_name} @ {mesh_name}] compile {dt:.1f}s | "
            f"args {ma['argument_bytes']/2**30:.2f} GiB temp {ma['temp_bytes']/2**30:.2f} GiB | "
            f"flops/chip {roof.flops_per_chip:.3e} bytes/chip {roof.bytes_per_chip:.3e} "
            f"coll/chip {roof.collective_bytes_per_chip:.3e} | "
            f"t_comp {roof.t_compute*1e3:.1f}ms t_mem {roof.t_memory*1e3:.1f}ms "
            f"t_coll {roof.t_collective*1e3:.1f}ms -> {roof.bottleneck} | "
            f"useful {roof.useful_flops_ratio:.2f} roofline {roof.roofline_fraction:.2%}"
        )
    return roof.to_dict()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(DEFAULT_OUT))
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    cells = []
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for mp in meshes:
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for mp in meshes:
            cells.append((args.arch, args.shape, mp))

    for arch, shape_name, mp in cells:
        key = f"{arch}|{shape_name}|{'multi' if mp else 'single'}"
        if key in results and not args.force and "error" not in results[key]:
            print(f"skip {key} (cached)")
            continue
        try:
            results[key] = run_cell(arch, shape_name, mp)
        except Exception as e:  # record failures — they are bugs to fix
            traceback.print_exc()
            results[key] = {"arch": arch, "shape": shape_name, "error": repr(e)}
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)

    n_err = sum(1 for v in results.values() if "error" in v)
    print(f"\n{len(results)} cells recorded, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
