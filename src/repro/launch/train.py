"""Production training launcher: any assigned arch, hier-PS embeddings.

Trains ``--arch`` on this host's devices (``--model-parallel`` splits a
model axis off the host mesh) with the paper's embedding path: token rows
pulled per batch from a PS cluster (MEM-PS/SSD-PS), row-Adagrad state on
the rows, AdamW on the backbone, async checkpoints, deterministic resume.

At production scale the same step function lowers against
``make_production_mesh()`` — that path is exercised by
``python -m repro.launch.dryrun``; this launcher is the runnable driver.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --scale smoke \
      --steps 50 --batch 8 --seq 128 [--ckpt-dir /tmp/ck] [--resume]
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.client import PSClient
from repro.core.node import Cluster
from repro.core.tables import RowSchema, TableSpec
from repro.data.tokens import TokenStream
from repro.launch import sharding as shd
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train.optim import AdamW
from repro.train.train_step import TrainSettings, make_lm_train_step_hier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="yi-9b")
    ap.add_argument("--scale", choices=["smoke", "full"], default="smoke")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.scale == "smoke" else get_config(args.arch)
    model = get_model(cfg)
    mesh = make_host_mesh(model=args.model_parallel)
    rules = shd.build_rules(cfg, mesh)
    shd.install_constraints(mesh, rules)

    params = model.init(cfg, jax.random.PRNGKey(0))
    settings = TrainSettings(optimizer=AdamW(lr=args.lr), microbatches=1)
    opt = settings.optimizer
    opt_state = opt.init(params)
    step = jax.jit(make_lm_train_step_hier(cfg, settings))

    base = args.ckpt_dir or tempfile.mkdtemp(prefix=f"train_{args.arch.replace('/', '_')}_")
    tok_table = TableSpec("tok_emb", RowSchema.with_adagrad(cfg.d_model))
    cluster = Cluster(
        args.nodes, os.path.join(base, "ps"), dim=cfg.d_model * 2,
        cache_capacity=max(4096, 4 * args.batch * args.seq),
        file_capacity=1024, init_scale=0.02,
    )
    client = PSClient(cluster, [tok_table])
    checkpointer = ckpt.AsyncCheckpointer(os.path.join(base, "ckpt"))

    start = 0
    if args.resume:
        tree, start, extra, manifest = ckpt.restore(
            os.path.join(base, "ckpt"), {"params": params, "opt": opt_state}
        )
        params, opt_state = tree["params"], tree["opt"]
        if manifest is not None:
            cluster = Cluster.restore(manifest, cluster.base_dir, **{
                **cluster.ctor_kwargs(), "tables": None,  # manifest's specs win
            })
            client = PSClient(cluster, [tok_table])
        print(f"resumed from step {start}")

    stream = TokenStream(cfg.vocab_size, args.batch, args.seq, seed=start)
    losses = []
    t0 = time.perf_counter()
    with mesh:
        for i in range(start, start + args.steps):
            toks = stream.next_batch()
            inputs, targets = toks[:, :-1], toks[:, 1:]
            with client.session("tok_emb", inputs.astype(np.uint64)) as s:
                batch = {"tokens": jnp.asarray(s.slots), "targets": jnp.asarray(targets)}
                if cfg.family == "audio":
                    batch["frames"] = jnp.zeros((args.batch, cfg.n_frames, cfg.d_model), jnp.bfloat16)
                if cfg.family == "vlm":
                    batch["image_embeds"] = jnp.zeros((args.batch, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
                params, opt_state, metrics, new_t, new_acc = step(
                    params, opt_state, batch, jnp.asarray(s.params), jnp.asarray(s.opt_state)
                )
                s.commit(np.asarray(new_t), np.asarray(new_acc))
            losses.append(float(metrics["loss"]))
            if (i + 1) % 10 == 0:
                print(f"step {i+1}: loss {np.mean(losses[-10:]):.4f}")
            if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
                checkpointer.save(
                    i + 1, {"params": params, "opt": opt_state},
                    ps_manifest=cluster.manifest(),
                )
    checkpointer.wait()
    shd.clear_constraints()
    dt = time.perf_counter() - t0
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"{args.steps} steps in {dt:.0f}s ({tok_s:,.0f} tok/s); "
          f"loss {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f}")
    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    print(f"embedding cache hit rate {hits/max(1,hits+misses):.1%}; "
          f"checkpoints in {base}/ckpt")


if __name__ == "__main__":
    main()
