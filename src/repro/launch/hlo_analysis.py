"""Trip-count-aware cost analysis of partitioned, optimized HLO text.

XLA's ``HloCostAnalysis`` (behind ``compiled.cost_analysis()``) counts a
``while`` body ONCE, so any scan-over-layers / grad-accumulation /
blockwise-attention program is undercounted by its trip counts. This module
re-derives the three roofline quantities from the HLO text with loop
structure honored:

  * **dot FLOPs** — exact, from dot shapes + contracting/batch dims
    (dots are >99% of FLOPs in these models; elementwise residue is ignored
    and reported separately via the flat cost_analysis number);
  * **HBM bytes** — fusion-level traffic model of the *optimized* module:
    every non-container instruction contributes operand + output bytes
    (fusion internals stay in VMEM and contribute no bytes, matching
    HloCostAnalysis semantics);
  * **collective bytes** — per-kind operand bytes (all-gather output/g,
    reduce-scatter output*g, others output), multiplied by enclosing trip
    counts.

Trip counts come from the canonical XLA loop form: condition is
``compare(induction, constant), direction=LT`` with induction starting at 0.
Loops that don't match report trip=1 and set ``unknown_trip`` (surfaced in
results so it is never silent).
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))\s*"
    r"([a-z0-9\-]+)\((.*)$"
)
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_ATTR_COMP = {
    "while": ("body", "condition"),
    "fusion": ("calls",),
    "call": ("to_apply",),
    "conditional": (),  # branch computations parsed from branch_computations
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all",
}
_CONTAINER_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "while", "conditional", "call", "after-all", "add-dependency",
    "partition-id", "replica-id", "opt-barrier", "iota",
}


def _shape_elems_bytes(shape_txt: str) -> int:
    total = 0
    for t, dims in _SHAPE_RE.findall(shape_txt):
        if t not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[t]
    return total


def _shape_dims(shape_txt: str) -> list[int]:
    m = _SHAPE_RE.search(shape_txt)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    shape_txt: str
    op: str
    rest: str  # operand list + attrs

    @property
    def out_bytes(self) -> int:
        return _shape_elems_bytes(self.shape_txt)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    defs: dict = field(default_factory=dict)  # instr name -> Instr


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for raw in text.splitlines():
        line = _COMMENT_RE.sub("", raw).rstrip()
        if cur is None:
            m = _COMP_RE.match(line)
            if m and ("->" in line or line.strip().endswith("{")):
                cur = Computation(m.group(2))
                if m.group(1):
                    entry = cur.name
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.defs[ins.name] = ins
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


# ---------------------------------------------------------------- dot flops


_DIMS_ATTR = re.compile(r"(\w+)=\{([0-9,]*)\}")


def _dot_flops(ins: Instr, comp: Computation) -> float:
    ops = _OPERAND_RE.findall(ins.rest)
    if len(ops) < 2:
        return 0.0
    lhs, rhs = comp.defs.get(ops[0]), comp.defs.get(ops[1])
    if lhs is None or rhs is None:
        return 0.0
    L, R = _shape_dims(lhs.shape_txt), _shape_dims(rhs.shape_txt)
    attrs = dict(_DIMS_ATTR.findall(ins.rest))
    lc = [int(x) for x in attrs.get("lhs_contracting_dims", "").split(",") if x]
    lb = [int(x) for x in attrs.get("lhs_batch_dims", "").split(",") if x]
    rc = [int(x) for x in attrs.get("rhs_contracting_dims", "").split(",") if x]
    rb = [int(x) for x in attrs.get("rhs_batch_dims", "").split(",") if x]
    batch = math.prod(L[i] for i in lb) if lb else 1
    K = math.prod(L[i] for i in lc) if lc else 1
    M = math.prod(L) // max(1, K * batch)
    N = math.prod(R) // max(1, math.prod(R[i] for i in rc) * (math.prod(R[i] for i in rb) if rb else 1))
    return 2.0 * batch * M * N * K


# ------------------------------------------------------------- trip counts


_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')


def _trip_count(while_ins: Instr, cond: Computation | None) -> tuple[int, bool]:
    """Trip count of an XLA loop: primary source is the while instruction's
    backend_config known_trip_count; fallback scans the condition for a
    compare-LT against an s32 constant (possibly via a wrapped fusion)."""
    m = _TRIP_RE.search(while_ins.rest)
    if m:
        return int(m.group(1)), True
    if cond is not None:
        consts = {}
        for ins in cond.instrs:
            if ins.op == "constant":
                m2 = re.match(r"\s*(\d+)\)", ins.rest)
                if m2:
                    consts[ins.name] = int(m2.group(1))
        for ins in cond.instrs:
            if (ins.op == "compare" and "direction=LT" in ins.rest) or ins.op == "fusion":
                ops = _OPERAND_RE.findall(ins.rest.split(", direction")[0].split("),")[0])
                for o in ops:
                    if o in consts:
                        return consts[o], True
    return 1, False


# ---------------------------------------------------------------- analysis


@dataclass
class HLOCost:
    dot_flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)
    collective_counts: dict = field(default_factory=dict)
    unknown_trip_loops: int = 0
    # (op, computation) -> bytes and flops, trip-multiplied (perf triage)
    bytes_by_site: dict = field(default_factory=dict)
    flops_by_site: dict = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def top_bytes(self, n: int = 12) -> list[tuple[str, float]]:
        items = sorted(self.bytes_by_site.items(), key=lambda kv: -kv[1])[:n]
        return [(f"{op} @ {comp}", b) for (op, comp), b in items]

    def top_flops(self, n: int = 8) -> list[tuple[str, float]]:
        items = sorted(self.flops_by_site.items(), key=lambda kv: -kv[1])[:n]
        return [(f"{op} @ {comp}", b) for (op, comp), b in items]


_GROUP_SET_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(rest: str) -> int:
    m = _GROUP_SET_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return 1


def _called_comps(ins: Instr) -> list[str]:
    names = []
    for attr in ("body", "calls", "to_apply", "branch_computations"):
        m = re.search(attr + r"=\{?%?([\w.\-]+(?:, *%?[\w.\-]+)*)\}?", ins.rest)
        if m:
            for nm in m.group(1).split(","):
                names.append(nm.strip().lstrip("%"))
    return names


def _fusion_bytes(ins: Instr, comp: Computation, comps: dict) -> float:
    """HBM traffic of one fusion call: slice-aware operand reads + output.

    A fusion parameter consumed ONLY by dynamic-slice/gather ops inside the
    fused computation is read slice-wise (scan xs slicing fuses this way) —
    count the slices, not the whole buffer. Output via the root: a
    dynamic-update-slice root writes only the update region.
    """
    call_args = ins.rest.split("),")[0]
    operand_names = _OPERAND_RE.findall(call_args)
    fcomps = _called_comps(ins)
    fc = comps.get(fcomps[0]) if fcomps else None
    total = 0.0
    slice_like = ("dynamic-slice", "gather")
    param_reads: dict[int, float | None] = {}
    root: Instr | None = None
    if fc is not None:
        params = [i for i in fc.instrs if i.op == "parameter"]
        # map param name -> index from "parameter(N)" argument
        pidx = {}
        for p in params:
            m = re.match(r"\s*(\d+)\)", p.rest)
            if m:
                pidx[p.name] = int(m.group(1))
        uses: dict[str, list[Instr]] = {p.name: [] for p in params}
        for i2 in fc.instrs:
            if i2.op == "parameter":
                continue
            for oname in _OPERAND_RE.findall(i2.rest.split("),")[0]):
                if oname in uses:
                    uses[oname].append(i2)
        for pname, ulist in uses.items():
            if ulist and all(u.op in slice_like for u in ulist):
                param_reads[pidx.get(pname, -1)] = float(
                    sum(u.out_bytes for u in ulist)
                )
        root = fc.instrs[-1] if fc.instrs else None  # ROOT is printed last
    for idx, oname in enumerate(operand_names):
        if idx in param_reads:
            total += param_reads[idx]
            continue
        d = comp.defs.get(oname)
        if d is not None:
            total += d.out_bytes
    if root is not None and root.op == "dynamic-update-slice":
        ops_ = _OPERAND_RE.findall(root.rest.split("),")[0])
        upd = fc.defs.get(ops_[1]) if len(ops_) > 1 else None
        total += (upd.out_bytes if upd is not None else ins.out_bytes)
    else:
        total += ins.out_bytes
    return total


def analyze_text(text: str) -> HLOCost:
    comps, entry = parse_module(text)
    cost = HLOCost()
    # memoize per-computation direct quantities
    seen_async: set[str] = set()

    def comp_cost(cname: str, mult: float, in_fusion: bool, stack: tuple):
        comp = comps.get(cname)
        if comp is None or cname in stack:
            return
        def add_bytes(op, b):
            cost.hbm_bytes += b
            k = (op, cname)
            cost.bytes_by_site[k] = cost.bytes_by_site.get(k, 0.0) + b

        for ins in comp.instrs:
            op = ins.op
            base = op[:-6] if op.endswith("-start") else op[:-5] if op.endswith("-done") else op
            if op.endswith("-start"):
                continue  # counted at -done
            if op in ("dot", "convolution"):
                fl = mult * _dot_flops(ins, comp)
                cost.dot_flops += fl
                k = (op, cname)
                cost.flops_by_site[k] = cost.flops_by_site.get(k, 0.0) + fl
            if base in _COLLECTIVES:
                out_b = ins.out_bytes
                g = max(1, _group_size(ins.rest))
                if base == "all-gather":
                    b = out_b // g
                elif base == "reduce-scatter":
                    b = out_b * g
                else:
                    b = out_b
                cost.collective_bytes[base] = cost.collective_bytes.get(base, 0) + mult * b
                cost.collective_counts[base] = cost.collective_counts.get(base, 0) + mult
                if not in_fusion:
                    add_bytes(base, mult * (out_b + out_b))
                # recurse into to_apply region (tiny add) skipped
                continue
            # HBM bytes: only at non-fusion level, skipping containers
            if not in_fusion and op not in _CONTAINER_OPS:
                if op == "dynamic-slice":
                    # reads only the slice, not the operand buffer
                    add_bytes(op, mult * 2 * ins.out_bytes)
                elif op == "dynamic-update-slice":
                    # reads + writes only the updated region (buffer aliased)
                    ops_ = _OPERAND_RE.findall(ins.rest.split("),")[0])
                    upd = comp.defs.get(ops_[1]) if len(ops_) > 1 else None
                    ub = upd.out_bytes if upd is not None else ins.out_bytes
                    add_bytes(op, mult * 2 * ub)
                elif op == "gather":
                    add_bytes(op, mult * 2 * ins.out_bytes)
                elif op == "scatter":
                    ops_ = _OPERAND_RE.findall(ins.rest.split("),")[0])
                    upd = comp.defs.get(ops_[-1]) if ops_ else None
                    ub = upd.out_bytes if upd is not None else ins.out_bytes
                    add_bytes(op, mult * 2 * ub)
                elif op == "fusion":
                    add_bytes(op, mult * _fusion_bytes(ins, comp, comps))
                else:
                    operand_bytes = 0
                    call_args = ins.rest.split("),")[0]
                    for oname in _OPERAND_RE.findall(call_args):
                        d = comp.defs.get(oname)
                        if d is not None:
                            operand_bytes += d.out_bytes
                    add_bytes(op, mult * (operand_bytes + ins.out_bytes))
            # recurse
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.rest)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                body = mb.group(1) if mb else None
                condc = mc.group(1) if mc else None
                trip, known = _trip_count(ins, comps.get(condc))
                if not known:
                    cost.unknown_trip_loops += 1
                for c in (body, condc):
                    if c:
                        comp_cost(c, mult * max(1, trip), in_fusion, stack + (cname,))
            elif op == "fusion":
                for c in _called_comps(ins):
                    comp_cost(c, mult, True, stack + (cname,))
            elif op in ("call", "conditional", "async-start"):
                for c in _called_comps(ins):
                    comp_cost(c, mult, in_fusion, stack + (cname,))

    comp_cost(entry, 1.0, False, ())
    return cost
