"""Production meshes.

Single pod : (data=16, model=16)        = 256 chips  (TPU v5e pod slice)
Multi-pod  : (pod=2, data=16, model=16) = 512 chips  (DCN across pods)

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host offers (tests/examples); model axis optional."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


# v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per-chip figure used in the 3-term model)
