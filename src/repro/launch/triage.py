import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Perf triage for one dry-run cell: roofline terms + top cost sites.

Usage:
  PYTHONPATH=src python -m repro.launch.triage --arch olmoe-1b-7b --shape train_4k
"""

import argparse
import time

import jax

from repro.configs import SHAPES, get_config
from repro.launch import dryrun as DR
from repro.launch import sharding as shd
from repro.launch.hlo_analysis import analyze_text
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.launch.roofline import model_flops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--save-hlo", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    t0 = time.perf_counter()
    fn, fargs, shards = DR.build_cell(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=shards).lower(*fargs).compile()
    shd.clear_constraints()
    print(f"compiled in {time.perf_counter()-t0:.1f}s")
    text = compiled.as_text()
    if args.save_hlo:
        with open(args.save_hlo, "w") as f:
            f.write(text)
    hc = analyze_text(text)
    n_chips = mesh.devices.size
    tc = hc.dot_flops / PEAK_FLOPS_BF16
    tm = hc.hbm_bytes / HBM_BW
    tl = hc.total_collective_bytes / ICI_BW
    mf = model_flops(cfg, shape, cfg.param_count(active_only=True))
    t_useful = mf / n_chips / PEAK_FLOPS_BF16
    print(f"t_compute={tc:.3f}s t_memory={tm:.3f}s t_collective={tl:.3f}s")
    print(f"useful(6ND) t={t_useful:.3f}s -> roofline fraction {t_useful/max(tc,tm,tl):.2%}")
    ma = compiled.memory_analysis()
    print(f"memory: args {ma.argument_size_in_bytes/2**30:.2f} GiB, temp {ma.temp_size_in_bytes/2**30:.2f} GiB")
    print("\n-- top HBM byte sites (trip-multiplied) --")
    for site, b in hc.top_bytes(14):
        print(f"  {b/1e12:8.3f} TB  {site[:90]}")
    print("\n-- top FLOP sites --")
    for site, f_ in hc.top_flops(8):
        print(f"  {f_/1e12:8.2f} TF  {site[:90]}")
    print("\n-- collectives --")
    for k in hc.collective_bytes:
        print(f"  {k:20s} {hc.collective_bytes[k]/1e9:10.2f} GB  x{hc.collective_counts[k]:.0f}")


if __name__ == "__main__":
    main()
