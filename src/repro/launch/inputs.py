"""Abstract input specs (ShapeDtypeStruct) + shardings for every cell.

``input_specs()`` provides weak-type-correct, shardable stand-ins for every
model input — no device allocation — for each (arch x shape) cell. The
working-table size in hier_ps mode is the static capacity the MEM-PS
provisions: min(vocab, tokens-in-batch, 64k) for training/prefill (zipfian
token traffic keeps real unique counts well under this; capacity misses fall
back to a second pull in production), and a small bound for decode.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchConfig, ShapeSpec
from repro.launch.sharding import data_axes, pspec
from repro.models.attention import KVCache

F32 = jnp.float32
BF16 = jnp.bfloat16
I32 = jnp.int32

WORKING_CAP = 65536


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def working_rows(cfg: ArchConfig, n_tokens: int) -> int:
    n = min(cfg.vocab_size, n_tokens, WORKING_CAP)
    return max(256, (n + 255) // 256 * 256)


def _ns(mesh, *parts):
    return NamedSharding(mesh, P(*parts))


class Bundle(NamedTuple):
    args: tuple  # abstract step args (after params/opt_state)
    shardings: tuple  # matching NamedSharding pytrees


def batch_sharding(mesh: Mesh, rules: dict, tree: Any):
    """Shardings for a batch dict of ShapeDtypeStructs by logical meaning."""
    dp = data_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None

    def spec(path_leaf):
        name, leaf = path_leaf
        if name in ("tokens", "targets", "token"):
            return _ns(mesh, dp if leaf.shape[0] % max(1, math.prod(mesh.shape[a] for a in dp)) == 0 else None)
        if name in ("working_table", "row_accum"):
            return NamedSharding(mesh, pspec(leaf.shape, ("working_rows", "working_dim"), rules, mesh))
        if name in ("frames", "image_embeds"):
            return NamedSharding(
                mesh, pspec(leaf.shape, ("batch", None, "working_dim"), rules, mesh)
            )
        return NamedSharding(mesh, P())

    return {k: spec((k, v)) for k, v in tree.items()}


# --------------------------------------------------------------------------
# per-kind input builders (batch dicts; params/opt handled by dryrun)
# --------------------------------------------------------------------------


def train_batch(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, S), I32), "targets": sds((B, S), I32)}
    if cfg.family == "audio":
        batch["frames"] = sds((B, cfg.n_frames, cfg.d_model), BF16)
    if cfg.family == "vlm":
        batch["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model), BF16)
    return batch


def hier_tables(cfg: ArchConfig, n_tokens: int) -> tuple:
    n = working_rows(cfg, n_tokens)
    return sds((n, cfg.d_model), F32), sds((n, cfg.d_model), F32)


def prefill_batch(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((B, S), I32)}
    if cfg.family == "audio":
        batch["frames"] = sds((B, cfg.n_frames, cfg.d_model), BF16)
    if cfg.family == "vlm":
        batch["image_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model), BF16)
    if cfg.embedding_mode == "hier_ps":
        batch["working_table"] = hier_tables(cfg, B * S)[0]
    return batch


def decode_batch(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B = shape.global_batch
    batch = {"token": sds((B, 1), I32)}
    if cfg.embedding_mode == "hier_ps":
        batch["working_table"] = sds((working_rows(cfg, max(B, 256)), cfg.d_model), F32)
    return batch


# --------------------------------------------------------------------------
# decode caches (abstract) + shardings per family
# --------------------------------------------------------------------------


def decode_cache(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh, rules: dict):
    """Returns (abstract cache, cache shardings) for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    dp = data_axes(mesh)
    b_ax = dp if B % max(1, math.prod(mesh.shape[a] for a in dp)) == 0 else None

    def kv_spec(length_dim_shape):
        return NamedSharding(
            mesh,
            pspec(length_dim_shape, ("layers", "batch", "kv_heads_cache", "kv_seq", None), rules, mesh),
        )

    if cfg.family in ("dense", "moe", "vlm"):
        S_tot = S + (cfg.n_image_tokens if cfg.family == "vlm" else 0)
        shp = (cfg.n_layers, B, Hkv, S_tot, hd)
        cache = KVCache(sds(shp, BF16), sds(shp, BF16))
        shard = KVCache(kv_spec(shp), kv_spec(shp))
        return cache, shard

    if cfg.family == "audio":
        from repro.models.whisper import WhisperCache

        self_shp = (cfg.n_layers, B, Hkv, S, hd)
        cross_shp = (cfg.n_layers, B, Hkv, cfg.n_frames, hd)
        cache = WhisperCache(
            KVCache(sds(self_shp, BF16), sds(self_shp, BF16)),
            KVCache(sds(cross_shp, BF16), sds(cross_shp, BF16)),
        )
        shard = WhisperCache(
            KVCache(kv_spec(self_shp), kv_spec(self_shp)),
            KVCache(kv_spec(cross_shp), kv_spec(cross_shp)),
        )
        return cache, shard

    if cfg.family == "hybrid":
        from repro.models import hymba as H

        cache = jax.eval_shape(lambda: H.init_cache(cfg, B, max_len=S))

        def spec(leaf):
            if leaf.ndim == 5:  # KV caches [L, B, Hkv, len, hd]
                return kv_spec(leaf.shape)
            if leaf.ndim == 4 and leaf.shape[-1] == cfg.ssm_state:  # ssm h [L,B,din,N]
                return NamedSharding(mesh, pspec(leaf.shape, (None, "batch", "ssm", None), rules, mesh))
            if leaf.ndim == 4:  # conv hist [L,B,K-1,din]
                return NamedSharding(mesh, pspec(leaf.shape, (None, "batch", None, "ssm"), rules, mesh))
            return NamedSharding(mesh, P())

        return cache, jax.tree.map(spec, cache)

    if cfg.family == "ssm":
        from repro.models import xlstm as X

        cache = jax.eval_shape(lambda: X.init_cache(cfg, B))

        def spec(leaf):
            if leaf.ndim == 6:  # mLSTM C [ns, mp, B, H, dqk, dv]
                return NamedSharding(mesh, pspec(leaf.shape, (None, None, "batch", None, "ssm", None), rules, mesh))
            if leaf.ndim == 5 and leaf.shape[-1] != (4 - 1):  # n [ns,mp,B,H,dqk]
                return NamedSharding(mesh, pspec(leaf.shape, (None, None, "batch", None, "ssm"), rules, mesh))
            if leaf.ndim == 5:  # conv [ns, mp, B, K-1, dp]
                return NamedSharding(mesh, pspec(leaf.shape, (None, None, "batch", None, "ssm"), rules, mesh))
            return NamedSharding(mesh, P())

        return cache, jax.tree.map(spec, cache)

    raise ValueError(cfg.family)
