"""Logical-axis -> mesh-axis sharding rules (MaxText-style, config-aware).

``build_rules(cfg, mesh)`` decides, per logical axis name, which mesh axes
shard it — honoring divisibility (an axis that doesn't divide is replicated)
and never assigning one mesh axis to two dims of the same tensor
(``pspec`` drops repeats, first dim wins).

The strategy encoded here:
  * weights: tensor-parallel over ``model`` (heads/mlp/vocab/experts/ssm) +
    FSDP over (``pod``, ``data``) on the d_model dim -> every large tensor is
    2-D sharded and optimizer state scales to 512 chips;
  * activations: batch over (``pod``, ``data``); moe buffers over ``model``;
  * decode KV caches: kv-heads over ``model`` when divisible, else the cache
    *sequence* dim goes over ``model`` (flash-decode style sharded softmax —
    how a 5 TB nemotron cache fits).
"""

from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.experimental.shard_map import shard_map

from repro.configs import ArchConfig
from repro.models.common import (
    ParamSpec,
    set_embed_gather_fn,
    set_logical_constraint_fn,
)


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    return math.prod(mesh.shape[a] for a in axes)


def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def build_rules(cfg: ArchConfig, mesh: Mesh) -> dict[str, Any]:
    dp = data_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None
    msize = mesh.shape.get("model", 1)
    Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    kv_on_model = model and Hkv % msize == 0
    rules: dict[str, Any] = {
        "layers": None,
        "embed": dp or None,  # FSDP dim of weight matrices
        "vocab": model,
        "vocab_rep": None,  # input-embedding rows replicated (gather local)
        "embed_tp": model if cfg.d_model % msize == 0 else None,
        "heads": model,
        "kv_heads": model if kv_on_model else None,
        "mlp": model,
        "experts": model,
        "ssm": model,
        # activations
        "batch": dp or None,
        "embed_act": None,
        # sequence parallelism hook (§Perf): setting this to `model` shards
        # block outputs on the seq dim (Megatron-SP pattern). REFUTED on this
        # XLA version: the partitioner keeps the full-activation all-reduce
        # and adds resharding all-to-alls on top (nemotron t_mem +43%,
        # t_coll +8%) instead of folding the psum into a reduce-scatter.
        # Left off; revisit with explicit shard_map blocks.
        "seq_act": None,
        "vocab_act": model,
        "mlp_act": model,
        "ssm_act": model,
        "experts_act": model,
        "heads_sep": model if cfg.n_heads % msize == 0 else None,
        # decode caches
        "kv_heads_cache": model if kv_on_model else None,
        "kv_seq": None if kv_on_model else model,
        "working_rows": None,  # working-table rows stay host-ordered
        "working_dim": model if cfg.d_model % msize == 0 else None,
    }
    return rules


def pspec(shape: tuple[int, ...], logical: tuple[Optional[str], ...], rules: dict, mesh: Mesh) -> P:
    """Build a PartitionSpec honoring divisibility + no-axis-reuse."""
    used: set[str] = set()
    parts = []
    for dim, name in zip(shape, logical):
        ax = rules.get(name) if name else None
        if ax is None:
            parts.append(None)
            continue
        ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
        ax_t = tuple(a for a in ax_t if a not in used)
        if not ax_t or dim % _axes_size(mesh, ax_t) != 0:
            parts.append(None)
            continue
        used.update(ax_t)
        parts.append(ax_t if len(ax_t) > 1 else ax_t[0])
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def schema_shardings(schema: dict, rules: dict, mesh: Mesh):
    """Pytree of NamedSharding matching a param schema."""

    def go(node):
        if isinstance(node, ParamSpec):
            return NamedSharding(mesh, pspec(node.shape, node.logical, rules, mesh))
        return {k: go(v) for k, v in node.items()}

    return go(schema)


def like_tree(tree, spec_fn):
    """Map leaves (ShapeDtypeStruct or arrays) -> NamedSharding via spec_fn(leaf)."""
    return jax.tree.map(spec_fn, tree)


def install_constraints(mesh: Mesh, rules: dict) -> None:
    """Route models' with_logical_constraint() through this mesh's rules and
    install the explicit shard_map HBM-PS row gather."""

    def fn(x, logical):
        spec = pspec(x.shape, tuple(logical), rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    set_logical_constraint_fn(fn)

    def gather(table, ids):
        # table: rows replicated, d tensor-parallel; ids: batch over data
        # axes. Local take per shard — the paper's hash-table ``get`` with
        # zero collectives (and no generic-gather partitioner involvement).
        tspec = pspec(table.shape, ("vocab_rep", "embed_tp"), rules, mesh)
        ispec = pspec(ids.shape, ("batch",) + (None,) * (ids.ndim - 1), rules, mesh)
        b_part = tuple(ispec)[0] if tuple(ispec) else None
        d_part = tuple(tspec)[1] if len(tuple(tspec)) > 1 else None
        ospec = P(*((b_part,) + (None,) * (ids.ndim - 1) + (d_part,)))

        def body(tbl, tok):
            return jnp.take(tbl, tok, axis=0)

        return shard_map(
            body, mesh=mesh, in_specs=(tspec, ispec), out_specs=ospec, check_rep=False
        )(table, ids)

    set_embed_gather_fn(gather)


def clear_constraints() -> None:
    from repro.models.common import set_param_constraint_fn

    set_logical_constraint_fn(None)
    set_embed_gather_fn(None)
    set_param_constraint_fn(None)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
