"""Render EXPERIMENTS.md tables from dryrun_results.json.

Usage: PYTHONPATH=src python -m repro.launch.report [results.json]
"""

from __future__ import annotations

import json
import sys

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def row(r: dict) -> str:
    tc = r["flops_per_chip"] / PEAK_FLOPS_BF16
    tm = r["bytes_per_chip"] / HBM_BW
    tl = r["collective_bytes_per_chip"] / ICI_BW
    dom = max((tc, "compute"), (tm, "memory"), (tl, "collective"))[1]
    frac = r.get("roofline_fraction", 0.0)
    useful = r.get("useful_flops_ratio", 0.0)
    gib = r["memory_per_chip"]["argument_bytes"] / 2**30
    tmp = r["memory_per_chip"]["temp_bytes"] / 2**30
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | {fmt_s(tc)} | {fmt_s(tm)} | "
        f"{fmt_s(tl)} | **{dom}** | {useful:.2f} | {frac:.1%} | {gib:.2f}+{tmp:.2f} |"
    )


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    with open(path) as f:
        results = json.load(f)
    print(
        "| arch | shape | mesh | t_compute | t_memory | t_collective | bottleneck "
        "| 6ND/HLO | roofline | GiB args+temp |"
    )
    print("|---|---|---|---|---|---|---|---|---|---|")
    order = sorted(
        (k for k, v in results.items() if "error" not in v and "skipped" not in v),
        key=lambda k: (results[k]["arch"], results[k]["shape"], results[k]["mesh"]),
    )
    for k in order:
        print(row(results[k]))
    skipped = [k for k, v in results.items() if "skipped" in v]
    if skipped:
        print(f"\nskipped cells ({len(skipped)}): long_500k on pure full-attention archs "
              "(task-spec: sub-quadratic only; see DESIGN.md §Arch-applicability)")


if __name__ == "__main__":
    main()
