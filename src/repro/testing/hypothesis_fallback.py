"""Fixed-seed fallback for ``hypothesis`` when it is not installed.

The property tests in this repo use a small slice of the hypothesis API:
``@given`` with positional or keyword strategies, ``@settings`` (in either
decorator order), ``HealthCheck``, and the ``lists`` / ``integers`` /
``floats`` / ``tuples`` / ``sampled_from`` / ``booleans`` strategies with
``.map`` / ``.filter``. This module reimplements exactly that slice as a
deterministic fixed-seed example generator, so the suite still *runs* the
property tests (rather than skipping them) in environments without
hypothesis. Test modules import it as:

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from repro.testing.hypothesis_fallback import given, settings, st

It is NOT a general hypothesis replacement: no shrinking, no coverage
guidance, no database — just N deterministic examples per test (default 20,
honouring ``settings(max_examples=...)``), seeded from the test's qualified
name so runs are reproducible and order-independent.
"""

from __future__ import annotations

import inspect
import random
import types
import zlib


class Strategy:
    """A deterministic value generator: ``draw(rnd)`` -> example."""

    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)

    def map(self, fn) -> "Strategy":
        return Strategy(lambda rnd: fn(self._draw(rnd)))

    def filter(self, pred) -> "Strategy":
        def draw(rnd):
            for _ in range(1000):
                x = self._draw(rnd)
                if pred(x):
                    return x
            raise ValueError("filter predicate rejected 1000 consecutive examples")

        return Strategy(draw)


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda rnd: rnd.randint(min_value, max_value))


def floats(min_value: float, max_value: float, allow_nan: bool = False, **_kw) -> Strategy:
    return Strategy(lambda rnd: rnd.uniform(min_value, max_value))


def booleans() -> Strategy:
    return Strategy(lambda rnd: rnd.random() < 0.5)


def sampled_from(elements) -> Strategy:
    elements = list(elements)
    return Strategy(lambda rnd: rnd.choice(elements))


def tuples(*strategies: Strategy) -> Strategy:
    return Strategy(lambda rnd: tuple(s.draw(rnd) for s in strategies))


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10, unique: bool = False) -> Strategy:
    def draw(rnd):
        size = rnd.randint(min_size, max_size)
        if not unique:
            return [elements.draw(rnd) for _ in range(size)]
        seen: list = []
        for _ in range(50 * max(1, size)):
            x = elements.draw(rnd)
            if x not in seen:
                seen.append(x)
            if len(seen) == size:
                break
        return seen if len(seen) >= min_size else seen + [elements.draw(rnd)]

    return Strategy(draw)


st = types.SimpleNamespace(
    integers=integers,
    floats=floats,
    booleans=booleans,
    sampled_from=sampled_from,
    tuples=tuples,
    lists=lists,
)


class HealthCheck:
    function_scoped_fixture = "function_scoped_fixture"
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def settings(max_examples: int | None = None, **_ignored):
    """Decorator recording ``max_examples``; other options are no-ops here.

    Works in either order relative to ``@given`` (hypothesis allows both):
    the attribute is read off the decorated object at call time.
    """

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(*pos_strategies: Strategy, **kw_strategies: Strategy):
    """Run the test over N fixed-seed examples.

    Positional strategies bind to the test's *last* parameters (hypothesis
    fills from the right, leaving leading parameters for pytest fixtures);
    keyword strategies bind by name. The wrapper exposes only the fixture
    parameters to pytest via ``__signature__``.
    """

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters)
        strat_map: dict[str, Strategy] = {}
        if pos_strategies:
            for name, s in zip(params[len(params) - len(pos_strategies):], pos_strategies):
                strat_map[name] = s
        strat_map.update(kw_strategies)
        fixture_names = [p for p in params if p not in strat_map]

        def wrapper(*args, **kwargs):
            bound = dict(zip(fixture_names, args))
            bound.update(kwargs)
            n = (
                getattr(wrapper, "_fallback_max_examples", None)
                or getattr(fn, "_fallback_max_examples", None)
                or 20
            )
            seed0 = zlib.adler32(f"{fn.__module__}.{fn.__qualname__}".encode())
            for i in range(n):
                rnd = random.Random(seed0 * 100_003 + i)
                drawn = {name: s.draw(rnd) for name, s in strat_map.items()}
                fn(**bound, **drawn)

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.__signature__ = inspect.Signature(
            [sig.parameters[p] for p in fixture_names]
        )
        return wrapper

    return deco
