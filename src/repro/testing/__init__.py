"""Test-support utilities (no runtime dependencies beyond numpy)."""
