"""SanLock: runtime lock-order + pin-leak sanitizer (``REPRO_SANLOCK=1``).

The static rules (PS201/PS202) see only syntactic nesting; this module
records what the threads actually did. :func:`install` replaces
``threading.Lock``/``threading.RLock`` with factories that wrap locks
*allocated from inside* ``src/repro`` (the caller's frame decides —
pytest/queue/Condition internals keep raw locks). Every wrapped
acquisition while other wrapped locks are held adds held->acquired edges
to a global, instance-level acquisition graph; :func:`find_cycle` detects
potential-deadlock cycles, which the conftest fixture turns into test
failures.

Instance-level matters: the SSD heal path legitimately takes a snapshot
view's ``SSDParameterServer._lock`` while holding the training shard's —
same allocation site, different instances, not a self-cycle. Nodes hold
strong references to the wrappers so ``id()`` reuse cannot alias edges;
names are allocation sites (``ssd_ps.py:155``) for readable reports.

The pin half: ``Cluster.__init__`` calls :func:`register_cluster`, and
the conftest fixture asserts ``total_pins() == 0`` on every registered
cluster at test teardown (mark a test ``pscheck_allow_pins`` to opt out).

``install`` only affects locks created *after* it runs — hence the
conftest installs at import time, before any ``repro`` module allocates.
"""

from __future__ import annotations

import os
import sys
import threading
import weakref

_ORIG_LOCK = threading.Lock
_ORIG_RLOCK = threading.RLock

_installed = False
_graph_guard = _ORIG_LOCK()
# (id(held), id(acquired)) -> [held_wrapper, acquired_wrapper, count]
_edges: dict[tuple[int, int], list] = {}
_tls = threading.local()
_clusters: list = []  # weakrefs to every Cluster ever constructed


def enabled() -> bool:
    return _installed


class _SanLockBase:
    """Wraps a real lock; context-manager + acquire/release compatible."""

    _reentrant = False

    def __init__(self, raw, name: str):
        self._raw = raw
        self.name = name

    def acquire(self, blocking: bool = True, timeout: float = -1):
        got = self._raw.acquire(blocking, timeout)
        if got:
            _note_acquire(self)
        return got

    def release(self):
        self._raw.release()
        _note_release(self)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()

    def locked(self):
        return self._raw.locked()

    def __repr__(self):
        return f"<SanLock {self.name} at {id(self):#x}>"


class _SanLock(_SanLockBase):
    pass


class _SanRLock(_SanLockBase):
    _reentrant = True

    def locked(self):  # RLocks grew .locked() only in 3.12
        m = getattr(self._raw, "locked", None)
        return m() if m is not None else False


def _note_acquire(lock) -> None:
    stack = getattr(_tls, "stack", None)
    if stack is None:
        stack = _tls.stack = []
    if any(held is lock for held in stack):
        # reentrant re-acquisition: the thread already owns it, so this
        # acquire can never block and constrains no ordering — adding
        # edges here would paint callback re-entry (SSD read -> fault
        # injector -> ssd.is_retained) as a false faults->ssd->faults cycle
        stack.append(lock)
        return
    for held in stack:
        key = (id(held), id(lock))
        with _graph_guard:
            cell = _edges.get(key)
            if cell is None:
                _edges[key] = [held, lock, 1]
            else:
                cell[2] += 1
    stack.append(lock)


def _note_release(lock) -> None:
    stack = getattr(_tls, "stack", None)
    if stack:
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] is lock:
                del stack[i]
                break


def _from_repro(frame) -> bool:
    fname = frame.f_code.co_filename
    sep = os.sep
    return f"{sep}repro{sep}" in fname and f"{sep}analysis{sep}" not in fname


def _site(frame) -> str:
    return f"{os.path.basename(frame.f_code.co_filename)}:{frame.f_lineno}"


def _lock_factory():
    f = sys._getframe(1)
    raw = _ORIG_LOCK()
    return _SanLock(raw, _site(f)) if _from_repro(f) else raw


def _rlock_factory():
    f = sys._getframe(1)
    raw = _ORIG_RLOCK()
    return _SanRLock(raw, _site(f)) if _from_repro(f) else raw


def install() -> None:
    """Patch the threading lock factories (idempotent)."""
    global _installed
    if _installed:
        return
    threading.Lock = _lock_factory
    threading.RLock = _rlock_factory
    _installed = True


def uninstall() -> None:
    global _installed
    threading.Lock = _ORIG_LOCK
    threading.RLock = _ORIG_RLOCK
    _installed = False


def reset_graph() -> None:
    with _graph_guard:
        _edges.clear()


def edges() -> list[tuple[str, str, int]]:
    with _graph_guard:
        return [(h.name, a.name, n) for h, a, n in _edges.values()]


def find_cycle() -> list[str] | None:
    """DFS over the instance-level graph; returns the cycle's allocation
    sites (closed walk) or None if acyclic."""
    with _graph_guard:
        adj: dict[int, set[int]] = {}
        names: dict[int, str] = {}
        for h, a, _n in _edges.values():
            adj.setdefault(id(h), set()).add(id(a))
            adj.setdefault(id(a), set())
            names[id(h)] = h.name
            names[id(a)] = a.name
    WHITE, GREY, BLACK = 0, 1, 2
    color = {v: WHITE for v in adj}
    path: list[int] = []

    def dfs(v) -> list[int] | None:
        color[v] = GREY
        path.append(v)
        for w in adj[v]:
            if color[w] == GREY:
                return path[path.index(w):] + [w]
            if color[w] == WHITE:
                cyc = dfs(w)
                if cyc is not None:
                    return cyc
        path.pop()
        color[v] = BLACK
        return None

    for v in list(adj):
        if color[v] == WHITE:
            cyc = dfs(v)
            if cyc is not None:
                return [names[x] for x in cyc]
    return None


def assert_acyclic() -> None:
    cyc = find_cycle()
    if cyc is not None:
        raise AssertionError(
            "SanLock: lock-acquisition cycle (potential deadlock): "
            + " -> ".join(cyc)
        )


# ------------------------------------------------------------------- pins
def register_cluster(cluster) -> None:
    """Called by Cluster.__init__ (cheap; weakref only)."""
    _clusters.append(weakref.ref(cluster))


def cluster_mark() -> int:
    """Snapshot of the registry length; pass to pin_leaks to scope the
    check to clusters created after the mark (per-test attribution)."""
    return len(_clusters)


def pin_leaks(mark: int = 0) -> list[tuple[str, int]]:
    """(repr, residual pin count) for live clusters registered at or after
    ``mark`` whose ``total_pins()`` is nonzero."""
    leaks = []
    for ref in _clusters[mark:]:
        c = ref()
        if c is None:
            continue
        try:
            pins = int(c.total_pins())
        except Exception as err:  # cluster mid-teardown: report, don't mask
            leaks.append((f"{c!r} (total_pins raised {err!r})", -1))
            continue
        if pins:
            leaks.append((repr(c), pins))
    return leaks


def prune_dead_clusters() -> None:
    _clusters[:] = [r for r in _clusters if r() is not None]
