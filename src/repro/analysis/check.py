"""pscheck CLI: ``python -m repro.analysis.check src/``.

Walks the given paths (default ``src/``), runs every rule from
``repro.analysis.rules`` on each ``.py`` file, and prints unsuppressed
findings as ``file:line rule-id message``. Exit status 1 iff any remain.

Suppression, in order of preference:

1. fix the code;
2. ``# pscheck: ok PSxxx <reason>`` on the finding's line or its
   enclosing ``def`` line (for invariants that hold by a contract the
   rule cannot see — say which contract);
3. a line ``PSxxx path::qualname`` in ``pscheck_baseline.txt`` for
   grandfathered cases (line-number-free so it survives edits).

``--report FILE`` writes the full report (including suppressed counts)
for the CI artifact; ``--write-baseline`` regenerates the baseline from
the current findings (for deliberate grandfathering only).
"""

from __future__ import annotations

import argparse
import ast
import re
import sys
from pathlib import Path

from repro.analysis.rules import Finding, run_rules

REPO_ROOT = Path(__file__).resolve().parents[3]
DEFAULT_BASELINE = REPO_ROOT / "pscheck_baseline.txt"

_PRAGMA_RE = re.compile(r"#\s*pscheck:\s*ok\s+((?:PS\d+|all)(?:\s*,\s*(?:PS\d+|all))*)")


def load_registry(metrics_path: Path | None = None) -> frozenset[str]:
    """Parse KNOWN_COUNTERS out of repro/metrics.py with ast (the checker
    never imports the checked tree)."""
    p = metrics_path or (REPO_ROOT / "src" / "repro" / "metrics.py")
    try:
        tree = ast.parse(p.read_text(), filename=str(p))
    except (OSError, SyntaxError):
        return frozenset()
    for nd in ast.walk(tree):
        if isinstance(nd, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "KNOWN_COUNTERS" for t in nd.targets
        ):
            names = [
                c.value
                for c in ast.walk(nd.value)
                if isinstance(c, ast.Constant) and isinstance(c.value, str)
            ]
            return frozenset(names)
    return frozenset()


def _pragmas(src: str) -> dict[int, set[str]]:
    out: dict[int, set[str]] = {}
    for i, line in enumerate(src.splitlines(), start=1):
        m = _PRAGMA_RE.search(line)
        if m:
            out[i] = {t.strip() for t in m.group(1).split(",")}
    return out


def _suppressed_by_pragma(f: Finding, pragmas: dict[int, set[str]]) -> bool:
    for line in (f.line, f.scope_line):
        rules = pragmas.get(line)
        if rules and (f.rule in rules or "all" in rules):
            return True
    return False


def _iter_py_files(paths: list[Path]):
    for p in paths:
        if p.is_file() and p.suffix == ".py":
            yield p
        elif p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" not in f.parts:
                    yield f


def load_baseline(path: Path) -> set[str]:
    if not path.exists():
        return set()
    out = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if line and not line.startswith("#"):
            out.add(line)
    return out


def check_paths(
    paths: list[Path],
    baseline: set[str] | None = None,
    registry: frozenset[str] | None = None,
) -> tuple[list[Finding], int, int]:
    """Returns (unsuppressed findings, n_pragma_suppressed, n_baselined)."""
    if registry is None:
        registry = load_registry()
    baseline = baseline or set()
    remaining: list[Finding] = []
    n_pragma = n_base = 0
    for f in _iter_py_files(paths):
        src = f.read_text()
        try:
            rel = f.resolve().relative_to(REPO_ROOT)
        except ValueError:
            rel = f
        findings = run_rules(src, str(rel), registry=registry)
        if not findings:
            continue
        pragmas = _pragmas(src)
        for fd in findings:
            if _suppressed_by_pragma(fd, pragmas):
                n_pragma += 1
            elif fd.baseline_key() in baseline:
                n_base += 1
            else:
                remaining.append(fd)
    return remaining, n_pragma, n_base


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.check", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files/directories to check (default: src)")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="baseline file (default: <repo>/pscheck_baseline.txt)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline (report grandfathered findings)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings")
    ap.add_argument("--report", default=None,
                    help="also write the findings report to this file")
    args = ap.parse_args(argv)

    paths = []
    for p in args.paths:
        pp = Path(p)
        if not pp.exists() and (REPO_ROOT / p).exists():
            pp = REPO_ROOT / p  # allow running from any cwd
        paths.append(pp)

    baseline_path = Path(args.baseline)
    baseline = set() if (args.no_baseline or args.write_baseline) \
        else load_baseline(baseline_path)
    findings, n_pragma, n_base = check_paths(paths, baseline=baseline)

    if args.write_baseline:
        lines = ["# pscheck baseline — grandfathered findings (rule path::qualname).",
                 "# Prefer fixing or pragma'ing with a reason; keep this short."]
        lines += sorted({f.baseline_key() for f in findings})
        baseline_path.write_text("\n".join(lines) + "\n")
        print(f"wrote {len(findings)} entries to {baseline_path}")
        return 0

    lines = [f.format() for f in findings]
    summary = (
        f"pscheck: {len(findings)} finding(s)"
        f" ({n_pragma} pragma-suppressed, {n_base} baselined)"
    )
    report = "\n".join(lines + [summary]) + "\n"
    sys.stdout.write(report)
    if args.report:
        Path(args.report).write_text(report)
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
