"""Declared lock-order table + blocking-call model for pscheck (DESIGN.md §10).

Every ``threading.Lock``/``RLock`` attribute in ``src/repro`` must appear
here. Levels are the permitted acquisition order: a thread holding a lock
at level L may only take locks at a *strictly greater* level (same-instance
re-acquisition of a reentrant RLock is exempt). ``blocking_ok`` declares
whether holding the lock across blocking work (SSD file I/O, cluster
pull/push, NIC transfer, sleep/join) is part of the design — e.g. the
MEM-PS cache lock intentionally serializes SSD miss-fill, while the
serving tier's three locks must never block (they sit on the lookup
hot path).

The runtime sanitizer (``sanlock``) checks the *instance-level* graph for
cycles and does not use the levels: two same-class locks at one level
(e.g. the training SSD-PS lock and a snapshot-view SSD-PS lock on the
heal path) are distinct nodes there.
"""

from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class LockSpec:
    cls: str  # class whose instances own the lock
    attr: str  # attribute name (``with self.<attr>:``)
    level: int  # strictly increasing along any nesting chain
    blocking_ok: bool  # may blocking work run while it is held?
    reentrant: bool = False  # RLock: same-instance nesting is fine
    why: str = ""


LOCK_ORDER: tuple[LockSpec, ...] = (
    LockSpec(
        "ServingEngine", "_mu", 10, False,
        why="request coalescing map; leaders pull OUTSIDE it",
    ),
    LockSpec(
        "HierarchicalPS", "_push_lock", 10, True,
        why="serializes deferred cluster pushes by design (push stage)",
    ),
    LockSpec(
        "StagingRing", "_lock", 11, False,
        why="slot sequence/occupancy bookkeeping only; deps.wait, NIC "
        "transfer and device_put all run outside it (ingest/staging.py)",
    ),
    LockSpec(
        "RetrievalEngine", "_lock", 11, True,
        why="index binds/rolls only (manifest scan + corpus upload under "
        "it by design); searches read the bound index without locking",
    ),
    LockSpec(
        "SnapshotPublisher", "_lock", 12, True,
        why="publish = flush_all + manifest write; serialized by design",
    ),
    LockSpec(
        "ServingCluster", "_lock", 12, True,
        why="roll_forward opens manifests under it; version flips are rare",
    ),
    LockSpec(
        "HierarchicalPS", "_lock", 20, False, reentrant=True,
        why="in-flight registry bookkeeping only; pulls happen outside",
    ),
    LockSpec(
        "ServingEngine", "_dev_mu", 20, False,
        why="DeviceHotSet plan/admit; host pulls must happen between, "
        "with a generation re-check (PR 7 lookup_device fix)",
    ),
    LockSpec(
        "ServingEngine", "_cache_mu", 30, False,
        why="HotRowCache probe/insert; leader pulls run outside it",
    ),
    LockSpec(
        "MemParameterServer", "_lock", 40, True, reentrant=True,
        why="cache lock intentionally covers SSD miss-fill and evict-flush",
    ),
    LockSpec(
        "SSDParameterServer", "_lock", 50, True, reentrant=True,
        why="file I/O IS the protected resource (read/write/compact/heal)",
    ),
    LockSpec(
        "RedoLog", "_lock", 60, False,
        why="memory-only append/snapshot; readers copy out under it",
    ),
    LockSpec(
        "FaultInjector", "_lock", 70, True,
        why="fires SSD drop/truncate at read time by design (test support)",
    ),
    LockSpec(
        "Counters", "_lock", 100, False,
        why="leaf: plain dict bump, nothing may nest inside",
    ),
)

LOCKS: dict[tuple[str, str], LockSpec] = {(s.cls, s.attr): s for s in LOCK_ORDER}

BY_ATTR: dict[str, list[LockSpec]] = {}
for _s in LOCK_ORDER:
    BY_ATTR.setdefault(_s.attr, []).append(_s)

# Attribute names that look like locks: _mu, _lock, _cache_mu, _push_lock...
LOCK_ATTR_RE = re.compile(r"^_(?:[a-z0-9]+_)*(?:mu|lock)$")

# Method names that block regardless of receiver (PS hierarchy verbs +
# thread/time waits). str.join / "sep".join is excluded by the Constant-
# receiver check in rules.py.
BLOCKING_ATTRS = frozenset({
    "pull", "push", "transfer", "flush_all", "publish_manifest",
    "read_batch", "write_batch", "recover_node", "roll_forward",
    "acquire_version", "publish", "sleep", "join", "wait",
})

# os./shutil. file-system calls (only flagged with that receiver, so
# str.replace / list.remove stay clean).
FS_BLOCKING_ATTRS = frozenset({
    "remove", "replace", "rename", "makedirs", "rmtree", "unlink",
    "getsize", "listdir", "fsync",
})
FS_RECEIVERS = frozenset({"os", "shutil", "path"})

# Bare-name calls that block.
BLOCKING_NAMES = frozenset({"open"})
