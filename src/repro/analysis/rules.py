"""pscheck static rules (stdlib ``ast`` only — no new dependencies).

Each rule emits :class:`Finding` records in ``file:line rule-id message``
format. Rule semantics are documented in ``repro.analysis.__doc__`` and
DESIGN.md §10; suppression is via ``# pscheck: ok PSxxx <reason>`` on the
finding line (or its enclosing ``def`` line) or the checked-in baseline.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass

from repro.analysis import locks as L

CRITICAL_EXCS = frozenset({"NodeDownError", "SSDCorruptionError"})
BROAD_EXCS = frozenset({"Exception", "BaseException"})
# calls that make a broad handler "loud": counted, logged, or warned
LOUD_CALL_ATTRS = frozenset({
    "inc", "warn", "warning", "error", "exception", "log", "debug", "info",
})
PIN_RELEASE_ATTRS = frozenset({
    "unpin", "_forget", "abort_batch", "abort", "drain", "release_pins",
})
# names whose presence in an If test marks an *explicit* kernel dispatch
# (as opposed to a silent shape/dtype fallback — the PR-5 bug class)
DISPATCH_TEST_NAMES = frozenset({"use_pallas", "interpret", "impl", "_on_tpu"})


@dataclass
class Finding:
    path: str  # repo-relative, forward slashes
    line: int
    rule: str
    msg: str
    qualname: str = ""  # enclosing function ('' at module level)
    scope_line: int = 0  # the enclosing def's line (0 at module level)

    def format(self) -> str:
        return f"{self.path}:{self.line} {self.rule} {self.msg}"

    def baseline_key(self) -> str:
        # line-number-free so the baseline survives unrelated edits
        return f"{self.rule} {self.path}::{self.qualname or '<module>'}"


# --------------------------------------------------------------- helpers
def iter_functions(tree: ast.Module):
    """Yield (qualname, classname, fn_node) for every def in the module."""

    def rec(node, prefix: str, classname: str | None):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                yield qual, classname, child
                yield from rec(child, f"{qual}.", classname)
            elif isinstance(child, ast.ClassDef):
                yield from rec(child, f"{child.name}.", child.name)

    yield from rec(tree, "", None)


def _receiver_chain(expr) -> list[str]:
    """['self', 'cluster'] for the receiver of ``self.cluster.pull(...)``."""
    parts: list[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        parts.append(expr.id)
    return list(reversed(parts))


def _test_names(test) -> set[str]:
    out: set[str] = set()
    for nd in ast.walk(test):
        if isinstance(nd, ast.Name):
            out.add(nd.id)
        elif isinstance(nd, ast.Attribute):
            out.add(nd.attr)
    return out


# ------------------------------------------------------------ PS101: pins
def _is_pin_acquire(call: ast.Call) -> str | None:
    if not isinstance(call.func, ast.Attribute):
        return None
    chain = _receiver_chain(call.func.value)
    if call.func.attr == "pin":
        # redo-log cursors (``redo.pin()``) are index pins, not row pins
        if any(p.endswith("redo") for p in chain):
            return None
        return "pin"
    if call.func.attr == "pull":
        for kw in call.keywords:
            if (
                kw.arg == "pin"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
            ):
                return "pull(pin=True)"
    return None


def _has_release_handler(fn) -> bool:
    for nd in ast.walk(fn):
        if not isinstance(nd, ast.Try):
            continue
        cleanup = list(nd.finalbody)
        for h in nd.handlers:
            cleanup.extend(h.body)
        for st in cleanup:
            for sub in ast.walk(st):
                if (
                    isinstance(sub, ast.Call)
                    and isinstance(sub.func, ast.Attribute)
                    and sub.func.attr in PIN_RELEASE_ATTRS
                ):
                    return True
    return False


def rule_ps101(path, functions, findings):
    for qual, _cls, fn in functions:
        acquires = [
            (nd.lineno, kind)
            for nd in ast.walk(fn)
            if isinstance(nd, ast.Call) and (kind := _is_pin_acquire(nd))
        ]
        if not acquires or _has_release_handler(fn):
            continue
        line, kind = acquires[0]
        findings.append(Finding(
            path, line, "PS101",
            f"{qual} takes MEM-PS row pins ({kind}) but no except/finally "
            "path releases them (unpin/_forget/abort) — pins leak if an "
            "exception unwinds; pragma only if ownership transfers to the "
            "caller by contract",
            qual, fn.lineno,
        ))


# --------------------------------------------------- PS201/PS202: locking
class _UndeclaredLock:
    def __init__(self, cls, attr):
        self.cls, self.attr = cls, attr


def _lock_spec_of(expr, classname):
    """LockSpec for ``with self._lock:`` items; _UndeclaredLock for lock-ish
    attrs missing from the table; None for non-lock context managers."""
    if not isinstance(expr, ast.Attribute) or not L.LOCK_ATTR_RE.match(expr.attr):
        return None
    chain = _receiver_chain(expr.value)
    if chain == ["self"]:  # only `with self._lock:` resolves via the class
        spec = L.LOCKS.get((classname or "", expr.attr))
        if spec is not None:
            return spec
    cands = L.BY_ATTR.get(expr.attr, [])
    if len(cands) == 1:
        return cands[0]
    return _UndeclaredLock(classname or "<module>", expr.attr)


def _is_blocking_primitive(call: ast.Call) -> str | None:
    f = call.func
    if isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Constant):
            return None  # "sep".join(...)
        if f.attr in L.BLOCKING_ATTRS:
            return f"{'.'.join(_receiver_chain(f.value)[-1:]) or '?'}.{f.attr}"
        if f.attr in L.FS_BLOCKING_ATTRS:
            chain = _receiver_chain(f.value)
            if set(chain) & L.FS_RECEIVERS:
                return f"{'.'.join(chain)}.{f.attr}"
    elif isinstance(f, ast.Name) and f.id in L.BLOCKING_NAMES:
        return f.id
    return None


def module_blocking_summary(tree) -> dict[str, bool]:
    """name -> transitively-blocking?, fixpoint over same-module calls
    (``self.x()`` / bare ``x()``). Catches e.g. engine._rows_for ->
    _pull_source -> source.pull."""
    fns = {fn.name: fn for _q, _c, fn in iter_functions(tree)}
    blocked = {
        n: any(
            isinstance(nd, ast.Call) and _is_blocking_primitive(nd)
            for nd in ast.walk(f)
        )
        for n, f in fns.items()
    }
    changed = True
    while changed:
        changed = False
        for n, f in fns.items():
            if blocked[n]:
                continue
            for nd in ast.walk(f):
                if not isinstance(nd, ast.Call):
                    continue
                callee = None
                if isinstance(nd.func, ast.Name):
                    callee = nd.func.id
                elif (
                    isinstance(nd.func, ast.Attribute)
                    and isinstance(nd.func.value, ast.Name)
                    and nd.func.value.id == "self"
                ):
                    callee = nd.func.attr
                if callee is not None and blocked.get(callee):
                    blocked[n] = True
                    changed = True
                    break
    return blocked


def _describe_blocking(call, blocked: dict[str, bool]) -> str | None:
    prim = _is_blocking_primitive(call)
    if prim:
        return prim
    f = call.func
    if isinstance(f, ast.Name) and blocked.get(f.id):
        return f"{f.id}() [transitively blocking]"
    if (
        isinstance(f, ast.Attribute)
        and isinstance(f.value, ast.Name)
        and f.value.id == "self"
        and blocked.get(f.attr)
    ):
        return f"self.{f.attr}() [transitively blocking]"
    return None


def rule_locks(path, functions, blocked, findings):
    for qual, cls, fn in functions:
        _walk_locks(fn, path, qual, cls, fn.lineno, [], blocked, findings)


def _walk_locks(node, path, qual, cls, scope_line, stack, blocked, findings):
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue  # separate scope; body does not run at this point
        if isinstance(child, (ast.With, ast.AsyncWith)):
            entered = []
            for item in child.items:
                spec = _lock_spec_of(item.context_expr, cls)
                if isinstance(spec, _UndeclaredLock):
                    findings.append(Finding(
                        path, child.lineno, "PS201",
                        f"{qual} acquires undeclared lock "
                        f"{spec.cls}.{spec.attr}: add it to "
                        "repro.analysis.locks.LOCK_ORDER with a level and "
                        "blocking_ok policy",
                        qual, scope_line,
                    ))
                elif spec is not None:
                    for held in stack:
                        if held is spec:
                            if not spec.reentrant:
                                findings.append(Finding(
                                    path, child.lineno, "PS201",
                                    f"{qual} re-acquires non-reentrant "
                                    f"{spec.cls}.{spec.attr} while holding it",
                                    qual, scope_line,
                                ))
                            continue
                        if held.level >= spec.level:
                            findings.append(Finding(
                                path, child.lineno, "PS201",
                                f"{qual} acquires {spec.cls}.{spec.attr} "
                                f"(level {spec.level}) while holding "
                                f"{held.cls}.{held.attr} (level {held.level})"
                                " — violates the declared lock order",
                                qual, scope_line,
                            ))
                    entered.append(spec)
            stack.extend(entered)
            _walk_locks(child, path, qual, cls, scope_line, stack, blocked, findings)
            for _ in entered:
                stack.pop()
            continue
        if isinstance(child, ast.Call):
            strict = [s for s in stack if not s.blocking_ok and s not in
                      getattr(child, "_pscheck_seen", ())]
            if strict:
                desc = _describe_blocking(child, blocked)
                if desc:
                    held = strict[-1]
                    findings.append(Finding(
                        path, child.lineno, "PS202",
                        f"{qual} calls blocking {desc} while holding "
                        f"{held.cls}.{held.attr} (blocking_ok=False) — move "
                        "the call outside the critical section",
                        qual, scope_line,
                    ))
                    # don't re-report the same call for outer With recursion
                    child._pscheck_seen = tuple(stack)
        _walk_locks(child, path, qual, cls, scope_line, stack, blocked, findings)


# -------------------------------------------------- PS301: silent excepts
def _exc_names(type_node) -> set[str]:
    if type_node is None:
        return set()
    nodes = type_node.elts if isinstance(type_node, ast.Tuple) else [type_node]
    out = set()
    for nd in nodes:
        if isinstance(nd, ast.Name):
            out.add(nd.id)
        elif isinstance(nd, ast.Attribute):
            out.add(nd.attr)
    return out


def _handler_is_loud(h: ast.ExceptHandler) -> bool:
    for st in h.body:
        for nd in ast.walk(st):
            if isinstance(nd, ast.Raise):
                return True
            if h.name and isinstance(nd, ast.Name) and nd.id == h.name:
                return True  # bound exception is inspected/stored/re-raised
            if (
                isinstance(nd, ast.Call)
                and isinstance(nd.func, ast.Attribute)
                and nd.func.attr in LOUD_CALL_ATTRS
            ):
                return True
    return False


def rule_ps301(path, functions, tree, findings):
    seen: set[int] = set()
    scopes = [(q, fn, fn.lineno) for q, _c, fn in functions]
    scopes.append(("<module>", tree, 0))
    for qual, scope, scope_line in scopes:
        for nd in ast.walk(scope) if scope is not tree else list(ast.iter_child_nodes(tree)):
            for sub in ast.walk(nd):
                if not isinstance(sub, ast.Try) or id(sub) in seen:
                    continue
                seen.add(id(sub))
                for h in sub.handlers:
                    names = _exc_names(h.type)
                    broad = h.type is None or (names & BROAD_EXCS)
                    if broad:
                        if not _handler_is_loud(h):
                            what = "bare except" if h.type is None else \
                                f"except {'/'.join(sorted(names))}"
                            findings.append(Finding(
                                path, h.lineno, "PS301",
                                f"{qual}: {what} swallows errors (can hide "
                                "NodeDownError/SSDCorruptionError) — "
                                "re-raise, use the bound exception, or "
                                "increment a quarantine counter",
                                qual, scope_line,
                            ))
                    elif names & CRITICAL_EXCS and all(
                        isinstance(st, (ast.Pass, ast.Continue)) for st in h.body
                    ):
                        findings.append(Finding(
                            path, h.lineno, "PS301",
                            f"{qual}: except {'/'.join(sorted(names & CRITICAL_EXCS))}"
                            " is silently dropped — recover, count, or re-raise",
                            qual, scope_line,
                        ))


# ------------------------------------------- PS302: silent kernel fallback
def _walk_skip_ifs(st):
    yield st
    for child in ast.iter_child_nodes(st):
        if isinstance(child, (ast.If, ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield from _walk_skip_ifs(child)


def _is_ref_call(nd) -> bool:
    if not isinstance(nd, ast.Call):
        return False
    f = nd.func
    name = f.id if isinstance(f, ast.Name) else f.attr if isinstance(f, ast.Attribute) else ""
    return name.endswith("_ref")


def rule_ps302(path, functions, findings):
    for qual, _cls, fn in functions:
        touches_pallas = any(
            isinstance(nd, ast.Call)
            and isinstance(nd.func, (ast.Name, ast.Attribute))
            and (
                (isinstance(nd.func, ast.Name) and nd.func.id.endswith("_pallas"))
                or (isinstance(nd.func, ast.Attribute)
                    and (nd.func.attr.endswith("_pallas")
                         or nd.func.attr == "pallas_call"))
            )
            for nd in ast.walk(fn)
        )
        if not touches_pallas:
            continue
        for ifnode in ast.walk(fn):
            if not isinstance(ifnode, ast.If):
                continue
            if _test_names(ifnode.test) & DISPATCH_TEST_NAMES:
                continue  # explicit dispatch (use_pallas/interpret/impl)
            for branch in (ifnode.body, ifnode.orelse):
                loud = any(
                    isinstance(nd, ast.Call)
                    and isinstance(nd.func, ast.Attribute)
                    and nd.func.attr in LOUD_CALL_ATTRS
                    for st in branch for nd in ast.walk(st)
                )
                if loud:
                    continue
                for st in branch:
                    for nd in _walk_skip_ifs(st):
                        if isinstance(nd, ast.Return) and nd.value is not None and any(
                            _is_ref_call(s) for s in ast.walk(nd.value)
                        ):
                            findings.append(Finding(
                                path, nd.lineno, "PS302",
                                f"{qual}: shape/dtype-conditioned fallback to"
                                " the reference kernel without a counter or "
                                "warning — the PR-5 Adagrad bug class; "
                                "repack/pad to the kernel's layout or make "
                                "the degradation loud",
                                qual, fn.lineno,
                            ))
                            break


# ------------------------------------------------- PS401: counter hygiene
def _counterish_receiver(expr) -> bool:
    chain = _receiver_chain(expr)
    return bool(chain) and "counter" in chain[-1].lower()


def rule_ps401(path, tree, registry, findings, functions):
    qual_of = _line_to_scope(functions)
    for nd in ast.walk(tree):
        if not isinstance(nd, ast.Call):
            continue
        f = nd.func
        if isinstance(f, ast.Attribute) and f.attr == "inc" and _counterish_receiver(f.value):
            if not nd.args:
                continue
            a0 = nd.args[0]
            if isinstance(a0, ast.Constant) and isinstance(a0.value, str):
                if a0.value not in registry:
                    q, sl = qual_of(nd.lineno)
                    findings.append(Finding(
                        path, nd.lineno, "PS401",
                        f"counter {a0.value!r} is not in "
                        "repro.metrics.KNOWN_COUNTERS — typos silently mint "
                        "new counters; declare it or fix the name",
                        q, sl,
                    ))
            else:
                q, sl = qual_of(nd.lineno)
                findings.append(Finding(
                    path, nd.lineno, "PS401",
                    "non-literal counter name passed to Counters.inc — "
                    "names must be statically checkable against "
                    "KNOWN_COUNTERS (pragma if derived from a declared set)",
                    q, sl,
                ))
        name = f.id if isinstance(f, ast.Name) else f.attr if isinstance(f, ast.Attribute) else ""
        if name == "Counters":
            for a in nd.args:
                if isinstance(a, ast.Constant) and isinstance(a.value, str) \
                        and a.value not in registry:
                    q, sl = qual_of(nd.lineno)
                    findings.append(Finding(
                        path, nd.lineno, "PS401",
                        f"Counters(...) declares {a.value!r} which is not in "
                        "repro.metrics.KNOWN_COUNTERS",
                        q, sl,
                    ))
    # module-level COUNTER_NAMES-style literal tuples
    for nd in ast.iter_child_nodes(tree):
        if isinstance(nd, ast.Assign) and any(
            isinstance(t, ast.Name) and "COUNTER" in t.id for t in nd.targets
        ) and isinstance(nd.value, (ast.Tuple, ast.List, ast.Set)):
            for a in nd.value.elts:
                if isinstance(a, ast.Constant) and isinstance(a.value, str) \
                        and a.value not in registry:
                    findings.append(Finding(
                        path, nd.lineno, "PS401",
                        f"declared counter {a.value!r} is not in "
                        "repro.metrics.KNOWN_COUNTERS",
                        "<module>", 0,
                    ))


def _line_to_scope(functions):
    spans = sorted(
        (fn.lineno, max((n.lineno for n in ast.walk(fn) if hasattr(n, "lineno")),
                        default=fn.lineno), q, fn.lineno)
        for q, _c, fn in functions
    )

    def lookup(line):
        best = ("<module>", 0)
        for lo, hi, q, sl in spans:
            if lo <= line <= hi:
                best = (q, sl)  # innermost def sorts later
        return best

    return lookup


# ------------------------------------------- PS501: models/ gather hygiene
def rule_ps501(path, tree, findings, functions):
    if "/models/" not in f"/{path}":
        return
    qual_of = _line_to_scope(functions)
    for nd in ast.walk(tree):
        if not isinstance(nd, ast.Call) or not isinstance(nd.func, ast.Attribute):
            continue
        f = nd.func
        bad = None
        if f.attr == "take" and isinstance(f.value, ast.Name) and f.value.id == "jnp":
            bad = "jnp.take"
        elif f.attr == "one_hot" and isinstance(f.value, ast.Attribute) \
                and f.value.attr == "nn":
            bad = "jax.nn.one_hot"
        if bad:
            q, sl = qual_of(nd.lineno)
            findings.append(Finding(
                path, nd.lineno, "PS501",
                f"{bad} in a production forward: embedding-style gathers "
                "must go through kernels.ops (embedding_bag / "
                "embedding_lookup) — pragma only for genuinely non-embedding"
                " uses (e.g. router dispatch masks)",
                q, sl,
            ))


# --------------------------------------------- PS502: pallas_call contract
def rule_ps502(path, tree, findings, functions):
    qual_of = _line_to_scope(functions)
    for nd in ast.walk(tree):
        if not isinstance(nd, ast.Call) or not isinstance(nd.func, ast.Attribute) \
                or nd.func.attr != "pallas_call":
            continue
        kws = {kw.arg for kw in nd.keywords if kw.arg}
        ok = "grid_spec" in kws or (
            {"in_specs", "out_specs"} <= kws and "grid" in kws
        )
        if not ok:
            q, sl = qual_of(nd.lineno)
            findings.append(Finding(
                path, nd.lineno, "PS502",
                "pl.pallas_call without explicit BlockSpecs/grid: pass "
                "in_specs+out_specs+grid or a grid_spec so memory spaces "
                "and tiling are stated, not inferred",
                q, sl,
            ))


# ----------------------------------------------------------------- driver
def run_rules(src: str, path: str, registry: frozenset[str] | None = None):
    """All rules over one file; ``path`` should be repo-relative."""
    path = path.replace(os.sep, "/")
    tree = ast.parse(src, filename=path)
    functions = list(iter_functions(tree))
    blocked = module_blocking_summary(tree)
    findings: list[Finding] = []
    rule_ps101(path, functions, findings)
    rule_locks(path, functions, blocked, findings)
    rule_ps301(path, functions, tree, findings)
    rule_ps302(path, functions, findings)
    if registry is not None:
        rule_ps401(path, tree, registry, findings, functions)
    rule_ps501(path, tree, findings, functions)
    rule_ps502(path, tree, findings, functions)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
