"""pscheck: project-specific invariant lint + concurrency sanitizer.

Static half (``python -m repro.analysis.check src/``, stdlib ``ast`` only):

====== ==============================================================
rule   invariant
====== ==============================================================
PS101  pin/unpin balance: pin-acquiring functions must release on
       every exit path (try/except/finally) or be pragma'd as
       ownership-transferring
PS201  lock discipline: ``with self._lock`` nesting must follow the
       declared order table (``repro.analysis.locks.LOCK_ORDER``)
PS202  no blocking call (cluster.pull, NetworkModel.transfer, file
       I/O, sleep/join/wait) while holding a lock whose spec says
       ``blocking_ok=False``
PS301  no silent degradation: broad ``except`` must re-raise, use the
       bound exception, or count/log — never swallow NodeDownError /
       SSDCorruptionError
PS302  Pallas wrappers must not fall back to the reference kernel on
       shape/dtype conditions without a counter or warning (the PR-5
       Adagrad bug class)
PS401  counter hygiene: ``Counters.inc`` / ctor names must come from
       ``repro.metrics.KNOWN_COUNTERS``
PS501  no ``jnp.take`` / ``jax.nn.one_hot`` embedding paths in
       production forwards under ``models/``
PS502  every ``pl.pallas_call`` must pass explicit BlockSpecs
       (in_specs/out_specs or a grid_spec) and a grid
====== ==============================================================

Suppression: append ``# pscheck: ok PSxxx <reason>`` to the finding's
line (or the enclosing ``def`` line), or add ``PSxxx path::qualname``
to ``pscheck_baseline.txt`` for grandfathered cases.

Runtime half (``repro.analysis.sanlock``, enabled by ``REPRO_SANLOCK=1``):
wraps ``threading.Lock``/``RLock`` allocated inside ``src/repro`` and
records the actual lock-acquisition graph while tier-1 tests run; the
conftest fixture fails any test session whose graph has a cycle, and
asserts ``Cluster.total_pins() == 0`` at teardown (DESIGN.md §10).
"""

from repro.analysis.rules import Finding, run_rules  # noqa: F401
