"""Synthetic LM token streams (zipfian unigram mix with local structure)."""

from __future__ import annotations

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, batch_size: int, seq_len: int, seed: int = 0, zipf_a: float = 1.1):
        self.vocab_size = vocab_size
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.zipf_a = zipf_a
        self.rng = np.random.default_rng(seed)

    def next_batch(self) -> np.ndarray:
        z = self.rng.zipf(self.zipf_a, size=(self.batch_size, self.seq_len + 1))
        toks = (z - 1) % self.vocab_size
        # add weak local structure (repeat-prev with p=0.2) so loss can drop
        rep = self.rng.random((self.batch_size, self.seq_len + 1)) < 0.2
        toks[:, 1:] = np.where(rep[:, 1:], toks[:, :-1], toks[:, 1:])
        return toks.astype(np.int32)

    def __iter__(self):
        while True:
            yield self.next_batch()
