"""Synthetic CTR click logs with a planted ground-truth model.

Mirrors the paper's data shape: each example has ``nnz`` non-zero sparse
features drawn from a zipfian key popularity (real CTR key traffic is heavily
skewed — this is what makes the MEM-PS cache hit ~46%, Fig 4c). Labels come
from a planted sparse-logistic ground truth so AUC is a meaningful,
learnable signal (used by the OP+OSRP Tables-1/2 reproduction and the
lossless-training check).

Batches stream like the paper's HDFS reader: an iterator of CTRBatch.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.keys import hash_keys


@dataclass
class CTRBatch:
    keys: np.ndarray  # uint64 [B, nnz] sparse feature keys
    slot_of: np.ndarray  # int32 [B, nnz] feature slot per nonzero
    valid: np.ndarray  # bool [B, nnz]
    labels: np.ndarray  # float32 [B]
    batch_id: int


class SyntheticCTRStream:
    def __init__(
        self,
        n_keys: int,
        nnz: int,
        n_slots: int,
        batch_size: int,
        seed: int = 0,
        zipf_a: float = 1.05,
        noise: float = 1.0,
    ):
        self.n_keys = n_keys
        self.nnz = nnz
        self.n_slots = n_slots
        self.batch_size = batch_size
        self.zipf_a = zipf_a
        self.noise = noise
        self.rng = np.random.default_rng(seed)
        self._batch_id = 0

    def _draw_keys(self, size) -> np.ndarray:
        # zipf over a finite key space: rejection-free via truncated zipf ranks
        z = self.rng.zipf(self.zipf_a, size=size)
        ranks = (z - 1) % self.n_keys
        # rank -> key id via hash so "popular" keys are spread across shards
        return hash_keys(ranks.astype(np.uint64), seed=17) % np.uint64(self.n_keys)

    def _ground_truth_logit(self, keys: np.ndarray, valid: np.ndarray) -> np.ndarray:
        # planted weight per key: deterministic in the key, heavy-tailed
        h = hash_keys(keys, seed=23)
        w = ((h >> np.uint64(11)).astype(np.float64) / (1 << 53) - 0.5) * 2.0
        w = np.sign(w) * (np.abs(w) ** 3) * 4.0  # sparsify influence
        return (w * valid).sum(axis=1)

    def next_batch(self) -> CTRBatch:
        B, nnz = self.batch_size, self.nnz
        keys = self._draw_keys((B, nnz)).astype(np.uint64)
        slot_of = (hash_keys(keys, seed=31) % np.uint64(self.n_slots)).astype(np.int32)
        valid = np.ones((B, nnz), dtype=bool)
        logit = self._ground_truth_logit(keys, valid)
        logit = (logit - logit.mean()) / (logit.std() + 1e-6) * 2.0
        p = 1.0 / (1.0 + np.exp(-(logit + self.rng.normal(0, self.noise, B))))
        labels = (self.rng.random(B) < p).astype(np.float32)
        b = CTRBatch(keys, slot_of, valid, labels, self._batch_id)
        self._batch_id += 1
        return b

    def __iter__(self):
        while True:
            yield self.next_batch()
