"""Synthetic CTR click logs with a planted ground-truth model.

Mirrors the paper's data shape: each example has ``nnz`` non-zero sparse
features drawn from a zipfian key popularity (real CTR key traffic is heavily
skewed — this is what makes the MEM-PS cache hit ~46%, Fig 4c). Labels come
from a planted sparse-logistic ground truth so AUC is a meaningful,
learnable signal (used by the OP+OSRP Tables-1/2 reproduction and the
lossless-training check).

Batches stream like the paper's HDFS reader: an iterator of CTRBatch.

Two feed modes (DESIGN.md §11):

* ``next_batch`` — the classic host feeder: hashing, slot bucketing and
  packing all happen in numpy on the feeder thread. Kept as the **bitwise
  parity oracle** for the device extraction path.
* ``raw_records`` — emits :class:`RawRecordBatch` of *unhashed* feature-id
  surrogates with variable per-example nnz (what a real log reader hands
  over before any feature extraction). The ingest subsystem
  (:mod:`repro.ingest`) turns these into train-ready batches on device;
  :func:`extract_host` is the host-side numpy reference it must match
  bitwise.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.keys import hash_keys

KEY_SEED = 17  # raw surrogate -> key hash (the feeder's historical seeds)
SLOT_SEED = 31  # key -> feature slot hash


@dataclass
class CTRBatch:
    keys: np.ndarray  # uint64 [B, nnz] sparse feature keys
    slot_of: np.ndarray  # int32 [B, nnz] feature slot per nonzero
    valid: np.ndarray  # bool [B, nnz]
    labels: np.ndarray  # float32 [B]
    batch_id: int


@dataclass
class RawRecordBatch:
    """One batch of raw log records, pre-extraction.

    ``raw_ids`` are the unhashed string-surrogate feature ids (uint64); only
    the first ``lengths[i]`` entries of row i are real — the rest is reader
    padding with unspecified content. ``labels`` ride along from the log
    (production click logs carry the label; the synthetic generator plants
    it from its ground-truth model at generation time).
    """

    raw_ids: np.ndarray  # uint64 [B, L] unhashed feature-id surrogates
    lengths: np.ndarray  # int32 [B] real (ragged) nnz per example
    labels: np.ndarray  # float32 [B]
    batch_id: int

    @property
    def n_examples(self) -> int:
        return self.raw_ids.shape[0]


def extract_host(
    raw_ids: np.ndarray,
    lengths: np.ndarray | None,
    n_keys: int,
    n_slots: int,
    pack_width: int | None = None,
    key_seed: int = KEY_SEED,
    slot_seed: int = SLOT_SEED,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The host numpy feature extraction: raw ids -> (keys, slot_of, valid).

    THE semantic contract for the device extraction kernel
    (``kernels.ops.feature_extract``): key = ``hash(raw) % n_keys``, slot =
    ``hash(key) % n_slots`` (the feeder hashes the *finished* key), ragged
    rows packed to ``pack_width`` columns (longer rows truncate, shorter
    rows pad), and padded positions pinned to key 0 / slot 0 / invalid.
    ``lengths=None`` means every position is real (the classic fixed-nnz
    feed).
    """
    raw_ids = np.asarray(raw_ids, dtype=np.uint64)
    B, L = raw_ids.shape
    P = L if pack_width is None else pack_width
    raw = raw_ids[:, :P]
    if lengths is None:
        valid = np.ones((B, P), dtype=bool)
    else:
        valid = np.arange(P, dtype=np.int32)[None, :] < np.asarray(
            lengths, dtype=np.int32
        )[:, None]
    keys = hash_keys(raw, seed=key_seed) % np.uint64(n_keys)
    slot_of = (hash_keys(keys, seed=slot_seed) % np.uint64(n_slots)).astype(np.int32)
    keys = np.where(valid, keys, np.uint64(0))
    slot_of = np.where(valid, slot_of, np.int32(0))
    return keys, slot_of, valid


def to_ctr_batch(
    raw: RawRecordBatch, n_keys: int, n_slots: int, pack_width: int
) -> CTRBatch:
    """Host-feeder arm over raw records: numpy-extract one RawRecordBatch
    into a CTRBatch (the baseline the device ingest path is benched and
    parity-pinned against)."""
    keys, slot_of, valid = extract_host(
        raw.raw_ids, raw.lengths, n_keys, n_slots, pack_width=pack_width
    )
    return CTRBatch(keys, slot_of, valid, raw.labels, raw.batch_id)


class SyntheticCTRStream:
    def __init__(
        self,
        n_keys: int,
        nnz: int,
        n_slots: int,
        batch_size: int,
        seed: int = 0,
        zipf_a: float = 1.05,
        noise: float = 1.0,
    ):
        self.n_keys = n_keys
        self.nnz = nnz
        self.n_slots = n_slots
        self.batch_size = batch_size
        self.zipf_a = zipf_a
        self.noise = noise
        self.rng = np.random.default_rng(seed)
        self._batch_id = 0

    def _draw_raw(self, size) -> np.ndarray:
        """Unhashed feature-id surrogates via truncated zipf ranks."""
        z = self.rng.zipf(self.zipf_a, size=size)
        return ((z - 1) % self.n_keys).astype(np.uint64)

    def _draw_keys(self, size) -> np.ndarray:
        # raw surrogate -> key via hash so "popular" keys spread across shards
        return hash_keys(self._draw_raw(size), seed=KEY_SEED) % np.uint64(self.n_keys)

    def _ground_truth_logit(self, keys: np.ndarray, valid: np.ndarray) -> np.ndarray:
        # planted weight per key: deterministic in the key, heavy-tailed
        h = hash_keys(keys, seed=23)
        w = ((h >> np.uint64(11)).astype(np.float64) / (1 << 53) - 0.5) * 2.0
        w = np.sign(w) * (np.abs(w) ** 3) * 4.0  # sparsify influence
        return (w * valid).sum(axis=1)

    def _labels_for(self, keys: np.ndarray, valid: np.ndarray) -> np.ndarray:
        B = keys.shape[0]
        logit = self._ground_truth_logit(keys, valid)
        logit = (logit - logit.mean()) / (logit.std() + 1e-6) * 2.0
        p = 1.0 / (1.0 + np.exp(-(logit + self.rng.normal(0, self.noise, B))))
        return (self.rng.random(B) < p).astype(np.float32)

    def next_batch(self) -> CTRBatch:
        B, nnz = self.batch_size, self.nnz
        raw = self._draw_raw((B, nnz))
        keys, slot_of, valid = extract_host(raw, None, self.n_keys, self.n_slots)
        labels = self._labels_for(keys, valid)
        b = CTRBatch(keys, slot_of, valid, labels, self._batch_id)
        self._batch_id += 1
        return b

    def next_raw(self, min_nnz: int = 1, max_nnz: int | None = None) -> RawRecordBatch:
        """One batch of raw records with variable per-example nnz.

        Rows are ``max_nnz`` wide (default: the stream's pack width); row i
        carries ``lengths[i] ~ U[min_nnz, max_nnz]`` real ids. Labels are
        planted from the ground truth over the *packed* view (the first
        ``self.nnz`` columns — what a trainer at this pack width sees).
        """
        B = self.batch_size
        L = self.nnz if max_nnz is None else max_nnz
        raw = self._draw_raw((B, L))
        lengths = self.rng.integers(min_nnz, L + 1, B).astype(np.int32)
        keys, _, valid = extract_host(
            raw, lengths, self.n_keys, self.n_slots, pack_width=self.nnz
        )
        labels = self._labels_for(keys, valid)
        b = RawRecordBatch(raw, lengths, labels, self._batch_id)
        self._batch_id += 1
        return b

    def raw_records(self, min_nnz: int = 1, max_nnz: int | None = None):
        """Endless iterator of :class:`RawRecordBatch` (the ingest feed)."""
        while True:
            yield self.next_raw(min_nnz=min_nnz, max_nnz=max_nnz)

    def __iter__(self):
        while True:
            yield self.next_batch()
