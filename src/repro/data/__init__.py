from repro.data.synthetic_ctr import CTRBatch, SyntheticCTRStream
from repro.data.tokens import TokenStream

__all__ = ["CTRBatch", "SyntheticCTRStream", "TokenStream"]
