"""Pixtral-12B — VLM: mistral-nemo decoder backbone; ViT frontend is a stub.

``input_specs()`` provides precomputed patch embeddings (batch,
n_image_tokens, d_model) already projected into the decoder width.
[hf:mistralai/Pixtral-12B-2409]
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    n_image_tokens=256,
)

SMOKE = ArchConfig(
    name="pixtral-12b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=8,
    mlp_act="swiglu",
    n_image_tokens=8,
)
