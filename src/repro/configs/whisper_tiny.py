"""Whisper-tiny — encoder-decoder audio transformer, backbone only.

The conv frontend is a stub per the task spec: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, n_frames, d_model).
[arXiv:2212.04356]
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,  # decoder layers
    encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    head_dim=64,
    mlp_act="gelu",
    n_frames=1500,
)

SMOKE = ArchConfig(
    name="whisper-tiny-smoke",
    family="audio",
    n_layers=2,
    encoder_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    mlp_act="gelu",
    n_frames=32,
)
