"""Phi-3-mini-3.8B — dense MHA (kv == heads) RoPE SwiGLU LM. [arXiv:2404.14219]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    head_dim=96,
    mlp_act="swiglu",
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="phi3-mini-3.8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    mlp_act="swiglu",
)
