"""The paper's own CTR prediction models A-E (Table 3), plus scaled variants.

Paper Table 3:
  model  #nnz/example  #sparse      #dense   size    MPI nodes
  A      100           8e9          7e5      300 GB  100
  B      100           2e10         2e4      600 GB  80
  C      500           6e10         2e6      2 TB    75
  D      500           1e11         4e6      6 TB    150
  E      500           2e11         7e6      10 TB   128

The ``paper`` configs carry those numbers for roofline math; the ``scaled``
configs shrink the key space so the full hierarchical-PS workflow (SSD files,
cache, compaction) runs on this container while keeping the *structure*
(nnz/example ratios, dense-net shapes, zipfian key popularity) identical.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.tables import RowSchema, TableSpec


@dataclass(frozen=True)
class SlotGroup:
    """A set of feature slots sharing one embedding table.

    Production CTR models give different feature families (query, ad,
    user-portrait slots) different embedding widths; each group becomes a
    named table with its own :class:`RowSchema` on the shared cluster.
    """

    name: str  # table name on the PS cluster
    n_slots: int  # feature slots pooled within this group
    emb_dim: int  # embedding width of this group's table

    @property
    def pooled_dim(self) -> int:
        return self.n_slots * self.emb_dim


@dataclass(frozen=True)
class CTRConfig:
    name: str
    n_sparse_keys: int  # size of the sparse key space (rows that exist)
    nnz_per_example: int  # non-zero features per example
    emb_dim: int  # embedding width per sparse feature
    n_slots: int  # feature slots; nnz are spread across slots & sum-pooled
    mlp_hidden: tuple[int, ...]  # fully-connected tower
    batch_size: int  # examples per training batch ("HDFS batch")
    minibatches_per_batch: int  # GPU mini-batches per pulled working set
    zipf_a: float = 1.05  # key popularity skew (cache-ability)
    # heterogeneous embedding widths: slots partitioned into named groups,
    # each backed by its own PS table. None => one uniform group ("ctr")
    # of (n_slots, emb_dim) — the single-table layout.
    slot_groups: tuple[SlotGroup, ...] | None = None

    @property
    def groups(self) -> tuple[SlotGroup, ...]:
        if self.slot_groups is not None:
            return self.slot_groups
        return (SlotGroup("ctr", self.n_slots, self.emb_dim),)

    @property
    def pooled_dim(self) -> int:
        """Tower input width: per-slot sum-pools concatenated across groups."""
        return sum(g.pooled_dim for g in self.groups)

    @property
    def dense_params(self) -> int:
        dims = (self.pooled_dim,) + self.mlp_hidden + (1,)
        return sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))

    @property
    def sparse_params(self) -> int:
        # each slot group draws from its own n_sparse_keys-sized key space
        return sum(self.n_sparse_keys * g.emb_dim for g in self.groups)


def table_specs(cfg: CTRConfig) -> list[TableSpec]:
    """One named training table per slot group: ``[emb | adagrad]`` rows.

    The hosting cluster's row width must be ``>= 2 * max(emb_dim)`` across
    groups; narrower groups use a row prefix (fixed-size-value design)."""
    return [TableSpec(g.name, RowSchema.with_adagrad(g.emb_dim)) for g in cfg.groups]


def _scale(name: str, keys: int, nnz: int, hidden: tuple[int, ...], batch: int) -> CTRConfig:
    return CTRConfig(
        name=name,
        n_sparse_keys=keys,
        nnz_per_example=nnz,
        emb_dim=8,
        n_slots=max(8, nnz // 4),
        mlp_hidden=hidden,
        batch_size=batch,
        minibatches_per_batch=4,
    )


# --- paper-spec configs (used for analytic/roofline math; never allocated) ---
PAPER = {
    "A": CTRConfig("ctr-A", 8 * 10**9, 100, 8, 32, (511, 255, 127), 4_000_000, 1000),
    "B": CTRConfig("ctr-B", 2 * 10**10, 100, 8, 32, (96, 64, 32), 4_000_000, 1000),
    "C": CTRConfig("ctr-C", 6 * 10**10, 500, 8, 128, (859, 430, 215), 4_000_000, 1000),
    "D": CTRConfig("ctr-D", 1 * 10**11, 500, 8, 128, (1330, 660, 330), 4_000_000, 1000),
    "E": CTRConfig("ctr-E", 2 * 10**11, 500, 8, 128, (1840, 920, 460), 4_000_000, 1000),
}

# --- container-scale configs (run the real workflow end-to-end) ---
SCALED = {
    "A": _scale("ctr-A-scaled", 80_000, 100, (64, 32), 4096),
    "B": _scale("ctr-B-scaled", 200_000, 100, (32, 16), 4096),
    "C": _scale("ctr-C-scaled", 600_000, 500, (96, 48), 2048),
    "D": _scale("ctr-D-scaled", 1_000_000, 500, (128, 64), 2048),
    "E": _scale("ctr-E-scaled", 2_000_000, 500, (160, 80), 2048),
}

# storage-bound bench config: the paper's operating point. The key space is
# far larger than the MEM-PS cache, so every batch's pull/push does real
# SSD-PS work — the regime the 4-stage pipeline exists to hide. (The SCALED
# configs' working sets cover most of their key space, so after warm-up they
# are DRAM-resident and train-bound.)
STORAGE_BENCH = CTRConfig(
    name="ctr-storage",
    n_sparse_keys=8_000_000,
    nnz_per_example=64,
    emb_dim=8,
    n_slots=16,
    mlp_hidden=(64, 32),
    batch_size=1024,
    minibatches_per_batch=8,
)

# heterogeneous per-slot embedding widths: "query"-style slots at width 4,
# "ad"-style slots at width 8, each group a named table on one cluster
# (cluster row width = 2 * max emb = 16; the width-8 rows use a prefix)
TINY_HETERO = CTRConfig(
    name="ctr-tiny-hetero",
    n_sparse_keys=1_000,
    nnz_per_example=16,
    emb_dim=8,  # max width (used for cluster sizing helpers)
    n_slots=8,
    mlp_hidden=(16, 8),
    batch_size=64,
    minibatches_per_batch=2,
    slot_groups=(SlotGroup("query", 4, 4), SlotGroup("ad", 4, 8)),
)

# a tiny config for unit tests
TINY = CTRConfig(
    name="ctr-tiny",
    n_sparse_keys=1_000,
    nnz_per_example=16,
    emb_dim=4,
    n_slots=8,
    mlp_hidden=(16, 8),
    batch_size=64,
    minibatches_per_batch=2,
)
