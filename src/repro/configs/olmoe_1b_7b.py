"""OLMoE-1B-7B — MoE LM, 64 experts top-8, per-expert d_ff=1024. [arXiv:2409.02060; hf]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    head_dim=128,
    mlp_act="swiglu",
    n_experts=64,
    top_k=8,
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="olmoe-1b-7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=64,
    vocab_size=512,
    head_dim=16,
    mlp_act="swiglu",
    n_experts=8,
    top_k=2,
)
