"""xLSTM-1.3B — recurrent LM of mLSTM blocks with one sLSTM per 8 (7:1 ratio).

d_ff=0: mixing happens inside the (s/m)LSTM blocks via a 2x up-projection.
[arXiv:2405.04517]
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=0,  # inner dim / n_heads, resolved in the model
    slstm_every=8,
    proj_factor=2.0,
)

SMOKE = ArchConfig(
    name="xlstm-1.3b-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    slstm_every=2,
    proj_factor=2.0,
)
