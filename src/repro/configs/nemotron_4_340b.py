"""Nemotron-4-340B — dense GQA LM with squared-ReLU MLP. [arXiv:2402.16819]"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    head_dim=192,
    mlp_act="squared_relu",
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="nemotron-4-340b-smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=384,
    vocab_size=512,
    head_dim=16,
    mlp_act="squared_relu",
)
