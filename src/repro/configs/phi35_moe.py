"""Phi-3.5-MoE-42B (A6.6B) — MoE LM, 16 experts top-2, per-expert d_ff=6400.

[hf:microsoft/Phi-3.5-MoE-instruct]
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    head_dim=128,
    mlp_act="swiglu",
    n_experts=16,
    top_k=2,
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    head_dim=8,
    mlp_act="swiglu",
    n_experts=4,
    top_k=2,
)
