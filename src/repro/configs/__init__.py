"""Architecture configs and input-shape specs.

Every assigned architecture is a selectable config (``--arch <id>``). Each
config file exports ``CONFIG`` (the exact published numbers) and ``SMOKE``
(a reduced same-family config used by CPU smoke tests). The registry here
resolves ids to configs.
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Shape specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One (seq_len, global_batch) input-shape cell.

    kind:
      train   -> lowers train_step
      prefill -> lowers serve_prefill (full-sequence forward, builds KV cache)
      decode  -> lowers serve_step (1 new token against a cache of seq_len)
    """

    name: str
    kind: str
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- MLP activation: swiglu | squared_relu | gelu
    mlp_act: str = "swiglu"
    rope_theta: float = 10_000.0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- hybrid / ssm ---
    ssm_state: int = 0
    window: int = 0  # sliding-window size (0 = full attention)
    global_attn_layers: tuple[int, ...] = ()  # layers w/ full attn in SWA archs
    n_meta_tokens: int = 0  # hymba learned meta tokens
    slstm_every: int = 0  # xLSTM: one sLSTM block every k blocks (0 = none)
    proj_factor: float = 2.0  # xLSTM mLSTM up-projection factor
    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    n_frames: int = 0  # stub frontend: precomputed frame embeddings
    # --- vlm ---
    n_image_tokens: int = 0  # stub frontend: precomputed patch embeddings
    # --- embedding handling: dense (pjit) | hier_ps (paper technique) ---
    embedding_mode: str = "hier_ps"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True when decode state does not grow quadratically w/ context."""
        return self.family in ("ssm", "hybrid")

    def supports(self, shape: ShapeSpec) -> bool:
        if shape.name == "long_500k":
            # long-context decode needs sub-quadratic attention.
            return self.subquadratic
        return True

    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count (embedding + backbone [+ experts])."""
        d, hd = self.d_model, self.resolved_head_dim
        q = self.n_heads * hd
        kv = self.n_kv_heads * hd
        attn = d * q + 2 * d * kv + q * d  # wq wk wv wo
        if self.mlp_act == "swiglu":
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        norms = 2 * d
        if self.family == "ssm":
            # mLSTM block params (qkv + gates + out) approximation via actual
            # shapes used in models/xlstm.py
            dp = int(d * self.proj_factor)
            block = d * dp * 2 + 3 * dp * hd * self.n_heads // max(self.n_heads, 1)
            block = 2 * d * dp + 3 * dp * dp + dp * d + norms
            per_layer = block
        elif self.family == "hybrid":
            dssm = 2 * d  # mamba inner dim
            ssm = d * 2 * dssm + dssm * (2 * self.ssm_state + 1) + dssm * d
            per_layer = attn + ssm + d * self.d_ff * 3 + norms
        elif self.is_moe:
            per_layer = attn + self.n_experts * 3 * d * self.d_ff + d * self.n_experts + norms
            if active_only:
                per_layer = attn + self.top_k * 3 * d * self.d_ff + d * self.n_experts + norms
        else:
            per_layer = attn + mlp_dense + norms
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        enc = self.encoder_layers * (attn + mlp_dense + norms)
        return emb + head + self.n_layers * per_layer + enc


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_IDS = (
    "yi-9b",
    "granite-20b",
    "nemotron-4-340b",
    "phi3-mini-3.8b",
    "olmoe-1b-7b",
    "phi3.5-moe-42b-a6.6b",
    "hymba-1.5b",
    "xlstm-1.3b",
    "whisper-tiny",
    "pixtral-12b",
)

_MODULES = {
    "yi-9b": "yi_9b",
    "granite-20b": "granite_20b",
    "nemotron-4-340b": "nemotron_4_340b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "hymba-1.5b": "hymba_1p5b",
    "xlstm-1.3b": "xlstm_1p3b",
    "whisper-tiny": "whisper_tiny",
    "pixtral-12b": "pixtral_12b",
}


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.SMOKE


def all_cells() -> list[tuple[str, str]]:
    """Every supported (arch_id, shape_name) dry-run cell."""
    cells = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES.values():
            if cfg.supports(s):
                cells.append((a, s.name))
    return cells


__all__ = [
    "ArchConfig",
    "ShapeSpec",
    "SHAPES",
    "ARCH_IDS",
    "get_config",
    "get_smoke_config",
    "all_cells",
    "replace",
]
