"""Granite-20B-Code — dense LM with MQA (kv=1). [arXiv:2405.04324; hf]

d_ff = 4 x d_model implies a non-gated MLP (gpt-bigcode lineage); with gelu
the count lands on ~20B as published.
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    mlp_act="gelu",
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="granite-20b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=1,
    d_ff=160,
    vocab_size=512,
    head_dim=8,
    mlp_act="gelu",
)
