"""Hymba-1.5B — hybrid: parallel attention + mamba heads in every layer.

Sliding-window attention in most layers, full attention in {first, middle,
last}; 128 learned meta tokens prepended. [arXiv:2411.13676; hf]
"""

from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    head_dim=64,
    mlp_act="swiglu",
    ssm_state=16,
    window=1024,
    global_attn_layers=(0, 15, 31),
    n_meta_tokens=128,
    rope_theta=10_000.0,
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke",
    family="hybrid",
    n_layers=2,
    d_model=64,
    n_heads=5,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    mlp_act="swiglu",
    ssm_state=4,
    window=32,
    global_attn_layers=(0,),
    n_meta_tokens=8,
)
