"""Shared evaluation metrics."""

from __future__ import annotations

import numpy as np


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (Mann-Whitney), with tie averaging."""
    labels = np.asarray(labels).astype(bool)
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty(len(scores), dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    s_sorted = scores[order]
    i = 0
    while i < len(s_sorted):
        j = i
        while j + 1 < len(s_sorted) and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        if j > i:
            ranks[order[i : j + 1]] = (i + j + 2) / 2.0
        i = j + 1
    n_pos = labels.sum()
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[labels].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
