"""Shared evaluation metrics and event counters."""

from __future__ import annotations

import os
import threading

import numpy as np

# Every production counter name, in one place. pscheck rule PS401 parses
# this set (via ast, without importing) and flags any ``Counters.inc`` /
# ``Counters(...)`` literal not listed here, so a typo'd name can never
# silently mint a new counter that no bench or test ever reads. Runtime
# strict mode (REPRO_SANLOCK=1 / REPRO_STRICT_COUNTERS=1) enforces the
# same contract on dynamically-built names.
KNOWN_COUNTERS = frozenset({
    # serving engine (serve/engine.py COUNTER_NAMES)
    "lookups", "coalesced_requests", "merged_pulls",
    "hot_hits", "hot_misses", "device_rows_reused", "rows_served",
    "version_rolls", "failovers", "failover_rows", "failed_lookups",
    "replica_errors",
    # SSD-PS integrity (core/ssd_ps.py)
    "ssd_files_quarantined", "ssd_rows_quarantined",
    "ssd_rows_healed", "ssd_rows_reinit", "ssd_heal_degraded",
    # node recovery (core/node.py fault_counters)
    "node_recoveries", "rows_replayed",
    # NIC wire quantization (core/node.py NetworkModel via add_from)
    "quantized_messages", "quantize_bytes_saved",
    # training wire (core/hier_ps.py WIRE_COUNTER_NAMES): push direction is
    # raw-vs-encoded bytes for the quantized gradient push; pull direction is
    # per-conflict-class rows and bytes saved (device-served rows ship no
    # bytes, forwarded rows ride the pin transfer, dedup rows collapse a
    # repeat pull inside the coalescing window to a pin message)
    "wire_push_rows", "wire_push_raw_bytes", "wire_push_enc_bytes",
    "wire_push_nonfinite_rows",
    "wire_pull_fresh_rows", "wire_pull_fresh_bytes",
    "wire_pull_device_rows", "wire_pull_device_bytes_saved",
    "wire_pull_forwarded_rows", "wire_pull_forwarded_bytes_saved",
    "wire_pull_dedup_rows", "wire_pull_dedup_bytes_saved",
    # streaming ingestion (ingest/staging.py + ingest/extract.py); times
    # are integer microseconds (counters are int-only)
    "ingest_batches", "ingest_examples", "staging_bytes",
    "ingest_wait_us", "ingest_overlap_us", "ingest_drained",
    # ad retrieval (retrieval/engine.py RETRIEVAL_COUNTER_NAMES)
    "retrieval_searches", "retrieval_queries", "retrieval_candidates",
    "retrieval_rows_scored", "retrieval_index_builds",
    "retrieval_index_rows", "retrieval_rolls", "retrieval_reranks",
    "retrieval_rerank_rows",
})


def _strict_default() -> bool:
    return bool(
        os.environ.get("REPRO_SANLOCK") or os.environ.get("REPRO_STRICT_COUNTERS")
    )


class Counters:
    """Named monotonic event counters (thread-safe).

    The serving subsystem reports through one of these (``lookups``,
    ``coalesced_requests``, ``hot_hits``, ``version_rolls``, ...) so benches
    and tests assert on counter values instead of scraping ad-hoc prints.
    Names passed to the constructor are pre-registered at 0 so a
    ``snapshot()`` always shows the full schema; ``inc`` accepts new names
    too (they appear once first incremented) — unless strict mode is on
    (``REPRO_SANLOCK``/``REPRO_STRICT_COUNTERS``, or ``strict=True``), in
    which case a name neither pre-registered nor in :data:`KNOWN_COUNTERS`
    raises instead of silently minting a counter.
    """

    def __init__(self, *names: str, strict: bool | None = None):
        self._lock = threading.Lock()
        self._c: dict[str, int] = {n: 0 for n in names}
        self._strict = _strict_default() if strict is None else bool(strict)

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            if self._strict and name not in self._c and name not in KNOWN_COUNTERS:
                raise ValueError(
                    f"unknown counter {name!r}: declare it in "
                    "repro.metrics.KNOWN_COUNTERS (or the constructor)"
                )
            self._c[name] = self._c.get(name, 0) + int(n)

    def __getitem__(self, name: str) -> int:
        with self._lock:
            return self._c.get(name, 0)

    def snapshot(self) -> dict:
        """A consistent copy of every counter."""
        with self._lock:
            return dict(self._c)

    def reset(self) -> None:
        with self._lock:
            self._c = {n: 0 for n in self._c}

    def add_from(self, other: "Counters | dict") -> None:
        """Accumulate another counter set (or plain dict) into this one —
        benches merge per-subsystem counters (cluster faults, serving
        engine) into one report without losing either source."""
        src = other.snapshot() if isinstance(other, Counters) else dict(other)
        with self._lock:
            for n, v in src.items():
                self._c[n] = self._c.get(n, 0) + int(v)


def auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (Mann-Whitney), with tie averaging."""
    labels = np.asarray(labels).astype(bool)
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty(len(scores), dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    s_sorted = scores[order]
    i = 0
    while i < len(s_sorted):
        j = i
        while j + 1 < len(s_sorted) and s_sorted[j + 1] == s_sorted[i]:
            j += 1
        if j > i:
            ranks[order[i : j + 1]] = (i + j + 2) / 2.0
        i = j + 1
    n_pos = labels.sum()
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[labels].sum() - n_pos * (n_pos + 1) / 2) / (n_pos * n_neg))
