"""Online serving subsystem (DESIGN.md §7).

Train -> serve handoff via versioned snapshots (:mod:`repro.serve.snapshot`),
a request-coalescing engine with a version-keyed hot-row cache and device
residency (:mod:`repro.serve.engine`), and the per-family prefill/decode
step factories (:mod:`repro.serve.serve_step`).
"""

from repro.serve.engine import (  # noqa: F401
    COUNTER_NAMES,
    HotRowCache,
    LiveClusterView,
    ServingEngine,
)
from repro.serve.snapshot import (  # noqa: F401
    ServingCluster,
    ServingVersion,
    SnapshotPublisher,
    latest_version,
    list_versions,
)
