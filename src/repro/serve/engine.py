"""Request-coalescing serving engine + serving-side hot-row cache (§7).

The serving read path, top to bottom:

    request streams ---\
    request streams ----+--> ServingEngine.lookup(table, keys)
    request streams ---/          |  leader/follower coalescing: concurrent
                                  |  requests merge into ONE deduped pull,
                                  v  results scatter back per request
                          HotRowCache (DRAM)     version-keyed, pin-free
                                  |  misses only
                                  v
                  ServingCluster.pull / live Cluster.pull(pin=False)
                     (remote segments: int8 wire when opted in)

plus a device tier for decode loops: :meth:`ServingEngine.lookup_device`
keeps the hottest rows device-resident across steps via
:class:`~repro.core.hbm_ps.DeviceHotSet` and transfers only the delta.

Everything is **version-keyed**: a merged batch acquires one
:class:`~repro.serve.snapshot.ServingVersion` and serves every request in
it from that version alone; cache rows remember the version they were
filled at and rows from a retired version read as misses. Hot hits are
bit-identical to a cold pull because a version's rows are immutable (the
cache stores exactly the bytes the cold pull returned, quantized wire
included — the encode is deterministic).

Counters (``lookups``, ``coalesced_requests``, ``hot_hits``, ``hot_misses``,
``version_rolls``, ...) are :class:`repro.metrics.Counters` — benches and
tests assert on them instead of scraping prints.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

import numpy as np

from repro.core.hash_index import U64Index
from repro.core.hbm_ps import DeviceHotSet
from repro.core.node import Cluster
from repro.core.tables import TableRegistry, TableSpec
from repro.metrics import Counters

COUNTER_NAMES = (
    "lookups",
    "coalesced_requests",
    "merged_pulls",
    "hot_hits",
    "hot_misses",
    "device_rows_reused",
    "rows_served",
    "version_rolls",
    "failovers",
    "failover_rows",
    "failed_lookups",
    "replica_errors",
)


class HotRowCache:
    """Pin-free, version-keyed read-through row cache (the serving DRAM tier).

    ``U64Index``-backed and array-backed like the MEM-PS arena, with none of
    its dirty/staging/pin machinery: serving rows are immutable within a
    version, so there is nothing to write back and nothing to pin. Staleness
    is impossible by construction — every row remembers the version it was
    filled at, and a lookup only hits rows whose version matches the
    request's; rows from retired versions read as misses and get overwritten
    in place or evicted.

    Eviction is one vectorized pass: stale-version rows first, then coldest
    by (freq, recency). All operations are batched numpy over unique keys —
    no per-key Python on hit or miss paths.
    """

    def __init__(self, capacity: int, dim: int):
        self.capacity = int(capacity)
        self.dim = int(dim)
        self.arena = np.zeros((self.capacity, self.dim), dtype=np.float32)
        self.key_of_row = np.zeros(self.capacity, dtype=np.uint64)
        self.version_of = np.full(self.capacity, -1, dtype=np.int64)
        self.freq = np.zeros(self.capacity, dtype=np.int64)
        self.last_used = np.zeros(self.capacity, dtype=np.int64)
        self.used = np.zeros(self.capacity, dtype=bool)
        self.index = U64Index(self.capacity)
        self._free = np.arange(self.capacity - 1, -1, -1, dtype=np.int64)
        self._free_n = self.capacity
        self._clock = 0
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return self.capacity - self._free_n

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.hits + self.misses)

    def lookup(self, keys: np.ndarray, version: int) -> tuple[np.ndarray, np.ndarray]:
        """keys: unique uint64. Returns (hit_mask, rows[n_hit]) — a hit
        requires both key presence AND a matching fill version."""
        keys = np.asarray(keys, dtype=np.uint64)
        rows = self.index.lookup(keys)
        m = rows >= 0
        hrows = rows[m]
        ok = self.version_of[hrows] == version
        hrows = hrows[ok]
        mask = np.zeros(len(keys), dtype=bool)
        mask[np.nonzero(m)[0][ok]] = True
        n_hit = len(hrows)
        self.hits += n_hit
        self.misses += len(keys) - n_hit
        if n_hit:
            self.freq[hrows] += 1
            self.last_used[hrows] = self._clock + np.arange(n_hit)
            self._clock += n_hit
        return mask, self.arena[hrows]

    def insert(self, keys: np.ndarray, rows: np.ndarray, version: int) -> None:
        """keys: unique uint64; rows: [n, dim]. Existing entries (stale
        versions included) are overwritten in place; new entries evict the
        stale-then-coldest rows when full."""
        keys = np.asarray(keys, dtype=np.uint64)
        rows = np.asarray(rows, dtype=np.float32)
        if len(keys) > self.capacity:  # keep the head; callers pass request
            keys, rows = keys[: self.capacity], rows[: self.capacity]  # order
        slots = self.index.lookup(keys)
        have = slots >= 0
        if have.any():
            hs = slots[have]
            self.arena[hs] = rows[have]
            self.version_of[hs] = version
            self.freq[hs] += 1
            self.last_used[hs] = self._clock + np.arange(len(hs))
            self._clock += len(hs)
        need = np.nonzero(~have)[0]
        n = len(need)
        if n == 0:
            return
        if n > self._free_n:
            self._evict(n - self._free_n, version)
        new_rows = self._free[self._free_n - n : self._free_n].copy()
        self._free_n -= n
        self.arena[new_rows] = rows[need]
        self.key_of_row[new_rows] = keys[need]
        self.version_of[new_rows] = version
        self.freq[new_rows] = 1
        self.last_used[new_rows] = self._clock + np.arange(n)
        self._clock += n
        self.used[new_rows] = True
        self.index.insert(keys[need], new_rows)

    def _evict(self, n: int, version: int) -> None:
        cand = np.nonzero(self.used)[0]
        # stale-version rows first (they can never hit again), then coldest
        stale = self.version_of[cand] != version
        order = np.lexsort((self.last_used[cand], self.freq[cand], ~stale))
        victims = cand[order[:n]]
        self.index.delete(self.key_of_row[victims])
        self.used[victims] = False
        self.version_of[victims] = -1
        self._free[self._free_n : self._free_n + len(victims)] = victims
        self._free_n += len(victims)


class LiveClusterView:
    """Serve directly off the live training cluster — no snapshot handoff.

    Reads are pin-free (``Cluster.pull(pin=False)``) and see whatever the
    trainer last pushed, so there is no cross-request version guarantee; the
    ``version`` here is a manual epoch for the engine's caches — call
    :meth:`roll_forward` after the trainer mutates rows to invalidate them.
    Use :class:`~repro.serve.snapshot.ServingCluster` for real versioned
    serving.
    """

    def __init__(self, cluster: Cluster, node_id: int = 0):
        if cluster.tables is None or len(cluster.tables) == 0:
            raise ValueError("live serving needs a cluster with registered tables")
        self.cluster = cluster
        self.node_id = int(node_id)
        self._version = 0

    @dataclass(frozen=True)
    class _Epoch:
        version: int

    @property
    def version(self) -> int:
        return self._version

    @property
    def registry(self) -> TableRegistry:
        return self.cluster.tables

    @property
    def dim(self) -> int:
        return self.cluster.dim

    @property
    def network(self):
        return self.cluster.network

    def acquire(self) -> "_Epoch":
        return LiveClusterView._Epoch(self._version)

    def pull(self, keys: np.ndarray, view=None) -> np.ndarray:
        return self.cluster.pull(keys, requester=self.node_id, pin=False)

    def roll_forward(self, version: int | None = None) -> int:
        self._version = self._version + 1 if version is None else int(version)
        return self._version


@dataclass
class _Request:
    """One stream's enqueued lookup, filled by the flush that serves it."""

    spec: TableSpec
    shape: tuple
    keys: np.ndarray  # flat, namespaced
    event: threading.Event = field(default_factory=threading.Event)
    out: np.ndarray | None = None
    err: BaseException | None = None
    promoted: bool = False  # woken to take over leadership, not served yet


class ServingEngine:
    """The serving API: coalesced, cached, versioned lookups on named tables.

    ``source`` is a :class:`~repro.serve.snapshot.ServingCluster` (versioned
    snapshots) or a :class:`LiveClusterView`. Concurrent ``lookup`` calls
    coalesce leader/follower style: the first request in becomes the leader,
    optionally sleeps ``coalesce_window_s`` to let followers enqueue, then
    merges everything pending — dedup across requests, ONE cluster pull for
    the union's misses — and scatters rows back per request before waking
    the followers. ``lookup_many`` runs the same merge for a list of
    requests in one call (deterministic coalescing for closed-loop callers
    and tests). ``lookup_device`` is the decode-loop path: slots + a dense
    device table, with a :class:`DeviceHotSet` keeping hot rows resident.
    """

    def __init__(
        self,
        source,
        *,
        cache_rows: int = 65536,
        device_hot_rows: int = 0,
        coalesce_window_s: float = 0.0,
        counters: Counters | None = None,
        fallbacks: "list | tuple" = (),
    ):
        self.source = source
        # surviving replicas to serve from when the primary source fails
        # mid-lookup (DESIGN.md §9): tried in order, each on its own active
        # version — degraded serving, so failover rows are never cached
        # under the primary's version key
        self.fallbacks = list(fallbacks)
        self.counters = counters or Counters(*COUNTER_NAMES)
        self.cache = HotRowCache(cache_rows, source.dim) if cache_rows else None
        self.coalesce_window_s = float(coalesce_window_s)
        self._mu = threading.Lock()  # pending queue + leader election
        self._cache_mu = threading.Lock()  # hot-row cache state
        self._dev_mu = threading.Lock()  # device hot sets (plan/admit pairs)
        self._pending: list[_Request] = []
        self._flushing = False
        self._dev: dict[str, DeviceHotSet] = {}
        self._device_hot_rows = int(device_hot_rows)

    # ------------------------------------------------------------- plumbing
    @property
    def registry(self) -> TableRegistry:
        return self.source.registry

    @property
    def version(self) -> int:
        return self.source.version

    def roll_forward(self, version: int | None = None) -> int:
        """Advance to a newer published version (default: latest) without
        dropping in-flight lookups; they finish on the version they
        acquired. Stale cache/device-resident rows become misses."""
        before = self.source.version
        after = self.source.roll_forward(version)
        for fb in self.fallbacks:
            fb.roll_forward(after)  # replicas track the primary's version
        if after != before:
            self.counters.inc("version_rolls")
        return after

    def _make_req(self, table: str, keys) -> _Request:
        spec = self.registry.require(table)
        arr = np.asarray(keys, dtype=np.uint64)
        return _Request(spec, np.shape(arr), spec.namespace(arr).reshape(-1))

    # ------------------------------------------------------------- failover
    def _pull_source(self, view, keys: np.ndarray) -> "tuple[np.ndarray, bool]":
        """Pull ``keys`` from the primary source, failing over to surviving
        fallback replicas when it raises (replica loss rides through as a
        served request, not an error). Returns ``(rows, cacheable)`` —
        failover rows come from the fallback's own active version, so they
        must NOT be cached under the primary view's version key (a later
        hot hit would have to be bit-identical to a primary cold pull).
        Only when every replica fails does the original error surface."""
        try:
            return self.source.pull(keys, view=view), True
        except Exception as primary_err:
            for fb in self.fallbacks:
                try:
                    rows = fb.pull(keys, view=fb.acquire())
                except Exception:
                    # this replica is gone too; try the next — but count the
                    # skip so replica loss is never silent (pscheck PS301)
                    self.counters.inc("replica_errors")
                    continue
                self.counters.inc("failovers")
                self.counters.inc("failover_rows", len(keys))
                return rows, False
            self.counters.inc("failed_lookups")
            raise primary_err

    # ------------------------------------------------------------ hot cache
    def _rows_for(self, view, uniq: np.ndarray) -> np.ndarray:
        """Full-width rows for unique cluster keys, read through the
        version-keyed hot cache.

        The cluster pull runs OUTSIDE the cache lock: a cold pull pays SSD
        reads plus (possibly slept) NIC time, and holding the lock across
        it would serialize every concurrent path — including pure cache
        hits — behind one flush's misses. Two threads may then pull the
        same row concurrently; that is safe, not just tolerable, because a
        version's rows are immutable (both pulls return identical bytes and
        the second insert overwrites in place)."""
        version = view.version
        if self.cache is None:
            self.counters.inc("hot_misses", len(uniq))
            rows, _ = self._pull_source(view, uniq)
            return rows
        with self._cache_mu:
            mask, hit_rows = self.cache.lookup(uniq, version)
        n_hit = int(mask.sum())
        self.counters.inc("hot_hits", n_hit)
        if n_hit == len(uniq):
            return hit_rows
        out = np.empty((len(uniq), self.source.dim), dtype=np.float32)
        out[mask] = hit_rows
        miss = ~mask
        self.counters.inc("hot_misses", int(miss.sum()))
        pulled, cacheable = self._pull_source(view, uniq[miss])
        out[miss] = pulled
        if cacheable:
            with self._cache_mu:
                self.cache.insert(uniq[miss], pulled, version)
        return out

    # ------------------------------------------------------------- lookups
    def _serve_batch(self, batch: list[_Request]) -> None:
        """Merge, pull once, scatter back. Never raises — failures land on
        each request's ``err`` so follower threads re-raise locally."""
        try:
            view = self.source.acquire()  # ONE version for the whole merge
            all_keys = np.concatenate([r.keys for r in batch])
            uniq, inverse = np.unique(all_keys, return_inverse=True)
            self.counters.inc("merged_pulls")
            if len(batch) > 1:
                self.counters.inc("coalesced_requests", len(batch))
            rows = self._rows_for(view, uniq)
            self.counters.inc("rows_served", len(all_keys))
            off = 0
            for r in batch:
                n = len(r.keys)
                emb = r.spec.schema.emb_dim
                sel = inverse[off : off + n]
                off += n
                r.out = rows[sel][:, :emb].reshape(r.shape + (emb,))
        except BaseException as e:
            for r in batch:
                r.err = e
        finally:
            for r in batch:
                r.event.set()

    def _lead_one_flush(self) -> None:
        """Serve ONE merged batch (everything pending right now — which
        includes the calling thread's own request), then hand leadership to
        the oldest newly-arrived follower instead of draining the queue:
        under sustained load a drain-to-empty leader would keep serving
        other streams' requests long after its own was filled, unbounding
        that request's latency. ``_flushing`` stays True across the
        handoff, so arrivals keep enqueueing as followers."""
        with self._mu:
            batch, self._pending = self._pending, []
            if not batch:
                self._flushing = False
                return
        self._serve_batch(batch)
        with self._mu:
            if not self._pending:
                self._flushing = False
                return
            nxt = self._pending[0]
            nxt.promoted = True
        nxt.event.set()  # wakes as the next leader, not as served

    def lookup(self, table: str, keys) -> np.ndarray:
        """Rows of ``table``'s ``emb`` field for ``keys`` (any shape);
        returns ``keys.shape + (emb_dim,)``. Thread-safe; concurrent calls
        coalesce into shared pulls."""
        req = self._make_req(table, keys)
        self.counters.inc("lookups")
        with self._mu:
            self._pending.append(req)
            lead = not self._flushing
            if lead:
                self._flushing = True
        if lead:
            if self.coalesce_window_s > 0:
                time.sleep(self.coalesce_window_s)
            self._lead_one_flush()
        else:
            req.event.wait()
            if req.promoted:  # take over leadership; our request is still
                req.event.clear()  # pending and gets served in our flush
                self._lead_one_flush()
        if req.err is not None:
            raise req.err
        return req.out

    def lookup_at(self, table: str, keys, *, view=None) -> np.ndarray:
        """Version-pinned lookup: like :meth:`lookup` but served entirely
        from ``view`` (an acquired source version; default: the active one)
        and without coalescing. The retrieval rerank path reads user-side
        rows at the exact version its index was built on, so a concurrent
        ``roll_forward`` can never mix versions inside one scored request.
        Rows still read through the version-keyed hot cache."""
        req = self._make_req(table, keys)
        self.counters.inc("lookups")
        if view is None:
            view = self.source.acquire()
        uniq, inverse = np.unique(req.keys, return_inverse=True)
        rows = self._rows_for(view, uniq)
        self.counters.inc("rows_served", len(req.keys))
        emb = req.spec.schema.emb_dim
        return rows[inverse][:, :emb].reshape(req.shape + (emb,))

    def lookup_many(self, requests: "list[tuple[str, np.ndarray]]") -> list[np.ndarray]:
        """Serve N streams' lookups as one merged batch (deterministic
        coalescing: one deduped pull for the union of all keys)."""
        batch = [self._make_req(t, k) for t, k in requests]
        self.counters.inc("lookups", len(batch))
        self._serve_batch(batch)
        for r in batch:
            if r.err is not None:
                raise r.err
        return [r.out for r in batch]

    # ---------------------------------------------------------- device path
    def lookup_device(self, table: str, keys):
        """Decode-loop path: ``(slots, device_table)`` where ``slots`` maps
        each key position to a row of the dense [n_working, emb_dim] device
        table. With ``device_hot_rows`` > 0 the hottest rows stay
        device-resident across steps (per table) and only the delta is
        transferred from host."""
        import jax.numpy as jnp

        req = self._make_req(table, keys)
        self.counters.inc("lookups")
        emb = req.spec.schema.emb_dim
        uniq, inverse = np.unique(req.keys, return_inverse=True)
        slots = inverse.astype(np.int32).reshape(req.shape)
        view = self.source.acquire()
        self.counters.inc("rows_served", len(req.keys))
        if self._device_hot_rows <= 0:
            rows = self._rows_for(view, uniq)[:, :emb]
            return slots, jnp.asarray(rows)
        # An admit() swapping the resident table between another thread's
        # plan() and assemble() would gather rows by stale indices — jnp
        # clamps out-of-bounds gathers, so that bug would serve wrong rows
        # silently, not raise. But the host pull blocks on SSD/NIC work,
        # so it must NOT run under _dev_mu (pscheck PS202): instead plan
        # under the lock, pull outside it, and re-check the hot set's
        # generation before assembling — a concurrent mutation just replans
        # (the second pass usually reuses the first pull's rows from the
        # hot cache, so the retry is cheap).
        while True:
            with self._dev_mu:
                dev = self._dev.get(table)
                if dev is None:
                    dev = self._dev[table] = DeviceHotSet(self._device_hot_rows, emb * 4)
                plan = dev.plan(uniq, view.version)
                gen = dev.generation
            if len(plan.fresh_dst):
                host = self._rows_for(view, uniq[plan.fresh_dst])[:, :emb]
            else:
                host = np.empty((0, emb), dtype=np.float32)
            with self._dev_mu:
                if dev.generation != gen:
                    continue  # raced with another lookup's admit: replan
                self.counters.inc("device_rows_reused", plan.n_reused)
                table_dev = dev.assemble_and_admit(jnp.asarray(host), plan)
            return slots, table_dev

    def device_hot_stats(self, table: str):
        dev = self._dev.get(table)
        return None if dev is None else dev.stats
