"""Serving-step factories: prefill and decode programs per family.

These are the exact programs the dry-run lowers for the ``prefill_*`` /
``decode_*`` / ``long_*`` shape cells, and the programs examples/serve_lm.py
runs. Decode caches:

  transformer — KVCache stacked [L, B, Hkv, C, Dh]; C = context length;
                sharded over (batch, kv-heads|kv-seq, -) per sharding rules
  hymba       — HymbaCache: ring buffers (SWA) + 3 full caches + SSM states
  xlstm       — XLSTMCache: O(1) recurrent state (no KV at all)
  whisper     — WhisperCache: decoder self cache + precomputed cross K/V
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import get_model
from repro.models.attention import KVCache


def make_prefill_step(cfg: ArchConfig, attn_impl: str = "auto"):
    """fn(params, batch) -> (last_logits, cache-or-state)."""
    model = get_model(cfg)

    if cfg.family in ("dense", "moe", "vlm"):

        def step(params, batch):
            kwargs = {}
            if cfg.family == "vlm":
                kwargs["image_embeds"] = batch["image_embeds"]
            if cfg.embedding_mode == "hier_ps":
                kwargs["working_table"] = batch["working_table"]
            from repro.models import transformer as T

            return T.prefill(cfg, params, batch["tokens"], attn_impl=attn_impl, **kwargs)

    elif cfg.family == "audio":

        def step(params, batch):
            from repro.models import whisper as W

            kwargs = {}
            if cfg.embedding_mode == "hier_ps":
                kwargs["working_table"] = batch["working_table"]
            return W.prefill(cfg, params, batch["tokens"], batch["frames"], attn_impl=attn_impl, **kwargs)

    elif cfg.family == "hybrid":

        def step(params, batch):
            from repro.models import hymba as H

            kwargs = {}
            if cfg.embedding_mode == "hier_ps":
                kwargs["working_table"] = batch["working_table"]
            return H.prefill(cfg, params, batch["tokens"], attn_impl=attn_impl, **kwargs)

    elif cfg.family == "ssm":

        def step(params, batch):
            from repro.models import xlstm as X

            kwargs = {}
            if cfg.embedding_mode == "hier_ps":
                kwargs["working_table"] = batch["working_table"]
            logits, _ = X.forward(cfg, params, batch["tokens"], remat=False, **kwargs)
            return logits[:, -1:], None

    else:
        raise ValueError(cfg.family)

    return step


def make_decode_step(cfg: ArchConfig, attn_impl: str = "naive"):
    """fn(params, batch, cache, pos) -> (logits, new_cache).

    ``batch["token"]``: [B, 1] int32 (working slot in hier_ps mode);
    ``pos``: traced int32 scalar — current context length.
    """
    model = get_model(cfg)

    def step(params, batch, cache, pos):
        kwargs = {}
        if cfg.embedding_mode == "hier_ps":
            kwargs["working_table"] = batch["working_table"]
        if cfg.family == "ssm":
            return model.decode_step(cfg, params, batch["token"], cache, **kwargs)
        return model.decode_step(
            cfg, params, batch["token"], cache, pos, attn_impl=attn_impl, **kwargs
        )

    return step


def greedy_sample(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
