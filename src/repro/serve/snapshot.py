"""Versioned snapshot publishing: the train -> serve handoff (DESIGN.md §7).

The training cluster's SSD-PS is log-structured — parameter files are
immutable and updates always land in *new* files (``ssd_ps.py``). Publishing
a serving snapshot is therefore **repointing, not copying**:

    trainer ----publish----> v_00000007.json            (manifest only)
        |                       |  key->file map, table specs, init params
        |                       |  + retention refs on every named file
        '--- keeps training --->|  (new files; compaction parks, never
                                |   deletes, a retained path)
    ServingCluster --open------>'  read-only views over the SAME files

:class:`SnapshotPublisher` captures the cluster's ``publish_manifest()``
(which atomically takes per-file retention references so compaction can
never delete a file a live version points to), writes one immutable JSON
manifest per version, and flips a ``LATEST`` pointer last — the same
temp-file + ``os.replace`` discipline as ``checkpoint.py``, whose helpers it
shares. Publishing N versions after training M batches costs N small JSON
files, not N copies of the table.

:class:`ServingCluster` is the inference-side counterpart: it opens a named
version **read-only** (per-node SSD views built from the manifest; no
MEM-PS, no pins, no write path) and can :meth:`~ServingCluster.roll_forward`
to a newer version without dropping requests — the active
:class:`ServingVersion` is swapped atomically and in-flight lookups keep
reading the version object they acquired, whose files stay on disk until
the publisher releases them. Remote shard reads travel the simulated NIC
and, with ``NetworkModel(wire_quantize=True)``, the int8 row-sparse wire
format (serving reads tolerate quantization; see ``compression.py``).
"""

from __future__ import annotations

import os
import re
import threading

import numpy as np

from repro.core.keys import key_to_node, partition_by_owner
from repro.core.node import Cluster, NetworkModel, NodeDownError
from repro.core.ssd_ps import SSDParameterServer
from repro.core.tables import TableRegistry
from repro.train.checkpoint import atomic_write_json, flip_pointer

_VERSION_RE = re.compile(r"^v_(\d{8})\.json$")


def _version_path(directory: str, version: int) -> str:
    return os.path.join(directory, f"v_{version:08d}.json")


def list_versions(directory: str) -> list[int]:
    """All published version ids in ``directory``, ascending."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _VERSION_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def latest_version(directory: str) -> int | None:
    """The LATEST pointer's target (fallback: newest manifest on disk)."""
    ptr = os.path.join(directory, "LATEST")
    if os.path.exists(ptr):
        with open(ptr) as f:
            name = f.read().strip()
        m = _VERSION_RE.match(name)
        if m:
            return int(m.group(1))
    versions = list_versions(directory)
    return versions[-1] if versions else None


def load_version(directory: str, version: int) -> dict:
    import json

    with open(_version_path(directory, version)) as f:
        return json.load(f)


class SnapshotPublisher:
    """Training-side: atomically publish immutable table versions.

    ``keep`` > 0 auto-releases versions this publisher created beyond the
    newest ``keep`` (their retained files become deletable); ``keep=0``
    (default) never auto-releases — the operator (or a test) calls
    :meth:`release` once no serving cluster reads the version anymore.
    Releasing a version a live ServingCluster still serves is an operator
    error, exactly like deleting a checkpoint mid-restore.
    """

    def __init__(self, cluster: Cluster, directory: str, keep: int = 0):
        os.makedirs(directory, exist_ok=True)
        self.cluster = cluster
        self.dir = directory
        self.keep = int(keep)
        self._lock = threading.Lock()
        # version -> per-node retained path lists (for release)
        self._live: dict[int, dict[int, list[str]]] = {}
        self._released: set[int] = set()  # release() is idempotent per id
        last = latest_version(directory)
        self._next = (last or 0) + 1

    def publish(self) -> int:
        """Publish the cluster's current (flushed) state as a new version.

        Returns the version id. The manifest is written to a temp file and
        ``os.replace``d, then LATEST is flipped — a reader never observes a
        half-written version, and a crash mid-publish leaves the previous
        LATEST intact.
        """
        with self._lock:
            version = self._next
            self._next += 1
            # pin the redo log *before* the manifest's flush: the retained
            # suffix then covers every push after this snapshot's state, so
            # the cluster can heal a quarantined SSD file bit-exactly as
            # snapshot(version) + redo replay (DESIGN.md §9)
            redo_pin = self.cluster.pin_redo()
            m = self.cluster.publish_manifest()  # flush + atomic retention
            retained = {
                int(nid): list(nm.get("retained_paths", []))
                for nid, nm in m["nodes"].items()
            }
            atomic_write_json(
                _version_path(self.dir, version),
                {"version": version, "cluster": m},
            )
            flip_pointer(
                os.path.join(self.dir, "LATEST"),
                os.path.basename(_version_path(self.dir, version)),
            )
            self._live[version] = retained
            self.cluster.set_heal_source(self.dir, version, redo_pin)
            if self.keep > 0:
                for v in sorted(self._live)[: -self.keep]:
                    self._release_locked(v)
            return version

    def _release_locked(self, version: int) -> None:
        if version in self._released:
            return  # double release would over-decrement refs that other
            # versions still hold on shared paths
        retained = self._live.pop(version, None)
        if retained is None:
            # a version published by a previous publisher instance over the
            # same directory (restart): its retained paths are recorded in
            # the on-disk manifest, so the release still reaches the SSDs
            try:
                m = load_version(self.dir, version)["cluster"]
            except FileNotFoundError:
                return
            retained = {
                int(nid): list(nm.get("retained_paths", []))
                for nid, nm in m["nodes"].items()
            }
        self._released.add(version)
        self.cluster.release_files(retained)

    def rebind(self, cluster: Cluster) -> None:
        """Re-attach to a restored/resharded cluster (CTRTrainer.resume).

        Retention references live inside the SSD-PS instances, so a
        ``Cluster.restore`` starts with zero — without re-taking them,
        compaction on the restored cluster would delete files that live
        published versions still reference. Re-takes every live version's
        references on the new instances."""
        with self._lock:
            self.cluster = cluster
            for retained in self._live.values():
                for nid, paths in retained.items():
                    cluster.nodes[int(nid)].ssd.retain_files(paths)

    def release(self, version: int) -> None:
        """Retire a version: its manifest stays but its retention refs drop
        (files already superseded by compaction get deleted)."""
        with self._lock:
            self._release_locked(version)

    def versions(self) -> list[int]:
        return list_versions(self.dir)

    def latest(self) -> int | None:
        return latest_version(self.dir)


class ServingVersion:
    """One immutable published version, opened read-only.

    Holds per-node SSD views over the *training* cluster's parameter files
    (paths come from the manifest; nothing is copied) with the table
    registry's schema-aware missing-row initializer installed, so unseen
    keys serve the same deterministic init rows the training cluster would.
    The object is immutable after construction — a lookup that acquired it
    keeps a consistent view across a concurrent roll-forward.
    """

    def __init__(self, directory: str, version: int):
        snap = load_version(directory, version)
        m = snap["cluster"]
        self.version = int(snap["version"])
        self.n_nodes = int(m["n_nodes"])
        self.dim = int(m["dim"])
        init_scale = float(m.get("init_scale", 0.01))
        init_cols = m.get("init_cols")
        self.tables = (
            TableRegistry.from_manifest(m["tables"]) if m.get("tables") else TableRegistry()
        )
        nodes = m["nodes"]
        self.ssd: list[SSDParameterServer] = []
        for nid in range(self.n_nodes):
            nm = nodes.get(nid, nodes.get(str(nid)))  # JSON string keys
            view = SSDParameterServer.from_manifest(
                directory, nm, init_scale=init_scale, init_cols=init_cols,
                auto_compact=False,
            )
            if len(self.tables):
                view.initializer = self.tables.initializer(
                    self.dim, init_scale, init_cols
                )
            self.ssd.append(view)

    def read(self, node_id: int, keys: np.ndarray) -> np.ndarray:
        return self.ssd[node_id].read_batch(keys)


class ServingCluster:
    """Read-only serving side over published versions.

    The partitioned pull mirrors :meth:`Cluster.pull`'s owner-sorted
    protocol (local shard from the local view, remote shards over the NIC
    model, int8 wire when ``network.wire_quantize``) but with no MEM-PS, no
    pins and no write path — the serving-side DRAM tier is the engine's
    version-keyed :class:`~repro.serve.engine.HotRowCache` instead.
    """

    def __init__(
        self,
        directory: str,
        version: int | None = None,
        network: NetworkModel | None = None,
        node_id: int = 0,
    ):
        self.dir = directory
        self.network = network or NetworkModel()
        self.node_id = int(node_id)
        self._lock = threading.Lock()
        if version is None:
            version = latest_version(directory)
            if version is None:
                raise FileNotFoundError(f"no published versions in {directory}")
        self._active = ServingVersion(directory, version)
        self.alive = True

    # ---------------------------------------------------------- fault model
    def kill(self) -> None:
        """Simulate losing this serving replica: subsequent pulls raise
        :class:`~repro.core.node.NodeDownError` (the engine fails over to
        surviving replicas, DESIGN.md §9) until a roll_forward revives it —
        modeling a replacement replica coming up on the published version."""
        self.alive = False

    # ------------------------------------------------------------ versions
    @property
    def version(self) -> int:
        return self._active.version

    @property
    def registry(self) -> TableRegistry:
        return self._active.tables

    @property
    def dim(self) -> int:
        return self._active.dim

    def acquire(self) -> ServingVersion:
        """The active version, atomically. A request works entirely against
        the object it acquired — rolling forward mid-request cannot mix
        versions within one lookup."""
        return self._active

    def roll_forward(self, version: int | None = None) -> int:
        """Swap to ``version`` (default: LATEST). The new version is opened
        fully *before* the swap, so concurrent lookups see either the old
        or the new version, never a partial one. Returns the active id."""
        with self._lock:
            target = latest_version(self.dir) if version is None else int(version)
            if target is None or target == self._active.version:
                self.alive = True  # replacement replica on the same version
                return self._active.version
            self._active = ServingVersion(self.dir, target)
            self.alive = True
            return self._active.version

    # ---------------------------------------------------------------- pull
    def pull(self, keys: np.ndarray, view: ServingVersion | None = None) -> np.ndarray:
        """Owner-partitioned read of ``keys`` (cluster key space) against
        one version. Remote segments cross the simulated NIC; serving reads
        ride the int8 wire when the network opts in."""
        if not self.alive:
            raise NodeDownError("serving replica is down")
        view = view or self.acquire()
        keys = np.asarray(keys, dtype=np.uint64)
        owners = key_to_node(keys, view.n_nodes)
        order, splits = partition_by_owner(keys, owners, view.n_nodes)
        bounds = np.concatenate([[0], splits, [len(keys)]])
        sorted_keys = keys[order]
        sorted_out = np.empty((len(keys), view.dim), dtype=np.float32)
        for node_id in range(view.n_nodes):
            lo, hi = int(bounds[node_id]), int(bounds[node_id + 1])
            if lo == hi:
                continue
            vals = view.read(node_id, sorted_keys[lo:hi])
            if node_id != self.node_id:
                self.network.transfer((hi - lo) * 8)  # request keys out
                vals = self.network.reply(sorted_keys[lo:hi], vals, serving=True)
            sorted_out[lo:hi] = vals
        out = np.empty_like(sorted_out)
        out[order] = sorted_out
        return out
