"""Mamba (S6) selective-SSM mixer — used by the Hymba hybrid heads.

Faithful Mamba-1 structure: in-proj -> causal depthwise conv + SiLU ->
selective scan (input-dependent dt, B, C; diagonal A) -> gate -> out-proj.

Scan strategies:
  * ``recurrent`` — lax.scan over time, state h [B, din, N]. Exact; O(1)
    state; used for decode and as the oracle.
  * ``chunked``  — lax.scan over chunks of size Q with a closed-form
    intra-chunk pass in log space (cumsum of decays). Memory O(S*din*N/Q
    chunks processed one at a time) — used for train/prefill.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, with_logical_constraint


class MambaState(NamedTuple):
    h: jax.Array  # [B, din, N]
    conv: jax.Array  # [B, K-1, din] — last K-1 inputs for the depthwise conv


def mamba_schema(d_model: int, ssm_state: int, layers: int | None = None, expand: int = 2, conv_k: int = 4, dt_rank: int = 128) -> dict:
    din = expand * d_model
    L = layers
    stack = (L,) if L else ()
    lax_ = ("layers",) if L else ()
    f = len(stack)
    return {
        "in_proj": ParamSpec(stack + (d_model, 2 * din), lax_ + ("embed", "ssm"), fan_axis=f),
        "conv_w": ParamSpec(stack + (conv_k, din), lax_ + (None, "ssm"), scale=0.5, fan_axis=f),
        "conv_b": ParamSpec(stack + (din,), lax_ + ("ssm",), init="zeros"),
        "w_bc": ParamSpec(stack + (din, 2 * ssm_state), lax_ + ("ssm", None), fan_axis=f),
        "w_dt_down": ParamSpec(stack + (din, dt_rank), lax_ + ("ssm", None), fan_axis=f),
        "w_dt_up": ParamSpec(stack + (dt_rank, din), lax_ + (None, "ssm"), fan_axis=f),
        "dt_bias": ParamSpec(stack + (din,), lax_ + ("ssm",), init="zeros"),
        "a_log": ParamSpec(stack + (din, ssm_state), lax_ + ("ssm", None), init="zeros"),
        "d_skip": ParamSpec(stack + (din,), lax_ + ("ssm",), init="ones"),
        "out_proj": ParamSpec(stack + (din, d_model), lax_ + ("ssm", "embed"), fan_axis=f),
    }


def _conv_causal(x: jax.Array, w: jax.Array, b: jax.Array, history: jax.Array | None = None):
    """Depthwise causal conv. x: [B,S,din]; w: [K,din]. history: [B,K-1,din]."""
    K = w.shape[0]
    if history is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = history.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, din]
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K)) + b
    new_hist = xp[:, -(K - 1) :] if K > 1 else xp[:, :0]
    return out, new_hist


def _ssm_inputs(p: dict, x: jax.Array):
    """Common projections. x: [B,S,din] (post-conv). Returns dt, B_t, C_t, A."""
    N = p["a_log"].shape[-1]
    bc = x @ p["w_bc"]  # [B,S,2N]
    B_t, C_t = bc[..., :N], bc[..., N:]
    dt = jax.nn.softplus((x @ p["w_dt_down"]) @ p["w_dt_up"] + p["dt_bias"])  # [B,S,din]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # [din, N], negative
    return dt, B_t, C_t, A


def mamba_mixer(
    p: dict,
    x: jax.Array,  # [B, S, d_model]
    *,
    chunk: int = 256,
    state: MambaState | None = None,
) -> tuple[jax.Array, MambaState]:
    """Full mixer. With ``state`` (decode), S is typically 1."""
    B, S, _ = x.shape
    din = p["out_proj"].shape[0]
    xz = x @ p["in_proj"]
    xin, z = xz[..., :din], xz[..., din:]
    xin, conv_hist = _conv_causal(
        xin, p["conv_w"], p["conv_b"], None if state is None else state.conv
    )
    xin = jax.nn.silu(xin)
    xin = with_logical_constraint(xin, "batch", None, "ssm_act")
    dt, B_t, C_t, A = _ssm_inputs(p, xin)

    h0 = None if state is None else state.h
    if S == 1 and state is not None:  # decode: one recurrent step
        y, h = _scan_recurrent(xin, dt, B_t, C_t, A, h0)
    else:
        q = min(chunk, S)
        while S % q:  # largest power-of-two-ish divisor (meta tokens etc.)
            q //= 2
        y, h = _scan_chunked(xin, dt, B_t, C_t, A, h0, chunk=max(1, q))
    y = y + p["d_skip"] * xin
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    out = with_logical_constraint(out, "batch", None, "embed_act")
    return out, MambaState(h, conv_hist)


def _scan_recurrent(xin, dt, B_t, C_t, A, h0):
    """Exact per-step recurrence (oracle + decode). Shapes: xin/dt [B,S,din],
    B_t/C_t [B,S,N], A [din,N]."""
    B, S, din = xin.shape
    N = A.shape[-1]
    h0 = jnp.zeros((B, din, N), jnp.float32) if h0 is None else h0

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp  # [B,din],[B,din],[B,N],[B,N]
        decay = jnp.exp(dt_t[..., None] * A[None])  # [B,din,N]
        drive = (dt_t * x_t)[..., None] * b_t[:, None, :]  # [B,din,N]
        h = decay * h + drive
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        xin.transpose(1, 0, 2).astype(jnp.float32),
        dt.transpose(1, 0, 2).astype(jnp.float32),
        B_t.transpose(1, 0, 2).astype(jnp.float32),
        C_t.transpose(1, 0, 2).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(xin.dtype), h


def _scan_chunked(xin, dt, B_t, C_t, A, h0, *, chunk: int):
    """Chunkwise-parallel selective scan.

    Within a chunk (local steps 1..Q) the linear recurrence
    ``h_j = exp(l_j) h_{j-1} + u_j`` is evaluated with an *associative scan*
    over (decay, value) pairs — numerically safe (only products of decays
    <= 1 appear; a cumsum/exp(-cum) closed form overflows f32 for strong
    decays) and log-depth on device. Memory O(Q * din * N) per chunk; the
    outer lax.scan carries the O(1) state between chunks.
    """
    B, S, din = xin.shape
    N = A.shape[-1]
    Q = chunk
    assert S % Q == 0, f"S={S} must tile by chunk={Q}"
    n_chunks = S // Q
    h0 = jnp.zeros((B, din, N), jnp.float32) if h0 is None else h0

    xin_c = xin.reshape(B, n_chunks, Q, din).transpose(1, 0, 2, 3).astype(jnp.float32)
    dt_c = dt.reshape(B, n_chunks, Q, din).transpose(1, 0, 2, 3).astype(jnp.float32)
    Bc = B_t.reshape(B, n_chunks, Q, N).transpose(1, 0, 2, 3).astype(jnp.float32)
    Cc = C_t.reshape(B, n_chunks, Q, N).transpose(1, 0, 2, 3).astype(jnp.float32)

    def combine(a, b):
        (d1, v1), (d2, v2) = a, b
        return d1 * d2, d2 * v1 + v2

    def chunk_step(h, inp):
        x_q, dt_q, b_q, c_q = inp  # [B,Q,din],[B,Q,din],[B,Q,N],[B,Q,N]
        l = dt_q[..., None] * A[None, None]  # [B,Q,din,N] log decay per step
        decay = jnp.exp(l)
        u = (dt_q * x_q)[..., None] * b_q[:, :, None, :]  # [B,Q,din,N]
        D, V = jax.lax.associative_scan(combine, (decay, u), axis=1)
        h_all = D * h[:, None] + V  # [B,Q,din,N]
        y = jnp.einsum("bqdn,bqn->bqd", h_all, c_q)
        return h_all[:, -1], y

    h, ys = jax.lax.scan(chunk_step, h0, (xin_c, dt_c, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, din)
    return y.astype(xin.dtype), h


def init_mamba_state(p_one_layer: dict, batch: int, n_layers: int | None = None) -> MambaState:
    din, N = p_one_layer["a_log"].shape[-2:]
    K = p_one_layer["conv_w"].shape[-2]
    lead = (n_layers,) if n_layers else ()
    return MambaState(
        jnp.zeros(lead + (batch, din, N), jnp.float32),
        jnp.zeros(lead + (batch, K - 1, din), jnp.float32),
    )
