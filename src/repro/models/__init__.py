"""Model zoo registry: one uniform API per architecture family."""

from __future__ import annotations

from types import SimpleNamespace

from repro.configs import ArchConfig


def get_model(cfg: ArchConfig) -> SimpleNamespace:
    """Returns a namespace with schema/init/forward/prefill/decode_step."""
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import transformer as m

        return SimpleNamespace(
            name="transformer",
            schema=m.schema,
            init=m.init,
            forward=m.forward,
            prefill=m.prefill,
            decode_step=m.decode_step,
        )
    if cfg.family == "hybrid":
        from repro.models import hymba as m

        return SimpleNamespace(
            name="hymba",
            schema=m.schema,
            init=m.init,
            forward=m.forward,
            prefill=m.prefill,
            decode_step=m.decode_step,
            init_cache=m.init_cache,
        )
    if cfg.family == "ssm":
        from repro.models import xlstm as m

        return SimpleNamespace(
            name="xlstm",
            schema=m.schema,
            init=m.init,
            forward=m.forward,
            prefill=None,  # recurrent: prefill == forward stepping states
            decode_step=m.decode_step,
            init_cache=m.init_cache,
        )
    if cfg.family == "audio":
        from repro.models import whisper as m

        return SimpleNamespace(
            name="whisper",
            schema=m.schema,
            init=m.init,
            forward=m.forward,
            prefill=m.prefill,
            decode_step=m.decode_step,
        )
    raise ValueError(f"unknown family {cfg.family!r}")
