"""Hymba: hybrid-head LM — parallel attention + mamba heads in every layer.

Per the paper [arXiv:2411.13676]: each layer normalizes its input once, runs
*attention heads* and *SSM (mamba) heads* in parallel on it, normalizes each
branch output and averages them (learned per-branch scale), then a SwiGLU MLP.
128 learned meta tokens are prepended to the sequence. Most layers use
sliding-window attention (SWA); layers {first, middle, last} use full
("global") attention.

Layer layout: the interleaved global/SWA pattern is realized as *segments* —
the SWA runs are scanned (stacked params), the few global layers are
unrolled. This keeps the scan uniform (a single static window per scan) and
gives each group its own cache geometry for long-context decode:

  * SWA layers — ring-buffer KV cache of size ``window``  (O(1) in context)
  * global layers — full-length KV cache (only 3 layers -> affordable)
  * mamba heads — O(1) recurrent state

which is exactly why this arch runs the ``long_500k`` cell.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import mamba as mamba_mod
from repro.models.attention import KVCache, attention_block, attention_schema
from repro.models.common import ParamSpec, init_params, rms_norm, with_logical_constraint
from repro.models.transformer import COMPUTE_DTYPE, _cast, mlp_block, mlp_schema


class HymbaCache(NamedTuple):
    swa: KVCache  # [n_swa, B, Hkv, W, Dh] ring buffers
    glb: KVCache  # [n_glb, B, Hkv, C, Dh] full caches
    ssm_swa: mamba_mod.MambaState  # stacked [n_swa, ...]
    ssm_glb: mamba_mod.MambaState  # stacked [n_glb, ...]


def segments(cfg: ArchConfig) -> list[tuple[str, int, int]]:
    """[(kind, start_layer, n_layers)] covering 0..n_layers in order."""
    glb = sorted(cfg.global_attn_layers)
    out: list[tuple[str, int, int]] = []
    prev = 0
    for g in glb:
        if g > prev:
            out.append(("swa", prev, g - prev))
        out.append(("global", g, 1))
        prev = g + 1
    if prev < cfg.n_layers:
        out.append(("swa", prev, cfg.n_layers - prev))
    return out


def _layer_schema(cfg: ArchConfig, L: int) -> dict:
    d = cfg.d_model
    return {
        "ln_in": ParamSpec((L, d), ("layers", None), init="ones"),
        "ln_attn": ParamSpec((L, d), ("layers", None), init="ones"),
        "ln_ssm": ParamSpec((L, d), ("layers", None), init="ones"),
        "beta_attn": ParamSpec((L, d), ("layers", None), init="ones"),
        "beta_ssm": ParamSpec((L, d), ("layers", None), init="ones"),
        "ln_mlp": ParamSpec((L, d), ("layers", None), init="ones"),
        "attn": attention_schema(cfg, layers=L),
        "ssm": mamba_mod.mamba_schema(d, cfg.ssm_state, layers=L),
        "mlp": mlp_schema(cfg, layers=L),
    }


def schema(cfg: ArchConfig) -> dict:
    n_glb = len(cfg.global_attn_layers)
    n_swa = cfg.n_layers - n_glb
    out: dict = {
        "swa_layers": _layer_schema(cfg, n_swa),
        "glb_layers": _layer_schema(cfg, n_glb),
        "meta_tokens": ParamSpec((cfg.n_meta_tokens, cfg.d_model), (None, "embed"), scale=0.02),
        "final_norm": ParamSpec((cfg.d_model,), (None,), init="ones"),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }
    if cfg.embedding_mode == "dense":
        out["embed"] = ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab_rep", "embed_tp"), scale=0.02)
    return out


def init(cfg: ArchConfig, rng: jax.Array):
    return init_params(schema(cfg), rng)


def _hymba_layer(
    cfg: ArchConfig,
    h: jax.Array,
    lp: dict,
    *,
    positions: jax.Array,
    window: int,
    attn_impl: str,
    cache: Optional[KVCache] = None,
    cache_pos=None,
    ring: bool = False,
    ssm_state: Optional[mamba_mod.MambaState] = None,
    q_offset=0,
):
    x = rms_norm(h, lp["ln_in"], cfg.norm_eps)
    attn_out, new_kv = attention_block(
        x, lp["attn"], cfg,
        positions=positions, causal=True, window=window, impl=attn_impl,
        cache=cache, cache_pos=cache_pos, ring=ring, q_offset=q_offset,
        return_kv=cache is None,
    )
    if ssm_state is None and x.shape[1] > 1:
        # recompute-vjp: don't store the chunk-scan intermediates
        # (decay/drive [B,Q,din,N] trees) as backward residuals (§Perf)
        ssm_out, new_state = jax.checkpoint(
            lambda p_, x_: mamba_mod.mamba_mixer(p_, x_)
        )(lp["ssm"], x)
    else:
        ssm_out, new_state = mamba_mod.mamba_mixer(lp["ssm"], x, state=ssm_state)
    mixed = 0.5 * (
        rms_norm(attn_out, lp["ln_attn"], cfg.norm_eps) * lp["beta_attn"]
        + rms_norm(ssm_out, lp["ln_ssm"], cfg.norm_eps) * lp["beta_ssm"]
    )
    h = h + mixed
    m = rms_norm(h, lp["ln_mlp"], cfg.norm_eps)
    h = h + mlp_block(m, lp["mlp"], cfg)
    return h, new_kv, new_state


def _take(params: dict, sl: slice):
    return jax.tree.map(lambda a: a[sl], params)


def forward(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,  # [B, S]
    *,
    working_table: Optional[jax.Array] = None,
    attn_impl: str = "auto",
    remat: bool = True,
    collect: bool = False,
):
    """Train/prefill forward. Meta tokens prepended. Returns
    (logits [B, S, V], aux) — or (logits, per-segment (kv, ssm) lists) when
    ``collect`` (prefill uses this to build the decode cache)."""
    from repro.models.transformer import embed_tokens

    h = embed_tokens(cfg, params, tokens, working_table)
    B = h.shape[0]
    meta = jnp.broadcast_to(
        params["meta_tokens"].astype(COMPUTE_DTYPE)[None], (B,) + params["meta_tokens"].shape
    )
    h = jnp.concatenate([meta, h], axis=1)
    S = h.shape[1]
    positions = jnp.arange(S)

    collected: list = []
    swa_idx = glb_idx = 0
    for kind, start, n in segments(cfg):
        window = 0 if kind == "global" else cfg.window
        group = params["glb_layers"] if kind == "global" else params["swa_layers"]
        idx = glb_idx if kind == "global" else swa_idx
        stack = _take(group, slice(idx, idx + n))

        def scan_body(carry, layer_p, window=window):
            out, kv, st = _hymba_layer(
                cfg, carry, _cast(layer_p),
                positions=positions, window=window, attn_impl=attn_impl,
            )
            ys = None
            if collect:
                ys = (
                    kv.k.astype(COMPUTE_DTYPE),
                    kv.v.astype(COMPUTE_DTYPE),
                    st.h,
                    st.conv,
                )
            return out, ys

        body = jax.checkpoint(scan_body, policy=jax.checkpoint_policies.nothing_saveable) if remat else scan_body
        h, ys = jax.lax.scan(body, h, stack)
        collected.append((kind, ys))
        if kind == "global":
            glb_idx += n
        else:
            swa_idx += n

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    # drop meta-token positions from the output
    logits = logits[:, cfg.n_meta_tokens :]
    if collect:
        return logits.astype(jnp.float32), collected
    return logits.astype(jnp.float32), jnp.float32(0)


def prefill(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,
    *,
    working_table: Optional[jax.Array] = None,
    attn_impl: str = "auto",
    max_len: int | None = None,
):
    """Returns (last_logits [B,1,V], HymbaCache ready for decode at
    pos = n_meta + S). SWA caches become ring buffers (last ``window``
    positions, rolled so slot = pos % window); global caches are padded to
    ``max_len``."""
    B, S_in = tokens.shape
    S_tot = cfg.n_meta_tokens + S_in
    W = cfg.window
    max_len = max_len or S_tot
    logits, collected = forward(
        cfg, params, tokens, working_table=working_table, attn_impl=attn_impl,
        remat=False, collect=True,
    )
    swa_k, swa_v, swa_h, swa_c = [], [], [], []
    glb_k, glb_v, glb_h, glb_c = [], [], [], []
    for kind, (ks, vs, hs, cs) in collected:
        if kind == "global":
            pad = max_len - S_tot
            glb_k.append(jnp.pad(ks, ((0, 0),) * 3 + ((0, pad), (0, 0))))
            glb_v.append(jnp.pad(vs, ((0, 0),) * 3 + ((0, pad), (0, 0))))
            glb_h.append(hs), glb_c.append(cs)
        else:
            if S_tot >= W:  # ring: slot j holds position p with p % W == j
                rk = jnp.roll(ks[..., S_tot - W :, :], S_tot % W, axis=-2)
                rv = jnp.roll(vs[..., S_tot - W :, :], S_tot % W, axis=-2)
            else:
                pad = ((0, 0),) * 3 + ((0, W - S_tot), (0, 0))
                rk, rv = jnp.pad(ks, pad), jnp.pad(vs, pad)
            swa_k.append(rk), swa_v.append(rv)
            swa_h.append(hs), swa_c.append(cs)
    cache = HymbaCache(
        KVCache(jnp.concatenate(swa_k), jnp.concatenate(swa_v)),
        KVCache(jnp.concatenate(glb_k), jnp.concatenate(glb_v)),
        mamba_mod.MambaState(jnp.concatenate(swa_h), jnp.concatenate(swa_c)),
        mamba_mod.MambaState(jnp.concatenate(glb_h), jnp.concatenate(glb_c)),
    )
    return logits[:, -1:].astype(jnp.float32), cache


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16) -> HymbaCache:
    n_glb = len(cfg.global_attn_layers)
    n_swa = cfg.n_layers - n_glb
    Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    W = min(cfg.window, max_len)
    swa_shape = (n_swa, batch, Hkv, W, hd)
    glb_shape = (n_glb, batch, Hkv, max_len, hd)
    one_layer = _strip(cfg)
    ssm_swa = mamba_mod.init_mamba_state(one_layer, batch, n_layers=n_swa)
    ssm_glb = mamba_mod.init_mamba_state(one_layer, batch, n_layers=n_glb)
    return HymbaCache(
        KVCache(jnp.zeros(swa_shape, dtype), jnp.zeros(swa_shape, dtype)),
        KVCache(jnp.zeros(glb_shape, dtype), jnp.zeros(glb_shape, dtype)),
        ssm_swa,
        ssm_glb,
    )


def _strip(cfg: ArchConfig) -> dict:
    """Abstract one-layer mamba params (shapes only) for state allocation."""
    sch = mamba_mod.mamba_schema(cfg.d_model, cfg.ssm_state, layers=None)
    from repro.models.common import abstract_params

    return abstract_params(sch)


def decode_step(
    cfg: ArchConfig,
    params,
    token: jax.Array,  # [B, 1]
    cache: HymbaCache,
    pos: jax.Array,  # scalar int32: tokens already consumed (incl. meta)
    *,
    working_table: Optional[jax.Array] = None,
    attn_impl: str = "naive",
):
    from repro.models.transformer import embed_tokens

    h = embed_tokens(cfg, params, token, working_table)
    positions = jnp.full((1,), pos, dtype=jnp.int32)

    new_swa_k, new_swa_v, new_glb_k, new_glb_v = [], [], [], []
    new_ssm_swa_h, new_ssm_swa_c, new_ssm_glb_h, new_ssm_glb_c = [], [], [], []
    swa_idx = glb_idx = 0
    for kind, start, n in segments(cfg):
        is_glb = kind == "global"
        group = params["glb_layers"] if is_glb else params["swa_layers"]
        idx = glb_idx if is_glb else swa_idx
        stack = _take(group, slice(idx, idx + n))
        kv = cache.glb if is_glb else cache.swa
        st = cache.ssm_glb if is_glb else cache.ssm_swa
        kv_seg = KVCache(kv.k[idx : idx + n], kv.v[idx : idx + n])
        st_seg = mamba_mod.MambaState(st.h[idx : idx + n], st.conv[idx : idx + n])

        def scan_body(carry, xs, is_glb=is_glb):
            layer_p, ck, cv, sh, sc = xs
            out, new_kv, new_state = _hymba_layer(
                cfg, carry, _cast(layer_p),
                positions=positions,
                window=0,
                attn_impl=attn_impl,
                cache=KVCache(ck, cv),
                cache_pos=pos,
                ring=not is_glb,
                ssm_state=mamba_mod.MambaState(sh, sc),
                q_offset=pos,
            )
            return out, (new_kv.k, new_kv.v, new_state.h, new_state.conv)

        h, (ks, vs, shs, scs) = jax.lax.scan(
            scan_body, h, (stack, kv_seg.k, kv_seg.v, st_seg.h, st_seg.conv)
        )
        if is_glb:
            new_glb_k.append(ks), new_glb_v.append(vs)
            new_ssm_glb_h.append(shs), new_ssm_glb_c.append(scs)
            glb_idx += n
        else:
            new_swa_k.append(ks), new_swa_v.append(vs)
            new_ssm_swa_h.append(shs), new_ssm_swa_c.append(scs)
            swa_idx += n

    new_cache = HymbaCache(
        KVCache(jnp.concatenate(new_swa_k), jnp.concatenate(new_swa_v)),
        KVCache(jnp.concatenate(new_glb_k), jnp.concatenate(new_glb_v)),
        mamba_mod.MambaState(jnp.concatenate(new_ssm_swa_h), jnp.concatenate(new_ssm_swa_c)),
        mamba_mod.MambaState(jnp.concatenate(new_ssm_glb_h), jnp.concatenate(new_ssm_glb_c)),
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    return logits.astype(jnp.float32), new_cache
