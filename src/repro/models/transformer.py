"""Decoder-only transformer LM: dense, MoE and VLM families.

Production conventions:

* **scan over layers** with stacked parameters (compile time independent of
  depth; the standard MaxText/Megatron-JAX structure);
* configurable **remat** around the scan body (activation checkpointing);
* **bf16 compute / f32 master params**;
* the input embedding follows the paper's technique when
  ``cfg.embedding_mode == 'hier_ps'``: the train/serve step takes a dense
  *working table* (the batch's unique token rows, pulled by the MEM-PS) and
  renumbered ``slots`` instead of owning a [vocab, d] parameter. The output
  head is a dense (fully-referenced) parameter either way, as in the paper.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models import moe as moe_mod
from repro.models.attention import KVCache, attention_block, attention_schema
from repro.models.common import (
    ParamSpec,
    init_params,
    mlp_activation,
    rms_norm,
    with_logical_constraint,
)

COMPUTE_DTYPE = jnp.bfloat16


def mlp_schema(cfg: ArchConfig, layers: int | None = None) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    L = cfg.n_layers if layers is None else layers
    stack = (L,) if L else ()
    lax_ = ("layers",) if L else ()
    fan = len(stack)
    schema = {
        "wi": ParamSpec(stack + (d, ff), lax_ + ("embed", "mlp"), fan_axis=fan),
        "wo": ParamSpec(stack + (ff, d), lax_ + ("mlp", "embed"), fan_axis=fan),
    }
    if cfg.mlp_act == "swiglu":
        schema["wg"] = ParamSpec(stack + (d, ff), lax_ + ("embed", "mlp"), fan_axis=fan)
    return schema


def mlp_block(x: jax.Array, p: dict, cfg: ArchConfig) -> jax.Array:
    h = x @ p["wi"]
    if cfg.mlp_act == "swiglu":
        h = mlp_activation("swiglu", h, x @ p["wg"])
    else:
        h = mlp_activation(cfg.mlp_act, h)
    h = with_logical_constraint(h, "batch", None, "mlp_act")
    out = h @ p["wo"]
    seq = "seq_act" if out.shape[1] > 1 else None  # sequence parallel
    return with_logical_constraint(out, "batch", seq, "embed_act")


def schema(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    layers: dict = {
        "ln1": ParamSpec((cfg.n_layers, d), ("layers", None), init="ones"),
        "ln2": ParamSpec((cfg.n_layers, d), ("layers", None), init="ones"),
        "attn": attention_schema(cfg),
    }
    if cfg.is_moe:
        layers["moe"] = moe_mod.moe_schema(cfg)
    else:
        layers["mlp"] = mlp_schema(cfg)
    out: dict = {
        "layers": layers,
        "final_norm": ParamSpec((d,), (None,), init="ones"),
        "lm_head": ParamSpec((d, cfg.vocab_size), ("embed", "vocab"), fan_axis=0),
    }
    if cfg.embedding_mode == "dense":
        out["embed"] = ParamSpec((cfg.vocab_size, d), ("vocab_rep", "embed_tp"), scale=0.02)
    return out


def init(cfg: ArchConfig, rng: jax.Array):
    return init_params(schema(cfg), rng)


# --------------------------------------------------------------------------
# embedding resolution
# --------------------------------------------------------------------------


def embed_tokens(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,  # [B, S] int32 — token ids (dense) or working slots
    working_table: Optional[jax.Array],  # [n_working, d] (hier_ps mode)
) -> jax.Array:
    from repro.models.common import embed_gather

    if cfg.embedding_mode == "hier_ps":
        assert working_table is not None, "hier_ps mode needs the working table"
        h = embed_gather(working_table, tokens)
    else:
        h = embed_gather(params["embed"], tokens)
    # gather output sharded like the table's d dim (rows replicated, d
    # tensor-parallel): the row gather is fully local per shard and XLA
    # all-gathers the [b, s, d] activation only where full-d is needed
    h = with_logical_constraint(h, "batch", None, "embed_tp")
    return h.astype(COMPUTE_DTYPE)


# --------------------------------------------------------------------------
# forward (train / prefill share the layer stack)
# --------------------------------------------------------------------------


def _cast(p):
    return jax.tree.map(lambda a: a.astype(COMPUTE_DTYPE) if a.dtype == jnp.float32 else a, p)


def _layer_fn(cfg: ArchConfig, attn_impl: str, capacity: int | None):
    def body(h, layer_p, positions):
        a = rms_norm(h, layer_p["ln1"], cfg.norm_eps)
        attn_out, _ = attention_block(
            a, layer_p["attn"], cfg, positions=positions, causal=True, impl=attn_impl
        )
        h = h + attn_out
        m = rms_norm(h, layer_p["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            mlp_out, aux = moe_mod.moe_block(m, layer_p["moe"], cfg, capacity=capacity)
        else:
            mlp_out, aux = mlp_block(m, layer_p["mlp"], cfg), jnp.float32(0)
        return h + mlp_out, aux

    return body


def forward(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,  # [B, S]
    *,
    working_table: Optional[jax.Array] = None,
    image_embeds: Optional[jax.Array] = None,  # [B, n_img, d] (vlm)
    attn_impl: str = "auto",
    remat: bool = True,
    logits_for: str = "all",  # all | last
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits, moe_aux_loss)."""
    h = embed_tokens(cfg, params, tokens, working_table)
    if image_embeds is not None:
        h = jnp.concatenate([image_embeds.astype(COMPUTE_DTYPE), h], axis=1)
    B, S, d = h.shape
    positions = jnp.arange(S)

    body = _layer_fn(cfg, attn_impl, None)

    def scan_body(carry, layer_p):
        out, aux = body(carry, _cast(layer_p), positions)
        return out, aux

    if remat:
        scan_body = jax.checkpoint(
            scan_body, policy=jax.checkpoint_policies.nothing_saveable
        )
    h, auxs = jax.lax.scan(scan_body, h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if logits_for == "last":
        h = h[:, -1:]
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    logits = with_logical_constraint(logits, "batch", None, "vocab_act")
    return logits.astype(jnp.float32), jnp.sum(auxs)


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------


def prefill(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,  # [B, S]
    *,
    working_table: Optional[jax.Array] = None,
    image_embeds: Optional[jax.Array] = None,
    attn_impl: str = "auto",
) -> tuple[jax.Array, KVCache]:
    """Full-sequence forward emitting the KV cache + last-position logits."""
    h = embed_tokens(cfg, params, tokens, working_table)
    if image_embeds is not None:
        h = jnp.concatenate([image_embeds.astype(COMPUTE_DTYPE), h], axis=1)
    B, S, d = h.shape
    positions = jnp.arange(S)

    def scan_body(carry, layer_p):
        lp = _cast(layer_p)
        a = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        attn_out, kv = attention_block(
            a, lp["attn"], cfg, positions=positions, causal=True, impl=attn_impl,
            return_kv=True,
        )
        h2 = carry + attn_out
        m = rms_norm(h2, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            mlp_out, _ = moe_mod.moe_block(m, lp["moe"], cfg)
        else:
            mlp_out = mlp_block(m, lp["mlp"], cfg)
        return h2 + mlp_out, (kv.k.astype(COMPUTE_DTYPE), kv.v.astype(COMPUTE_DTYPE))

    h, (ks, vs) = jax.lax.scan(scan_body, h, params["layers"])
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    return logits.astype(jnp.float32), KVCache(ks, vs)


def decode_step(
    cfg: ArchConfig,
    params,
    token: jax.Array,  # [B, 1] int32
    cache: KVCache,  # stacked [L, B, Hkv, C, Dh]
    pos: jax.Array,  # scalar int32: number of tokens already in cache
    *,
    working_table: Optional[jax.Array] = None,
    attn_impl: str = "naive",
) -> tuple[jax.Array, KVCache]:
    h = embed_tokens(cfg, params, token, working_table)
    B = h.shape[0]
    positions = jnp.full((1,), pos, dtype=jnp.int32)

    def scan_body(carry, xs):
        layer_p, ck, cv = xs
        lp = _cast(layer_p)
        a = rms_norm(carry, lp["ln1"], cfg.norm_eps)
        attn_out, new_cache = attention_block(
            a,
            lp["attn"],
            cfg,
            positions=positions,
            impl=attn_impl,
            cache=KVCache(ck, cv),
            cache_pos=pos,
            q_offset=pos,
        )
        h2 = carry + attn_out
        m = rms_norm(h2, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            mlp_out, _ = moe_mod.moe_block(m, lp["moe"], cfg)
        else:
            mlp_out = mlp_block(m, lp["mlp"], cfg)
        return h2 + mlp_out, (new_cache.k, new_cache.v)

    h, (ks, vs) = jax.lax.scan(scan_body, h, (params["layers"], cache.k, cache.v))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    return logits.astype(jnp.float32), KVCache(ks, vs)
