"""The paper's CTR prediction network (Figure 1) + the LR baseline.

Sparse one/multi-hot features -> embedding rows (through the hierarchical
PS working table) -> per-slot sum pooling -> fully-connected tower ->
sigmoid CTR. The embedding rows are the "sparse parameters" managed by
HBM/MEM/SSD-PS; the tower is the small dense part pinned in HBM.

Inputs are padded sparse rows (per table/slot group):
  slots_ids  int32 [B, nnz]  — working-slot ids (renumbered keys)
  slot_of    int32 [B, nnz]  — which feature slot each nonzero belongs to
  valid      bool  [B, nnz]

Heterogeneous embedding widths (``CTRConfig.slot_groups``): each slot
group is backed by its own named PS table (its own working table at its
own ``emb_dim``); ``forward_grouped`` pools every group at its native
width and concatenates into the tower — the multi-table co-hosting layout
of production ads systems.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.ctr_models import CTRConfig
from repro.kernels import ops as kops
from repro.models.common import ParamSpec, init_params


def tower_schema(cfg: CTRConfig) -> dict:
    dims = (cfg.pooled_dim,) + tuple(cfg.mlp_hidden) + (1,)
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"w{i}"] = ParamSpec((a, b), ("embed", "mlp"), fan_axis=0)
        out[f"b{i}"] = ParamSpec((b,), (None,), init="zeros")
    return out


def init_tower(cfg: CTRConfig, rng: jax.Array):
    return init_params(tower_schema(cfg), rng)


def embed_pool(
    working_table: jax.Array,  # [n_working, emb_dim]
    slot_ids: jax.Array,  # [B, nnz]
    slot_of: jax.Array,  # [B, nnz]
    valid: jax.Array,  # [B, nnz]
    n_slots: int,
) -> jax.Array:
    """Sum-pool embedding rows into per-slot buckets -> [B, n_slots*emb].

    One fused embedding-bag op (``kernels.ops.embedding_bag``): gather and
    per-slot pooling in a single pass, custom VJP through ``scatter_add``.
    The semantic contract is ``kernels.ref.embedding_bag_ref`` (the seed's
    one-hot/einsum math)."""
    B = slot_ids.shape[0]
    pooled = kops.embedding_bag(working_table, slot_ids, slot_of, valid, n_slots)
    return pooled.reshape(B, -1)


def _tower_mlp(tower, h: jax.Array) -> jax.Array:
    """The shared fully-connected tower: pooled features -> logits [B]."""
    n = len([k for k in tower if k.startswith("w")])
    for i in range(n):
        h = h @ tower[f"w{i}"] + tower[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h[:, 0]


def _bce_with_logits(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Numerically-stable mean binary cross-entropy."""
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def forward(
    cfg: CTRConfig,
    tower,
    working_table: jax.Array,
    slot_ids: jax.Array,
    slot_of: jax.Array,
    valid: jax.Array,
) -> jax.Array:
    """Returns CTR logits [B]."""
    return _tower_mlp(tower, embed_pool(working_table, slot_ids, slot_of, valid, cfg.n_slots))


def loss_fn(cfg, tower, working_table, slot_ids, slot_of, valid, labels) -> jax.Array:
    """Mean BCE-with-logits."""
    return _bce_with_logits(
        forward(cfg, tower, working_table, slot_ids, slot_of, valid), labels
    )


# --------------------------------------------------------------------------
# heterogeneous slot groups: one working table per group, own emb width
# --------------------------------------------------------------------------


def forward_grouped(cfg, tower, tables: dict, inputs: dict) -> jax.Array:
    """Multi-table forward: ``tables[g.name]`` is that group's working
    table [n_working_g, emb_g]; ``inputs[g.name]`` holds the group's padded
    sparse triple ``{"slot_ids", "slot_of", "valid"}`` (slot_of indexes
    *within* the group). Pools each group at its native width, concatenates
    across groups, then runs the shared tower. Returns CTR logits [B]."""
    pooled = []
    for g in cfg.groups:
        inp = inputs[g.name]
        pooled.append(
            embed_pool(
                tables[g.name], inp["slot_ids"], inp["slot_of"], inp["valid"], g.n_slots
            )
        )
    return _tower_mlp(tower, jnp.concatenate(pooled, axis=-1))


def loss_fn_grouped(cfg, tower, tables: dict, inputs: dict, labels) -> jax.Array:
    """Mean BCE-with-logits over the grouped forward."""
    return _bce_with_logits(forward_grouped(cfg, tower, tables, inputs), labels)


# --------------------------------------------------------------------------
# LR baseline (Tables 1-2): one weight per sparse feature, same PS machinery
# --------------------------------------------------------------------------


def lr_forward(working_table: jax.Array, slot_ids: jax.Array, valid: jax.Array, bias: jax.Array) -> jax.Array:
    """working_table: [n_working, 1] per-feature weights. Returns logits [B].

    An embedding bag with one slot of width 1: the pooled [B, 1, 1] sum of
    active feature weights IS the linear score. Width-1 rows degenerate to
    scalar DMAs on the Pallas grid, so this always takes the segment-sum
    path."""
    pooled = kops.embedding_bag(
        working_table, slot_ids, jnp.zeros_like(slot_ids), valid, 1, use_pallas=False
    )
    return pooled[:, 0, 0] + bias


def lr_loss_fn(working_table, slot_ids, valid, labels, bias) -> jax.Array:
    return _bce_with_logits(lr_forward(working_table, slot_ids, valid, bias), labels)
