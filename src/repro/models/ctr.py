"""The paper's CTR prediction network (Figure 1) + the LR baseline.

Sparse one/multi-hot features -> embedding rows (through the hierarchical
PS working table) -> per-slot sum pooling -> fully-connected tower ->
sigmoid CTR. The embedding rows are the "sparse parameters" managed by
HBM/MEM/SSD-PS; the tower is the small dense part pinned in HBM.

Inputs are padded sparse rows:
  slots_ids  int32 [B, nnz]  — working-slot ids (renumbered keys)
  slot_of    int32 [B, nnz]  — which feature slot each nonzero belongs to
  valid      bool  [B, nnz]
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.ctr_models import CTRConfig
from repro.models.common import ParamSpec, init_params


def tower_schema(cfg: CTRConfig) -> dict:
    dims = (cfg.n_slots * cfg.emb_dim,) + tuple(cfg.mlp_hidden) + (1,)
    out = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        out[f"w{i}"] = ParamSpec((a, b), ("embed", "mlp"), fan_axis=0)
        out[f"b{i}"] = ParamSpec((b,), (None,), init="zeros")
    return out


def init_tower(cfg: CTRConfig, rng: jax.Array):
    return init_params(tower_schema(cfg), rng)


def embed_pool(
    working_table: jax.Array,  # [n_working, emb_dim]
    slot_ids: jax.Array,  # [B, nnz]
    slot_of: jax.Array,  # [B, nnz]
    valid: jax.Array,  # [B, nnz]
    n_slots: int,
) -> jax.Array:
    """Sum-pool embedding rows into per-slot buckets -> [B, n_slots*emb]."""
    B, nnz = slot_ids.shape
    emb = jnp.take(working_table, slot_ids, axis=0)  # [B, nnz, emb]
    emb = emb * valid[..., None]
    onehot = jax.nn.one_hot(slot_of, n_slots, dtype=emb.dtype)  # [B, nnz, n_slots]
    pooled = jnp.einsum("bne,bns->bse", emb, onehot)  # [B, n_slots, emb]
    return pooled.reshape(B, -1)


def forward(
    cfg: CTRConfig,
    tower,
    working_table: jax.Array,
    slot_ids: jax.Array,
    slot_of: jax.Array,
    valid: jax.Array,
) -> jax.Array:
    """Returns CTR logits [B]."""
    h = embed_pool(working_table, slot_ids, slot_of, valid, cfg.n_slots)
    n = len([k for k in tower if k.startswith("w")])
    for i in range(n):
        h = h @ tower[f"w{i}"] + tower[f"b{i}"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h[:, 0]


def loss_fn(cfg, tower, working_table, slot_ids, slot_of, valid, labels) -> jax.Array:
    """Mean BCE-with-logits."""
    logits = forward(cfg, tower, working_table, slot_ids, slot_of, valid)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


# --------------------------------------------------------------------------
# LR baseline (Tables 1-2): one weight per sparse feature, same PS machinery
# --------------------------------------------------------------------------


def lr_forward(working_table: jax.Array, slot_ids: jax.Array, valid: jax.Array, bias: jax.Array) -> jax.Array:
    """working_table: [n_working, 1] per-feature weights. Returns logits [B]."""
    w = jnp.take(working_table[:, 0], slot_ids)  # [B, nnz]
    return jnp.sum(w * valid, axis=1) + bias


def lr_loss_fn(working_table, slot_ids, valid, labels, bias) -> jax.Array:
    logits = lr_forward(working_table, slot_ids, valid, bias)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
