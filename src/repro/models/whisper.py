"""Whisper backbone: transformer encoder + decoder with cross-attention.

Per the task spec the conv/mel frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings [B, n_frames, d_model] (what the two conv
layers would emit). Everything downstream — sinusoidal encoder positions,
pre-LN blocks with biased LayerNorm, GELU MLPs, learned decoder positions,
causal self-attention + cross-attention — is implemented.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.attention import KVCache, attention_block, attention_schema
from repro.models.common import (
    ParamSpec,
    init_params,
    layer_norm,
    with_logical_constraint,
)

COMPUTE_DTYPE = jnp.bfloat16


class WhisperCache(NamedTuple):
    self_kv: KVCache  # [L, B, H, C, Dh] decoder self-attention
    cross_kv: KVCache  # [L, B, H, n_frames, Dh] precomputed from encoder


def _ln(L: int, d: int) -> dict:
    return {
        "w": ParamSpec((L, d), ("layers", None), init="ones"),
        "b": ParamSpec((L, d), ("layers", None), init="zeros"),
    }


def _mlp(L: int, d: int, ff: int) -> dict:
    return {
        "wi": ParamSpec((L, d, ff), ("layers", "embed", "mlp"), fan_axis=1),
        "bi": ParamSpec((L, ff), ("layers", "mlp"), init="zeros"),
        "wo": ParamSpec((L, ff, d), ("layers", "mlp", "embed"), fan_axis=1),
        "bo": ParamSpec((L, d), ("layers", "embed"), init="zeros"),
    }


def schema(cfg: ArchConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    Le, Ld = cfg.encoder_layers, cfg.n_layers
    out: dict = {
        "encoder": {
            "ln1": _ln(Le, d),
            "attn": attention_schema(cfg, layers=Le),
            "ln2": _ln(Le, d),
            "mlp": _mlp(Le, d, ff),
        },
        "enc_final_ln": {"w": ParamSpec((d,), (None,), init="ones"), "b": ParamSpec((d,), (None,), init="zeros")},
        "decoder": {
            "ln1": _ln(Ld, d),
            "self_attn": attention_schema(cfg, layers=Ld),
            "ln_x": _ln(Ld, d),
            "cross_attn": attention_schema(cfg, layers=Ld),
            "ln2": _ln(Ld, d),
            "mlp": _mlp(Ld, d, ff),
        },
        "dec_final_ln": {"w": ParamSpec((d,), (None,), init="ones"), "b": ParamSpec((d,), (None,), init="zeros")},
        # published whisper uses 448 decoder positions; sized to cover the
        # assigned decode_32k cell (backbone-structural contract, DESIGN.md)
        "dec_pos": ParamSpec((65536, d), (None, "embed"), scale=0.02),
        "lm_head": ParamSpec((d, cfg.vocab_size), ("embed", "vocab")),
    }
    if cfg.embedding_mode == "dense":
        out["embed"] = ParamSpec((cfg.vocab_size, d), ("vocab_rep", "embed_tp"), scale=0.02)
    return out


def init(cfg: ArchConfig, rng: jax.Array):
    return init_params(schema(cfg), rng)


def _sinusoids(length: int, d: int) -> jax.Array:
    half = d // 2
    log_timescale = jnp.log(10000.0) / (half - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(half))
    scaled = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def _cast(p):
    return jax.tree.map(lambda a: a.astype(COMPUTE_DTYPE) if a.dtype == jnp.float32 else a, p)


def encode(cfg: ArchConfig, params, frames: jax.Array, *, attn_impl: str = "auto", remat: bool = True) -> jax.Array:
    """frames: [B, n_frames, d] stub conv output. Returns encoder states."""
    h = frames.astype(COMPUTE_DTYPE) + _sinusoids(frames.shape[1], cfg.d_model).astype(COMPUTE_DTYPE)
    h = with_logical_constraint(h, "batch", None, "embed_act")
    positions = jnp.arange(frames.shape[1])

    def body(carry, lp):
        lp = _cast(lp)
        a = layer_norm(carry, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
        attn_out, _ = attention_block(
            a, lp["attn"], cfg, positions=positions, causal=False, rope=False, impl=attn_impl
        )
        h2 = carry + attn_out
        m = layer_norm(h2, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
        mlp = jax.nn.gelu(m @ lp["mlp"]["wi"] + lp["mlp"]["bi"]) @ lp["mlp"]["wo"] + lp["mlp"]["bo"]
        return h2 + mlp, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["encoder"])
    return layer_norm(h, params["enc_final_ln"]["w"], params["enc_final_ln"]["b"], cfg.norm_eps)


def _decoder_layer(cfg, carry, lp, positions, enc_or_kv, *, self_cache=None, cache_pos=None, attn_impl="auto", return_kv=False):
    a = layer_norm(carry, lp["ln1"]["w"], lp["ln1"]["b"], cfg.norm_eps)
    self_out, new_self = attention_block(
        a, lp["self_attn"], cfg, positions=positions, causal=True, rope=False,
        impl=attn_impl, cache=self_cache, cache_pos=cache_pos,
        q_offset=0 if cache_pos is None else cache_pos, return_kv=return_kv,
    )
    h = carry + self_out
    x = layer_norm(h, lp["ln_x"]["w"], lp["ln_x"]["b"], cfg.norm_eps)
    if isinstance(enc_or_kv, KVCache):  # precomputed cross K/V (decode)
        cross_out, _ = attention_block(
            x, lp["cross_attn"], cfg, positions=positions, causal=False, rope=False,
            impl=attn_impl, cross_kv=(enc_or_kv.k, enc_or_kv.v),
        )
        new_cross = enc_or_kv
    else:  # encoder states: project K/V here (prefill) and emit them
        B, Se, _ = enc_or_kv.shape
        Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
        ck = (enc_or_kv @ lp["cross_attn"]["wk"]).reshape(B, Se, Hkv, hd).transpose(0, 2, 1, 3)
        cv = (enc_or_kv @ lp["cross_attn"]["wv"]).reshape(B, Se, Hkv, hd).transpose(0, 2, 1, 3)
        cross_out, _ = attention_block(
            x, lp["cross_attn"], cfg, positions=positions, causal=False, rope=False,
            impl=attn_impl, cross_kv=(ck, cv),
        )
        new_cross = KVCache(ck, cv)
    h = h + cross_out
    m = layer_norm(h, lp["ln2"]["w"], lp["ln2"]["b"], cfg.norm_eps)
    mlp = jax.nn.gelu(m @ lp["mlp"]["wi"] + lp["mlp"]["bi"]) @ lp["mlp"]["wo"] + lp["mlp"]["bo"]
    return h + mlp, new_self, new_cross


def forward(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,  # [B, S] decoder tokens
    frames: jax.Array,  # [B, n_frames, d] stub frontend embeddings
    *,
    working_table: Optional[jax.Array] = None,
    attn_impl: str = "auto",
    remat: bool = True,
):
    """Training forward: encoder + teacher-forced decoder. Returns logits."""
    enc = encode(cfg, params, frames, attn_impl=attn_impl, remat=remat)
    from repro.models.transformer import embed_tokens

    h = embed_tokens(cfg, params, tokens, working_table)
    S = tokens.shape[1]
    h = h + params["dec_pos"][:S].astype(COMPUTE_DTYPE)
    positions = jnp.arange(S)

    def body(carry, lp):
        out, _, _ = _decoder_layer(cfg, carry, _cast(lp), positions, enc, attn_impl=attn_impl)
        return out, None

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(body, h, params["decoder"])
    h = layer_norm(h, params["dec_final_ln"]["w"], params["dec_final_ln"]["b"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    return logits.astype(jnp.float32), jnp.float32(0)


def prefill(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,
    frames: jax.Array,
    *,
    working_table: Optional[jax.Array] = None,
    attn_impl: str = "auto",
):
    """Encode audio + consume the decoder prompt; emit self+cross caches."""
    enc = encode(cfg, params, frames, attn_impl=attn_impl, remat=False)
    from repro.models.transformer import embed_tokens

    h = embed_tokens(cfg, params, tokens, working_table)
    S = tokens.shape[1]
    h = h + params["dec_pos"][:S].astype(COMPUTE_DTYPE)
    positions = jnp.arange(S)

    def body(carry, lp):
        out, skv, ckv = _decoder_layer(
            cfg, carry, _cast(lp), positions, enc, attn_impl=attn_impl, return_kv=True
        )
        return out, ((skv.k, skv.v), (ckv.k, ckv.v))

    h, ((sk, sv), (ck, cv)) = jax.lax.scan(body, h, params["decoder"])
    h = layer_norm(h[:, -1:], params["dec_final_ln"]["w"], params["dec_final_ln"]["b"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    return logits.astype(jnp.float32), WhisperCache(KVCache(sk, sv), KVCache(ck, cv))


def decode_step(
    cfg: ArchConfig,
    params,
    token: jax.Array,  # [B, 1]
    cache: WhisperCache,
    pos: jax.Array,
    *,
    working_table: Optional[jax.Array] = None,
    attn_impl: str = "naive",
):
    from repro.models.transformer import embed_tokens

    h = embed_tokens(cfg, params, token, working_table)
    h = h + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1).astype(COMPUTE_DTYPE)
    positions = jnp.full((1,), pos, dtype=jnp.int32)

    def body(carry, xs):
        lp, sk, sv, ck, cv = xs
        out, new_self, _ = _decoder_layer(
            cfg, carry, _cast(lp), positions, KVCache(ck, cv),
            self_cache=KVCache(sk, sv), cache_pos=pos, attn_impl=attn_impl,
        )
        return out, (new_self.k, new_self.v)

    h, (nk, nv) = jax.lax.scan(
        body, h, (params["decoder"], cache.self_kv.k, cache.self_kv.v, cache.cross_kv.k, cache.cross_kv.v)
    )
    h = layer_norm(h, params["dec_final_ln"]["w"], params["dec_final_ln"]["b"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    return logits.astype(jnp.float32), WhisperCache(KVCache(nk, nv), cache.cross_kv)
