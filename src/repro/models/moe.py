"""Mixture-of-Experts block: top-k routing with capacity-based dispatch.

Dispatch is scatter-based (GShard/Switch style): every (token, k) assignment
gets a position inside its expert's capacity buffer via a cumulative count;
overflow tokens are dropped (their combine weight is zero). Compute is then
dense batched GEMMs [E, C, d] x [E, d, ff] — MXU-friendly and
expert-parallel: the E dim is sharded over the ``model`` mesh axis, so the
scatter/gather turn into all-to-alls on ICI (XLA SPMD inserts them).

FLOP note: with capacity_factor f, compute is f * (top_k / E) of the dense
equivalent of E experts — the dry-run's HLO-FLOPs vs 6*N_active*D ratio
verifies this (no one-hot-matmul dispatch blow-up).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.common import ParamSpec, mlp_activation, with_logical_constraint


def moe_schema(cfg: ArchConfig, layers: int | None = None) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    L = cfg.n_layers if layers is None else layers
    stack = (L,) if L else ()
    lax_ = ("layers",) if L else ()
    fan = len(stack) + 1
    schema = {
        "router": ParamSpec(stack + (d, E), lax_ + ("embed", None), fan_axis=len(stack)),
        "wi": ParamSpec(stack + (E, d, ff), lax_ + ("experts", "embed", "mlp"), fan_axis=fan),
        "wo": ParamSpec(stack + (E, ff, d), lax_ + ("experts", "mlp", "embed"), fan_axis=fan),
    }
    if cfg.mlp_act == "swiglu":
        schema["wg"] = ParamSpec(stack + (E, d, ff), lax_ + ("experts", "embed", "mlp"), fan_axis=fan)
    return schema


def expert_capacity(cfg: ArchConfig, n_tokens: int, groups: int = 1) -> int:
    cap = int(n_tokens / groups * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, (cap + 7) // 8 * 8)  # pad to vreg-friendly multiple


# §Perf toggle: dispatch groups for local-capacity routing. Positions are
# computed within each of N token groups (group = data shard) and the
# capacity buffer gets a [groups] dim sharded over the data axes — removing
# the data-axis replication (and its gradient all-reduce) of the buffer and
# shrinking the cumsum from [T*k, E] to per-group. 0 = single global group
# (paper-faithful GShard-style global capacity).
DISPATCH_GROUPS = 32
DISPATCH_DTYPE = jnp.bfloat16


def moe_block(
    x: jax.Array,  # [B, S, d]
    p: dict,  # one layer's {router, wi[, wg], wo}
    cfg: ArchConfig,
    *,
    capacity: int | None = None,
    groups: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (output [B,S,d], aux_loss scalar: load-balancing loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = groups if groups is not None else (DISPATCH_GROUPS or 1)
    while T % G:
        G //= 2
    G = max(1, G)
    xf = x.reshape(T, d)
    C = capacity if capacity is not None else expert_capacity(cfg, T, G)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)  # [T, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)  # renormalize

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    density = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)  # pscheck: ok PS501 router load stats over E experts, not an embedding gather
    router_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * router_prob)

    # position of each (token, k) inside its expert's per-group capacity
    # slice: ranks reset at group boundaries so dispatch is group-local
    flat_e = top_i.reshape(-1)  # [T*k], token-major
    Tg = T * k // G
    onehot = jax.nn.one_hot(flat_e.reshape(G, Tg), E, dtype=jnp.int32)  # [G,Tg,E]  # pscheck: ok PS501 capacity-rank mask over E experts, not an embedding gather
    pos = jnp.cumsum(onehot, axis=1) - 1  # running count per (group, expert)
    pos_of = jnp.take_along_axis(pos, flat_e.reshape(G, Tg, 1), axis=2)[..., 0]
    keep = (pos_of < C).reshape(-1)
    gidx = jnp.repeat(jnp.arange(G), Tg)
    slot = jnp.where(
        keep, flat_e * (G * C) + gidx * C + pos_of.reshape(-1), E * G * C
    )

    xe = jnp.repeat(xf, k, axis=0).astype(DISPATCH_DTYPE)  # [T*k, d]
    buf = jnp.zeros((E * G * C + 1, d), DISPATCH_DTYPE).at[slot].set(xe)[: E * G * C]
    buf = buf.reshape(E, G * C, d).astype(xf.dtype)
    buf = with_logical_constraint(buf, "experts_act", "batch", None)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
        h = mlp_activation("swiglu", h, g)
    else:
        h = mlp_activation(cfg.mlp_act, h)
    h = with_logical_constraint(h, "experts_act", "batch", "mlp_act")
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"])  # [E, G*C, d]
    y = with_logical_constraint(y, "experts_act", "batch", None)

    # gather back to (token, k) order and combine with routing weights
    y_flat = y.astype(DISPATCH_DTYPE).reshape(E * G * C, d)
    y_tok = jnp.where(
        keep[:, None],
        jnp.take(y_flat, jnp.minimum(slot, E * G * C - 1), axis=0),  # pscheck: ok PS501 activation un-dispatch (expert buffer -> token order), not a parameter-table gather
        0.0,
    )
    y_tok = y_tok.reshape(T, k, d)
    out = jnp.einsum("tkd,tk->td", y_tok.astype(jnp.float32), top_p).astype(x.dtype)
    return out.reshape(B, S, d), aux
