"""Shared model substrate: schema-based params, norms, RoPE, attention, MLPs.

Parameters are declared as a *schema* (nested dict of ParamSpec). One schema
drives both initialization (``init_params``) and sharding
(``logical_specs`` -> launch/sharding.py maps logical axis names to mesh
axes), so init shapes and partition specs can never drift apart.

Logical axis vocabulary (mapped to mesh axes by launch/sharding.py):
  layers   — stacked scan dim (never sharded)
  embed    — d_model dim (FSDP-sharded over the data axes)
  vocab    — vocabulary dim (tensor-parallel)
  heads    — attention query heads x head_dim, flattened (tensor-parallel)
  kv_heads — kv heads x head_dim, flattened (tensor-parallel if divisible)
  mlp      — feed-forward hidden (tensor-parallel)
  experts  — MoE expert dim (expert-parallel)
  ssm      — SSM inner channels (tensor-parallel)
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any  # nested dict of arrays


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    logical: tuple[Optional[str], ...]
    init: str = "normal"  # normal | zeros | ones
    scale: Optional[float] = None  # stddev; default 1/sqrt(shape[fan_axis])
    fan_axis: int = 0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def init_params(schema: dict, rng: jax.Array) -> Params:
    """Materialize a schema into arrays; per-leaf rng folded in by path."""

    def go(node, path):
        if isinstance(node, ParamSpec):
            if node.init == "zeros":
                return jnp.zeros(node.shape, node.dtype)
            if node.init == "ones":
                return jnp.ones(node.shape, node.dtype)
            key = rng
            for p in path:
                key = jax.random.fold_in(key, hash(p) & 0x7FFFFFFF)
            fan = node.shape[node.fan_axis] if node.shape else 1
            scale = node.scale if node.scale is not None else 1.0 / math.sqrt(max(1, fan))
            return (jax.random.normal(key, node.shape, jnp.float32) * scale).astype(node.dtype)
        return {k: go(v, path + (k,)) for k, v in node.items()}

    return go(schema, ())


def abstract_params(schema: dict) -> Params:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""

    def go(node):
        if isinstance(node, ParamSpec):
            return jax.ShapeDtypeStruct(node.shape, node.dtype)
        return {k: go(v) for k, v in node.items()}

    return go(schema)


def logical_specs(schema: dict) -> Any:
    """Pytree of logical-axis tuples matching the schema structure."""

    def go(node):
        if isinstance(node, ParamSpec):
            return node.logical
        return {k: go(v) for k, v in node.items()}

    return go(schema)


def param_count(schema: dict) -> int:
    total = 0

    def go(node):
        nonlocal total
        if isinstance(node, ParamSpec):
            total += math.prod(node.shape) if node.shape else 1
        else:
            for v in node.values():
                go(v)

    go(schema)
    return total


# --------------------------------------------------------------------------
# normalization / activations / RoPE
# --------------------------------------------------------------------------


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight).astype(x.dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * weight + bias).astype(x.dtype)


def mlp_activation(kind: str, h: jax.Array, gate: jax.Array | None = None) -> jax.Array:
    if kind == "swiglu":
        assert gate is not None
        return jax.nn.silu(gate) * h
    if kind == "squared_relu":
        r = jax.nn.relu(h)
        return r * r
    if kind == "gelu":
        return jax.nn.gelu(h)
    raise ValueError(kind)


def rope_tables(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotate-half RoPE. positions: [...,] int."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., half]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, H, S, Dh]; cos/sin: [S, Dh/2] (or broadcastable)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[None, None].astype(jnp.float32)
    s = sin[None, None].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# sharding-annotation hooks (populated by launch/sharding.py at trace time)
# --------------------------------------------------------------------------

_LOGICAL_CONSTRAINT_FN = None
_EMBED_GATHER_FN = None


def set_logical_constraint_fn(fn) -> None:
    """Install a fn(x, logical_axes) -> x applying sharding constraints."""
    global _LOGICAL_CONSTRAINT_FN
    _LOGICAL_CONSTRAINT_FN = fn


def with_logical_constraint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    if _LOGICAL_CONSTRAINT_FN is None:
        return x
    return _LOGICAL_CONSTRAINT_FN(x, logical)


_PARAM_CONSTRAINT_FN = None


def set_param_constraint_fn(fn) -> None:
    """Install fn(param_like_pytree) -> pytree applying the parameter
    shardings to a matching pytree (gradients). Forcing per-microbatch
    gradients onto the FSDP param sharding makes XLA reduce-scatter each
    contribution instead of all-reducing full gradients inside the
    accumulation loop (§Perf: the dominant collective win on large dense
    models)."""
    global _PARAM_CONSTRAINT_FN
    _PARAM_CONSTRAINT_FN = fn


def constrain_like_params(grads):
    if _PARAM_CONSTRAINT_FN is None:
        return grads
    return _PARAM_CONSTRAINT_FN(grads)


def set_embed_gather_fn(fn) -> None:
    """Install the distributed HBM-PS row gather (shard_map local take).

    The launcher installs a mesh-aware version: table d-dim is tensor-
    parallel, rows replicated, so each shard takes its d-slice locally with
    ZERO collectives — the explicit form of the paper's hash-table ``get``
    (XLA's generic gather partitioner mis-handles this pattern inside
    scans; see launch/sharding.py).
    """
    global _EMBED_GATHER_FN
    _EMBED_GATHER_FN = fn


def embed_gather(table: jax.Array, ids: jax.Array) -> jax.Array:
    if _EMBED_GATHER_FN is None:
        # single-host default: the kernel-layer lookup (Pallas row-gather on
        # TPU, jnp.take-equivalent reference elsewhere — bitwise identical)
        from repro.kernels import ops as kops

        flat = kops.embedding_lookup(table, ids.reshape(-1))
        return flat.reshape(*ids.shape, table.shape[-1])
    return _EMBED_GATHER_FN(table, ids)
