"""xLSTM LM: mLSTM blocks with one sLSTM block every ``slstm_every`` layers.

mLSTM (matrix-memory, exponential gating) is parallelizable; we implement

  * an exact **sequential** recurrence (oracle + decode step), and
  * a **chunkwise-parallel** form (TPU-native: intra-chunk quadratic on the
    MXU + O(1) inter-chunk state, the FlashLinearAttention/TFLA structure)
    used for train/prefill — this is the hardware adaptation of record for
    this arch (see DESIGN.md).

sLSTM (scalar memory, hidden-to-hidden recurrence) is inherently sequential;
it runs as a lax.scan over time with block-diagonal per-head recurrent
matrices, exactly as published.

Layer layout: supersteps of (slstm_every - 1) scanned mLSTM blocks followed
by one unrolled sLSTM block; params are stacked [n_super, m_per, ...] so the
whole depth compiles as two nested scans.

Stabilized mLSTM recurrence (per head; q,k in R^dk, v in R^dv):

  m_t = max(lf_t + m_{t-1}, li_t)
  C_t = exp(lf_t + m_{t-1} - m_t) C_{t-1} + exp(li_t - m_t) k_t v_t^T
  n_t = exp(lf_t + m_{t-1} - m_t) n_{t-1} + exp(li_t - m_t) k_t
  h_t = (q_t C_t) / (max(|q_t . n_t|, exp(-m_t)) + eps)

with lf = logsigmoid(f-preact), li = i-preact, q scaled by dk^-1/2.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.models.common import (
    ParamSpec,
    init_params,
    rms_norm,
    with_logical_constraint,
)

COMPUTE_DTYPE = jnp.bfloat16
EPS = 1e-6


class MLSTMState(NamedTuple):
    C: jax.Array  # [B, H, dk, dv]
    n: jax.Array  # [B, H, dk]
    m: jax.Array  # [B, H]
    conv: jax.Array  # [B, K-1, dp]


class SLSTMState(NamedTuple):
    c: jax.Array  # [B, H, dh]
    n: jax.Array  # [B, H, dh]
    m: jax.Array  # [B, H, dh]
    h: jax.Array  # [B, H, dh]


class XLSTMCache(NamedTuple):
    mlstm: MLSTMState  # stacked [n_super, m_per, ...]
    slstm: SLSTMState  # stacked [n_super, ...]


# --------------------------------------------------------------------------
# schemas
# --------------------------------------------------------------------------

CONV_K = 4


def _mlstm_schema(cfg: ArchConfig, stack: tuple[int, ...]) -> dict:
    d = cfg.d_model
    dp = int(cfg.proj_factor * d)
    H = cfg.n_heads
    dh = dp // H
    lax_ = tuple("layers" for _ in stack)
    f = len(stack)
    return {
        "ln": ParamSpec(stack + (d,), lax_ + (None,), init="ones"),
        "w_up": ParamSpec(stack + (d, 2 * dp), lax_ + ("embed", "ssm"), fan_axis=f),
        "conv_w": ParamSpec(stack + (CONV_K, dp), lax_ + (None, "ssm"), scale=0.5),
        "conv_b": ParamSpec(stack + (dp,), lax_ + ("ssm",), init="zeros"),
        # block-diagonal per-head projections; output dim tensor-parallel
        # (replicating these cost 2 GiB/chip at 48 layers — §Perf)
        "wq": ParamSpec(stack + (H, dh, dh), lax_ + (None, None, "ssm"), fan_axis=f + 1),
        "wk": ParamSpec(stack + (H, dh, dh), lax_ + (None, None, "ssm"), fan_axis=f + 1),
        "wv": ParamSpec(stack + (H, dh, dh), lax_ + (None, None, "ssm"), fan_axis=f + 1),
        "w_i": ParamSpec(stack + (dp, H), lax_ + ("ssm", None), fan_axis=f),
        "b_i": ParamSpec(stack + (H,), lax_ + (None,), init="zeros"),
        "w_f": ParamSpec(stack + (dp, H), lax_ + ("ssm", None), fan_axis=f),
        "b_f": ParamSpec(stack + (H,), lax_ + (None,), init="ones"),
        "out_norm": ParamSpec(stack + (dp,), lax_ + ("ssm",), init="ones"),
        "w_down": ParamSpec(stack + (dp, d), lax_ + ("ssm", "embed"), fan_axis=f),
    }


def _slstm_schema(cfg: ArchConfig, stack: tuple[int, ...]) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    dff = int(4 * d / 3 + 127) // 128 * 128  # PF=4/3, padded to lanes
    lax_ = tuple("layers" for _ in stack)
    f = len(stack)
    return {
        "ln": ParamSpec(stack + (d,), lax_ + (None,), init="ones"),
        "w_zifo": ParamSpec(stack + (d, 4 * d), lax_ + ("embed", "ssm"), fan_axis=f),
        "r_zifo": ParamSpec(stack + (H, dh, 4 * dh), lax_ + (None, None, None), fan_axis=f + 1),
        "b_zifo": ParamSpec(stack + (4 * d,), lax_ + ("ssm",), init="zeros"),
        "out_norm": ParamSpec(stack + (d,), lax_ + (None,), init="ones"),
        "ln_ffn": ParamSpec(stack + (d,), lax_ + (None,), init="ones"),
        "ffn_up": ParamSpec(stack + (d, 2 * dff), lax_ + ("embed", "mlp"), fan_axis=f),
        "ffn_down": ParamSpec(stack + (dff, d), lax_ + ("mlp", "embed"), fan_axis=f),
    }


def layout(cfg: ArchConfig) -> tuple[int, int]:
    """(n_super, mlstm_per_super). slstm_every == 0 -> pure mLSTM."""
    if cfg.slstm_every == 0:
        return 1, cfg.n_layers
    assert cfg.n_layers % cfg.slstm_every == 0
    return cfg.n_layers // cfg.slstm_every, cfg.slstm_every - 1


def schema(cfg: ArchConfig) -> dict:
    n_super, m_per = layout(cfg)
    has_slstm = cfg.slstm_every > 0
    out: dict = {
        "mlstm": _mlstm_schema(cfg, (n_super, m_per)),
        "final_norm": ParamSpec((cfg.d_model,), (None,), init="ones"),
        "lm_head": ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab")),
    }
    if has_slstm:
        out["slstm"] = _slstm_schema(cfg, (n_super,))
    if cfg.embedding_mode == "dense":
        out["embed"] = ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab_rep", "embed_tp"), scale=0.02)
    return out


def init(cfg: ArchConfig, rng: jax.Array):
    return init_params(schema(cfg), rng)


# --------------------------------------------------------------------------
# mLSTM cell — sequential (oracle/decode) and chunkwise (train/prefill)
# --------------------------------------------------------------------------


def mlstm_sequential(q, k, v, li, lf, state: tuple | None = None):
    """q,k,v: [B,H,S,dh]; li,lf: [B,H,S]. Returns (h [B,H,S,dh], state)."""
    B, H, S, dh = q.shape
    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state
    qf = q.astype(jnp.float32) * (dh**-0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)

    def step(carry, inp):
        C, n, m = carry
        q_t, k_t, v_t, li_t, lf_t = inp
        m_new = jnp.maximum(lf_t + m, li_t)
        decay = jnp.exp(lf_t + m - m_new)[..., None]
        inject = jnp.exp(li_t - m_new)[..., None]
        C = decay[..., None] * C + inject[..., None] * (k_t[..., :, None] * v_t[..., None, :])
        n = decay * n + inject * k_t
        num = jnp.einsum("bhk,bhkv->bhv", q_t, C)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", q_t, n))
        den = jnp.maximum(den, jnp.exp(-m_new)) + EPS
        return (C, n, m_new), num / den[..., None]

    xs = tuple(a.transpose(2, 0, 1, 3) for a in (qf, kf, vf)) + tuple(
        a.transpose(2, 0, 1) for a in (li, lf)
    )
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3).astype(q.dtype), (C, n, m)


def mlstm_chunkwise(q, k, v, li, lf, state: tuple | None = None, *, chunk: int = 64):
    """Chunkwise-parallel mLSTM, numerically identical to sequential."""
    B, H, S, dh = q.shape
    Q = min(chunk, S)
    assert S % Q == 0, f"S={S} must tile by chunk={Q}"
    nC = S // Q
    if state is None:
        C0 = jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = jnp.zeros((B, H, dh), jnp.float32)
        m0 = jnp.full((B, H), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state

    qf = (q.astype(jnp.float32) * (dh**-0.5)).reshape(B, H, nC, Q, dh).transpose(2, 0, 1, 3, 4)
    kf = k.astype(jnp.float32).reshape(B, H, nC, Q, dh).transpose(2, 0, 1, 3, 4)
    vf = v.astype(jnp.float32).reshape(B, H, nC, Q, dh).transpose(2, 0, 1, 3, 4)
    lic = li.reshape(B, H, nC, Q).transpose(2, 0, 1, 3)
    lfc = lf.reshape(B, H, nC, Q).transpose(2, 0, 1, 3)

    def chunk_step(carry, inp):
        C, n, m = inp_C, inp_n, inp_m = carry
        q_c, k_c, v_c, li_c, lf_c = inp  # [B,H,Q,dh] / [B,H,Q]
        a = jnp.cumsum(lf_c, axis=-1)  # decay chunk-start..j (inclusive)
        g = a[..., -1]  # total chunk decay
        # per-position stabilizer: max(inter, intra)
        intra_sc = a[..., :, None] - a[..., None, :] + li_c[..., None, :]  # [B,H,Q,Q] (j,t)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        intra_sc = jnp.where(tri, intra_sc, -jnp.inf)
        m_intra = intra_sc.max(axis=-1)  # [B,H,Q]
        m_inter = a + m[..., None]  # [B,H,Q]
        m_j = jnp.maximum(m_inter, m_intra)
        # inter-chunk contribution
        inter_w = jnp.exp(m_inter - m_j)  # [B,H,Q]
        h_inter = jnp.einsum("bhqk,bhkv->bhqv", q_c, C) * inter_w[..., None]
        n_inter = jnp.einsum("bhqk,bhk->bhq", q_c, n) * inter_w
        # intra-chunk (masked quadratic)
        w = jnp.exp(intra_sc - m_j[..., None])  # [B,H,Q,Q]
        s = jnp.einsum("bhqk,bhtk->bhqt", q_c, k_c) * w
        h_intra = jnp.einsum("bhqt,bhtv->bhqv", s, v_c)
        n_intra = s.sum(axis=-1)
        den = jnp.abs(n_inter + n_intra)
        den = jnp.maximum(den, jnp.exp(-m_j)) + EPS
        h_c = (h_inter + h_intra) / den[..., None]
        # state update
        m_next = jnp.maximum(g + m, (g[..., None] - a + li_c).max(axis=-1))
        carry_decay = jnp.exp(g + m - m_next)  # [B,H]
        kw = jnp.exp(g[..., None] - a + li_c - m_next[..., None])  # [B,H,Q]
        C_next = carry_decay[..., None, None] * C + jnp.einsum(
            "bhtk,bhtv->bhkv", k_c * kw[..., None], v_c
        )
        n_next = carry_decay[..., None] * n + (k_c * kw[..., None]).sum(axis=2)
        return (C_next, n_next, m_next), h_c

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qf, kf, vf, lic, lfc))
    h = hs.transpose(1, 2, 0, 3, 4).reshape(B, H, S, dh)
    return h.astype(q.dtype), (C, n, m)


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------


def _conv_causal(x, w, b, history=None):
    K = w.shape[0]
    pad = (
        jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
        if history is None
        else history.astype(x.dtype)
    )
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i] for i in range(K)) + b
    return out, xp[:, -(K - 1) :]


def _heads(x, H):
    B, S, dp = x.shape
    return x.reshape(B, S, H, dp // H).transpose(0, 2, 1, 3)  # [B,H,S,dh]


def mlstm_block(cfg: ArchConfig, p: dict, x: jax.Array, *, state: MLSTMState | None = None, chunk: int = 64):
    """x: [B,S,d]. Returns (out, new_state)."""
    B, S, d = x.shape
    H = cfg.n_heads
    dp = p["w_down"].shape[0]
    res = x
    x = rms_norm(x, p["ln"], cfg.norm_eps)
    u = x @ p["w_up"]
    z, gate = u[..., :dp], u[..., dp:]
    c, conv_hist = _conv_causal(z, p["conv_w"], p["conv_b"], None if state is None else state.conv)
    c = jax.nn.silu(c)
    q = jnp.einsum("bhsd,hde->bhse", _heads(c, H), p["wq"])
    k = jnp.einsum("bhsd,hde->bhse", _heads(c, H), p["wk"])
    v = jnp.einsum("bhsd,hde->bhse", _heads(z, H), p["wv"])
    li = (c @ p["w_i"] + p["b_i"]).transpose(0, 2, 1).astype(jnp.float32)  # [B,H,S]
    lf = jax.nn.log_sigmoid((c @ p["w_f"] + p["b_f"]).transpose(0, 2, 1).astype(jnp.float32))
    cell_state = None if state is None else (state.C, state.n, state.m)
    if S == 1 and state is not None:
        h, new_cell = mlstm_sequential(q, k, v, li, lf, cell_state)
    else:
        h, new_cell = mlstm_chunkwise(q, k, v, li, lf, cell_state, chunk=chunk)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, dp)
    # per-head group norm
    hg = h.reshape(B, S, H, dp // H)
    hg = hg * jax.lax.rsqrt(jnp.mean(hg.astype(jnp.float32) ** 2, axis=-1, keepdims=True) + cfg.norm_eps)
    h = hg.reshape(B, S, dp).astype(x.dtype) * p["out_norm"]
    out = (h * jax.nn.silu(gate)) @ p["w_down"]
    out = with_logical_constraint(out, "batch", None, "embed_act")
    new_state = MLSTMState(*new_cell, conv_hist)
    return res + out, new_state


def slstm_block(cfg: ArchConfig, p: dict, x: jax.Array, *, state: SLSTMState | None = None):
    """Sequential sLSTM block + PF-4/3 gated FFN. x: [B,S,d]."""
    B, S, d = x.shape
    H = cfg.n_heads
    dh = d // H
    res = x
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    wx = xn @ p["w_zifo"] + p["b_zifo"]  # [B,S,4d]
    wx = wx.reshape(B, S, 4, H, dh).astype(jnp.float32)

    if state is None:
        zeros = jnp.zeros((B, H, dh), jnp.float32)
        state = SLSTMState(zeros, zeros + EPS, zeros - 10.0, zeros)

    r = p["r_zifo"].astype(jnp.float32)  # [H, dh, 4dh]

    def step(carry, wx_t):
        c, n, m, h = carry
        rec = jnp.einsum("bhd,hde->bhe", h, r).reshape(B, H, 4, dh)
        zt = jnp.tanh(wx_t[:, :, 0] + rec[:, :, 0])
        it = wx_t[:, :, 1] + rec[:, :, 1]
        ft = wx_t[:, :, 2] + rec[:, :, 2]
        ot = jax.nn.sigmoid(wx_t[:, :, 3] + rec[:, :, 3])
        lf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(lf + m, it)
        c_new = jnp.exp(lf + m - m_new) * c + jnp.exp(it - m_new) * zt
        n_new = jnp.exp(lf + m - m_new) * n + jnp.exp(it - m_new)
        h_new = ot * c_new / (n_new + EPS)
        return SLSTMState(c_new, n_new, m_new, h_new), h_new

    new_state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 3, 2, 4))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    h = rms_norm(h, p["out_norm"], cfg.norm_eps)
    x = res + h
    # gated FFN
    m_in = rms_norm(x, p["ln_ffn"], cfg.norm_eps)
    u = m_in @ p["ffn_up"]
    dff = p["ffn_down"].shape[0]
    h2 = jax.nn.gelu(u[..., :dff]) * u[..., dff:]
    return x + h2 @ p["ffn_down"], new_state


# --------------------------------------------------------------------------
# full model
# --------------------------------------------------------------------------


def _cast(p):
    return jax.tree.map(lambda a: a.astype(COMPUTE_DTYPE) if a.dtype == jnp.float32 else a, p)


def forward(
    cfg: ArchConfig,
    params,
    tokens: jax.Array,
    *,
    working_table: Optional[jax.Array] = None,
    remat: bool = True,
    chunk: int = 64,
    attn_impl: str = "auto",  # attention-free arch: accepted for API parity
):
    from repro.models.transformer import embed_tokens

    h = embed_tokens(cfg, params, tokens, working_table)
    n_super, m_per = layout(cfg)
    has_slstm = cfg.slstm_every > 0

    def super_body(carry, xs):
        mp = xs["mlstm"]

        def m_body(c2, lp):
            out, _ = mlstm_block(cfg, _cast(lp), c2, chunk=chunk)
            return out, None

        body = jax.checkpoint(m_body, policy=jax.checkpoint_policies.nothing_saveable) if remat else m_body
        carry, _ = jax.lax.scan(body, carry, mp)
        if has_slstm:
            carry, _ = slstm_block(cfg, _cast(xs["slstm"]), carry)
        return carry, None

    xs = {"mlstm": params["mlstm"]}
    if has_slstm:
        xs["slstm"] = params["slstm"]
    h, _ = jax.lax.scan(super_body, h, xs)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    return logits.astype(jnp.float32), jnp.float32(0)


def init_cache(cfg: ArchConfig, batch: int) -> XLSTMCache:
    n_super, m_per = layout(cfg)
    d = cfg.d_model
    dp = int(cfg.proj_factor * d)
    H = cfg.n_heads
    dh_m = dp // H
    dh_s = d // H
    m = MLSTMState(
        jnp.zeros((n_super, m_per, batch, H, dh_m, dh_m), jnp.float32),
        jnp.zeros((n_super, m_per, batch, H, dh_m), jnp.float32),
        jnp.full((n_super, m_per, batch, H), -jnp.inf, jnp.float32),
        jnp.zeros((n_super, m_per, batch, CONV_K - 1, dp), jnp.float32),
    )
    s = SLSTMState(
        jnp.zeros((n_super, batch, H, dh_s), jnp.float32),
        jnp.zeros((n_super, batch, H, dh_s), jnp.float32) + EPS,
        jnp.zeros((n_super, batch, H, dh_s), jnp.float32) - 10.0,
        jnp.zeros((n_super, batch, H, dh_s), jnp.float32),
    )
    return XLSTMCache(m, s)


def decode_step(
    cfg: ArchConfig,
    params,
    token: jax.Array,  # [B, 1]
    cache: XLSTMCache,
    pos=None,  # unused (stateful recurrence); kept for API uniformity
    *,
    working_table: Optional[jax.Array] = None,
):
    from repro.models.transformer import embed_tokens

    h = embed_tokens(cfg, params, token, working_table)
    has_slstm = cfg.slstm_every > 0

    def super_body(carry, xs):
        h2 = carry
        mp, mstate = xs["mlstm"], xs["mstate"]

        def m_body(c2, inp):
            lp, st = inp
            out, new_st = mlstm_block(
                cfg, _cast(lp), c2, state=MLSTMState(st[0], st[1], st[2], st[3])
            )
            return out, new_st

        h2, new_m = jax.lax.scan(m_body, h2, (mp, tuple(mstate)))
        new_s = None
        if has_slstm:
            h2, new_s = slstm_block(
                cfg, _cast(xs["slstm"]), h2, state=SLSTMState(*xs["sstate"])
            )
        return h2, (new_m, new_s)

    xs = {"mlstm": params["mlstm"], "mstate": tuple(cache.mlstm)}
    if has_slstm:
        xs["slstm"] = params["slstm"]
        xs["sstate"] = tuple(cache.slstm)
    h, (new_m, new_s) = jax.lax.scan(super_body, h, xs)
    new_cache = XLSTMCache(
        MLSTMState(*new_m), SLSTMState(*new_s) if has_slstm else cache.slstm
    )
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"].astype(COMPUTE_DTYPE)
    return logits.astype(jnp.float32), new_cache
