"""GQA attention block: projections + RoPE + cache plumbing.

One module serves all four execution modes:

  train    — full-sequence causal attention, no cache
  prefill  — full-sequence causal attention, emits a KV cache
  decode   — one token vs a cache (kv_len = traced position + 1)
  ring     — one token vs a sliding-window ring buffer (sub-quadratic decode)
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.kernels import ops as kops
from repro.models.common import ParamSpec, apply_rope, rope_tables, with_logical_constraint


class KVCache(NamedTuple):
    k: jax.Array  # [B, Hkv, C, Dh]
    v: jax.Array  # [B, Hkv, C, Dh]


def attention_schema(cfg: ArchConfig, layers: int | None = None, rope: bool = True) -> dict:
    """Schema for stacked attention projections (leading ``layers`` dim)."""
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    L = cfg.n_layers if layers is None else layers
    stack = (L,) if L else ()
    lax_ = ("layers",) if L else ()
    return {
        "wq": ParamSpec(stack + (d, H * hd), lax_ + ("embed", "heads"), fan_axis=len(stack)),
        "wk": ParamSpec(stack + (d, Hkv * hd), lax_ + ("embed", "kv_heads"), fan_axis=len(stack)),
        "wv": ParamSpec(stack + (d, Hkv * hd), lax_ + ("embed", "kv_heads"), fan_axis=len(stack)),
        "wo": ParamSpec(stack + (H * hd, d), lax_ + ("heads", "embed"), fan_axis=len(stack)),
    }


def attention_block(
    x: jax.Array,  # [B, S, d]
    p: dict,  # one layer's {wq, wk, wv, wo}
    cfg: ArchConfig,
    *,
    positions: jax.Array,  # [S] absolute positions of x
    causal: bool = True,
    window: int = 0,
    rope: bool = True,
    impl: str = "auto",
    cache: Optional[KVCache] = None,
    cache_pos: Optional[jax.Array] = None,  # traced write position (decode)
    ring: bool = False,
    q_offset: int | jax.Array = 0,
    kv_len: int | jax.Array | None = None,
    cross_kv: Optional[tuple[jax.Array, jax.Array]] = None,
    return_kv: bool = False,  # cache-less prefill: emit this segment's K/V
) -> tuple[jax.Array, Optional[KVCache]]:
    B, S, d = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim

    q = (x @ p["wq"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    q = with_logical_constraint(q, "batch", "heads_sep", None, None)
    if cross_kv is None:
        k = (x @ p["wk"]).reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
        v = (x @ p["wv"]).reshape(B, S, Hkv, hd).transpose(0, 2, 1, 3)
    else:  # encoder-decoder cross attention: kv precomputed from encoder
        k, v = cross_kv

    if rope and cross_kv is None:
        cos, sin = rope_tables(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is None and return_kv:
        new_cache = KVCache(k, v)
    if cache is not None:
        if ring:  # sliding-window ring buffer: slot = pos % window
            W = cache.k.shape[2]
            slot = (cache_pos % W).astype(jnp.int32)
            ck = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, slot, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, slot, 0))
            new_cache = KVCache(ck, cv)
            k, v = ck, cv
            causal = False  # every filled slot is past context
            kv_len = jnp.minimum(cache_pos + 1, W)
            window = 0  # the ring itself enforces the window
        else:
            ck = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, cache_pos, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, cache_pos, 0))
            new_cache = KVCache(ck, cv)
            k, v = ck, cv
            causal = False
            kv_len = cache_pos + S

    out = kops.attention(
        q, k, v, causal=causal, window=window, q_offset=q_offset, kv_len=kv_len, impl=impl
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * hd)
    out = out @ p["wo"]
    seq = "seq_act" if S > 1 else None  # sequence parallel in train/prefill
    return with_logical_constraint(out, "batch", seq, "embed_act"), new_cache


def init_kv_cache(cfg: ArchConfig, batch: int, length: int, n_layers: int, dtype=jnp.bfloat16):
    Hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, batch, Hkv, length, hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
