"""Embedding-based ad retrieval over versioned snapshots (DESIGN.md §12).

Public surface:

* :class:`RetrievalIndex` — one (table, snapshot version)'s embedding rows
  as a device-resident, lane-aligned corpus.
* :class:`RetrievalEngine` — versioned ``search(queries, k)`` via the
  blocked Pallas MIPS kernel + feature-interaction ``rerank``.
* :class:`RetrievalResult` — one search's (scores, indices, ad_keys).
"""

from repro.retrieval.engine import (
    RETRIEVAL_COUNTER_NAMES,
    RetrievalEngine,
    RetrievalResult,
)
from repro.retrieval.index import RetrievalIndex

__all__ = [
    "RETRIEVAL_COUNTER_NAMES",
    "RetrievalEngine",
    "RetrievalIndex",
    "RetrievalResult",
]
