"""RetrievalIndex: one snapshot version's embedding table, device-resident.

The index side of the retrieval subsystem (DESIGN.md §12): materialize a
named table's live rows out of a published :class:`ServingVersion` into a
lane-aligned corpus the blocked MIPS kernel can stream.

Build protocol:

1. **Manifest scan** — iterate every node view's ``iter_live()`` (the same
   corruption-safe primitive reshard/checkpoint use) and keep rows whose
   high-bit key tag matches the table; only the schema's ``emb`` field
   (the row prefix) enters the corpus — optimizer slots never ship to the
   device.
2. **Deterministic corpus order** — rows sort by raw (un-namespaced) ad
   key ascending, so corpus index ``i`` maps to one key independent of
   node count, file layout, or scan order. The kernel's tie-breaking
   (minimum corpus index) therefore has a stable meaning across rebuilds.
3. **Lane alignment** — the corpus pads to ``block_n`` rows x 128-lane
   feature columns and moves to device once; ``n_rows`` marks the live
   prefix and the kernel masks everything past it.

The index pins the :class:`ServingVersion` object it was built from
(``view``) — rerank reads go through that exact view — and optionally a
set of per-node retention-ref'd file paths (``retained``) the engine takes
on the *training* cluster's SSDs so compaction can never delete a file the
bound snapshot still points at.
"""

from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np

from repro.core.keys import split_namespaced

_LANE = 128


class RetrievalIndex:
    """Device-resident corpus blocks for one (table, snapshot version)."""

    def __init__(
        self,
        *,
        table: str,
        version: int,
        view,
        keys: np.ndarray,
        corpus,
        n_rows: int,
        dim: int,
        block_n: int,
        retained: "dict[int, list[str]] | None" = None,
    ):
        self.table = table
        self.version = int(version)
        self.view = view  # the pinned ServingVersion (rerank reads use it)
        self.keys = keys  # uint64 [n_rows] corpus row -> raw ad key, ascending
        self.corpus = corpus  # jnp f32 [Np, Dp] lane-aligned device corpus
        self.n_rows = int(n_rows)
        self.dim = int(dim)
        self.block_n = int(block_n)
        self.retained = retained

    @classmethod
    def build(cls, source, table: str, *, block_n: int = 512, view=None) -> "RetrievalIndex":
        """Scan ``view`` (default: ``source.acquire()``) for the table's
        live rows and materialize the device corpus. ``source`` must be a
        snapshot-backed :class:`~repro.serve.snapshot.ServingCluster` —
        a live training view has no immutable version to bind."""
        if view is None:
            view = source.acquire()
        if not hasattr(view, "ssd"):
            raise TypeError(
                "retrieval indexes bind to published snapshot versions; "
                "serve from a ServingCluster (SnapshotPublisher.publish + "
                "PSClient.serving_view(snapshots=...)), not the live cluster"
            )
        spec = view.tables.require(table)
        if spec.table_id is None:
            raise ValueError(f"table {table!r} has no assigned id")
        emb = spec.schema.emb_dim
        key_parts: list[np.ndarray] = []
        row_parts: list[np.ndarray] = []
        for ssd in view.ssd:
            for fkeys, fvals in ssd.iter_live():
                tids, raw = split_namespaced(fkeys)
                m = tids == spec.table_id
                if m.any():
                    key_parts.append(raw[m])
                    row_parts.append(np.asarray(fvals[m, :emb], dtype=np.float32))
        if key_parts:
            keys = np.concatenate(key_parts)
            rows = np.concatenate(row_parts)
            order = np.argsort(keys, kind="stable")
            keys, rows = keys[order], rows[order]
        else:
            keys = np.zeros(0, dtype=np.uint64)
            rows = np.zeros((0, emb), dtype=np.float32)
        n = len(keys)
        n_pad = max(block_n, math.ceil(n / block_n) * block_n)
        d_pad = max(_LANE, math.ceil(emb / _LANE) * _LANE)
        padded = np.zeros((n_pad, d_pad), dtype=np.float32)
        padded[:n, :emb] = rows
        return cls(
            table=table,
            version=view.version,
            view=view,
            keys=keys,
            corpus=jnp.asarray(padded),
            n_rows=n,
            dim=emb,
            block_n=block_n,
        )
