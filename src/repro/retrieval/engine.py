"""RetrievalEngine: versioned top-k ad retrieval + feature-interaction
rerank over the serving tier (DESIGN.md §12).

The second production workload on the hierarchy: candidate retrieval runs
brute-force blocked MIPS (``kernels.ops.topk_mips``) over a
:class:`~repro.retrieval.index.RetrievalIndex` built from the same
published snapshot versions the point-lookup :class:`ServingEngine`
serves, then an optional feature-interaction stage re-scores the top-k by
pooling each request's user-side features through the fused embedding-bag
kernel and adding ``<user_vec, candidate_emb>``.

Version binding mirrors the serving engine's atomicity contract:

* ``search`` reads ``self._index`` once (one atomic reference load) and
  works entirely against that object — corpus, key map and pinned
  :class:`ServingVersion` travel together, so a concurrent roll can never
  mix versions inside one request.
* ``roll_forward`` (under ``RetrievalEngine._lock``) rolls the serving
  engine, builds the **new** index completely, then swaps the reference —
  in-flight searches finish on the version they started with.
* With ``retain_cluster`` (the training cluster) the engine takes
  retention refs on every file the bound version's manifest names, so
  training-side compaction parks rather than deletes them while an index
  is bound; the refs drop when the index is replaced or ``close``d.

Counters flow through :class:`repro.metrics.Counters` under the names in
``RETRIEVAL_COUNTER_NAMES`` (registered in ``metrics.KNOWN_COUNTERS``).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.metrics import Counters
from repro.retrieval.index import RetrievalIndex

RETRIEVAL_COUNTER_NAMES = (
    "retrieval_searches",
    "retrieval_queries",
    "retrieval_candidates",
    "retrieval_rows_scored",
    "retrieval_index_builds",
    "retrieval_index_rows",
    "retrieval_rolls",
    "retrieval_reranks",
    "retrieval_rerank_rows",
)


@dataclass
class RetrievalResult:
    """One search's candidates, sorted (score desc, corpus index asc).

    ``indices`` are corpus row ids in the bound index (-1 = padding past
    the live corpus), ``ad_keys`` the corresponding raw table keys (0 where
    invalid — check ``valid``). ``index`` pins the exact index/version the
    result was scored against; rerank reuses it."""

    scores: np.ndarray  # f32 [Q, k]
    indices: np.ndarray  # i32 [Q, k]
    ad_keys: np.ndarray  # u64 [Q, k]
    valid: np.ndarray  # bool [Q, k]
    version: int
    index: RetrievalIndex = field(repr=False)


class RetrievalEngine:
    """Top-k MIPS retrieval bound to the serving tier's snapshot versions."""

    def __init__(
        self,
        engine,
        table: str,
        *,
        block_q: int = 128,
        block_n: int = 512,
        counters: Counters | None = None,
        retain_cluster=None,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
    ):
        from repro.serve.snapshot import ServingCluster

        if not isinstance(engine.source, ServingCluster):
            raise TypeError(
                "retrieval needs a snapshot-backed ServingEngine "
                "(PSClient.serving_view(snapshots=...)); the live cluster "
                "view has no immutable version to bind an index to"
            )
        self.engine = engine
        self.table = table
        self.block_q = int(block_q)
        self.block_n = int(block_n)
        self.counters = counters or Counters(*RETRIEVAL_COUNTER_NAMES)
        self.retain_cluster = retain_cluster
        self.use_pallas = use_pallas
        self.interpret = interpret
        self._lock = threading.Lock()  # index binds/rolls; search never takes it
        self._index: RetrievalIndex | None = None
        with self._lock:
            self._bind_locked(engine.source.acquire())

    # ------------------------------------------------------ version binding
    @property
    def version(self) -> int:
        idx = self._index
        if idx is None:
            raise RuntimeError("retrieval engine is closed")
        return idx.version

    def _retained_paths(self, version: int) -> "dict[int, list[str]]":
        from repro.serve.snapshot import load_version

        m = load_version(self.engine.source.dir, version)["cluster"]
        return {
            int(nid): list(nm.get("retained_paths", []))
            for nid, nm in m["nodes"].items()
        }

    def _bind_locked(self, view) -> None:
        idx = RetrievalIndex.build(
            self.engine.source, self.table, block_n=self.block_n, view=view
        )
        if self.retain_cluster is not None:
            retained = self._retained_paths(idx.version)
            for nid, paths in retained.items():
                self.retain_cluster.nodes[int(nid)].ssd.retain_files(paths)
            idx.retained = retained
        old, self._index = self._index, idx
        self.counters.inc("retrieval_index_builds")
        self.counters.inc("retrieval_index_rows", idx.n_rows)
        self._drop_refs(old)

    def _drop_refs(self, idx: "RetrievalIndex | None") -> None:
        if idx is not None and idx.retained is not None:
            self.retain_cluster.release_files(idx.retained)
            idx.retained = None

    def roll_forward(self, version: int | None = None) -> int:
        """Roll the serving engine forward (default: latest published) and
        rebuild the index on the new version. The swap is atomic: searches
        in flight finish on the index object they loaded, and no search
        ever sees a half-built corpus."""
        with self._lock:
            after = self.engine.roll_forward(version)
            if self._index is None or self._index.version != after:
                self._bind_locked(self.engine.source.acquire())
                self.counters.inc("retrieval_rolls")
            return after

    def close(self) -> None:
        """Unbind the index and drop its snapshot retention refs."""
        with self._lock:
            idx, self._index = self._index, None
            self._drop_refs(idx)

    # -------------------------------------------------------------- search
    def search(self, queries, k: int) -> RetrievalResult:
        """Top-k ads by inner product against the bound version's corpus.

        ``queries`` is [Q, emb_dim] (Q may be 0). Results follow the kernel
        contract exactly — descending score, ties by ascending corpus index,
        (-inf, -1) padding when k exceeds the live corpus — and are equal to
        ``kernels.ref.topk_mips_ref`` on the same corpus.
        """
        idx = self._index
        if idx is None:
            raise RuntimeError("retrieval engine is closed")
        k = int(k)
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        q = np.asarray(queries, dtype=np.float32)
        if q.ndim != 2 or q.shape[1] != idx.dim:
            raise ValueError(
                f"queries must be [Q, {idx.dim}] for table {idx.table!r}, "
                f"got {q.shape}"
            )
        n_q = q.shape[0]
        if n_q == 0:  # nothing to score; keep the result shape contract
            scores = np.zeros((0, k), dtype=np.float32)
            cand = np.full((0, k), -1, dtype=np.int32)
        else:
            d_pad = idx.corpus.shape[1]
            qp = jnp.asarray(np.pad(q, ((0, 0), (0, d_pad - idx.dim))))
            vals, ind = kops.topk_mips(
                qp, idx.corpus, k,
                n_valid=idx.n_rows,
                block_q=self.block_q, block_n=self.block_n,
                use_pallas=self.use_pallas, interpret=self.interpret,
            )
            scores, cand = np.asarray(vals), np.asarray(ind)
        valid = cand >= 0
        ad_keys = np.zeros(cand.shape, dtype=np.uint64)
        if idx.n_rows:
            ad_keys[valid] = idx.keys[cand[valid]]
        self.counters.inc("retrieval_searches")
        self.counters.inc("retrieval_queries", n_q)
        self.counters.inc("retrieval_candidates", int(valid.sum()))
        self.counters.inc("retrieval_rows_scored", n_q * idx.n_rows)
        return RetrievalResult(
            scores=scores, indices=cand, ad_keys=ad_keys, valid=valid,
            version=idx.version, index=idx,
        )

    # -------------------------------------------------------------- rerank
    def rerank(
        self,
        result: RetrievalResult,
        user_keys,  # [Q, nnz] raw keys into ``user_table``
        slot_of,  # [Q, nnz] i32 pooling bucket per nonzero
        valid,  # [Q, nnz] padding mask
        *,
        n_slots: int,
        user_table: str | None = None,
        alpha: float = 1.0,
    ) -> RetrievalResult:
        """Feature-interaction scoring stage: re-rank ``result``'s top-k.

        Each query's user-side features pool through the fused
        embedding-bag kernel (rows pulled at the result's **pinned**
        version via ``ServingEngine.lookup_at``, so a concurrent roll
        cannot mix versions), the pooled slots sum to one user vector, and
        the final score is ``retrieval + alpha * <user_vec, cand_emb>``.
        Candidates re-sort by (score desc, corpus index asc) — the same
        deterministic order as retrieval itself.
        """
        idx = result.index
        uk = np.asarray(user_keys, dtype=np.uint64)
        n_q, k = result.scores.shape
        if uk.ndim != 2 or uk.shape[0] != n_q:
            raise ValueError(
                f"user_keys must be [{n_q}, nnz] to match the result, got {uk.shape}"
            )
        if n_q == 0:
            self.counters.inc("retrieval_reranks")
            return result
        uniq, inv = np.unique(uk.reshape(-1), return_inverse=True)
        rows = self.engine.lookup_at(self.table if user_table is None else user_table,
                                     uniq, view=idx.view)
        if rows.shape[1] != idx.dim:
            raise ValueError(
                f"user table emb dim {rows.shape[1]} != ad emb dim {idx.dim}"
            )
        pooled = kops.embedding_bag(
            jnp.asarray(rows),
            jnp.asarray(inv.astype(np.int32).reshape(uk.shape)),
            jnp.asarray(np.asarray(slot_of, dtype=np.int32)),
            jnp.asarray(np.asarray(valid)),
            int(n_slots),
            use_pallas=self.use_pallas, interpret=self.interpret,
        )  # [Q, n_slots, emb]
        user_vec = jnp.sum(pooled, axis=1)  # [Q, emb]
        cand_emb = jnp.take(
            idx.corpus, jnp.asarray(np.maximum(result.indices, 0)), axis=0
        )[..., : idx.dim]  # [Q, k, emb]
        inter = np.asarray(jnp.einsum("qd,qkd->qk", user_vec, cand_emb))
        final = np.where(
            result.valid, result.scores + np.float32(alpha) * inter, -np.inf
        ).astype(np.float32)
        # deterministic re-sort: score desc, then corpus index asc, per row
        row = np.repeat(np.arange(n_q), k)
        flat = np.lexsort((result.indices.reshape(-1), -final.reshape(-1), row))
        order = flat.reshape(n_q, k) - (np.arange(n_q) * k)[:, None]
        take = lambda a: np.take_along_axis(a, order, axis=1)
        self.counters.inc("retrieval_reranks")
        self.counters.inc("retrieval_rerank_rows", int(result.valid.sum()))
        return RetrievalResult(
            scores=take(final), indices=take(result.indices),
            ad_keys=take(result.ad_keys), valid=take(result.valid),
            version=result.version, index=idx,
        )
