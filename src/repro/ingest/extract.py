"""Device-side feature extraction over staged raw records (DESIGN.md §11).

:class:`DeviceIngestor` is the ingest pipeline stage's engine: it takes a
:class:`~repro.data.synthetic_ctr.RawRecordBatch` (unhashed uint64 feature-id
surrogates, ragged per-example nnz), stages the raw planes through the
:class:`~repro.ingest.staging.StagingRing`, and runs the fused hash +
slot-bucket kernel (:func:`repro.kernels.ops.feature_extract`) on device —
emitting the exact ``(keys, slot_of, valid)`` layout the embedding-bag
kernel consumes.

Parity contract: for any raw batch, the produced planes are **bitwise
equal** to the host feeder's numpy extraction
(:func:`repro.data.synthetic_ctr.extract_host`) at the same pack width —
keys hashed with the same splitmix64 mix (u32-pair emulated on device),
slots hashed over the finished key, padding pinned to key 0 / slot 0.
Pinned in tests/test_ingest.py.

The pull/push stage still needs the batch's keys on host (the PS hierarchy
is a host subsystem), so the extracted key pair planes make one device→host
hop — also modelled through the NIC so staging benches account for it (two
u32 planes = the same 8 bytes/key a u64 plane would be). Everything
else (slot_of, valid, labels) stays device-resident: the transfer stage
reshapes device arrays instead of re-uploading host ones.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import numpy as np

from repro.data.synthetic_ctr import KEY_SEED, SLOT_SEED, RawRecordBatch
from repro.ingest.staging import StagedBatch, StagingRing
from repro.kernels import ops as kops

_MASK32 = np.uint64(0xFFFFFFFF)


@dataclass
class IngestedBatch:
    """A train-ready batch whose planes live on device.

    Duck-types ``CTRBatch`` for the trainer's pull/transfer/train stages:
    ``keys`` is host uint64 (the PS pull needs host keys); ``slot_of`` /
    ``valid`` / ``labels`` are device arrays from the staging slot. The
    train stage releases ``staged`` when the batch's step commits.
    """

    keys: np.ndarray  # uint64 [B, P] — host, for the PS pull
    slot_of: Any  # int32 [B, P] — device
    valid: Any  # bool [B, P] — device
    labels: Any  # float32 [B] — device
    batch_id: int
    staged: StagedBatch | None = None


class DeviceIngestor:
    """Raw records → staged, device-extracted batches."""

    def __init__(
        self,
        *,
        n_keys: int,
        n_slots: int,
        pack_width: int,
        network=None,
        deps=None,
        counters=None,
        depth: int = 2,
        key_seed: int = KEY_SEED,
        slot_seed: int = SLOT_SEED,
        use_pallas: bool | None = None,
        interpret: bool | None = None,
    ):
        self.n_keys = n_keys
        self.n_slots = n_slots
        self.pack_width = pack_width
        self.key_seed = key_seed
        self.slot_seed = slot_seed
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.network = network
        self.ring = StagingRing(
            depth=depth, network=network, deps=deps, counters=counters
        )
        self.counters = self.ring.counters

    def ingest(self, raw: RawRecordBatch) -> IngestedBatch:
        """Stage one raw batch and extract its features on device."""
        B, L = raw.raw_ids.shape
        P = self.pack_width
        ids = np.asarray(raw.raw_ids, dtype=np.uint64)[:, :P]
        if L < P:  # reader rows narrower than the pack width: pad (invalid)
            ids = np.pad(ids, ((0, 0), (0, P - L)))
        lengths = np.asarray(raw.lengths, dtype=np.int32)
        valid = np.arange(P, dtype=np.int32)[None, :] < lengths[:, None]
        staged = self.ring.stage(
            raw.batch_id,
            {
                # u64 raw ids travel as two u32 planes (no u64 on device)
                "raw_lo": (ids & _MASK32).astype(np.uint32),
                "raw_hi": (ids >> np.uint64(32)).astype(np.uint32),
                "valid": valid,
                "labels": np.asarray(raw.labels, dtype=np.float32),
            },
        )
        hi_dev, lo_dev, slot_dev = kops.feature_extract(
            staged.tensors["raw_lo"],
            staged.tensors["raw_hi"],
            staged.tensors["valid"],
            n_keys=self.n_keys,
            n_slots=self.n_slots,
            key_seed=self.key_seed,
            slot_seed=self.slot_seed,
            use_pallas=self.use_pallas,
            interpret=self.interpret,
        )
        # the one device->host hop: the PS pull wants host u64 keys, so the
        # two u32 planes (8 bytes/key, same wire cost as before the key
        # space widened past 2^32) recombine here. np.asarray blocks until
        # the extraction is done, so downstream stages never see a
        # half-written plane.
        keys = (
            np.asarray(hi_dev).astype(np.uint64) << np.uint64(32)
        ) | np.asarray(lo_dev).astype(np.uint64)
        if self.network is not None:
            self.network.transfer(int(keys.nbytes))
        self.counters.inc("ingest_examples", B)
        return IngestedBatch(
            keys=keys,
            slot_of=slot_dev,
            valid=staged.tensors["valid"],
            labels=staged.tensors["labels"],
            batch_id=raw.batch_id,
            staged=staged,
        )

    def release(self, batch: IngestedBatch) -> None:
        if batch.staged is not None:
            self.ring.release(batch.staged)

    def reset(self) -> None:
        self.ring.reset()
