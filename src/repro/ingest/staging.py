"""Double-buffered host→device batch staging (DESIGN.md §11).

The staging ring owns a fixed pool of ``depth`` device buffer slots (the
paper-style pinned slot pair at ``depth=2``). Staging batch *k* dispatches
its host→device copies asynchronously and returns immediately, so the copy
of batch *k+1* overlaps the pull/transfer/train of batch *k*; batch
*k+depth* cannot stage until batch *k*'s slot is released by the train
stage — that back-pressure is what bounds device memory to ``depth`` staged
batches.

Buffer-ownership protocol (who may touch a slot, in order):

1. **stage(k)** — the ingest stage thread claims sequence number ``seq``
   under ``_lock``, then *outside the lock* waits for token
   ``("ingest_free", seq - depth)``, models the PCIe copy on the simulated
   NIC (``network.transfer`` — which is also where an injected NIC_STALL
   fault bites), and device_puts the host planes. The slot now belongs to
   the staged batch.
2. **downstream stages** — pull/transfer/train read the slot's tensors but
   never write or free them.
3. **release(k)** — the train stage (or a drain/abort path) frees the slot:
   signals ``("ingest_free", seq)`` and collapses older tokens behind a
   floor so the registry stays bounded. Release is idempotent — the drain
   hook and the trainer's failure path may both call it.

All waits go through the pipeline's :class:`DependencyRegistry`, so
``Pipeline._shutdown``'s ``deps.abort()`` wakes a staging thread blocked on
a slot that will never free (it raises ``DependencyAborted`` instead of
hanging). ``reset()`` restarts the sequence space after ``deps.reset()``
(which drops all signalled tokens) — a new pipeline run on a mid-sequence
ring would otherwise wait forever on tokens from the previous run.

pscheck: ``StagingRing._lock`` is declared in analysis/locks.py (level 15,
non-blocking) — the ``deps.wait`` / ``network.transfer`` / device_put calls
all happen outside it.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import DependencyRegistry
from repro.metrics import Counters

_FREE = "ingest_free"  # token family: ("ingest_free", seq) = slot seq freed


@dataclass
class StagedBatch:
    """One occupied ring slot: the device-resident planes of one batch."""

    seq: int  # monotone staging sequence number (ring slot = seq % depth)
    batch_id: int
    tensors: dict[str, Any]  # name -> device array
    nbytes: int
    t_staged: float  # perf_counter at stage() — overlap window start
    released: bool = field(default=False)


class StagingRing:
    """Fixed-depth ring of device staging slots with explicit ownership."""

    def __init__(
        self,
        depth: int = 2,
        network=None,  # NetworkModel: models the H2D copy + absorbs NIC faults
        deps: DependencyRegistry | None = None,
        counters: Counters | None = None,
    ):
        if depth < 1:
            raise ValueError("staging ring needs depth >= 1")
        self.depth = depth
        self.network = network
        self.deps = deps if deps is not None else DependencyRegistry()
        self.counters = counters if counters is not None else Counters()
        self._lock = threading.Lock()
        self._seq = 0
        self._live: dict[int, StagedBatch] = {}  # seq -> occupied slot

    # ------------------------------------------------------------ protocol
    def stage(self, batch_id: int, host: dict[str, np.ndarray]) -> StagedBatch:
        """Claim the next slot and dispatch async host→device copies.

        Blocks (via the DependencyRegistry, abort-safely) until the slot
        ``depth`` batches back has been released; time spent blocked is
        recorded as ``ingest_wait_us`` — with real overlap it stays near
        zero because train releases slots faster than ingest claims them.
        """
        with self._lock:
            seq = self._seq
            self._seq += 1
        if seq >= self.depth:
            t0 = time.perf_counter()
            self.deps.wait((_FREE, seq - self.depth))
            self.counters.inc(
                "ingest_wait_us", int((time.perf_counter() - t0) * 1e6)
            )
        nbytes = sum(int(np.asarray(v).nbytes) for v in host.values())
        if self.network is not None:
            # the modelled PCIe/NIC hop: counts bytes and (under fault
            # injection) is where a NIC_STALL lands mid-staging
            self.network.transfer(nbytes)
        tensors = {k: jnp.asarray(v) for k, v in host.items()}
        staged = StagedBatch(
            seq=seq,
            batch_id=batch_id,
            tensors=tensors,
            nbytes=nbytes,
            t_staged=time.perf_counter(),
        )
        with self._lock:
            self._live[seq] = staged
        self.counters.inc("ingest_batches")
        self.counters.inc("staging_bytes", nbytes)
        return staged

    def release(self, staged: StagedBatch) -> None:
        """Free the slot for batch ``seq + depth``. Idempotent: the train
        stage, the pipeline drain hook, and the trainer's failure path may
        each call it without double-counting."""
        with self._lock:
            if staged.released:
                return
            staged.released = True
            self._live.pop(staged.seq, None)
        self.counters.inc(
            "ingest_overlap_us",
            int((time.perf_counter() - staged.t_staged) * 1e6),
        )
        self.deps.signal((_FREE, staged.seq))
        # collapse the token tail so the done-set stays bounded over long
        # runs; releases can arrive out of order on drain, so only the
        # contiguous released prefix is floored — later out-of-order
        # releases stay as individual tokens until the gap closes
        self.deps.set_floor(_FREE, self._contiguous_floor())

    def _contiguous_floor(self) -> int:
        """Highest seq S such that every slot <= S has been released."""
        with self._lock:
            live = sorted(self._live)
            top = self._seq - 1
        if not live:
            return top
        return live[0] - 1

    def drain_release(self, staged: StagedBatch) -> None:
        """Release path for batches the pipeline drained unconsumed."""
        self.counters.inc("ingest_drained")
        self.release(staged)

    def reset(self) -> None:
        """Restart the sequence space (new pipeline run). The caller owns
        ordering: only call with no stage() in flight, after the previous
        run's pipeline has shut down."""
        with self._lock:
            self._live.clear()
            self._seq = 0

    # ------------------------------------------------------------ inspect
    @property
    def live_slots(self) -> int:
        with self._lock:
            return len(self._live)

    @property
    def staged_total(self) -> int:
        with self._lock:
            return self._seq
