"""Streaming on-device ingestion subsystem (DESIGN.md §11).

Raw log records (unhashed feature-id surrogates + ragged nnz) in, train-ready
batches on device out. Two pieces:

* :class:`~repro.ingest.staging.StagingRing` — a depth-2 host→device staging
  ring; staging batch k+1 overlaps the pull/transfer/train of batch k, and
  slot reuse is sequenced through the pipeline's DependencyRegistry so an
  abort can never strand a waiter.
* :class:`~repro.ingest.extract.DeviceIngestor` — stages a raw batch and runs
  the fused hash/slot-bucket extraction kernel
  (:func:`repro.kernels.ops.feature_extract`) over the staged planes,
  yielding an :class:`~repro.ingest.extract.IngestedBatch` that duck-types
  ``CTRBatch`` for the existing pull/transfer/train stages.

The extraction is bitwise-equal to the host feeder
(:func:`repro.data.synthetic_ctr.extract_host`) — pinned in
tests/test_ingest.py.
"""

from repro.ingest.extract import DeviceIngestor, IngestedBatch
from repro.ingest.staging import StagedBatch, StagingRing

__all__ = ["DeviceIngestor", "IngestedBatch", "StagedBatch", "StagingRing"]
