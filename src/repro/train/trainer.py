"""The end-to-end CTR trainer: Algorithm 1 + the 4-stage pipeline.

Wires together every subsystem the paper describes:

  stage 1 (read)      — synthetic HDFS stream -> CTRBatch
  stage 2 (pull/push) — HierarchicalPS.prepare_batch (MEM-PS + SSD-PS +
                        remote pulls); the *push* of the previous batch also
                        happens here, keeping SSD traffic on this stage's
                        thread exactly like the paper
  stage 3 (transfer)  — device_put of minibatch tensors + working table
  stage 4 (train)     — one jit: k mini-batches + row-Adagrad + tower Adam

Fault tolerance: periodic async checkpoints persist tower/opt state and the
PS cluster manifest; ``resume`` restores and continues deterministically.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ctr_models import CTRConfig
from repro.core.hier_ps import HierarchicalPS, WorkingSet
from repro.core.node import Cluster
from repro.core.pipeline import Pipeline, Stage
from repro.data.synthetic_ctr import CTRBatch, SyntheticCTRStream
from repro.models import ctr as ctr_model
from repro.train import checkpoint as ckpt
from repro.train.optim import AdamW
from repro.train.train_step import make_ctr_train_step


@dataclass
class TrainerConfig:
    row_lr: float = 0.05
    tower_lr: float = 1e-3
    checkpoint_every: int = 0  # batches; 0 = off
    checkpoint_dir: str = ""
    queue_capacity: int = 2
    stage_timeout: float | None = None  # straggler threshold


class CTRTrainer:
    def __init__(self, cfg: CTRConfig, cluster: Cluster, tcfg: TrainerConfig = TrainerConfig(), seed: int = 0):
        self.cfg = cfg
        self.cluster = cluster
        self.tcfg = tcfg
        # SSD row = [emb | adagrad accum] -> opt_dim == emb_dim
        self.ps = HierarchicalPS(cluster, cfg.emb_dim, cfg.emb_dim)
        self.tower = ctr_model.init_tower(cfg, jax.random.PRNGKey(seed))
        self.opt = AdamW(lr=tcfg.tower_lr)
        self.opt_state = self.opt.init(self.tower)
        self.step_fn = jax.jit(make_ctr_train_step(cfg, tcfg.row_lr, self.opt))
        self.batches_done = 0
        self.losses: list[float] = []
        self.ckpt = (
            ckpt.AsyncCheckpointer(tcfg.checkpoint_dir) if tcfg.checkpoint_every else None
        )

    # ------------------------------------------------------------ stages
    def _stage_pull(self, batch: CTRBatch):
        ws = self.ps.prepare_batch(batch.keys)
        return batch, ws

    def _stage_transfer(self, item):
        batch, ws = item
        k = self.cfg.minibatches_per_batch
        B = batch.keys.shape[0]
        mb = B // k
        sl = lambda a: jnp.asarray(a.reshape((k, mb) + a.shape[1:]))
        minibatches = {
            "slot_ids": sl(ws.slots),
            "slot_of": sl(batch.slot_of),
            "valid": sl(batch.valid),
            "labels": sl(batch.labels),
        }
        return batch, ws, minibatches, jnp.asarray(ws.params), jnp.asarray(ws.opt_state)

    def _stage_train(self, item):
        batch, ws, minibatches, table, accum = item
        self.tower, self.opt_state, new_table, new_accum, metrics = self.step_fn(
            self.tower, self.opt_state, table, accum, minibatches
        )
        # push updated rows (+ optimizer slots) back through MEM-PS -> SSD-PS
        self.ps.complete_batch(ws, np.asarray(new_table), np.asarray(new_accum))
        loss = float(metrics["loss"])
        self.losses.append(loss)
        self.batches_done += 1
        if self.ckpt and self.batches_done % self.tcfg.checkpoint_every == 0:
            self.ckpt.save(
                self.batches_done,
                {"tower": self.tower, "opt": self.opt_state},
                extra={"losses": self.losses[-16:]},
                ps_manifest=self.cluster.manifest(),
            )
        return {"batch_id": batch.batch_id, "loss": loss, "n_working": ws.n_working}

    # ------------------------------------------------------------ running
    def build_pipeline(self) -> Pipeline:
        t = self.tcfg
        return Pipeline(
            [
                Stage("read", lambda b: b, capacity=t.queue_capacity),
                Stage("pull_push", self._stage_pull, capacity=t.queue_capacity, timeout=t.stage_timeout),
                Stage("transfer", self._stage_transfer, capacity=t.queue_capacity),
                Stage("train", self._stage_train, capacity=t.queue_capacity),
            ]
        )

    def run(self, stream, n_batches: int, pipelined: bool = True):
        src = (next(it) for it in [iter(stream)] for _ in range(n_batches))
        if pipelined:
            pipe = self.build_pipeline()
            results = list(pipe.run(src))
            self.last_pipeline = pipe
        else:  # serial baseline (the "no pipeline" ablation)
            results = []
            for b in src:
                results.append(self._stage_train(self._stage_transfer(self._stage_pull(b))))
        if self.ckpt:
            self.ckpt.wait()
        return results

    # ------------------------------------------------------------ recovery
    def resume(self) -> int:
        """Restore tower/opt + PS manifest from the latest checkpoint."""
        tree, step, extra, ps_manifest = ckpt.restore(
            self.tcfg.checkpoint_dir, {"tower": self.tower, "opt": self.opt_state}
        )
        self.tower, self.opt_state = tree["tower"], tree["opt"]
        self.batches_done = step
        if ps_manifest is not None:
            self.cluster = Cluster.restore(ps_manifest, self.cluster.base_dir)
            self.ps = HierarchicalPS(self.cluster, self.cfg.emb_dim, self.cfg.emb_dim)
        return step
