"""The end-to-end CTR trainer: Algorithm 1 + the 4-stage pipeline.

Wires together every subsystem the paper describes:

  stage 1 (read)      — synthetic HDFS stream -> CTRBatch
  stage 2 (pull/push) — PSClient.session on the "ctr" table: applies the
                        deferred push of completed batches, pulls the new
                        batch's fresh keys (MEM-PS + SSD-PS + remote
                        pulls), and resolves cross-batch conflicts by
                        per-key version forwarding — all SSD/MEM-PS
                        traffic stays on this stage's thread, overlapped
                        with device compute
  stage 3 (transfer)  — device_put of minibatch tensors + only the *delta*
                        working rows; rows shared with the previous batch
                        stay device-resident (DeviceWorkingSet remap)
  stage 4 (train)     — one jit: k mini-batches + row-Adagrad + tower Adam;
                        results are committed with ``defer=True`` for the
                        pull/push stage to push, keeping this stage pure
                        device compute

The overlap is lossless: pipelined and serial execution produce bitwise-
identical loss trajectories and parameter state (tests/test_system.py).

Fault tolerance: periodic async checkpoints persist tower/opt state and the
PS cluster manifest; ``resume`` restores and continues deterministically.

Serving handoff: with ``publish_every``/``publish_dir`` set, the trainer
periodically publishes versioned serving snapshots (repro.serve.snapshot)
at the same consistent cut a checkpoint would capture — serving clusters
open them read-only and roll forward while training continues.
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.ctr_models import CTRConfig, table_specs
from repro.core.client import PSClient
from repro.core.compression import WireConfig
from repro.core.hbm_ps import DeviceWorkingSet
from repro.core.node import Cluster, NodeDownError
from repro.core.pipeline import Pipeline, Stage
from repro.data.synthetic_ctr import CTRBatch, SyntheticCTRStream
from repro.models import ctr as ctr_model
from repro.train import checkpoint as ckpt
from repro.train.optim import AdamW
from repro.train.train_step import make_ctr_train_step


@dataclass
class TrainerConfig:
    row_lr: float = 0.05
    tower_lr: float = 1e-3
    checkpoint_every: int = 0  # batches; 0 = off
    checkpoint_dir: str = ""
    publish_every: int = 0  # batches; 0 = off — versioned serving snapshots
    publish_dir: str = ""
    publish_keep: int = 2  # auto-release published versions beyond this many
    queue_capacity: int = 2
    # straggler threshold for the read stage (the paper's HDFS-read
    # stragglers); the stateful stages (pull/push pins rows, transfer
    # advances the reuse plan, train owns the model) are never speculated
    stage_timeout: float | None = None
    device_reuse: bool = True  # cross-batch device working-set residency
    # ride-through recovery (DESIGN.md §9): on a NodeDownError mid-pipeline,
    # recover the dead node (restart + redo-log replay), land the trained
    # prefix's deferred pushes, drain the untrained remainder, replay it
    # serially from the batch replay buffer, then resume pipelining — the
    # recovered run's losses stay bitwise-equal to a fault-free run
    ride_through: bool = False
    max_recoveries: int = 4  # distinct faults survived per run() call
    redo_rows: int = 262_144  # redo-log auto-flush bound (ride_through)
    # streaming ingestion (DESIGN.md §11): the stream yields RawRecordBatch
    # (unhashed ids, ragged nnz) and an ingest stage ahead of pull/push
    # stages them through the double-buffered ring + extracts features on
    # device; False = classic host feeder (stream yields CTRBatch)
    ingest: bool = False
    staging_depth: int = 2  # ring slots (2 = the paper-style pinned pair)
    # training wire (DESIGN.md §13): wire_quantize_train turns on the int8
    # delta push with per-key error feedback — LOSSY (final loss tracks the
    # exact run within the bounded-loss harness's tolerance, but bitwise
    # serial parity no longer holds); the error-feedback residual rides
    # checkpoints under the "wire_ef" subtree. wire_dedup_window > 0
    # additionally serves repeat-key pulls from the pushed-row window
    # (lossless, works with the exact wire too)
    wire_quantize_train: bool = False
    wire_dedup_window: int = 0


class CTRTrainer:
    def __init__(self, cfg: CTRConfig, cluster: Cluster, tcfg: TrainerConfig | None = None, seed: int = 0):
        self.cfg = cfg
        self.cluster = cluster
        # each trainer gets its own config object — a shared mutable default
        # instance would leak one caller's mutations into every other trainer
        self.tcfg = tcfg if tcfg is not None else TrainerConfig()
        tcfg = self.tcfg
        # one named table per slot group (SSD row = [emb | adagrad accum]);
        # the pipelined trainer drives exactly one — heterogeneous groups
        # train through the grouped serial step (train_step.py)
        assert len(cfg.groups) == 1, (
            "CTRTrainer pipelines a single table; use make_ctr_train_step_grouped "
            "with per-group sessions for heterogeneous slot_groups"
        )
        self.wire = WireConfig(
            quantize_push=tcfg.wire_quantize_train,
            dedup_window=tcfg.wire_dedup_window,
        )
        self.client = PSClient(cluster, table_specs(cfg), wire=self.wire)
        self.table = cfg.groups[0].name
        self.ps = self.client.engine(self.table)  # per-table engine (stats, tests)
        self.dev_ws = DeviceWorkingSet(row_bytes=2 * cfg.emb_dim * 4)
        self.tower = ctr_model.init_tower(cfg, jax.random.PRNGKey(seed))
        self.opt = AdamW(lr=tcfg.tower_lr)
        self.opt_state = self.opt.init(self.tower)
        self.step_fn = jax.jit(make_ctr_train_step(cfg, tcfg.row_lr, self.opt))
        self.batches_done = 0
        self.losses: list[float] = []
        self._prev_table = None  # previous batch's final device rows
        self._prev_accum = None
        self._train_seq = 0  # device-table generation (guards reuse plans)
        # ride-through state: batches enter _replay when the feeder hands
        # them to the pipeline and leave when their train stage completes,
        # so a mid-pipeline failure knows exactly which batches still need
        # (re-)training; _results collects every completed batch's result
        # dict even when the pipeline dies before yielding it downstream
        self._replay: dict[int, CTRBatch] = {}
        self._results: dict[int, dict] = {}
        self.recovery_time_s = 0.0
        # streaming ingestion: raw records are staged + device-extracted by
        # a dedicated pipeline stage; the ring shares the client's
        # DependencyRegistry so pipeline aborts wake staging waiters
        self.ingestor = None
        if tcfg.ingest:
            from repro.ingest import DeviceIngestor

            self.ingestor = DeviceIngestor(
                n_keys=cfg.n_sparse_keys,
                n_slots=cfg.n_slots,
                pack_width=cfg.nnz_per_example,
                network=cluster.network,
                deps=self.client.deps,
                depth=tcfg.staging_depth,
            )
        if self.tcfg.ride_through:
            cluster.enable_redo(self.tcfg.redo_rows)
        self.ckpt = (
            ckpt.AsyncCheckpointer(tcfg.checkpoint_dir) if tcfg.checkpoint_every else None
        )
        # versioned serving snapshots (DESIGN.md §7): publishing repoints the
        # log-structured SSD files behind a manifest — no copy of the table
        self.publisher = None
        if tcfg.publish_every or tcfg.publish_dir:
            if not tcfg.publish_dir:
                raise ValueError("publish_every requires publish_dir to be set")
            from repro.serve.snapshot import SnapshotPublisher

            self.publisher = SnapshotPublisher(
                cluster, tcfg.publish_dir, keep=tcfg.publish_keep
            )

    # ------------------------------------------------------------ stages
    def _stage_ingest(self, raw):
        # stage the raw planes into the next ring slot (overlapping the
        # previous batch's pull/transfer/train) and extract (keys, slot_of,
        # valid) on device; the result duck-types CTRBatch downstream
        return self.ingestor.ingest(raw)

    def _drain_release(self, item):
        """on_drain hook: free the staging slot of a batch the pipeline
        dropped at shutdown (stage outputs carry the batch first)."""
        batch = item[0] if isinstance(item, tuple) else item
        staged = getattr(batch, "staged", None)
        if staged is not None:
            self.ingestor.ring.drain_release(staged)

    def _stage_pull(self, batch: CTRBatch):
        # opening the session also applies completed predecessors' deferred
        # pushes on this thread, then pulls fresh keys / forwards
        # conflicting ones; batch_id dedups straggler re-execution (no
        # double pinning). With device reuse on, keys shared with the
        # immediately-preceding batch are served from the device-resident
        # copy (no host value, no wait)
        sess = self.client.session(
            self.table, batch.keys, batch_id=batch.batch_id,
            device_resident_prev=self.tcfg.device_reuse,
        )
        return batch, sess

    def _stage_transfer(self, item):
        batch, sess = item
        k = self.cfg.minibatches_per_batch
        B = batch.keys.shape[0]
        mb = B // k
        sl = lambda a: jnp.asarray(a.reshape((k, mb) + a.shape[1:]))
        minibatches = {
            "slot_ids": sl(sess.slots),
            "slot_of": sl(batch.slot_of),
            "valid": sl(batch.valid),
            "labels": sl(batch.labels),
        }
        if self.tcfg.device_reuse:
            # only the delta crosses the host->device link; rows shared with
            # the previous batch are remapped on device at train time
            plan = self.dev_ws.plan(sess.keys, batch_id=batch.batch_id)
            params = jnp.asarray(sess.params[plan.fresh_dst])
            accum = jnp.asarray(sess.opt_state[plan.fresh_dst])
        else:
            plan = None
            params = jnp.asarray(sess.params)
            accum = jnp.asarray(sess.opt_state)
        return batch, sess, minibatches, plan, params, accum

    def _stage_train(self, item):
        batch, sess, minibatches, plan, params, accum = item
        if plan is None:
            table, row_accum = params, accum
        else:
            # a plan that reuses rows must remap from the table produced by
            # the generation right before it (full-transfer plans resync
            # after a reset/aborted run, where no residency is assumed)
            if plan.n_reused and plan.seq != self._train_seq + 1:
                raise RuntimeError(
                    f"device working-set plan {plan.seq} does not match table "
                    f"generation {self._train_seq} (pipeline stage skipped?)"
                )
            table = DeviceWorkingSet.assemble(self._prev_table, params, plan)
            row_accum = DeviceWorkingSet.assemble(self._prev_accum, accum, plan)
        self.tower, self.opt_state, new_table, new_accum, metrics = self.step_fn(
            self.tower, self.opt_state, table, row_accum, minibatches
        )
        self._prev_table, self._prev_accum = new_table, new_accum
        if plan is not None:
            self._train_seq = plan.seq
        # deferred commit: the pull/push stage thread pushes the rows
        # through MEM-PS -> SSD-PS and forwards them to any successor batch
        # waiting on these keys — this stage stays pure device compute
        sess.commit(np.asarray(new_table), np.asarray(new_accum), defer=True)
        loss = float(metrics["loss"])
        self.losses.append(loss)
        self.batches_done += 1
        if self.ckpt and self.batches_done % self.tcfg.checkpoint_every == 0:
            # flush deferred pushes so the manifest captures a consistent
            # cut: all batches up to and including this one. The manifest
            # records the hosted table specs alongside the SSD file map.
            self.client.apply_ready_pushes()
            tree = {"tower": self.tower, "opt": self.opt_state}
            wire_ef = self.client.wire_state()
            if wire_ef:
                # the lossy wire's per-key quantization residuals are model
                # state: resuming without them re-applies error the next
                # pushes already carried
                tree["wire_ef"] = wire_ef
            self.ckpt.save(
                self.batches_done,
                tree,
                extra={"losses": self.losses[-16:]},
                ps_manifest=self.client.manifest(),
            )
        if (
            self.publisher
            and self.tcfg.publish_every
            and self.batches_done % self.tcfg.publish_every == 0
        ):
            self.publish()
        # the staged planes have been consumed: free the ring slot so the
        # batch depth slots ahead can start staging (double-buffer release)
        staged = getattr(batch, "staged", None)
        if staged is not None:
            self.ingestor.ring.release(staged)
        result = {"batch_id": batch.batch_id, "loss": loss, "n_working": sess.n_working}
        # recorded here (not at the pipeline sink): a batch whose result
        # dict is still in a queue when the pipeline dies has already
        # trained — it must count as done, not be replayed
        self._results[batch.batch_id] = result
        self._replay.pop(batch.batch_id, None)
        return result

    def publish(self) -> int:
        """Publish a serving snapshot at a consistent cut: every batch up to
        and including the last trained one has its deferred push applied and
        its dirty rows flushed before the version manifest is written."""
        assert self.publisher is not None, "configure publish_dir/publish_every"
        self.client.apply_ready_pushes()
        return self.publisher.publish()

    # ------------------------------------------------------------ running
    def build_pipeline(self) -> Pipeline:
        t = self.tcfg
        stages = [
            # only the read stage is side-effect free, so it alone gets
            # straggler speculation (the paper's HDFS-read stragglers)
            Stage("read", lambda b: b, capacity=t.queue_capacity,
                  timeout=t.stage_timeout),
        ]
        rel = self._drain_release if self.ingestor is not None else None
        if self.ingestor is not None:
            # a fresh pipeline run resets the registry (Pipeline.run ->
            # deps.reset), dropping the previous run's slot-free tokens —
            # the ring's sequence space must restart with it
            self.ingestor.ring.reset()
            # stage() claims a monotone ring sequence number: re-execution
            # would burn slots, so never speculated
            stages.append(
                Stage("ingest", self._stage_ingest, capacity=t.queue_capacity,
                      idempotent=False, on_drain=rel)
            )
        stages += [
            # pull/push pins MEM-PS rows and registers in-flight batches,
            # transfer advances the device-reuse plan, train owns the
            # model state: NOT idempotent, never speculated
            Stage("pull_push", self._stage_pull, capacity=t.queue_capacity,
                  idempotent=False, on_drain=rel),
            Stage("transfer", self._stage_transfer, capacity=t.queue_capacity,
                  idempotent=False, on_drain=rel),
            # train mutates tower/opt state before it can fail, so a
            # retry would apply the batch's gradient step twice
            Stage("train", self._stage_train, capacity=t.queue_capacity,
                  idempotent=False, max_retries=0),
        ]
        return Pipeline(stages, deps=self.client.deps)

    def _serial_step(self, batch):
        """One batch through the full stage chain on the calling thread —
        the serial baseline and the ride-through replay path."""
        if self.ingestor is not None:
            batch = self._stage_ingest(batch)
        return self._stage_train(self._stage_transfer(self._stage_pull(batch)))

    def _record(self, src):
        """Tee the source into the replay buffer: every batch handed to the
        pipeline is retained until its train stage completes."""
        for b in src:
            self._replay[b.batch_id] = b
            yield b

    @staticmethod
    def _node_down_in(e: BaseException | None) -> bool:
        """Is a NodeDownError anywhere in the cause chain? (The pipeline
        wraps stage errors in PipelineError ``from`` the root cause.)"""
        seen: set[int] = set()
        while e is not None and id(e) not in seen:
            if isinstance(e, NodeDownError):
                return True
            seen.add(id(e))
            e = e.__cause__ or e.__context__
        return False

    def _ride_through(self) -> None:
        """Recover from a node kill mid-pipeline, preserving the bitwise
        serial-parity contract (DESIGN.md §9):

        1. restart + redo-replay every dead node (exact pre-kill values);
        2. drain: the trained prefix's deferred pushes land (train runs in
           batch order, so trained in-flight entries are always a prefix),
           the untrained remainder is unpinned and forgotten;
        3. replay the untrained batches serially — serial and pipelined
           execution are bitwise-identical, so the recovered trajectory
           equals the fault-free one;
        4. the caller then resumes pipelined execution on the rest of the
           stream. A second fault during replay lands back here."""
        t0 = time.perf_counter()
        self.cluster.recover_dead_nodes()
        # strict drain: after recovery, a push failure is a real error
        self.client.drain()
        self.dev_ws.reset()
        if self.ingestor is not None:
            # the aborted pipeline left ring slots occupied; replay re-stages
            # every unfinished batch from its raw record, so restart the ring
            self.ingestor.ring.reset()
        self._prev_table = self._prev_accum = None
        for bid in sorted(self._replay):
            batch = self._replay[bid]  # popped by _stage_train on success
            self._serial_step(batch)
        self.recovery_time_s += time.perf_counter() - t0

    def run(self, stream, n_batches: int, pipelined: bool = True):
        src = (next(it) for it in [iter(stream)] for _ in range(n_batches))
        self._replay.clear()
        self._results.clear()
        recorded = self._record(src)
        recoveries = 0
        while True:
            try:
                if pipelined:
                    pipe = self.build_pipeline()
                    for _ in pipe.run(recorded):
                        pass  # results are recorded at the train stage
                    self.last_pipeline = pipe
                else:  # serial baseline (the "no pipeline" ablation)
                    if self.ingestor is not None:
                        self.ingestor.ring.reset()
                    for b in recorded:
                        self._serial_step(b)
                break
            except BaseException as e:
                # a further kill *during* the replay lands back here too:
                # keep recovering until the replay completes or the budget
                # (or a non-node-down failure) stops it
                while (
                    self.tcfg.ride_through
                    and recoveries < self.tcfg.max_recoveries
                    and self._node_down_in(e)
                ):
                    recoveries += 1
                    try:
                        self._ride_through()
                        e = None
                        break
                    except BaseException as e2:
                        e = e2
                if e is None:
                    continue  # resume pipelining on the remaining stream
                # failure path: release pins without masking the primary error
                self.client.drain(strict=False)
                self.dev_ws.reset()
                if self.ingestor is not None:
                    self.ingestor.ring.reset()
                raise e
        # success path: the tail batches' deferred pushes MUST land (a
        # failure here is a real error) — then drop cross-run device
        # residency: a later run may follow a resume(), where the cached
        # rows no longer match the cluster state
        self.client.drain()
        self.dev_ws.reset()
        if self.ingestor is not None:
            self.ingestor.ring.reset()
        if self.ckpt:
            self.ckpt.wait()
        return [self._results[b] for b in sorted(self._results)]

    # ------------------------------------------------------------ recovery
    def resume(self) -> int:
        """Restore tower/opt + PS manifest from the latest checkpoint."""
        tree, step, extra, ps_manifest = ckpt.restore(
            self.tcfg.checkpoint_dir, {"tower": self.tower, "opt": self.opt_state}
        )
        self.tower, self.opt_state = tree["tower"], tree["opt"]
        self.batches_done = step
        if ps_manifest is not None:
            # rebuild with the original capacities/network model — restoring
            # with defaults would silently change cache behaviour. The
            # manifest's recorded table specs win over the live registry
            # (they describe what the checkpointed rows actually contain).
            kw = self.cluster.ctor_kwargs()
            kw["tables"] = None  # defer to the manifest's table specs
            self.cluster = Cluster.restore(ps_manifest, self.cluster.base_dir, **kw)
            # re-adding the config's specs is a no-op when the manifest
            # already recorded them (and covers pre-multi-table manifests)
            self.client = PSClient(self.cluster, table_specs(self.cfg), wire=self.wire)
            self.ps = self.client.engine(self.table)
            if self.publisher is not None:
                # re-take live versions' retention refs on the restored SSDs
                self.publisher.rebind(self.cluster)
        if self.wire.quantize_push:
            # rebind the error-feedback residuals captured at the same cut
            # as the manifest (absent in pre-wire checkpoints -> fresh EF)
            self.client.load_wire_state(
                ckpt.restore_extra_arrays(self.tcfg.checkpoint_dir, "wire_ef/", step=step)
            )
        self.dev_ws.reset()
        self._prev_table = self._prev_accum = None
        return step
