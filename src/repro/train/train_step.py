"""Train-step factories: the exact jitted programs the launcher lowers.

``make_lm_train_step`` builds the LM step for any assigned architecture:
cross-entropy next-token loss (+ MoE aux), microbatch gradient-accumulation
scan (bounds activation memory), remat inside the model, AdamW update.

In ``hier_ps`` embedding mode (the paper's technique as a first-class
feature) the step additionally takes the pulled *working table* and its
row-Adagrad accumulator, and returns both updated — Algorithm 1's device
phase; the host MEM-PS packs them back into one SSD row per key.

``make_ctr_train_step`` is the paper's CTR trainer: k mini-batches per pulled
working set inside ONE jit (Algorithm 1 lines 11-15), row-Adagrad on the
working table, Adam on the dense tower.

``make_ctr_train_step_grouped`` is its multi-table form: one working table
(+ accumulator) per slot group, each at its own embedding width, pulled from
its own named PS table via ``PSClient.session`` — heterogeneous feature
families co-hosted on one cluster.

Every factory routes working-row updates through ``kops.adagrad_update`` —
the fused Pallas Adagrad on TPU (pad-to-tile, so odd working-set shapes stay
on the kernel), the bitwise-identical reference elsewhere — and the CTR
forward passes pool through the fused embedding-bag op (models/ctr.py).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig
from repro.kernels import ops as kops
from repro.models import get_model
from repro.models.common import constrain_like_params
from repro.train.optim import Adagrad, AdamW


@dataclass(frozen=True)
class TrainSettings:
    optimizer: AdamW = field(default_factory=AdamW)
    microbatches: int = 1
    attn_impl: str = "auto"
    remat: bool = True
    moe_aux_coef: float = 0.01
    row_lr: float = 0.05  # adagrad lr for hier-PS working rows


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean next-token CE; one-hot contraction (SPMD-friendly on sharded
    vocab). logits: [B,S,V] f32; targets: [B,S] int32."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    return jnp.mean(lse - picked)


def _make_loss_fn(cfg: ArchConfig, settings: TrainSettings, hier: bool):
    model = get_model(cfg)

    def loss_fn(params, working_table, micro):
        kwargs: dict = {}
        if cfg.family == "audio":
            kwargs["frames"] = micro["frames"]
        if cfg.family == "vlm":
            kwargs["image_embeds"] = micro["image_embeds"]
        if hier:
            kwargs["working_table"] = working_table
        logits, aux = model.forward(
            cfg, params, micro["tokens"],
            attn_impl=settings.attn_impl, remat=settings.remat, **kwargs,
        )
        if cfg.family == "vlm":  # image prefix positions carry no LM loss
            logits = logits[:, cfg.n_image_tokens :]
        loss = cross_entropy(logits, micro["targets"])
        return loss + settings.moe_aux_coef * aux, (loss, aux)

    return loss_fn


def make_lm_train_step(cfg: ArchConfig, settings: TrainSettings = TrainSettings()):
    """Dense-embedding LM step.

    step(params, opt_state, batch) -> (params, opt_state, metrics)
    batch: {"tokens": [B,S] int32, "targets": [B,S] int32,
            ["frames"|"image_embeds"]: modality stub inputs}
    """
    assert cfg.embedding_mode == "dense"
    loss_fn = _make_loss_fn(cfg, settings, hier=False)
    opt = settings.optimizer

    def step(params, opt_state, batch):
        n_micro = settings.microbatches
        split = lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        micros = {k: split(v) for k, v in batch.items()}
        grad_fn = jax.value_and_grad(loss_fn, argnums=0, has_aux=True)

        def micro_step(acc, micro):
            (_, (loss, aux)), grads = grad_fn(params, None, micro)
            grads = constrain_like_params(grads)  # -> reduce-scatter per micro
            return jax.tree.map(jnp.add, acc, grads), (loss, aux)

        zero = constrain_like_params(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )
        acc, (losses, auxs) = jax.lax.scan(micro_step, zero, micros)
        grads = jax.tree.map(lambda g: g / n_micro, acc)
        new_params, new_opt = opt.update(grads, opt_state, params)
        return new_params, new_opt, {"loss": jnp.mean(losses), "moe_aux": jnp.mean(auxs)}

    return step


def make_lm_train_step_hier(cfg: ArchConfig, settings: TrainSettings = TrainSettings()):
    """hier_ps LM step: working table rows updated with row-Adagrad.

    step(params, opt_state, batch, working_table, row_accum)
      -> (params, opt_state, metrics, new_table, new_accum)
    batch["tokens"] holds *working slots*; batch["targets"] holds vocab ids.
    """
    assert cfg.embedding_mode == "hier_ps"
    loss_fn = _make_loss_fn(cfg, settings, hier=True)
    opt = settings.optimizer

    def step(params, opt_state, batch, working_table, row_accum):
        n_micro = settings.microbatches
        split = lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])
        micros = {k: split(v) for k, v in batch.items()}
        grad_fn = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)

        def micro_step(acc, micro):
            (_, (loss, aux)), grads = grad_fn(params, working_table, micro)
            grads = (constrain_like_params(grads[0]), grads[1])  # reduce-scatter
            return jax.tree.map(jnp.add, acc, grads), (loss, aux)

        zero = lambda t: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)
        acc, (losses, auxs) = jax.lax.scan(
            micro_step, (constrain_like_params(zero(params)), zero(working_table)), micros
        )
        grads = jax.tree.map(lambda g: g / n_micro, acc)
        new_params, new_opt = opt.update(grads[0], opt_state, params)
        new_table, new_accum = kops.adagrad_update(
            working_table, row_accum, grads[1], settings.row_lr
        )
        metrics = {"loss": jnp.mean(losses), "moe_aux": jnp.mean(auxs)}
        return new_params, new_opt, metrics, new_table, new_accum

    return step


# --------------------------------------------------------------------------
# CTR (the paper's own workload)
# --------------------------------------------------------------------------


def make_ctr_train_step(ctr_cfg, row_lr: float = 0.05, tower_opt: AdamW = AdamW(lr=1e-3)):
    """One pulled working set, k mini-batches trained inside one jit
    (Algorithm 1 lines 11-15).

    step(tower, opt_state, working_table, row_accum, minibatches)
      -> (tower, opt_state, table, accum, metrics)
    minibatches: dict of stacked [k, mb, ...] arrays
    (slot_ids, slot_of, valid, labels).
    """
    from repro.models import ctr as ctr_model

    def step(tower, opt_state, working_table, row_accum, minibatches):
        def one_minibatch(carry, mb):
            tower, opt_state, table, accum = carry
            loss, grads = jax.value_and_grad(
                lambda tw, tb: ctr_model.loss_fn(
                    ctr_cfg, tw, tb, mb["slot_ids"], mb["slot_of"], mb["valid"], mb["labels"]
                ),
                argnums=(0, 1),
            )(tower, table)
            tower, opt_state = tower_opt.update(grads[0], opt_state, tower)
            # paper: parameters synchronized across GPUs after EVERY
            # mini-batch — the row update applies to the shared table before
            # the next mini-batch sees it
            table, accum = kops.adagrad_update(table, accum, grads[1], row_lr)
            return (tower, opt_state, table, accum), loss

        (tower, opt_state, working_table, row_accum), losses = jax.lax.scan(
            one_minibatch, (tower, opt_state, working_table, row_accum), minibatches
        )
        return tower, opt_state, working_table, row_accum, {"loss": jnp.mean(losses)}

    return step


def make_ctr_train_step_grouped(ctr_cfg, row_lr: float = 0.05, tower_opt: AdamW = AdamW(lr=1e-3)):
    """Multi-table CTR step: one working table per slot group, all updated
    inside one jit.

    step(tower, opt_state, tables, accums, minibatches)
      -> (tower, opt_state, tables, accums, metrics)
    tables/accums: {group_name: [n_working_g, emb_g]} per named PS table
    minibatches: {"labels": [k, mb],
                  "inputs": {group_name: {"slot_ids","slot_of","valid"}
                             each stacked [k, mb, nnz_g]}}
    """
    from repro.models import ctr as ctr_model

    def step(tower, opt_state, tables, accums, minibatches):
        def one_minibatch(carry, mb):
            tower, opt_state, tables, accums = carry
            loss, grads = jax.value_and_grad(
                lambda tw, tb: ctr_model.loss_fn_grouped(
                    ctr_cfg, tw, tb, mb["inputs"], mb["labels"]
                ),
                argnums=(0, 1),
            )(tower, tables)
            tower, opt_state = tower_opt.update(grads[0], opt_state, tower)
            # synchronize after every mini-batch (Algorithm 1 line 14),
            # independently per table
            new_tables, new_accums = {}, {}
            for name in tables:
                new_tables[name], new_accums[name] = kops.adagrad_update(
                    tables[name], accums[name], grads[1][name], row_lr
                )
            return (tower, opt_state, new_tables, new_accums), loss

        (tower, opt_state, tables, accums), losses = jax.lax.scan(
            one_minibatch, (tower, opt_state, tables, accums), minibatches
        )
        return tower, opt_state, tables, accums, {"loss": jnp.mean(losses)}

    return step
