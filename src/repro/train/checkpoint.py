"""Checkpoint/restart: atomic, async, covers device state AND PS state.

Layout: <dir>/step_<N>/ containing
  manifest.json          — treedef paths, shapes/dtypes, step, extra metadata
  arrays.npz             — all pytree leaves (keyed by flattened path)
  ps_manifest.json       — optional PS cluster manifest: the SSD file map
                           plus the hosted table specs (name/table_id/
                           RowSchema), so Cluster.restore rebuilds the same
                           named tables and their key namespacing

Writes go to a temp dir then ``os.replace`` (atomic on POSIX); a ``latest``
symlink is flipped last, so a crash mid-save never corrupts the restore
point. ``AsyncCheckpointer`` snapshots arrays on the caller thread (device ->
host copy) and persists on a background thread — the training loop is only
blocked for the copy, as in production checkpointing.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = tree
    return out


def _unflatten_into(template, flat, prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(v, flat, f"{prefix}{k}/") for k, v in template.items()}
    if isinstance(template, tuple) and hasattr(template, "_fields"):  # NamedTuple
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)]
        return type(template)(*vals)
    if isinstance(template, (list, tuple)):
        vals = [_unflatten_into(v, flat, f"{prefix}{i}/") for i, v in enumerate(template)]
        return type(template)(vals) if isinstance(template, list) else tuple(vals)
    return flat[prefix.rstrip("/")]


def atomic_write_json(path: str, obj) -> None:
    """JSON via temp file + ``os.replace`` (atomic on POSIX) with numpy
    scalars coerced. Shared by checkpoint manifests and the serving
    snapshot publisher (repro.serve.snapshot)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(_jsonify(obj), f)
    os.replace(tmp, path)


def flip_pointer(path: str, value: str) -> None:
    """Atomically repoint a one-line pointer file (``latest``/``LATEST``)."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(value)
    os.replace(tmp, path)


def save(directory: str, step: int, tree, extra: dict | None = None, ps_manifest: dict | None = None) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat), "extra": extra or {}}, f)
    if ps_manifest is not None:
        with open(os.path.join(tmp, "ps_manifest.json"), "w") as f:
            json.dump(_jsonify(ps_manifest), f)
    shutil.rmtree(final, ignore_errors=True)
    os.replace(tmp, final)
    # flip the 'latest' pointer last
    flip_pointer(os.path.join(directory, "latest"), os.path.basename(final))
    return final


def latest_step(directory: str) -> int | None:
    latest = os.path.join(directory, "latest")
    if not os.path.exists(latest):
        return None
    with open(latest) as f:
        name = f.read().strip()
    return int(name.split("_")[1])


def restore_extra_arrays(directory: str, prefix: str, step: int | None = None) -> dict:
    """Load the array leaves saved under ``prefix`` as a nested dict —
    for checkpoint subtrees whose shape varies between saves (e.g. the
    training wire's per-key error-feedback state, DESIGN.md §13) and so
    cannot ride the fixed ``restore`` template. Returns ``{}`` when the
    checkpoint predates the subtree, keeping old checkpoints restorable."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    out: dict = {}
    with np.load(os.path.join(path, "arrays.npz")) as z:
        for k in z.files:
            if not k.startswith(prefix):
                continue
            parts = k[len(prefix):].strip("/").split("/")
            d = out
            for p in parts[:-1]:
                d = d.setdefault(p, {})
            d[parts[-1]] = z[k]
    return out


def restore(directory: str, template, step: int | None = None):
    """Returns (tree, step, extra, ps_manifest|None)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    tree = _unflatten_into(template, flat)
    ps_manifest = None
    ps_path = os.path.join(path, "ps_manifest.json")
    if os.path.exists(ps_path):
        with open(ps_path) as f:
            ps_manifest = json.load(f)
    return tree, manifest["step"], manifest["extra"], ps_manifest


def _jsonify(obj):
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    return obj


class AsyncCheckpointer:
    """Snapshot on the caller thread, persist on a background thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree, extra=None, ps_manifest=None) -> None:
        self.wait()  # one in flight at a time
        snapshot = jax.tree.map(lambda a: np.asarray(a), tree)  # device->host

        def work():
            try:
                save(self.directory, step, snapshot, extra, ps_manifest)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
