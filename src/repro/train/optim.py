"""Optimizers in pure JAX (pytree-based): AdamW and Adagrad.

Adagrad is the paper-era CTR optimizer (per-row adaptive step on sparse
rows); AdamW is the LM default. Both expose an optax-like
(init, update) pair so the train step is optimizer-agnostic.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0

    def init(self, params) -> AdamState:
        zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamState(jnp.zeros((), jnp.int32), zeros(), zeros())

    def update(self, grads, state: AdamState, params):
        step = state.step + 1
        if self.clip_norm > 0:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda mu, g: b1 * mu + (1 - b1) * g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(lambda nu, g: b2 * nu + (1 - b2) * jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - b1**step.astype(jnp.float32)
        bc2 = 1 - b2**step.astype(jnp.float32)

        def upd(p, mu, nu):
            u = (mu / bc1) / (jnp.sqrt(nu / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - self.lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamState(step, m, v)


class AdagradState(NamedTuple):
    accum: Any


@dataclass(frozen=True)
class Adagrad:
    lr: float = 0.05
    eps: float = 1e-8

    def init(self, params) -> AdagradState:
        return AdagradState(jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params))

    def update(self, grads, state: AdagradState, params):
        accum = jax.tree.map(lambda a, g: a + jnp.square(g.astype(jnp.float32)), state.accum, grads)

        def upd(p, g, a):
            return (p.astype(jnp.float32) - self.lr * g.astype(jnp.float32) / (jnp.sqrt(a) + self.eps)).astype(p.dtype)

        return jax.tree.map(upd, params, grads, accum), AdagradState(accum)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(1, warmup)
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = base_lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr
