"""Pallas TPU kernel: grouped matmul (megablox-lite) for MoE expert compute.

Tokens arrive sorted by expert (rows grouped contiguously); each row block
multiplies its group's expert weight matrix:

    out[t] = x[t] @ w[group_of(t)]

The wrapper pads every group to a multiple of ``block_t`` so a row tile
never straddles two experts; the per-tile group id arrives via scalar
prefetch and selects the weight block in the BlockSpec index_map — the
weight matrix streams HBM->VMEM only for tiles that actually use it.

Grid: (T_padded/block_t, N/block_n, K/block_k) with a VMEM f32 accumulator
(K innermost, MXU-aligned 128x128x128 default tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(gid_ref, x_ref, w_ref, o_ref, acc_ref, *, n_k):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(ik == n_k - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_n", "block_k", "interpret"))
def gmm_pallas(
    x: jax.Array,  # [T, K] rows sorted by group; T % block_t == 0
    w: jax.Array,  # [E, K, N]
    tile_gid: jax.Array,  # [T // block_t] int32 group id per row tile
    *,
    block_t: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    T, K = x.shape
    E, _, N = w.shape
    bt, bn, bk = min(block_t, T), min(block_n, N), min(block_k, K)
    assert T % bt == 0 and N % bn == 0 and K % bk == 0
    grid = (T // bt, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_gmm_kernel, n_k=K // bk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bt, bk), lambda i, j, k, gid: (i, k)),
                pl.BlockSpec((1, bk, bn), lambda i, j, k, gid: (gid[i], k, j)),
            ],
            out_specs=pl.BlockSpec((bt, bn), lambda i, j, k, gid: (i, j)),
            scratch_shapes=[pltpu.VMEM((bt, bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((T, N), x.dtype),
        interpret=interpret,
    )(tile_gid.astype(jnp.int32), x, w)
