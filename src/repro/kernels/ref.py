"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the semantic ground truth a kernel must match under
``np.testing.assert_allclose`` across shape/dtype sweeps.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_lookup_ref(table: jax.Array, ids: jax.Array) -> jax.Array:
    """[N, D] gathered by int32 ids [B] -> [B, D]."""
    return jnp.take(table, ids, axis=0)


def scatter_add_ref(table: jax.Array, ids: jax.Array, grads: jax.Array) -> jax.Array:
    """table[ids[i]] += grads[i] with duplicate ids accumulating."""
    return table.at[ids].add(grads.astype(table.dtype))


def embedding_bag_ref(
    table: jax.Array,  # [N, emb]
    slot_ids: jax.Array,  # [B, nnz] int32
    slot_of: jax.Array,  # [B, nnz] int32 in [0, n_slots)
    valid: jax.Array,  # [B, nnz] bool
    n_slots: int,
) -> jax.Array:
    """Gather rows and sum-pool per (example, slot) -> [B, n_slots, emb].

    The seed CTR math (materialized gather + one-hot einsum), kept verbatim
    as the semantic contract for the fused embedding-bag kernel and its
    portable segment-sum fallback.
    """
    emb = jnp.take(table, slot_ids, axis=0)  # [B, nnz, emb]
    emb = emb * valid[..., None]
    onehot = jax.nn.one_hot(slot_of, n_slots, dtype=emb.dtype)  # [B, nnz, n_slots]
    return jnp.einsum("bne,bns->bse", emb, onehot)  # [B, n_slots, emb]


def adagrad_ref(
    params: jax.Array,
    accum: jax.Array,
    grads: jax.Array,
    lr: float,
    eps: float = 1e-8,
) -> tuple[jax.Array, jax.Array]:
    """Row-wise Adagrad (the paper's CTR-style sparse optimizer)."""
    g = grads.astype(jnp.float32)
    new_accum = accum + g * g
    new_params = params - lr * g / (jnp.sqrt(new_accum) + eps)
    return new_params.astype(params.dtype), new_accum


def attention_ref(
    q: jax.Array,  # [B, H, Sq, Dh]
    k: jax.Array,  # [B, Hkv, Skv, Dh]
    v: jax.Array,  # [B, Hkv, Skv, Dh]
    causal: bool = True,
    window: int = 0,  # sliding window size; 0 = unlimited
    q_offset: int | jax.Array = 0,  # absolute position of q[..., 0, :]
    kv_len: int | jax.Array | None = None,  # valid kv prefix (decode caches)
) -> jax.Array:
    """Naive full-materialization attention with GQA + causal/window masks."""
    B, H, Sq, Dh = q.shape
    Hkv = k.shape[1]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = 1.0 / jnp.sqrt(jnp.asarray(Dh, dtype=jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Sq, k.shape[2]), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def topk_mips_ref(
    queries: jax.Array,  # [Q, D]
    corpus: jax.Array,  # [N, D]
    k: int,
    n_valid: int | None = None,  # live corpus prefix; rows >= n_valid masked
) -> tuple[jax.Array, jax.Array]:
    """Brute-force top-k maximum-inner-product search, the retrieval
    contract: (scores f32 [Q, k], indices i32 [Q, k]) sorted by descending
    score with ties broken by **ascending corpus index** (stable argsort),
    positions past the live corpus padded with (-inf, -1).

    Also the portable fallback `kernels.ops.topk_mips` dispatches to — at
    serving corpus sizes the full [Q, N] score matrix fits comfortably."""
    queries = jnp.asarray(queries, jnp.float32)
    corpus = jnp.asarray(corpus, jnp.float32)
    N = corpus.shape[0]
    n = N if n_valid is None else int(n_valid)
    scores = jax.lax.dot_general(
        queries, corpus,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [Q, N]
    live = jnp.arange(N, dtype=jnp.int32)[None, :] < min(n, N)
    scores = jnp.where(live, scores, -jnp.inf)
    kk = min(int(k), N)
    order = jnp.argsort(-scores, axis=1, stable=True)[:, :kk].astype(jnp.int32)
    vals = jnp.take_along_axis(scores, order, axis=1)
    idx = jnp.where(jnp.isneginf(vals), -1, order)
    if k > N:
        pad = ((0, 0), (0, int(k) - N))
        vals = jnp.pad(vals, pad, constant_values=-jnp.inf)
        idx = jnp.pad(idx, pad, constant_values=-1)
    return vals, idx


def gmm_ref(x: jax.Array, w: jax.Array, group_sizes: jax.Array) -> jax.Array:
    """Grouped matmul oracle: rows of x are grouped contiguously by expert.

    x: [T, K] tokens sorted by expert; w: [E, K, N]; group_sizes: int32 [E]
    summing to T. Row t multiplies w[e] where e is t's group.
    """
    T = x.shape[0]
    bounds = jnp.cumsum(group_sizes)
    gid = jnp.searchsorted(bounds, jnp.arange(T), side="right")
    return jnp.einsum("tk,tkn->tn", x, w[gid])
