"""Pallas TPU kernel: blocked maximum-inner-product search with streaming
top-k (the retrieval subsystem's hot op, DESIGN.md §12).

Brute-force MIPS over a snapshot's embedding table: score every corpus row
against every query (one MXU matmul per (query tile, corpus block) pair) and
keep a running per-query top-k as the grid sweeps corpus blocks. At ads
scale this beats ANN indexes because the corpus streams through the MXU at
full bandwidth while the top-k state — a ``[block_q, kp]`` (value, index)
pair — stays VMEM-resident across the whole corpus sweep (the innermost
grid axis revisits the same output block, the same residency trick as the
embedding-bag kernel's pooled tile).

Grid layout: ``(n_query_tiles, n_corpus_blocks)`` with the corpus axis
innermost. Each step computes ``scores = q_tile @ corpus_block.T``
([block_q, block_n] f32 on the MXU), masks padded corpus rows to -inf, and
merges the block into the running top-k with a k-step selection loop:
every step extracts the best remaining candidate — maximum score, ties
broken by **minimum corpus index** — so the output ordering is fully
deterministic and block-size independent. Selected entries are retired to
(-inf, INT32_MAX), which makes them indistinguishable from padding; the
wrapper maps any -inf survivor to index -1.

Exactness: each score is ONE dot product over the full (lane-padded)
feature dim — scores are never accumulated across grid steps — so the only
f32 caveat vs the jnp oracle is reduction order inside a single dot.
Corpus/query values on a dyadic grid (e.g. int8-quantized embeddings)
make kernel and oracle bitwise equal; tests and the recall@k bench pin
exactly that.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INT32_MAX = 2**31 - 1  # retired/padding index sentinel inside the kernel
_LANE = 128


def _mips_kernel(q_ref, c_ref, vals_ref, idx_ref, *, k, kp, block_n, n_valid):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():  # fresh query tile: empty running top-k
        vals_ref[...] = jnp.full(vals_ref.shape, -jnp.inf, jnp.float32)
        idx_ref[...] = jnp.full(idx_ref.shape, _INT32_MAX, jnp.int32)

    scores = jax.lax.dot_general(
        q_ref[...], c_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [block_q, block_n]
    gidx = j * block_n + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    live = gidx < n_valid
    scores = jnp.where(live, scores, -jnp.inf)
    gidx = jnp.where(live, gidx, _INT32_MAX)

    # candidates = running top-k (disjoint indices: every corpus row lives
    # in exactly one block) ∪ this block's scores
    cand_vals = jnp.concatenate([vals_ref[...], scores], axis=1)
    cand_idx = jnp.concatenate([idx_ref[...], gidx], axis=1)
    bq = cand_vals.shape[0]
    col = jax.lax.broadcasted_iota(jnp.int32, (bq, kp), 1)

    def select_one(t, carry):
        cv, ci, nv, ni = carry
        best = jnp.max(cv, axis=1, keepdims=True)
        # deterministic ties: the smallest index among score == best
        bi = jnp.min(jnp.where(cv == best, ci, _INT32_MAX), axis=1, keepdims=True)
        nv = jnp.where(col == t, best, nv)
        ni = jnp.where(col == t, bi, ni)
        taken = (cv == best) & (ci == bi)
        return (
            jnp.where(taken, -jnp.inf, cv),
            jnp.where(taken, _INT32_MAX, ci),
            nv,
            ni,
        )

    _, _, new_vals, new_idx = jax.lax.fori_loop(
        0, k, select_one,
        (
            cand_vals,
            cand_idx,
            jnp.full((bq, kp), -jnp.inf, jnp.float32),
            jnp.full((bq, kp), _INT32_MAX, jnp.int32),
        ),
    )
    vals_ref[...] = new_vals
    idx_ref[...] = new_idx


@functools.partial(
    jax.jit,
    static_argnames=("k", "n_valid", "block_q", "block_n", "interpret"),
)
def topk_mips_pallas(
    queries: jax.Array,  # [Q, D] f32 query vectors
    corpus: jax.Array,  # [N, D] f32 corpus rows (index i = corpus id i)
    k: int,
    *,
    n_valid: int | None = None,  # live corpus prefix; rows >= n_valid masked
    block_q: int = 128,
    block_n: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Top-k inner products -> (scores f32 [Q, k], indices i32 [Q, k]).

    Rows are sorted by descending score, ties by ascending corpus index;
    positions past the live corpus size come back as (-inf, -1).
    """
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    Q, D = queries.shape
    N = corpus.shape[0]
    n = N if n_valid is None else int(n_valid)
    Dp = max(_LANE, math.ceil(D / _LANE) * _LANE)
    Qp = max(block_q, math.ceil(Q / block_q) * block_q)
    Np = max(block_n, math.ceil(N / block_n) * block_n)
    kp = max(_LANE, math.ceil(k / _LANE) * _LANE)
    qp = jnp.pad(queries.astype(jnp.float32), ((0, Qp - Q), (0, Dp - D)))
    cp = jnp.pad(corpus.astype(jnp.float32), ((0, Np - N), (0, Dp - D)))
    kernel = functools.partial(
        _mips_kernel, k=k, kp=kp, block_n=block_n, n_valid=min(n, N)
    )
    vals, idx = pl.pallas_call(
        kernel,
        grid=(Qp // block_q, Np // block_n),
        in_specs=[
            pl.BlockSpec((block_q, Dp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_n, Dp), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, kp), lambda i, j: (i, 0)),
            pl.BlockSpec((block_q, kp), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Qp, kp), jnp.float32),
            jax.ShapeDtypeStruct((Qp, kp), jnp.int32),
        ],
        interpret=interpret,
    )(qp, cp)
    vals, idx = vals[:Q, :k], idx[:Q, :k]
    return vals, jnp.where(idx == _INT32_MAX, -1, idx)
