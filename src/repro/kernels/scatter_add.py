"""Pallas TPU kernel: scatter-accumulate into the working table (``accumulate``).

The push half of the paper's HBM-PS hash-table ``accumulate``: gradient rows
are added into their working-table rows. GPUs use atomics; TPUs have no
global atomics, so we make collisions *structurally* race-free instead:

* the wrapper sorts ids (duplicates become consecutive grid steps);
* the TPU grid is sequential, and Pallas keeps an output block resident in
  VMEM while its block index is unchanged — consecutive duplicate rows
  accumulate in VMEM and write back to HBM once;
* ``input_output_aliases`` makes the update in-place in HBM.

Grid: (B, D // block_d); out block = table row ids[i], d-tile j.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 2048


def _scatter_kernel(ids_ref, grad_ref, table_ref, out_ref):
    i = pl.program_id(0)
    prev = ids_ref[jnp.maximum(i - 1, 0)]
    first_visit = jnp.logical_or(i == 0, ids_ref[i] != prev)

    @pl.when(first_visit)
    def _():
        out_ref[...] = table_ref[...] + grad_ref[...].astype(table_ref.dtype)

    @pl.when(jnp.logical_not(first_visit))
    def _():
        out_ref[...] = out_ref[...] + grad_ref[...].astype(table_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def scatter_add_pallas(
    table: jax.Array,  # [N, D]
    ids: jax.Array,  # [B] int32 — MUST be sorted (wrapper sorts)
    grads: jax.Array,  # [B, D]
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    N, D = table.shape
    B = ids.shape[0]
    bd = min(block_d, D)
    assert D % bd == 0, f"D={D} must tile by block_d={bd}"
    grid = (B, D // bd)
    return pl.pallas_call(
        _scatter_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, bd), lambda i, j, ids: (i, j)),  # grads
                pl.BlockSpec((1, bd), lambda i, j, ids: (ids[i], j)),  # table in
            ],
            out_specs=pl.BlockSpec((1, bd), lambda i, j, ids: (ids[i], j)),
        ),
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        input_output_aliases={2: 0},
        interpret=interpret,
    )(ids.astype(jnp.int32), grads, table)
