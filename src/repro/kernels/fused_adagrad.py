"""Pallas TPU kernel: fused row-wise Adagrad on working-set rows.

The paper's CTR optimizer applies a per-row adaptive update to every pulled
working row. Unfused this is 4 HBM round-trips (read p, read a, write p,
write a) plus 3 elementwise kernels; fused it is a single VMEM pass:

    a' = a + g*g ;  p' = p - lr * g / (sqrt(a') + eps)

Grid tiles rows x d; params/accum are aliased in-place.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256
DEFAULT_BLOCK_D = 512


def _adagrad_kernel(p_ref, a_ref, g_ref, lr_ref, po_ref, ao_ref, *, eps):
    g = g_ref[...].astype(jnp.float32)
    a = a_ref[...] + g * g
    ao_ref[...] = a
    lr = lr_ref[0, 0]
    po_ref[...] = (p_ref[...].astype(jnp.float32) - lr * g / (jnp.sqrt(a) + eps)).astype(po_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "block_d", "eps", "interpret"))
def adagrad_pallas(
    params: jax.Array,  # [B, D]
    accum: jax.Array,  # [B, D] float32
    grads: jax.Array,  # [B, D]
    lr: jax.Array | float,
    *,
    eps: float = 1e-8,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    B, D = params.shape
    br = min(block_rows, B)
    bd = min(block_d, D)
    assert B % br == 0 and D % bd == 0, f"({B},{D}) must tile by ({br},{bd})"
    lr_arr = jnp.asarray(lr, dtype=jnp.float32).reshape(1, 1)
    grid = (B // br, D // bd)
    blk = pl.BlockSpec((br, bd), lambda i, j: (i, j))
    p_new, a_new = pl.pallas_call(
        functools.partial(_adagrad_kernel, eps=eps),
        grid=grid,
        in_specs=[
            blk,
            blk,
            blk,
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),  # lr: replicated scalar
        ],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct((B, D), params.dtype),
            jax.ShapeDtypeStruct((B, D), jnp.float32),
        ],
        input_output_aliases={0: 0, 1: 1},
        interpret=interpret,
    )(params, accum, grads, lr_arr)
    return p_new, a_new
