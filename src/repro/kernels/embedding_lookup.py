"""Pallas TPU kernel: dynamic embedding-row gather (HBM-PS ``get``/pull).

The hot device-side op of the paper's HBM-PS: fetch the rows of the working
parameter table referenced by a mini-batch. The table stays in HBM; rows
stream through VMEM one (row, d-tile) block per grid step. Row ids arrive via
scalar prefetch so the BlockSpec ``index_map`` can address HBM blocks
directly — the Pallas pipeline turns this into async HBM->VMEM DMAs that
overlap with the copy of the previous block (the TPU analogue of the paper's
NVLink peer-to-peer ``get``).

Grid: (B, D // block_d). Block (1, block_d) of the table at row ids[i].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 2048


def _gather_kernel(ids_ref, table_ref, out_ref):
    # the pipeline already fetched the right (row, tile) block; pure copy.
    out_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def embedding_lookup_pallas(
    table: jax.Array,  # [N, D] float32/bf16, D multiple of 128
    ids: jax.Array,  # [B] int32
    *,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    N, D = table.shape
    (B,) = ids.shape
    bd = min(block_d, D)
    assert D % bd == 0, f"D={D} must tile by block_d={bd}"
    grid = (B, D // bd)
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, bd), lambda i, j, ids: (ids[i], j))],
            out_specs=pl.BlockSpec((1, bd), lambda i, j, ids: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, D), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
