"""Pallas TPU kernel: blockwise fused (flash) attention forward.

The 32k-prefill cells cannot materialize S x S scores (32 x 32heads x 32k^2
f32 would be petabytes); attention must stream KV blocks through VMEM with a
running (max, sum, acc) reduction. This kernel is the TPU-native version:

* grid (B, H, Sq/bq, Skv/bk) — the kv axis is innermost and carries the
  running softmax state in VMEM scratch;
* GQA: the kv-head block index is h // (H // Hkv) in the k/v index_maps, so
  grouped queries read the same KV block without materializing repeats;
* causal + sliding-window masking by absolute position (q_offset supports
  decode: query position = cache length), with whole-block skipping when the
  block is fully masked (the dominant win for causal prefill: ~2x).

Block shapes default to (128, 128) — MXU-aligned (multiples of 8x128 vregs,
128x128 systolic array). VMEM footprint per step ~= bq*Dh + 2*bk*Dh + bq*bk
floats; at (128,128,Dh=128) that's ~200KB, comfortably inside the ~16MB VMEM
budget, leaving room for double buffering.

The backward pass recomputes attention blockwise (flash-style) in jnp — on
TPU this is the standard remat trade (recompute is compute-cheap vs storing
S x S), and it keeps one oracle for both directions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale, causal, window, q_offset, block_q, block_k, n_kv_blocks,
):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    iq = pl.program_id(2)
    # absolute positions of this (q block, k block)
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # whole-block skip test (trace-time grid indices -> cheap scalar guard)
    q_lo = q_offset + iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_k
    k_hi = k_lo + block_k - 1
    needed = jnp.bool_(True)
    if causal:
        needed = jnp.logical_and(needed, k_lo <= q_hi)
    if window > 0:
        needed = jnp.logical_and(needed, k_hi > q_lo - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, Dh]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, Dh]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]  # [bq]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_cur)  # [bq]
        p = jnp.exp(s - m_cur[:, None])  # [bq, bk]
        p = jnp.where(mask, p, 0.0)
        l_cur = l_scr[:, 0] * corr + p.sum(axis=-1)
        v = v_ref[0, 0].astype(jnp.float32)  # [bk, Dh]
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * corr[:, None] + pv
        m_scr[...] = jnp.broadcast_to(m_cur[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_cur[:, None], l_scr.shape)

    @pl.when(ik == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, 0]
        safe_l = jnp.where(l == 0.0, 1.0, l)  # fully-masked query rows -> 0
        o_ref[0, 0] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # [B, H, Sq, Dh]
    k: jax.Array,  # [B, Hkv, Skv, Dh]
    v: jax.Array,  # [B, Hkv, Skv, Dh]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    rep = H // Hkv
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0, f"seq ({Sq},{Skv}) must tile by ({bq},{bk})"
    n_kv_blocks = Skv // bk
    grid = (B, H, Sq // bq, n_kv_blocks)
    scale = 1.0 / (Dh**0.5)

    kernel = functools.partial(
        _flash_fwd_kernel,
        scale=scale,
        causal=causal,
        window=window,
        q_offset=q_offset,
        block_q=bq,
        block_k=bk,
        n_kv_blocks=n_kv_blocks,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, Dh), lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
