"""Pallas TPU kernel: streaming feature extraction (hash + slot bucketing).

The ingest subsystem's device hot op (DESIGN.md §11): raw log records carry
*unhashed* feature-id surrogates (the integer stand-in for strings like
``query=shoes``); turning them into train-ready ``(keys, slot_of, valid)``
takes two rounds of splitmix64 plus a modulo each — exactly the host-side
numpy work (`repro.core.keys.hash_keys`) that serializes the feeder at
production batch sizes. This kernel moves that math onto the accelerator.

TPUs have no native 64-bit integer lanes (and Pallas TPU kernels cannot use
u64 at all), so every 64-bit quantity is carried as a **pair of uint32
planes** (lo, hi) and splitmix64 is emulated with u32 adds/xors/shifts and a
16-bit-limb 32x32->64 multiply. The pair math is bit-exact against numpy's
u64 `splitmix64` (pinned in tests/test_ingest.py), which is what makes the
whole extraction path bitwise-equal to the host feeder.

The modulo (``hash % n_keys`` / ``% n_slots``) is a power-of-two mask when
the modulus allows and otherwise a vectorized binary long division
(`lax.fori_loop`, no 64-bit intermediates). Two division widths:
:func:`mod_pair` keeps the remainder in one u32 lane (moduli up to 2^32 —
the ``(r << 1) | bit`` shift needs the top bit free, so the *loop* runs
only for m <= 2^31 and the 2^31..2^32 range routes through the wide path);
:func:`mod_pair_wide` carries the remainder as a (hi, lo) pair and covers
any modulus below 2^63 — paper-scale 1e11-key tables (~2^37) included.

The kernel itself is purely elementwise over ``[rows, 128]`` u32 planes
(raw_lo, raw_hi, valid -> key_hi, key_lo, slot), so the grid is a flat 1-D
sweep of (8, 128) tiles; ragged-nnz packing (valid masks from per-example
lengths, pack-width truncation) is cheap jnp glue around it. Keys leave the
kernel as a u32 pair for the same reason they enter as one — no u64 lanes —
and the host side recombines them (``hi << 32 | lo``).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# splitmix64 constants (repro.core.keys), split into (hi, lo) u32 halves
_GOLDEN = 0x9E3779B97F4A7C15
_MIX1 = 0xBF58476D1CE4E5B9
_MIX2 = 0x94D049BB133111EB
_MASK32 = 0xFFFFFFFF

_BLOCK_ROWS = 8  # one f32/u32 tile of (8, 128) lanes per grid step


def _const_pair(c: int) -> tuple[int, int]:
    return (c >> 32) & _MASK32, c & _MASK32


def _u32(x) -> jax.Array:
    return jnp.asarray(x, dtype=jnp.uint32)


# ------------------------------------------------------------ u64 pair math
def add64(a_hi, a_lo, b_hi, b_lo):
    lo = a_lo + b_lo  # wraps mod 2^32
    carry = (lo < a_lo).astype(jnp.uint32)
    return a_hi + b_hi + carry, lo


def shr64(hi, lo, k: int):
    """Logical right shift by a static 0 < k < 32."""
    return hi >> _u32(k), (lo >> _u32(k)) | (hi << _u32(32 - k))


def umul32_wide(a, b):
    """Full 32x32 -> 64 product as a (hi, lo) u32 pair, via 16-bit limbs."""
    a0, a1 = a & _u32(0xFFFF), a >> _u32(16)
    b0, b1 = b & _u32(0xFFFF), b >> _u32(16)
    p00 = a0 * b0
    p01 = a0 * b1
    p10 = a1 * b0
    p11 = a1 * b1
    mid = (p00 >> _u32(16)) + (p01 & _u32(0xFFFF)) + (p10 & _u32(0xFFFF))
    lo = (p00 & _u32(0xFFFF)) | (mid << _u32(16))
    hi = p11 + (p01 >> _u32(16)) + (p10 >> _u32(16)) + (mid >> _u32(16))
    return hi, lo


def mul64(a_hi, a_lo, b_hi, b_lo):
    """Low 64 bits of the 64x64 product (u64 wrap-around multiply)."""
    hi, lo = umul32_wide(a_lo, b_lo)
    return hi + a_lo * b_hi + a_hi * b_lo, lo


def splitmix64_pair(hi, lo, seed: int = 0):
    """Bit-exact splitmix64 (`repro.core.keys.splitmix64(x ^ seed)`) on
    (hi, lo) uint32 pairs."""
    s_hi, s_lo = _const_pair(seed)
    g_hi, g_lo = _const_pair(_GOLDEN)
    m1_hi, m1_lo = _const_pair(_MIX1)
    m2_hi, m2_lo = _const_pair(_MIX2)
    hi, lo = hi ^ _u32(s_hi), lo ^ _u32(s_lo)
    z_hi, z_lo = add64(hi, lo, _u32(g_hi), _u32(g_lo))
    t_hi, t_lo = shr64(z_hi, z_lo, 30)
    z_hi, z_lo = mul64(z_hi ^ t_hi, z_lo ^ t_lo, _u32(m1_hi), _u32(m1_lo))
    t_hi, t_lo = shr64(z_hi, z_lo, 27)
    z_hi, z_lo = mul64(z_hi ^ t_hi, z_lo ^ t_lo, _u32(m2_hi), _u32(m2_lo))
    t_hi, t_lo = shr64(z_hi, z_lo, 31)
    return z_hi ^ t_hi, z_lo ^ t_lo


def mod_pair(hi, lo, m: int) -> jax.Array:
    """``(hi * 2^32 + lo) % m`` as uint32, for a static modulus m <= 2^32.

    Power-of-two moduli reduce to a mask of the low word. Up to 2^31 the
    general case is a 64-step vectorized binary long division whose
    remainder register stays < m <= 2^31, so ``(r << 1) | bit`` never
    overflows u32; the 2^31..2^32 range loses that headroom and routes
    through :func:`mod_pair_wide` instead (the remainder still fits one
    u32). Wider moduli need the pair-valued :func:`mod_pair_wide`.
    """
    if not 0 < m <= (1 << 32):
        raise ValueError(
            f"modulus {m} must be in (0, 2^32] for a u32 result; use "
            "mod_pair_wide for wider moduli"
        )
    if m & (m - 1) == 0:
        return lo & _u32(m - 1)  # x mod 2^k depends only on the low k bits
    if m > (1 << 31):
        return mod_pair_wide(hi, lo, m)[1]  # r < m <= 2^32: hi word is 0

    def body(i, r):
        word = jnp.where(i < 32, hi, lo)
        sh = (_u32(31) - (_u32(i) & _u32(31))).astype(jnp.uint32)
        bit = (word >> sh) & _u32(1)
        r = (r << _u32(1)) | bit
        return jnp.where(r >= _u32(m), r - _u32(m), r)

    return jax.lax.fori_loop(0, 64, body, jnp.zeros_like(lo))


def mod_pair_wide(hi, lo, m: int) -> tuple[jax.Array, jax.Array]:
    """``(hi * 2^32 + lo) % m`` as a (hi, lo) u32 pair, for m < 2^63.

    Same binary long division as :func:`mod_pair`, but the remainder is a
    u32 pair: shift-left-with-carry ``r_hi = (r_hi << 1) | (r_lo >> 31)``,
    pair compare, borrow subtract. The headroom argument that bounds the
    narrow loop at 2^31 bounds this one at 2^63 — ``r < m < 2^63`` keeps
    ``r_hi < 2^31``, so the carry shift never drops a bit. (2^63 itself is
    a power of two and reduces to the mask fast path.)
    """
    if not 0 < m <= (1 << 63):
        raise ValueError(f"modulus {m} must be in (0, 2^63] for pair math")
    if m & (m - 1) == 0:
        mk_hi, mk_lo = _const_pair(m - 1)
        return hi & _u32(mk_hi), lo & _u32(mk_lo)
    m_hi, m_lo = _const_pair(m)

    def body(i, carry):
        r_hi, r_lo = carry
        word = jnp.where(i < 32, hi, lo)
        sh = (_u32(31) - (_u32(i) & _u32(31))).astype(jnp.uint32)
        bit = (word >> sh) & _u32(1)
        r_hi = (r_hi << _u32(1)) | (r_lo >> _u32(31))
        r_lo = (r_lo << _u32(1)) | bit
        ge = (r_hi > _u32(m_hi)) | ((r_hi == _u32(m_hi)) & (r_lo >= _u32(m_lo)))
        borrow = (r_lo < _u32(m_lo)).astype(jnp.uint32)
        s_hi = r_hi - _u32(m_hi) - borrow
        s_lo = r_lo - _u32(m_lo)
        return jnp.where(ge, s_hi, r_hi), jnp.where(ge, s_lo, r_lo)

    z = jnp.zeros_like(lo)
    return jax.lax.fori_loop(0, 64, body, (z, z))


def hash_mod_pair(hi, lo, seed: int, m: int) -> jax.Array:
    """``hash_keys(x, seed) % m`` on u32 pairs -> u32 (m <= 2^32)."""
    h_hi, h_lo = splitmix64_pair(hi, lo, seed)
    return mod_pair(h_hi, h_lo, m)


def hash_mod_pair_wide(hi, lo, seed: int, m: int) -> tuple[jax.Array, jax.Array]:
    """``hash_keys(x, seed) % m`` on u32 pairs -> u32 pair (m <= 2^63)."""
    h_hi, h_lo = splitmix64_pair(hi, lo, seed)
    return mod_pair_wide(h_hi, h_lo, m)


# ------------------------------------------------------- the extraction op
def _extract_math(raw_hi, raw_lo, valid_u32, *, n_keys, n_slots, key_seed, slot_seed):
    """Shared elementwise core: raw id pair + valid mask ->
    (key_hi, key_lo, slot).

    Bitwise contract (`repro.data.synthetic_ctr.extract_host`): the slot
    hash is taken over the *modded* key (matching the host feeder, which
    hashes the finished u64 key), and padded positions carry key 0 /
    slot 0. Keys are a u32 pair so ``n_keys`` may exceed 2^32 (paper-scale
    1e11-key tables); when it doesn't, the high plane is identically zero
    and the narrow division runs instead of the pair one.
    """
    h_hi, h_lo = splitmix64_pair(raw_hi, raw_lo, key_seed)
    if n_keys <= (1 << 32):
        key_lo = mod_pair(h_hi, h_lo, n_keys)
        key_hi = jnp.zeros_like(key_lo)
    else:
        key_hi, key_lo = mod_pair_wide(h_hi, h_lo, n_keys)
    slot = hash_mod_pair(key_hi, key_lo, slot_seed, n_slots)
    live = valid_u32 != 0
    return (
        jnp.where(live, key_hi, 0),
        jnp.where(live, key_lo, 0),
        jnp.where(live, slot, 0).astype(jnp.int32),
    )


def _extract_kernel(raw_lo_ref, raw_hi_ref, valid_ref,
                    key_hi_ref, key_lo_ref, slot_ref,
                    *, n_keys, n_slots, key_seed, slot_seed):
    key_hi, key_lo, slot = _extract_math(
        raw_hi_ref[...], raw_lo_ref[...], valid_ref[...],
        n_keys=n_keys, n_slots=n_slots, key_seed=key_seed, slot_seed=slot_seed,
    )
    key_hi_ref[...] = key_hi
    key_lo_ref[...] = key_lo
    slot_ref[...] = slot


@functools.partial(
    jax.jit,
    static_argnames=("n_keys", "n_slots", "key_seed", "slot_seed", "interpret"),
)
def feature_extract_pallas(
    raw_lo: jax.Array,  # [B, P] uint32 — low half of the raw feature ids
    raw_hi: jax.Array,  # [B, P] uint32 — high half
    valid: jax.Array,  # [B, P] padding mask (non-bool treated as != 0)
    *,
    n_keys: int,
    n_slots: int,
    key_seed: int = 17,
    slot_seed: int = 31,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused hash + slot-bucket kernel ->
    (keys_hi u32 [B, P], keys_lo u32 [B, P], slot_of i32 [B, P])."""
    B, P = raw_lo.shape
    n = B * P
    lane = _BLOCK_ROWS * 128
    rows = max(_BLOCK_ROWS, math.ceil(n / lane) * _BLOCK_ROWS)
    pad = rows * 128 - n
    plane = lambda x, dt: jnp.pad(
        jnp.asarray(x, dt).reshape(-1), (0, pad)
    ).reshape(rows, 128)
    kernel = functools.partial(
        _extract_kernel,
        n_keys=n_keys, n_slots=n_slots, key_seed=key_seed, slot_seed=slot_seed,
    )
    spec = pl.BlockSpec((_BLOCK_ROWS, 128), lambda i: (i, 0))
    keys_hi, keys_lo, slots = pl.pallas_call(
        kernel,
        grid=(rows // _BLOCK_ROWS,),
        in_specs=[spec, spec, spec],
        out_specs=[
            pl.BlockSpec((_BLOCK_ROWS, 128), lambda i: (i, 0)),
            pl.BlockSpec((_BLOCK_ROWS, 128), lambda i: (i, 0)),
            pl.BlockSpec((_BLOCK_ROWS, 128), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 128), jnp.uint32),
            jax.ShapeDtypeStruct((rows, 128), jnp.int32),
        ],
        interpret=interpret,
    )(
        plane(raw_lo, jnp.uint32),
        plane(raw_hi, jnp.uint32),
        plane((jnp.asarray(valid).reshape(-1) != 0), jnp.uint32),
    )
    unpack = lambda x: x.reshape(-1)[:n].reshape(B, P)
    return unpack(keys_hi), unpack(keys_lo), unpack(slots)


@functools.partial(
    jax.jit,
    static_argnames=("n_keys", "n_slots", "key_seed", "slot_seed"),
)
def feature_extract_portable(
    raw_lo: jax.Array,
    raw_hi: jax.Array,
    valid: jax.Array,
    *,
    n_keys: int,
    n_slots: int,
    key_seed: int = 17,
    slot_seed: int = 31,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Same math as the kernel, lowered as plain jnp (any backend)."""
    return _extract_math(
        jnp.asarray(raw_hi, jnp.uint32),
        jnp.asarray(raw_lo, jnp.uint32),
        (jnp.asarray(valid) != 0).astype(jnp.uint32),
        n_keys=n_keys, n_slots=n_slots, key_seed=key_seed, slot_seed=slot_seed,
    )
