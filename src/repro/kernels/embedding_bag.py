"""Pallas TPU kernel: fused embedding-bag — row gather + per-slot sum-pool.

The device hot op of the paper's CTR network: every example gathers its
``nnz`` working-table rows and sum-pools them into per-feature-slot buckets.
Unfused (the seed path) this materializes a ``[B, nnz, emb]`` gather *and* a
dense ``[B, nnz, n_slots]`` one-hot, then pools with an einsum — a dense
matmul doing a segment-sum's job, with ``B*nnz*n_slots*emb`` MACs and three
HBM-sized intermediates. Fused, neither intermediate ever exists:

* ids / slot_of / valid arrive via **scalar prefetch**, so the BlockSpec
  ``index_map`` addresses the HBM table row directly — the Pallas pipeline
  turns the gather into async HBM->VMEM DMAs overlapped with compute;
* each grid step adds one (row, d-tile) into its example's pooled
  ``[n_slots, block_d]`` output tile via a VPU masked add (iota == slot);
* the output tile stays **VMEM-resident** across an example's ``nnz`` steps
  (the grid revisits the same output block consecutively — the same
  residency contract the scatter_add kernel uses) and is written back to
  HBM once per (example, d-tile).

Cost: ``B*nnz*emb`` adds and ``(B*nnz + B*n_slots) * emb`` HBM bytes — vs
the seed path's dense ``B*nnz*n_slots*emb`` matmul.

Grid: (B, D // block_d, nnz) — nnz innermost so the pooled tile for
(example i, d-tile j) is revisited consecutively.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_D = 512


def _bag_kernel(ids_ref, slot_ref, valid_ref, row_ref, out_ref, *, nnz, n_slots):
    i = pl.program_id(0)
    n = pl.program_id(2)
    t = i * nnz + n
    s = slot_ref[t]
    v = valid_ref[t]
    row = row_ref[0, :].astype(jnp.float32) * v.astype(jnp.float32)
    # VPU masked add: route the row into its slot without a one-hot matmul.
    # The pooled tile is f32 regardless of table dtype — nnz-step partial
    # sums must not round to bf16 (the wrapper casts once at the end).
    sel = jax.lax.broadcasted_iota(jnp.int32, (n_slots, 1), 0) == s
    contrib = jnp.where(sel, row[None, :], 0.0)

    @pl.when(n == 0)
    def _():
        out_ref[0] = contrib

    @pl.when(n > 0)
    def _():
        out_ref[0] = out_ref[0] + contrib


@functools.partial(jax.jit, static_argnames=("n_slots", "block_d", "interpret"))
def embedding_bag_pallas(
    table: jax.Array,  # [N, D] float32/bf16 working table
    slot_ids: jax.Array,  # [B, nnz] int32 — working-slot row ids
    slot_of: jax.Array,  # [B, nnz] int32 — pooling bucket per nonzero
    valid: jax.Array,  # [B, nnz] padding mask (non-bool treated as != 0)
    *,
    n_slots: int,
    block_d: int = DEFAULT_BLOCK_D,
    interpret: bool = False,
) -> jax.Array:
    """Fused gather + per-(example, slot) sum-pool -> [B, n_slots, D]."""
    N, D = table.shape
    B, nnz = slot_ids.shape
    bd = math.gcd(D, block_d)  # largest tile that both divides D and fits
    grid = (B, D // bd, nnz)
    kernel = functools.partial(_bag_kernel, nnz=nnz, n_slots=n_slots)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                # table row for (example i, nonzero n), d-tile j
                pl.BlockSpec((1, bd), lambda i, j, n, ids, slots, vals: (ids[i * nnz + n], j)),
            ],
            # pooled tile: constant over the innermost nnz axis -> resident
            out_specs=pl.BlockSpec((1, n_slots, bd), lambda i, j, n, ids, slots, vals: (i, 0, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((B, n_slots, D), jnp.float32),
        interpret=interpret,
    )(
        slot_ids.reshape(-1).astype(jnp.int32),
        slot_of.reshape(-1).astype(jnp.int32),
        # mask semantics, not weights: != 0 keeps float masks from silently
        # truncating differently than the ref/portable paths
        (valid.reshape(-1) != 0).astype(jnp.int32),
        table,
    )
    return out.astype(table.dtype)
