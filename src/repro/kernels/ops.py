"""Public jit'd wrappers over the Pallas kernels, with portable fallbacks.

Dispatch policy: the TPU kernels are the *target*; on this CPU container they
run under ``interpret=True`` (tests) while production code paths call the
portable implementations that lower on any backend with the same math:

* ``embedding_lookup`` / ``scatter_add`` / ``adagrad_update`` — jnp gather /
  sorted-segment add / fused arithmetic (XLA fuses these well on TPU too;
  the Pallas versions additionally avoid touching non-working rows).
* ``embedding_bag`` — fused gather + per-(example, slot) sum-pool with a
  custom VJP (backward goes straight through ``scatter_add``); the portable
  path is a segment-sum, never the dense one-hot/einsum chain.
* ``attention`` — ``impl='flash'`` (Pallas kernel, recompute-vjp),
  ``'blockwise'`` (lax.scan streaming softmax: O(S*block) memory, compiles
  everywhere — what the multi-pod dry-run lowers), ``'naive'`` (materializes
  scores; small shapes / decode).
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

import numpy as np

from repro.kernels import ref as _ref
from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.embedding_lookup import embedding_lookup_pallas
from repro.kernels.feature_extract import (
    feature_extract_pallas,
    feature_extract_portable,
)
from repro.kernels.fused_adagrad import adagrad_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.scatter_add import scatter_add_pallas
from repro.kernels.topk_mips import topk_mips_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


# §Perf toggles (beyond-paper optimizations; see EXPERIMENTS.md).
# RECOMPUTE_ATTN: recompute-vjp attention — backward re-runs the streaming
#   softmax instead of storing per-KV-block (s, p) scan residuals. Dominant
#   memory-term win for long-sequence training.
# BANDED_WINDOW: sliding-window attention as banded chunks (q chunk attends
#   its [2W] neighborhood) instead of masking every KV block — cuts window
#   attention FLOPs and bytes by ~S/(2W).
RECOMPUTE_ATTN = True
BANDED_WINDOW = True


# --------------------------------------------------------------------------
# embedding lookup / scatter / optimizer
# --------------------------------------------------------------------------


def embedding_lookup(table, ids, *, use_pallas: bool | None = None, interpret: bool | None = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return embedding_lookup_pallas(table, ids, interpret=not _on_tpu() if interpret is None else interpret)
    return _ref.embedding_lookup_ref(table, ids)


def scatter_add(
    table, ids, grads, *,
    assume_sorted: bool = False,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
):
    """``table[ids[i]] += grads[i]`` with duplicates accumulating.

    The Pallas kernel needs duplicate ids consecutive, so the wrapper sorts
    by default. Callers whose ids are already sorted (the MEM-PS emits
    sorted-unique working sets; the embedding-bag VJP sorts once itself)
    pass ``assume_sorted=True`` to skip the redundant argsort+gathers.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        if not assume_sorted:
            order = jnp.argsort(ids)  # duplicates must be consecutive for the kernel
            ids, grads = ids[order], grads[order]
        return scatter_add_pallas(
            table, ids, grads,
            interpret=not _on_tpu() if interpret is None else interpret,
        )
    return _ref.scatter_add_ref(table, ids, grads)


def adagrad_update(params, accum, grads, lr, *, eps: float = 1e-8, use_pallas: bool | None = None, interpret: bool | None = None):
    """Fused row-Adagrad on the pulled working set.

    Working sets are sized by the batch's unique keys, so their shapes are
    rarely (8, 128)-tile aligned. The update is purely elementwise, so the
    wrapper repacks any shape into a lane-aligned [rows, 128] layout (padding
    strictly less than one (8, 128) tile — NOT naive pad-to-128 columns,
    which would be a 16x traffic blowup for the paper's emb_dim=8 rows) and
    every shape takes the fused Pallas path instead of silently falling back
    to the reference. Zero-padded grads leave padded elements at zero.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return _ref.adagrad_ref(params, accum, grads, lr, eps)
    interpret = not _on_tpu() if interpret is None else interpret
    B, D = params.shape
    if B % 8 == 0 and D % 128 == 0:
        return adagrad_pallas(params, accum, grads, lr, eps=eps, interpret=interpret)
    n = B * D
    rows = -(-n // 128)
    rows += -rows % 8
    pad = rows * 128 - n
    repack = lambda x: jnp.pad(x.reshape(-1), (0, pad)).reshape(rows, 128)
    p_new, a_new = adagrad_pallas(
        repack(params), repack(accum), repack(grads), lr, eps=eps, interpret=interpret
    )
    unpack = lambda x: x.reshape(-1)[:n].reshape(B, D)
    return unpack(p_new), unpack(a_new)


# --------------------------------------------------------------------------
# fused embedding-bag: gather + per-(example, slot) sum-pool, custom VJP
# --------------------------------------------------------------------------


def _embedding_bag_segment(table, slot_ids, slot_of, valid, n_slots):
    """Portable fallback: flat gather + segment-sum over (example, slot)
    buckets. No ``[B, nnz, n_slots]`` one-hot, no dense pooling matmul —
    XLA lowers this to a gather fused into a segment reduction on any
    backend."""
    B, nnz = slot_ids.shape
    # f32 partial sums regardless of table dtype — matches the Pallas
    # kernel's accumulator so TPU and portable runs pool identically
    rows = jnp.take(table, slot_ids.reshape(-1), axis=0).astype(jnp.float32)
    rows = rows * valid.reshape(-1, 1).astype(jnp.float32)
    seg = (jnp.arange(B, dtype=jnp.int32)[:, None] * n_slots + slot_of).reshape(-1)
    pooled = jax.ops.segment_sum(rows, seg, num_segments=B * n_slots)
    return pooled.reshape(B, n_slots, table.shape[1]).astype(table.dtype)


def _embedding_bag_impl(table, slot_ids, slot_of, valid, n_slots, use_pallas, interpret):
    if use_pallas:
        return embedding_bag_pallas(
            table, slot_ids, slot_of, valid, n_slots=n_slots, interpret=interpret
        )
    return _embedding_bag_segment(table, slot_ids, slot_of, valid, n_slots)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _embedding_bag(table, slot_ids, slot_of, valid, n_slots, use_pallas, interpret):
    return _embedding_bag_impl(table, slot_ids, slot_of, valid, n_slots, use_pallas, interpret)


def _embedding_bag_fwd(table, slot_ids, slot_of, valid, n_slots, use_pallas, interpret):
    out = _embedding_bag_impl(table, slot_ids, slot_of, valid, n_slots, use_pallas, interpret)
    return out, (table, slot_ids, slot_of, valid)


def _embedding_bag_bwd(n_slots, use_pallas, interpret, res, g):
    """Working-table cotangent without autodiff's dense intermediate chain:
    route each nonzero's pooled gradient back to its row (a [B, nnz, emb]
    take_along_axis instead of a one-hot matmul transpose) and scatter-add
    into the table. The kernel path sorts at this boundary and passes
    ``assume_sorted=True`` — same work as the wrapper's default sort, but
    the backward owns its ids ordering (batch ids are never pre-sorted) and
    the portable path skips sorting entirely."""
    table, slot_ids, slot_of, valid = res
    grad_rows = jnp.take_along_axis(g, slot_of[:, :, None].astype(jnp.int32), axis=1)
    grad_rows = grad_rows * valid[..., None].astype(g.dtype)
    flat_ids = slot_ids.reshape(-1)
    flat_grads = grad_rows.reshape(-1, table.shape[1])
    zeros = jnp.zeros_like(table)
    if use_pallas:
        order = jnp.argsort(flat_ids)  # one sort; kernel needs dups adjacent
        d_table = scatter_add(
            zeros, flat_ids[order], flat_grads[order],
            assume_sorted=True, use_pallas=True, interpret=interpret,
        )
    else:
        d_table = _ref.scatter_add_ref(zeros, flat_ids, flat_grads)
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)  # int/bool cotangents
    return d_table, f0(slot_ids), f0(slot_of), f0(valid)


_embedding_bag.defvjp(_embedding_bag_fwd, _embedding_bag_bwd)


def embedding_bag(
    table,  # [N, emb] working table
    slot_ids,  # [B, nnz] int32 working-slot row ids
    slot_of,  # [B, nnz] int32 pooling bucket per nonzero
    valid,  # [B, nnz] padding mask (cast to bool: mask semantics, not weights)
    n_slots: int,
    *,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
):
    """Fused gather + per-(example, slot) sum-pool -> [B, n_slots, emb].

    THE device lookup+pool primitive for CTR training and serving: on TPU
    the Pallas kernel (one VMEM pass, nothing materialized), elsewhere the
    segment-sum fallback — both under a custom VJP whose backward emits
    working-table cotangents straight through ``scatter_add``.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    valid = valid.astype(jnp.bool_)  # all three impls see identical mask math
    return _embedding_bag(
        table, slot_ids, slot_of, valid, int(n_slots), bool(use_pallas), bool(interpret)
    )


# --------------------------------------------------------------------------
# streaming feature extraction (ingest subsystem, DESIGN.md §11)
# --------------------------------------------------------------------------


def feature_extract(
    raw_lo,  # [B, P] uint32 — low half of the unhashed raw feature ids
    raw_hi,  # [B, P] uint32 — high half
    valid,  # [B, P] padding mask (cast to bool)
    *,
    n_keys: int,
    n_slots: int,
    key_seed: int = 17,
    slot_seed: int = 31,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
):
    """Device feature extraction:
    raw ids -> (keys_hi u32, keys_lo u32, slot_of i32).

    The ingest pipeline's hot op: two rounds of splitmix64 (as u32-pair
    math — TPUs have no 64-bit lanes) plus a modulo each, bitwise-equal to
    the host feeder's ``hash_keys(raw) % n_keys`` / ``% n_slots`` numpy
    path. Keys come back as a u32 pair (``hi << 32 | lo`` on host) so
    ``n_keys`` may exceed 2^32 — paper-scale 1e11-key spaces; for small
    key spaces the hi plane is identically zero. Padded positions come
    back as key 0 / slot 0.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return feature_extract_pallas(
            raw_lo, raw_hi, valid,
            n_keys=n_keys, n_slots=n_slots,
            key_seed=key_seed, slot_seed=slot_seed,
            interpret=not _on_tpu() if interpret is None else interpret,
        )
    return feature_extract_portable(
        raw_lo, raw_hi, valid,
        n_keys=n_keys, n_slots=n_slots,
        key_seed=key_seed, slot_seed=slot_seed,
    )


# --------------------------------------------------------------------------
# blocked top-k MIPS (retrieval subsystem, DESIGN.md §12)
# --------------------------------------------------------------------------


def topk_mips(
    queries,  # [Q, D] f32 query vectors
    corpus,  # [N, D] f32 corpus rows (row i = corpus id i)
    k: int,
    *,
    n_valid: int | None = None,
    block_q: int = 128,
    block_n: int = 512,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
):
    """Top-k maximum-inner-product search -> (scores [Q, k], indices [Q, k]).

    The retrieval subsystem's scoring op: on TPU the blocked Pallas kernel
    (corpus streams through the MXU, running top-k stays VMEM-resident),
    elsewhere the full-score-matrix oracle. Both follow the same contract:
    descending score, ties by ascending corpus index, positions past the
    live corpus (``n_valid``, default all of ``corpus``) come back as
    (-inf, -1).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return topk_mips_pallas(
            queries, corpus, int(k),
            n_valid=None if n_valid is None else int(n_valid),
            block_q=block_q, block_n=block_n,
            interpret=not _on_tpu() if interpret is None else interpret,
        )
    return _ref.topk_mips_ref(queries, corpus, int(k), n_valid=n_valid)


# --------------------------------------------------------------------------
# grouped matmul (MoE expert compute)
# --------------------------------------------------------------------------


def gmm(x, w, group_sizes, *, block_t: int = 128, use_pallas: bool | None = None, interpret: bool | None = None):
    """Grouped matmul: rows of ``x`` are contiguous groups (sorted by
    expert); row t multiplies ``w[group_of(t)]``.

    The Pallas path pads each group to a ``block_t`` multiple (tiles never
    straddle experts) and streams only the weights each tile needs.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return _ref.gmm_ref(x, w, group_sizes)
    from repro.kernels.moe_gmm import gmm_pallas

    T, K = x.shape
    E = w.shape[0]
    padded = ((group_sizes + block_t - 1) // block_t) * block_t  # per group
    offs = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(padded)[:-1].astype(jnp.int32)])
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(group_sizes)[:-1].astype(jnp.int32)])
    Tp = int(T + E * (block_t - 1) + block_t - 1) // block_t * block_t  # static bound
    # scatter rows into their padded positions
    gid_of_row = jnp.searchsorted(jnp.cumsum(group_sizes), jnp.arange(T), side="right")
    dst = offs[gid_of_row] + (jnp.arange(T) - starts[gid_of_row])
    xp = jnp.zeros((Tp, K), x.dtype).at[dst].set(x)
    tile_gid = jnp.clip(
        jnp.searchsorted(jnp.cumsum(padded), jnp.arange(Tp // block_t) * block_t, side="right"),
        0, E - 1,
    )
    out_p = gmm_pallas(
        xp, w, tile_gid,
        block_t=block_t,
        interpret=not _on_tpu() if interpret is None else interpret,
    )
    return jnp.take(out_p, dst, axis=0)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def attention_blockwise(
    q: jax.Array,  # [B, H, Sq, Dh]
    k: jax.Array,  # [B, Hkv, Skv, Dh]
    v: jax.Array,  # [B, Hkv, Skv, Dh]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,
    kv_len: int | jax.Array | None = None,
    block_k: int = 512,
) -> jax.Array:
    """Streaming-softmax attention via lax.scan over KV blocks.

    Memory O(Sq * block_k) instead of O(Sq * Skv); differentiable; lowers on
    any backend. GQA handled without materializing repeated KV.
    """
    B, H, Sq, Dh = q.shape
    _, Hkv, Skv, _ = k.shape
    rep = H // Hkv
    bk = min(block_k, Skv)
    if Skv % bk != 0:  # pad K/V to a block multiple; padded keys masked out
        pad = bk - Skv % bk
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if kv_len is None:
            kv_len = Skv
        Skv = Skv + pad
    nk = Skv // bk
    scale = 1.0 / (Dh**0.5)

    qf = q.astype(jnp.float32).reshape(B, Hkv, rep, Sq, Dh)
    kb = k.astype(jnp.float32).reshape(B, Hkv, nk, bk, Dh).transpose(2, 0, 1, 3, 4)
    vb = v.astype(jnp.float32).reshape(B, Hkv, nk, bk, Dh).transpose(2, 0, 1, 3, 4)
    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, inputs):
        m, l, acc = carry
        kblk, vblk, jk = inputs  # [B,Hkv,bk,Dh], [B,Hkv,bk,Dh], scalar
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qf, kblk) * scale
        k_pos = jk * bk + jnp.arange(bk)
        mask = jnp.ones((Sq, bk), dtype=bool)
        if causal:
            mask = jnp.logical_and(mask, k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = jnp.logical_and(mask, k_pos[None, :] > q_pos[:, None] - window)
        if kv_len is not None:
            mask = jnp.logical_and(mask, (k_pos[None, :] < kv_len))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard -inf - -inf for fully masked rows
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bgrqk,bgkd->bgrqd", p, vblk)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, rep, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, rep, Sq, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, jnp.arange(nk)))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).reshape(B, H, Sq, Dh)
    return out.astype(q.dtype)


def attention_banded(
    q: jax.Array,  # [B, H, S, Dh] — self-attention (Sq == Skv)
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    block_k: int = 512,  # unused; kept for API parity
) -> jax.Array:
    """Causal sliding-window attention via banded chunks.

    q is split into chunks of size W=window; chunk i attends only keys in
    chunks [i-1, i] (exactly covers the (p-W, p] window), so compute and
    memory are O(S * 2W) instead of O(S^2) with masking — the TPU-native
    form of SWA (contiguous MXU tiles, no wasted masked blocks).
    """
    B, H, S, Dh = q.shape
    Hkv = k.shape[1]
    W = window
    if S % W != 0:  # pad sequence to a chunk multiple (tail masked)
        pad = W - S % W
        qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return attention_banded(qp, kp, vp, window=W)[:, :, :S]
    n = S // W
    rep = H // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, rep, n, W, Dh)
    kc = k.astype(jnp.float32).reshape(B, Hkv, n, W, Dh)
    vc = v.astype(jnp.float32).reshape(B, Hkv, n, W, Dh)
    # neighborhood [i-1, i]: prepend a zero chunk for i = 0
    zeros = jnp.zeros_like(kc[:, :, :1])
    k2 = jnp.concatenate([jnp.concatenate([zeros, kc[:, :, :-1]], axis=2), kc], axis=3)
    v2 = jnp.concatenate([jnp.concatenate([zeros, vc[:, :, :-1]], axis=2), vc], axis=3)
    scale = 1.0 / (Dh**0.5)
    # NOTE(§Perf): a lax.scan over q chunks (one [W,2W] band live at a time)
    # was measured WORSE here — hymba t_memory 66.7 -> 81.3s, peak temp ~flat
    # (the peak is the global-attention layers, and the scan blocks fusion of
    # the band softmax). Kept as one einsum; the Pallas flash kernel with
    # window block-skipping is the real-TPU form with no HBM intermediates.
    s = jnp.einsum("bgrnqd,bgnkd->bgrnqk", qf, k2) * scale  # [.., W, 2W]
    qpos = jnp.arange(W)[:, None] + W  # position within the 2W band
    kpos = jnp.arange(2 * W)[None, :]
    first = jnp.arange(n) == 0
    mask = (kpos <= qpos) & (kpos > qpos - W)  # causal + window
    valid_prev = ~first[:, None, None]  # chunk 0 has no left neighbor
    mask = mask[None, :, :] & (valid_prev | (kpos[None] >= W))
    s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrnqk,bgnkd->bgrnqd", p, v2)
    return out.reshape(B, H, S, Dh).astype(q.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, causal, window, q_offset, block_q, block_k):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, interpret=not _on_tpu(),
    )


def _flash_fwd(q, k, v, causal, window, q_offset, block_q, block_k):
    return _flash(q, k, v, causal, window, q_offset, block_q, block_k), (q, k, v)


def _flash_bwd(causal, window, q_offset, block_q, block_k, res, g):
    q, k, v = res  # recompute blockwise (flash-style remat backward)
    _, vjp = jax.vjp(
        lambda q, k, v: attention_blockwise(
            q, k, v, causal=causal, window=window, q_offset=q_offset, block_k=block_k
        ),
        q, k, v,
    )
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int | jax.Array = 0,
    kv_len: int | jax.Array | None = None,
    impl: Literal["auto", "naive", "blockwise", "flash"] = "auto",
    block_q: int = 128,
    block_k: int = 512,
) -> jax.Array:
    """Fused attention with GQA + causal/sliding-window masks.

    ``q_offset``/``kv_len`` may be traced scalars except under impl='flash'
    (the Pallas kernel specializes them statically).
    """
    Sq, Skv = q.shape[2], k.shape[2]
    if impl == "auto":
        if _on_tpu() and Sq >= 128 and isinstance(q_offset, int) and kv_len is None:
            impl = "flash"
        elif Sq * Skv > 2048 * 2048:
            impl = "blockwise"
        else:
            impl = "naive"
    if impl == "flash":
        assert kv_len is None and isinstance(q_offset, int), "flash needs static bounds"
        return _flash(q, k, v, causal, window, q_offset, block_q, min(block_k, 128))
    # banded fast path for full-sequence sliding-window self-attention
    if (
        BANDED_WINDOW
        and window > 0
        and causal
        and Sq == Skv
        and Sq > window
        and kv_len is None
        and isinstance(q_offset, int)
        and q_offset == 0
    ):
        fn = lambda q, k, v: attention_banded(q, k, v, window=window)
        if RECOMPUTE_ATTN:
            fn = jax.checkpoint(fn)
        return fn(q, k, v)
    if impl == "blockwise":
        fn = lambda q, k, v: attention_blockwise(
            q, k, v, causal=causal, window=window, q_offset=q_offset,
            kv_len=kv_len, block_k=block_k,
        )
        if RECOMPUTE_ATTN:
            # recompute-vjp: backward re-streams KV blocks instead of storing
            # per-block (s, p) residuals — the flash-attention memory trade
            fn = jax.checkpoint(fn)
        return fn(q, k, v)
    return _ref.attention_ref(
        q, k, v, causal=causal, window=window, q_offset=q_offset, kv_len=kv_len
    )
