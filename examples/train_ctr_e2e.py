"""End-to-end driver: train a ~100M-parameter CTR model through the full
hierarchical PS for a few hundred batches.

~100M trained parameters = 6M sparse keys x emb 8 (params + adagrad state
stream through MEM-PS/SSD-PS as one row on the named "ctr" table) + dense
tower. Runs the complete production path: raw-record streaming ingestion
(double-buffered staging + device feature extraction, DESIGN.md §11) ahead
of the 4-stage pipeline over PSClient batch sessions, multi-node pulls,
cache eviction, SSD compaction, async checkpoints (manifest records the
table specs), and AUC eval on held-out traffic through read-only sessions
(no pins, no registry).

Run:  PYTHONPATH=src python examples/train_ctr_e2e.py [--batches 200]
      (--host-feeder falls back to the classic numpy host extraction)
"""

import argparse
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.ctr_models import CTRConfig
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream, to_ctr_batch
from repro.models import ctr as ctr_model
from repro.train.trainer import CTRTrainer, TrainerConfig


def evaluate_auc(tr: CTRTrainer, cfg: CTRConfig, n_batches: int = 4) -> float:
    from repro.metrics import auc

    stream = SyntheticCTRStream(
        cfg.n_sparse_keys, cfg.nnz_per_example, cfg.n_slots, cfg.batch_size, seed=777
    )
    scores, labels = [], []
    for _ in range(n_batches):
        b = stream.next_batch()
        # read-only session: no pins, no in-flight registry — eval traffic
        # can never taint the training pipeline's device residency
        with tr.client.session(tr.table, b.keys, read_only=True) as s:
            logits = ctr_model.forward(
                cfg, tr.tower, jnp.asarray(s.params),
                jnp.asarray(s.slots), jnp.asarray(b.slot_of), jnp.asarray(b.valid),
            )
        scores.append(np.asarray(logits))
        labels.append(b.labels)
    return auc(np.concatenate(labels), np.concatenate(scores))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=200)
    ap.add_argument("--keys", type=int, default=6_000_000)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--host-feeder", action="store_true",
                    help="classic host numpy feeder instead of the "
                    "streaming ingest pipeline (same batches bitwise)")
    ap.add_argument("--wire-quantize-train", action="store_true",
                    help="int8 quantized gradient push with error feedback "
                    "+ repeat-key pull dedup (DESIGN.md §13); prints the "
                    "per-conflict-class bytes-on-wire report")
    args = ap.parse_args()

    cfg = CTRConfig(
        name="ctr-100M",
        n_sparse_keys=args.keys,
        nnz_per_example=100,
        emb_dim=8,
        n_slots=25,
        mlp_hidden=(256, 128, 64),
        batch_size=4096,
        minibatches_per_batch=4,
    )
    total = cfg.sparse_params + cfg.dense_params
    print(f"model: {cfg.sparse_params/1e6:.0f}M sparse + {cfg.dense_params/1e3:.0f}k dense "
          f"= {total/1e6:.0f}M params (+{cfg.sparse_params/1e6:.0f}M adagrad rows on SSD)")

    tmp = tempfile.mkdtemp(prefix="hps_e2e_")
    cluster = Cluster(
        args.nodes, tmp + "/ps", dim=cfg.emb_dim * 2,
        cache_capacity=600_000, file_capacity=8192, init_cols=cfg.emb_dim,
    )
    tr = CTRTrainer(
        cfg, cluster,
        TrainerConfig(checkpoint_every=50, checkpoint_dir=tmp + "/ckpt",
                      ingest=not args.host_feeder,
                      wire_quantize_train=args.wire_quantize_train,
                      wire_dedup_window=4 if args.wire_quantize_train else 0),
    )
    if args.wire_quantize_train:
        print("wire: int8 quantized push + error feedback, dedup window 4")
    stream = SyntheticCTRStream(
        cfg.n_sparse_keys, cfg.nnz_per_example, cfg.n_slots, cfg.batch_size,
        seed=0, zipf_a=1.05, noise=0.5,
    )
    # both feeds derive from the same raw records, so --host-feeder trains
    # on bitwise-identical batches through the classic numpy extraction
    if args.host_feeder:
        src = (
            to_ctr_batch(r, cfg.n_sparse_keys, cfg.n_slots, cfg.nnz_per_example)
            for r in stream.raw_records()
        )
        mode = "host feeder (numpy extraction)"
    else:
        src = stream.raw_records()
        mode = "streaming ingest (device extraction + staging ring)"
    print(f"feed: {mode}")

    auc0 = evaluate_auc(tr, cfg)
    print(f"AUC before training: {auc0:.4f}")
    t0 = time.perf_counter()
    results = tr.run(src, args.batches)
    dt = time.perf_counter() - t0
    losses = [r["loss"] for r in results]
    ex_per_s = args.batches * cfg.batch_size / dt
    print(f"trained {args.batches} batches in {dt:.0f}s  ({ex_per_s:,.0f} examples/s)")
    print(f"loss: first10={np.mean(losses[:10]):.4f}  last10={np.mean(losses[-10:]):.4f}")
    auc1 = evaluate_auc(tr, cfg)
    print(f"AUC after training: {auc1:.4f}  (+{auc1 - auc0:.4f})")

    rep = tr.last_pipeline.report()
    busy = {k: f"{v['busy_s']:.1f}s" for k, v in rep.items()}
    print(f"pipeline stage busy times: {busy}; bottleneck={tr.last_pipeline.bottleneck()}")
    if tr.ingestor is not None:
        c = tr.ingestor.counters.snapshot()
        print(f"ingest: {c.get('ingest_batches', 0)} batches staged "
              f"({c.get('staging_bytes', 0)/2**20:.0f} MiB through the ring), "
              f"slot wait {c.get('ingest_wait_us', 0)/1e6:.2f}s, "
              f"overlap {c.get('ingest_overlap_us', 0)/1e6:.2f}s")
    if args.wire_quantize_train:
        wc = tr.client.wire_counters()
        ratio = wc["wire_push_raw_bytes"] / max(1, wc["wire_push_enc_bytes"])
        print(f"wire push: {wc['wire_push_rows']:,} rows, "
              f"{wc['wire_push_raw_bytes']/2**20:.1f} MiB raw -> "
              f"{wc['wire_push_enc_bytes']/2**20:.1f} MiB encoded "
              f"({ratio:.2f}x); NIC saved {cluster.network.push_bytes_saved/2**20:.1f} MiB")
        print("wire pull bytes saved by conflict class: "
              f"device-served {wc['wire_pull_device_bytes_saved']/2**20:.1f} MiB "
              f"({wc['wire_pull_device_rows']:,} rows), "
              f"forwarded {wc['wire_pull_forwarded_bytes_saved']/2**20:.1f} MiB "
              f"({wc['wire_pull_forwarded_rows']:,} rows), "
              f"dedup {wc['wire_pull_dedup_bytes_saved']/2**20:.1f} MiB "
              f"({wc['wire_pull_dedup_rows']:,} rows); fresh pulls "
              f"{wc['wire_pull_fresh_bytes']/2**20:.1f} MiB "
              f"({wc['wire_pull_fresh_rows']:,} rows)")
    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    live = sum(n.ssd.n_live_rows for n in cluster.nodes)
    amp = max(n.ssd.space_amplification() for n in cluster.nodes)
    print(f"MEM-PS hit rate {hits/(hits+misses):.1%}; SSD live rows {live:,}; "
          f"space amp {amp:.2f}; remote bytes {cluster.network.bytes_moved/2**20:.0f} MiB")


if __name__ == "__main__":
    main()
