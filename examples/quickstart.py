"""Quickstart: the hierarchical parameter server in ~60 lines.

Builds a 2-node PS cluster (MEM-PS cache over SSD-PS files), pulls a
batch's working set, trains k mini-batches on device, pushes updates back —
Algorithm 1 of the paper, end to end.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import numpy as np

from repro.configs.ctr_models import TINY
from repro.core.hier_ps import HierarchicalPS
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.models import ctr as ctr_model
from repro.train.optim import AdamW
from repro.train.train_step import make_ctr_train_step


def main():
    cfg = TINY
    tmp = tempfile.mkdtemp(prefix="hps_quickstart_")

    # 3-tier PS: SSD files <- DRAM cache <- device working table
    cluster = Cluster(
        n_nodes=2, base_dir=tmp, dim=cfg.emb_dim * 2,  # row = [emb | adagrad]
        cache_capacity=4096, file_capacity=128, init_cols=cfg.emb_dim,
    )
    ps = HierarchicalPS(cluster, cfg.emb_dim, cfg.emb_dim)

    tower = ctr_model.init_tower(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(tower)
    step = jax.jit(make_ctr_train_step(cfg, row_lr=0.05, tower_opt=opt))

    stream = SyntheticCTRStream(
        cfg.n_sparse_keys, cfg.nnz_per_example, cfg.n_slots, cfg.batch_size, seed=0
    )
    for i in range(10):
        batch = stream.next_batch()
        ws = ps.prepare_batch(batch.keys)  # pull + dedup + renumber (pinned)

        k = cfg.minibatches_per_batch
        mb = cfg.batch_size // k
        stack = lambda a: jax.numpy.asarray(a.reshape((k, mb) + a.shape[1:]))
        minibatches = {
            "slot_ids": stack(ws.slots),
            "slot_of": stack(batch.slot_of),
            "valid": stack(batch.valid),
            "labels": stack(batch.labels),
        }
        tower, opt_state, table, accum, metrics = step(
            tower, opt_state, jax.numpy.asarray(ws.params), jax.numpy.asarray(ws.opt_state), minibatches
        )
        ps.complete_batch(ws, np.asarray(table), np.asarray(accum))  # push + unpin
        print(f"batch {i}: loss={float(metrics['loss']):.4f} working_set={ws.n_working}")

    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    print(f"MEM-PS hit rate: {hits / (hits + misses):.1%}; "
          f"remote bytes: {cluster.network.bytes_moved:,}")
    cluster.destroy()


if __name__ == "__main__":
    main()
