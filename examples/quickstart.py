"""Quickstart: the hierarchical parameter server in ~60 lines.

Builds a 2-node PS cluster (MEM-PS cache over SSD-PS files), opens a named
table on it, pulls a batch session's working set, trains k mini-batches on
device, commits the updates back — Algorithm 1 of the paper, end to end,
through the multi-table client API (PSClient / TableSpec / BatchSession).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax
import numpy as np

from repro.configs.ctr_models import TINY
from repro.core.client import PSClient
from repro.core.node import Cluster
from repro.core.tables import RowSchema, TableSpec
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.models import ctr as ctr_model
from repro.train.optim import AdamW
from repro.train.train_step import make_ctr_train_step


def main():
    cfg = TINY
    tmp = tempfile.mkdtemp(prefix="hps_quickstart_")

    # 3-tier PS: SSD files <- DRAM cache <- device working table. The
    # cluster hosts one named table whose rows pack [emb | adagrad accum].
    cluster = Cluster(
        n_nodes=2, base_dir=tmp, dim=cfg.emb_dim * 2,
        cache_capacity=4096, file_capacity=128,
    )
    client = PSClient(cluster, [TableSpec("ctr", RowSchema.with_adagrad(cfg.emb_dim))])

    tower = ctr_model.init_tower(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(tower)
    step = jax.jit(make_ctr_train_step(cfg, row_lr=0.05, tower_opt=opt))

    stream = SyntheticCTRStream(
        cfg.n_sparse_keys, cfg.nnz_per_example, cfg.n_slots, cfg.batch_size, seed=0
    )
    for i in range(10):
        batch = stream.next_batch()
        # session = pull + dedup + renumber (pinned); commit = push + unpin
        with client.session("ctr", batch.keys) as s:
            k = cfg.minibatches_per_batch
            mb = cfg.batch_size // k
            stack = lambda a: jax.numpy.asarray(a.reshape((k, mb) + a.shape[1:]))
            minibatches = {
                "slot_ids": stack(s.slots),
                "slot_of": stack(batch.slot_of),
                "valid": stack(batch.valid),
                "labels": stack(batch.labels),
            }
            tower, opt_state, table, accum, metrics = step(
                tower, opt_state, jax.numpy.asarray(s.params),
                jax.numpy.asarray(s.opt_state), minibatches
            )
            s.commit(np.asarray(table), np.asarray(accum))
        print(f"batch {i}: loss={float(metrics['loss']):.4f} working_set={s.n_working}")

    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    print(f"MEM-PS hit rate: {hits / (hits + misses):.1%}; "
          f"remote bytes: {cluster.network.bytes_moved:,}")
    cluster.destroy()


if __name__ == "__main__":
    main()
