"""Serve a small LM with batched requests: prefill + greedy decode.

Serving subsystem walkthrough (DESIGN.md §7)
--------------------------------------------
This example runs the full train->serve handoff on one host:

1. **Publish** — the trainer-side cluster publishes a versioned snapshot
   (``SnapshotPublisher``): because the SSD-PS is log-structured, publishing
   just writes a manifest and repoints — no copy of the table — and the
   referenced parameter files are retained against compaction.
2. **Open read-only** — ``client.serving_view(snapshots=...)`` builds a
   ``ServingEngine`` over the published version: a version-keyed hot-row
   cache in DRAM, plus a ``DeviceHotSet`` that keeps the hottest token
   embeddings device-resident across decode steps (only the delta rows
   cross the host->device link).
3. **Decode** — each decode step is ONE ``engine.lookup_device`` call for
   the whole request batch (the old per-sequence ``BatchSession``-per-step
   pattern is gone); concurrent request streams would coalesce through
   ``engine.lookup``/``lookup_many`` into shared deduped pulls.

``--wire-quantize`` opts remote shard reads into the int8 row-sparse wire
format (serving reads tolerate quantization; training pulls stay exact).
Serving counters (lookups, hot hits, device reuse, version rolls) come from
``engine.counters`` — the same source the serving bench and tests assert on.

Run:  PYTHONPATH=src python examples/serve_lm.py [--new-tokens 32]
"""

import argparse
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, replace
from repro.core.client import PSClient
from repro.core.node import Cluster, NetworkModel
from repro.core.tables import RowSchema, TableSpec
from repro.models import transformer as T
from repro.models.attention import KVCache
from repro.serve import SnapshotPublisher
from repro.serve.serve_step import greedy_sample


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--wire-quantize", action="store_true",
                    help="int8 wire format for remote serving reads")
    args = ap.parse_args()

    cfg = replace(
        get_smoke_config("yi-9b"),
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
        head_dim=16, vocab_size=2048,
    )
    params = T.init(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens

    tmp = tempfile.mkdtemp(prefix="hps_serve_")
    cluster = Cluster(2, f"{tmp}/train", dim=cfg.d_model, cache_capacity=4096,
                      file_capacity=256, init_scale=0.02)
    # serving table: embedding only, no optimizer slots in the row
    client = PSClient(cluster, [TableSpec("tok_emb", RowSchema.embedding(cfg.d_model))])

    # --- train->serve handoff: publish a version, open it read-only
    publisher = SnapshotPublisher(cluster, f"{tmp}/snapshots")
    version = publisher.publish()
    engine = client.serving_view(
        snapshots=publisher,
        network=NetworkModel(wire_quantize=args.wire_quantize),
        cache_rows=4096, device_hot_rows=1024,
    )

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.uint64)

    # --- prefill: one engine lookup for the whole prompt working set
    prefill = jax.jit(lambda p, t, wt: T.prefill(cfg, p, t, working_table=wt))
    t0 = time.perf_counter()
    slots, wt = engine.lookup_device("tok_emb", prompts)
    logits, cache = prefill(params, jnp.asarray(slots), wt)
    pad = max_len - args.prompt_len
    cache = KVCache(
        jnp.pad(cache.k, ((0, 0),) * 3 + ((0, pad), (0, 0))),
        jnp.pad(cache.v, ((0, 0),) * 3 + ((0, pad), (0, 0))),
    )
    t_prefill = time.perf_counter() - t0

    # --- decode loop: ONE engine lookup per step for the whole batch; hot
    # token rows stay device-resident (DeviceHotSet), the rest read through
    # the version-keyed hot-row cache
    decode = jax.jit(
        lambda p, tok, c, pos, wt: T.decode_step(cfg, p, tok, c, pos, working_table=wt)
    )
    out_tokens = []
    tok_ids = np.asarray(greedy_sample(logits)).astype(np.uint64)
    t0 = time.perf_counter()
    for i in range(args.new_tokens):
        slots, wt = engine.lookup_device("tok_emb", tok_ids)
        logits, cache = decode(
            params, jnp.asarray(slots), cache,
            jnp.int32(args.prompt_len + i), wt,
        )
        tok_ids = np.asarray(greedy_sample(logits)).astype(np.uint64)
        out_tokens.append(tok_ids[:, 0])
    t_decode = time.perf_counter() - t0

    tps = args.batch * args.new_tokens / t_decode
    print(f"serving snapshot v{version} (publish = manifest repoint, no copy)")
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in {t_prefill*1e3:.0f} ms")
    print(f"decode: {args.new_tokens} steps x {args.batch} seqs = {tps:,.0f} tok/s")
    c = engine.counters.snapshot()
    hot = c["hot_hits"] / max(1, c["hot_hits"] + c["hot_misses"])
    dev = engine.device_hot_stats("tok_emb")
    print(f"hot-row cache hit rate: {hot:.1%} over {c['lookups']} lookups")
    print(f"device-resident reuse: {dev.device_hit_rate:.1%} "
          f"({dev.bytes_saved/2**10:.0f} KiB host->device saved)")
    if args.wire_quantize:
        net = engine.source.network
        print(f"wire-quantized replies: {net.quantized_messages} "
              f"({net.quantize_bytes_saved/2**10:.0f} KiB saved on the NIC)")
    print("sampled:", np.stack(out_tokens, axis=1)[0][:16], "...")
    cluster.destroy()


if __name__ == "__main__":
    main()
