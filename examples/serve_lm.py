"""Serve a small LM with batched requests: prefill + greedy decode.

Demonstrates the serving substrate with the paper's technique live on the
input side: each request batch's unique token ids are pulled from the PS
cluster through a **read-only session** (no MEM-PS pins, no in-flight
registry — a decode loop must never accumulate pin pressure); decode steps
look up new tokens against fresh 1-row-per-seq sessions (hot rows come
from the MEM-PS cache). ``--wire-quantize`` opts remote reads into the
int8 row-sparse wire format (serving reads tolerate quantization;
training pulls always stay exact).

Run:  PYTHONPATH=src python examples/serve_lm.py [--new-tokens 32]
"""

import argparse
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, replace
from repro.core.client import PSClient
from repro.core.node import Cluster, NetworkModel
from repro.core.tables import RowSchema, TableSpec
from repro.models import transformer as T
from repro.models.attention import KVCache
from repro.serve.serve_step import greedy_sample


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--wire-quantize", action="store_true",
                    help="int8 wire format for remote serving reads")
    args = ap.parse_args()

    cfg = replace(
        get_smoke_config("yi-9b"),
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_ff=256,
        head_dim=16, vocab_size=2048,
    )
    params = T.init(cfg, jax.random.PRNGKey(0))
    max_len = args.prompt_len + args.new_tokens

    tmp = tempfile.mkdtemp(prefix="hps_serve_")
    cluster = Cluster(2, tmp, dim=cfg.d_model, cache_capacity=4096,
                      file_capacity=256, init_scale=0.02,
                      network=NetworkModel(wire_quantize=args.wire_quantize))
    # serving table: embedding only, no optimizer slots in the row
    client = PSClient(cluster, [TableSpec("tok_emb", RowSchema.embedding(cfg.d_model))])

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)
    ).astype(np.uint64)

    # --- prefill: read-only session over the prompt's working set
    prefill = jax.jit(lambda p, t, wt: T.prefill(cfg, p, t, working_table=wt))
    t0 = time.perf_counter()
    with client.session("tok_emb", prompts, read_only=True) as s:
        logits, cache = prefill(params, jnp.asarray(s.slots), jnp.asarray(s.params))
    pad = max_len - args.prompt_len
    cache = KVCache(
        jnp.pad(cache.k, ((0, 0),) * 3 + ((0, pad), (0, 0))),
        jnp.pad(cache.v, ((0, 0),) * 3 + ((0, pad), (0, 0))),
    )
    t_prefill = time.perf_counter() - t0

    # --- decode loop: each new token is pulled into a fresh 1-row-per-seq
    # read-only session (hot rows come from the MEM-PS cache, unpinned)
    decode = jax.jit(
        lambda p, tok, c, pos, wt: T.decode_step(cfg, p, tok, c, pos, working_table=wt)
    )
    out_tokens = []
    tok_ids = np.asarray(greedy_sample(logits)).astype(np.uint64)
    t0 = time.perf_counter()
    for i in range(args.new_tokens):
        with client.session("tok_emb", tok_ids, read_only=True) as s:
            logits, cache = decode(
                params, jnp.asarray(s.slots), cache,
                jnp.int32(args.prompt_len + i), jnp.asarray(s.params),
            )
        tok_ids = np.asarray(greedy_sample(logits)).astype(np.uint64)
        out_tokens.append(tok_ids[:, 0])
    t_decode = time.perf_counter() - t0

    tps = args.batch * args.new_tokens / t_decode
    print(f"prefill: {args.batch}x{args.prompt_len} tokens in {t_prefill*1e3:.0f} ms")
    print(f"decode: {args.new_tokens} steps x {args.batch} seqs = {tps:,.0f} tok/s")
    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    print(f"PS hit rate across decode pulls: {hits/(hits+misses):.1%}")
    if args.wire_quantize:
        net = cluster.network
        print(f"wire-quantized replies: {net.quantized_messages} "
              f"({net.quantize_bytes_saved/2**10:.0f} KiB saved on the NIC)")
    print("sampled:", np.stack(out_tokens, axis=1)[0][:16], "...")
    cluster.destroy()


if __name__ == "__main__":
    main()
