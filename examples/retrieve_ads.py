"""End-to-end ad retrieval demo: train -> publish -> index -> top-k serve.

The retrieval subsystem (DESIGN.md §12) as a production handoff:

1. a short CTR training run grows an embedding table through the full
   hierarchical PS (the same path ``train_ctr_e2e.py`` exercises at scale);
2. the trained state publishes as an immutable snapshot version (manifest
   repoint, no parameter copy);
3. a :class:`RetrievalEngine` binds that version — it scans the table's
   live rows into a device-resident, lane-aligned corpus — and serves
   ``search(queries, k)`` via blocked top-k MIPS;
4. each served user's pooled feature embedding becomes the query, and the
   feature-interaction ``rerank`` stage re-scores the candidates;
5. a second training burst + publish + ``roll_forward`` shows the index
   rolling to the new version atomically.

Run:  PYTHONPATH=src python examples/retrieve_ads.py [--batches 6]
"""

import argparse
import tempfile

import numpy as np

from repro.configs.ctr_models import TINY
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream
from repro.retrieval import RetrievalEngine
from repro.serve import SnapshotPublisher
from repro.train.trainer import CTRTrainer, TrainerConfig


def pooled_user_queries(engine, table, batch, dim):
    """Sum-pool each example's feature embeddings into its query vector."""
    emb = engine.lookup(table, batch.keys)  # [B, nnz, dim]
    return np.einsum("bn,bnd->bd", batch.valid.astype(np.float32), emb), batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batches", type=int, default=6)
    ap.add_argument("--topk", type=int, default=5)
    args = ap.parse_args()
    cfg = TINY

    with tempfile.TemporaryDirectory() as tmp:
        cluster = Cluster(2, f"{tmp}/train", dim=cfg.emb_dim * 2,
                          cache_capacity=4096, file_capacity=256,
                          init_cols=cfg.emb_dim)
        trainer = CTRTrainer(cfg, cluster, TrainerConfig())
        stream = SyntheticCTRStream(cfg.n_sparse_keys, cfg.nnz_per_example,
                                    cfg.n_slots, cfg.batch_size, seed=3)
        print(f"== training {args.batches} batches on {cfg.name!r}")
        for r in trainer.run(iter(stream), args.batches):
            print(f"   batch {r['batch_id']}: loss {r['loss']:.4f}")

        publisher = SnapshotPublisher(cluster, f"{tmp}/snap")
        v1 = publisher.publish()
        print(f"== published snapshot version {v1}")

        engine = trainer.client.serving_view(snapshots=publisher,
                                             cache_rows=4096)
        retr = RetrievalEngine(engine, trainer.table, retain_cluster=cluster)
        idx = retr._index
        print(f"== index: {idx.n_rows} ads, corpus {tuple(idx.corpus.shape)}, "
              f"version {retr.version}")

        queries, batch = pooled_user_queries(
            engine, trainer.table, stream.next_batch(), cfg.emb_dim
        )
        res = retr.search(queries[:4], args.topk)
        print(f"== top-{args.topk} ads for 4 users (version {res.version})")
        for b in range(4):
            pairs = ", ".join(
                f"{int(k)}:{s:.3f}"
                for k, s in zip(res.ad_keys[b], res.scores[b])
            )
            print(f"   user {b}: {pairs}")

        rr = retr.rerank(res, batch.keys[:4], batch.slot_of[:4],
                         batch.valid[:4], n_slots=cfg.n_slots)
        print("== after feature-interaction rerank")
        for b in range(4):
            pairs = ", ".join(
                f"{int(k)}:{s:.3f}" for k, s in zip(rr.ad_keys[b], rr.scores[b])
            )
            print(f"   user {b}: {pairs}")

        print(f"== training {args.batches} more batches, then rolling forward")
        for _ in trainer.run(iter(stream), args.batches):
            pass
        v2 = publisher.publish()
        retr.roll_forward()
        res2 = retr.search(queries[:4], args.topk)
        print(f"== rolled {v1} -> {v2}; top ad for user 0 now "
              f"{int(res2.ad_keys[0, 0])}:{res2.scores[0, 0]:.3f}")

        print("== retrieval counters")
        for name, val in sorted(retr.counters.snapshot().items()):
            if val:
                print(f"   {name}: {val}")
        retr.close()


if __name__ == "__main__":
    main()
