"""Train an LM with the hierarchical-PS embedding path (paper technique on
an assigned architecture family).

A reduced yi-style decoder trains on synthetic zipf tokens; the token
embedding lives in a named PS table ("tok_emb", rows = [emb | adagrad]),
pulled per batch as a working-table session, while the backbone trains
under AdamW — the exact integration the full-scale dry-run lowers for all
10 archs. Because tables are named and key-namespaced, this LM table can
co-host with CTR slot tables on the same cluster (tests/test_system.py).

Run:  PYTHONPATH=src python examples/train_lm_hierps.py [--steps 100]
"""

import argparse
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config, replace
from repro.core.client import PSClient
from repro.core.node import Cluster
from repro.core.tables import RowSchema, TableSpec
from repro.data.tokens import TokenStream
from repro.models import transformer as T
from repro.train.optim import AdamW
from repro.train.train_step import TrainSettings, make_lm_train_step_hier


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()

    cfg = replace(
        get_smoke_config("yi-9b"),
        n_layers=4, d_model=128, n_heads=8, n_kv_heads=2, d_ff=512,
        head_dim=16, vocab_size=8192, embedding_mode="hier_ps",
    )
    params = T.init(cfg, jax.random.PRNGKey(0))
    from repro.models.common import param_count

    print(f"backbone params: {param_count(T.schema(cfg))/1e6:.1f}M + "
          f"{cfg.vocab_size * cfg.d_model/1e6:.1f}M embedding rows on the PS")

    tmp = tempfile.mkdtemp(prefix="hps_lm_")
    cluster = Cluster(2, tmp, dim=cfg.d_model * 2, cache_capacity=6000,
                      file_capacity=512, init_scale=0.02)
    client = PSClient(
        cluster, [TableSpec("tok_emb", RowSchema.with_adagrad(cfg.d_model))]
    )

    settings = TrainSettings(optimizer=AdamW(lr=3e-4), microbatches=1, row_lr=0.1)
    step = jax.jit(make_lm_train_step_hier(cfg, settings))
    opt_state = settings.optimizer.init(params)

    stream = TokenStream(cfg.vocab_size, batch_size=8, seq_len=128, seed=0)
    losses = []
    t0 = time.perf_counter()
    for i in range(args.steps):
        toks = stream.next_batch()
        inputs, targets = toks[:, :-1], toks[:, 1:]
        with client.session("tok_emb", inputs.astype(np.uint64)) as s:
            batch = {"tokens": jnp.asarray(s.slots), "targets": jnp.asarray(targets)}
            params, opt_state, metrics, new_t, new_acc = step(
                params, opt_state, batch, jnp.asarray(s.params), jnp.asarray(s.opt_state)
            )
            s.commit(np.asarray(new_t), np.asarray(new_acc))
        losses.append(float(metrics["loss"]))
        if (i + 1) % 20 == 0:
            print(f"step {i+1}: loss {np.mean(losses[-20:]):.4f} "
                  f"(working set {s.n_working} rows)")
    dt = time.perf_counter() - t0
    print(f"{args.steps} steps in {dt:.0f}s; loss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}")
    hits = sum(n.mem.stats.hits for n in cluster.nodes)
    misses = sum(n.mem.stats.misses for n in cluster.nodes)
    print(f"embedding-row cache hit rate: {hits/(hits+misses):.1%}")
    cluster.destroy()


if __name__ == "__main__":
    main()
