"""Fig 4b: MEM-PS local vs remote parameter pulls over 1/2/4 nodes.

Reproduces the paper's observation that total pull time stays roughly flat
with node count: local SSD work shrinks ~1/N while remote requests grow,
and the two run in parallel. Remote time includes the simulated 100Gb NIC.
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import QUICK, emit, note
from repro.core.node import Cluster, NetworkModel
from repro.data.synthetic_ctr import SyntheticCTRStream


def main() -> None:
    note("Fig 4b: local/remote pull split vs node count (model E scaled)")
    n_keys, nnz, batch = 400_000, 100, 2048
    n_batches = 4 if QUICK else 8
    for n_nodes in (1, 2, 4):
        with tempfile.TemporaryDirectory() as tmp:
            cl = Cluster(
                n_nodes, tmp, dim=16,
                cache_capacity=60_000, file_capacity=4096,
                network=NetworkModel(),
            )
            stream = SyntheticCTRStream(n_keys, nnz, 32, batch, seed=0)
            for _ in range(n_batches):
                b = stream.next_batch()
                uniq = np.unique(b.keys)
                cl.pull(uniq, requester=0, pin=False)
            total = cl.pull_local_time + cl.pull_remote_time + cl.network.virtual_time
            emit(
                f"fig4b.nodes{n_nodes}",
                total / n_batches * 1e6,
                f"local_s={cl.pull_local_time:.3f} remote_s={cl.pull_remote_time:.3f} "
                f"nic_virtual_s={cl.network.virtual_time:.4f}",
            )


if __name__ == "__main__":
    main()
