"""MEM-PS benchmarks: Fig 4b pull split + the batch hot-path trajectory.

Two parts:

* ``main()`` — the paper's Fig 4b observation (local vs remote pull time
  stays roughly flat with node count), unchanged harness contract.
* ``bench_throughput()`` — pull/push rows-per-second of one MEM-PS at
  10k/100k unique keys plus a Zipf hit-rate sweep, written to
  ``BENCH_mem_ps.json`` at the repo root. This file is the perf
  trajectory: future PRs compare against it before touching the hot path
  (`python benchmarks/run.py --smoke` regenerates it in <60s).

``SEED_BASELINE_ROWS_PER_S`` pins the pre-vectorization (per-key
OrderedDict loop) numbers measured in this container, so the recorded
speedup is against a fixed reference rather than a moving one.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import QUICK, emit, note
from repro.core.mem_ps import MemParameterServer
from repro.core.node import Cluster, NetworkModel
from repro.core.ssd_ps import SSDParameterServer
from repro.data.synthetic_ctr import SyntheticCTRStream

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_mem_ps.json")

# rows/s of the seed's per-key-loop MEM-PS, measured in this container
# (dim=16, warm cache, 2x-capacity, sorted unique keys) before the
# vectorized rewrite — the fixed reference for the perf trajectory.
SEED_BASELINE_ROWS_PER_S = {
    "10000": {"pull_hit": 381_199, "push": 697_528},
    "100000": {"pull_hit": 403_495, "push": 727_060},
}


def _best(fn, repeats: int, warmup: int = 6) -> float:
    for _ in range(warmup):  # page-fault / frequency-scaling warmup
        fn()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def bench_throughput(out_path: str = BENCH_JSON) -> dict:
    note("MEM-PS batch hot path: pull/push rows-per-second (perf trajectory)")
    repeats = 5 if QUICK else 9
    dim = 16
    results: dict = {
        "bench": "mem_ps",
        "dim": dim,
        "quick": QUICK,
        "seed_baseline_rows_per_s": SEED_BASELINE_ROWS_PER_S,
        "throughput": {},
        "hit_rate_sweep": [],
    }
    for n in (10_000, 100_000):
        with tempfile.TemporaryDirectory() as tmp:
            ssd = SSDParameterServer(tmp, dim=dim, file_capacity=4096)
            mem = MemParameterServer(ssd, capacity=2 * n)
            keys = np.sort(
                np.random.default_rng(0).permutation(np.arange(3 * n, dtype=np.uint64))[:n]
            )
            t0 = time.perf_counter()
            mem.pull(keys, pin=False)  # cold: SSD-miss path
            t_cold = time.perf_counter() - t0
            rows = mem.pull(keys, pin=True)
            mem.push(keys, rows)  # warm both paths
            t_pull = _best(lambda: mem.pull(keys, pin=True), repeats)
            t_push = _best(lambda: mem.push(keys, rows), repeats)
            entry = {
                "pull_cold_rows_per_s": round(n / t_cold),
                "pull_hit_rows_per_s": round(n / t_pull),
                "push_rows_per_s": round(n / t_push),
                "pull_push_cycle_ms": round((t_pull + t_push) * 1e3, 3),
            }
            results["throughput"][str(n)] = entry
            emit(f"mem_ps.pull_hit.{n}", t_pull * 1e6,
                 f"rows_per_s={entry['pull_hit_rows_per_s']}")
            emit(f"mem_ps.push.{n}", t_push * 1e6,
                 f"rows_per_s={entry['push_rows_per_s']}")
            base = SEED_BASELINE_ROWS_PER_S[str(n)]
            seed_cycle = n / base["pull_hit"] + n / base["push"]
            speed = {
                "pull_hit": round(entry["pull_hit_rows_per_s"] / base["pull_hit"], 2),
                "push": round(entry["push_rows_per_s"] / base["push"], 2),
                # the headline gate: combined pull+push cycle time vs seed
                "pull_push_cycle": round(seed_cycle / (t_pull + t_push), 2),
            }
            results["throughput"][str(n)]["speedup_vs_seed"] = speed
            note(
                f"n={n}: {speed['pull_hit']}x pull, {speed['push']}x push, "
                f"{speed['pull_push_cycle']}x pull+push cycle vs seed"
            )
    # Zipf hit-rate sweep (Fig 4c flavour): capacity vs achieved hit rate
    n_hot, batches = 4096, (10 if QUICK else 50)
    for capacity in (256, 512, 1024, 2048):
        with tempfile.TemporaryDirectory() as tmp:
            ssd = SSDParameterServer(tmp, dim=dim, file_capacity=1024)
            mem = MemParameterServer(ssd, capacity=capacity)
            rng = np.random.default_rng(1)
            for _ in range(batches):
                ranks = (rng.zipf(1.2, size=256) - 1) % n_hot
                mem.pull(np.unique(ranks.astype(np.uint64)), pin=False)
            results["hit_rate_sweep"].append(
                {"capacity": capacity, "key_space": n_hot,
                 "hit_rate": round(mem.stats.hit_rate, 4)}
            )
            emit(f"mem_ps.hit_rate.cap{capacity}", 0.0,
                 f"hit_rate={mem.stats.hit_rate:.3f}")
    with open(out_path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    note(f"perf trajectory written to {os.path.abspath(out_path)}")
    return results


def main() -> None:
    note("Fig 4b: local/remote pull split vs node count (model E scaled)")
    n_keys, nnz, batch = 400_000, 100, 2048
    n_batches = 4 if QUICK else 8
    for n_nodes in (1, 2, 4):
        with tempfile.TemporaryDirectory() as tmp:
            cl = Cluster(
                n_nodes, tmp, dim=16,
                cache_capacity=60_000, file_capacity=4096,
                network=NetworkModel(),
            )
            stream = SyntheticCTRStream(n_keys, nnz, 32, batch, seed=0)
            for _ in range(n_batches):
                b = stream.next_batch()
                uniq = np.unique(b.keys)
                cl.pull(uniq, requester=0, pin=False)
            total = cl.pull_local_time + cl.pull_remote_time + cl.network.virtual_time
            emit(
                f"fig4b.nodes{n_nodes}",
                total / n_batches * 1e6,
                f"local_s={cl.pull_local_time:.3f} remote_s={cl.pull_remote_time:.3f} "
                f"nic_virtual_s={cl.network.virtual_time:.4f}",
            )
    bench_throughput()


if __name__ == "__main__":
    main()
