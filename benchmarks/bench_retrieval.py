"""Retrieval bench: top-k MIPS QPS/latency + recall@k vs the exact oracle.

One published snapshot's ad table becomes a :class:`RetrievalIndex`; the
bench then streams query batches through ``RetrievalEngine.search`` and
reports:

  (a) **search** — QPS (queries/s), per-batch p50/p99 latency, via the
      backend the dispatcher picks for this host (the portable jnp arm off
      TPU; the Pallas kernel on it).
  (b) **recall@k vs oracle** — every search result is checked against
      ``kernels.ref.topk_mips_ref`` on the same corpus. Embeddings are
      drawn on a dyadic grid (1/64 steps) so blocked and full matmuls are
      bitwise-equal in f32: the acceptance bar is recall == 1.0 *and*
      exact score/index equality, not approximate overlap.
  (c) **pallas parity sample** — a small query slice through the Pallas
      kernel (``interpret=True`` off TPU), equality-checked against the
      same oracle, so the kernel arm is exercised even where it is too
      slow to time honestly.
  (d) **rerank** — the feature-interaction second stage's per-batch cost.

Alternating best-of ``repeats`` timing (bench-noise protocol, see
BENCH_pipeline). Counters come from ``engine.counters`` — the same source
tests assert on. Results land in ``BENCH_retrieval.json``.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import QUICK, emit, note
from repro.core.client import PSClient
from repro.core.node import Cluster
from repro.core.tables import RowSchema, TableSpec
from repro.kernels import ref as kref
from repro.retrieval import RetrievalEngine
from repro.serve import SnapshotPublisher

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_retrieval.json")

DIM = 32
TABLE = "ads"
K = 10


def _dyadic(rng, shape):
    return (rng.integers(-128, 128, size=shape) / 64.0).astype(np.float32)


def main() -> None:
    note("retrieval: blocked top-k MIPS over one published snapshot")
    n_ads = 20_000 if QUICK else 50_000
    batch = 64
    n_requests = 24 if QUICK else 48
    repeats = 3 if QUICK else 5
    results: dict = {"quick": QUICK, "n_ads": n_ads, "dim": DIM, "k": K,
                     "batch": batch, "n_requests": n_requests,
                     "repeats": repeats}

    with tempfile.TemporaryDirectory() as tmp:
        cluster = Cluster(2, f"{tmp}/train", dim=DIM,
                          cache_capacity=2 * n_ads, file_capacity=4096)
        client = PSClient(cluster, [TableSpec(TABLE, RowSchema.embedding(DIM))])
        rng = np.random.default_rng(0)
        keys = np.arange(n_ads, dtype=np.uint64)
        rows = _dyadic(rng, (n_ads, DIM))
        cluster.push(keys, rows, unpin=False)
        publisher = SnapshotPublisher(cluster, f"{tmp}/snap")
        publisher.publish()

        engine = client.serving_view(snapshots=publisher, cache_rows=4096)
        t0 = time.perf_counter()
        retr = RetrievalEngine(engine, TABLE)
        build_s = time.perf_counter() - t0
        emit("retrieval.index_build", build_s * 1e6,
             f"rows={retr._index.n_rows};corpus={tuple(retr._index.corpus.shape)}")
        results["index_build"] = {"seconds": build_s, "rows": n_ads}

        queries = [_dyadic(rng, (batch, DIM)) for _ in range(n_requests)]

        retr.search(queries[0], K)  # warm (jit compile)
        best = float("inf")
        lat_best = None
        for _ in range(repeats):
            lat = np.empty(n_requests)
            t0 = time.perf_counter()
            for i, q in enumerate(queries):
                t1 = time.perf_counter()
                retr.search(q, K)
                lat[i] = time.perf_counter() - t1
            total = time.perf_counter() - t0
            if total < best:
                best, lat_best = total, lat
        n_q = n_requests * batch
        qps = n_q / best
        p50 = float(np.percentile(lat_best, 50)) * 1e6
        p99 = float(np.percentile(lat_best, 99)) * 1e6
        emit("retrieval.search", best / n_requests * 1e6,
             f"qps={qps:.0f};p50_us={p50:.1f};p99_us={p99:.1f}")
        results["search"] = {"qps": qps, "p50_us": p50, "p99_us": p99,
                             "us_per_batch": best / n_requests * 1e6}

        # recall@k vs the exact oracle — every request, score+index equality
        hits = total_k = 0
        exact = True
        for q in queries:
            res = retr.search(q, K)
            want_v, want_i = kref.topk_mips_ref(q, rows, K)
            want_v, want_i = np.asarray(want_v), np.asarray(want_i)
            exact = exact and (np.array_equal(res.scores, want_v)
                               and np.array_equal(res.indices, want_i))
            for b in range(batch):
                hits += len(np.intersect1d(res.indices[b], want_i[b]))
                total_k += K
        recall = hits / total_k
        emit("retrieval.recall", recall, f"exact_match={exact};k={K}")
        results["recall_at_k"] = {"recall": recall, "exact_match": exact}

        # pallas kernel arm (interpret off-TPU): parity sample, not a timing
        pal = RetrievalEngine(engine, TABLE, use_pallas=True,
                              block_q=64, block_n=1024)
        res = pal.search(queries[0][:8], K)
        want_v, want_i = kref.topk_mips_ref(queries[0][:8], rows, K)
        pal_exact = (np.array_equal(res.scores, np.asarray(want_v))
                     and np.array_equal(res.indices, np.asarray(want_i)))
        emit("retrieval.pallas_parity", float(pal_exact), "sample_queries=8")
        results["pallas_parity_sample"] = bool(pal_exact)
        pal.close()

        # feature-interaction rerank stage
        uk = rng.integers(0, n_ads, size=(batch, 8)).astype(np.uint64)
        so = rng.integers(0, 4, size=(batch, 8)).astype(np.int32)
        va = np.ones((batch, 8), bool)
        res = retr.search(queries[0], K)
        retr.rerank(res, uk, so, va, n_slots=4)  # warm
        best_rr = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            retr.rerank(res, uk, so, va, n_slots=4)
            best_rr = min(best_rr, time.perf_counter() - t0)
        emit("retrieval.rerank", best_rr * 1e6, f"batch={batch};nnz=8")
        results["rerank"] = {"us_per_batch": best_rr * 1e6}

        results["counters"] = retr.counters.snapshot()
        retr.close()

    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    note(f"recorded -> {os.path.normpath(BENCH_JSON)}")


if __name__ == "__main__":
    main()
