"""Serving-path bench: QPS + lookup latency through the ServingEngine.

Compares, on one published snapshot with a zipf-skewed request stream:

  (a) **baseline** — the PR-3 serving surface: one read-only ``BatchSession``
      per request (fresh session object, MEM-PS pull path, no serving
      cache). Its MEM-PS is deliberately sized DRAM-resident, so this is a
      *warm* baseline — the headline speedup is not an SSD-vs-DRAM trick.
  (b) **engine (hot)** — ``ServingEngine.lookup`` with the version-keyed
      hot-row cache warm: the request's rows come out of the serving cache
      with no cluster/session machinery per request.
  (c) **engine (coalesced)** — 8 request streams merged per
      ``lookup_many`` call: one deduped pull serves all streams.

Noise protocol (see BENCH_pipeline / memory: single-shot ratios swing
wildly in this container): each (baseline, hot) pair is timed in
**alternation** ``repeats`` times and the speedup is best-vs-best, which is
symmetric under noise. Latency percentiles come from the best rep's
per-request times.

Bytes-on-wire are measured separately with cache and MEM-PS out of the
picture (cold pulls on a fresh NIC model), f32 vs int8 wire.

Counters come from ``engine.counters`` (metrics.Counters) — the same
source tests assert on. Results land in ``BENCH_serving.json``.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import QUICK, emit, note
from repro.core.client import PSClient
from repro.core.node import Cluster, NetworkModel
from repro.core.tables import RowSchema, TableSpec
from repro.serve import ServingCluster, ServingEngine, SnapshotPublisher

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_serving.json")

DIM = 32
TABLE = "ads"


def _requests(rng, n_keys: int, n_requests: int, batch: int) -> list[np.ndarray]:
    z = rng.zipf(1.1, size=(n_requests, batch))
    return list(((z - 1) % n_keys).astype(np.uint64))


def _time_pass(fn, requests) -> tuple[float, np.ndarray]:
    """(total seconds, per-request seconds) for one pass over the stream."""
    lat = np.empty(len(requests))
    t0 = time.perf_counter()
    for i, q in enumerate(requests):
        t1 = time.perf_counter()
        fn(q)
        lat[i] = time.perf_counter() - t1
    return time.perf_counter() - t0, lat


def main() -> None:
    note("serving: ServingEngine (hot cache, coalescing) vs per-request sessions")
    n_keys = 20_000 if QUICK else 100_000
    batch = 512
    n_requests = 48 if QUICK else 200
    repeats = 3 if QUICK else 5
    results: dict = {"quick": QUICK, "n_keys": n_keys, "batch": batch,
                     "n_requests": n_requests, "repeats": repeats}

    with tempfile.TemporaryDirectory() as tmp:
        cluster = Cluster(2, f"{tmp}/train", dim=DIM,
                          cache_capacity=2 * n_keys, file_capacity=4096)
        client = PSClient(cluster, [TableSpec(TABLE, RowSchema.embedding(DIM))])
        rng = np.random.default_rng(0)
        all_keys = np.arange(n_keys, dtype=np.uint64)
        cluster.push(all_keys, rng.normal(size=(n_keys, DIM)).astype(np.float32),
                     unpin=False)
        publisher = SnapshotPublisher(cluster, f"{tmp}/snap")
        publisher.publish()
        requests = _requests(rng, n_keys, n_requests, batch)

        def baseline(q):
            with client.session(TABLE, q, read_only=True) as s:
                return s.params

        engine = client.serving_view(snapshots=publisher, cache_rows=2 * n_keys)

        def hot(q):
            return engine.lookup(TABLE, q)

        # warm both paths (baseline's MEM-PS + the engine's hot cache)
        _time_pass(baseline, requests)
        _time_pass(hot, requests)

        # alternating best-of repeats (bench-noise protocol)
        best_base = best_hot = float("inf")
        lat_hot = None
        ratios = []
        for _ in range(repeats):
            t_b, _ = _time_pass(baseline, requests)
            t_h, lat = _time_pass(hot, requests)
            ratios.append(t_b / t_h)
            best_base = min(best_base, t_b)
            if t_h < best_hot:
                best_hot, lat_hot = t_h, lat
        speedup = best_base / best_hot
        c = engine.counters.snapshot()
        hit_rate = c["hot_hits"] / max(1, c["hot_hits"] + c["hot_misses"])
        emit("serving.session_baseline", best_base / n_requests * 1e6,
             f"qps={n_requests / best_base:.0f}")
        emit("serving.engine_hot", best_hot / n_requests * 1e6,
             f"qps={n_requests / best_hot:.0f};speedup_vs_sessions={speedup:.2f}x"
             f";ratios={'/'.join(f'{r:.2f}' for r in ratios)}")
        emit("serving.latency", float(np.percentile(lat_hot, 50)) * 1e6,
             f"p99_us={np.percentile(lat_hot, 99) * 1e6:.1f};hit_rate={hit_rate:.3f}")
        results["session_baseline"] = {
            "us_per_request": best_base / n_requests * 1e6,
            "qps": n_requests / best_base,
        }
        results["engine_hot"] = {
            "us_per_request": best_hot / n_requests * 1e6,
            "qps": n_requests / best_hot,
            "p50_us": float(np.percentile(lat_hot, 50)) * 1e6,
            "p99_us": float(np.percentile(lat_hot, 99)) * 1e6,
            "speedup_vs_sessions": speedup,
            "speedup_ratios": ratios,
            "hot_hit_rate": hit_rate,
        }

        # coalesced multi-stream: 8 streams per merged call
        n_streams = 8
        groups = [requests[i : i + n_streams]
                  for i in range(0, len(requests) - n_streams + 1, n_streams)]
        engine.lookup_many([(TABLE, q) for q in groups[0]])  # warm
        best_co = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            for g in groups:
                engine.lookup_many([(TABLE, q) for q in g])
            best_co = min(best_co, time.perf_counter() - t0)
        n_served = len(groups) * n_streams
        emit("serving.engine_coalesced", best_co / n_served * 1e6,
             f"qps={n_served / best_co:.0f};streams={n_streams}")
        results["engine_coalesced"] = {
            "us_per_request": best_co / n_served * 1e6,
            "qps": n_served / best_co,
            "streams_per_merge": n_streams,
        }

        # bytes on wire: cold pulls, fresh NIC, f32 vs int8 (no cache/MEM-PS)
        wire = {}
        for tag, quant in (("f32", False), ("int8", True)):
            net = NetworkModel(wire_quantize=quant)
            cold = ServingEngine(
                ServingCluster(publisher.dir, network=net), cache_rows=0
            )
            for q in requests[:8]:
                cold.lookup(TABLE, q)
            wire[tag] = {"bytes_moved": net.bytes_moved,
                         "quantize_bytes_saved": net.quantize_bytes_saved}
        saved = 1 - wire["int8"]["bytes_moved"] / max(1, wire["f32"]["bytes_moved"])
        emit("serving.wire_bytes", wire["f32"]["bytes_moved"],
             f"int8_bytes={wire['int8']['bytes_moved']};saved_frac={saved:.2f}")
        results["wire"] = wire
        # final snapshot, AFTER the coalesced phase, so the recorded
        # coalesced_requests reflect the bench that sits next to it
        results["counters"] = engine.counters.snapshot()

    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
        f.write("\n")
    note(f"recorded -> {os.path.normpath(BENCH_JSON)}")


if __name__ == "__main__":
    main()
