"""Fig 4a: HBM-PS op time distribution — pull/push vs train compute.

The paper's finding: pull/push scales with #nonzeros per example, train
scales with the dense-tower size. We time the three device phases (working-
row gather, scatter-accumulate, dense fwd/bwd) for models with 100 vs 500
nnz and different towers.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, emit, note, time_call
from repro.configs.ctr_models import SCALED
from repro.kernels import ref as kref
from repro.models import ctr as ctr_model
from repro.train.optim import AdamW


def main() -> None:
    note("Fig 4a: device-phase times (gather 'pull' / scatter 'push' / train)")
    models = ["A", "C"] if QUICK else ["A", "B", "C", "D", "E"]
    B = 2048
    for tag in models:
        cfg = SCALED[tag]
        n_working = min(cfg.n_sparse_keys, B * cfg.nnz_per_example)
        key = jax.random.PRNGKey(0)
        table = jax.random.normal(key, (n_working, cfg.emb_dim))
        ids = jax.random.randint(key, (B, cfg.nnz_per_example), 0, n_working)
        slot_of = jax.random.randint(key, (B, cfg.nnz_per_example), 0, cfg.n_slots)
        valid = jnp.ones((B, cfg.nnz_per_example), bool)
        labels = jnp.asarray(np.random.default_rng(0).integers(0, 2, B).astype(np.float32))
        tower = ctr_model.init_tower(cfg, key)

        pull = jax.jit(lambda t, i: jnp.take(t, i.reshape(-1), axis=0))
        grads = jax.random.normal(key, (B * cfg.nnz_per_example, cfg.emb_dim))
        push = jax.jit(lambda t, i, g: t.at[i.reshape(-1)].add(g))
        train = jax.jit(
            jax.grad(
                lambda tw, tb: ctr_model.loss_fn(cfg, tw, tb, ids, slot_of, valid, labels),
                argnums=(0, 1),
            )
        )

        t_pull = time_call(lambda: jax.block_until_ready(pull(table, ids)))
        t_push = time_call(lambda: jax.block_until_ready(push(table, ids, grads)))
        t_train = time_call(lambda: jax.block_until_ready(train(tower, table)))
        tot = t_pull + t_push + t_train
        emit(
            f"fig4a.{tag}",
            tot * 1e6,
            f"pull={t_pull/tot*100:.0f}% push={t_push/tot*100:.0f}% train={t_train/tot*100:.0f}% nnz={cfg.nnz_per_example}",
        )


if __name__ == "__main__":
    main()
