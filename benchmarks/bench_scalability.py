"""Fig 5b: training throughput speedup over 1/2/4 nodes.

On one host, N logical nodes share the CPU, so wall-clock scaling is
meaningless; we reproduce the paper's *model* of scaling instead: per-batch
virtual time = max over nodes of (local SSD/cache work of its key shard) +
NIC transfer time for remote rows, with each node processing 1/N of the
global batch. The derived column reports speedup vs 1 node (paper: 3.57/4).
"""

from __future__ import annotations

import tempfile
import time

import numpy as np

from benchmarks.common import QUICK, emit, note
from repro.core.node import Cluster, NetworkModel
from repro.data.synthetic_ctr import SyntheticCTRStream


def run(n_nodes: int, tmp: str) -> float:
    """Virtual seconds per global batch."""
    n_keys, nnz, global_batch = 200_000, 100, 4096
    n_batches = 3 if QUICK else 6
    cl = Cluster(n_nodes, f"{tmp}/n{n_nodes}", dim=16, cache_capacity=50_000 // n_nodes,
                 file_capacity=4096, network=NetworkModel())
    stream = SyntheticCTRStream(n_keys, nnz, 32, global_batch, seed=0)
    virtual = 0.0
    for _ in range(n_batches):
        b = stream.next_batch()
        per_node = np.array_split(np.unique(b.keys), n_nodes)
        node_times = []
        for req, shard_keys in enumerate(per_node):
            t0 = time.perf_counter()
            lt0, rt0 = cl.pull_local_time, cl.pull_remote_time
            nic0 = cl.network.virtual_time
            cl.pull(shard_keys.astype(np.uint64), requester=req, pin=False)
            host = time.perf_counter() - t0
            nic = cl.network.virtual_time - nic0
            node_times.append(host + nic)
        virtual += max(node_times)  # nodes run in parallel
    return virtual / n_batches


def main() -> None:
    note("Fig 5b: scalability 1/2/4 nodes (virtual-time model, shared-host)")
    with tempfile.TemporaryDirectory() as tmp:
        base = run(1, tmp)
        emit("fig5b.nodes1", base * 1e6, "speedup=1.00x")
        for n in (2, 4):
            t = run(n, tmp)
            emit(f"fig5b.nodes{n}", t * 1e6, f"speedup={base / t:.2f}x ideal={n}.0x")


if __name__ == "__main__":
    main()
