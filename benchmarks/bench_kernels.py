"""Kernel micro-benches: portable implementations vs naive references.

On this CPU container the Pallas TPU kernels only run under interpret mode
(correctness, not speed), so the timed comparison is between the *portable*
implementations the models actually execute here (blockwise attention,
gather/scatter) and their naive counterparts; derived columns carry the
memory-footprint reasoning that motivates the TPU kernels.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import QUICK, emit, note, time_call
from repro.kernels import ops, ref


def main() -> None:
    note("kernel microbenches (portable paths; Pallas validated in tests)")
    key = jax.random.PRNGKey(0)
    S = 1024 if QUICK else 2048
    B, H, Hkv, Dh = 1, 8, 2, 64
    q = jax.random.normal(key, (B, H, S, Dh))
    k = jax.random.normal(key, (B, Hkv, S, Dh))
    v = jax.random.normal(key, (B, Hkv, S, Dh))

    naive = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    block = jax.jit(lambda q, k, v: ops.attention_blockwise(q, k, v, causal=True, block_k=512))
    t_naive = time_call(lambda: jax.block_until_ready(naive(q, k, v)))
    t_block = time_call(lambda: jax.block_until_ready(block(q, k, v)))
    scores_mb = B * H * S * S * 4 / 2**20
    blk_mb = B * H * S * 512 * 4 / 2**20
    emit("kernels.attn_naive", t_naive * 1e6, f"scores_mem={scores_mb:.0f}MiB")
    emit(
        "kernels.attn_blockwise",
        t_block * 1e6,
        f"stream_mem={blk_mb:.0f}MiB ratio={t_block / t_naive:.2f}x_time {scores_mb / blk_mb:.0f}x_less_mem",
    )

    N, D, Bk = 100_000, 64, 8192
    table = jax.random.normal(key, (N, D))
    ids = jax.random.randint(key, (Bk,), 0, N)
    grads = jax.random.normal(key, (Bk, D))
    gather = jax.jit(lambda t, i: jnp.take(t, i, axis=0))
    scatter = jax.jit(lambda t, i, g: t.at[i].add(g))
    t_g = time_call(lambda: jax.block_until_ready(gather(table, ids)))
    t_s = time_call(lambda: jax.block_until_ready(scatter(table, ids, grads)))
    emit("kernels.working_gather", t_g * 1e6, f"rows={Bk} touched={Bk*D*4/2**20:.1f}MiB of {N*D*4/2**20:.0f}MiB")
    emit("kernels.working_scatter", t_s * 1e6, f"race_free=sorted-duplicates (Pallas) / XLA scatter-add here")

    p = jax.random.normal(key, (Bk, D))
    a = jnp.abs(jax.random.normal(key, (Bk, D)))
    fused = jax.jit(lambda p, a, g: ref.adagrad_ref(p, a, g, 0.05))
    t_f = time_call(lambda: jax.block_until_ready(fused(p, a, grads)))
    emit("kernels.fused_adagrad", t_f * 1e6, "1 pass vs 4 HBM round-trips unfused")


if __name__ == "__main__":
    main()
