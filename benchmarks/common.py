"""Shared benchmark utilities: timing, AUC, CSV emission.

Every bench prints ``name,us_per_call,derived`` rows (the harness contract)
plus human-readable context lines prefixed with '#'.
"""

from __future__ import annotations

import os
import time

import numpy as np

QUICK = os.environ.get("BENCH_QUICK", "0") == "1"


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def note(msg: str) -> None:
    print(f"# {msg}")


def time_call(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn(*args)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


from repro.metrics import auc  # noqa: F401  (re-export for benches)
