"""End-to-end ingestion bench: raw-record examples/s, device ingest vs host feeder.

The FeatureBox argument (arxiv 2210.07768, ROADMAP "streaming feature/data
pipeline"): at production batch sizes the *feeder* — host-side hashing, slot
bucketing, nnz packing, and per-batch device_put — caps examples/s before
the PS hierarchy does. This bench runs the same raw-record stream through
both arms of the trainer:

  host   — numpy extraction in the feed (extract_host) + the classic
           transfer stage device_put of every batch plane;
  ingest — the §11 subsystem: double-buffered staging ring + fused
           device extraction kernel; only the key plane returns to host.

Both arms consume identical raw records (same seed) and must produce
bitwise-identical losses — the bench asserts it, so the speedup is never
bought with a semantics change. Alongside examples/s, the transfer stage's
share of total stage busy time is recorded for each arm: staging overlap
moves plane uploads off the transfer stage, so its share must drop
measurably (the acceptance criterion).

On a CPU-only container the "device" extraction runs the u32-pair-emulated
splitmix64 on the same cores the feeder would use, which costs more than
numpy's native u64 mix — so raw examples/s may not beat the host arm here;
the structural win (transfer-share drop, staging overlap, device-resident
planes) is what transfers to a real accelerator, where extraction is free
parallel compute off the host entirely.

Results land in ``BENCH_ingest.json`` at the repo root.
"""

from __future__ import annotations

import json
import os
import time

from benchmarks.common import QUICK, emit, note
from repro.configs.ctr_models import CTRConfig
from repro.core.node import Cluster
from repro.data.synthetic_ctr import SyntheticCTRStream, to_ctr_batch
from repro.train.trainer import CTRTrainer, TrainerConfig

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_ingest.json")

# feeder-bound operating point: DRAM-resident key space (pull/push cheap
# after warm-up) with a wide raggedly-packed batch, so batch preparation —
# not the PS hierarchy — is the contended resource the two arms differ on
INGEST_BENCH = CTRConfig(
    name="ctr-ingest",
    n_sparse_keys=200_000,
    nnz_per_example=64,
    emb_dim=8,
    n_slots=16,
    mlp_hidden=(32, 16),
    batch_size=512 if QUICK else 2048,
    minibatches_per_batch=4,
)


def _cluster(tmp: str, tag: str, cfg: CTRConfig) -> Cluster:
    working = min(cfg.n_sparse_keys, cfg.batch_size * cfg.nnz_per_example)
    return Cluster(2, f"{tmp}/{tag}", dim=cfg.emb_dim * 2,
                   cache_capacity=2 * working, file_capacity=16384,
                   init_cols=cfg.emb_dim)


def _raw_stream(cfg: CTRConfig, seed: int = 3):
    return SyntheticCTRStream(cfg.n_sparse_keys, cfg.nnz_per_example,
                              cfg.n_slots, cfg.batch_size, seed=seed)


def _host_feed(cfg: CTRConfig, seed: int = 3):
    return (
        to_ctr_batch(r, cfg.n_sparse_keys, cfg.n_slots, cfg.nnz_per_example)
        for r in _raw_stream(cfg, seed).raw_records()
    )


def _transfer_share(pipe) -> float:
    rep = pipe.report()
    busy = sum(s["busy_s"] for s in rep.values())
    return rep["transfer"]["busy_s"] / max(busy, 1e-12)


def main() -> None:
    import tempfile

    cfg = INGEST_BENCH
    n_batches = 8 if QUICK else 24
    repeats = 2 if QUICK else 3
    note(f"{cfg.name}: B={cfg.batch_size} nnz={cfg.nnz_per_example} "
         f"keys={cfg.n_sparse_keys} batches={n_batches} repeats={repeats}")

    with tempfile.TemporaryDirectory() as tmp:
        tr_h = CTRTrainer(cfg, _cluster(tmp, "host", cfg), TrainerConfig())
        tr_i = CTRTrainer(cfg, _cluster(tmp, "ingest", cfg),
                          TrainerConfig(ingest=True))
        # warm-up: fills the MEM-PS cache and compiles the jit steps
        tr_h.run(_host_feed(cfg), 2)
        tr_i.run(_raw_stream(cfg).raw_records(), 2)

        t_h = t_i = float("inf")
        share_h = share_i = 1.0
        losses_h = losses_i = None
        for _ in range(repeats):  # alternating best-of (noisy container)
            t0 = time.perf_counter()
            losses_h = [r["loss"] for r in tr_h.run(_host_feed(cfg), n_batches)]
            dt = time.perf_counter() - t0
            if dt < t_h:
                t_h, share_h = dt, _transfer_share(tr_h.last_pipeline)

            t0 = time.perf_counter()
            losses_i = [r["loss"]
                        for r in tr_i.run(_raw_stream(cfg).raw_records(), n_batches)]
            dt = time.perf_counter() - t0
            if dt < t_i:
                t_i, share_i = dt, _transfer_share(tr_i.last_pipeline)

        assert losses_i == losses_h, (
            "ingest arm must be bitwise-equal to the host feeder"
        )

        n_ex = n_batches * cfg.batch_size
        eps_h, eps_i = n_ex / t_h, n_ex / t_i
        c = tr_i.ingestor.counters.snapshot()
        emit("ingest.examples_per_s.host", t_h / n_batches * 1e6,
             f"examples_per_s={eps_h:.0f};transfer_share={share_h:.3f}")
        emit("ingest.examples_per_s.device", t_i / n_batches * 1e6,
             f"examples_per_s={eps_i:.0f};transfer_share={share_i:.3f}"
             f";speedup={eps_i / eps_h:.2f}x")
        note(f"staging: bytes={c.get('staging_bytes', 0)} "
             f"wait_us={c.get('ingest_wait_us', 0)} "
             f"overlap_us={c.get('ingest_overlap_us', 0)}")

        result = {
            "config": cfg.name,
            "batch_size": cfg.batch_size,
            "nnz": cfg.nnz_per_example,
            "n_batches": n_batches,
            "host_feeder": {
                "examples_per_s": eps_h,
                "us_per_batch": t_h / n_batches * 1e6,
                "transfer_busy_share": share_h,
            },
            "device_ingest": {
                "examples_per_s": eps_i,
                "us_per_batch": t_i / n_batches * 1e6,
                "transfer_busy_share": share_i,
                "speedup_vs_host": eps_i / eps_h,
                "staging_bytes": c.get("staging_bytes", 0),
                "ingest_batches": c.get("ingest_batches", 0),
                "ingest_wait_us": c.get("ingest_wait_us", 0),
                "ingest_overlap_us": c.get("ingest_overlap_us", 0),
            },
            "transfer_share_reduction": share_h - share_i,
            "bitwise_equal": True,
            "quick": QUICK,
        }
    with open(BENCH_JSON, "w") as f:
        json.dump(result, f, indent=2, sort_keys=True)
        f.write("\n")
    note(f"wrote {os.path.abspath(BENCH_JSON)}")


if __name__ == "__main__":
    main()
